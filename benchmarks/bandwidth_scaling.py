"""Paper Fig 11 + §5.8: performance as function bandwidth grows 1x -> 20x.
FuncPipe keeps an edge through optimized memory allocation even when the
communication bottleneck disappears."""
from __future__ import annotations

import dataclasses

from repro.core.profiler import paper_model_profile
from repro.serverless.frameworks import funcpipe, lambda_ml
from repro.serverless.platform import AWS_LAMBDA


def rows(fast: bool = False):
    out = []
    models = ["amoebanet-d36"] if fast else ["resnet101", "amoebanet-d18",
                                             "amoebanet-d36", "bert-large"]
    scales = [1, 4, 20] if fast else [1, 2, 4, 8, 20]
    for model in models:
        for scale in scales:
            platform = dataclasses.replace(
                AWS_LAMBDA,
                max_function_bandwidth=AWS_LAMBDA.max_function_bandwidth * scale,
            )
            prof = paper_model_profile(model, platform)
            lm = lambda_ml(prof, platform, 64)
            fp = funcpipe(prof, platform, 64)
            rec = fp.recommended_sim
            out.append({
                "bench": "fig11", "model": model, "bw_scale": scale,
                "lambdaml_t": round(lm.t_iter, 2), "lambdaml_c": round(lm.cost, 5),
                "funcpipe_t": round(rec.t_iter, 2), "funcpipe_c": round(rec.cost, 5),
                "speedup": round(lm.t_iter / rec.t_iter, 2),
                "cost_ratio": round(rec.cost / lm.cost, 2),
            })
    return out


def main(fast: bool = False):
    for r in rows(fast):
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
