"""Paper Table 3 analog: performance-model prediction error, measured against
the independent discrete-event simulator (the offline stand-in for the real
testbed; the paper reports ~11% mean error) — plus a third column measuring
the storage-backed execution engine (``repro.serverless.runtime``) against
the same simulator, closing the loop closed-form <-> DP <-> executed."""
from __future__ import annotations

import numpy as np

from repro.core import planner
from repro.core.profiler import paper_model_profile
from repro.serverless.frameworks import ALPHA_PAIRS
from repro.serverless.platform import AWS_LAMBDA
from repro.serverless.runtime import run_plan
from repro.serverless.simulator import simulate_funcpipe

MODELS = ["resnet101", "amoebanet-d18", "amoebanet-d36", "bert-large"]


def rows(fast: bool = False):
    out = []
    models = MODELS[:2] if fast else MODELS
    batches = [64] if fast else [16, 64, 256]
    errs_all = []
    eng_errs_all = []
    for model in models:
        prof = paper_model_profile(model, AWS_LAMBDA)
        errs = []
        eng_errs = []
        for gb in batches:
            M = gb // 4
            for alpha in (ALPHA_PAIRS[1:2] if fast else ALPHA_PAIRS):
                r = planner.solve(prof, AWS_LAMBDA, alpha=alpha,
                                  total_micro_batches=M, merge_to=8)
                if r is None:
                    continue
                sim = simulate_funcpipe(r.profile, AWS_LAMBDA, r.config, M)
                errs.append(abs(r.evaluation.t_iter - sim.t_iter) / sim.t_iter)
                eng = run_plan(r.profile, AWS_LAMBDA, r.config, M)
                eng_errs.append(abs(eng.t_iter - sim.t_iter) / sim.t_iter)
        errs_all += errs
        eng_errs_all += eng_errs
        out.append({
            "bench": "table3", "model": model,
            "mean_err": round(float(np.mean(errs)), 4),
            "max_err": round(float(np.max(errs)), 4),
            "engine_mean_err": round(float(np.mean(eng_errs)), 4),
            "engine_max_err": round(float(np.max(eng_errs)), 4),
            "n": len(errs),
        })
    out.append({"bench": "table3", "model": "AVERAGE",
                "mean_err": round(float(np.mean(errs_all)), 4),
                "max_err": round(float(np.max(errs_all)), 4),
                "engine_mean_err": round(float(np.mean(eng_errs_all)), 4),
                "engine_max_err": round(float(np.max(eng_errs_all)), 4),
                "n": len(errs_all)})
    return out


def main(fast: bool = False):
    for r in rows(fast):
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
