"""Paper Fig 9 + §5.6: co-optimization vs TPDMP-style and Bayes-style
algorithms — training time/cost of the found configs and solver runtime."""
from __future__ import annotations

import time

from repro.core import planner
from repro.core.profiler import paper_model_profile
from repro.serverless.frameworks import ALPHA_PAIRS
from repro.serverless.platform import AWS_LAMBDA
from repro.serverless.simulator import simulate_funcpipe


def rows(fast: bool = False):
    out = []
    models = ["amoebanet-d18"] if fast else ["resnet101", "amoebanet-d18",
                                             "amoebanet-d36", "bert-large"]
    alphas = ALPHA_PAIRS[1:3] if fast else ALPHA_PAIRS
    M = 16  # global batch 64, micro-batch 4 (paper Fig 9 uses gb 64)
    for model in models:
        prof = paper_model_profile(model, AWS_LAMBDA)
        for alpha in alphas:
            kw = dict(alpha=alpha, total_micro_batches=M, merge_to=8)
            for name, fn in [
                ("funcpipe", planner.solve),
                ("tpdmp", planner.tpdmp_solve),
                ("bayes", lambda *a, **k: planner.bayes_solve(*a, rounds=100, seed=0, **k)),
            ]:
                t0 = time.time()
                r = fn(prof, AWS_LAMBDA, **kw)
                dt = time.time() - t0
                if r is None:
                    out.append({"bench": "fig9", "model": model, "alpha2": alpha[1],
                                "algo": name, "status": "infeasible"})
                    continue
                sim = simulate_funcpipe(r.profile, AWS_LAMBDA, r.config, M)
                out.append({
                    "bench": "fig9", "model": model, "alpha2": alpha[1],
                    "algo": name, "t_iter": round(sim.t_iter, 2),
                    "cost": round(sim.cost, 5),
                    "objective": round(r.objective, 6),
                    "solve_s": round(dt, 2),
                })
    return out


def main(fast: bool = False):
    for r in rows(fast):
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
