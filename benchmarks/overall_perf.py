"""Paper Fig 5: overall time/cost vs serverless baselines, 4 models x 3
global batch sizes, on the AWS-Lambda platform model."""
from __future__ import annotations

from repro.core.profiler import paper_model_profile
from repro.serverless.frameworks import funcpipe, lambda_ml
from repro.serverless.platform import AWS_LAMBDA

MODELS = ["resnet101", "amoebanet-d18", "amoebanet-d36", "bert-large"]


def rows(fast: bool = False):
    out = []
    models = MODELS[1:3] if fast else MODELS
    batches = [64] if fast else [16, 64, 256]
    for model in models:
        prof = paper_model_profile(model, AWS_LAMBDA)
        for gb in batches:
            lm = lambda_ml(prof, AWS_LAMBDA, gb)
            hp = lambda_ml(prof, AWS_LAMBDA, gb, ps=True)
            lma = lambda_ml(prof, AWS_LAMBDA, gb, grad_accum=True)
            hpa = lambda_ml(prof, AWS_LAMBDA, gb, grad_accum=True, ps=True)
            fp = funcpipe(prof, AWS_LAMBDA, gb)
            rec = fp.recommended_sim
            cheapest = min(fp.sims, key=lambda s: s.cost)
            out.append({
                "bench": "fig5", "model": model, "global_batch": gb,
                "lambdaml_t": round(lm.t_iter, 2), "lambdaml_c": round(lm.cost, 5),
                "hybridps_t": round(hp.t_iter, 2), "hybridps_c": round(hp.cost, 5),
                "lambdaml_ga_t": round(lma.t_iter, 2) if lma else None,
                "hybridps_ga_t": round(hpa.t_iter, 2) if hpa else None,
                "funcpipe_rec_t": round(rec.t_iter, 2),
                "funcpipe_rec_c": round(rec.cost, 5),
                "funcpipe_min_c": round(cheapest.cost, 5),
                "speedup_vs_lambdaml": round(lm.t_iter / rec.t_iter, 2),
                "cost_red_vs_lambdaml": round(1 - cheapest.cost / lm.cost, 3),
                "pareto_points": len(fp.sims),
            })
    return out


def main(fast: bool = False):
    for r in rows(fast):
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
