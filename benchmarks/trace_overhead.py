"""Tracing-overhead gate: span recording must be ~free when off and cheap
when on.

Times ``run_plan`` on the **local** execution backend (real daemon threads
over the blocking in-process store — the only backend where host wall-clock
is the measurement, so recording overhead is observable) in three modes:

* ``off``      — no recorder attached; the per-op cost is one
  ``tracer is None`` check,
* ``on``       — ``trace=True``: every store op and compute block brackets a
  ``perf_counter`` pair and appends a Span,
* ``emulated`` — the virtual-clock backend traced, as a sanity row (its
  "overhead" is pure bookkeeping; the virtual timings are identical by
  construction).

Each mode reports the **min over reps** of host seconds per step — min, not
mean, because scheduler noise only ever adds time.  ``--check`` enforces the
CI gate ``traced_min <= base_min * 1.05 + 0.05`` (5% relative + 50ms
absolute slack for timer/thread-start jitter on tiny runs) and exits 1 on
breach.  Writes ``BENCH_trace_overhead.json`` at the repo root.

    PYTHONPATH=src python -m benchmarks.trace_overhead [--fast] [--check]
"""
from __future__ import annotations

import json
import os
import time

from repro.core.partition import merge_layers
from repro.core.perfmodel import Config
from repro.core.profiler import paper_model_profile
from repro.serverless.platform import AWS_LAMBDA
from repro.serverless.execution import ExecutionConfig
from repro.serverless.runtime import run_plan

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_JSON = os.path.join(_REPO_ROOT, "BENCH_trace_overhead.json")

# relative + absolute slack of the --check gate (also quoted in ci.yml)
REL_SLACK = 1.05
ABS_SLACK = 0.05


def _plan(d):
    prof = merge_layers(paper_model_profile("bert-large", AWS_LAMBDA), 6)
    L = prof.L
    x = tuple(1 if i == 2 else 0 for i in range(L - 1))
    return prof, Config(x=x, d=d, z=tuple(5 for _ in range(L)))


def _time_once(backend, trace, *, d, M, steps):
    prof, cfg = _plan(d)
    t0 = time.perf_counter()
    res = run_plan(prof, AWS_LAMBDA, cfg, M,
                   ExecutionConfig(steps=steps, backend=backend, trace=trace))
    host = time.perf_counter() - t0
    n_spans = 0 if res.trace is None else len(res.trace.spans)
    return host / steps, n_spans


def rows(fast: bool = False):
    reps = 3 if fast else 5
    d, M, steps = 2, 8, (1 if fast else 2)
    out = []
    for name, backend, trace in (("local_off", "local", False),
                                 ("local_traced", "local", True),
                                 ("emulated_traced", "emulated", True)):
        best, n_spans = min(
            _time_once(backend, trace, d=d, M=M, steps=steps)
            for _ in range(reps))
        out.append({"bench": name, "backend": backend, "traced": trace,
                    "reps": reps, "steps": steps,
                    "min_s_per_step": round(best, 6), "spans": n_spans})
    base = next(r for r in out if r["bench"] == "local_off")
    traced = next(r for r in out if r["bench"] == "local_traced")
    limit = base["min_s_per_step"] * REL_SLACK + ABS_SLACK
    gate = {"bench": "gate", "base_s": base["min_s_per_step"],
            "traced_s": traced["min_s_per_step"], "limit_s": round(limit, 6),
            "ok": traced["min_s_per_step"] <= limit}
    out.append(gate)
    with open(OUT_JSON, "w") as f:
        json.dump(out, f, indent=1)
    return out


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(prog="benchmarks.trace_overhead")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if traced local runs breach the overhead "
                         "gate")
    args = ap.parse_args(argv)
    rs = rows(fast=args.fast)
    for r in rs:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    gate = next(r for r in rs if r["bench"] == "gate")
    if args.check and not gate["ok"]:
        print(f"FAIL: traced local step {gate['traced_s']}s exceeds "
              f"{gate['limit_s']}s ({REL_SLACK:.0%} of untraced "
              f"{gate['base_s']}s + {ABS_SLACK}s)")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
