"""Serving gate: pipelined decode must be bit-identical to the monolithic
decode loop, and the autoscaling simulator deterministic under a fixed seed.

Plans a ``workload="serve"`` deployment for the reduced arch with
:func:`repro.serving.plan_serving`, then runs the pipelined prefill +
token-by-token decode through the execution backends and compares every
token against :func:`repro.serving.reference_decode` — the single-process
oracle running the same model monolithically.  Multi-stage pipelining is
exercised by forcing a 2-stage split of the planned deployment (the SLO
planner prefers 1 stage for models this small: each extra stage adds KV
round-trips and boundary hops to *every* decoded token).  The autoscale row
runs the bursty-arrival simulator twice at one seed and requires
byte-identical output.

``--check`` enforces the CI gate: all token parities hold and the
autoscale table is deterministic.  Writes ``BENCH_serving.json`` at the
repo root (``--fast`` writes ``BENCH_serving_fast.json`` and skips the
process backend, so the tracked file is never clobbered by CI smokes).

    PYTHONPATH=src python -m benchmarks.serving_bench [--fast] [--check]
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax
import numpy as np

from repro.models import registry
from repro.serving import (
    autoscale_plan,
    arch_config_for_model,
    make_prompt,
    plan_serving,
    reference_decode,
    run_serve_plan,
)

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_JSON = os.path.join(_REPO_ROOT, "BENCH_serving.json")
OUT_JSON_FAST = os.path.join(_REPO_ROOT, "BENCH_serving_fast.json")

MODEL = "phi3-mini-3.8b@reduced"
SLO_S = 60.0
BATCH, PREFILL, NEW = 2, 16, 4
SEED = 0


def _parity_row(plan, backend: str, label: str, ref: np.ndarray) -> dict:
    res = run_serve_plan(plan, backend=backend, seed=SEED)
    kv = float((res.store_stats.class_bytes_in or {}).get("kv", 0.0))
    return {
        "bench": label, "backend": backend, "stages": sum(plan.x) + 1,
        "t_request_s": round(res.t_request, 4),
        "cost_per_1k": round(res.cost_per_1k, 6),
        "kv_bytes_in_store": kv,
        "tokens_match_reference": bool(np.array_equal(res.tokens, ref)),
    }


def rows(fast: bool = False):
    plan = plan_serving(MODEL, "aws", slo=SLO_S, batch=BATCH,
                        prefill_tokens=PREFILL, new_tokens=NEW)
    # the oracle: same params + prompt seed as run_serve_plan, one process
    cfg = arch_config_for_model(MODEL)
    params = registry.init_params(cfg, jax.random.PRNGKey(SEED))
    toks = make_prompt(cfg, BATCH, PREFILL, seed=SEED)
    ref = reference_decode(cfg, params, toks, NEW)

    out = [_parity_row(plan, "emulated", "decode_planned", ref)]
    # force multi-stage pipelining: cut after the embed instance
    plan2 = dataclasses.replace(plan, x=(0, 1, 0), z=(0, 0, 0, 0))
    out.append(_parity_row(plan2, "emulated", "decode_2stage", ref))
    if not fast:
        out.append(_parity_row(plan2, "process", "decode_2stage", ref))

    scale_kw = dict(rate=2.0, horizon=90.0, replicas=(1, 2, 4),
                    arrival="bursty", seed=SEED)
    table = [r.as_dict() for r in autoscale_plan(plan, **scale_kw)]
    again = [r.as_dict() for r in autoscale_plan(plan, **scale_kw)]
    deterministic = json.dumps(table) == json.dumps(again)
    for r in table:
        out.append({"bench": "autoscale", "replicas": r["replicas"],
                    "requests": r["requests"], "p50_s": round(r["p50"], 4),
                    "p95_s": round(r["p95"], 4), "p99_s": round(r["p99"], 4),
                    "slo_violation_frac": round(r["slo_violation_frac"], 4),
                    "cold_starts": r["cold_starts"],
                    "cost_per_1k": round(r["cost_per_1k"], 6),
                    "utilization": round(r["utilization"], 4)})

    parities = [r["tokens_match_reference"] for r in out
                if "tokens_match_reference" in r]
    out.append({"bench": "gate", "decode_runs": len(parities),
                "all_tokens_match": all(parities),
                "autoscale_deterministic": deterministic,
                "ok": all(parities) and deterministic})
    with open(OUT_JSON_FAST if fast else OUT_JSON, "w") as f:
        json.dump(out, f, indent=1)
    return out


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(prog="benchmarks.serving_bench")
    ap.add_argument("--fast", action="store_true",
                    help="skip the process backend; write the _fast JSON")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless every pipelined decode matched the "
                         "monolithic reference and the autoscale table is "
                         "seed-deterministic")
    args = ap.parse_args(argv)
    rs = rows(fast=args.fast)
    for r in rs:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    gate = next(r for r in rs if r["bench"] == "gate")
    if args.check and not gate["ok"]:
        print(f"FAIL: tokens_match={gate['all_tokens_match']} "
              f"autoscale_deterministic={gate['autoscale_deterministic']}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
