"""Benchmark aggregator — one module per paper table/figure (see DESIGN.md §6).

Prints ``name,us_per_call,derived`` CSV per the harness convention: each row
times its benchmark module and carries the module's headline derived metric.

    PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import time


def _headline(name: str, rows: list) -> str:
    if name == "scatter_reduce":
        r = [x for x in rows if x["bench"] == "fig8_training"]
        return f"max_sync_reduction={max(x['sync_reduction'] for x in r)}"
    if name == "overall_perf":
        sp = [x["speedup_vs_lambdaml"] for x in rows]
        return f"speedup_range={min(sp)}-{max(sp)}x"
    if name == "scaling":
        return f"max_tp_gain={max(x['tp_gain'] for x in rows)}"
    if name == "coopt":
        ours = [x for x in rows if x.get("algo") == "funcpipe" and "objective" in x]
        return f"funcpipe_solves={len(ours)}"
    if name == "bandwidth_scaling":
        r20 = [x for x in rows if x["bw_scale"] == max(y["bw_scale"] for y in rows)]
        return f"speedup_at_max_bw={r20[0]['speedup']}"
    if name == "perfmodel_accuracy":
        avg = [x for x in rows if x["model"] == "AVERAGE"]
        return f"mean_err={avg[0]['mean_err']}" if avg else "n/a"
    if name == "runtime_accuracy":
        mx = [x for x in rows if x["model"] == "MAX"]
        return f"max_sim_err={mx[0]['sim_rel_err']}" if mx else "n/a"
    if name == "roofline":
        ok = [x for x in rows if x.get("status") == "ok"]
        skip = [x for x in rows if x.get("status") == "skip"]
        return f"lowered={len(ok)};skips={len(skip)}"
    if name == "alibaba":
        return f"max_speedup={max(x['speedup_vs_best_baseline'] for x in rows)}"
    if name == "planner":
        cmp_rows = [x for x in rows if x.get("speedup_vs_scalar") is not None]
        sp = max((x["speedup_vs_scalar"] for x in cmp_rows), default="n/a")
        same = all(x["identical_plan"] for x in cmp_rows)
        dp_ok = all(x["dp_not_worse_than_batch"] for x in rows
                    if x["engine"] == "dp")
        return (f"batch_speedup={sp};identical_plans={same};"
                f"dp_never_worse={dp_ok}")
    if name == "collectives":
        return f"bidi_link_reduction={rows[0]['link_reduction']}"
    if name == "trace_overhead":
        gate = [x for x in rows if x["bench"] == "gate"]
        return f"gate_ok={gate[0]['ok']}" if gate else "n/a"
    if name == "fault_overhead":
        gate = [x for x in rows if x["bench"] == "gate"]
        return f"gate_ok={gate[0]['ok']}" if gate else "n/a"
    if name == "calibration":
        gate = [x for x in rows if x["bench"] == "gate"]
        if not gate:
            return "n/a"
        return (f"err={gate[0]['baseline']}->{gate[0]['residual']};"
                f"gate_ok={gate[0]['ok']}")
    if name == "serving":
        gate = [x for x in rows if x["bench"] == "gate"]
        if not gate:
            return "n/a"
        return (f"tokens_match={gate[0]['all_tokens_match']};"
                f"gate_ok={gate[0]['ok']}")
    return f"rows={len(rows)}"


# bench name -> module path; `python -m repro bench --list` prints these
BENCH_NAMES = (
    "scatter_reduce", "overall_perf", "scaling", "coopt", "planner",
    "bandwidth_scaling", "alibaba", "perfmodel_accuracy", "runtime_accuracy",
    "roofline", "collectives", "trace_overhead", "fault_overhead",
    "calibration", "serving",
)


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(prog="benchmarks.run")
    # no choices= here: py3.10 argparse validates the empty default against it
    ap.add_argument("names", nargs="*",
                    help=f"bench names to run (default: all): {BENCH_NAMES}")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args(argv)
    unknown = set(args.names) - set(BENCH_NAMES)
    if unknown:
        ap.error(f"unknown bench names {sorted(unknown)}; "
                 f"choose from {BENCH_NAMES}")
    fast = args.fast
    from benchmarks import (
        alibaba_bench,
        bandwidth_scaling,
        calibration_bench,
        collectives_bench,
        coopt_bench,
        fault_overhead,
        overall_perf,
        perfmodel_accuracy,
        planner_bench,
        roofline_bench,
        runtime_accuracy,
        scaling,
        scatter_reduce_bench,
        serving_bench,
        trace_overhead,
    )

    benches = [
        ("scatter_reduce", scatter_reduce_bench),     # §3.3 + Fig 8
        ("overall_perf", overall_perf),               # Fig 5
        ("scaling", scaling),                         # Fig 7
        ("coopt", coopt_bench),                       # Fig 9
        ("planner", planner_bench),                   # batch vs scalar engine
        ("bandwidth_scaling", bandwidth_scaling),     # Fig 11
        ("alibaba", alibaba_bench),                   # Fig 10 / §5.7
        ("perfmodel_accuracy", perfmodel_accuracy),   # Table 3
        ("runtime_accuracy", runtime_accuracy),       # engine vs sim vs model
        ("roofline", roofline_bench),                 # deliverable (g)
        ("collectives", collectives_bench),           # eq(1)/(2) on TPU rings
        ("trace_overhead", trace_overhead),           # span-recording gate
        ("fault_overhead", fault_overhead),           # recovery-machinery gate
        ("calibration", calibration_bench),           # measured-profile gate
        ("serving", serving_bench),                   # pipelined-decode gate
    ]
    # BENCH_NAMES exists so --list stays import-light; keep it honest
    assert tuple(n for n, _ in benches) == BENCH_NAMES, \
        "BENCH_NAMES is out of sync with the benches list"
    if args.names:
        benches = [(n, m) for n, m in benches if n in args.names]
    print("name,us_per_call,derived")
    all_rows = {}
    for name, mod in benches:
        t0 = time.time()
        rows = mod.rows(fast=fast)
        dt = (time.time() - t0) * 1e6 / max(1, len(rows))
        all_rows[name] = rows
        print(f"{name},{dt:.0f},{_headline(name, rows)}")
    print()
    for name, rows in all_rows.items():
        print(f"## {name}")
        for r in rows:
            print(",".join(f"{k}={v}" for k, v in r.items()))
        print()


if __name__ == "__main__":
    main()
