"""Paper Fig 7: throughput scaling with total memory (FuncPipe vs LambdaML),
with the per-worker bandwidth-contention model enabled."""
from __future__ import annotations

from repro.core.profiler import paper_model_profile
from repro.serverless.frameworks import funcpipe, lambda_ml
from repro.serverless.platform import AWS_LAMBDA


def rows(fast: bool = False):
    out = []
    models = ["amoebanet-d18"] if fast else ["amoebanet-d18", "amoebanet-d36"]
    for model in models:
        prof = paper_model_profile(model, AWS_LAMBDA)
        base_tp = None
        for gb in [32, 64, 128, 256] if not fast else [32, 128]:
            lm = lambda_ml(prof, AWS_LAMBDA, gb, contention=True)
            fp = funcpipe(prof, AWS_LAMBDA, gb, contention=True)
            rec = fp.recommended_sim
            lm_tp = gb / lm.t_iter
            fp_tp = gb / rec.t_iter
            if base_tp is None:
                base_tp = lm_tp
            out.append({
                "bench": "fig7", "model": model, "global_batch": gb,
                "lambdaml_mem_gb": round(lm.total_mem_gb, 1),
                "funcpipe_mem_gb": round(rec.total_mem_gb, 1),
                "lambdaml_tp_norm": round(lm_tp / base_tp, 2),
                "funcpipe_tp_norm": round(fp_tp / base_tp, 2),
                "tp_gain": round(fp_tp / lm_tp, 2),
            })
    return out


def main(fast: bool = False):
    for r in rows(fast):
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
