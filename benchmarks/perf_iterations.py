"""§Perf hillclimbing harness (deliverable g, perf-iteration log).

For each of the three selected (arch x shape) pairs, runs the declared
sequence of configurations through the REAL dry-run (lower + compile on the
16x16 production mesh) and records hypothesis -> change -> before/after of
the roofline terms into benchmarks/results/perf/<tag>.json.

Sequence per pair: the LambdaML-analog baseline (unidirectional ring sync),
the paper-faithful FuncPipe analog (bidirectional), then the beyond-paper
plan iterations.  Run:

    PYTHONPATH=src python -m benchmarks.perf_iterations [pair_index ...]
"""
from __future__ import annotations

import json
import os
import sys

RESULTS = os.path.join(os.path.dirname(__file__), "results", "perf")

# Each variant: (name, hypothesis, plan_overrides, bidirectional)
PAIRS = [
    {
        "arch": "gemma3-4b",
        "shape": "train_4k",
        "why": "most collective-bound baseline (t_coll 1.24s vs t_comp 0.78s): "
               "tp=8 row-parallel psums dominate",
        "variants": [
            ("uni_ring", "pre-paper baseline: LambdaML-analog unidirectional "
             "ring scatter-reduce", {}, False),
            ("paper_bidi", "paper technique: full-duplex bidirectional ring "
             "halves grad-sync wall bytes (eq1->eq2 analog)", {}, True),
            ("stages4_tp4", "TP psum bytes scale with layers/stage * (tp-1)/tp; "
             "stages 2->4 (tp 8->4) should cut the psum term ~2x at +2 padding "
             "layers (34->36) and a slightly deeper pipeline",
             {"stages": 4, "tensor": 4}, True),
            ("stages8_tp2", "continue: tp=2 halves psum bytes again; padding "
             "grows to 40 layers (+6 idle) and bubble deepens (S=8)",
             {"stages": 8, "tensor": 2}, True),
            ("stages16_tp1", "extreme: no TP psums at all, but 34->48 padded "
             "layers = +41% wasted compute and S=16 bubble",
             {"stages": 16, "tensor": 1}, True),
            ("s8tp2_norematl", "beyond-paper: drop activation remat (peak was "
             "only 4.5GB of 16GB) -> forward recompute (1/4 of train FLOPs) "
             "disappears; predicted ~ -19% step time",
             {"stages": 8, "tensor": 2, "remat": "none"}, True),
        ],
    },
    {
        "arch": "qwen3-moe-235b-a22b",
        "shape": "train_4k",
        "why": "most representative of the paper's technique: deepest pipeline "
               "(16 stages) + expert parallelism + largest model; bubble "
               "factor (16+15)/16=1.94 dominates the wall estimate",
        "variants": [
            ("uni_ring", "pre-paper baseline: unidirectional ring sync", {}, False),
            ("paper_bidi", "paper technique: bidirectional ring halves "
             "grad RS/AG wall bytes", {}, True),
            ("stages8_tp2", "bubble: S 16->8 cuts fill/drain from 15/16 to "
             "7/16 of a pipeline round (1.94x -> 1.44x); cost: expert FFN "
             "d_ff 1536 splits to 768 per tp member + row-parallel psums",
             {"stages": 8, "tensor": 2}, True),
            ("stages8_mb32", "more micro-batches shrink the bubble further "
             "(mu=32: 1.22x) IF the local batch allows mu*mb<=16... expect "
             "infeasible (B_local=16) — recorded as a refuted hypothesis",
             {"stages": 8, "tensor": 2, "microbatches": 32}, True),
            ("stages4_tp4", "S=4: bubble 1.19x; tp=4 splits experts to 384 "
             "wide (MXU-unfriendly <512) and quadruples psum count",
             {"stages": 4, "tensor": 4}, True),
            ("s8tp2_noremat", "beyond-paper: tpu_planner says remat=none fits "
             "(est 12.5GB) at S8/tp2; removes the recompute quarter of "
             "train FLOPs; watch peak memory",
             {"stages": 8, "tensor": 2, "remat": "none"}, True),
        ],
    },
    {
        "arch": "xlstm-125m",
        "shape": "train_4k",
        "why": "worst roofline fraction: 125M params on 256 chips; tp=8 "
               "replicated mixers waste 8x compute, collectives dominate",
        "variants": [
            ("uni_ring", "pre-paper baseline: unidirectional ring sync", {}, False),
            ("paper_bidi", "paper technique: bidirectional rings", {}, True),
            ("stages8_tp2", "xLSTM TP is pure replication (DESIGN.md): tp 8->2 "
             "cuts replicated-mixer waste 4x; 6 period-instances pad to 8 "
             "stages (2 idle stages) — net win expected",
             {"stages": 8, "tensor": 2}, True),
            ("stages2_tp8_mb16", "alternative: keep S=2 but raise mu 4->16 to "
             "kill the bubble (1.25x -> 1.06x); acts per permute shrink 4x",
             {"microbatches": 16}, True),
            ("stages8_tp2_mb16", "combine the two winners",
             {"stages": 8, "tensor": 2, "microbatches": 16}, True),
            ("s8tp2mb16_noremat", "beyond-paper: remat off (125M model, "
             "memory is nowhere near the limit)",
             {"stages": 8, "tensor": 2, "microbatches": 16, "remat": "none"}, True),
        ],
    },
]


def run_pair(pair, out_dir=RESULTS):
    from repro.launch.dryrun import lower_combo

    os.makedirs(out_dir, exist_ok=True)
    tag = f"{pair['arch']}_{pair['shape']}"
    path = os.path.join(out_dir, tag + ".json")
    done = {}
    if os.path.exists(path):  # resume: keep completed iterations
        for it in json.load(open(path)).get("iterations", []):
            done[it["name"]] = it
    log = {"arch": pair["arch"], "shape": pair["shape"], "why": pair["why"],
           "iterations": []}
    prev = None
    for name, hypothesis, overrides, bidi in pair["variants"]:
        if name in done and done[name].get("status") in ("ok", "fail"):
            entry = done[name]
            if entry.get("status") == "ok":
                if prev is not None:
                    entry["delta_vs_prev"] = round(1 - entry["t_step_est_ms"] / prev, 4)
                prev = entry["t_step_est_ms"]
            log["iterations"].append(entry)
            print(f"[perf] {tag} {name}: cached")
            continue
        try:
            rec, _ = lower_combo(pair["arch"], pair["shape"],
                                 plan_overrides=overrides, bidirectional=bidi,
                                 verbose=False)
            rf = rec["roofline"]
            entry = {
                "name": name, "hypothesis": hypothesis,
                "overrides": overrides, "bidirectional": bidi,
                "status": rec["status"],
                "plan": rec["plan"],
                "t_compute_ms": round(rf["t_compute_s"] * 1e3, 2),
                "t_memory_ms": round(rf["t_memory_s"] * 1e3, 2),
                "t_collective_ms": round(rf["t_collective_s"] * 1e3, 2),
                "bubble": round(rf["bubble_factor"], 3),
                "t_step_est_ms": round(rf["t_step_est_s"] * 1e3, 2),
                "peak_gb": round((rec["memory"]["peak_bytes"] or 0) / 2**30, 2),
                "compile_s": rec["compile_s"],
            }
            if prev is not None:
                entry["delta_vs_prev"] = round(
                    1 - entry["t_step_est_ms"] / prev, 4)
            prev = entry["t_step_est_ms"]
        except Exception as e:  # noqa: BLE001
            entry = {"name": name, "hypothesis": hypothesis,
                     "overrides": overrides, "status": "fail",
                     "error": f"{type(e).__name__}: {str(e)[:300]}"}
        log["iterations"].append(entry)
        print(f"[perf] {tag} {name}: " + json.dumps(
            {k: v for k, v in entry.items() if k not in ("hypothesis",)}))
    with open(path, "w") as f:
        json.dump(log, f, indent=2)
    return log


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description="per-pair perf iteration logs")
    ap.add_argument("indices", nargs="*", type=int,
                    help=f"pair indices 0..{len(PAIRS) - 1} (default: all)")
    args = ap.parse_args(argv)
    for i in args.indices or range(len(PAIRS)):
        run_pair(PAIRS[i])


if __name__ == "__main__":
    main()
