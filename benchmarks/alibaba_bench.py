"""Paper Fig 10 + §5.7: Alibaba Cloud — OSS caps total storage bandwidth at
10 Gb/s, which throttles storage-based designs as workers grow; HybridPS
(VM-based sync) becomes the best baseline there, and FuncPipe still wins."""
from __future__ import annotations

from repro.core.profiler import paper_model_profile
from repro.serverless.frameworks import funcpipe, lambda_ml
from repro.serverless.platform import ALIBABA_FC


def rows(fast: bool = False):
    out = []
    models = ["amoebanet-d36"] if fast else ["resnet101", "amoebanet-d36"]
    batches = [64] if fast else [64, 256]
    for model in models:
        prof = paper_model_profile(model, ALIBABA_FC)
        for gb in batches:
            lm = lambda_ml(prof, ALIBABA_FC, gb)
            hp = lambda_ml(prof, ALIBABA_FC, gb, ps=True)
            fp = funcpipe(prof, ALIBABA_FC, gb)
            rec = fp.recommended_sim
            best_base = min([x for x in (lm, hp) if x], key=lambda s: s.t_iter)
            out.append({
                "bench": "fig10", "model": model, "global_batch": gb,
                "lambdaml_t": round(lm.t_iter, 2) if lm else None,
                "hybridps_t": round(hp.t_iter, 2) if hp else None,
                "funcpipe_t": round(rec.t_iter, 2),
                "funcpipe_c": round(rec.cost, 5),
                "speedup_vs_best_baseline": round(best_base.t_iter / rec.t_iter, 2),
                "cost_red_vs_best": round(1 - min(s.cost for s in fp.sims) / best_base.cost, 3),
            })
    return out


def main(fast: bool = False):
    for r in rows(fast):
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
