"""Deliverable (g): roofline table from the dry-run artifacts.

Reads benchmarks/results/dryrun/*.json (produced by repro.launch.dryrun) and
prints the three roofline terms, dominant bottleneck, MODEL_FLOPS ratio and
peak memory per (arch x shape x mesh)."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def rows(fast: bool = False):
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        r = json.load(open(path))
        if r["status"] == "skip":
            out.append({"bench": "roofline", "arch": r["arch"], "shape": r["shape"],
                        "status": "skip", "reason": r["reason"][:48]})
            continue
        if r["status"] != "ok":
            out.append({"bench": "roofline", "arch": r["arch"], "shape": r["shape"],
                        "status": "FAIL"})
            continue
        rf = r["roofline"]
        out.append({
            "bench": "roofline", "arch": r["arch"], "shape": r["shape"],
            "mesh": r["mesh"], "status": "ok",
            "t_compute_ms": round(rf["t_compute_s"] * 1e3, 2),
            "t_memory_ms": round(rf["t_memory_s"] * 1e3, 2),
            "t_collective_ms": round(rf["t_collective_s"] * 1e3, 2),
            "bottleneck": rf["bottleneck"],
            "useful_flops_ratio": round(r["useful_flops_ratio"], 3)
            if r.get("useful_flops_ratio") else None,
            "peak_gb": round(r["memory"]["peak_bytes"] / 2**30, 2)
            if r["memory"]["peak_bytes"] else None,
            "hlo_coll_kinds": ";".join(
                f"{k}:{v}" for k, v in sorted(r["roofline_hlo"]["collective_counts"].items())
            ),
        })
    return out


def main(fast: bool = False):
    for r in rows(fast):
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
