"""Three-level accuracy: storage-backed engine vs analytic simulator vs
closed-form performance model, across models and both platforms (§5.1).

For each (model, platform) the planner picks a configuration; the engine then
*executes* it through the emulated object store (timing axis only — sizes and
clocks, no JAX) and we report the relative iteration-time disagreement of
each analytic level against the executed ground truth.

    PYTHONPATH=src python -m benchmarks.runtime_accuracy [--fast]
"""
from __future__ import annotations

import sys

import numpy as np

from repro.configs import get_config
from repro.core import planner
from repro.core.profiler import arch_model_profile, paper_model_profile
from repro.serverless.frameworks import ALPHA_PAIRS
from repro.serverless.platform import ALIBABA_FC, AWS_LAMBDA
from repro.serverless.runtime import run_plan
from repro.serverless.simulator import simulate_funcpipe

MODELS = ["bert-large", "gemma3-4b", "phi3-mini-3.8b"]
PLATFORMS = [AWS_LAMBDA, ALIBABA_FC]


def _profile(model, platform):
    if model in ("bert-large", "resnet101", "amoebanet-d18", "amoebanet-d36"):
        return paper_model_profile(model, platform)
    return arch_model_profile(get_config(model), platform)


def rows(fast: bool = False):
    out = []
    models = MODELS[:2] if fast else MODELS
    platforms = PLATFORMS[:1] if fast else PLATFORMS
    batches = [64] if fast else [16, 64]
    max_eng = 0.0
    for model in models:
        for platform in platforms:
            prof = _profile(model, platform)
            for gb in batches:
                M = gb // 4
                # planner's pick, plus a forced data-parallel plan (d>1
                # exercises the emulated scatter-reduce against eq (2))
                solves = [("planned", dict())]
                if M >= 4:
                    solves.append(("d4", dict(d_options=(4,))))
                for tag, kw in solves:
                    r = planner.solve(prof, platform, alpha=ALPHA_PAIRS[1],
                                      total_micro_batches=M, merge_to=8, **kw)
                    if r is None:
                        out.append({"bench": "runtime_accuracy", "model": model,
                                    "platform": platform.name, "gb": gb,
                                    "plan": tag, "status": "infeasible"})
                        continue
                    sim = simulate_funcpipe(r.profile, platform, r.config, M)
                    eng = run_plan(r.profile, platform, r.config, M, steps=2)
                    err_model = abs(r.evaluation.t_iter - eng.t_iter) / eng.t_iter
                    err_sim = abs(sim.t_iter - eng.t_iter) / eng.t_iter
                    max_eng = max(max_eng, err_sim)
                    out.append({
                        "bench": "runtime_accuracy", "model": model,
                        "platform": platform.name, "gb": gb, "plan": tag,
                        "stages": sum(r.config.x) + 1, "d": r.config.d,
                        "t_engine": round(eng.t_iter, 3),
                        "t_sim": round(sim.t_iter, 3),
                        "t_model": round(r.evaluation.t_iter, 3),
                        "sim_rel_err": round(err_sim, 4),
                        "model_rel_err": round(err_model, 4),
                    })
    out.append({"bench": "runtime_accuracy", "model": "MAX",
                "platform": "-", "gb": "-",
                "sim_rel_err": round(max_eng, 4),
                "model_rel_err": round(max(
                    r.get("model_rel_err", 0.0) for r in out), 4)})
    return out


def main(fast: bool = False):
    rs = rows(fast)
    for r in rs:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    mx = rs[-1]
    print(f"\nmax relative error vs executed engine: "
          f"simulator={mx['sim_rel_err']:.2%} perfmodel={mx['model_rel_err']:.2%}")


if __name__ == "__main__":
    main("--fast" in sys.argv)
