"""Three-level accuracy: storage-backed engine vs analytic simulator vs
closed-form performance model, across models and both platforms (§5.1).

For each (model, platform) the planner picks a configuration; the engine then
*executes* it through the emulated object store (timing axis only — sizes and
clocks, no JAX) and we report the relative iteration-time disagreement of
each analytic level against the executed ground truth.

Also measures the *host* wall-clock of numeric execution (real JAX fwd/bwd
through the store) across three backward modes — the seed's eager
per-micro-batch ``jax.vjp`` retracing, the jitted recompute-in-backward
variant, and the default jitted path that caches VJP residuals between
forward and backward — the ``walltime`` rows; plus a ``backend_parity`` row
checking that the same numeric plan trains to bit-identical params on the
``local`` (real thread concurrency, wall-clock) execution backend.

Writes the accuracy rows to ``BENCH_runtime_accuracy.json`` at the repo root
(``--fast`` writes ``BENCH_runtime_accuracy_fast.json``) so CI can track the
engine-vs-simulator error as an artifact.

    PYTHONPATH=src python -m benchmarks.runtime_accuracy [--fast]
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from repro.configs import get_config
from repro.core import planner
from repro.core.profiler import arch_model_profile, paper_model_profile
from repro.serverless.frameworks import ALPHA_PAIRS
from repro.serverless.execution import ExecutionConfig
from repro.serverless.platform import ALIBABA_FC, AWS_LAMBDA
from repro.serverless.runtime import Execution, run_plan
from repro.serverless.simulator import simulate_funcpipe

MODELS = ["bert-large", "gemma3-4b", "phi3-mini-3.8b"]
PLATFORMS = [AWS_LAMBDA, ALIBABA_FC]

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_JSON = os.path.join(_REPO_ROOT, "BENCH_runtime_accuracy.json")
OUT_JSON_FAST = os.path.join(_REPO_ROOT, "BENCH_runtime_accuracy_fast.json")


def _walltime_rows(fast: bool):
    """Host seconds per numeric engine step across the three backward modes:
    eager per-micro-batch ``jax.vjp`` (the seed), jitted with forward
    recompute inside the VJP (``remat``), and the default jitted path that
    caches the VJP residuals between forward and backward (``resid``) — the
    last two isolate the wall-clock delta of not re-running the forward."""
    import jax

    import repro.configs as configs
    from repro.configs.base import InputShape
    from repro.core.perfmodel import Config
    from repro.data.synthetic import make_batch
    from repro.models import registry
    from repro.optim import AdamW

    cfg = dataclasses.replace(configs.get_config("phi3-mini-3.8b").reduced(),
                              n_layers=4)
    B, S, d, mu = 8, 16, 1, 4
    steps = 2 if fast else 4
    shape = InputShape("bench", S, B, "train")
    prof = arch_model_profile(cfg, AWS_LAMBDA, seq=S, micro_batch=B // (d * mu))
    L = prof.L
    x = tuple(1 if i == 2 else 0 for i in range(L - 1))
    config = Config(x=x, d=d, z=tuple(0 for _ in range(L)))
    params0 = registry.init_params(cfg, jax.random.PRNGKey(0))
    batches = [make_batch(cfg, shape, step=k) for k in range(steps)]
    out = []
    times = {}
    modes = [("eager", dict(jit=False)),
             ("jit-remat", dict(jit=True, remat=True)),
             ("jit-resid", dict(jit=True, remat=False))]
    for mode, kw in modes:
        exe = Execution(cfg=cfg, optimizer=AdamW(lr=1e-3), init_params=params0,
                        batch_fn=lambda k: batches[k], **kw)
        t0 = time.time()
        run_plan(prof, AWS_LAMBDA, config, d * mu,
                 ExecutionConfig(steps=steps), execution=exe)
        per_step = (time.time() - t0) / steps
        times[mode] = per_step
        out.append({"bench": "runtime_accuracy", "model": "walltime",
                    "platform": "host", "mode": mode, "steps": steps,
                    "sec_per_step": round(per_step, 3)})
    for label, num, den in [("jit_speedup", "eager", "jit-resid"),
                            ("resid_speedup", "jit-remat", "jit-resid")]:
        out.append({"bench": "runtime_accuracy", "model": "walltime",
                    "platform": "host", "mode": label,
                    "sec_per_step": round(
                        times[num] / max(times[den], 1e-9), 2)})
    return out


def _backend_parity_rows(fast: bool):
    """Numeric K-step run on the emulated (virtual clock) and local (real
    concurrent threads, wall-clock) execution backends: params must be
    bit-identical — the acceptance bar for any future real-platform
    backend — with both hosts' seconds reported for reference."""
    import jax

    import repro.configs as configs
    from repro.configs.base import InputShape
    from repro.core.perfmodel import Config
    from repro.data.synthetic import make_batch
    from repro.models import registry
    from repro.optim import AdamW

    cfg = dataclasses.replace(configs.get_config("phi3-mini-3.8b").reduced(),
                              n_layers=4)
    B, S, d, mu = 8, 16, 2, 2
    steps = 1 if fast else 2
    shape = InputShape("bparity", S, B, "train")
    prof = arch_model_profile(cfg, AWS_LAMBDA, seq=S, micro_batch=B // (d * mu))
    L = prof.L
    x = tuple(1 if i == 2 else 0 for i in range(L - 1))
    config = Config(x=x, d=d, z=tuple(0 for _ in range(L)))
    params0 = registry.init_params(cfg, jax.random.PRNGKey(0))
    batches = [make_batch(cfg, shape, step=k) for k in range(steps)]
    out = []
    results = {}
    for backend in ("emulated", "local"):
        exe = Execution(cfg=cfg, optimizer=AdamW(lr=1e-2),
                        init_params=params0, batch_fn=lambda k: batches[k])
        t0 = time.time()
        results[backend] = run_plan(prof, AWS_LAMBDA, config, d * mu,
                                    ExecutionConfig(steps=steps,
                                                    backend=backend),
                                    execution=exe)
        out.append({"bench": "runtime_accuracy", "model": "backend_parity",
                    "platform": "host", "backend": backend, "steps": steps,
                    "sec_per_step": round((time.time() - t0) / steps, 3)})
    leaves_e = jax.tree.leaves(results["emulated"].params)
    leaves_l = jax.tree.leaves(results["local"].params)
    bit = all(np.array_equal(np.asarray(a), np.asarray(b))
              for a, b in zip(leaves_e, leaves_l))
    out.append({"bench": "runtime_accuracy", "model": "backend_parity",
                "platform": "host", "backend": "emulated-vs-local",
                "bit_identical_params": bool(bit),
                "loss_identical": results["emulated"].losses
                == results["local"].losses})
    return out


def _profile(model, platform):
    if model in ("bert-large", "resnet101", "amoebanet-d18", "amoebanet-d36"):
        return paper_model_profile(model, platform)
    return arch_model_profile(get_config(model), platform)


def rows(fast: bool = False):
    out = []
    models = MODELS[:2] if fast else MODELS
    platforms = PLATFORMS[:1] if fast else PLATFORMS
    batches = [64] if fast else [16, 64]
    max_eng = 0.0
    for model in models:
        for platform in platforms:
            prof = _profile(model, platform)
            for gb in batches:
                M = gb // 4
                # planner's pick, plus a forced data-parallel plan (d>1
                # exercises the emulated scatter-reduce against eq (2))
                solves = [("planned", dict())]
                if M >= 4:
                    solves.append(("d4", dict(d_options=(4,))))
                for tag, kw in solves:
                    r = planner.solve(prof, platform, alpha=ALPHA_PAIRS[1],
                                      total_micro_batches=M, merge_to=8, **kw)
                    if r is None:
                        out.append({"bench": "runtime_accuracy", "model": model,
                                    "platform": platform.name, "gb": gb,
                                    "plan": tag, "status": "infeasible"})
                        continue
                    sim = simulate_funcpipe(r.profile, platform, r.config, M)
                    eng = run_plan(r.profile, platform, r.config, M,
                                   ExecutionConfig(steps=2))
                    err_model = abs(r.evaluation.t_iter - eng.t_iter) / eng.t_iter
                    err_sim = abs(sim.t_iter - eng.t_iter) / eng.t_iter
                    max_eng = max(max_eng, err_sim)
                    out.append({
                        "bench": "runtime_accuracy", "model": model,
                        "platform": platform.name, "gb": gb, "plan": tag,
                        "stages": sum(r.config.x) + 1, "d": r.config.d,
                        "t_engine": round(eng.t_iter, 3),
                        "t_sim": round(sim.t_iter, 3),
                        "t_model": round(r.evaluation.t_iter, 3),
                        "sim_rel_err": round(err_sim, 4),
                        "model_rel_err": round(err_model, 4),
                    })
    out.append({"bench": "runtime_accuracy", "model": "MAX",
                "platform": "-", "gb": "-",
                "sim_rel_err": round(max_eng, 4),
                "model_rel_err": round(max(
                    r.get("model_rel_err", 0.0) for r in out), 4)})
    out.extend(_walltime_rows(fast))
    out.extend(_backend_parity_rows(fast))
    _write_json(out, fast)
    return out


def _write_json(out, fast: bool) -> None:
    mx = next(r for r in out if r["model"] == "MAX")
    parity = next(r for r in out if "bit_identical_params" in r)
    summary = {
        "fast": fast,
        "max_sim_rel_err": mx["sim_rel_err"],
        "max_model_rel_err": mx["model_rel_err"],
        "backend_parity_bit_identical": parity["bit_identical_params"],
        "rows": out,
    }
    with open(OUT_JSON_FAST if fast else OUT_JSON, "w") as f:
        json.dump(summary, f, indent=2)
        f.write("\n")


def main(fast: bool = False):
    rs = rows(fast)
    for r in rs:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    mx = next(r for r in rs if r["model"] == "MAX")
    print(f"\nmax relative error vs executed engine: "
          f"simulator={mx['sim_rel_err']:.2%} perfmodel={mx['model_rel_err']:.2%}")
    jt = next(r for r in rs if r.get("mode") == "jit_speedup")
    rd = next(r for r in rs if r.get("mode") == "resid_speedup")
    print(f"numeric engine wall-clock: {jt['sec_per_step']}x faster than "
          f"eager vjp; residual caching {rd['sec_per_step']}x faster than "
          f"recompute-in-bwd")
    parity = next(r for r in rs if "bit_identical_params" in r)
    print(f"backend parity (emulated vs local): bit_identical_params="
          f"{parity['bit_identical_params']}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description="engine vs sim vs model accuracy")
    ap.add_argument("--fast", action="store_true")
    main(ap.parse_args().fast)
