"""Planner engine benchmark: seed scalar co-optimizer vs the batched engine.

For each merge depth, times ``planner.solve`` and records plan quality; where
both engines run (shallow depths) it asserts they return the *identical*
plan.  The scalar engine is only timed at depths where it is tractable —
the batched engine is what makes ``merge_to`` >= 14 usable at all.  Results
are also written to ``BENCH_planner.json`` at the repo root so the planner
perf trajectory is tracked from this PR onward.

    PYTHONPATH=src python -m benchmarks.planner_bench [--fast] [--check]

``--check`` (CI smoke guard) exits non-zero when the engines diverge or the
batched engine is less than 2x faster than scalar at the comparison depth.
"""
from __future__ import annotations

import json
import os
import time

from repro.core import planner
from repro.core.profiler import paper_model_profile
from repro.serverless.frameworks import ALPHA_PAIRS
from repro.serverless.platform import AWS_LAMBDA

MODEL = "bert-large"
ALPHA = ALPHA_PAIRS[1]
M = 16
OUT_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_planner.json")

# scalar is O(2^L) evaluate calls: ~seconds at merge_to=8, minutes at 10,
# hopeless beyond — the batched engine runs every depth
SCALAR_DEPTHS_FULL = (8, 10)
BATCH_DEPTHS_FULL = (8, 10, 14, 16, 18)
SCALAR_DEPTHS_FAST = (8,)
BATCH_DEPTHS_FAST = (8, 10, 14)


def _solve(engine: str, merge_to: int):
    prof = paper_model_profile(MODEL, AWS_LAMBDA)
    t0 = time.time()
    r = planner.solve(prof, AWS_LAMBDA, alpha=ALPHA, total_micro_batches=M,
                      merge_to=merge_to, engine=engine)
    dt = time.time() - t0
    return r, dt


def rows(fast: bool = False):
    scalar_depths = SCALAR_DEPTHS_FAST if fast else SCALAR_DEPTHS_FULL
    batch_depths = BATCH_DEPTHS_FAST if fast else BATCH_DEPTHS_FULL
    out = []
    scalar_at = {}
    for mt in scalar_depths:
        r, dt = _solve("scalar", mt)
        scalar_at[mt] = (r, dt)
        out.append({
            "bench": "planner", "engine": "scalar", "merge_to": mt,
            "seconds": round(dt, 3), "objective": r.objective,
            "t_iter": round(r.evaluation.t_iter, 4),
            "c_iter": round(r.evaluation.c_iter, 6),
            "stages": sum(r.config.x) + 1, "d": r.config.d,
        })
    base_obj = None
    for mt in batch_depths:
        r, dt = _solve("batch", mt)
        row = {
            "bench": "planner", "engine": "batch", "merge_to": mt,
            "seconds": round(dt, 3), "objective": r.objective,
            "t_iter": round(r.evaluation.t_iter, 4),
            "c_iter": round(r.evaluation.c_iter, 6),
            "stages": sum(r.config.x) + 1, "d": r.config.d,
        }
        if mt in scalar_at:
            rs, dts = scalar_at[mt]
            row["identical_plan"] = (r.config == rs.config
                                     and r.objective == rs.objective)
            row["speedup_vs_scalar"] = round(dts / max(dt, 1e-9), 1)
        if base_obj is None:
            base_obj = r.objective
        # plan-quality delta vs the shallowest batched depth (negative = better)
        row["quality_delta"] = round(r.objective / base_obj - 1, 6)
        out.append(row)
    if not fast:  # the tracked perf-trajectory file records full runs only
        _write_json(out, fast)
    return out


def _write_json(out, fast: bool) -> None:
    cmp_rows = [r for r in out if r.get("speedup_vs_scalar") is not None]
    summary = {
        "model": MODEL, "alpha": list(ALPHA), "micro_batches": M, "fast": fast,
        "max_speedup_vs_scalar": max((r["speedup_vs_scalar"] for r in cmp_rows),
                                     default=None),
        "all_plans_identical": all(r["identical_plan"] for r in cmp_rows),
        "best_quality_delta": min(r["quality_delta"] for r in out
                                  if "quality_delta" in r),
        "rows": out,
    }
    with open(OUT_JSON, "w") as f:
        json.dump(summary, f, indent=2)
        f.write("\n")


def check(fast: bool = True) -> int:
    """CI smoke: fail on engine divergence or a >2x perf regression."""
    rs = rows(fast)
    cmp_rows = [r for r in rs if r.get("speedup_vs_scalar") is not None]
    ok = True
    if not cmp_rows:
        print("check: no scalar/batch comparison rows produced")
        ok = False
    for r in cmp_rows:
        if not r["identical_plan"]:
            print(f"check: engines diverged at merge_to={r['merge_to']}: {r}")
            ok = False
        if r["speedup_vs_scalar"] < 2.0:
            print(f"check: batched engine only {r['speedup_vs_scalar']}x faster "
                  f"at merge_to={r['merge_to']} (>=2x required)")
            ok = False
    for r in rs:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    print("check:", "OK" if ok else "FAILED")
    return 0 if ok else 1


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description="batch-vs-scalar planner bench")
    ap.add_argument("--check", action="store_true",
                    help="CI gate: parity + >=2x speedup")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="with --check: run the full (non-fast) sweep")
    args = ap.parse_args(argv)
    if args.check:
        raise SystemExit(check(fast=not args.full))
    for r in rows(args.fast):
        print(",".join(f"{k}={v}" for k, v in r.items()))
    print(f"\nwrote {OUT_JSON}")


if __name__ == "__main__":
    main()
