"""Planner engine benchmark: scalar oracle vs batched enumeration vs exact DP.

For each merge depth, times ``planner.solve`` and records plan quality; where
several engines run the same depth it cross-checks them.  The scalar engine
is only timed at depths where it is tractable, the batched engine where the
2^(L-1) partition space stays interactive, and the DP engine everywhere —
including ``merge_to=None`` (full layer depth, L=26 for bert-large), the
regime only the DP reaches.  Full runs refresh the committed
``BENCH_planner.json`` at the repo root; ``--fast`` (CI smoke) runs write
``BENCH_planner_fast.json`` instead, so the tracked perf trajectory is never
clobbered by a smoke run (CI uploads both spellings as artifacts).

    PYTHONPATH=src python -m benchmarks.planner_bench [--fast] [--check]

``--check`` (CI smoke guard) exits non-zero when
  * batch and scalar diverge at any shared depth (they must be identical),
  * the batched engine is less than 2x faster than scalar,
  * the DP engine's objective is *worse* than the batch engine's at any
    shared depth, or worse at full depth than batch at its deepest depth —
    the DP is exact, so "dp ever worse" is an optimality regression.
"""
from __future__ import annotations

import json
import os
import time

from repro.core import planner
from repro.core.profiler import paper_model_profile
from repro.serverless.frameworks import ALPHA_PAIRS
from repro.serverless.platform import AWS_LAMBDA

MODEL = "bert-large"
ALPHA = ALPHA_PAIRS[1]
M = 16
# full runs refresh the committed perf-trajectory file; fast (CI-smoke) runs
# write a sibling artifact so `--check` never clobbers the tracked numbers
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_JSON = os.path.join(_REPO_ROOT, "BENCH_planner.json")
OUT_JSON_FAST = os.path.join(_REPO_ROOT, "BENCH_planner_fast.json")
# the dp engine may beat batch outright (it is exact where batch's CD is a
# heuristic) but must never be worse; the band absorbs the ~1e-13 float
# association difference between the engines' accumulation orders
DP_RTOL = 1e-9

# scalar is O(2^L) evaluate calls: ~seconds at merge_to=8, minutes at 10,
# hopeless beyond; batch prunes but still enumerates 2^(L-1) partitions —
# the hierarchical merge keeps many near-optimal partitions alive, so its
# practical ceiling is ~14; the DP runs every depth including None (= full)
SCALAR_DEPTHS_FULL = (8, 10)
BATCH_DEPTHS_FULL = (8, 10, 14)
DP_DEPTHS_FULL = (8, 10, 14, 16, None)
SCALAR_DEPTHS_FAST = (8,)
BATCH_DEPTHS_FAST = (8, 10)
DP_DEPTHS_FAST = (8, 10, None)


def _solve(engine: str, merge_to):
    prof = paper_model_profile(MODEL, AWS_LAMBDA)
    t0 = time.time()
    r = planner.solve(prof, AWS_LAMBDA, alpha=ALPHA, total_micro_batches=M,
                      merge_to=merge_to, engine=engine)
    dt = time.time() - t0
    return r, dt


def _row(engine: str, merge_to, r, dt) -> dict:
    return {
        "bench": "planner", "engine": engine,
        "merge_to": "full" if merge_to is None else merge_to,
        "seconds": round(dt, 3), "objective": r.objective,
        "t_iter": round(r.evaluation.t_iter, 4),
        "c_iter": round(r.evaluation.c_iter, 6),
        "stages": sum(r.config.x) + 1, "d": r.config.d,
    }


def rows(fast: bool = False):
    scalar_depths = SCALAR_DEPTHS_FAST if fast else SCALAR_DEPTHS_FULL
    batch_depths = BATCH_DEPTHS_FAST if fast else BATCH_DEPTHS_FULL
    dp_depths = DP_DEPTHS_FAST if fast else DP_DEPTHS_FULL
    out = []
    scalar_at = {}
    for mt in scalar_depths:
        r, dt = _solve("scalar", mt)
        scalar_at[mt] = (r, dt)
        out.append(_row("scalar", mt, r, dt))
    base_obj = None
    batch_at = {}
    for mt in batch_depths:
        r, dt = _solve("batch", mt)
        batch_at[mt] = (r, dt)
        row = _row("batch", mt, r, dt)
        if mt in scalar_at:
            rs, dts = scalar_at[mt]
            row["identical_plan"] = (r.config == rs.config
                                     and r.objective == rs.objective)
            row["speedup_vs_scalar"] = round(dts / max(dt, 1e-9), 1)
        if base_obj is None:
            base_obj = r.objective
        # plan-quality delta vs the shallowest batched depth (negative = better)
        row["quality_delta"] = round(r.objective / base_obj - 1, 6)
        out.append(row)
    deepest_batch = batch_at[max(batch_at)][0]
    for mt in dp_depths:
        r, dt = _solve("dp", mt)
        row = _row("dp", mt, r, dt)
        # vs batch at the same depth (or its deepest depth for the depths
        # only dp reaches): dp is exact — it must never be worse
        rb, dtb = batch_at.get(mt, (deepest_batch, None))
        row["dp_not_worse_than_batch"] = bool(
            r.objective <= rb.objective * (1 + DP_RTOL))
        if dtb is not None:
            row["speedup_vs_batch"] = round(dtb / max(dt, 1e-9), 1)
        row["quality_delta"] = round(r.objective / base_obj - 1, 6)
        out.append(row)
    _write_json(out, fast)
    return out


def _write_json(out, fast: bool) -> None:
    cmp_rows = [r for r in out if r.get("speedup_vs_scalar") is not None]
    dp_rows = [r for r in out if r["engine"] == "dp"]
    dp_full = [r for r in dp_rows if r["merge_to"] == "full"]
    summary = {
        "model": MODEL, "alpha": list(ALPHA), "micro_batches": M, "fast": fast,
        "max_speedup_vs_scalar": max((r["speedup_vs_scalar"] for r in cmp_rows),
                                     default=None),
        "all_plans_identical": all(r["identical_plan"] for r in cmp_rows),
        "dp_never_worse": all(r["dp_not_worse_than_batch"] for r in dp_rows),
        "dp_full_depth_seconds": dp_full[0]["seconds"] if dp_full else None,
        "best_quality_delta": min(r["quality_delta"] for r in out
                                  if "quality_delta" in r),
        "rows": out,
    }
    with open(OUT_JSON_FAST if fast else OUT_JSON, "w") as f:
        json.dump(summary, f, indent=2)
        f.write("\n")


def check(fast: bool = True) -> int:
    """CI smoke: fail on engine divergence, a >2x perf regression, or a
    dp-vs-batch optimality regression."""
    rs = rows(fast)
    cmp_rows = [r for r in rs if r.get("speedup_vs_scalar") is not None]
    ok = True
    if not cmp_rows:
        print("check: no scalar/batch comparison rows produced")
        ok = False
    for r in cmp_rows:
        if not r["identical_plan"]:
            print(f"check: engines diverged at merge_to={r['merge_to']}: {r}")
            ok = False
        if r["speedup_vs_scalar"] < 2.0:
            print(f"check: batched engine only {r['speedup_vs_scalar']}x faster "
                  f"at merge_to={r['merge_to']} (>=2x required)")
            ok = False
    dp_rows = [r for r in rs if r["engine"] == "dp"]
    if not dp_rows:
        print("check: no dp rows produced")
        ok = False
    for r in dp_rows:
        if not r["dp_not_worse_than_batch"]:
            print(f"check: dp objective WORSE than batch at "
                  f"merge_to={r['merge_to']}: {r} (optimality regression)")
            ok = False
    for r in rs:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    print("check:", "OK" if ok else "FAILED")
    return 0 if ok else 1


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description="scalar/batch/dp planner bench")
    ap.add_argument("--check", action="store_true",
                    help="CI gate: parity + >=2x speedup + dp optimality")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="with --check: run the full (non-fast) sweep")
    args = ap.parse_args(argv)
    if args.check:
        raise SystemExit(check(fast=not args.full))
    for r in rows(args.fast):
        print(",".join(f"{k}={v}" for k, v in r.items()))
    print(f"\nwrote {OUT_JSON_FAST if args.fast else OUT_JSON}")


if __name__ == "__main__":
    main()
