"""Calibration-loop gate: a measured profile must predict the run it was
calibrated from far better than the analytic tables do.

Runs the numeric reduced arch (real JAX) through the **process** backend
with ``payload_true`` + ``throttle`` — real OS worker processes moving real
payload bytes through the file store at the plan's modeled per-worker
bandwidth, so spans measure host wall-clock seconds under the plan's own
budget.  The traced run is folded back through
:func:`repro.obs.calibrate.calibrate_profile` and the headline is the max
per-stage relative error of the model's ``stage_aggregates`` terms against
the observed spans, before (analytic profile) vs after (measured profile).

``--check`` enforces the CI gate ``residual <= baseline * 0.5 + 0.02`` —
calibrated re-planning is pointless unless the measured tables at least
halve the predicted-vs-observed error (the 2pp absolute slack covers
wall-clock jitter on runs whose analytic error is already tiny).  A replan
row records how the re-solved deployment prices against the old one on the
measured tables.  Writes ``BENCH_calibration.json`` at the repo root.

    PYTHONPATH=src python -m benchmarks.calibration_bench [--fast] [--check]
"""
from __future__ import annotations

import json
import os
from argparse import Namespace

from repro.cli import _numeric_plan
from repro.obs.calibrate import calibrate_profile, replan
from repro.serverless.execution import ExecutionConfig
from repro.serverless.runtime import run_plan

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_JSON = os.path.join(_REPO_ROOT, "BENCH_calibration.json")

# gate: residual <= baseline * REL_FACTOR + ABS_SLACK (also quoted in ci.yml)
REL_FACTOR = 0.5
ABS_SLACK = 0.02


def rows(fast: bool = False):
    steps = 2 if fast else 3     # >= 2 so the JIT-compile step-0 warmup drops
    plan, prof, ex = _numeric_plan(Namespace(
        model="phi3-mini-3.8b", platform="aws", n_layers=4, seq=16,
        batch=8, dp=2, stages=2, lambda_ml_sync=False))
    rp = plan.resolve(profile=prof)
    res = run_plan(rp.profile, rp.platform, rp.config,
                   rp.total_micro_batches,
                   ExecutionConfig(steps=steps, backend="process",
                                   payload_true=True, throttle=True,
                                   trace=True),
                   pipelined_sync=rp.pipelined_sync, execution=ex)
    cal = calibrate_profile(res.trace, rp.profile, rp.platform, rp.config,
                            rp.total_micro_batches,
                            pipelined_sync=rp.pipelined_sync)
    baseline = cal.baseline["max_rel_err"]
    residual = cal.residual["max_rel_err"]
    rep = replan(cal, plan)
    a1, a2 = rep.alpha
    obj_old = rep.old_on_measured.objective(a1, a2)
    obj_new = rep.new_on_measured.objective(a1, a2)
    limit = baseline * REL_FACTOR + ABS_SLACK
    out = [
        {"bench": "calibration", "backend": "process", "steps": steps,
         "warmup": cal.warmup, "t_iter_s": round(res.t_iter, 4),
         "baseline_max_rel_err": round(baseline, 4),
         "residual_max_rel_err": round(residual, 4),
         "warnings": ";".join(w.name for w in cal.warnings) or "-"},
        {"bench": "replan", "old_stages": rep.old_plan.n_stages,
         "new_stages": rep.new_plan.n_stages, "old_d": rep.old_plan.d,
         "new_d": rep.new_plan.d,
         "objective_old_on_measured": round(obj_old, 8),
         "objective_new_on_measured": round(obj_new, 8),
         "improved_or_equal": obj_new <= obj_old + 1e-12},
        {"bench": "gate", "baseline": round(baseline, 4),
         "residual": round(residual, 4), "limit": round(limit, 4),
         "ok": residual <= limit},
    ]
    with open(OUT_JSON, "w") as f:
        json.dump(out, f, indent=1)
    return out


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(prog="benchmarks.calibration_bench")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless the measured profile at least "
                         "halves the predicted-vs-observed error")
    args = ap.parse_args(argv)
    rs = rows(fast=args.fast)
    for r in rs:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    gate = next(r for r in rs if r["bench"] == "gate")
    if args.check and not gate["ok"]:
        print(f"FAIL: calibrated residual error {gate['residual']} exceeds "
              f"{gate['limit']} ({REL_FACTOR:.0%} of analytic baseline "
              f"{gate['baseline']} + {ABS_SLACK})")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
