"""Fault-tolerance-overhead gate: recovery machinery must be ~free when no
faults fire.

Times ``run_plan`` on the **local** execution backend (real daemon threads
over the blocking in-process store — host wall-clock is the measurement) in
three modes:

* ``off``      — no tolerance: no retry wrappers, no heartbeats charged, no
  checkpoints,
* ``tolerant`` — full recovery machinery armed on a fault-free run: every
  store op goes through :class:`~repro.serverless.faults.ResilientContext`,
  workers heartbeat, and stage state checkpoints into the store each step,
* ``chaos``    — a seeded :class:`FaultPlan` (transient + crash + lifetime
  cap) actually firing, as a sanity row: recovery must terminate and is
  allowed to cost real time.

Each mode reports the **min over reps** of host seconds per step — min, not
mean, because scheduler noise only ever adds time.  ``--check`` enforces the
CI gate ``tolerant_min <= base_min * 1.05 + 0.05`` (5% relative + 50ms
absolute slack for timer/thread-start jitter on tiny runs) and exits 1 on
breach.  Writes ``BENCH_fault_overhead.json`` at the repo root.

    PYTHONPATH=src python -m benchmarks.fault_overhead [--fast] [--check]
"""
from __future__ import annotations

import json
import os
import time

from repro.core.partition import merge_layers
from repro.core.perfmodel import Config
from repro.core.profiler import paper_model_profile
from repro.serverless import faults as F
from repro.serverless.platform import AWS_LAMBDA
from repro.serverless.execution import ExecutionConfig
from repro.serverless.runtime import run_plan

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_JSON = os.path.join(_REPO_ROOT, "BENCH_fault_overhead.json")

# relative + absolute slack of the --check gate (also quoted in ci.yml)
REL_SLACK = 1.05
ABS_SLACK = 0.05


def _plan(d):
    prof = merge_layers(paper_model_profile("bert-large", AWS_LAMBDA), 6)
    L = prof.L
    x = tuple(1 if i == 2 else 0 for i in range(L - 1))
    return prof, Config(x=x, d=d, z=tuple(5 for _ in range(L)))


def _chaos_plan(steps):
    return F.FaultPlan(events=(
        F.FaultEvent(kind="transient", stage=0, replica=0, step=0,
                     op="put", index=0),
        F.FaultEvent(kind="crash", stage=1, replica=0,
                     step=max(0, steps - 1), phase="fwd"),
    ), lifetime_steps=max(2, steps))


def _time_once(*, d, M, steps, faults=None, tolerance=None):
    prof, cfg = _plan(d)
    t0 = time.perf_counter()
    res = run_plan(prof, AWS_LAMBDA, cfg, M,
                   ExecutionConfig(steps=steps, backend="local",
                                   faults=faults, tolerance=tolerance))
    host = time.perf_counter() - t0
    rep = res.fault_report
    return host / steps, (0 if rep is None else rep.restarts
                          + rep.planned_restarts)


def rows(fast: bool = False):
    reps = 3 if fast else 5
    d, M, steps = 2, 8, (1 if fast else 2)
    tol = F.FaultTolerance(retry=F.RetryPolicy(base_delay_s=0.01))
    modes = (
        ("local_off", dict()),
        ("local_tolerant", dict(tolerance=tol)),
        ("local_chaos", dict(faults=_chaos_plan(steps), tolerance=tol)),
    )
    out = []
    for name, kw in modes:
        best, restarts = min(
            _time_once(d=d, M=M, steps=steps, **kw) for _ in range(reps))
        out.append({"bench": name, "reps": reps, "steps": steps,
                    "min_s_per_step": round(best, 6), "restarts": restarts})
    base = next(r for r in out if r["bench"] == "local_off")
    tolerant = next(r for r in out if r["bench"] == "local_tolerant")
    limit = base["min_s_per_step"] * REL_SLACK + ABS_SLACK
    gate = {"bench": "gate", "base_s": base["min_s_per_step"],
            "tolerant_s": tolerant["min_s_per_step"],
            "limit_s": round(limit, 6),
            "ok": tolerant["min_s_per_step"] <= limit}
    out.append(gate)
    with open(OUT_JSON, "w") as f:
        json.dump(out, f, indent=1)
    return out


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(prog="benchmarks.fault_overhead")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if fault-free tolerant runs breach the "
                         "overhead gate")
    args = ap.parse_args(argv)
    rs = rows(fast=args.fast)
    for r in rs:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    gate = next(r for r in rs if r["bench"] == "gate")
    if args.check and not gate["ok"]:
        print(f"FAIL: tolerant fault-free step {gate['tolerant_s']}s exceeds "
              f"{gate['limit_s']}s ({REL_SLACK:.0%} of plain "
              f"{gate['base_s']}s + {ABS_SLACK}s)")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
