"""Generate the data-driven sections of EXPERIMENTS.md from artifacts:
dry-run JSONs (§Dry-run, §Roofline), perf-iteration JSONs (§Perf tables).
Hand-written analysis lives in EXPERIMENTS.md around the generated blocks.

    PYTHONPATH=src python -m benchmarks.gen_experiments > /tmp/sections.md
"""
from __future__ import annotations

import glob
import json
import os

HERE = os.path.dirname(__file__)


def dryrun_rows(mesh_suffix):
    rows = []
    for f in sorted(glob.glob(os.path.join(HERE, "results/dryrun", f"*_{mesh_suffix}.json"))):
        rows.append(json.load(open(f)))
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    return rows


def emit_dryrun():
    print("### Dry-run matrix (generated)\n")
    for mesh, label in [("16x16", "single-pod 16x16 (256 chips)"),
                        ("2x16x16", "multi-pod 2x16x16 (512 chips)")]:
        rows = dryrun_rows(mesh)
        ok = [r for r in rows if r["status"] == "ok"]
        skip = [r for r in rows if r["status"] == "skip"]
        fail = [r for r in rows if r["status"] not in ("ok", "skip")]
        print(f"**{label}** — lowered+compiled: {len(ok)}, documented skips: "
              f"{len(skip)}, failures: {len(fail)}\n")
        print("| arch | shape | plan (S/tp/mu/ep/seq) | compile_s | peak GB | args GB | collective schedule (HLO) |")
        print("|---|---|---|---|---|---|---|")
        for r in ok:
            p = r["plan"]
            plan = f"{p['stages']}/{p['tensor']}/{p['microbatches']}/{p['ep']}/{p['seq_shards']}"
            peak = r["memory"]["peak_bytes"] / 2**30
            args = (r["memory"]["argument_bytes"] or 0) / 2**30
            hlo = ";".join(f"{k.split('-')[0]}:{v}" for k, v in
                           sorted(r["roofline_hlo"]["collective_counts"].items()))
            print(f"| {r['arch']} | {r['shape']} | {plan} | {r['compile_s']} | "
                  f"{peak:.2f} | {args:.2f} | {hlo} |")
        for r in skip:
            print(f"| {r['arch']} | {r['shape']} | SKIP | — | — | — | {r['reason']} |")
        print()


def emit_roofline():
    print("### Roofline table, single-pod (generated)\n")
    print("TPU v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.")
    print("Terms from the analytic per-chip model (launch.roofline); the HLO")
    print("cross-check columns give XLA cost_analysis flops (counts scan bodies")
    print("once — lower bound) and trip-weighted collective bytes parsed from")
    print("the compiled HLO.\n")
    print("| arch | shape | t_comp ms | t_mem ms | t_coll ms | bubble | t_step est ms | bottleneck | useful/total FLOPs | HLO flops (lb) | HLO link MB |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in dryrun_rows("16x16"):
        if r["status"] != "ok":
            continue
        rf = r["roofline"]
        h = r["roofline_hlo"]
        print(f"| {r['arch']} | {r['shape']} | {rf['t_compute_s']*1e3:.1f} | "
              f"{rf['t_memory_s']*1e3:.1f} | {rf['t_collective_s']*1e3:.1f} | "
              f"{rf.get('bubble_factor', 1):.2f} | {rf.get('t_step_est_s', 0)*1e3:.1f} | "
              f"{rf['bottleneck']} | {r.get('useful_flops_ratio') or 0:.2f} | "
              f"{h['flops']:.2e} | {h['link_bytes']/1e6:.0f} |")
    print()


def emit_perf():
    print("### §Perf iteration logs (generated)\n")
    for f in sorted(glob.glob(os.path.join(HERE, "results/perf", "*.json"))):
        d = json.load(open(f))
        print(f"**{d['arch']} × {d['shape']}** — {d['why']}\n")
        print("| iteration | hypothesis (abridged) | plan | t_comp | t_coll | bubble | t_step est | Δ | peak GB | verdict |")
        print("|---|---|---|---|---|---|---|---|---|---|")
        prev = None
        for it in d["iterations"]:
            if it.get("status") != "ok":
                print(f"| {it['name']} | {it['hypothesis'][:70]} | — | — | — | — | — | — | — | "
                      f"INFEASIBLE: {it.get('error', '')[:60]} |")
                continue
            p = it["plan"]
            plan = f"S{p['stages']}/tp{p['tensor']}/mu{p['microbatches']}" + \
                   ("" if p.get("bidirectional", True) else "/uni")
            delta = it.get("delta_vs_prev")
            verdict = "—"
            if delta is not None:
                verdict = "confirmed" if delta > 0.02 else ("refuted" if delta < -0.02 else "neutral")
            print(f"| {it['name']} | {it['hypothesis'][:70]} | {plan} | "
                  f"{it['t_compute_ms']:.0f} | {it['t_collective_ms']:.0f} | "
                  f"{it['bubble']:.2f} | {it['t_step_est_ms']:.0f}ms | "
                  f"{'' if delta is None else f'{delta:+.1%}'} | {it['peak_gb']:.1f} | {verdict} |")
        print()


def main():
    emit_dryrun()
    emit_roofline()
    emit_perf()


if __name__ == "__main__":
    main()
