"""Uni- vs bi-directional ring collectives: analytic bytes-on-link / steps
(the TPU analog of eq (1) vs eq (2)) plus HLO op counts from a tiny lowering."""
from __future__ import annotations

from repro.core import collectives as cc


def rows(fast: bool = False):
    out = []
    for nbytes, label in [(1e9, "1GB"), (280e6, "280MB(paper)")]:
        for d in [2, 4, 8, 16]:
            uni = cc.reduce_scatter_cost(nbytes, d, False)
            bi = cc.reduce_scatter_cost(nbytes, d, True)
            ar_uni = cc.all_reduce_cost(nbytes, d, False)
            ar_bi = cc.all_reduce_cost(nbytes, d, True)
            out.append({
                "bench": "ring_analytic", "payload": label, "d": d,
                "rs_uni_MB_link": round(uni.bytes_on_link / 1e6, 1),
                "rs_bi_MB_link": round(bi.bytes_on_link / 1e6, 1),
                "link_reduction": round(1 - bi.bytes_on_link / uni.bytes_on_link, 3),
                "ar_uni_ms@50GBps": round(ar_uni.bytes_on_link / 50e9 * 1e3, 3),
                "ar_bi_ms@50GBps": round(ar_bi.bytes_on_link / 50e9 * 1e3, 3),
            })
    return out


def main(fast: bool = False):
    for r in rows(fast):
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
