"""Paper §3.3 + Fig 8: pipelined vs non-pipelined scatter-reduce.

Reports (a) the analytic eq (1)/(2) times including the 280MB/8-worker
example, (b) simulated training sync time & throughput vs DP degree on the
AmoebaNet-D18 recommended 3-stage config (the paper's Fig 8 setup).
"""
from __future__ import annotations

from repro.core.perfmodel import sync_time_nonpipelined, sync_time_pipelined
from repro.core.profiler import paper_model_profile
from repro.core.partition import merge_layers
from repro.core.perfmodel import Config
from repro.serverless.platform import AWS_LAMBDA, MB
from repro.serverless.simulator import simulate_funcpipe


def rows(fast: bool = False):
    out = []
    # ---- eq (1) vs eq (2) (paper's worked example)
    s, w = 280 * MB, 70 * MB
    for n in [2, 4, 8, 16, 32]:
        t1 = sync_time_nonpipelined(s, w, n, 0.040)
        t2 = sync_time_pipelined(s, w, n, 0.040)
        out.append({
            "bench": "eq1_vs_eq2", "n_workers": n,
            "nonpipelined_s": round(t1, 3), "pipelined_s": round(t2, 3),
            "reduction": round(1 - t2 / t1, 3),
        })
    # ---- Fig 8: training with the 3-stage AmoebaNet-D18 plan, growing DP
    prof = merge_layers(paper_model_profile("amoebanet-d18", AWS_LAMBDA), 6)
    L = prof.L
    x = tuple(1 if i in (L // 3 - 1, 2 * L // 3 - 1) else 0 for i in range(L - 1))
    z = tuple([6] * L)
    for d in [2, 4, 8, 16, 32]:
        M = 8 * d  # global batch grows with DP (paper Fig 8)
        a = simulate_funcpipe(prof, AWS_LAMBDA, Config(x=x, d=d, z=z), M,
                              pipelined_sync=False, contention=True)
        b = simulate_funcpipe(prof, AWS_LAMBDA, Config(x=x, d=d, z=z), M,
                              pipelined_sync=True, contention=True)
        out.append({
            "bench": "fig8_training", "dp": d,
            "sync_nonpipelined_s": round(a.breakdown["sync"], 2),
            "sync_pipelined_s": round(b.breakdown["sync"], 2),
            "sync_reduction": round(1 - b.breakdown["sync"] / a.breakdown["sync"], 3),
            "iter_speedup": round(a.t_iter / b.t_iter, 3),
        })
    return out


def main(fast: bool = False):
    for r in rows(fast):
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
