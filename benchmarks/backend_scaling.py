"""Backend-scaling gate: what do real OS worker processes buy over threads?

Times the same compute-heavy numeric plan (real JAX through the store) on
the **local** backend (S x d worker threads in one Python process) and the
**process** backend (S x d real OS processes over the file store) and
reports the speedup of steady-state seconds per step.  Steady state is
``(step_ends[-1] - step_ends[0]) / (N - 1)`` off the trace metadata — the
first step (jit compile, process spawn) is excluded by construction — and
each backend takes the **min over reps** (scheduler noise only adds time).

The gate is host-aware, because the quantity under test depends on the
machine:

* **enough cores** (``cpu_count >= 2 * n_workers``): stage compute can
  actually run in parallel, so the gate enforces the GIL-release win —
  ``process`` must be at least ``--min-speedup`` (default 1.05x) faster
  than ``local``.
* **core-starved hosts** (fewer cores than that, e.g. 1-core CI
  containers): there is no parallelism to win, and the measurement
  degenerates to pricing the process substrate itself (spawn, file locks,
  the shared ``stats.json``, pickling through the filesystem).  The gate
  then enforces an overhead **ceiling** instead: ``process`` must stay
  within ``1 / --min-overhead-speedup`` (default 0.25x, i.e. at most 4x
  slower).  The JSON records which basis applied (``gate_basis``) so a
  green run on a laptop and a green run in CI cannot be confused.

``--min-speedup auto`` (the default) picks the basis from the live host.
Writes ``BENCH_backend_scaling.json`` at the repo root; ``--check`` exits 1
on breach.

    PYTHONPATH=src python -m benchmarks.backend_scaling [--fast] [--check]
"""
from __future__ import annotations

import dataclasses
import json
import os

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_JSON = os.path.join(_REPO_ROOT, "BENCH_backend_scaling.json")

DEFAULT_MIN_SPEEDUP = 1.05       # parallel hosts: the GIL-release win
DEFAULT_MIN_OVERHEAD_SPEEDUP = 0.25   # starved hosts: <= 4x substrate tax


def _setup(*, n_layers, B, seq, d, mu, steps):
    import jax

    import repro.configs as configs
    from repro.configs.base import InputShape
    from repro.core.perfmodel import Config
    from repro.core.profiler import arch_model_profile
    from repro.data.synthetic import make_batch
    from repro.models import registry
    from repro.optim import AdamW
    from repro.serverless.platform import AWS_LAMBDA
    from repro.serverless.runtime import Execution

    cfg = dataclasses.replace(configs.get_config("phi3-mini-3.8b").reduced(),
                              n_layers=n_layers)
    shape = InputShape("bscale", seq, B, "train")
    prof = arch_model_profile(cfg, AWS_LAMBDA, seq=seq,
                              micro_batch=B // (d * mu))
    L = prof.L
    x = tuple(1 if i == 2 else 0 for i in range(L - 1))
    config = Config(x=x, d=d, z=tuple(0 for _ in range(L)))
    params0 = registry.init_params(cfg, jax.random.PRNGKey(0))
    optimizer = AdamW(lr=1e-2)
    batches = [make_batch(cfg, shape, step=k) for k in range(steps)]
    mk_exec = lambda: Execution(cfg=cfg, optimizer=optimizer,  # noqa: E731
                                init_params=params0,
                                batch_fn=lambda k: batches[k])
    return prof, config, d * mu, mk_exec


def _steady_s_per_step(prof, config, M, mk_exec, backend, steps) -> float:
    from repro.serverless.platform import AWS_LAMBDA
    from repro.serverless.runtime import run_plan

    res = run_plan(prof, AWS_LAMBDA, config, M, steps=steps,
                   pipelined_sync=True, execution=mk_exec(),
                   backend=backend, trace=True)
    ends = res.trace.meta["step_ends"]
    assert len(ends) >= 2, "need >= 2 steps for a steady-state estimate"
    return (ends[-1] - ends[0]) / (len(ends) - 1)


def rows(fast: bool = False, min_speedup: str = "auto"):
    reps = 1 if fast else 2
    steps = 3 if fast else 4
    # compute-heavy on purpose: big enough matmuls that stage compute, not
    # store chatter, dominates a step — that is where process parallelism
    # can show up at all
    wl = dict(n_layers=4, B=32, seq=64, d=2, mu=2, steps=steps)
    prof, config, M, mk_exec = _setup(**wl)
    n_workers = (sum(config.x) + 1) * config.d

    out = []
    best = {}
    for name in ("local", "process"):
        best[name] = min(
            _steady_s_per_step(prof, config, M, mk_exec, name, steps)
            for _ in range(reps))
        out.append({"bench": f"{name}_steady", "reps": reps, "steps": steps,
                    "workload": {k: v for k, v in wl.items() if k != "steps"},
                    "s_per_step": round(best[name], 6)})

    speedup = best["local"] / best["process"]
    cores = os.cpu_count() or 1
    parallel_host = cores >= 2 * n_workers
    if min_speedup == "auto":
        limit = (DEFAULT_MIN_SPEEDUP if parallel_host
                 else DEFAULT_MIN_OVERHEAD_SPEEDUP)
    else:
        limit = float(min_speedup)
    basis = ("parallel-host GIL-release win" if parallel_host else
             "core-starved host: gating the process-substrate overhead "
             "ceiling (no parallelism available to win)")
    out.append({"bench": "gate", "cores": cores, "n_workers": n_workers,
                "local_s": round(best["local"], 6),
                "process_s": round(best["process"], 6),
                "speedup": round(speedup, 4),
                "min_speedup": round(limit, 4),
                "gate_basis": basis,
                "ok": speedup >= limit})
    with open(OUT_JSON, "w") as f:
        json.dump(out, f, indent=1)
    return out


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(prog="benchmarks.backend_scaling")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--min-speedup", default="auto",
                    help="required process-vs-local steady-state speedup; "
                         "'auto' (default) picks 1.05 on hosts with >= 2x "
                         "cores per worker and the 0.25 overhead ceiling "
                         "otherwise")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if the speedup gate is breached")
    args = ap.parse_args(argv)
    rs = rows(fast=args.fast, min_speedup=args.min_speedup)
    for r in rs:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    gate = next(r for r in rs if r["bench"] == "gate")
    if args.check and not gate["ok"]:
        print(f"FAIL: process/local steady-state speedup {gate['speedup']}x "
              f"below required {gate['min_speedup']}x "
              f"({gate['gate_basis']})")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
