"""The paper's workflow ①-⑤: profile a model, co-optimize partition +
resources (MIQP), print the Pareto frontier and the recommended config,
compare with the baseline algorithms.

    PYTHONPATH=src python examples/plan_serverless.py [model] [global_batch] [merge_to]

model ∈ paper models (bert-large, amoebanet-d18/36, resnet101) or any
assigned arch id (planned via the ArchConfig bridge).

This is a thin wrapper over the unified CLI — the same run is

    PYTHONPATH=src python -m repro sweep --model bert-large --batch 64 --merge-to 12

and the library front door is ``repro.api.session(...).sweep()``; add
``--save-dir`` to keep every swept DeploymentPlan as replayable JSON.
"""
import argparse

from repro.cli import main as cli_main


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("model", nargs="?", default="bert-large")
    ap.add_argument("global_batch", nargs="?", type=int, default=64)
    ap.add_argument("merge_to", nargs="?", type=int, default=12)
    ap.add_argument("--save-dir", default=None,
                    help="save the swept DeploymentPlan JSONs here")
    args = ap.parse_args(argv)
    cli_argv = ["sweep", "--model", args.model,
                "--batch", str(args.global_batch),
                "--merge-to", str(args.merge_to)]
    if args.save_dir:
        cli_argv += ["--save-dir", args.save_dir]
    return cli_main(cli_argv)


if __name__ == "__main__":
    raise SystemExit(main())
