"""The paper's workflow ①-⑤: profile a model, co-optimize partition +
resources (MIQP), print the Pareto frontier and the recommended config,
compare with the baseline algorithms.

    PYTHONPATH=src python examples/plan_serverless.py [model] [global_batch] [merge_to]

model ∈ paper models (bert-large, amoebanet-d18/36, resnet101) or any
assigned arch id (planned via the ArchConfig bridge).

The solver runs the batched engine (``perfmodel.evaluate_batch``), so
planning at merge_to=12 — beyond what the paper's minute-scale MIQP budget
allowed — is sub-second here; pass a third argument to go deeper still.
"""
import sys

from repro.configs import ARCH_IDS, get_config
from repro.core import planner
from repro.core.partition import stages_of
from repro.core.profiler import arch_model_profile, paper_model_profile
from repro.serverless.frameworks import ALPHA_PAIRS
from repro.serverless.platform import AWS_LAMBDA, GB
from repro.serverless.simulator import simulate_funcpipe


def main():
    model = sys.argv[1] if len(sys.argv) > 1 else "bert-large"
    gb = int(sys.argv[2]) if len(sys.argv) > 2 else 64
    merge_to = int(sys.argv[3]) if len(sys.argv) > 3 else 12
    if model in ARCH_IDS:
        prof = arch_model_profile(get_config(model), AWS_LAMBDA)
    else:
        prof = paper_model_profile(model, AWS_LAMBDA)
    M = gb // 4
    print(f"model={model} params={prof.param_bytes/2**20:.0f}MB layers={prof.L} "
          f"global_batch={gb} micro_batches={M} merge_to={merge_to}")
    results = []
    for alpha in ALPHA_PAIRS:
        r = planner.solve(prof, AWS_LAMBDA, alpha=alpha, total_micro_batches=M,
                          merge_to=merge_to)
        if r is None:
            print(f"alpha={alpha}: infeasible")
            continue
        results.append(r)
        sim = simulate_funcpipe(r.profile, AWS_LAMBDA, r.config, M)
        st = stages_of(r.config.x)
        mems = [AWS_LAMBDA.memory_options[r.config.z[lo]] // (1024**2) for lo, _ in st]
        print(f"alpha2={alpha[1]:.2e}: stages={len(st)} d={r.config.d} "
              f"mem={mems}MB t_iter={sim.t_iter:.2f}s cost=${sim.cost:.5f} "
              f"(model predicts {r.evaluation.t_iter:.2f}s; solve {r.solve_seconds:.1f}s)")
    if not results:
        print("no feasible FuncPipe config for this model/batch on this "
              "platform (try a smaller batch or the alibaba platform)")
        return
    rec = planner.recommend(results)
    print(f"\nRECOMMENDED: d={rec.config.d}, {sum(rec.config.x)+1} stages, "
          f"t={rec.evaluation.t_iter:.2f}s, ${rec.evaluation.c_iter:.5f}/iter")

    print("\nbaseline algorithms (same objective, alpha2=2^19e-9):")
    kw = dict(alpha=(1.0, 2**19 * 1e-9), total_micro_batches=M, merge_to=8)
    for name, fn in [("tpdmp", planner.tpdmp_solve),
                     ("bayes", lambda *a, **k: planner.bayes_solve(*a, rounds=100, **k))]:
        r = fn(prof, AWS_LAMBDA, **kw)
        if r:
            print(f"  {name}: t={r.evaluation.t_iter:.2f}s ${r.evaluation.c_iter:.5f} "
                  f"obj={r.objective:.5f}")


if __name__ == "__main__":
    main()
