"""End-to-end driver: train the FULL xlstm-125m (an assigned ~125M-param
architecture) for a few hundred steps on CPU with synthetic data,
checkpointing via the Function-Manager policy.

    PYTHONPATH=src python examples/train_e2e.py --steps 300 --seq 64 --batch 4
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import FunctionManager, restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.configs.base import InputShape
from repro.data.synthetic import make_batch
from repro.models import registry
from repro.optim import AdamW


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_e2e.msgpack")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--out", default="benchmarks/results/e2e_loss.csv")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    n = cfg.param_count()
    print(f"training FULL {cfg.name}: {n/1e6:.0f}M params, seq={args.seq}, "
          f"batch={args.batch}, {args.steps} steps")
    shape = InputShape("e2e", args.seq, args.batch, "train")
    optimizer = AdamW(lr=args.lr, weight_decay=0.01)

    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    params = jax.tree.map(lambda a: a.astype(jnp.float32), params)
    opt_state = jax.tree.map(
        lambda p: {"master": p.astype(jnp.float32),
                   **optimizer.init_state(p.astype(jnp.float32))}, params)
    start_step = 0
    if os.path.exists(args.ckpt):
        (params, opt_state), start_step = restore_checkpoint(
            args.ckpt, (params, opt_state))
        print(f"resumed from checkpoint at step {start_step}")

    @jax.jit
    def train_step(params, opt_state, batch, step_idx):
        def loss_of(p):
            loss, m = registry.loss_fn(cfg, p, batch)
            return loss, m

        (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(params)

        def upd(g, p, st):
            new_m, new_sub = optimizer.update(
                g, st["master"], {k: v for k, v in st.items() if k != "master"},
                step_idx)
            return new_m.astype(p.dtype), {"master": new_m, **new_sub}

        flat_g, tdef = jax.tree.flatten(grads)
        flat_p = jax.tree.leaves(params)
        flat_s = jax.tree.leaves(opt_state,
                                 is_leaf=lambda x: isinstance(x, dict) and "master" in x)
        outs = [upd(g, p, s) for g, p, s in zip(flat_g, flat_p, flat_s)]
        return (jax.tree.unflatten(tdef, [a for a, _ in outs]),
                jax.tree.unflatten(tdef, [b for _, b in outs]), loss, metrics)

    fm = FunctionManager(args.ckpt, lifetime=15 * 60)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    losses = []
    t_start = time.time()
    with open(args.out, "a") as f:
        for i in range(start_step, args.steps):
            batch = make_batch(cfg, shape, step=i)
            params, opt_state, loss, metrics = train_step(
                params, opt_state, batch, jnp.int32(i))
            loss = float(loss)
            losses.append(loss)
            f.write(f"{i},{loss:.5f}\n")
            f.flush()
            if i % 10 == 0 or i == args.steps - 1:
                dt = time.time() - t_start
                print(f"step {i:4d} loss={loss:.4f} "
                      f"({dt/(i-start_step+1):.1f}s/step)", flush=True)
            if (i + 1) % args.ckpt_every == 0 or fm.should_checkpoint():
                fm.checkpoint_and_restart((params, opt_state), i + 1)
                print(f"  checkpointed at step {i+1}")
    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'DECREASED' if last < first else 'no decrease'})")


if __name__ == "__main__":
    main()
