"""The paper's pipelined scatter-reduce on TPU rings: uni vs bidirectional
ring reduce-scatter/all-gather on 8 fake devices + the analytic eq(1)/eq(2)
comparison on the serverless side.

    PYTHONPATH=src python examples/scatter_reduce_demo.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import collectives as cc
from repro.core.perfmodel import sync_time_nonpipelined, sync_time_pipelined
from repro.serverless.platform import MB


def main():
    print("=== serverless storage scatter-reduce (paper §3.3) ===")
    s, w, lat = 280 * MB, 70 * MB, 0.040
    for n in [2, 4, 8, 16]:
        t1 = sync_time_nonpipelined(s, w, n, lat)
        t2 = sync_time_pipelined(s, w, n, lat)
        print(f"  n={n:2d}: LambdaML {t1:6.2f}s  FuncPipe {t2:6.2f}s  "
              f"(-{(1-t2/t1)*100:.0f}%)")

    print("\n=== TPU ring analog (bidirectional = full-duplex ICI) ===")
    for d in [4, 8, 16]:
        uni = cc.all_reduce_cost(1e9, d, False)
        bi = cc.all_reduce_cost(1e9, d, True)
        print(f"  d={d:2d}: 1GB all-reduce link-bytes: uni {uni.bytes_on_link/1e6:.0f}MB "
              f"-> bidi {bi.bytes_on_link/1e6:.0f}MB "
              f"({uni.bytes_on_link/1e6/50:.1f}ms -> {bi.bytes_on_link/1e6/50:.1f}ms @50GB/s)")

    print("\n=== correctness on 8 fake devices ===")
    mesh = jax.make_mesh((8,), ("d",), axis_types=(jax.sharding.AxisType.Auto,))
    x = jax.random.normal(jax.random.PRNGKey(0), (8 * 1024,), jnp.float32)
    ref = jax.jit(jax.shard_map(
        lambda t: jax.lax.psum_scatter(t, "d", scatter_dimension=0, tiled=True),
        mesh=mesh, in_specs=P("d"), out_specs=P("d"), check_vma=False))(x)
    for bi in (False, True):
        rs = jax.jit(jax.shard_map(
            lambda t: cc.ring_reduce_scatter(t, "d", bidirectional=bi),
            mesh=mesh, in_specs=P("d"), out_specs=P("d"), check_vma=False))(x)
        print(f"  {'bidirectional' if bi else 'unidirectional':14s} ring RS "
              f"max|err| = {float(jnp.max(jnp.abs(rs-ref))):.2e}")


if __name__ == "__main__":
    main()
