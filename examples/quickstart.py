"""Quickstart: pipelined training of a reduced arch on 8 fake CPU devices.

    PYTHONPATH=src python examples/quickstart.py [arch] [steps]

Shows the full production path in miniature: config -> plan -> param layout ->
pipelined train step (GPipe over 4 stages x 2-way data parallel with the
paper's bidirectional-ring scatter-reduce) -> loss curve.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core import sharding
from repro.core.plan import make_plan
from repro.data.synthetic import make_batch
from repro.models import registry
from repro.optim import AdamW
from repro.train.train_step import init_opt_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("arch", nargs="?", default="phi3-mini-3.8b")
    ap.add_argument("steps", nargs="?", type=int, default=10)
    args = ap.parse_args(argv)
    arch, steps = args.arch, args.steps
    import dataclasses
    cfg = dataclasses.replace(get_config(arch).reduced(), stages=4, tensor=1,
                              n_layers=4)
    shape = InputShape("quickstart", 128, 8, "train")
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    plan = make_plan(cfg, shape, data=2, model=4, microbatches=2)
    print(f"arch={cfg.name} plan: stages={plan.stages} tp={plan.tensor} "
          f"microbatches={plan.microbatches} ep={plan.ep}")

    optimizer = AdamW(lr=3e-3)
    with jax.set_mesh(mesh):
        base = registry.init_params(cfg, jax.random.PRNGKey(0))
        params = sharding.to_pipeline_layout(cfg, plan, base)
        opt_state = init_opt_state(cfg, plan, optimizer, params)
        step = make_train_step(cfg, plan, mesh, optimizer, shape)
        for i in range(steps):
            batch = make_batch(cfg, shape, step=i)
            t0 = time.time()
            params, opt_state, metrics = step(params, opt_state, batch, i)
            loss = float(metrics["loss"])
            print(f"step {i:3d} loss={loss:.4f} ce={float(metrics['ce']):.4f} "
                  f"({time.time()-t0:.2f}s)")
    print("done.")


if __name__ == "__main__":
    main()
