"""Serving example: batched prefill + token-by-token decode for a reduced
arch (single device).  Prints per-token latency and throughput.

    PYTHONPATH=src python examples/serve_decode.py [arch] [batch] [new_tokens]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import registry


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("arch", nargs="?", default="gemma3-4b")
    ap.add_argument("batch", nargs="?", type=int, default=8)
    ap.add_argument("new_tokens", nargs="?", type=int, default=32)
    args = ap.parse_args(argv)
    arch, B, n_new = args.arch, args.batch, args.new_tokens
    cfg = get_config(arch).reduced()
    assert not cfg.is_encoder, "encoder archs have no decode path"
    S_pre, s_ctx = 64, 64 + n_new
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S_pre), 0,
                              cfg.vocab_size, jnp.int32)

    prefill = jax.jit(lambda p, b: registry.prefill(cfg, p, b, capacity=s_ctx))
    decode = jax.jit(lambda p, c, t: registry.decode_step(cfg, p, c, t))

    t0 = time.time()
    logits, caches = prefill(params, {"tokens": toks})
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"{cfg.name}: prefill {B}x{S_pre} tokens in {t_prefill:.2f}s")

    cur = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t0 = time.time()
    outs = []
    for _ in range(n_new):
        logits, caches = decode(params, caches, cur)
        cur = jnp.argmax(logits[:, -1], axis=-1, keepdims=True).astype(jnp.int32)
        outs.append(cur)
    jax.block_until_ready(cur)
    dt = time.time() - t0
    print(f"decoded {n_new} tokens x {B} seqs: {dt/n_new*1e3:.1f} ms/token, "
          f"{B*n_new/dt:.1f} tok/s")
    print("sample continuation ids:", [int(o[0, 0]) for o in outs[:8]])


if __name__ == "__main__":
    main()
