"""Backend-agnostic span schema for pipeline tracing (the observability
substrate behind ``EngineResult.trace`` / ``repro inspect``).

A :class:`Span` is one op on one worker's serial resource — a boundary
download, a micro-batch compute, an upload, a phase fence, or a closed-form
sync interval — stamped with the worker's (stage, replica), the training
step, the phase (``fwd``/``bwd``/``sync``) and the clock interval it
occupied.  The *same* schema carries three kinds of timelines:

  * **virtual** spans from the emulated backend (``StageChannel`` emits one
    span per charged resource task, including every scatter-reduce chunk);
  * **wall** spans from the local backend's real threads (host
    ``perf_counter`` intervals around the blocking store ops);
  * **predicted** spans from ``simulate_funcpipe``'s longest-path DP — the
    simulator's opinion of where each op should land, in the same shape, so
    ``repro.obs.attribution`` can difference them cell by cell.

:class:`Trace` bundles spans + run metadata and serializes to the Chrome
Trace Event Format (the ``{"traceEvents": [...]}`` object form) so the file
loads directly in Perfetto / ``chrome://tracing``; the full typed payload
rides along under a ``"repro"`` top-level key (trace viewers ignore unknown
keys), which is what ``Trace.load`` reads back — export round-trips.

:func:`validate_trace` enforces the schema invariants the tests and the CI
checker rely on: per-(worker, resource) spans never overlap, and phases are
ordered within each (worker, step) — all forward work ends before backward
work starts, and backward work ends before the worker's sync uploads begin
(sync *downloads* may legitimately start earlier: the pipelined collective
prefetches peers' chunks on the idle downlink).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

# fwd/bwd/sync are the training phases (ordering-checked below); prefill and
# decode are the serving engine's phases — serving traces have no intra-step
# phase-order invariant beyond lane occupancy
PHASES = ("fwd", "bwd", "sync", "prefill", "decode")
OPS = ("download", "compute", "upload", "barrier", "sync", "retry", "restart")

# which serial worker resource a span occupies; barrier and the closed-form
# sync interval are ordering/aggregate marks, not resource occupancy.
# "retry" (backoff stall across all resources) and "restart" (checkpoint
# restore reads during recovery) are likewise whole-worker recovery marks,
# not single-lane occupancy — repro inspect sums them as recovery overhead.
RESOURCE_OF = {
    "download": "downlink",
    "compute": "cpu",
    "upload": "uplink",
    "barrier": None,
    "sync": None,
    "retry": None,
    "restart": None,
}


class TraceValidationError(ValueError):
    """A trace violates the span-schema invariants (overlapping resource
    spans, out-of-order phases, malformed fields)."""


@dataclass(frozen=True)
class Span:
    """One op on one worker's timeline (all times on the trace's clock)."""

    stage: int
    replica: int
    step: int
    phase: str                  # fwd | bwd | sync
    op: str                     # download | compute | upload | barrier | sync
    start: float
    end: float
    nbytes: float = 0.0         # modeled object size (transfers), else 0
    key: Optional[str] = None   # store key (transfers), else None

    @property
    def worker(self) -> str:
        return f"s{self.stage}r{self.replica}"

    @property
    def duration(self) -> float:
        return self.end - self.start

    @property
    def resource(self) -> Optional[str]:
        return RESOURCE_OF[self.op]

    def to_dict(self) -> dict:
        d = {"stage": self.stage, "replica": self.replica, "step": self.step,
             "phase": self.phase, "op": self.op,
             "start": self.start, "end": self.end}
        if self.nbytes:
            d["nbytes"] = self.nbytes
        if self.key is not None:
            d["key"] = self.key
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Span":
        return cls(stage=int(d["stage"]), replica=int(d["replica"]),
                   step=int(d["step"]), phase=d["phase"], op=d["op"],
                   start=float(d["start"]), end=float(d["end"]),
                   nbytes=float(d.get("nbytes", 0.0)), key=d.get("key"))


class WorkerTracer:
    """One worker's span emitter: bound to a (stage, replica), carrying the
    mutable step/phase state the backend driver keeps current.  ``emit`` is
    the only hot-path call; backends guard it with ``if tracer is not None``
    so untraced runs pay nothing."""

    __slots__ = ("_spans", "stage", "replica", "step", "phase")

    def __init__(self, spans: List[Span], stage: int, replica: int):
        self._spans = spans
        self.stage = stage
        self.replica = replica
        self.step = 0
        self.phase = "fwd"

    def emit(self, op: str, start: float, end: float, *,
             nbytes: float = 0.0, key: Optional[str] = None) -> None:
        self._spans.append(Span(
            stage=self.stage, replica=self.replica, step=self.step,
            phase=self.phase, op=op, start=float(start), end=float(end),
            nbytes=float(nbytes), key=key))


class SpanRecorder:
    """The per-run span sink a backend fills (``ExecutionBackend.
    attach_recorder``).  One shared list; per-worker :class:`WorkerTracer`
    handles append into it (``list.append`` is atomic under the GIL, so the
    local backend's concurrent threads need no extra locking)."""

    def __init__(self) -> None:
        self.spans: List[Span] = []
        self.tracers: List[WorkerTracer] = []

    def tracer(self, stage: int, replica: int) -> WorkerTracer:
        t = WorkerTracer(self.spans, stage, replica)
        self.tracers.append(t)
        return t

    def set_step(self, step: int) -> None:
        for t in self.tracers:
            t.step = step

    def set_phase(self, phase: str) -> None:
        for t in self.tracers:
            t.phase = phase


TRACE_SCHEMA_VERSION = 1


@dataclass
class Trace:
    """Spans + run metadata (+ optionally the simulator's predicted spans in
    the same schema), serializable as a Perfetto-loadable Chrome trace."""

    spans: List[Span] = field(default_factory=list)
    meta: Dict[str, Any] = field(default_factory=dict)
    predicted: Optional[List[Span]] = None

    # ------------------------------------------------------------- payload
    def to_payload(self) -> dict:
        p = {"version": TRACE_SCHEMA_VERSION, "meta": self.meta,
             "spans": [s.to_dict() for s in self.spans]}
        if self.predicted is not None:
            p["predicted"] = [s.to_dict() for s in self.predicted]
        return p

    @classmethod
    def from_payload(cls, p: dict) -> "Trace":
        if not isinstance(p, dict):
            raise ValueError("trace payload is not a JSON object")
        version = p.get("version")
        if version != TRACE_SCHEMA_VERSION:
            raise TraceValidationError(
                f"trace schema version {version!r} != supported "
                f"{TRACE_SCHEMA_VERSION}")
        pred = p.get("predicted")
        return cls(spans=[Span.from_dict(d) for d in p.get("spans", [])],
                   meta=dict(p.get("meta", {})),
                   predicted=(None if pred is None
                              else [Span.from_dict(d) for d in pred]))

    # -------------------------------------------------------- chrome export
    _RES_TID = {"cpu": 0, "uplink": 1, "downlink": 2, None: 3}

    def chrome_events(self) -> List[dict]:
        """Trace Event Format events: pid = stage (predicted stages offset
        by 1000), tid = replica x resource lane, ts/dur in microseconds."""
        events: List[dict] = []
        seen_pids: Dict[int, str] = {}
        seen_tids: set = set()

        def add(spans: List[Span], pid_base: int, tag: str) -> None:
            for s in spans:
                pid = pid_base + s.stage
                if pid not in seen_pids:
                    seen_pids[pid] = f"stage {s.stage}{tag}"
                    events.append({"ph": "M", "name": "process_name",
                                   "pid": pid, "tid": 0,
                                   "args": {"name": seen_pids[pid]}})
                tid = s.replica * 4 + self._RES_TID[s.resource]
                if (pid, tid) not in seen_tids:
                    seen_tids.add((pid, tid))
                    lane = s.resource or "events"
                    events.append({"ph": "M", "name": "thread_name",
                                   "pid": pid, "tid": tid,
                                   "args": {"name": f"r{s.replica} {lane}"}})
                ev = {"ph": "X", "name": f"{s.phase}/{s.op}", "cat": s.phase,
                      "pid": pid, "tid": tid,
                      "ts": s.start * 1e6, "dur": (s.end - s.start) * 1e6,
                      "args": {"step": s.step}}
                if s.nbytes:
                    ev["args"]["bytes"] = s.nbytes
                if s.key is not None:
                    ev["args"]["key"] = s.key
                events.append(ev)

        add(self.spans, 0, "")
        if self.predicted:
            add(self.predicted, 1000, " (predicted)")
        return events

    def to_chrome_json(self, *, indent: Optional[int] = None) -> str:
        # object form of the Trace Event Format; viewers ignore the extra
        # "repro" key, Trace.load reads it back — one file serves both
        doc = {"traceEvents": self.chrome_events(),
               "displayTimeUnit": "ms",
               "repro": self.to_payload()}
        return json.dumps(doc, indent=indent)

    # ----------------------------------------------------------------- file
    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_chrome_json() + "\n")

    @classmethod
    def load(cls, path) -> "Trace":
        with open(path) as f:
            doc = json.load(f)
        if "repro" in doc:
            return cls.from_payload(doc["repro"])
        return cls.from_payload(doc)   # bare payload also accepted


# ------------------------------------------------------------------ checking
def _check_no_overlap(spans: List[Span], eps: float, where: str,
                      problems: List[str]) -> None:
    ordered = sorted(spans, key=lambda s: (s.start, s.end))
    for a, b in zip(ordered, ordered[1:]):
        if b.start < a.end - eps:
            problems.append(
                f"{where}: {a.phase}/{a.op} [{a.start:.6f}, {a.end:.6f}] "
                f"overlaps {b.phase}/{b.op} [{b.start:.6f}, {b.end:.6f}]")
            return           # one report per lane is enough to fail


def validate_trace(trace: Trace, *, eps: Optional[float] = None) -> None:
    """Raise :class:`TraceValidationError` unless the trace satisfies the
    span-schema invariants (see module docstring).  ``eps`` defaults to a
    1e-9 relative slack on the trace's time extent — bit-exact virtual
    clocks pass at equality, wall clocks get timer-granularity room."""
    problems: List[str] = []
    spans = trace.spans
    t_max = max((s.end for s in spans), default=0.0)
    if eps is None:
        eps = 1e-9 * max(1.0, t_max)

    for i, s in enumerate(spans):
        if s.phase not in PHASES:
            problems.append(f"span {i}: unknown phase {s.phase!r}")
        if s.op not in OPS:
            problems.append(f"span {i}: unknown op {s.op!r}")
        if not (s.start == s.start and s.end == s.end):   # NaN
            problems.append(f"span {i}: non-finite times")
        elif s.end < s.start - eps:
            problems.append(f"span {i}: end {s.end} < start {s.start}")
        if s.nbytes < 0:
            problems.append(f"span {i}: negative nbytes {s.nbytes}")
        if problems and len(problems) >= 8:
            raise TraceValidationError("; ".join(problems))

    # per-(worker, resource) serial occupancy
    lanes: Dict[tuple, List[Span]] = {}
    for s in spans:
        if s.resource is not None:
            lanes.setdefault((s.stage, s.replica, s.resource), []).append(s)
    for (st, r, res), lane in sorted(lanes.items()):
        _check_no_overlap(lane, eps, f"worker s{st}r{r} {res}", problems)

    # per-(worker, step) phase ordering; barriers are the fences themselves
    # and span the transition, so they are exempt; sync downloads may start
    # before the worker's own bwd tail (full-duplex prefetch), so the sync
    # gate is checked against sync *uploads* only
    groups: Dict[tuple, Dict[str, List[Span]]] = {}
    for s in spans:
        if s.op == "barrier":
            continue
        groups.setdefault((s.stage, s.replica, s.step), {}) \
              .setdefault(s.phase, []).append(s)
    # a recovered run may replay a step after a mid-step fault: the same
    # (worker, step) then holds several *attempts*, sequential in time.
    # Replay leniency is earned, not assumed: only a trace that carries
    # recovery evidence (restart spans, or a fault_report recording
    # restarts) gets it — a phase-disordered ordinary trace still fails.
    fr = trace.meta.get("fault_report") or {}
    recovered = (any(s.op == "restart" for s in spans)
                 or bool(fr.get("restarts") or fr.get("planned_restarts")))
    for (st, r, k), by_phase in sorted(groups.items()):
        # within the group, a fwd span starting after bwd/sync spans were
        # seen opens a new attempt; phase ordering must hold within each
        # attempt, not across the aborted one and its replay
        ordered = sorted((s for ph in by_phase.values() for s in ph),
                         key=lambda s: (s.start, s.end))
        if recovered and any(s.op == "restart" for s in ordered):
            # the crashed step itself: its group mixes the aborted attempt,
            # the checkpoint-restore reads, and a replay whose spans virtual
            # clocks charge at per-lane free times with no causal edge to
            # the restore — phase order across that mix is meaningless.
            # Lane occupancy (above) still holds; numeric parity is the
            # real invariant for recovered steps (tests/test_faults.py).
            continue
        attempts: List[List[Span]] = [[]]
        if recovered:
            past_fwd = False
            for s in ordered:
                if s.phase == "fwd" and past_fwd:
                    attempts.append([])
                    past_fwd = False
                if s.phase in ("bwd", "sync"):
                    past_fwd = True
                attempts[-1].append(s)
        else:
            attempts[0] = ordered
        for att in attempts:
            fwd_end = max((s.end for s in att if s.phase == "fwd"),
                          default=None)
            bwd = [s for s in att if s.phase == "bwd"]
            if fwd_end is not None and bwd:
                bwd_start = min(s.start for s in bwd)
                if bwd_start < fwd_end - eps:
                    problems.append(
                        f"worker s{st}r{r} step {k}: bwd starts at "
                        f"{bwd_start:.6f} before fwd ends at {fwd_end:.6f}")
            bwd_end = max((s.end for s in bwd), default=None)
            sync_up = [s for s in att
                       if s.phase == "sync" and s.op == "upload"]
            if bwd_end is not None and sync_up:
                sync_start = min(s.start for s in sync_up)
                if sync_start < bwd_end - eps:
                    problems.append(
                        f"worker s{st}r{r} step {k}: sync upload at "
                        f"{sync_start:.6f} before bwd ends at {bwd_end:.6f}")

    if problems:
        raise TraceValidationError("; ".join(problems[:8]))
