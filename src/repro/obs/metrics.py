"""Derived pipeline-health metrics over a span trace.

Definitions (also in README "Observability"):

* **bubble fraction** (per stage): ``1 - compute_busy / (d * elapsed)`` —
  the share of the stage's worker-seconds its CPUs sat idle (pipeline fill/
  drain, boundary-transfer waits, sync).  ``elapsed`` is the trace's total
  run time, so a perfectly packed stage scores 0.
* **uplink / downlink utilization**: transferred bytes divided by what the
  provisioned per-worker bandwidth (``StageAggregates.w``, §5.4/§5.7
  effective) could have moved over the whole run — how much of the paid-for
  link the schedule actually used.  The companion ``*_busy`` fraction is
  time-based (share of worker-seconds the link was charged).
* **straggler ratio**: max over workers of total busy time divided by the
  mean — 1.0 is perfectly balanced; the paper's symmetric stages should sit
  near 1 on the virtual clock, while wall-clock runs expose host jitter.
* **phase byte totals**: uploaded/downloaded bytes per (phase, direction),
  reconciled against the store's own ``StoreStats`` counters — the span
  layer and the byte-accounting layer must tell the same story.
"""
from __future__ import annotations

from typing import Any, Dict, List

from repro.obs.schema import Trace


def pipeline_health(trace: Trace) -> Dict[str, Any]:
    """Utilization table + imbalance + byte reconciliation for a trace."""
    spans = trace.spans
    meta = trace.meta
    S = int(meta.get("S", 1 + max((s.stage for s in spans), default=0)))
    d = int(meta.get("d", 1 + max((s.replica for s in spans), default=0)))
    t_total = float(meta.get("t_total",
                             max((s.end for s in spans), default=0.0)))
    denom = d * t_total if t_total > 0 else float("inf")
    bandwidth = meta.get("bandwidth")    # [S] provisioned bytes/s, optional
    if meta.get("clock") == "wall":
        # modeled bytes over host seconds vs modeled bandwidth is not a
        # utilization — only virtual-clock traces get the bw columns
        bandwidth = None

    stages: List[Dict[str, float]] = []
    for s in range(S):
        mine = [sp for sp in spans if sp.stage == s]
        busy = {"cpu": 0.0, "uplink": 0.0, "downlink": 0.0}
        nbytes = {"uplink": 0.0, "downlink": 0.0}
        for sp in mine:
            res = sp.resource
            if res is not None:
                busy[res] += sp.duration
                if res != "cpu":
                    nbytes[res] += sp.nbytes
        row = {
            "stage": s,
            "compute_frac": busy["cpu"] / denom,
            "bubble_frac": 1.0 - busy["cpu"] / denom,
            "up_frac": busy["uplink"] / denom,
            "dn_frac": busy["downlink"] / denom,
            "up_bytes": nbytes["uplink"],
            "dn_bytes": nbytes["downlink"],
        }
        if bandwidth is not None and t_total > 0:
            cap = d * t_total * float(bandwidth[s])
            row["up_bw_util"] = nbytes["uplink"] / cap
            row["dn_bw_util"] = nbytes["downlink"] / cap
        stages.append(row)

    # straggler/imbalance: total busy seconds per worker
    busy_by_worker: Dict[tuple, float] = {}
    for sp in spans:
        if sp.resource is not None:
            k = (sp.stage, sp.replica)
            busy_by_worker[k] = busy_by_worker.get(k, 0.0) + sp.duration
    vals = list(busy_by_worker.values())
    mean = sum(vals) / len(vals) if vals else 0.0
    straggler = (max(vals) / mean) if mean > 0 else 1.0

    phase_bytes: Dict[str, Dict[str, float]] = {}
    for sp in spans:
        if sp.op in ("upload", "download"):
            direction = "up" if sp.op == "upload" else "dn"
            phase_bytes.setdefault(sp.phase, {"up": 0.0, "dn": 0.0})
            phase_bytes[sp.phase][direction] += sp.nbytes

    out: Dict[str, Any] = {
        "stages": stages,
        "straggler_ratio": straggler,
        "phase_bytes": phase_bytes,
    }

    # recovery overhead: retry-backoff stalls and checkpoint-restore reads
    # (the fault-tolerance layer's footprint on the timeline; zero on a
    # fault-free run)
    retry = [sp for sp in spans if sp.op == "retry"]
    restart = [sp for sp in spans if sp.op == "restart"]
    if retry or restart:
        out["recovery"] = {
            "retry_s": sum(sp.duration for sp in retry),
            "retry_count": len(retry),
            "restart_s": sum(sp.duration for sp in restart),
            "restart_count": len(restart),
            "restart_bytes": sum(sp.nbytes for sp in restart),
        }

    store = meta.get("store")
    if store is not None:
        span_up = sum(sp.nbytes for sp in spans if sp.op == "upload")
        # checkpoint-restore reads ("restart" op) are real store gets — the
        # byte-accounting layer counts them, so the span side must too
        span_dn = sum(sp.nbytes for sp in spans
                      if sp.op in ("download", "restart"))
        up_ref = float(store.get("bytes_in", 0.0))
        dn_ref = float(store.get("bytes_out", 0.0))
        tol = 1e-6 * max(up_ref, dn_ref, 1.0)
        out["reconciliation"] = {
            "span_bytes_up": span_up, "store_bytes_in": up_ref,
            "span_bytes_dn": span_dn, "store_bytes_out": dn_ref,
            "up_delta": span_up - up_ref, "dn_delta": span_dn - dn_ref,
            "ok": abs(span_up - up_ref) <= tol and abs(span_dn - dn_ref) <= tol,
        }
    return out
