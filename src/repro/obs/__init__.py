"""Span-level tracing + metrics for the execution stack (observability).

One schema, three timelines: the emulated backend's virtual-clock spans, the
local backend's wall-clock spans, and ``simulate_funcpipe``'s *predicted*
spans — exported as a Perfetto-loadable Chrome trace, summarized into
pipeline-health metrics, and differenced into a predicted-vs-observed gap
attribution.  Front doors: ``run_plan(..., trace=True)`` /
``Session.emulate(trace=True)`` / ``repro emulate --trace out.json`` /
``repro inspect out.json``.

PR 9 closes the loop: ``repro.obs.calibrate`` folds a traced run back into a
*measured* ``ModelProfile`` and re-plans on it — ``Session.emulate(...)
.calibrate().plan()`` or ``repro calibrate trace.json``.
"""
from repro.obs.attribution import ELAPSED, GapRow, gap_attribution
from repro.obs.calibrate import (
    Calibration,
    PerfModelWarning,
    ReplanReport,
    StageObservation,
    calibrate_profile,
    calibrate_trace,
    observe_stages,
    replan,
    stage_prediction_errors,
)
from repro.obs.metrics import pipeline_health
from repro.obs.schema import (
    OPS,
    PHASES,
    RESOURCE_OF,
    Span,
    SpanRecorder,
    Trace,
    TraceValidationError,
    WorkerTracer,
    validate_trace,
)

__all__ = [
    "ELAPSED", "GapRow", "gap_attribution", "pipeline_health",
    "OPS", "PHASES", "RESOURCE_OF", "Span", "SpanRecorder", "Trace",
    "TraceValidationError", "WorkerTracer", "validate_trace",
    "Calibration", "PerfModelWarning", "ReplanReport", "StageObservation",
    "calibrate_profile", "calibrate_trace", "observe_stages", "replan",
    "stage_prediction_errors",
]
