"""Predicted-vs-observed gap attribution.

The ROADMAP's accuracy targets (the ~4% engine-vs-simulator gap, the 31%
``max_model_rel_err``) are single scalars; this module localizes them.  Both
the engine trace (observed) and ``simulate_funcpipe(trace=True)`` (predicted)
speak the same span schema, so the per-(stage, phase, op) busy totals can be
differenced directly:

* **op cells** — observed busy seconds summed per (stage, phase, op) and
  normalized per replica-step (the predicted timeline is one step of one
  replica), against the predicted cell sum.  A large ``download`` gap on one
  stage means the cost model's boundary-transfer term is off *there*.
* **elapsed cells** (``op="(elapsed)"``) — the phase's makespan per (stage,
  phase): observed ``max(end) - min(start)`` averaged over (replica, step)
  vs the predicted extent.  Busy sums can match while the *placement* drifts
  (serialization the simulator missed); elapsed catches that.  The sync
  phase is compared on elapsed only: observed sync is per-chunk transfers,
  predicted sync is one closed-form interval.

Rows are ranked by absolute gap — the top row is where the simulator and
the runtime disagree most, i.e. where the roofline/1F1B work should look
first.  On wall-clock traces the comparison crosses clocks (host seconds vs
modeled seconds); ``repro inspect`` labels it accordingly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.obs.schema import Span, Trace

ELAPSED = "(elapsed)"


@dataclass(frozen=True)
class GapRow:
    """One (stage, phase, op) attribution cell, per replica-step seconds."""

    stage: int
    phase: str
    op: str                    # an op name, or "(elapsed)" for phase makespan
    observed_s: float
    predicted_s: float

    @property
    def gap_s(self) -> float:
        return self.observed_s - self.predicted_s

    @property
    def rel_err(self) -> float:
        return self.gap_s / max(self.predicted_s, 1e-12)


def _busy_cells(spans: List[Span]) -> Dict[Tuple[int, str, str], float]:
    cells: Dict[Tuple[int, str, str], float] = {}
    for s in spans:
        if s.op == "barrier":
            continue
        k = (s.stage, s.phase, s.op)
        cells[k] = cells.get(k, 0.0) + s.duration
    return cells


def _elapsed_cells(spans: List[Span]) -> Dict[Tuple[int, str], float]:
    """Phase makespan per (stage, phase), averaged over (replica, step)."""
    extent: Dict[tuple, Tuple[float, float]] = {}
    for s in spans:
        if s.op == "barrier":
            continue
        k = (s.stage, s.phase, s.replica, s.step)
        lo, hi = extent.get(k, (s.start, s.end))
        extent[k] = (min(lo, s.start), max(hi, s.end))
    agg: Dict[Tuple[int, str], List[float]] = {}
    for (stage, phase, _r, _k), (lo, hi) in extent.items():
        agg.setdefault((stage, phase), []).append(hi - lo)
    return {k: sum(v) / len(v) for k, v in agg.items()}


def gap_attribution(trace: Trace,
                    predicted: Optional[List[Span]] = None) -> List[GapRow]:
    """Attribution rows, most divergent (by ``|gap_s|``) first.

    ``predicted`` defaults to ``trace.predicted``; raises ``ValueError``
    when the trace carries no predicted timeline to difference against."""
    if predicted is None:
        predicted = trace.predicted
    if not predicted:
        raise ValueError(
            "trace has no predicted spans — produce it via "
            "`repro emulate --trace` (which attaches the simulator's "
            "timeline) or pass predicted= explicitly")
    meta = trace.meta
    steps = int(meta.get("steps", 1))
    d = int(meta.get("d", 1 + max((s.replica for s in trace.spans),
                                  default=0)))
    norm = max(1, steps) * max(1, d)   # predicted = 1 step of 1 replica

    rows: List[GapRow] = []
    obs = _busy_cells(trace.spans)
    pred = _busy_cells(predicted)
    for (stage, phase, op) in sorted(set(obs) | set(pred)):
        if phase == "sync":
            continue           # per-chunk vs closed-form: elapsed-only below
        rows.append(GapRow(stage=stage, phase=phase, op=op,
                           observed_s=obs.get((stage, phase, op), 0.0) / norm,
                           predicted_s=pred.get((stage, phase, op), 0.0)))

    obs_el = _elapsed_cells(trace.spans)
    pred_el = _elapsed_cells(predicted)
    for (stage, phase) in sorted(set(obs_el) | set(pred_el)):
        rows.append(GapRow(stage=stage, phase=phase, op=ELAPSED,
                           observed_s=obs_el.get((stage, phase), 0.0),
                           predicted_s=pred_el.get((stage, phase), 0.0)))

    rows.sort(key=lambda r: (-abs(r.gap_s), r.stage, r.phase, r.op))
    return rows
