"""Closed-loop trace calibration: measured profiles drive re-planning.

PR 6's gap attribution *localizes* predicted-vs-observed disagreement; this
module makes the planner consume it.  A traced run (``run_plan(...,
ExecutionConfig(trace=True))`` — ideally ``--backend process --payload-true
--throttle``, which moves real payloads through a real store at the plan's
modeled per-worker bandwidth, so spans carry real seconds under the plan's
own budget) is folded back into the per-layer tables:

* **compute** — observed mean per-micro-batch fwd/bwd compute per stage,
  divided by the analytic ``stage_aggregates`` terms, gives one
  multiplicative scale per (stage, direction); it is applied across *all*
  memory options of every layer in the stage (ratio calibration: the
  memory->CPU shape of the analytic model is retained, its level is
  corrected).  Stages whose phase was never observed keep their analytic
  values.
* **boundary bytes** — with ``payload_true``, upload spans carry real
  payload sizes; the boundary layers' ``out_bytes``/``grad_out_bytes`` are
  rescaled to the observed means (these drive the pipeline-transfer and
  planner communication terms).
* **bandwidth / sync** — observed effective store bandwidth and the per-step
  sync makespan are *compared* against the model and surfaced as named
  :class:`PerfModelWarning` signatures (e.g. the eq (2) closed-form sync
  underestimating the per-chunk collective) rather than folded in — they are
  platform terms, not profile terms.

The result is a **measured** :class:`~repro.core.partition.ModelProfile`
(``source="measured"`` + :class:`~repro.core.partition.CalibrationMeta`,
folded into the profile fingerprint so measured plans never collide with
analytic plan-cache entries), plus before/after prediction-error tables and
:func:`replan` — re-solve on the measured tables and report the plan delta.

Front doors: ``Session.emulate(...).calibrate().plan()`` and
``repro calibrate trace.json`` (the trace file embeds its plan).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.partition import (
    CalibrationMeta,
    LayerProfile,
    ModelProfile,
    stages_of,
)
from repro.core.perfmodel import Config, evaluate
from repro.obs.schema import Span, Trace
from repro.serverless.platform import MB, Platform
from repro.serverless.simulator import stage_aggregates

_EPS = 1e-12


# ---------------------------------------------------------------- observation
@dataclass(frozen=True)
class StageObservation:
    """What one pipeline stage's spans actually measured (trace clock)."""

    stage: int
    n_fwd: int                          # fwd compute spans folded in
    n_bwd: int
    fwd_compute_s: Optional[float]      # mean per-micro-batch fwd compute
    bwd_compute_s: Optional[float]
    fwd_up_bytes: Optional[float]       # mean fwd boundary upload payload
    bwd_up_bytes: Optional[float]       # mean bwd boundary upload payload
    up_bw: Optional[float]              # effective uplink bytes/s (pipeline)
    dn_bw: Optional[float]              # effective downlink bytes/s


def _mean(vals: List[float]) -> Optional[float]:
    return float(np.mean(vals)) if vals else None


def _effective_bw(spans: List[Span], t_lat: float) -> Optional[float]:
    """Total bytes over total (duration - latency) across transfer spans."""
    xs = [(s.nbytes, s.duration) for s in spans if s.nbytes > 0]
    if not xs:
        return None
    nbytes = sum(b for b, _ in xs)
    busy = sum(max(t - t_lat, _EPS) for _, t in xs)
    return float(nbytes / max(busy, _EPS))


def default_warmup(trace: Trace) -> int:
    """Steps to drop before averaging: wall-clock runs pay JIT compilation
    (and OS scheduling cold-start) in step 0, so multi-step wall traces
    skip it; virtual clocks are exact from step 0."""
    meta = trace.meta
    steps = int(meta.get("steps", 1))
    return 1 if meta.get("clock") == "wall" and steps > 1 else 0


def observe_stages(trace: Trace, *,
                   warmup: Optional[int] = None) -> List[StageObservation]:
    """Reduce a trace's spans to per-stage observed quantities.

    ``warmup`` drops the first N steps from the averages (default:
    :func:`default_warmup`).  Recovery marks (``retry``/``restart``) and
    barriers are never folded in; replayed attempts of a recovered step
    contribute like any other sample."""
    meta = trace.meta
    if warmup is None:
        warmup = default_warmup(trace)
    t_lat = float(meta.get("t_lat", 0.0))
    S = int(meta.get("S", 1 + max((s.stage for s in trace.spans), default=0)))

    by_stage: Dict[int, List[Span]] = {s: [] for s in range(S)}
    for sp in trace.spans:
        if sp.step < warmup or sp.op in ("barrier", "retry", "restart"):
            continue
        by_stage.setdefault(sp.stage, []).append(sp)

    out = []
    for s in range(S):
        spans = by_stage.get(s, [])
        fwd_c = [x.duration for x in spans
                 if x.op == "compute" and x.phase == "fwd"]
        bwd_c = [x.duration for x in spans
                 if x.op == "compute" and x.phase == "bwd"]
        fwd_up = [x.nbytes for x in spans
                  if x.op == "upload" and x.phase == "fwd" and x.nbytes > 0]
        bwd_up = [x.nbytes for x in spans
                  if x.op == "upload" and x.phase == "bwd" and x.nbytes > 0]
        pipe = [x for x in spans if x.phase in ("fwd", "bwd")]
        out.append(StageObservation(
            stage=s, n_fwd=len(fwd_c), n_bwd=len(bwd_c),
            fwd_compute_s=_mean(fwd_c), bwd_compute_s=_mean(bwd_c),
            fwd_up_bytes=_mean(fwd_up), bwd_up_bytes=_mean(bwd_up),
            up_bw=_effective_bw([x for x in pipe if x.op == "upload"], t_lat),
            dn_bw=_effective_bw([x for x in pipe if x.op == "download"],
                                t_lat),
        ))
    return out


# ------------------------------------------------------------------- warnings
@dataclass(frozen=True)
class PerfModelWarning:
    """A named systematic gap-attribution signature — a candidate perf-model
    refinement, not a per-run fluke."""

    name: str                   # stable signature id (tests/docs key on it)
    message: str
    stages: Tuple[int, ...] = ()
    magnitude: float = 0.0      # signature-specific ratio (observed/modeled)

    def describe(self) -> str:
        st = f" stages={list(self.stages)}" if self.stages else ""
        return f"[{self.name}] {self.message}{st}"


def _detect_warnings(observations, agg, *, pipelined_sync: bool,
                     observed_sync: Optional[float],
                     predicted_sync: float, d: int,
                     tol: float = 0.25) -> List[PerfModelWarning]:
    warns: List[PerfModelWarning] = []

    unobserved = tuple(o.stage for o in observations
                       if o.fwd_compute_s is None or o.bwd_compute_s is None)
    if unobserved:
        warns.append(PerfModelWarning(
            name="unobserved-stages",
            message="no compute spans for some stages/phases; their "
                    "analytic table values were kept",
            stages=unobserved))

    scales = [(o.stage, o.fwd_compute_s / max(agg.t_fc[o.stage], _EPS))
              for o in observations if o.fwd_compute_s is not None]
    scales += [(o.stage, o.bwd_compute_s / max(agg.t_bc[o.stage], _EPS))
               for o in observations if o.bwd_compute_s is not None]
    if scales:
        vals = np.array([v for _, v in scales])
        med = float(np.median(vals))
        if np.all(vals > 1.0 + tol):
            warns.append(PerfModelWarning(
                name="compute-underestimate",
                message=f"analytic compute tables systematically "
                        f"underestimate observed stage compute "
                        f"(median x{med:.2f})",
                stages=tuple(sorted({s for s, _ in scales})),
                magnitude=med))
        elif np.all(vals < 1.0 - tol):
            warns.append(PerfModelWarning(
                name="compute-overestimate",
                message=f"analytic compute tables systematically "
                        f"overestimate observed stage compute "
                        f"(median x{med:.2f})",
                stages=tuple(sorted({s for s, _ in scales})),
                magnitude=med))

    bw_ratios = [(o.stage, bw / max(agg.w[o.stage], _EPS))
                 for o in observations
                 for bw in (o.up_bw, o.dn_bw) if bw is not None]
    if bw_ratios:
        med = float(np.median([v for _, v in bw_ratios]))
        if med < 1.0 - tol:
            warns.append(PerfModelWarning(
                name="bandwidth-shortfall",
                message=f"observed effective store bandwidth is x{med:.2f} "
                        "of the platform model's per-worker bandwidth "
                        "(store contention / serialization overhead the "
                        "bandwidth curve does not carry)",
                stages=tuple(sorted({s for s, _ in bw_ratios})),
                magnitude=med))

    if observed_sync is not None and d > 1 and predicted_sync > _EPS:
        ratio = observed_sync / predicted_sync
        eq = "eq2" if pipelined_sync else "eq1"
        if ratio > 1.0 + tol:
            warns.append(PerfModelWarning(
                name=f"{eq}-sync-underestimate",
                message=f"the {eq} closed-form sync time underestimates the "
                        f"observed per-chunk scatter-reduce collective "
                        f"(observed x{ratio:.2f} of predicted — per-chunk "
                        "latency and chunk serialization are not in the "
                        "closed form)",
                magnitude=ratio))
        elif ratio < 1.0 - tol:
            warns.append(PerfModelWarning(
                name=f"{eq}-sync-overestimate",
                message=f"the {eq} closed-form sync time overestimates the "
                        f"observed collective (observed x{ratio:.2f})",
                magnitude=ratio))
    return warns


# ------------------------------------------------------------------ residuals
def stage_prediction_errors(profile: ModelProfile, platform: Platform,
                            config: Config, total_micro_batches: int,
                            observations: List[StageObservation],
                            *, contention: bool = False) -> dict:
    """Per-stage relative errors of the model's ``stage_aggregates`` terms
    against observed values — the quantity calibration must shrink.  Rows
    carry one cell per observed quantity (fwd/bwd per-micro-batch compute,
    boundary upload bytes); ``max_rel_err`` is the headline."""
    agg = stage_aggregates(profile, platform, config, total_micro_batches,
                           contention=contention)
    rows = []
    worst = 0.0
    for o in observations:
        s = o.stage
        cells = {}
        pairs = [("t_fc", float(agg.t_fc[s]), o.fwd_compute_s),
                 ("t_bc", float(agg.t_bc[s]), o.bwd_compute_s)]
        if s < agg.S - 1:
            pairs.append(("out_b", float(agg.out_b[s]), o.fwd_up_bytes))
        if s > 0:
            pairs.append(("grad_b", float(agg.grad_b[s]), o.bwd_up_bytes))
        for name, pred, obs in pairs:
            if obs is None:
                continue
            err = abs(pred - obs) / max(abs(obs), _EPS)
            cells[name] = {"predicted": pred, "observed": obs,
                           "rel_err": err}
            worst = max(worst, err)
        rows.append({"stage": s, "cells": cells})
    return {"stages": rows, "max_rel_err": worst}


# ---------------------------------------------------------------- calibration
@dataclass
class Calibration:
    """A measured profile plus everything learned producing it."""

    profile: ModelProfile               # source="measured"
    observations: List[StageObservation]
    scales: List[dict]                  # per-stage applied scale factors
    warnings: List[PerfModelWarning]
    baseline: dict                      # stage_prediction_errors(analytic)
    residual: dict                      # stage_prediction_errors(measured)
    observed_sync: Optional[float]      # mean per-step sync makespan
    predicted_sync: float               # closed-form t_sync_max
    warmup: int
    meta: dict = field(default_factory=dict)   # trace meta echo (subset)

    def describe(self) -> str:
        lines = [
            f"calibration: {self.profile.name} from "
            f"{self.meta.get('backend', '?')} trace "
            f"({self.meta.get('clock', '?')} clock, "
            f"{self.meta.get('steps', '?')} steps, warmup {self.warmup})",
            "stage  fwd-scale  bwd-scale  out-scale  grad-scale",
        ]
        for row in self.scales:
            def cell(k):
                v = row.get(k)
                return "     -" if v is None else f"x{v:5.2f}"
            lines.append(f"{row['stage']:>5d}  {cell('fwd'):>9s}  "
                         f"{cell('bwd'):>9s}  {cell('out'):>9s}  "
                         f"{cell('grad'):>10s}")
        lines.append(
            f"prediction error (max per-stage rel err): analytic "
            f"{self.baseline['max_rel_err']:.1%} -> measured "
            f"{self.residual['max_rel_err']:.1%}")
        for w in self.warnings:
            lines.append(f"warning {w.describe()}")
        return "\n".join(lines)


def calibrate_profile(trace: Trace, profile: ModelProfile,
                      platform: Platform, config: Config,
                      total_micro_batches: int, *,
                      pipelined_sync: bool = True,
                      contention: bool = False,
                      warmup: Optional[int] = None) -> Calibration:
    """Fold a traced run back into a measured :class:`ModelProfile`.

    ``profile`` must be the (merged) profile the traced plan indexes —
    exactly what ``DeploymentPlan.resolve().profile`` returns.  Layers in
    stages whose phase was never observed keep their analytic values."""
    if profile.source != "analytic":
        raise ValueError(
            f"calibrating a {profile.source!r} profile would compound "
            "scale factors; calibrate from the analytic profile")
    if warmup is None:
        warmup = default_warmup(trace)
    observations = observe_stages(trace, warmup=warmup)
    agg = stage_aggregates(profile, platform, config, total_micro_batches,
                           contention=contention)
    if agg.S != len(observations):
        raise ValueError(f"trace has {len(observations)} stages but the "
                         f"plan's partition has {agg.S}")
    stages = stages_of(config.x)

    scale_rows: List[dict] = []
    fwd_scale = np.ones(agg.S)
    bwd_scale = np.ones(agg.S)
    out_scale = np.ones(agg.S)
    grad_scale = np.ones(agg.S)
    for o in observations:
        s = o.stage
        row = {"stage": s, "fwd": None, "bwd": None, "out": None,
               "grad": None}
        if o.fwd_compute_s is not None and agg.t_fc[s] > _EPS:
            fwd_scale[s] = o.fwd_compute_s / agg.t_fc[s]
            row["fwd"] = float(fwd_scale[s])
        if o.bwd_compute_s is not None and agg.t_bc[s] > _EPS:
            bwd_scale[s] = o.bwd_compute_s / agg.t_bc[s]
            row["bwd"] = float(bwd_scale[s])
        if s < agg.S - 1 and o.fwd_up_bytes is not None \
                and agg.out_b[s] > _EPS:
            out_scale[s] = o.fwd_up_bytes / agg.out_b[s]
            row["out"] = float(out_scale[s])
        if s > 0 and o.bwd_up_bytes is not None and agg.grad_b[s] > _EPS:
            grad_scale[s] = o.bwd_up_bytes / agg.grad_b[s]
            row["grad"] = float(grad_scale[s])
        scale_rows.append(row)

    layers: List[LayerProfile] = []
    for s, (lo, hi) in enumerate(stages):
        for i in range(lo, hi + 1):
            l = profile.layers[i]
            layers.append(dataclasses.replace(
                l,
                fwd_time=tuple(t * fwd_scale[s] for t in l.fwd_time),
                bwd_time=tuple(t * bwd_scale[s] for t in l.bwd_time),
                out_bytes=(l.out_bytes * out_scale[s]
                           if i == hi else l.out_bytes),
                grad_out_bytes=(l.grad_out_bytes * grad_scale[s]
                                if i == lo else l.grad_out_bytes),
            ))

    from repro.api.plan import profile_fingerprint

    meta = trace.meta
    cal_meta = CalibrationMeta(
        backend=str(meta.get("backend", "?")),
        clock=str(meta.get("clock", "?")),
        steps=int(meta.get("steps", 1)),
        base_fingerprint=profile_fingerprint(profile, platform),
        t_total=float(meta.get("t_total", 0.0)),
    )
    measured = ModelProfile(name=profile.name, layers=tuple(layers),
                            source="measured", calibration=cal_meta)

    ev = evaluate(profile, platform, config, total_micro_batches,
                  pipelined_sync=pipelined_sync)
    step_syncs = [float(v) for v in meta.get("step_syncs", [])][warmup:]
    observed_sync = _mean(step_syncs)
    warns = _detect_warnings(observations, agg,
                             pipelined_sync=pipelined_sync,
                             observed_sync=observed_sync,
                             predicted_sync=float(ev.t_sync_max),
                             d=agg.d)
    baseline = stage_prediction_errors(profile, platform, config,
                                       total_micro_batches, observations,
                                       contention=contention)
    residual = stage_prediction_errors(measured, platform, config,
                                       total_micro_batches, observations,
                                       contention=contention)
    keep = ("model", "backend", "clock", "steps", "S", "d", "mu",
            "t_total", "t_iter", "payload_true", "throttle")
    return Calibration(
        profile=measured, observations=observations, scales=scale_rows,
        warnings=warns, baseline=baseline, residual=residual,
        observed_sync=observed_sync, predicted_sync=float(ev.t_sync_max),
        warmup=warmup, meta={k: meta[k] for k in keep if k in meta})


def calibrate_trace(trace: Trace, *, plan=None,
                    warmup: Optional[int] = None) -> Tuple["Calibration", object]:
    """Self-contained front door for ``repro calibrate``: a traced run whose
    metadata embeds its plan (every ``--trace`` file written since the
    calibration loop landed does) comes back as (Calibration, plan).  Pass
    ``plan`` explicitly for older traces."""
    from repro.api.plan import DeploymentPlan

    if plan is None:
        doc = trace.meta.get("plan")
        if doc is None:
            raise ValueError(
                "trace metadata carries no plan document (older trace?) — "
                "pass the plan explicitly (repro calibrate --plan plan.json)")
        import json as _json

        plan = DeploymentPlan.from_json(_json.dumps(doc))
    rp = plan.resolve()
    cal = calibrate_profile(trace, rp.profile, rp.platform, rp.config,
                            rp.total_micro_batches,
                            pipelined_sync=rp.pipelined_sync, warmup=warmup)
    return cal, plan


# --------------------------------------------------------------------- replan
@dataclass
class ReplanReport:
    """The plan delta after re-solving on the measured tables."""

    old_plan: object                    # DeploymentPlan (analytic)
    new_plan: object                    # DeploymentPlan (measured)
    old_on_measured: object             # Evaluation of old config, measured
    new_on_measured: object             # Evaluation of new config, measured
    alpha: Tuple[float, float]

    def describe(self) -> str:
        from repro.serverless.platform import get_platform

        old, new = self.old_plan, self.new_plan
        platform = get_platform(new.platform)
        a1, a2 = self.alpha

        def mems(plan):
            return [platform.memory_options[plan.z[lo]] // MB
                    for lo, _ in stages_of(plan.x)]

        obj_old = self.old_on_measured.objective(a1, a2)
        obj_new = self.new_on_measured.objective(a1, a2)
        delta = (obj_new - obj_old) / max(abs(obj_old), _EPS)
        changed = (tuple(old.x), old.d, tuple(old.z)) != \
                  (tuple(new.x), new.d, tuple(new.z))
        lines = [
            f"re-plan on the measured profile "
            f"({'changed' if changed else 'unchanged'} deployment):",
            f"  stages: {old.n_stages} -> {new.n_stages}   "
            f"d: {old.d} -> {new.d}   M: {old.total_micro_batches} -> "
            f"{new.total_micro_batches}",
            f"  mem/stage: {mems(old)}MB -> {mems(new)}MB",
            f"  analytic plan predicted t_iter={old.t_iter:.3f}s "
            f"cost=${old.c_iter:.6f}; the measured tables price that same "
            f"deployment at t_iter={self.old_on_measured.t_iter:.3f}s "
            f"cost=${self.old_on_measured.c_iter:.6f}",
            f"  re-planned deployment (measured): "
            f"t_iter={self.new_on_measured.t_iter:.3f}s "
            f"cost=${self.new_on_measured.c_iter:.6f} "
            f"(objective {obj_old:.6f} -> {obj_new:.6f}, "
            f"{delta:+.1%})",
        ]
        if not self.old_on_measured.mem_ok:
            lines.append("  note: the old deployment is memory-infeasible "
                         "under the measured tables")
        return "\n".join(lines)


def replan(calibration: Calibration, plan, *,
           alpha: Optional[Tuple[float, float]] = None,
           engine: str = "dp",
           d_options: Optional[Tuple[int, ...]] = None) -> ReplanReport:
    """Re-solve the co-optimization on the measured profile and report the
    delta.  The measured profile is already at the traced plan's merged
    depth, so the solve runs at ``merge_to=None``; ``engine='dp'`` (exact at
    any depth) is the default.  ``alpha`` defaults to the plan's recorded
    objective weights (manual/numeric plans record (1, 0) — pass the paper
    default explicitly when cost-only is not what you want)."""
    from repro.api.plan import DeploymentPlan
    from repro.core import planner
    from repro.serverless.platform import get_platform

    measured = calibration.profile
    platform = get_platform(plan.platform)
    if alpha is None:
        alpha = plan.alpha
    kw = dict(alpha=tuple(alpha),
              total_micro_batches=plan.total_micro_batches,
              merge_to=None, pipelined_sync=plan.pipelined_sync)
    if d_options is not None:
        kw["d_options"] = tuple(d_options)
    r = planner.solve(measured, platform, engine=engine, **kw)
    if r is None:
        raise RuntimeError(
            f"no feasible plan for the measured profile of {plan.model!r} "
            f"on {platform.name} at M={plan.total_micro_batches}")
    new_plan = DeploymentPlan.from_result(
        r, model=plan.model, platform=platform, alpha=tuple(alpha),
        total_micro_batches=plan.total_micro_batches,
        pipelined_sync=plan.pipelined_sync, solver="cd", engine=engine,
        merge_to=None, seq=plan.seq, micro_batch=plan.micro_batch)
    old_ev = evaluate(measured, platform, plan.config,
                      plan.total_micro_batches,
                      pipelined_sync=plan.pipelined_sync)
    return ReplanReport(old_plan=plan, new_plan=new_plan,
                        old_on_measured=old_ev,
                        new_on_measured=r.evaluation, alpha=tuple(alpha))
