from repro.optim.optimizers import SGD, AdamW, Optimizer  # noqa: F401
