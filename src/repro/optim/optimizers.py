"""Optimizers (pure-JAX, per-leaf).  Master weights live in fp32 inside the
optimizer state; with ZeRO-1 (train.train_step) each data-shard owns 1/D of
every master leaf — the paper's scatter-reduce synchronization then becomes
reduce-scatter(grads) -> shard update -> all-gather(params).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp


class Optimizer:
    def init_state(self, master: jax.Array) -> dict:  # pragma: no cover
        raise NotImplementedError

    def update(self, g, master, state, step) -> Tuple[jax.Array, dict]:  # pragma: no cover
        raise NotImplementedError


@dataclass(frozen=True)
class SGD(Optimizer):
    lr: float = 0.01
    momentum: float = 0.9

    def init_state(self, master):
        return {"mu": jnp.zeros_like(master)}

    def update(self, g, master, state, step):
        g = g.astype(jnp.float32)
        mu = self.momentum * state["mu"] + g
        return master - self.lr * mu, {"mu": mu}


@dataclass(frozen=True)
class AdamW(Optimizer):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0

    def init_state(self, master):
        return {"m": jnp.zeros_like(master), "v": jnp.zeros_like(master)}

    def update(self, g, master, state, step):
        g = g.astype(jnp.float32)
        step = step.astype(jnp.float32) + 1.0
        m = self.b1 * state["m"] + (1 - self.b1) * g
        v = self.b2 * state["v"] + (1 - self.b2) * jnp.square(g)
        mhat = m / (1 - self.b1**step)
        vhat = v / (1 - self.b2**step)
        upd = mhat / (jnp.sqrt(vhat) + self.eps) + self.weight_decay * master
        return master - self.lr * upd, {"m": m, "v": v}
