"""``python -m repro`` — the single command-line front door.

    plan      profile a model + co-optimize -> print/save a DeploymentPlan
    simulate  replay a plan through the analytic discrete-event simulator
    emulate   execute a plan through the storage-backed runtime engine
    inspect   validate a trace (emulate/simulate --trace); pipeline-health
              metrics + predicted-vs-observed gap attribution
    sweep     the paper's workflow ①-⑤: Pareto frontier + recommendation +
              the §5.6 baseline algorithms (old examples/plan_serverless.py)
    serve     SLO-aware inference serving: plan a serve partition, execute
              pipelined decode on a backend, autoscale under arrival traces
    bench     run the paper-table benchmark modules (benchmarks/run.py)
    train     mesh/TPU training driver (delegates to repro.launch.train)
    dryrun    mesh compile-only sweep (delegates to repro.launch.dryrun)

Every subcommand that plans accepts ``--fast`` (small merge depth, reduced
DP grid) so CI can smoke the whole surface in seconds.  ``plan -o plan.json``
then ``simulate plan.json`` / ``emulate plan.json`` replays the saved
artifact bit-identically (fingerprint-checked; see ``repro.api``).
"""
from __future__ import annotations

import argparse
import sys
from contextlib import contextmanager
from typing import List, Optional

from repro.serverless.platform import MB, get_platform


@contextmanager
def _operator_errors():
    """Model/platform lookups raise KeyError with a helpful message; at the
    CLI that is an operator typo, not a bug — exit cleanly like the old
    per-driver mains did.  Scoped to the lookup call sites so unrelated
    KeyErrors keep their tracebacks."""
    try:
        yield
    except KeyError as e:
        raise SystemExit(
            f"error: {e.args[0] if e.args else e}") from None

_PLATFORM_CHOICES = ("aws", "alibaba")
_FAST = dict(merge_to=6, d_options=(1, 2, 4))


def _add_model_args(p: argparse.ArgumentParser, *, model_default=None):
    p.add_argument("--model", default=model_default,
                   help="paper model (bert-large, resnet101, amoebanet-d18/36)"
                        " or assigned arch id")
    p.add_argument("--platform", default="aws", choices=_PLATFORM_CHOICES)
    p.add_argument("--batch", type=int, default=None,
                   help="global batch size (default 64)")
    p.add_argument("--micro-batch", type=int, default=None,
                   help="micro-batch size (default 4; explicit values are "
                        "also used when profiling arch models)")
    p.add_argument("--seq", type=int, default=None,
                   help="profiling sequence length (arch models)")
    p.add_argument("--lambda-ml-sync", action="store_true",
                   help="use the 3-phase eq (1) collective instead of eq (2)")
    p.add_argument("--contention", action="store_true",
                   help="model §5.4 bandwidth contention")


def _add_cache_args(p: argparse.ArgumentParser):
    p.add_argument("--plan-cache", default=None, metavar="DIR",
                   help="plan-cache directory (default: $REPRO_PLAN_CACHE "
                        "or ~/.cache/repro/plans)")
    p.add_argument("--no-plan-cache", action="store_true",
                   help="always solve; never read or write the plan cache")


def _add_solver_args(p: argparse.ArgumentParser):
    p.add_argument("--merge-to", type=int, default=None,
                   help="layer-merge depth (default: planner default)")
    p.add_argument("--alpha2", type=float, default=None,
                   help="time weight a2 in the objective a1*c + a2*t "
                        "(a1=1; default 2^16 * 1e-9)")
    p.add_argument("--solver", default="cd",
                   choices=("cd", "cd-steepest", "exhaustive", "tpdmp",
                            "bayes"))
    p.add_argument("--engine", default="batch",
                   choices=("batch", "scalar", "dp"),
                   help="search engine: batch/scalar enumerate the merged "
                        "partition space, dp is the exact cut-point DP "
                        "(defaults to full layer depth unless --merge-to "
                        "or --fast bounds it)")
    p.add_argument("--max-stages", type=int, default=None)
    p.add_argument("--fast", action="store_true",
                   help="CI-sized search (merge_to=6, d in {1,2,4})")


def _cache_spec(args):
    """CLI plan-cache policy: on by default (repeated plans/sweeps become
    near-instant), --no-plan-cache to always solve, --plan-cache DIR to
    point somewhere else."""
    if getattr(args, "no_plan_cache", False):
        return None
    explicit = getattr(args, "plan_cache", None)
    return True if explicit is None else explicit


def _make_session(args, **kw):
    from repro.api import session

    return session(args.model, platform=args.platform,
                   global_batch=64 if args.batch is None else args.batch,
                   micro_batch=args.micro_batch,
                   seq=args.seq, pipelined_sync=not args.lambda_ml_sync,
                   contention=getattr(args, "contention", False),
                   plan_cache=_cache_spec(args), **kw)


def _plan_kw(args) -> dict:
    from repro.core import planner

    alpha2 = 2**16 * 1e-9 if args.alpha2 is None else args.alpha2
    if args.solver == "bayes" and args.engine != "batch":
        # bayes is a random sampler over the batched kernel; silently running
        # it instead of the requested scalar/dp engine would mislead
        raise SystemExit(
            f"--solver bayes only runs on the batch kernel; drop "
            f"--engine {args.engine}")
    kw = dict(alpha=(1.0, alpha2), solver=args.solver,
              engine=args.engine)
    if args.solver in ("cd", "cd-steepest", "exhaustive") \
            and args.max_stages is not None:
        kw["max_stages"] = args.max_stages
    if args.merge_to is not None:
        kw["merge_to"] = args.merge_to
    elif args.fast:
        kw["merge_to"] = _FAST["merge_to"]
    elif args.engine == "dp":
        kw["merge_to"] = None          # exact DP: plan at full layer depth
    else:
        kw["merge_to"] = planner.DEFAULT_MERGE_TO
    if args.fast:
        kw["d_options"] = _FAST["d_options"]
    return kw


def _load_or_plan(args):
    """Shared simulate/emulate input: a saved plan file or --model flags."""
    from repro.api import DeploymentPlan

    if args.plan_file:
        # flags that would contradict what the plan file records must not be
        # silently ignored — a replay always uses the recorded decisions
        conflicting = [name for name, passed in [
            ("--model", args.model),
            ("--lambda-ml-sync", args.lambda_ml_sync),
            ("--batch", args.batch is not None),
            ("--alpha2", args.alpha2 is not None),
            ("--merge-to", args.merge_to is not None),
            ("--seq", args.seq is not None),
            ("--micro-batch", args.micro_batch is not None),
            ("--solver", args.solver != "cd"),
            ("--engine", args.engine != "batch"),
            ("--max-stages", args.max_stages is not None),
            ("--fast", args.fast),
            ("--plan-cache", getattr(args, "plan_cache", None) is not None),
        ] if passed]
        if conflicting:
            raise SystemExit(
                f"{', '.join(conflicting)} conflict with replaying "
                f"{args.plan_file}: a saved plan replays exactly as "
                "recorded.  Drop the flags (or drop the file to plan fresh).")
        try:
            return DeploymentPlan.load(args.plan_file)
        except FileNotFoundError:
            raise SystemExit(f"error: no such plan file: {args.plan_file}")
    if not args.model:
        raise SystemExit("pass a saved plan.json or --model")
    with _operator_errors():        # unknown model/platform lookups only
        s = _make_session(args).profile()
    return s.plan(**_plan_kw(args)).deployment_plan


def _profile_override(args) -> dict:
    """``--profile FILE``: resolve the plan against a saved (typically
    *measured*) ModelProfile instead of rebuilding the analytic tables —
    the only way to replay a plan whose ``profile_source`` is measured."""
    if not getattr(args, "profile", None):
        return {}
    from repro.core.partition import ModelProfile

    try:
        return {"profile": ModelProfile.load(args.profile)}
    except FileNotFoundError:
        raise SystemExit(f"error: no such profile file: {args.profile}")


# ------------------------------------------------------------------- plan
def _cmd_plan(args) -> int:
    if not args.model:
        raise SystemExit("--model is required")
    with _operator_errors():        # unknown model/platform lookups only
        s = _make_session(args).profile()
    plan = s.plan(**_plan_kw(args)).deployment_plan
    print(plan.describe())
    cached = " [plan cache hit]" if s.plan_cache and s.plan_cache.hits else ""
    print(f"solve: {plan.solve_seconds:.2f}s{cached} "
          f"(alpha={plan.alpha[0]:g},{plan.alpha[1]:.3e}; "
          f"objective={plan.objective:.6f})")
    r = s.plan_result
    if r is not None and r.stats is not None:
        print(f"planner: {r.stats.describe()}")
    if args.out:
        plan.save(args.out)
        print(f"wrote {args.out} (content hash {plan.content_hash})")
    return 0


# --------------------------------------------------------------- simulate
def _cmd_simulate(args) -> int:
    from repro.core.perfmodel import evaluate
    from repro.serverless.simulator import simulate_funcpipe

    plan = _load_or_plan(args)
    print(plan.describe())
    # one profile rebuild + fingerprint check (--profile overrides rebuild)
    rp = plan.resolve(**_profile_override(args))
    sim = simulate_funcpipe(rp.profile, rp.platform, rp.config,
                            rp.total_micro_batches,
                            pipelined_sync=rp.pipelined_sync,
                            contention=args.contention,
                            trace=bool(args.trace))
    if args.trace:
        sim.trace.save(args.trace)
        print(f"wrote trace {args.trace} "
              f"({len(sim.trace.spans)} predicted spans)")
    bd = sim.breakdown
    print(f"simulate: t_iter={sim.t_iter:.3f}s cost=${sim.cost:.6f}/iter "
          f"mem={sim.total_mem_gb:.1f}GB "
          f"(compute={bd['compute']:.3f}s pipe_comm={bd['pipeline_comm']:.3f}s "
          f"sync={bd['sync']:.3f}s)")
    ev = evaluate(rp.profile, rp.platform, rp.config, rp.total_micro_batches,
                  pipelined_sync=rp.pipelined_sync)
    print(f"vs perfmodel: t_iter={ev.t_iter:.3f}s "
          f"(rel err {abs(sim.t_iter - ev.t_iter) / ev.t_iter:.1%})")
    return 0


# ---------------------------------------------------------------- emulate
def _numeric_partition(cfg, n_stages: int) -> tuple:
    """Boundary vector over the arch profile ([embed]+layers+[head]) cutting
    at period boundaries so every stage owns whole instances."""
    L = cfg.n_layers + 2
    plen = cfg.period_len
    n_inst = cfg.n_periods
    assert n_stages <= n_inst, (n_stages, n_inst)
    x = [0] * (L - 1)
    for s in range(1, n_stages):
        inst = round(s * n_inst / n_stages)
        layer = inst * plen               # first layer of stage s
        x[layer] = 1                      # cut after profile layer `layer`
    return tuple(x)


def _min_feasible_z(profile, platform, x, d, mu):
    from repro.core import planner

    stage_mem = planner._min_feasible_stage_mem(profile, platform, x, d, mu)
    if stage_mem is None:
        raise SystemExit("no memory option fits the per-stage working set")
    return planner._expand_z(stage_mem, x, profile.L)


def _numeric_plan(args):
    """Numeric-mode setup: period-aligned manual partition + Execution."""
    import dataclasses

    import jax

    from repro.api import DeploymentPlan
    from repro.configs import ARCH_IDS, get_config
    from repro.configs.base import InputShape
    from repro.core.perfmodel import Config
    from repro.core.profiler import arch_model_profile
    from repro.data.synthetic import make_batch
    from repro.models import registry
    from repro.optim import AdamW
    from repro.serverless.runtime import Execution

    platform = get_platform(args.platform)
    arch = args.model or "phi3-mini-3.8b"
    if arch not in ARCH_IDS:
        raise SystemExit(
            f"--numerics runs real JAX and needs an assigned arch id, got "
            f"{arch!r}; archs: {sorted(ARCH_IDS)}")
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              n_layers=args.n_layers)
    seq = args.seq if args.seq is not None else 16
    batch = 64 if args.batch is None else args.batch
    shape = InputShape("emulate", seq, batch, "train")
    mu = max(1, batch // (args.dp * 2))
    if batch % (args.dp * mu):
        raise SystemExit(f"--batch {batch} must be divisible by dp*mu "
                         f"= {args.dp}*{mu}")
    if args.stages > cfg.n_periods:
        raise SystemExit(
            f"--stages {args.stages} exceeds the {cfg.n_periods} period "
            f"instances of {arch} at --n-layers {args.n_layers}")
    mb = batch // (args.dp * mu)
    prof = arch_model_profile(cfg, platform, seq=seq, micro_batch=mb)
    x = _numeric_partition(cfg, args.stages)
    z = _min_feasible_z(prof, platform, x, args.dp, mu)
    plan = DeploymentPlan.from_config(
        prof, platform, Config(x=x, d=args.dp, z=z), args.dp * mu,
        model=f"{arch}@reduced{args.n_layers}",   # replayable spelling
        pipelined_sync=not args.lambda_ml_sync, seq=seq,
        micro_batch=mb, solver="manual")
    params0 = registry.init_params(cfg, jax.random.PRNGKey(0))
    ex = Execution(cfg=cfg, optimizer=AdamW(lr=1e-2), init_params=params0,
                   batch_fn=lambda k: make_batch(cfg, shape, step=k))
    return plan, prof, ex


def _cmd_emulate(args) -> int:
    from repro.core.perfmodel import evaluate
    from repro.serverless.runtime import run_plan
    from repro.serverless.simulator import simulate_funcpipe

    if args.numerics:
        if args.plan_file:
            raise SystemExit(
                "--numerics builds its own period-aligned plan and cannot "
                "replay a plan file; drop the file argument (numeric runs "
                "can SAVE their plan with -o, and that file replays on the "
                "timing axis via `repro simulate`/`repro emulate` without "
                "--numerics)")
        # the numeric partition is manual: solver flags would be silently
        # ignored, so reject them (mirrors the plan-file conflict check)
        ignored = [name for name, passed in [
            ("--merge-to", args.merge_to is not None),
            ("--alpha2", args.alpha2 is not None),
            ("--micro-batch", args.micro_batch is not None),
            ("--solver", args.solver != "cd"),
            ("--engine", args.engine != "batch"),
            ("--max-stages", args.max_stages is not None),
            ("--fast", args.fast),
            ("--profile", bool(args.profile)),
        ] if passed]
        if ignored:
            raise SystemExit(
                f"{', '.join(ignored)} have no effect with --numerics "
                "(the numeric partition comes from --stages/--dp/--batch)")
        plan, prof, ex = _numeric_plan(args)
        rp = plan.resolve(profile=prof)
    else:
        plan = _load_or_plan(args)
        rp = plan.resolve(**_profile_override(args))
        ex = None
    print(plan.describe())
    if args.out:
        plan.save(args.out)
        print(f"wrote {args.out} (content hash {plan.content_hash})")

    from repro.serverless.execution import ExecutionConfig

    faults_obj = None
    if args.fault_plan and args.fault_seed is not None:
        raise SystemExit("--fault-plan and --fault-seed are mutually "
                         "exclusive (one names the schedule, the other "
                         "generates it)")
    if args.fault_plan or args.fault_seed is not None:
        from repro.serverless import faults as F

        if args.fault_plan:
            faults_obj = F.FaultPlan.load(args.fault_plan)
        else:
            faults_obj = F.FaultPlan.generate(
                args.fault_seed, steps=args.steps,
                S=sum(rp.config.x) + 1, d=rp.config.d)
        print(f"fault plan: {faults_obj.counts() or 'empty'} "
              f"(seed={faults_obj.seed})")

    try:
        ec = ExecutionConfig(
            backend=args.backend, steps=args.steps, trace=bool(args.trace),
            payload_true=bool(args.payload_true),
            throttle=bool(args.throttle), bandwidth=args.bandwidth,
            faults=faults_obj, retries=args.retries,
            checkpoint_every=args.checkpoint_every)
        with _operator_errors():    # unknown backend name lists the registry
            ec.resolve_backend()    # all execution validation lives here
    except ValueError as e:
        raise SystemExit(f"error: {e}") from None
    res = run_plan(rp.profile, rp.platform, rp.config,
                   rp.total_micro_batches, ec,
                   pipelined_sync=rp.pipelined_sync,
                   contention=args.contention, execution=ex)
    for k, m in enumerate(res.metrics):
        print(f"step {k}: loss={m['loss']:.4f} ce={m['ce']:.4f} "
              f"aux={m['aux']:.4f}")
    bd = res.breakdown
    clock = "host wall-clock" if res.wall_clock else "virtual"
    print(f"engine[{res.backend}]: t_iter={res.t_iter:.3f}s ({clock}) "
          f"cost=${res.cost:.6f}/iter mem={res.total_mem_gb:.1f}GB "
          f"(compute={bd['compute']:.3f}s pipe_comm={bd['pipeline_comm']:.3f}s "
          f"sync={bd['sync']:.3f}s)")
    ss = res.store_stats
    print(f"store: {ss.puts} puts / {ss.gets} gets / {ss.deletes} deletes, "
          f"{ss.bytes_in / MB:.0f}MB in / {ss.bytes_out / MB:.0f}MB out, "
          f"peak {ss.peak_bytes / MB:.0f}MB (drained, bytes conserved)")
    if ss.class_bytes_in:
        per_cls = " ".join(f"{c}={ss.class_bytes_in[c] / MB:.0f}MB"
                           for c in sorted(ss.class_bytes_in))
        print(f"store uploads by key class: {per_cls}")
    if res.fault_report is not None:
        print(f"fault tolerance: {res.fault_report.describe()}")

    if args.trace:
        # attach the simulator's predicted timeline so `repro inspect` can
        # run the gap attribution straight off the file
        sim_t = simulate_funcpipe(rp.profile, rp.platform, rp.config,
                                  rp.total_micro_batches,
                                  pipelined_sync=rp.pipelined_sync,
                                  contention=args.contention, trace=True)
        res.trace.predicted = sim_t.trace.spans
        # embed the plan document so `repro calibrate` (and inspect) can
        # re-plan straight from the file, no plan JSON needed
        res.trace.meta["plan"] = plan._as_dict()
        res.trace.save(args.trace)
        print(f"wrote trace {args.trace} ({len(res.trace.spans)} spans + "
              f"{len(sim_t.trace.spans)} predicted)")

    if res.wall_clock:
        # host seconds are not the cost model's seconds: the analytic
        # comparison only makes sense on virtual-clock backends
        print(f"vs simulator: n/a (backend {res.backend!r} measures host "
              "wall-clock; numerics validated instead — see "
              "tests/test_backends.py)")
        return 0
    sim = simulate_funcpipe(rp.profile, rp.platform, rp.config,
                            rp.total_micro_batches,
                            pipelined_sync=rp.pipelined_sync,
                            contention=args.contention)
    ev = evaluate(rp.profile, rp.platform, rp.config, rp.total_micro_batches,
                  pipelined_sync=rp.pipelined_sync)
    for name, t in [("simulator", sim.t_iter), ("perfmodel", ev.t_iter)]:
        print(f"vs {name}: t_iter={t:.3f}s "
              f"(rel err {abs(res.t_iter - t) / t:.1%})")
    return 0


# ------------------------------------------------------------------ sweep
def _cmd_sweep(args) -> int:
    """Paper workflow ①-⑤ (old examples/plan_serverless.py output format)."""
    import os

    from repro.api import InfeasiblePlanError
    from repro.core import planner
    from repro.core.partition import stages_of
    from repro.serverless.frameworks import ALPHA_PAIRS
    from repro.serverless.simulator import simulate_funcpipe

    if not args.model:
        raise SystemExit("--model is required")
    platform = get_platform(args.platform)
    with _operator_errors():
        s = _make_session(args)
        prof = s.profile().model_profile
    M = s.total_micro_batches
    if args.merge_to is not None:
        merge_to = args.merge_to
    elif args.fast:
        merge_to = _FAST["merge_to"]
    elif args.engine == "dp":
        merge_to = None                # exact DP: sweep at full layer depth
    else:
        merge_to = 12
    print(f"model={args.model} params={prof.param_bytes/2**20:.0f}MB "
          f"layers={prof.L} global_batch={s.global_batch} micro_batches={M} "
          f"merge_to={'full' if merge_to is None else merge_to} "
          f"engine={args.engine}")
    plan_kw = dict(merge_to=merge_to, engine=args.engine)
    if args.fast:
        plan_kw["d_options"] = _FAST["d_options"]
    results, saved = [], []
    for alpha in ALPHA_PAIRS:
        try:
            s.plan(alpha=alpha, **plan_kw)
        except InfeasiblePlanError:
            print(f"alpha={alpha}: infeasible")
            continue
        r, plan = s.plan_result, s.deployment_plan
        results.append(r)
        saved.append(plan)
        sim = simulate_funcpipe(r.profile, platform, r.config, M,
                                pipelined_sync=s.pipelined_sync,
                                contention=args.contention)
        st = stages_of(r.config.x)
        mems = [platform.memory_options[r.config.z[lo]] // MB for lo, _ in st]
        print(f"alpha2={alpha[1]:.2e}: stages={len(st)} d={r.config.d} "
              f"mem={mems}MB t_iter={sim.t_iter:.2f}s cost=${sim.cost:.5f} "
              f"(model predicts {r.evaluation.t_iter:.2f}s; "
              f"solve {r.solve_seconds:.1f}s)")
    if not results:
        print("no feasible FuncPipe config for this model/batch on this "
              "platform (try a smaller batch or the alibaba platform)")
        return 1
    rec = planner.recommend(results)
    print(f"\nRECOMMENDED: d={rec.config.d}, {sum(rec.config.x)+1} stages, "
          f"t={rec.evaluation.t_iter:.2f}s, ${rec.evaluation.c_iter:.5f}/iter")
    if s.plan_cache is not None and (s.plan_cache.hits or s.plan_cache.misses):
        print(f"plan cache: {s.plan_cache.hits} hits / "
              f"{s.plan_cache.misses} misses / "
              f"{s.plan_cache.evictions} evicted ({s.plan_cache.root})")
    if args.save_dir:
        os.makedirs(args.save_dir, exist_ok=True)
        for plan in saved:
            path = os.path.join(args.save_dir,
                                f"{plan.model}-{plan.content_hash}.json")
            plan.save(path)
        print(f"saved {len(saved)} plans to {args.save_dir}/")

    print("\nbaseline algorithms (same objective, alpha2=2^19e-9):")
    base_merge = 8 if merge_to is None else min(8, merge_to)
    for name in ("tpdmp", "bayes"):
        try:
            s.plan(alpha=(1.0, 2**19 * 1e-9), solver=name,
                   merge_to=base_merge,
                   **({"d_options": _FAST["d_options"]} if args.fast else {}))
        except InfeasiblePlanError:
            continue
        r = s.plan_result
        print(f"  {name}: t={r.evaluation.t_iter:.2f}s "
              f"${r.evaluation.c_iter:.5f} obj={r.objective:.5f}")
    return 0


# ------------------------------------------------------------------ serve
def _cmd_serve(args) -> int:
    """Plan (or replay) a ``workload="serve"`` deployment; optionally run the
    pipelined decode through a backend and/or the autoscaling simulator."""
    from repro.api import DeploymentPlan
    from repro.serving import autoscale_plan, plan_serving, run_serve_plan

    if args.plan_file:
        if args.model or args.slo is not None:
            raise SystemExit(
                "--model/--slo conflict with replaying a saved serve plan; "
                "drop the flags (or drop the file to plan fresh)")
        try:
            plan = DeploymentPlan.load(args.plan_file)
        except FileNotFoundError:
            raise SystemExit(f"error: no such plan file: {args.plan_file}")
    else:
        if not args.model:
            raise SystemExit("pass a saved serve plan.json or --model")
        if args.slo is None:
            raise SystemExit("--slo SECONDS is required when planning "
                             "(the per-request latency constraint)")
        with _operator_errors():    # unknown model/platform lookups only
            plan = plan_serving(
                args.model, args.platform, slo=args.slo,
                batch=args.serve_batch, prefill_tokens=args.prefill_tokens,
                new_tokens=args.new_tokens, max_stages=args.max_stages)
    print(plan.describe())
    sv = plan.serving or {}
    if "n_feasible" in sv:
        print(f"planner: {sv['n_feasible']} feasible candidates over "
              f"{sv['n_candidates']} partitions; "
              f"t_prefill={sv['t_prefill']:.3f}s "
              f"t_token={sv['t_token'] * 1e3:.1f}ms "
              f"kv={sum(sv['kv_bytes']) / MB:.1f}MB/stage-set")
    if args.out:
        plan.save(args.out)
        print(f"wrote {args.out} (content hash {plan.content_hash})")

    if args.execute:
        res = run_serve_plan(plan, backend=args.execute, seed=args.seed,
                             trace=bool(args.trace))
        clock = "host wall-clock" if res.backend == "process" else "virtual"
        print(f"serve[{res.backend}]: {res.tokens.shape[0]} request(s) x "
              f"{res.tokens.shape[1]} tokens  t_request={res.t_request:.3f}s "
              f"({clock})  cost=${res.cost_per_1k:.4f}/1k-req")
        print(f"tokens: {res.tokens.tolist()}")
        ss = res.store_stats
        cls = ss.class_bytes_in or {}
        per_cls = " ".join(f"{c}={cls[c] / MB:.2f}MB" for c in sorted(cls))
        print(f"store: {ss.puts} puts / {ss.gets} gets (drained); "
              f"uploads by key class: {per_cls or 'none'}")
        if args.trace:
            res.trace.save(args.trace)
            print(f"wrote trace {args.trace} ({len(res.trace.spans)} spans)")

    if args.autoscale:
        try:
            replicas = tuple(int(x) for x in args.autoscale.split(","))
        except ValueError:
            raise SystemExit(
                f"--autoscale wants a comma list of replica counts, got "
                f"{args.autoscale!r}")
        rows = autoscale_plan(
            plan, rate=args.rate, horizon=args.horizon, replicas=replicas,
            arrival=args.arrival, trace_file=args.trace_file, seed=args.seed)
        print(f"\nautoscale ({args.arrival} arrivals, rate={args.rate}/s, "
              f"horizon={args.horizon}s, seed={args.seed}):")
        print("replicas  requests      p50      p95      p99  viol%  "
              "cold      $/1k   util")
        for r in rows:
            print(f"{r.replicas:>8d}  {r.requests:>8d} {r.p50:>8.3f} "
                  f"{r.p95:>8.3f} {r.p99:>8.3f} "
                  f"{r.slo_violation_frac:>6.1%} {r.cold_starts:>5d} "
                  f"{r.cost_per_1k:>9.4f} {r.utilization:>6.1%}")
    return 0


# ---------------------------------------------------------------- inspect
def _cmd_inspect(args) -> int:
    """Validate a saved trace and print pipeline health + gap attribution."""
    from repro.obs import (
        ELAPSED,
        Trace,
        TraceValidationError,
        gap_attribution,
        pipeline_health,
        validate_trace,
    )

    try:
        tr = Trace.load(args.trace_file)
    except FileNotFoundError:
        raise SystemExit(f"error: no such trace file: {args.trace_file}")
    except (ValueError, KeyError) as e:
        raise SystemExit(f"error: not a repro trace: {e}")
    try:
        validate_trace(tr)
    except TraceValidationError as e:
        raise SystemExit(f"trace INVALID: {e}")
    meta = tr.meta
    print(f"trace OK: {len(tr.spans)} spans  model={meta.get('model', '?')} "
          f"backend={meta.get('backend', '?')} "
          f"clock={meta.get('clock', '?')} "
          f"S={meta.get('S', '?')} d={meta.get('d', '?')} "
          f"mu={meta.get('mu', '?')} steps={meta.get('steps', '?')} "
          f"t_total={float(meta.get('t_total', 0.0)):.3f}s")

    h = pipeline_health(tr)
    have_bw = any("up_bw_util" in row for row in h["stages"])
    hdr = "stage  compute  bubble    up-busy  dn-busy"
    if have_bw:
        hdr += "  up-util  dn-util"
    print(hdr)
    for row in h["stages"]:
        line = (f"{row['stage']:>5d}  {row['compute_frac']:>7.1%} "
                f"{row['bubble_frac']:>7.1%}  {row['up_frac']:>7.1%} "
                f"{row['dn_frac']:>8.1%}")
        if "up_bw_util" in row:
            line += f"  {row['up_bw_util']:>7.1%}  {row['dn_bw_util']:>7.1%}"
        print(line)
    print(f"straggler ratio: {h['straggler_ratio']:.3f}")
    rcv = h.get("recovery")
    if rcv is not None:
        print(f"recovery: {rcv['retry_count']} retries "
              f"({rcv['retry_s']:.3f}s backoff), "
              f"{rcv['restart_count']} restore reads "
              f"({rcv['restart_s']:.3f}s, "
              f"{rcv['restart_bytes'] / MB:.0f}MB re-fetched)")
    for phase in ("fwd", "bwd", "sync"):
        pb = h["phase_bytes"].get(phase)
        if pb:
            print(f"bytes[{phase}]: {pb['up'] / MB:.0f}MB up / "
                  f"{pb['dn'] / MB:.0f}MB down")
    rec = h.get("reconciliation")
    if rec is not None:
        verdict = "OK" if rec["ok"] else "MISMATCH"
        print(f"byte reconciliation vs StoreStats: {verdict} "
              f"(spans {rec['span_bytes_up'] / MB:.0f}MB up vs store "
              f"{rec['store_bytes_in'] / MB:.0f}MB in; "
              f"spans {rec['span_bytes_dn'] / MB:.0f}MB down vs store "
              f"{rec['store_bytes_out'] / MB:.0f}MB out)")
    store = meta.get("store") or {}
    cls_in = store.get("class_bytes_in") or {}
    if cls_in:
        per_cls = " ".join(f"{c}={cls_in[c] / MB:.0f}MB"
                           for c in sorted(cls_in))
        print(f"store uploads by key class: {per_cls}")

    if not tr.predicted:
        print("no predicted timeline in this trace — produce one with "
              "`repro emulate --trace` (gap attribution skipped)")
        return 0
    if meta.get("clock") == "wall":
        print("note: observed spans are host wall-clock, predicted spans "
              "are modeled seconds — gaps below compare across clocks")
    rows = gap_attribution(tr)
    print(f"\ngap attribution (top {args.top} of {len(rows)} cells, "
          "per replica-step seconds):")
    print("stage  phase  op          observed  predicted       gap")
    for r in rows[:args.top]:
        op = "elapsed" if r.op == ELAPSED else r.op
        print(f"{r.stage:>5d}  {r.phase:<5s}  {op:<10s} "
              f"{r.observed_s:>9.4f}  {r.predicted_s:>9.4f} "
              f"{r.gap_s:>+9.4f}")
    return 0


# -------------------------------------------------------------- calibrate
def _cmd_calibrate(args) -> int:
    from repro.api import DeploymentPlan
    from repro.obs import Trace, calibrate_trace, replan

    try:
        trace = Trace.load(args.trace_file)
    except FileNotFoundError:
        raise SystemExit(f"error: no such trace file: {args.trace_file}")
    plan = None
    if args.plan:
        try:
            plan = DeploymentPlan.load(args.plan)
        except FileNotFoundError:
            raise SystemExit(f"error: no such plan file: {args.plan}")
    try:
        cal, plan = calibrate_trace(trace, plan=plan, warmup=args.warmup)
    except ValueError as e:
        raise SystemExit(f"error: {e}") from None
    print(cal.describe())
    if args.profile_out:
        cal.profile.save(args.profile_out)
        print(f"wrote measured profile {args.profile_out}")
    if args.no_replan:
        return 0
    alpha = (1.0, args.alpha2) if args.alpha2 is not None else None
    rep = replan(cal, plan, alpha=alpha, engine=args.engine)
    print(rep.describe())
    if args.out:
        rep.new_plan.save(args.out)
        hint = args.profile_out or "PROFILE.json (save one with --profile-out)"
        print(f"wrote re-planned {args.out} (content hash "
              f"{rep.new_plan.content_hash}); replay it with "
              f"`repro simulate/emulate {args.out} --profile {hint}`")
    return 0


# ------------------------------------------------------------------ bench
def _cmd_bench(args) -> int:
    try:
        from benchmarks import run as bench_run
    except ImportError:
        raise SystemExit(
            "the benchmarks/ package is not importable — run from the repo "
            "root: PYTHONPATH=src python -m repro bench")
    if args.list:
        for n in bench_run.BENCH_NAMES:
            print(n)
        return 0
    argv = (["--fast"] if args.fast else []) + (args.names or [])
    bench_run.main(argv)
    return 0


# ------------------------------------------------------------------- main
def main(argv: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    # train/dryrun forward their whole tail to the launch drivers' own
    # parsers (argparse REMAINDER won't capture a leading option like
    # --help, so dispatch before parsing)
    if argv and argv[0] in ("train", "dryrun"):
        if argv[0] == "train":
            from repro.launch import train

            return train.main(argv[1:]) or 0
        from repro.launch import dryrun

        return dryrun.main(argv[1:]) or 0

    ap = argparse.ArgumentParser(
        prog="repro", description="FuncPipe repro: plan, replay and train "
        "serverless deployments (see repro.api for the library front door)")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("plan", help="co-optimize and save a DeploymentPlan")
    _add_model_args(p)
    _add_solver_args(p)
    _add_cache_args(p)
    p.add_argument("-o", "--out", default=None, help="write plan JSON here")
    p.set_defaults(func=_cmd_plan)

    p = sub.add_parser("simulate",
                       help="replay a plan through the analytic simulator")
    p.add_argument("plan_file", nargs="?", default=None,
                   help="saved DeploymentPlan JSON (or pass --model to plan)")
    _add_model_args(p)
    _add_solver_args(p)
    _add_cache_args(p)
    p.add_argument("--trace", default=None, metavar="OUT.json",
                   help="write the simulator's predicted span timeline as a "
                        "Chrome/Perfetto trace (see `repro inspect`)")
    p.add_argument("--profile", default=None, metavar="PROFILE.json",
                   help="resolve the plan against this saved ModelProfile "
                        "(e.g. a measured profile from `repro calibrate "
                        "--profile-out`) instead of rebuilding the analytic "
                        "tables — required to replay measured plans")
    p.set_defaults(func=_cmd_simulate)

    p = sub.add_parser("emulate",
                       help="execute a plan through the runtime engine")
    p.add_argument("plan_file", nargs="?", default=None,
                   help="saved DeploymentPlan JSON (or pass --model to plan)")
    _add_model_args(p)
    _add_solver_args(p)
    _add_cache_args(p)
    # validated against the live backend registry at run time (not a
    # hardcoded choices=) so register_backend'ed third-party names work here
    p.add_argument("--backend", default="emulated", metavar="NAME",
                   help="execution backend: emulated (virtual-clock cost "
                        "model, default), local (real concurrent worker "
                        "threads, host wall-clock), process (real OS worker "
                        "processes over a file store), aws (real S3 object "
                        "store, needs boto3), oss (stub), or any registered "
                        "backend name; the same plan JSON drives any of them")
    p.add_argument("--steps", type=int, default=2)
    p.add_argument("-o", "--out", default=None,
                   help="also save the executed plan JSON here")
    p.add_argument("--numerics", action="store_true",
                   help="run real JAX through the store (reduced arch)")
    p.add_argument("--stages", type=int, default=2, help="numeric mode stages")
    p.add_argument("--dp", type=int, default=2, help="numeric mode DP degree")
    p.add_argument("--n-layers", type=int, default=4,
                   help="numeric mode depth")
    p.add_argument("--trace", default=None, metavar="OUT.json",
                   help="record per-worker spans and write a Chrome/Perfetto "
                        "trace with the simulator's predicted timeline "
                        "attached (see `repro inspect`)")
    p.add_argument("--payload-true", action="store_true",
                   help="charge store transfers their real payload sizes "
                        "(np nbytes) instead of the modeled ones; process "
                        "backend only")
    p.add_argument("--throttle", action="store_true",
                   help="sleep each store transfer for nbytes/bandwidth + "
                        "latency per the platform profile, giving traces a "
                        "calibrated wall-clock time axis; process backend "
                        "only")
    p.add_argument("--bandwidth", type=float, default=None, metavar="BYTES_S",
                   help="override the per-worker throttle bandwidth in "
                        "bytes/s (default: the plan's modeled per-worker "
                        "store bandwidth); implies --throttle")
    p.add_argument("--fault-plan", default=None, metavar="PLAN.json",
                   help="chaos-test the run: inject faults from a saved "
                        "FaultPlan JSON; recovery must reproduce the "
                        "fault-free numbers bit-for-bit")
    p.add_argument("--fault-seed", type=int, default=None, metavar="N",
                   help="generate a seeded FaultPlan sized to this run "
                        "instead of loading --fault-plan")
    p.add_argument("--retries", type=int, default=None, metavar="N",
                   help="enable fault tolerance with N max attempts per "
                        "store op (default 5 when faults are injected)")
    p.add_argument("--checkpoint-every", type=int, default=None, metavar="N",
                   help="checkpoint stage state into the object store every "
                        "N steps (default 1 when fault tolerance is on)")
    p.add_argument("--profile", default=None, metavar="PROFILE.json",
                   help="resolve the plan against this saved ModelProfile "
                        "(e.g. a measured profile from `repro calibrate "
                        "--profile-out`) instead of rebuilding the analytic "
                        "tables — required to replay measured plans")
    p.set_defaults(func=_cmd_emulate)

    p = sub.add_parser("inspect",
                       help="validate a saved trace; print pipeline health "
                            "metrics + predicted-vs-observed gap attribution")
    p.add_argument("trace_file", help="trace JSON from emulate/simulate --trace")
    p.add_argument("--top", type=int, default=10,
                   help="attribution rows to print (default 10)")
    p.set_defaults(func=_cmd_inspect)

    p = sub.add_parser("calibrate",
                       help="fold a traced run back into a measured "
                            "profile, re-plan on it and report the delta")
    p.add_argument("trace_file",
                   help="trace JSON from `repro emulate --trace` (the plan "
                        "document is embedded in the trace metadata)")
    p.add_argument("--plan", default=None, metavar="PLAN.json",
                   help="plan the trace executed (only needed for traces "
                        "written before plans were embedded in trace "
                        "metadata)")
    p.add_argument("--warmup", type=int, default=None, metavar="N",
                   help="drop the first N steps from the averages (default: "
                        "1 on multi-step wall-clock traces — JIT compile "
                        "skew — else 0)")
    p.add_argument("--alpha2", type=float, default=None,
                   help="re-plan objective time weight (default: the plan's "
                        "recorded alpha; manual/numeric plans record "
                        "cost-only)")
    p.add_argument("--engine", default="dp",
                   choices=("dp", "batch", "scalar"),
                   help="re-plan engine (default dp: exact at the measured "
                        "profile's full depth)")
    p.add_argument("--no-replan", action="store_true",
                   help="only calibrate and report; skip the re-plan")
    p.add_argument("--profile-out", default=None, metavar="PROFILE.json",
                   help="save the measured ModelProfile here (replay plans "
                        "with `repro simulate/emulate --profile`)")
    p.add_argument("-o", "--out", default=None, metavar="PLAN.json",
                   help="save the re-planned DeploymentPlan here")
    p.set_defaults(func=_cmd_calibrate)

    p = sub.add_parser("sweep", help="Pareto frontier + recommendation + "
                                     "baseline algorithms (paper §5)")
    _add_model_args(p)
    _add_cache_args(p)
    p.add_argument("--merge-to", type=int, default=None)
    p.add_argument("--engine", default="batch",
                   choices=("batch", "scalar", "dp"),
                   help="planner engine for the sweep; dp sweeps exactly at "
                        "full layer depth unless --merge-to bounds it")
    p.add_argument("--fast", action="store_true")
    p.add_argument("--save-dir", default=None,
                   help="save every swept plan JSON into this directory")
    p.set_defaults(func=_cmd_sweep)

    p = sub.add_parser("serve", help="SLO-aware serving: plan, execute "
                                     "pipelined decode, autoscale")
    p.add_argument("plan_file", nargs="?", default=None,
                   help="saved workload='serve' DeploymentPlan JSON "
                        "(or pass --model + --slo to plan fresh)")
    p.add_argument("--model", default=None,
                   help="assigned arch id at reduced depth "
                        "(e.g. phi3-mini-3.8b@reduced)")
    p.add_argument("--platform", default="aws", choices=_PLATFORM_CHOICES)
    p.add_argument("--slo", type=float, default=None, metavar="SECONDS",
                   help="per-request latency SLO the plan must meet "
                        "(infeasible SLOs exit with InfeasibleSLOError)")
    p.add_argument("--serve-batch", type=int, default=1,
                   help="requests decoded together per pipeline (default 1)")
    p.add_argument("--prefill-tokens", type=int, default=64,
                   help="prompt length the SLO is planned at (default 64)")
    p.add_argument("--new-tokens", type=int, default=8,
                   help="tokens decoded per request (default 8)")
    p.add_argument("--max-stages", type=int, default=None)
    p.add_argument("-o", "--out", default=None, help="write plan JSON here")
    p.add_argument("--execute", default=None, metavar="BACKEND",
                   help="run the pipelined prefill+decode through an "
                        "execution backend (emulated | process) and check "
                        "the store drains")
    p.add_argument("--seed", type=int, default=0,
                   help="prompt/arrival seed (default 0; deterministic)")
    p.add_argument("--trace", default=None, metavar="OUT.json",
                   help="with --execute: record prefill/decode spans and "
                        "write a Chrome/Perfetto trace (see `repro inspect`)")
    p.add_argument("--autoscale", default=None, metavar="N,N,...",
                   help="simulate these replica counts under a seeded "
                        "arrival trace (p50/p95/p99, SLO violations, cold "
                        "starts, cost)")
    p.add_argument("--rate", type=float, default=1.0,
                   help="autoscale arrival rate, req/s (default 1.0)")
    p.add_argument("--horizon", type=float, default=120.0,
                   help="autoscale trace horizon, seconds (default 120)")
    p.add_argument("--arrival", default="poisson",
                   choices=("poisson", "bursty", "trace"))
    p.add_argument("--trace-file", default=None, metavar="GAPS.txt",
                   help="inter-arrival gaps file for --arrival trace")
    p.set_defaults(func=_cmd_serve)

    p = sub.add_parser("bench", help="run benchmark modules (benchmarks/run.py)")
    p.add_argument("names", nargs="*", help="bench names (default: all)")
    p.add_argument("--fast", action="store_true")
    p.add_argument("--list", action="store_true", help="list bench names")
    p.set_defaults(func=_cmd_bench)

    # dispatched above before parse_args; registered so --help lists them
    p = sub.add_parser("train", help="mesh training driver (repro.launch.train)",
                       add_help=False)
    p = sub.add_parser("dryrun", help="mesh compile sweep (repro.launch.dryrun)",
                       add_help=False)

    args = ap.parse_args(argv)
    from repro.api import InfeasiblePlanError, PlanCompatibilityError
    from repro.serverless.backends import BackendUnavailableError

    try:
        return args.func(args) or 0
    except (PlanCompatibilityError, InfeasiblePlanError,
            BackendUnavailableError) as e:
        # operator-facing outcomes (incl. cloud-backend stubs), not bugs:
        # exit cleanly with the message; a genuine NotImplementedError
        # elsewhere still crashes loudly with its traceback
        raise SystemExit(f"error: {e}") from None


if __name__ == "__main__":
    sys.exit(main())
