"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh), TPU v5e constants:
    compute    = HLO_FLOPs / (chips * 197e12 bf16 FLOP/s)
    memory     = HLO_bytes / (chips * 819e9 B/s HBM)
    collective = per-chip link bytes / 50e9 B/s ICI

cost_analysis() supplies FLOPs/bytes; collective bytes are NOT in
cost_analysis, so we parse the compiled HLO text and sum operand/result
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, converting each to ring-schedule bytes-on-link using its
replica_groups size.
"""
from __future__ import annotations

import dataclasses
import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link direction

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^=]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.MULTILINE,
)
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    group_size: int
    trip_mult: float = 1.0  # while-loop trip multiplier (scan bodies)

    @property
    def link_bytes(self) -> float:
        """Bytes through one link direction per chip, ring schedules."""
        g = max(1, self.group_size)
        if self.kind == "collective-permute":
            return float(self.result_bytes)  # point-to-point, no groups
        if g == 1:
            return 0.0
        if self.kind == "all-gather":
            # result = gathered size; each chip receives (g-1)/g of it
            return self.result_bytes * (g - 1) / g
        if self.kind == "reduce-scatter":
            # result = shard; input g*shard moves (g-1) shard-hops
            return self.result_bytes * (g - 1)
        if self.kind == "all-reduce":
            return 2 * self.result_bytes * (g - 1) / g
        if self.kind == "all-to-all":
            return self.result_bytes * (g - 1) / g
        if self.kind == "collective-permute":
            return float(self.result_bytes)
        return float(self.result_bytes)

    @property
    def weighted_link_bytes(self) -> float:
        return self.link_bytes * self.trip_mult


_COMPUTATION_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*?\)\s*->", re.MULTILINE)
_WHILE_RE = re.compile(
    r"while\([^\n]*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)", re.MULTILINE
)
_TRIP_RE = re.compile(r"constant\((\d+)\)")
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")


def _computation_spans(hlo_text: str) -> Dict[str, tuple]:
    """name -> (start, end) character spans of each HLO computation."""
    marks = [(m.start(), m.group(1)) for m in _COMPUTATION_RE.finditer(hlo_text)]
    spans = {}
    for i, (pos, name) in enumerate(marks):
        end = marks[i + 1][0] if i + 1 < len(marks) else len(hlo_text)
        spans[name] = (pos, end)
    return spans


def computation_multipliers(hlo_text: str) -> Dict[str, float]:
    """Execution-count multiplier per computation: while bodies run
    trip-count times (jax scans lower to while loops whose condition compares
    the induction variable against a constant)."""
    spans = _computation_spans(hlo_text)

    def owner(pos: int) -> Optional[str]:
        for name, (s, e) in spans.items():
            if s <= pos < e:
                return name
        return None

    # edges: computation -> (child computation, multiplier)
    children: Dict[str, List[tuple]] = {}
    for m in _WHILE_RE.finditer(hlo_text):
        cond, body = m.group(1), m.group(2)
        trips = 1
        if cond in spans:
            s, e = spans[cond]
            consts = [int(c) for c in _TRIP_RE.findall(hlo_text[s:e])]
            if consts:
                trips = max(consts)
        par = owner(m.start())
        if par:
            children.setdefault(par, []).append((body, float(trips)))
            children[par].append((cond, float(trips)))
    for m in _CALL_RE.finditer(hlo_text):
        par = owner(m.start())
        if par:
            children.setdefault(par, []).append((m.group(1), 1.0))

    mult: Dict[str, float] = {}
    roots = [n for n in spans if n.startswith("main") or n == "entry"]
    if not roots:
        # entry computation is the one never referenced as a child
        referenced = {c for kids in children.values() for c, _ in kids}
        roots = [n for n in spans if n not in referenced]

    def visit(name: str, m: float, depth=0):
        if depth > 64:
            return
        mult[name] = max(mult.get(name, 0.0), m)
        for child, k in children.get(name, []):
            visit(child, m * k, depth + 1)

    for r in roots:
        visit(r, 1.0)
    return mult


def parse_collectives(hlo_text: str, *, trip_weighted: bool = True) -> List[CollectiveOp]:
    mult = computation_multipliers(hlo_text) if trip_weighted else {}
    spans = _computation_spans(hlo_text)

    def owner_mult(pos: int) -> float:
        best = 1.0
        for name, (s, e) in spans.items():
            if s <= pos < e:
                return mult.get(name, 1.0)
        return best

    out = []
    for m in _COLL_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        line_end = hlo_text.find("\n", m.end())
        line = hlo_text[m.start(): line_end if line_end > 0 else None]
        if "-done(" in line:
            continue
        gs = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            gs = len([t for t in gm.group(1).split(",") if t.strip()])
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                gs = int(gi.group(2))
        w = owner_mult(m.start()) if trip_weighted else 1.0
        op = CollectiveOp(kind=kind, result_bytes=_shape_bytes(type_str), group_size=gs)
        op.trip_mult = w
        out.append(op)
    return out


@dataclass
class Roofline:
    flops: float                  # per-chip HLO flops
    hbm_bytes: float              # per-chip bytes accessed
    link_bytes: float             # per-chip bytes through a link direction
    collective_counts: Dict[str, int] = field(default_factory=dict)
    collective_bytes_by_kind: Dict[str, float] = field(default_factory=dict)
    bubble_factor: float = 1.0    # GPipe fill/drain: (mu + S - 1) / mu

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.link_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_step_est(self) -> float:
        """Wall-time estimate: busy compute stretched by the pipeline bubble,
        plus non-overlapped collectives (memory term assumed overlapped with
        compute on TPU)."""
        return max(self.t_compute, self.t_memory) * self.bubble_factor + self.t_collective

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "link_bytes": self.link_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bubble_factor": self.bubble_factor,
            "t_step_est_s": self.t_step_est,
            "bottleneck": self.bottleneck,
            "collective_counts": self.collective_counts,
            "collective_bytes_by_kind": self.collective_bytes_by_kind,
        }


def analyze(compiled, *, hlo_text: Optional[str] = None) -> Roofline:
    """HLO-derived roofline.  NOTE: XLA's aggregate cost_analysis counts
    while-loop (scan) bodies ONCE; the collective term here is corrected with
    parsed trip counts, and the raw flops/bytes are kept as a lower bound —
    the analytic model (analytic_roofline) is the primary compute/memory
    term and is cross-checked against these numbers in EXPERIMENTS.md."""
    cost = compiled.cost_analysis() or {}
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    ops = parse_collectives(text)
    link = sum(op.weighted_link_bytes for op in ops)
    counts: Dict[str, int] = {}
    by_kind: Dict[str, float] = {}
    for op in ops:
        counts[op.kind] = counts.get(op.kind, 0) + 1
        by_kind[op.kind] = by_kind.get(op.kind, 0.0) + op.weighted_link_bytes
    return Roofline(flops=flops, hbm_bytes=hbm, link_bytes=link,
                    collective_counts=counts, collective_bytes_by_kind=by_kind)


# --------------------------------------------------------------- analytic model
def analytic_roofline(cfg, shape, plan, *, bidirectional: bool = True) -> Roofline:
    """First-principles per-chip roofline for one (arch x shape x plan).

    FLOPs: 2*N_active per token forward (+2x backward, +1x remat recompute),
    plus attention's O(S*ctx) term per layer kind.  HBM bytes: weight reads
    per micro-batch pass, activation traffic, KV-cache reads (decode), and
    optimizer state read/write (train).  Collective bytes: pipeline permutes,
    grad reduce-scatter + param all-gather over data, EP all-to-alls, TP
    psums — matching the schedule core.pipeline emits.
    """
    from repro.configs.base import ATTN, MAMBA, MLSTM, SLSTM, MOE_FF, GLOBAL_WINDOW

    chips = plan.pods * plan.data * plan.model_axis
    P_BYTES = 2 if cfg.param_dtype == "bfloat16" else 4
    N_active = cfg.active_param_count()
    N_total = cfg.param_count()
    d = cfg.d_model
    S = shape.seq_len
    B = shape.global_batch
    train = shape.kind == "train"
    decode = shape.kind == "decode"

    # ---------- matmul flops per token (2*N_active) + attention extra
    def attn_extra_flops_per_layer(tokens_ctx):
        # QK^T + PV: 4 * Hq * hd * ctx per token
        return 4.0 * cfg.n_heads * cfg.hd * tokens_ctx

    extra = 0.0
    for i in range(cfg.n_layers):
        spec = cfg.layer_spec(i)
        if spec.mixer == ATTN:
            if decode:
                ctx = min(S, spec.window) if spec.window else S
            else:
                ctx = min(S, spec.window) if spec.window else S / 2  # causal avg
            extra += attn_extra_flops_per_layer(ctx)
        elif spec.mixer == MLSTM:
            extra += attn_extra_flops_per_layer(256)  # chunk-local quadratic
        elif spec.mixer == MAMBA:
            extra += 10.0 * cfg.mamba.d_inner(d) * cfg.mamba.d_state
    n_tokens = B * S if not decode else B
    fwd = (2.0 * N_active + extra) * n_tokens
    if train:
        remat = 1.0 if plan.remat in ("tick", "layer") else 0.0
        flops_global = fwd * (3.0 + remat)
    else:
        flops_global = fwd
    flops_chip = flops_global / chips

    # ---------- HBM bytes per chip
    # params per chip: dense split over (stages x tensor); experts also over EP
    moe_params = 0.0
    if cfg.moe is not None:
        n_moe = sum(1 for i in range(cfg.n_layers) if cfg.layer_spec(i).ff == MOE_FF)
        moe_params = n_moe * cfg.moe.n_experts * 3 * d * cfg.moe.d_ff_expert
    dense_params = N_total - moe_params
    params_chip = (dense_params / (plan.stages * plan.tensor)
                   + moe_params / (plan.stages * plan.tensor * plan.ep)) * P_BYTES

    mb_local = (B // (plan.pods * plan.data)) if plan.seq_shards == 1 else B // plan.pods
    n_mb = plan.microbatches
    passes = (3.0 if train else 1.0)  # fwd+bwd(+update) vs fwd
    weight_traffic = params_chip * n_mb * passes
    act_traffic = 6.0 * mb_local * S * d * P_BYTES * (cfg.n_layers / max(1, plan.stages)) * passes / max(1, plan.tensor)
    kv_traffic = 0.0
    if decode:
        for i in range(cfg.n_layers):
            spec = cfg.layer_spec(i)
            if spec.mixer == ATTN:
                ctx = min(S, spec.window) if spec.window else S // plan.seq_shards
                kv_local = max(1, cfg.n_kv_heads // plan.tensor) if plan.tensor > 1 else cfg.n_kv_heads
                kv_traffic += (mb_local if plan.seq_shards == 1 else B // plan.pods) * 2 * kv_local * ctx * cfg.hd * P_BYTES
        kv_traffic /= max(1, plan.stages)
    opt_traffic = 0.0
    if train:
        opt_traffic = (params_chip / P_BYTES) * 4 * 3 * 2 / plan.data  # m,v,master rw fp32, ZeRO-sharded
    hbm_chip = weight_traffic + act_traffic + kv_traffic + opt_traffic

    # ---------- collective bytes per chip (link-direction bytes)
    coll = {}
    act_bytes_mb = (mb_local // max(1, n_mb)) * S * d * P_BYTES if not decode else (mb_local // max(1, n_mb)) * d * P_BYTES
    # pipeline permutes: each micro-batch crosses S_eff-1 boundaries (x3 for train fwd+bwd grads... bwd sends grads back)
    hops = (plan.stages - 1) * n_mb * (2.0 if train else 1.0)
    coll["collective-permute"] = hops * act_bytes_mb / max(1, plan.stages)  # per-chip share
    # bidirectional rings drive both link directions -> half the wall bytes
    ring = 0.5 if bidirectional else 1.0
    if train:
        g_bytes = params_chip * 2  # fp32 grads of bf16 params
        coll["reduce-scatter"] = ring * g_bytes * (plan.data - 1) / plan.data
        coll["all-gather"] = ring * params_chip * (plan.data - 1) / plan.data
        if plan.pods > 1:
            coll["all-reduce"] = ring * 2 * g_bytes * (plan.pods - 1) / plan.pods
    if cfg.moe is not None and plan.ep > 1:
        n_moe_stage = sum(1 for i in range(cfg.n_layers) if cfg.layer_spec(i).ff == MOE_FF) / max(1, plan.stages)
        a2a = 2 * n_moe_stage * n_mb * act_bytes_mb * (3.0 if train else 1.0)
        coll["all-to-all"] = a2a * (plan.data - 1) / plan.data
    if plan.tensor > 1:
        # row-parallel psums: ~2 per layer per micro-batch pass
        n_layer_stage = cfg.n_layers / max(1, plan.stages)
        coll["all-reduce"] = coll.get("all-reduce", 0.0) + (
            2 * n_layer_stage * n_mb * act_bytes_mb * passes
            * 2 * (plan.tensor - 1) / plan.tensor
        )
    if plan.seq_shards > 1:
        # flash-decode partial-softmax psum per global-attn layer
        n_glob = sum(1 for i in range(cfg.n_layers)
                     if cfg.layer_spec(i).mixer == ATTN and cfg.layer_spec(i).window == GLOBAL_WINDOW)
        part = B * cfg.n_heads * (cfg.hd + 2) * 4
        coll["all-reduce"] = coll.get("all-reduce", 0.0) + (
            2 * (n_glob / max(1, plan.stages)) * part * (plan.data - 1) / plan.data
        )
    link = float(sum(coll.values()))
    bubble = (plan.microbatches + plan.stages - 1) / plan.microbatches
    return Roofline(flops=flops_chip, hbm_bytes=hbm_chip, link_bytes=link,
                    collective_counts={k: 1 for k in coll},
                    collective_bytes_by_kind=coll,
                    bubble_factor=bubble)


def model_flops(cfg, shape, *, backward: bool = True) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE); decode: per token."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
