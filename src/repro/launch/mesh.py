"""Production mesh construction.

IMPORTANT: functions only — importing this module must not touch jax device
state (the dry-run sets XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_test_mesh(data: int, model: int, *, pods: int = 1):
    """Small-mesh variant for CI / fake-device tests."""
    if pods > 1:
        return jax.make_mesh(
            (pods, data, model), ("pod", "data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 3,
        )
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
