"""Training launcher: mesh + plan + pipelined train loop.

On real hardware this runs the production 16x16 (or 2x16x16) mesh; on CPU it
runs any mesh of fake host devices for bring-up, e.g.:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python -m repro.launch.train \\
        --arch phi3-mini-3.8b --reduced --data 2 --model 4 --steps 20

``--plan auto`` asks core.tpu_planner for the best (stages x tp x mu x remat)
factorization instead of the config default.  Checkpoints via the
Function-Manager policy every --ckpt-every steps.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import FunctionManager
from repro.configs import get_config, INPUT_SHAPES
from repro.configs.base import InputShape
from repro.core import sharding, tpu_planner
from repro.core.plan import make_plan
from repro.data.synthetic import make_batch
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.models import registry
from repro.optim import AdamW
from repro.train.train_step import init_opt_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--shape", default=None, help="named input shape or none")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--data", type=int, default=16)
    ap.add_argument("--model", type=int, default=16)
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--plan", default="config", choices=["config", "auto"])
    ap.add_argument("--stages", type=int, default=None)
    ap.add_argument("--tensor", type=int, default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--uni-ring", action="store_true",
                    help="LambdaML-analog unidirectional ring sync")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_train.msgpack")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.shape:
        shape = INPUT_SHAPES[args.shape]
    else:
        shape = InputShape("cli", args.seq, args.batch, "train")

    if args.pods > 1 and args.data == 16 and args.model == 16:
        mesh = make_production_mesh(multi_pod=True)
    elif args.data == 16 and args.model == 16 and args.pods == 1:
        mesh = make_production_mesh()
    else:
        mesh = make_test_mesh(args.data, args.model, pods=args.pods)

    overrides = {}
    if args.plan == "auto":
        best = tpu_planner.solve(cfg, shape, data=args.data, model=args.model,
                                 pods=args.pods)
        assert best, "no feasible plan"
        p = best[0].plan
        overrides = dict(stages=p.stages, tensor=p.tensor,
                         microbatches=p.microbatches, remat=p.remat)
        print(f"[plan auto] S={p.stages} tp={p.tensor} mu={p.microbatches} "
              f"remat={p.remat} (est {best[0].t_step_est*1e3:.1f} ms/step)")
    for k in ("stages", "tensor", "microbatches"):
        v = getattr(args, k)
        if v is not None:
            overrides[k] = v
    if overrides.get("stages") or overrides.get("tensor"):
        cfg = dataclasses.replace(
            cfg,
            stages=overrides.get("stages", cfg.stages),
            tensor=overrides.get("tensor", cfg.tensor),
        )
    plan = make_plan(cfg, shape, data=args.data, model=args.model,
                     pods=args.pods, **overrides)
    print(f"plan: stages={plan.stages} tensor={plan.tensor} "
          f"mu={plan.microbatches} ep={plan.ep} remat={plan.remat}")

    optimizer = AdamW(lr=args.lr)
    fm = FunctionManager(args.ckpt)
    with jax.set_mesh(mesh):
        base = registry.init_params(cfg, jax.random.PRNGKey(0))
        params = sharding.to_pipeline_layout(cfg, plan, base)
        opt_state = init_opt_state(cfg, plan, optimizer, params)
        step_fn = make_train_step(cfg, plan, mesh, optimizer, shape,
                                  bidirectional=not args.uni_ring)
        for i in range(args.steps):
            batch = make_batch(cfg, shape, step=i)
            t0 = time.time()
            params, opt_state, metrics = step_fn(params, opt_state, batch, i)
            print(f"step {i:4d} loss={float(metrics['loss']):.4f} "
                  f"ce={float(metrics['ce']):.4f} ({time.time()-t0:.2f}s)",
                  flush=True)
            if (i + 1) % args.ckpt_every == 0 or fm.should_checkpoint():
                fm.checkpoint_and_restart((params, opt_state), i + 1)
                print(f"  checkpointed -> {fm.path}")
    print("done.")


if __name__ == "__main__":
    main()
