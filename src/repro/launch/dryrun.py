import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count="
    + os.environ.get("DRYRUN_DEVICES", "512")
)

"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture x input-shape) combination on the
production mesh — 16x16 single-pod and 2x16x16 multi-pod — with
ShapeDtypeStruct stand-ins (no allocation), printing memory_analysis() and
cost_analysis() and writing a JSON record with the roofline terms
(launch.roofline) for EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-mini-3.8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.core import sharding
from repro.core.plan import make_plan
from repro.data.specs import input_specs
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.optim import AdamW
from repro.train import serve_step as srv
from repro.train import train_step as ts


def _with_shardings(tree_specs, pspec_tree, mesh):
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=NamedSharding(mesh, p)),
        tree_specs,
        pspec_tree,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)),
    )


def lower_combo(arch_id: str, shape_name: str, *, multi_pod: bool = False,
                plan_overrides: dict | None = None, mesh=None, verbose=True,
                bidirectional: bool = True):
    """Lower+compile one combination.  Returns (record dict, compiled)."""
    cfg = get_config(arch_id)
    shape = INPUT_SHAPES[shape_name]
    if not cfg.supports_shape(shape_name):
        return {"arch": arch_id, "shape": shape_name, "status": "skip",
                "reason": "encoder has no decode step" if cfg.is_encoder
                else "full-attention arch: 500k decode infeasible (DESIGN.md)"}, None
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    pods = mesh.shape.get("pod", 1)
    data = mesh.shape["data"]
    model = mesh.shape["model"]
    overrides = plan_overrides or {}
    plan = make_plan(cfg, shape, data=data, model=model, pods=pods, **overrides)
    optimizer = AdamW(lr=1e-4)

    t0 = time.time()
    abs_params = sharding.abstract_params(cfg, plan, mesh)
    b_specs = ts.batch_pspecs(cfg, shape, plan)
    abs_batch = _with_shardings(input_specs(cfg, shape), b_specs, mesh)

    with jax.set_mesh(mesh):
        if shape.kind == "train":
            opt_abs, opt_specs = ts.opt_state_specs(cfg, plan, optimizer)
            abs_opt = _with_shardings(opt_abs, opt_specs, mesh)
            step = ts.make_train_step(cfg, plan, mesh, optimizer, shape, donate=True,
                                      bidirectional=bidirectional)
            lowered = step.lower(abs_params, abs_opt, abs_batch, jnp.int32(0))
        elif shape.kind == "prefill":
            step = srv.make_prefill_step(cfg, plan, mesh, shape)
            lowered = step.lower(abs_params, abs_batch)
        else:  # decode
            cshapes, cspecs = srv.cache_specs(cfg, plan, shape)
            abs_caches = _with_shardings(cshapes, cspecs, mesh)
            step = srv.make_decode_step(cfg, plan, mesh, shape, donate=True)
            lowered = step.lower(abs_params, abs_caches, abs_batch["tokens"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    chips = pods * data * model
    ana = rl.analyze(compiled)
    analytic = rl.analytic_roofline(cfg, shape, plan, bidirectional=bidirectional)
    mf = rl.model_flops(cfg, shape)
    record = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": f"{pods}x{data}x{model}" if pods > 1 else f"{data}x{model}",
        "status": "ok",
        "plan": {"stages": plan.stages, "tensor": plan.tensor,
                 "microbatches": plan.microbatches, "ep": plan.ep,
                 "seq_shards": plan.seq_shards, "remat": plan.remat,
                 "bidirectional": bidirectional},
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "roofline_hlo": ana.as_dict(),
        "roofline": analytic.as_dict(),
        "model_flops_global": mf,
        "model_flops_per_chip": mf / chips,
        "useful_flops_ratio": (mf / chips) / analytic.flops if analytic.flops else None,
    }
    if verbose:
        print(f"[dryrun] {arch_id} x {shape_name} mesh={record['mesh']} "
              f"lower={t_lower:.0f}s compile={t_compile:.0f}s "
              f"peak={record['memory']['peak_bytes']} "
              f"bottleneck={analytic.bottleneck} "
              f"t=(c{analytic.t_compute*1e3:.1f} m{analytic.t_memory*1e3:.1f} "
              f"x{analytic.t_collective*1e3:.1f})ms")
        print("  memory_analysis:", mem)
        ca = compiled.cost_analysis() or {}
        print("  cost_analysis: flops=%.3e bytes=%.3e" %
              (ca.get("flops", 0.0), ca.get("bytes accessed", 0.0)))
    return record, compiled


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    args = ap.parse_args(argv)

    combos = []
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for mp in meshes:
        mesh = make_production_mesh(multi_pod=mp)
        for a in archs:
            for s in shapes:
                tag = f"{a}_{s}_{'2x16x16' if mp else '16x16'}".replace("/", "-")
                try:
                    rec, _ = lower_combo(a, s, multi_pod=mp, mesh=mesh)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    rec = {"arch": a, "shape": s, "status": "fail",
                           "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=2)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
