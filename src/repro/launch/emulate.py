"""Emulated-FaaS training driver: run a FuncPipe plan through the runtime.

Timing mode (any paper model or assigned arch; planner picks the config):

    PYTHONPATH=src python -m repro.launch.emulate --model bert-large \\
        --platform aws --batch 64 --steps 2

Numeric mode (reduced arch, real JAX forward/backward through the emulated
object store; partition is a period-aligned balanced split):

    PYTHONPATH=src python -m repro.launch.emulate --arch phi3-mini-3.8b \\
        --numerics --stages 2 --dp 2 --batch 8 --seq 16 --steps 2

Prints the executed plan, per-step losses (numeric mode), the simulated
time/cost breakdown, and the agreement vs the analytic simulator and the
closed-form performance model.
"""
from __future__ import annotations

import argparse
import dataclasses

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import InputShape
from repro.core import planner
from repro.core.partition import stages_of
from repro.core.perfmodel import Config, evaluate
from repro.core.profiler import arch_model_profile, paper_model_profile
from repro.serverless.frameworks import ALPHA_PAIRS
from repro.serverless.platform import ALIBABA_FC, AWS_LAMBDA, MB
from repro.serverless.runtime import Execution, run_plan
from repro.serverless.simulator import simulate_funcpipe

PLATFORMS = {"aws": AWS_LAMBDA, "alibaba": ALIBABA_FC}


def numeric_partition(cfg, n_stages: int) -> tuple:
    """Boundary vector over the arch profile ([embed]+layers+[head]) cutting
    at period boundaries so every stage owns whole instances."""
    L = cfg.n_layers + 2
    plen = cfg.period_len
    n_inst = cfg.n_periods
    assert n_stages <= n_inst, (n_stages, n_inst)
    x = [0] * (L - 1)
    for s in range(1, n_stages):
        inst = round(s * n_inst / n_stages)
        layer = inst * plen               # first layer of stage s
        x[layer] = 1                      # cut after profile layer `layer`
    return tuple(x)


def min_feasible_z(profile, platform, x, d, mu):
    stage_mem = planner._min_feasible_stage_mem(profile, platform, x, d, mu)
    if stage_mem is None:
        raise SystemExit("no memory option fits the per-stage working set")
    return planner._expand_z(stage_mem, x, profile.L)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default=None, help="paper model (timing mode)")
    ap.add_argument("--arch", default=None, help="assigned arch id")
    ap.add_argument("--platform", default="aws", choices=sorted(PLATFORMS))
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--micro-batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=2)
    ap.add_argument("--numerics", action="store_true",
                    help="run real JAX through the store (reduced arch)")
    ap.add_argument("--stages", type=int, default=2, help="numeric mode stages")
    ap.add_argument("--dp", type=int, default=2, help="numeric mode DP degree")
    ap.add_argument("--seq", type=int, default=16, help="numeric mode seq len")
    ap.add_argument("--n-layers", type=int, default=4, help="numeric mode depth")
    ap.add_argument("--lambda-ml-sync", action="store_true",
                    help="use the 3-phase eq (1) collective instead of eq (2)")
    ap.add_argument("--contention", action="store_true")
    args = ap.parse_args(argv)
    platform = PLATFORMS[args.platform]
    pipelined = not args.lambda_ml_sync

    if args.numerics:
        import jax

        from repro.data.synthetic import make_batch
        from repro.models import registry
        from repro.optim import AdamW

        arch = args.arch or "phi3-mini-3.8b"
        cfg = dataclasses.replace(get_config(arch).reduced(),
                                  n_layers=args.n_layers)
        shape = InputShape("emulate", args.seq, args.batch, "train")
        mu = max(1, args.batch // (args.dp * 2))
        if args.batch % (args.dp * mu):
            raise SystemExit(
                f"--batch {args.batch} must be divisible by dp*mu "
                f"= {args.dp}*{mu}")
        if args.stages > cfg.n_periods:
            raise SystemExit(
                f"--stages {args.stages} exceeds the {cfg.n_periods} period "
                f"instances of {arch} at --n-layers {args.n_layers}")
        mb = args.batch // (args.dp * mu)
        prof = arch_model_profile(cfg, platform, seq=args.seq, micro_batch=mb)
        x = numeric_partition(cfg, args.stages)
        z = min_feasible_z(prof, platform, x, args.dp, mu)
        config = Config(x=x, d=args.dp, z=z)
        M = args.dp * mu
        params0 = registry.init_params(cfg, jax.random.PRNGKey(0))
        ex = Execution(
            cfg=cfg, optimizer=AdamW(lr=1e-2), init_params=params0,
            batch_fn=lambda k: make_batch(cfg, shape, step=k),
        )
    else:
        from repro.core.profiler import _PAPER_MODELS

        model = args.model or "bert-large"
        if model in ARCH_IDS:
            prof_full = arch_model_profile(get_config(model), platform)
        elif model in _PAPER_MODELS:
            prof_full = paper_model_profile(model, platform)
        else:
            raise SystemExit(
                f"unknown model {model!r}; paper models: "
                f"{sorted(_PAPER_MODELS)}, archs: {sorted(ARCH_IDS)}")
        M = max(1, args.batch // args.micro_batch)
        r = planner.solve(prof_full, platform, alpha=ALPHA_PAIRS[1],
                          total_micro_batches=M, merge_to=8,
                          pipelined_sync=pipelined)
        if r is None:
            raise SystemExit(f"planner found no feasible config for {model}")
        prof, config = r.profile, r.config
        ex = None

    st = stages_of(config.x)
    mems = [platform.memory_options[config.z[lo]] // MB for lo, _ in st]
    print(f"plan: {len(st)} stages x d={config.d} "
          f"({len(st) * config.d} workers), mem={mems}MB, "
          f"micro_batches={M} (mu={max(1, M // config.d)}/worker), "
          f"platform={platform.name}, sync={'eq(2)' if pipelined else 'eq(1)'}")

    res = run_plan(prof, platform, config, M, steps=args.steps,
                   pipelined_sync=pipelined, contention=args.contention,
                   execution=ex)
    if res.metrics:
        for k, m in enumerate(res.metrics):
            print(f"step {k}: loss={m['loss']:.4f} ce={m['ce']:.4f} "
                  f"aux={m['aux']:.4f}")
    bd = res.breakdown
    print(f"engine: t_iter={res.t_iter:.3f}s cost=${res.cost:.6f}/iter "
          f"mem={res.total_mem_gb:.1f}GB "
          f"(compute={bd['compute']:.3f}s pipe_comm={bd['pipeline_comm']:.3f}s "
          f"sync={bd['sync']:.3f}s)")
    ss = res.store_stats
    print(f"store: {ss.puts} puts / {ss.gets} gets, "
          f"{ss.bytes_in / MB:.0f}MB in / {ss.bytes_out / MB:.0f}MB out, "
          f"peak {ss.peak_bytes / MB:.0f}MB")

    sim = simulate_funcpipe(prof, platform, config, M,
                            pipelined_sync=pipelined,
                            contention=args.contention)
    ev = evaluate(prof, platform, config, M, pipelined_sync=pipelined)
    for name, t in [("simulator", sim.t_iter), ("perfmodel", ev.t_iter)]:
        print(f"vs {name}: t_iter={t:.3f}s "
              f"(rel err {abs(res.t_iter - t) / t:.1%})")


if __name__ == "__main__":
    main()
