"""Emulated-FaaS training driver — thin shim over ``python -m repro emulate``.

The implementation moved to :mod:`repro.cli` when the unified deployment API
landed; this module stays so ``python -m repro.launch.emulate`` keeps
working.  Prefer:

    PYTHONPATH=src python -m repro emulate --model bert-large --batch 64
    PYTHONPATH=src python -m repro emulate plan.json --steps 2
    PYTHONPATH=src python -m repro emulate --numerics --model phi3-mini-3.8b \\
        --stages 2 --dp 2 --batch 8 --seq 16 --steps 2
"""
from __future__ import annotations

import sys
from typing import List, Optional

from repro.cli import main as _cli_main


def main(argv: Optional[List[str]] = None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    # the pre-API driver spelled the arch flag --arch; keep both forms working
    args = ["--model" if a == "--arch"
            else "--model=" + a[len("--arch="):] if a.startswith("--arch=")
            else a
            for a in args]
    return _cli_main(["emulate", *args])


if __name__ == "__main__":
    sys.exit(main())
