"""Pallas-TPU flash-decode: one query token against a long KV cache.

The cache length is a runtime scalar (scalar-prefetch), the grid walks cache
blocks sequentially with the partial-softmax (m, l, acc) state in VMEM
scratch — the same combiner the data-axis-sharded 500k decode uses across
chips (models.attention sharded path), here applied within a chip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; accept either
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _decode_kernel(
    length_ref,                  # scalar prefetch: [1] int32
    q_ref,                       # [1, G, hd]  (one kv-head group)
    k_ref, v_ref,                # [1, CB, hd]
    o_ref,                       # [1, G, hd]
    m_ref, l_ref, acc_ref,       # scratch [G], [G], [G, hd]
    *,
    c_block: int,
    n_c: int,
    scale: float,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale               # [G, hd]
    k = k_ref[0].astype(jnp.float32)                       # [CB, hd]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [G, CB]
    slot = ci * c_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(slot < length_ref[0], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ci == n_c - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("c_block", "interpret"))
def decode_attention(
    q: jax.Array,        # [B, Hq, hd]
    k_cache: jax.Array,  # [B, Hkv, C, hd]
    v_cache: jax.Array,
    length: jax.Array,   # scalar int32: valid cache slots
    *,
    c_block: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, hd = q.shape
    Hkv, C = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    c_block = min(c_block, C)
    assert C % c_block == 0
    n_c = C // c_block

    qr = q.reshape(B * Hkv, G, hd)
    kr = k_cache.reshape(B * Hkv, C, hd)
    vr = v_cache.reshape(B * Hkv, C, hd)
    length = jnp.asarray(length, jnp.int32).reshape(1)

    kernel = functools.partial(
        _decode_kernel, c_block=c_block, n_c=n_c, scale=hd**-0.5
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * Hkv, n_c),
        in_specs=[
            pl.BlockSpec((1, G, hd), lambda bh, ci, *_: (bh, 0, 0)),
            pl.BlockSpec((1, c_block, hd), lambda bh, ci, *_: (bh, ci, 0)),
            pl.BlockSpec((1, c_block, hd), lambda bh, ci, *_: (bh, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda bh, ci, *_: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * Hkv, G, hd), q.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(length, qr, kr, vr)
    return out.reshape(B, Hq, hd)
