"""jit'd wrappers selecting Pallas kernels (TPU) or jnp oracles (CPU).

Models call these; ``REPRO_KERNEL_MODE`` picks the backend:
  auto      — Pallas on TPU, reference elsewhere (default)
  interpret — Pallas in interpret mode (CPU correctness runs)
  ref       — always the jnp oracle
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref


def _mode() -> str:
    m = os.environ.get("REPRO_KERNEL_MODE", "auto")
    if m == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return m


def flash_attention(q, k, v, *, causal=True, window=0, positions=None):
    mode = _mode()
    if mode == "ref":
        return _ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                        positions=positions)
    from repro.kernels.flash_attention import flash_attention as fa

    return fa(q, k, v, causal=causal, window=window,
              interpret=(mode == "interpret"))


def decode_attention(q, k_cache, v_cache, length):
    mode = _mode()
    if mode == "ref":
        return _ref.decode_attention_ref(q, k_cache, v_cache, length)
    from repro.kernels.decode_attention import decode_attention as da

    return da(q, k_cache, v_cache, length, interpret=(mode == "interpret"))


def decode_attention_capable(*, n_q_heads: int, n_kv_heads: int,
                             capacity: int, window: int = 0,
                             seq_shards: int = 1) -> bool:
    """Shape-capability probe for the flash-decode kernel: the Pallas path
    covers the plain append-cache layout only — no rolling-window ring
    validity, no sequence-sharded partial softmax — and needs whole-group
    query heads plus a cache capacity the grid can tile (C % c_block == 0
    with c_block = min(512, C)).  Callers fall back to the jnp path when
    this returns False, so ``use_pallas`` is safe to pass for any layer."""
    if window or seq_shards > 1:
        return False
    if n_kv_heads <= 0 or n_q_heads % n_kv_heads:
        return False
    return capacity <= 512 or capacity % 512 == 0


def swiglu(x, w_gate, w_up):
    mode = _mode()
    orig = x.shape
    x2 = x.reshape(-1, orig[-1])
    if mode == "ref" or x2.shape[0] % 8:
        out = _ref.swiglu_ref(x2, w_gate, w_up)
    else:
        from repro.kernels.swiglu import swiglu as sg

        out = sg(x2, w_gate, w_up, interpret=(mode == "interpret"))
    return out.reshape(*orig[:-1], w_gate.shape[1])
