"""Pure-jnp oracles for every Pallas kernel (the reference the tests
assert_allclose against, and the CPU execution path of the models)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array,   # [B, S, Hq, hd]
    k: jax.Array,   # [B, S, Hkv, hd]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    positions: jax.Array | None = None,  # [S]
) -> jax.Array:
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    pos = positions if positions is not None else jnp.arange(S, dtype=jnp.int32)
    qf = q.astype(jnp.float32) * hd**-0.5
    qg = qf.reshape(B, S, Hkv, G, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    if causal:
        allow = pos[None, :] <= pos[:, None]
        if window:
            allow &= pos[None, :] > (pos[:, None] - window)
        s = jnp.where(allow[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, Hq, hd).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,        # [B, Hq, hd] one new token per sequence
    k_cache: jax.Array,  # [B, Hkv, C, hd]
    v_cache: jax.Array,
    length: jax.Array,   # [] or [B]: number of valid cache slots
) -> jax.Array:
    B, Hq, hd = q.shape
    Hkv, C = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    length = jnp.broadcast_to(jnp.asarray(length), (B,))
    qg = (q.astype(jnp.float32) * hd**-0.5).reshape(B, Hkv, G, hd)
    s = jnp.einsum("bhgd,bhcd->bhgc", qg, k_cache.astype(jnp.float32))
    valid = jnp.arange(C)[None, :] < length[:, None]          # [B, C]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgc,bhcd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, hd).astype(q.dtype)


def swiglu_ref(x: jax.Array, w_gate: jax.Array, w_up: jax.Array) -> jax.Array:
    """x [T, d] @ {w_gate, w_up} [d, f] -> silu(x wg) * (x wu), fp32 accum."""
    xf = x.astype(jnp.float32)
    g = xf @ w_gate.astype(jnp.float32)
    u = xf @ w_up.astype(jnp.float32)
    return (jax.nn.silu(g) * u).astype(x.dtype)
