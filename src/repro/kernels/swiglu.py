"""Pallas-TPU fused SwiGLU: silu(x @ w_gate) * (x @ w_up) in one pass.

Both matmuls share the streamed x tile, the d (contraction) dimension is the
sequential innermost grid axis with two fp32 VMEM accumulators, and the
silu*mul epilogue runs on the last d block — saving one full [T, f] round
trip to HBM versus two separate matmuls + elementwise (the dense/expert FFN
hot loop).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; accept either
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _swiglu_kernel(
    x_ref,                   # [TB, DB]
    wg_ref, wu_ref,          # [DB, FB]
    o_ref,                   # [TB, FB]
    accg_ref, accu_ref,      # scratch [TB, FB] fp32
    *,
    n_d: int,
):
    di = pl.program_id(2)

    @pl.when(di == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    x = x_ref[...].astype(jnp.float32)
    accg_ref[...] += jax.lax.dot_general(
        x, wg_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    accu_ref[...] += jax.lax.dot_general(
        x, wu_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(di == n_d - 1)
    def _emit():
        g = accg_ref[...]
        o_ref[...] = (g * jax.nn.sigmoid(g) * accu_ref[...]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("t_block", "f_block", "d_block", "interpret")
)
def swiglu(
    x: jax.Array,        # [T, d]
    w_gate: jax.Array,   # [d, f]
    w_up: jax.Array,
    *,
    t_block: int = 256,
    f_block: int = 512,
    d_block: int = 512,
    interpret: bool = False,
) -> jax.Array:
    T, d = x.shape
    f = w_gate.shape[1]
    t_block = min(t_block, T)
    f_block = min(f_block, f)
    d_block = min(d_block, d)
    assert T % t_block == 0 and f % f_block == 0 and d % d_block == 0
    n_t, n_f, n_d = T // t_block, f // f_block, d // d_block

    kernel = functools.partial(_swiglu_kernel, n_d=n_d)
    return pl.pallas_call(
        kernel,
        grid=(n_t, n_f, n_d),
        in_specs=[
            pl.BlockSpec((t_block, d_block), lambda ti, fi, di: (ti, di)),
            pl.BlockSpec((d_block, f_block), lambda ti, fi, di: (di, fi)),
            pl.BlockSpec((d_block, f_block), lambda ti, fi, di: (di, fi)),
        ],
        out_specs=pl.BlockSpec((t_block, f_block), lambda ti, fi, di: (ti, fi)),
        out_shape=jax.ShapeDtypeStruct((T, f), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((t_block, f_block), jnp.float32),
            pltpu.VMEM((t_block, f_block), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(x, w_gate, w_up)
