"""Pallas-TPU flash attention (causal / sliding-window / GQA).

TPU adaptation notes (DESIGN.md §7): tiles are MXU-aligned (q-block x k-block
= 128-multiples), the (m, l, acc) online-softmax state lives in VMEM scratch
persisted across the sequential innermost k-block grid dimension, and the
output block is emitted on the last k iteration — the standard TPU flash
schedule (no warps/shared-memory banking to port from the CUDA version).

Validated on CPU with interpret=True against kernels.ref.flash_attention_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; accept either
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,          # [1, QB, hd], [1, KB, hd]
    o_ref,                        # [1, QB, hd]
    m_ref, l_ref, acc_ref,        # VMEM scratch: [QB], [QB], [QB, hd]
    *,
    q_block: int,
    k_block: int,
    n_k: int,
    scale: float,
    causal: bool,
    window: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale        # [QB, hd]
    k = k_ref[0].astype(jnp.float32)                # [KB, hd]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # [QB, KB]

    if causal:
        q_pos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32, (q_block, k_block), 0)
        k_pos = ki * k_block + jax.lax.broadcasted_iota(jnp.int32, (q_block, k_block), 1)
        allow = k_pos <= q_pos
        if window:
            allow &= k_pos > (q_pos - window)
        s = jnp.where(allow, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_block", "k_block", "interpret"),
)
def flash_attention(
    q: jax.Array,   # [B, S, Hq, hd]
    k: jax.Array,   # [B, S, Hkv, hd]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_block: int = 128,
    k_block: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    q_block = min(q_block, S)
    k_block = min(k_block, S)
    assert S % q_block == 0 and S % k_block == 0
    n_q = S // q_block
    n_k = S // k_block

    qr = q.transpose(0, 2, 1, 3).reshape(B * Hq, S, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, hd)

    def q_index(bh, qi, ki):
        return (bh, qi, 0)

    def kv_index(bh, qi, ki):
        b, h = bh // Hq, bh % Hq
        return (b * Hkv + h // G, ki, 0)

    kernel = functools.partial(
        _flash_kernel,
        q_block=q_block, k_block=k_block, n_k=n_k,
        scale=hd**-0.5, causal=causal, window=window,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, q_block, hd), q_index),
            pl.BlockSpec((1, k_block, hd), kv_index),
            pl.BlockSpec((1, k_block, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, q_block, hd), q_index),
        out_shape=jax.ShapeDtypeStruct((B * Hq, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block, hd), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(qr, kr, vr)
    return out.reshape(B, Hq, S, hd).transpose(0, 2, 1, 3)
