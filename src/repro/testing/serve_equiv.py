"""Multi-device serving equivalence: pipelined prefill/decode == single-device.

Run with fake devices:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m repro.testing.serve_equiv [arch] [stages] [tensor] [seq_shards]
"""
import os
import sys

if __name__ == "__main__" and "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    )

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core import sharding
from repro.core.plan import make_plan
from repro.models import registry
from repro.train import serve_step as srv


def run(arch_id="phi3-mini-3.8b", stages=4, tensor=1, seq_shards=1,
        n_decode=6, seed=0, tol=2e-3):
    model_ax = stages * tensor
    data_ax = 8 // model_ax
    mesh = jax.make_mesh((data_ax, model_ax), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = get_config(arch_id).reduced()
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.n_experts))
        )
    cfg = dataclasses.replace(cfg, stages=stages, tensor=tensor)
    S_pre = 64
    s_ctx = S_pre + n_decode
    B = 1 if seq_shards > 1 else 8
    # decode shape determines cache layout; seq_len == capacity
    dshape = InputShape("serve_equiv", s_ctx, B, "decode")
    pshape = InputShape("serve_equiv_p", S_pre, B, "prefill")
    plan = make_plan(cfg, dshape, data=data_ax, model=model_ax, microbatches=1)
    if seq_shards > 1:
        assert plan.seq_shards == data_ax, plan
    pplan = dataclasses.replace(plan, seq_shards=plan.seq_shards)

    key = jax.random.PRNGKey(seed)
    base = registry.init_params(cfg, key)
    toks = jax.random.randint(jax.random.fold_in(key, 1), (B, s_ctx), 0,
                              cfg.vocab_size, jnp.int32)
    from_scratch = plan.seq_shards > 1  # sharded caches: decode-only path

    # ---- single-device reference
    ref_steps = []
    if from_scratch:
        ref_caches = registry.init_decode_caches(cfg, B, s_ctx)
        e_pre = 0.0
        dec_range = range(0, n_decode)
        for t in dec_range:
            lg, ref_caches = registry.decode_step(cfg, base, ref_caches, toks[:, t:t + 1])
            ref_steps.append(lg)
    else:
        ref_logits_pre, ref_caches = registry.prefill(
            cfg, base, {"tokens": toks[:, :S_pre]}, capacity=s_ctx)
        dec_range = range(S_pre, S_pre + n_decode)
        for t in dec_range:
            lg, ref_caches = registry.decode_step(cfg, base, ref_caches, toks[:, t:t + 1])
            ref_steps.append(lg)

    # ---- pipelined
    with jax.set_mesh(mesh):
        params = sharding.to_pipeline_layout(cfg, plan, base)
        if from_scratch:
            caches = srv.init_caches(cfg, plan, dshape)
            e_pre = 0.0
        else:
            prefill = srv.make_prefill_step(cfg, pplan, mesh, pshape, capacity=s_ctx)
            logits_pre, caches = prefill(params, {"tokens": toks[:, :S_pre]})
            e_pre = float(jnp.max(jnp.abs(logits_pre - ref_logits_pre)))
        decode = srv.make_decode_step(cfg, plan, mesh, dshape, donate=False)
        steps = []
        for t in dec_range:
            lg, caches = decode(params, caches, toks[:, t:t + 1])
            steps.append(lg)

    e_dec = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(steps, ref_steps))
    print(f"[serve_equiv] {arch_id} stages={stages} tp={tensor} seq_shards={plan.seq_shards} "
          f"prefill_err={e_pre:.2e} decode_err={e_dec:.2e}")
    return e_pre < tol and e_dec < tol


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description="serve pipeline equivalence check")
    ap.add_argument("arch", nargs="?", default="phi3-mini-3.8b")
    ap.add_argument("stages", nargs="?", type=int, default=4)
    ap.add_argument("tensor", nargs="?", type=int, default=1)
    ap.add_argument("seq_shards", nargs="?", type=int, default=1)
    a = ap.parse_args()
    sys.exit(0 if run(a.arch, a.stages, a.tensor, a.seq_shards) else 1)
