"""Multi-device collective checks (run with fake devices in a subprocess)."""
import os
import sys

if __name__ == "__main__" and "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    )

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import collectives as cc


def run() -> bool:
    D = 8
    mesh = jax.make_mesh((D,), ("d",), axis_types=(jax.sharding.AxisType.Auto,))
    ok = True
    key = jax.random.PRNGKey(0)
    # NB: reduce-scatter needs the LOCAL leading dim divisible by D (the
    # ZeRO path pads flats to D*ceil(n/D)); shapes below satisfy that.
    for shape in [(D * D * 2,), (D * D * 2, 6), (D * D, 3, 5), (D * D * 3,)]:
        x = jax.random.normal(key, shape, jnp.float32)
        for bi in (False, True):
            rs = jax.jit(jax.shard_map(
                lambda t: cc.ring_reduce_scatter(t, "d", bidirectional=bi),
                mesh=mesh, in_specs=P("d"), out_specs=P("d"), check_vma=False))(x)
            ref = jax.jit(jax.shard_map(
                lambda t: jax.lax.psum_scatter(t, "d", scatter_dimension=0, tiled=True),
                mesh=mesh, in_specs=P("d"), out_specs=P("d"), check_vma=False))(x)
            e1 = float(jnp.max(jnp.abs(rs - ref)))
            ag = jax.jit(jax.shard_map(
                lambda t: cc.ring_all_gather(t, "d", bidirectional=bi),
                mesh=mesh, in_specs=P("d"), out_specs=P(None), check_vma=False))(x)
            e2 = float(jnp.max(jnp.abs(ag - x)))
            print(f"shape={shape} bidi={bi} rs_err={e1:.1e} ag_err={e2:.1e}")
            ok &= e1 < 1e-5 and e2 < 1e-5
    # composition: RS then AG on updated shard == allreduce-mean style update
    x = jax.random.normal(key, (D * 32,), jnp.float32)

    def update(t):
        shard = cc.ring_reduce_scatter(t, "d", bidirectional=True)
        return cc.ring_all_gather(shard * 0.5, "d", bidirectional=True)

    got = jax.jit(jax.shard_map(update, mesh=mesh, in_specs=P("d"),
                                out_specs=P(None), check_vma=False))(x)
    want = 0.5 * np.sum(np.asarray(x).reshape(D, -1), axis=0)
    e3 = float(np.max(np.abs(np.asarray(got) - want)))
    print(f"compose_err={e3:.1e}")
    ok &= e3 < 1e-4
    # analytic costs: bidi halves link bytes
    c_uni = cc.reduce_scatter_cost(1e9, 16, False)
    c_bi = cc.reduce_scatter_cost(1e9, 16, True)
    ok &= abs(c_bi.bytes_on_link * 2 - c_uni.bytes_on_link) < 1.0
    return ok


if __name__ == "__main__":
    sys.exit(0 if run() else 1)
