"""Multi-device equivalence check: pipelined train step == single-device step.

Run in a subprocess with fake devices:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m repro.testing.pipeline_equiv [arch_id] [stages] [tensor]

Exits nonzero on mismatch.  Used by tests/test_pipeline_multidev.py.
"""
import os
import sys

if __name__ == "__main__" and "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    )

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core import sharding
from repro.core.plan import make_plan
from repro.data.synthetic import make_batch
from repro.models import registry
from repro.optim import AdamW, SGD
from repro.train.train_step import (
    grad_sync_tree,
    init_opt_state,
    make_train_state,
    make_train_step,
)


def reference_step(cfg, base_params, batch, optimizer, step_idx=0):
    """Plain single-device step with fp32 masters (same math as ZeRO path)."""
    def loss_of(p):
        loss, metrics = registry.loss_fn(cfg, p, batch)
        return loss, metrics

    (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(base_params)

    def upd(g, p):
        master = p.astype(jnp.float32)
        st = optimizer.init_state(master)
        new_m, _ = optimizer.update(g.astype(jnp.float32), master, st,
                                    jnp.asarray(step_idx, jnp.int32))
        return new_m.astype(p.dtype)

    return jax.tree.map(upd, grads, base_params), loss, metrics


def run(arch_id="phi3-mini-3.8b", stages=4, tensor=1, n_layers=None,
        bidirectional=True, seed=0, tol=2e-4):
    data_ax = 8 // (stages * tensor)
    mesh = jax.make_mesh((data_ax, stages * tensor), ("data", "model"))
    cfg = get_config(arch_id).reduced()
    if n_layers is not None:
        cfg = dataclasses.replace(cfg, n_layers=n_layers)
    if cfg.moe is not None:
        # capacity: avoid drop mismatches between micro-batch groupings;
        # aux: the load-balance loss is an expectation over the routing group,
        # which legitimately differs between per-micro-batch and full-batch
        # routing — zero it for exact equivalence checking.
        cfg = dataclasses.replace(
            cfg,
            moe=dataclasses.replace(
                cfg.moe,
                capacity_factor=float(cfg.moe.n_experts),
                router_aux_weight=0.0,
            ),
        )
    cfg = dataclasses.replace(cfg, stages=stages, tensor=tensor)
    shape = InputShape("equiv", 64, 8, "train")
    plan = make_plan(cfg, shape, data=data_ax, model=stages * tensor,
                     microbatches=2, remat="tick")

    key = jax.random.PRNGKey(seed)
    base = registry.init_params(cfg, key)
    batch = make_batch(cfg, shape, seed=seed)
    optimizer = AdamW(lr=1e-2)

    with jax.set_mesh(mesh):
        params = sharding.to_pipeline_layout(cfg, plan, base)
        opt_state = init_opt_state(cfg, plan, optimizer, params)
        step = make_train_step(cfg, plan, mesh, optimizer, shape,
                               bidirectional=bidirectional, donate=False)
        new_params, new_opt, metrics = step(params, opt_state, batch, 0)

    ref_new_base, ref_loss, ref_metrics = reference_step(cfg, base, batch, optimizer)
    ref_new_layout = sharding.to_pipeline_layout(cfg, plan, ref_new_base)

    errs = {}
    loss_err = abs(float(metrics["loss"]) - float(ref_loss))
    errs["loss"] = loss_err
    flat_new = jax.tree.leaves_with_path(new_params)
    flat_ref = jax.tree.leaves(ref_new_layout)
    worst = ("", 0.0)
    for (path, a), b in zip(flat_new, flat_ref):
        e = float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        if e > worst[1]:
            worst = (jax.tree_util.keystr(path), e)
    errs["param"] = worst
    print(f"[pipeline_equiv] {arch_id} stages={stages} tp={tensor} "
          f"loss={float(metrics['loss']):.5f} ref={float(ref_loss):.5f} "
          f"loss_err={loss_err:.2e} worst_param={worst[0]} err={worst[1]:.2e}")
    ok = loss_err < tol and worst[1] < tol * 50
    return ok


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description="pipeline-vs-monolithic check")
    ap.add_argument("arch", nargs="?", default="phi3-mini-3.8b")
    ap.add_argument("stages", nargs="?", type=int, default=4)
    ap.add_argument("tensor", nargs="?", type=int, default=1)
    ap.add_argument("n_layers", nargs="?", type=int, default=None)
    a = ap.parse_args()
    sys.exit(0 if run(a.arch, a.stages, a.tensor, a.n_layers) else 1)
