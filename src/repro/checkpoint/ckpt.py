"""Checkpointing — the Function Manager's checkpoint/restart analog (§3.1 ⑧).

Serverless functions time out (15 min on Lambda); the paper's Function
Manager checkpoints to storage and relaunches workers.  On a pod the same
mechanism is ordinary periodic checkpointing; we serialize the param/opt
pytrees with msgpack (structure) + raw npy buffers.

Two surfaces:

* file checkpoints (``save_checkpoint``/``restore_checkpoint``) — atomic
  tmp-then-rename writes, so a crash mid-write (a truncated ``.tmp``) never
  corrupts the previous checkpoint;
* byte-level ``pack_state``/``unpack_state`` — the same wire format without
  the file, used by the engine to checkpoint stage state *into the object
  store* (the substrate the paper actually checkpoints to).

Restores validate everything they can — leaf count, the recorded treedef
string, shapes AND dtypes — and raise :class:`CheckpointError` (not bare
``assert``, which ``python -O`` strips) on any mismatch: a checkpoint that
silently restores into the wrong structure or precision would train on,
wrong, for thousands of steps before anyone noticed.
"""
from __future__ import annotations

import io
import os
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


class CheckpointError(RuntimeError):
    """A checkpoint payload is malformed or does not match the structure it
    is being restored into (treedef / leaf count / shape / dtype)."""


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


# ----------------------------------------------------------- wire format
def pack_state(tree: Any, *, step: int = 0) -> bytes:
    """Serialize a pytree of arrays to the checkpoint wire format (msgpack
    structure + raw npy leaf buffers) — what ``save_checkpoint`` writes to
    disk and the engine puts under ``ckpt/...`` store keys."""
    leaves, treedef = _flatten(tree)
    payload = {
        "step": int(step),
        "treedef": str(treedef),
        "leaves": [],
    }
    for leaf in leaves:
        arr = np.asarray(leaf)
        buf = io.BytesIO()
        np.save(buf, arr, allow_pickle=False)
        payload["leaves"].append(buf.getvalue())
    return msgpack.packb(payload, use_bin_type=True)


def unpack_state(blob: bytes, like: Any) -> tuple[Any, int]:
    """Deserialize :func:`pack_state` bytes into the structure of ``like``,
    validating treedef, leaf count, shapes and dtypes.  Returns
    ``(tree, step)``; raises :class:`CheckpointError` on any mismatch."""
    try:
        payload = msgpack.unpackb(blob, raw=False)
    except Exception as e:
        raise CheckpointError(f"checkpoint payload is not valid msgpack "
                              f"({type(e).__name__}: {e})") from e
    if not isinstance(payload, dict) or "leaves" not in payload:
        raise CheckpointError("checkpoint payload missing 'leaves'")
    leaves, treedef = _flatten(like)
    want_def = str(treedef)
    got_def = payload.get("treedef")
    if got_def != want_def:
        raise CheckpointError(
            f"checkpoint treedef does not match the restore target:\n"
            f"  checkpoint: {got_def}\n  target:     {want_def}")
    if len(payload["leaves"]) != len(leaves):
        raise CheckpointError(
            f"checkpoint has {len(payload['leaves'])} leaves, restore "
            f"target has {len(leaves)}")
    out = []
    for i, (blob_i, ref) in enumerate(zip(payload["leaves"], leaves)):
        try:
            arr = np.load(io.BytesIO(blob_i), allow_pickle=False)
        except Exception as e:
            raise CheckpointError(
                f"checkpoint leaf {i} is not a valid npy buffer "
                f"({type(e).__name__}: {e})") from e
        ref_arr = np.asarray(ref) if not hasattr(ref, "shape") else ref
        if tuple(arr.shape) != tuple(ref_arr.shape):
            raise CheckpointError(
                f"checkpoint leaf {i} shape {tuple(arr.shape)} != target "
                f"shape {tuple(ref_arr.shape)}")
        if np.dtype(arr.dtype) != np.dtype(ref_arr.dtype):
            raise CheckpointError(
                f"checkpoint leaf {i} dtype {np.dtype(arr.dtype)} != target "
                f"dtype {np.dtype(ref_arr.dtype)}")
        out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out), int(payload.get("step", 0))


# ----------------------------------------------------------------- files
def save_checkpoint(path: str, tree: Any, *, step: int = 0) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    blob = pack_state(tree, step=step)
    # atomic publish: a crash between write and replace leaves a stray
    # .tmp but never a torn checkpoint at `path`
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(blob)
    os.replace(tmp, path)


def restore_checkpoint(path: str, like: Any) -> tuple[Any, int]:
    """Restore into the structure of ``like`` (treedef/shapes/dtypes
    validated; :class:`CheckpointError` on mismatch or corruption)."""
    with open(path, "rb") as f:
        blob = f.read()
    return unpack_state(blob, like)


class FunctionManager:
    """Periodic checkpoint/restart policy: checkpoints whenever the elapsed
    'function lifetime' budget is nearly exhausted (the paper restarts
    workers before the 15-minute Lambda timeout).

    Two clocks, same policy: the wall-clock form (``lifetime`` seconds,
    used by ``launch/train.py``) and a step-based form (``lifetime_steps``,
    used by the engine, whose substrate may run on a virtual clock where
    wall time is meaningless) — ``should_restart(steps_since_launch)`` says
    when the engine must checkpoint + relaunch to stay under the platform's
    cap with margin ``safety``.
    """

    def __init__(self, path: str = "", *, lifetime: float = 15 * 60.0,
                 safety: float = 0.9,
                 lifetime_steps: Optional[int] = None):
        self.path = path
        self.lifetime = lifetime
        self.safety = safety
        self.lifetime_steps = lifetime_steps
        self.started = time.monotonic()
        self.restarts = 0

    def should_checkpoint(self) -> bool:
        return (time.monotonic() - self.started) >= self.lifetime * self.safety

    def should_restart(self, steps_since_launch: int) -> bool:
        """Step-based lifetime policy: restart once the *next* step might
        cross the cap's safety margin.  ``max(1, ...)`` guarantees progress
        even under an absurd one-step cap."""
        if self.lifetime_steps is None:
            return False
        budget = max(1, int(self.lifetime_steps * self.safety))
        return steps_since_launch >= budget

    def checkpoint_and_restart(self, tree: Any, step: int) -> None:
        save_checkpoint(self.path, tree, step=step)
        self.restarted()

    def restarted(self) -> None:
        """Record a relaunch (resets both lifetime clocks)."""
        self.started = time.monotonic()
        self.restarts += 1
