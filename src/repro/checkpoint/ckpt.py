"""Checkpointing — the Function Manager's checkpoint/restart analog (§3.1 ⑧).

Serverless functions time out (15 min on Lambda); the paper's Function
Manager checkpoints to storage and relaunches workers.  On a pod the same
mechanism is ordinary periodic checkpointing; we serialize the param/opt
pytrees with msgpack (structure) + raw npy buffers.
"""
from __future__ import annotations

import io
import os
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(path: str, tree: Any, *, step: int = 0) -> None:
    leaves, treedef = _flatten(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {
        "step": step,
        "treedef": str(treedef),
        "leaves": [],
    }
    for leaf in leaves:
        arr = np.asarray(leaf)
        buf = io.BytesIO()
        np.save(buf, arr, allow_pickle=False)
        payload["leaves"].append(buf.getvalue())
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def restore_checkpoint(path: str, like: Any) -> tuple[Any, int]:
    """Restore into the structure of ``like`` (shapes/dtypes asserted)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves, treedef = _flatten(like)
    assert len(payload["leaves"]) == len(leaves), "checkpoint structure mismatch"
    out = []
    for blob, ref in zip(payload["leaves"], leaves):
        arr = np.load(io.BytesIO(blob), allow_pickle=False)
        ref_arr = np.asarray(ref) if not hasattr(ref, "shape") else ref
        assert tuple(arr.shape) == tuple(ref_arr.shape), (arr.shape, ref_arr.shape)
        out.append(jnp.asarray(arr, dtype=ref_arr.dtype))
    return jax.tree.unflatten(treedef, out), int(payload["step"])


class FunctionManager:
    """Periodic checkpoint/restart policy: checkpoints whenever the elapsed
    'function lifetime' budget is nearly exhausted (the paper restarts
    workers before the 15-minute Lambda timeout)."""

    def __init__(self, path: str, *, lifetime: float = 15 * 60.0,
                 safety: float = 0.9):
        self.path = path
        self.lifetime = lifetime
        self.safety = safety
        self.started = time.monotonic()
        self.restarts = 0

    def should_checkpoint(self) -> bool:
        return (time.monotonic() - self.started) >= self.lifetime * self.safety

    def checkpoint_and_restart(self, tree: Any, step: int) -> None:
        save_checkpoint(self.path, tree, step=step)
        self.started = time.monotonic()  # simulated relaunch
        self.restarts += 1
