from repro.checkpoint.ckpt import (  # noqa: F401
    CheckpointError,
    FunctionManager,
    pack_state,
    restore_checkpoint,
    save_checkpoint,
    unpack_state,
)
