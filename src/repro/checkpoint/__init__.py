from repro.checkpoint.ckpt import save_checkpoint, restore_checkpoint, FunctionManager  # noqa: F401
