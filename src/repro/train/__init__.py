from repro.train.train_step import make_train_step, make_train_state  # noqa: F401
from repro.train.serve_step import make_decode_step, make_prefill_step  # noqa: F401
