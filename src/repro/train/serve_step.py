"""Serving steps: pipelined prefill and single-token decode.

Cache layout mirrors the parameter layout: every cache leaf is
[model_axis, ppstage, B, ...], sharded P('model', None, <batch axes>, ...).
For ``long_500k`` (global batch 1) the batch is replicated and the *capacity*
dim of global-attention KV leaves is sharded over 'data' instead
(flash-decode partial-softmax combination across the data axis).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape, ATTN, GLOBAL_WINDOW
from repro.core import sharding
from repro.core.pipeline import (
    _abstract_stage_caches,
    pipeline_decode_step,
    pipeline_prefill,
)
from repro.core.plan import PipelinePlan
from repro.models import attention
from repro.train.train_step import batch_pspecs


def _batch_axes(plan: PipelinePlan):
    if plan.seq_shards > 1:
        return None  # batch fully replicated; KV seq sharded over pod x data
    return ("pod", "data") if plan.pods > 1 else "data"


def cache_specs(cfg: ArchConfig, plan: PipelinePlan, shape: InputShape):
    """(abstract cache tree [model,pp,B,...], PartitionSpec tree)."""
    B = shape.global_batch
    s_ctx = shape.seq_len
    dtype = jnp.dtype(cfg.param_dtype)
    baxis = _batch_axes(plan)
    B_rep = B if plan.seq_shards > 1 else B  # global batch dim in global arrays

    # per-device local caches (what pipeline code sees), then lift to global
    B_local = B if plan.seq_shards > 1 else B // (plan.pods * plan.data)
    local = jax.eval_shape(
        lambda: _abstract_stage_caches(cfg, plan, B_local, s_ctx, dtype)
    )

    def lift(sds, pos_j, leaf_name):
        spec_j = cfg.period[pos_j]
        shp = list(sds.shape)  # [pp, B_local, ...]
        axes: list = ["model", None] + [None] * (len(shp) - 1)
        # scale batch dim back to global
        if plan.seq_shards > 1:
            axes[2] = None  # replicated batch
        else:
            axes[2] = baxis
            shp[1] = B
        # seq-sharded global-attn KV: capacity dim over (pod x) data
        if (
            plan.seq_shards > 1
            and spec_j.mixer == ATTN
            and spec_j.window == GLOBAL_WINDOW
            and leaf_name in ("k", "v")
        ):
            shp[3] *= plan.seq_shards  # [pp,B,kv,C,hd] -> global C
            axes[4] = ("pod", "data") if plan.pods > 1 else "data"
        return (
            jax.ShapeDtypeStruct((plan.model_axis, *shp), sds.dtype),
            P(*axes),
        )

    shapes, specs = [], []
    for j, pos_cache in enumerate(local):
        if hasattr(pos_cache, "_fields"):  # NamedTuple cache
            names = pos_cache._fields
            lifted = {n: lift(getattr(pos_cache, n), j, n) for n in names}
            shapes.append(type(pos_cache)(**{n: lifted[n][0] for n in names}))
            specs.append(type(pos_cache)(**{n: lifted[n][1] for n in names}))
        else:  # pragma: no cover
            raise TypeError(type(pos_cache))
    return tuple(shapes), tuple(specs)


def init_caches(cfg: ArchConfig, plan: PipelinePlan, shape: InputShape):
    shapes, _ = cache_specs(cfg, plan, shape)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)


def make_decode_step(
    cfg: ArchConfig,
    plan: PipelinePlan,
    mesh: Mesh,
    shape: InputShape,
    *,
    donate: bool = True,
):
    """jit-able (params, caches, tokens) -> (logits, caches)."""
    has_pod = "pod" in mesh.axis_names
    param_specs = sharding.pipeline_param_specs(cfg, plan)
    _, cspecs = cache_specs(cfg, plan, shape)
    mask = sharding.layer_mask_array(cfg, plan)
    baxis = _batch_axes(plan)
    tok_spec = P(baxis, None)

    def device_fn(params, caches, tokens, mask_arr):
        params_loc = {
            k: (jax.tree.map(lambda a: a[0], v) if k == "layers" else v)
            for k, v in params.items()
        }
        caches_loc = jax.tree.map(lambda a: a[0], caches)
        logits, new_caches = pipeline_decode_step(
            cfg, plan, params_loc, mask_arr[0], caches_loc, tokens, has_pod=has_pod
        )
        new_caches = jax.tree.map(lambda a: a[None], new_caches)
        return logits, new_caches

    smapped = jax.shard_map(
        device_fn,
        mesh=mesh,
        in_specs=(param_specs, cspecs, tok_spec, P("model", None, None)),
        out_specs=(tok_spec, cspecs),
        check_vma=False,
    )

    def step(params, caches, tokens):
        return smapped(params, caches, tokens, jnp.asarray(mask))

    donate_args = (1,) if donate else ()
    return jax.jit(step, donate_argnums=donate_args)


def make_prefill_step(
    cfg: ArchConfig,
    plan: PipelinePlan,
    mesh: Mesh,
    shape: InputShape,
    *,
    capacity: Optional[int] = None,
):
    """jit-able (params, batch) -> (last-pos logits, caches)."""
    has_pod = "pod" in mesh.axis_names
    param_specs = sharding.pipeline_param_specs(cfg, plan)
    b_specs = batch_pspecs(cfg, shape, plan)
    # prefill caches have capacity == seq (or window); build matching specs
    cap_shape = InputShape(shape.name, capacity or shape.seq_len, shape.global_batch, "decode")
    _, cspecs = cache_specs(cfg, plan, cap_shape)
    mask = sharding.layer_mask_array(cfg, plan)
    baxis = _batch_axes(plan)

    def device_fn(params, batch, mask_arr):
        params_loc = {
            k: (jax.tree.map(lambda a: a[0], v) if k == "layers" else v)
            for k, v in params.items()
        }
        logits, caches = pipeline_prefill(
            cfg, plan, params_loc, mask_arr[0], batch,
            capacity=capacity or shape.seq_len, has_pod=has_pod,
        )
        caches = jax.tree.map(lambda a: a[None], caches)
        return logits, caches

    smapped = jax.shard_map(
        device_fn,
        mesh=mesh,
        in_specs=(param_specs, b_specs, P("model", None, None)),
        out_specs=(P(baxis, None, None), cspecs),
        check_vma=False,
    )

    def step(params, batch):
        return smapped(params, batch, jnp.asarray(mask))

    return jax.jit(step)
