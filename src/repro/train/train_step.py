"""Distributed train step: pipeline forward/backward + the paper's
scatter-reduce gradient synchronization + ZeRO-1 sharded optimizer.

Per leaf (see core.sharding.grad_sync_specs):
  1. tp sync (replicated / kv-shared slices) over 'model' subgroups,
  2. psum over 'pod' (pure DP between pods),
  3. reduce-scatter over 'data' with the uni- or bi-directional ring
     (paper eq (1) vs eq (2) — ``bidirectional=True`` is FuncPipe's schedule),
  4. fp32 master update on the local 1/D shard,
  5. ring all-gather of the updated (bf16) parameters.
MoE expert leaves skip 3/5: expert parallelism already localizes their grads.
"""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.core import collectives as cc
from repro.core import sharding
from repro.core.pipeline import pipeline_train_loss, _unbox
from repro.core.plan import PipelinePlan
from repro.models import registry
from repro.optim import Optimizer


def _rs_chunk(n: int, d: int) -> int:
    return -(-n // d)


def grad_sync_tree(cfg: ArchConfig, plan: PipelinePlan):
    """grad_sync_specs extended with the globally-replicated leaves.
    tp_mode == 'model' marks leaves replicated across the whole model axis."""
    syncs = sharding.grad_sync_specs(cfg, plan)
    glob = sharding.GradSync(data_rs=True, tp_mode="model")
    out = {"embed": glob, "final_norm": glob, "layers": syncs["layers"]}
    if not cfg.tie_embeddings:
        out["head"] = glob
    return out


# ------------------------------------------------------------------ opt state
def _master_shape(p_shape, p_size, gs: sharding.GradSync, plan: PipelinePlan):
    if not gs.data_rs:
        return p_shape
    rows = 1 if gs.tp_mode == "model" else p_shape[0]
    c = _rs_chunk(p_size // rows, plan.data)
    return (rows, plan.data, c)


def init_opt_state(cfg: ArchConfig, plan: PipelinePlan, optimizer: Optimizer, params):
    """Concrete optimizer state from laid-out (global) params."""
    syncs = grad_sync_tree(cfg, plan)

    def one(p, gs: sharding.GradSync):
        if gs.data_rs:
            rows, data, c = _master_shape(p.shape, p.size, gs, plan)
            flat = p.astype(jnp.float32).reshape(rows, -1)
            pad = data * c - flat.shape[1]
            flat = jnp.pad(flat, ((0, 0), (0, pad)))
            master = flat.reshape(rows, data, c)
        else:
            master = p.astype(jnp.float32)
        return {"master": master, **optimizer.init_state(master)}

    return jax.tree.map(one, params, syncs)


def opt_state_specs(cfg: ArchConfig, plan: PipelinePlan, optimizer: Optimizer):
    """(abstract tree, PartitionSpec tree) for the optimizer state."""
    shapes = sharding.abstract_layout_shapes(cfg, plan)
    syncs = grad_sync_tree(cfg, plan)
    param_pspecs = sharding.pipeline_param_specs(cfg, plan)
    sub_keys = list(
        jax.eval_shape(
            lambda x: optimizer.init_state(x), jax.ShapeDtypeStruct((1,), jnp.float32)
        ).keys()
    )

    def one(sds, gs: sharding.GradSync, ps):
        if gs.data_rs:
            shape = _master_shape(sds.shape, int(np.prod(sds.shape)), gs, plan)
            spec = P("model", "data", None) if gs.tp_mode != "model" else P(None, "data", None)
        else:
            shape, spec = sds.shape, ps
        keys = ["master"] + sub_keys
        return (
            {k: jax.ShapeDtypeStruct(shape, jnp.float32) for k in keys},
            {k: spec for k in keys},
        )

    flat_p, treedef = jax.tree.flatten(shapes)
    flat_g = jax.tree.leaves(syncs)
    flat_ps = jax.tree.leaves(
        param_pspecs, is_leaf=lambda x: isinstance(x, P)
    )
    assert len(flat_p) == len(flat_g) == len(flat_ps)
    pairs = [one(s, g, ps) for s, g, ps in zip(flat_p, flat_g, flat_ps)]
    st = jax.tree.unflatten(treedef, [a for a, _ in pairs])
    sp = jax.tree.unflatten(treedef, [b for _, b in pairs])
    return st, sp


# ------------------------------------------------------------------ the step
def batch_pspecs(cfg: ArchConfig, shape: InputShape, plan: PipelinePlan):
    """PartitionSpecs for batch leaves (batch dim over pod+data, or replicated
    when the batch is smaller than the data axis — long-context decode)."""
    from repro.data.specs import input_specs

    specs = input_specs(cfg, shape)
    if plan.seq_shards > 1:
        baxis = None  # batch fully replicated; KV seq sharded over pod x data
    else:
        baxis = ("pod", "data") if plan.pods > 1 else "data"
    return jax.tree.map(lambda s: P(baxis, *([None] * (len(s.shape) - 1))), specs)


def _apply_updates(cfg, plan, optimizer, grads, params_loc, opt_loc, syncs, step,
                   *, bidirectional: bool, has_pod: bool):
    """Per-device gradient sync + ZeRO-1 update.  All args unboxed/local."""
    tpg = cc.tp_groups(plan.stages, plan.tensor)
    kvg = None
    if plan.tensor > 1 and cfg.n_kv_heads < plan.tensor:
        share = plan.tensor // cfg.n_kv_heads
        kvg = [
            [s * plan.tensor + g * share + u for u in range(share)]
            for s in range(plan.stages)
            for g in range(cfg.n_kv_heads)
        ]

    def one(g, p, opt, gs: sharding.GradSync):
        # NB: the differentiated loss is the per-device *local* contribution
        # (see pipeline_train_loss), so every sync here is a plain SUM of
        # distinct contributions — lane-partitioned CE makes tp lanes sum to
        # the full gradient for replicated leaves too.
        g = g.astype(jnp.float32)
        if gs.tp_mode == "all" and plan.tensor > 1:
            g = lax.psum(g, "model", axis_index_groups=tpg)
        elif gs.tp_mode == "kvshare" and kvg is not None:
            g = lax.psum(g, "model", axis_index_groups=kvg)
        elif gs.tp_mode == "model":
            g = lax.psum(g, "model")
        if has_pod:
            g = lax.psum(g, "pod")
        if gs.data_rs:
            flat = g.reshape(-1)
            c = opt["master"].shape[-1]
            pad = plan.data * c - flat.shape[0]
            if pad:
                flat = jnp.pad(flat, (0, pad))
            gsh = cc.ring_reduce_scatter(flat, "data", bidirectional=bidirectional)
            m = opt["master"].reshape(-1)
            st = {k: v.reshape(-1) for k, v in opt.items() if k != "master"}
            new_m, new_st = optimizer.update(gsh, m, st, step)
            new_p_flat = cc.ring_all_gather(
                new_m.astype(p.dtype), "data", bidirectional=bidirectional
            )
            if pad:
                new_p_flat = new_p_flat[:-pad]
            new_p = new_p_flat.reshape(p.shape)
            new_opt = {"master": new_m.reshape(opt["master"].shape),
                       **{k: v.reshape(opt[k].shape) for k, v in new_st.items()}}
        else:
            new_m, new_st = optimizer.update(g, opt["master"],
                                             {k: v for k, v in opt.items() if k != "master"},
                                             step)
            new_p = new_m.astype(p.dtype)
            new_opt = {"master": new_m, **new_st}
        return new_p, new_opt

    flat_g, tdef = jax.tree.flatten(grads)
    flat_p = jax.tree.leaves(params_loc)
    flat_o = jax.tree.leaves(opt_loc, is_leaf=lambda x: isinstance(x, dict) and "master" in x)
    flat_s = jax.tree.leaves(syncs)
    outs = [one(g, p, o, s) for g, p, o, s in zip(flat_g, flat_p, flat_o, flat_s)]
    new_params = jax.tree.unflatten(tdef, [a for a, _ in outs])
    new_opt = jax.tree.unflatten(tdef, [b for _, b in outs])
    return new_params, new_opt


def make_train_step(
    cfg: ArchConfig,
    plan: PipelinePlan,
    mesh: Mesh,
    optimizer: Optimizer,
    shape: InputShape,
    *,
    bidirectional: bool = True,
    use_pallas: bool = False,
    donate: bool = True,
):
    """jit-able (params, opt_state, batch, step) -> (params, opt_state, metrics)."""
    has_pod = "pod" in mesh.axis_names
    param_specs = sharding.pipeline_param_specs(cfg, plan)
    _, opt_specs = opt_state_specs(cfg, plan, optimizer)
    b_specs = batch_pspecs(cfg, shape, plan)
    syncs = grad_sync_tree(cfg, plan)
    mask = sharding.layer_mask_array(cfg, plan)
    mask_spec = P("model", None, None)

    def device_fn(params, opt_state, batch, step_idx, mask_arr):
        params_loc = {
            k: (jax.tree.map(lambda a: a[0], v) if k == "layers" else v)
            for k, v in params.items()
        }

        # opt leaf-dicts: data_rs -> local [1,1,c] -> [c]; EP -> [1,pp,...] -> [pp,...]
        def unbox_opt(d, gs):
            if gs.data_rs:
                return {k: v.reshape(-1) for k, v in d.items()}
            return {k: v[0] for k, v in d.items()}

        opt_loc = jax.tree.map(unbox_opt, opt_state, syncs,
                               is_leaf=lambda x: isinstance(x, dict) and "master" in x)
        mask_loc = mask_arr[0]

        def loss_of(p):
            return pipeline_train_loss(
                cfg, plan, p, mask_loc, batch, has_pod=has_pod, use_pallas=use_pallas
            )

        (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(params_loc)
        new_params_loc, new_opt_loc = _apply_updates(
            cfg, plan, optimizer, grads, params_loc, opt_loc, syncs, step_idx,
            bidirectional=bidirectional, has_pod=has_pod,
        )
        # re-box
        new_params = {
            k: (jax.tree.map(lambda a: a[None], v) if k == "layers" else v)
            for k, v in new_params_loc.items()
        }

        def rebox_opt(new, gs):
            if gs.data_rs:
                return {k: v.reshape(1, 1, -1) for k, v in new.items()}
            return {k: v[None] for k, v in new.items()}

        new_opt = jax.tree.map(rebox_opt, new_opt_loc, syncs,
                               is_leaf=lambda x: isinstance(x, dict) and "master" in x)
        return new_params, new_opt, metrics

    smapped = jax.shard_map(
        device_fn,
        mesh=mesh,
        in_specs=(param_specs, opt_specs, b_specs, P(), mask_spec),
        out_specs=(param_specs, opt_specs, P()),
        check_vma=False,
    )

    def step(params, opt_state, batch, step_idx):
        return smapped(params, opt_state, batch, jnp.asarray(step_idx, jnp.int32), jnp.asarray(mask))

    donate_args = (0, 1) if donate else ()
    return jax.jit(step, donate_argnums=donate_args)


def make_train_state(cfg, plan, key, optimizer):
    """Concrete laid-out params + opt state (single-controller path)."""
    base = registry.init_params(cfg, key)
    params = sharding.to_pipeline_layout(cfg, plan, base)
    opt_state = init_opt_state(cfg, plan, optimizer, params)
    return params, opt_state
