"""Architecture / shape configuration system.

Every assigned architecture is a module ``src/repro/configs/<id>.py`` (dashes and
leading digits sanitized to underscores) exporting ``CONFIG: ArchConfig``.  The
registry in ``repro.configs`` maps the public ``--arch`` id strings to them.

Design notes (see DESIGN.md §4):
  * A model is a sequence of *period instances*.  Each period is a statically
    known list of ``LayerSpec`` (mixer kind + ff kind + attention window).  The
    pipeline scans over period instances, so heterogeneous families (jamba's
    mamba:attn 7:1, xlstm's sLSTM/mLSTM alternation) stay SPMD-uniform as long
    as every stage holds an integer number of periods.
  * ``stages``/``tensor`` give the default factorization of the 16-wide
    ``model`` mesh axis into (pipeline stages x tensor parallel); the TPU
    planner may override them.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence

# Mixer kinds.
ATTN = "attn"
MAMBA = "mamba"
SLSTM = "slstm"
MLSTM = "mlstm"

# FF kinds.
DENSE_FF = "dense"
MOE_FF = "moe"
NO_FF = "none"

GLOBAL_WINDOW = 0  # sentinel: full (global) attention


@dataclass(frozen=True)
class LayerSpec:
    """One layer inside the repeating period."""

    mixer: str = ATTN
    ff: str = DENSE_FF
    window: int = GLOBAL_WINDOW  # sliding-window size; 0 = full attention

    def __post_init__(self):
        assert self.mixer in (ATTN, MAMBA, SLSTM, MLSTM), self.mixer
        assert self.ff in (DENSE_FF, MOE_FF, NO_FF), self.ff


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class MambaCfg:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model


@dataclass(frozen=True)
class XLSTMCfg:
    # Projection factor of the mLSTM up-projection and sLSTM ffn.
    m_proj_factor: float = 2.0
    s_proj_factor: float = 4.0 / 3.0
    conv_kernel: int = 4


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    citation: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    period: Sequence[LayerSpec] = (LayerSpec(),)
    moe: Optional[MoECfg] = None
    mamba: Optional[MambaCfg] = None
    xlstm: Optional[XLSTMCfg] = None
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    causal: bool = True
    is_encoder: bool = False          # encoder-only (no decode shapes)
    frontend: str = "none"            # none | audio | vision
    n_frontend_tokens: int = 256      # vision: #patch embeddings prepended
    tie_embeddings: bool = False
    qk_norm: bool = False
    # Default mesh-axis factorization: stages * tensor == model axis size (16).
    stages: int = 16
    tensor: int = 1
    # dtype of params/activations on the target hardware
    param_dtype: str = "bfloat16"

    # ------------------------------------------------------------------ helpers
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def period_len(self) -> int:
        return len(self.period)

    @property
    def n_periods(self) -> int:
        """Number of period instances, rounded up.  When the layer count is not
        a multiple of the period (gemma3: 34 = 5x6 + 4) the trailing layers of
        the last period are masked to identity by the runtime (layer index >=
        n_layers)."""
        return -(-self.n_layers // self.period_len)

    def layer_spec(self, i: int) -> LayerSpec:
        return self.period[i % self.period_len]

    @property
    def uses_attention(self) -> bool:
        return any(s.mixer == ATTN for s in self.period)

    @property
    def subquadratic(self) -> bool:
        """True if a 500k-token decode context is feasible (no full O(L^2) attn
        with an unbounded KV cache on every layer)."""
        if all(s.mixer != ATTN for s in self.period):
            return True
        # windowed attention on most layers + a few globals is acceptable
        # (globals use data-axis-sharded KV); pure-global attn everywhere is not.
        n_attn = sum(1 for s in self.period if s.mixer == ATTN)
        n_global = sum(1 for s in self.period if s.mixer == ATTN and s.window == GLOBAL_WINDOW)
        return n_global < n_attn or n_attn * 4 <= len(self.period)

    def supports_shape(self, shape_name: str) -> bool:
        if self.is_encoder and shape_name in ("decode_32k", "long_500k"):
            return False
        if shape_name == "long_500k" and not self.subquadratic:
            return False
        return True

    # --------------------------------------------------------------- counting
    def param_count(self) -> int:
        """Approximate parameter count (embeddings + per-layer, excl. norms)."""
        d, hd = self.d_model, self.hd
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            spec = self.layer_spec(i)
            if spec.mixer == ATTN:
                q = d * self.n_heads * hd
                kv = 2 * d * self.n_kv_heads * hd
                o = self.n_heads * hd * d
                total += q + kv + o
            elif spec.mixer == MAMBA:
                mc = self.mamba or MambaCfg()
                di = mc.d_inner(d)
                total += d * 2 * di + di * mc.d_conv + di * (2 * mc.d_state + 2) + di * d
            elif spec.mixer in (SLSTM, MLSTM):
                xc = self.xlstm or XLSTMCfg()
                f = xc.m_proj_factor if spec.mixer == MLSTM else xc.s_proj_factor
                di = int(d * f)
                total += 2 * d * di + di * d + 4 * d * di  # up/gate/down + gates
            if spec.ff == DENSE_FF:
                total += 3 * d * self.d_ff
            elif spec.ff == MOE_FF:
                assert self.moe is not None
                total += self.moe.n_experts * 3 * d * self.moe.d_ff_expert + d * self.moe.n_experts
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        n_moe_layers = sum(
            1 for i in range(self.n_layers) if self.layer_spec(i).ff == MOE_FF
        )
        inactive = (
            n_moe_layers
            * (self.moe.n_experts - self.moe.top_k)
            * 3
            * d
            * self.moe.d_ff_expert
        )
        return full - inactive

    # ----------------------------------------------------------------- reduce
    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: <=2 periods, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = min(self.n_kv_heads, max(1, n_heads // 2))
        head_dim = d_model // n_heads
        moe = None
        if self.moe is not None:
            moe = replace(
                self.moe,
                n_experts=min(4, self.moe.n_experts),
                top_k=min(2, self.moe.top_k),
                d_ff_expert=min(self.moe.d_ff_expert, 2 * d_model),
            )
        # Dense families shrink to 2 layers; multi-kind families keep one full
        # period so every mixer/ff kind is exercised.
        n_layers = self.period_len * (2 if self.period_len == 1 else 1)
        period = tuple(
            replace(s, window=min(s.window, 64) if s.window else 0) for s in self.period
        )
        return replace(
            self,
            name=self.name + "-reduced",
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 4 * d_model) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 1024),
            moe=moe,
            period=period,
            stages=1,
            tensor=1,
            n_frontend_tokens=min(self.n_frontend_tokens, 16),
            param_dtype="float32",
        )


# --------------------------------------------------------------------- shapes
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def validate(cfg: ArchConfig) -> None:
    assert cfg.n_periods >= 1
    assert cfg.n_heads % cfg.n_kv_heads == 0 or cfg.n_kv_heads % cfg.n_heads == 0
    if any(s.ff == MOE_FF for s in cfg.period):
        assert cfg.moe is not None
    if any(s.mixer == MAMBA for s in cfg.period):
        assert cfg.mamba is not None
    if any(s.mixer in (SLSTM, MLSTM) for s in cfg.period):
        assert cfg.xlstm is not None
    assert 16 % cfg.stages == 0 and cfg.stages * cfg.tensor in (cfg.stages * cfg.tensor,)
