"""bert-large — the paper's own evaluation model (Table 1: 1153 MB params).

Used by the serverless substrate benchmarks (Fig 5/6/11) and as an encoder
smoke model.  [arXiv:1810.04805]
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="bert-large",
    family="audio",  # encoder-only pathway (masked prediction)
    citation="arXiv:1810.04805",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=30522,
    period=(LayerSpec(),),
    causal=False,
    is_encoder=True,
    frontend="none",
    stages=8,
    tensor=2,
)
