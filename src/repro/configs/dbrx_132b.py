"""dbrx-132b  [moe]  — 16 experts top-4, fine-grained  [hf:databricks/dbrx-base]"""
from repro.configs.base import ArchConfig, LayerSpec, MoECfg, MOE_FF

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    citation="hf:databricks/dbrx-base",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    period=(LayerSpec(ff=MOE_FF),),
    moe=MoECfg(n_experts=16, top_k=4, d_ff_expert=10752),
    rope_theta=500_000.0,
    stages=8,  # 40 layers -> 5 per stage; tensor=2 within stage
    tensor=2,
)
