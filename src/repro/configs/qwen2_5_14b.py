"""qwen2.5-14b  [dense]  — GQA with QKV bias  [hf:Qwen/Qwen2.5-0.5B]"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="qwen2.5-14b",
    family="dense",
    citation="hf:Qwen/Qwen2.5-0.5B",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab_size=152064,
    period=(LayerSpec(),),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    stages=16,  # 48 layers -> 3 per stage
    tensor=1,
)
