"""Registry of assigned architectures (public ``--arch`` ids) -> ArchConfig."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    ArchConfig,
    InputShape,
    INPUT_SHAPES,
    LayerSpec,
    MambaCfg,
    MoECfg,
    XLSTMCfg,
    validate,
)

# public id -> module name
_ARCH_MODULES = {
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "hubert-xlarge": "hubert_xlarge",
    "qwen2.5-14b": "qwen2_5_14b",
    "dbrx-132b": "dbrx_132b",
    "xlstm-125m": "xlstm_125m",
    "internlm2-20b": "internlm2_20b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "internvl2-26b": "internvl2_26b",
    "gemma3-4b": "gemma3_4b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    # the paper's own evaluation model (serverless benchmarks)
    "bert-large": "bert_large",
}

ARCH_IDS = [k for k in _ARCH_MODULES if k != "bert-large"]


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    cfg = mod.CONFIG
    validate(cfg)
    return cfg


def all_configs() -> dict:
    return {aid: get_config(aid) for aid in ARCH_IDS}
