"""internlm2-20b  [dense]  — GQA  [arXiv:2403.17297]"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="internlm2-20b",
    family="dense",
    citation="arXiv:2403.17297",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92544,
    period=(LayerSpec(),),
    rope_theta=1_000_000.0,
    stages=16,  # 48 layers -> 3 per stage
    tensor=1,
)
