"""xlstm-125m  [ssm]  — alternating sLSTM + mLSTM blocks  [arXiv:2405.04517]

d_ff=0: xLSTM blocks carry their own up-projections (mLSTM pre-up-projection
x2, sLSTM post-up-projection 4/3), so there is no separate FFN.
"""
from repro.configs.base import ArchConfig, LayerSpec, XLSTMCfg, MLSTM, SLSTM, NO_FF

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    citation="arXiv:2405.04517",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    period=(LayerSpec(mixer=MLSTM, ff=NO_FF), LayerSpec(mixer=SLSTM, ff=NO_FF)),
    xlstm=XLSTMCfg(),
    stages=2,  # 12 layers = 6 periods -> 3 periods per stage; tensor=8
    tensor=8,
)
