"""qwen3-moe-235b-a22b  [moe]  — 128 experts top-8  [hf:Qwen/Qwen3-30B-A3B]

94 layers pad to 96 = 16 stages x 6; the pipeline masks the 2 padding layers.
"""
from repro.configs.base import ArchConfig, LayerSpec, MoECfg, MOE_FF

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    citation="hf:Qwen/Qwen3-30B-A3B",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,  # expert FFN width (fine-grained experts)
    vocab_size=151936,
    period=(LayerSpec(ff=MOE_FF),),
    moe=MoECfg(n_experts=128, top_k=8, d_ff_expert=1536),
    qk_norm=True,
    rope_theta=1_000_000.0,
    stages=16,  # ceil(94/16)=6 per stage (2 masked padding layers)
    tensor=1,
)
