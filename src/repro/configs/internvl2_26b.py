"""internvl2-26b  [vlm]  — InternViT + InternLM2 backbone  [arXiv:2404.16821]

The InternViT vision encoder + MLP projector are a stub per the task carve-out:
``input_specs`` provides precomputed patch embeddings (batch, n_patches,
d_model) which the language model consumes in its first ``n_frontend_tokens``
positions.  This module is the InternLM2-20B language backbone (+9 vocab for
the VLM special tokens).
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    citation="arXiv:2404.16821",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    period=(LayerSpec(),),
    rope_theta=1_000_000.0,
    frontend="vision",
    n_frontend_tokens=256,
    stages=16,
    tensor=1,
)
