"""phi3-mini-3.8b  [dense]  — RoPE SwiGLU GQA  [arXiv:2404.14219]"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="phi3-mini-3.8b",
    family="dense",
    citation="arXiv:2404.14219",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    period=(LayerSpec(),),
    rope_theta=10_000.0,
    stages=16,  # 32 layers -> 2 per stage
    tensor=1,
)
