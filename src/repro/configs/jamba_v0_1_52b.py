"""jamba-v0.1-52b  [hybrid]  — Mamba+attn 1:7 interleave, MoE 16e top-2  [arXiv:2403.19887]

Period of 8 layers: attention at index 4 (1:7 attn:mamba), MoE FFN on every
other layer (odd indices), dense FFN elsewhere — the Jamba block layout.
"""
from repro.configs.base import (
    ArchConfig,
    LayerSpec,
    MambaCfg,
    MoECfg,
    ATTN,
    MAMBA,
    DENSE_FF,
    MOE_FF,
)


def _layer(i: int) -> LayerSpec:
    mixer = ATTN if i == 4 else MAMBA
    ff = MOE_FF if i % 2 == 1 else DENSE_FF
    return LayerSpec(mixer=mixer, ff=ff)


CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    citation="arXiv:2403.19887",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    period=tuple(_layer(i) for i in range(8)),
    moe=MoECfg(n_experts=16, top_k=2, d_ff_expert=14336),
    mamba=MambaCfg(d_state=16, d_conv=4, expand=2),
    stages=4,  # 4 periods of 8 -> 1 period per stage; tensor=4
    tensor=4,
)
