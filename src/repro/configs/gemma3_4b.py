"""gemma3-4b  [dense]  — 5:1 local:global attention, 128k ctx  [hf:google/gemma-3-1b-pt]

Period of 6: five sliding-window (1024) layers then one global layer.  The
sliding window makes ``long_500k`` feasible: local layers keep a rolling
window cache; the 1-in-6 global layers shard their 500k KV over the data axis
with partial-softmax combination.
"""
from repro.configs.base import ArchConfig, LayerSpec, GLOBAL_WINDOW

LOCAL = LayerSpec(window=1024)
GLOBAL = LayerSpec(window=GLOBAL_WINDOW)

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    citation="hf:google/gemma-3-1b-pt",
    n_layers=34,  # 5 full periods of 6 + a truncated one (runtime masks layers >= 34)
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    period=(LOCAL, LOCAL, LOCAL, LOCAL, LOCAL, GLOBAL),
    qk_norm=True,
    rope_theta=1_000_000.0,
    stages=2,  # 6 periods -> 3 periods/stage; tensor=8
    tensor=8,
)
