"""hubert-xlarge  [audio]  — encoder-only transformer backbone [arXiv:2106.07447]

The conv/mel frontend is a stub per the task carve-out: ``input_specs`` provides
precomputed frame embeddings of shape (batch, seq, d_model); the model here is
the transformer encoder trained with masked-prediction CE over the 504-unit
codebook.  Encoder-only => no decode shapes.
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    citation="arXiv:2106.07447",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    period=(LayerSpec(),),
    causal=False,
    is_encoder=True,
    frontend="audio",
    stages=16,  # 48 layers -> 3 per stage
    tensor=1,
)
