"""Serverless inference serving (the training pipeline, turned around).

The subsystem reuses FuncPipe's machinery for a serving objective:

* :mod:`repro.serving.planner` — SLO-aware partition + memory search
  ($/1k-requests objective, per-request latency constraint, KV-cache bytes
  in the memory constraint), recorded as ``workload="serve"``
  :class:`~repro.api.DeploymentPlan`\\ s;
* :mod:`repro.serving.engine` — pipelined prefill + token-by-token decode
  as worker programs on the execution backends (emulated virtual clocks or
  real OS processes over the file store), KV caches persisted per stage in
  the object store, tokens bit-identical to the monolithic decode loop;
* :mod:`repro.serving.autoscale` — seeded bursty-arrival simulation of the
  plan across replica counts (p50/p95/p99, SLO violations, cold starts,
  cost).

Front doors: ``Session.plan(workload="serve")``, the ``repro serve`` CLI,
and ``benchmarks/serving_bench.py``.
"""
from repro.serving.autoscale import (
    AutoscaleRow,
    autoscale_plan,
    bursty_arrivals,
    poisson_arrivals,
    simulate_replicas,
    trace_arrivals,
)
from repro.serving.cost import (
    ServingEstimate,
    ServingSpec,
    arch_config_for_model,
    estimate_serving,
    kv_bytes_per_instance,
)
from repro.serving.engine import (
    SERVE_BACKENDS,
    ServeResult,
    make_prompt,
    reference_decode,
    run_serve_plan,
    serve_worker_program,
)
from repro.serving.planner import (
    InfeasibleSLOError,
    ServingSolution,
    plan_serving,
    solve_serving,
)
from repro.serving.worker import ServeStageWorker, greedy_token

__all__ = [
    "AutoscaleRow",
    "InfeasibleSLOError",
    "SERVE_BACKENDS",
    "ServeResult",
    "ServeStageWorker",
    "ServingEstimate",
    "ServingSolution",
    "ServingSpec",
    "arch_config_for_model",
    "autoscale_plan",
    "bursty_arrivals",
    "estimate_serving",
    "greedy_token",
    "kv_bytes_per_instance",
    "make_prompt",
    "plan_serving",
    "poisson_arrivals",
    "reference_decode",
    "run_serve_plan",
    "serve_worker_program",
    "simulate_replicas",
    "solve_serving",
    "trace_arrivals",
]
