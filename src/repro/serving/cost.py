"""Serving cost model: per-request prefill + decode latency and $/1k
requests for a partitioned pipeline on a serverless platform.

Training plans amortize boundary transfers over ``mu`` micro-batches per
step; a serving request is one prefill pass (seq = prompt length) followed
by ``new_tokens - 1`` single-token pipeline rounds, each of which must round-
trip the stage's KV cache through the object store (serverless functions are
stateless between invocations — the cache *is* store traffic, which is what
makes the decode cost model different from simply scaling the training one).

All per-stage terms reuse :func:`repro.serverless.simulator.stage_aggregates`
built from a profile at ``seq = prefill_tokens`` / ``micro_batch = batch``,
so compute times, bandwidths and memory options come from exactly the tables
the training planner charges.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple

import numpy as np

from repro.core.perfmodel import Config
from repro.serverless.platform import GB, Platform
from repro.serverless.simulator import stage_aggregates

#: greedy-token feedback object: int32 [B, 1]
TOKEN_BYTES = 4


def arch_config_for_model(model: str):
    """ArchConfig for a serving model id.

    Mirrors ``repro.core.profiler.resolve_profile``'s spelling — arch ids
    plus the ``<arch>@reduced[<n_layers>]`` reduced form — but *rejects* the
    paper's Table 1 models: they are analytic layer tables with no runnable
    layers, and serving needs executable prefill/decode math.
    """
    from repro.configs import ARCH_IDS, get_config

    base, _, spec = model.partition("@")
    if base not in ARCH_IDS or (spec and not spec.startswith("reduced")):
        raise KeyError(
            f"serving needs an executable architecture; {model!r} is not an "
            "arch id (paper Table 1 models are analytic-only). Use an arch "
            "id, optionally reduced: '<arch>@reduced[<n_layers>]'")
    cfg = get_config(base)
    if spec:
        cfg = cfg.reduced()
        depth = spec[len("reduced"):]
        if depth:
            try:
                cfg = dataclasses.replace(cfg, n_layers=int(depth))
            except ValueError:
                raise KeyError(
                    f"malformed reduced-arch spec {model!r}: depth "
                    f"{depth!r} is not an integer") from None
    return cfg


@dataclass(frozen=True)
class ServingSpec:
    """One serving workload: SLO + request shape."""

    slo_s: float            # per-request latency objective
    batch: int              # requests decoded together
    prefill_tokens: int     # prompt length
    new_tokens: int         # tokens generated per request (incl. the
                            # prefill's first token)

    def __post_init__(self):
        if self.slo_s <= 0:
            raise ValueError(f"slo_s must be positive, got {self.slo_s}")
        if self.batch < 1 or self.prefill_tokens < 1 or self.new_tokens < 1:
            raise ValueError(
                "batch, prefill_tokens and new_tokens must all be >= 1 "
                f"(got {self.batch}, {self.prefill_tokens}, "
                f"{self.new_tokens})")

    @property
    def s_ctx(self) -> int:
        """KV-cache capacity: prompt + every generated token."""
        return self.prefill_tokens + self.new_tokens

    def as_dict(self) -> dict:
        return {"slo_s": self.slo_s, "batch": self.batch,
                "prefill_tokens": self.prefill_tokens,
                "new_tokens": self.new_tokens, "context": self.s_ctx}


def kv_bytes_per_instance(cfg, batch: int, s_ctx: int) -> float:
    """Decode-cache bytes of ONE period instance (shapes only, no allocs)."""
    import jax

    from repro.models import registry

    caches = jax.eval_shape(
        lambda: registry.init_decode_caches(cfg, batch, s_ctx))
    total = 0.0
    for leaf in jax.tree.leaves(caches):
        # leaves are stacked [n_periods, ...]; charge one instance
        total += float(np.prod(leaf.shape[1:]) * np.dtype(leaf.dtype).itemsize)
    return total


@dataclass(frozen=True)
class ServingEstimate:
    """Closed-form per-request latency/cost of one partition + memory
    assignment (the serving planner's objective terms)."""

    t_prefill: float                 # prompt pass through the pipeline
    t_token: float                   # one decode pipeline round
    t_request: float                 # t_prefill + (new_tokens-1) * t_token
    cost_per_request: float          # $ (all stages occupied for t_request)
    cost_per_1k: float
    kv_bytes: Tuple[float, ...]      # [S] per-stage decode-cache bytes
    mem: Tuple[float, ...]           # [S] allocated function memory (bytes)
    t_prefill_stage: Tuple[float, ...]   # [S] per-stage prefill compute
    t_decode_stage: Tuple[float, ...]    # [S] per-stage decode compute


def estimate_serving(profile, platform: Platform, config: Config, cfg,
                     spec: ServingSpec) -> ServingEstimate:
    """Per-request latency and cost of serving ``spec`` on ``config``.

    ``profile`` must have been built at ``seq = spec.prefill_tokens`` and
    ``micro_batch = spec.batch`` so the aggregates' compute/boundary terms
    describe the prompt pass; decode terms are derived per token from them.
    """
    from repro.serverless.runtime.worker import stage_instance_ranges

    agg = stage_aggregates(profile, platform, config, 1)
    S = agg.S
    S_pre = spec.prefill_tokens
    t_lat = agg.t_lat
    w = agg.w

    # ---- prefill: one prompt flows through the pipeline depth-first
    t_prefill = float(np.sum(agg.t_fc))
    for s in range(S - 1):
        t_prefill += agg.out_b[s] / w[s] + t_lat          # producer uplink
        t_prefill += agg.out_b[s] / w[s + 1] + t_lat      # consumer downlink

    # ---- decode: compute and boundary scale to a single token
    t_dec = agg.t_fc / S_pre
    tok_b = agg.out_b / S_pre                             # [B, 1, d] hidden
    per_inst = kv_bytes_per_instance(cfg, spec.batch, spec.s_ctx)
    spans = stage_instance_ranges(cfg, config.x)
    kv_b = tuple(float((sp.inst_hi - sp.inst_lo) * per_inst) for sp in spans)

    t_token = 0.0
    for s in range(S):
        t_token += float(t_dec[s])
        if kv_b[s]:
            # stateless functions: the KV cache round-trips the store
            t_token += 2.0 * (kv_b[s] / w[s] + t_lat)
        if s < S - 1:
            t_token += tok_b[s] / w[s] + t_lat
            t_token += tok_b[s] / w[s + 1] + t_lat
    # greedy-token feedback: last stage -> store -> stage 0
    fb = float(spec.batch * TOKEN_BYTES)
    t_token += fb / w[S - 1] + t_lat + fb / w[0] + t_lat

    t_request = t_prefill + (spec.new_tokens - 1) * t_token
    cost = float(platform.price_per_gb_s
                 * (np.sum(agg.mem) / GB) * t_request)
    return ServingEstimate(
        t_prefill=float(t_prefill), t_token=float(t_token),
        t_request=float(t_request), cost_per_request=cost,
        cost_per_1k=1000.0 * cost, kv_bytes=kv_b,
        mem=tuple(float(m) for m in agg.mem),
        t_prefill_stage=tuple(float(t) for t in agg.t_fc),
        t_decode_stage=tuple(float(t) for t in t_dec),
    )
