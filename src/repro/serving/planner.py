"""SLO-aware serving planner: choose partition + memory sizes minimizing
$/1k-requests subject to a per-request latency SLO.

The search mirrors the training planner's grid engine — enumerate layer
partitions, derive a per-stage memory floor, then refine with one
first-improvement coordinate-descent sweep — but the objective and the
constraints are serving's:

* latency = prefill pass + ``(new_tokens - 1)`` decode pipeline rounds, each
  round-tripping stage KV caches through the store (``serving.cost``);
* the per-stage memory constraint gains the stage's KV-cache bytes;
* partitions must cut on period boundaries (``stage_instance_ranges``) —
  serving stages run real prefill/decode math, not analytic tables.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.api.session import InfeasiblePlanError
from repro.core.partition import stages_of
from repro.core.perfmodel import Config, perf_tables
from repro.core.planner import _expand_z, _partitions
from repro.core.profiler import resolve_profile
from repro.serverless.platform import Platform, get_platform
from repro.serving.cost import (
    ServingEstimate,
    ServingSpec,
    arch_config_for_model,
    estimate_serving,
    kv_bytes_per_instance,
)


class InfeasibleSLOError(InfeasiblePlanError):
    """No partition/memory assignment meets the serving SLO (or fits in
    the platform's memory options at all)."""


@dataclass(frozen=True)
class ServingSolution:
    model: str
    config: Config
    estimate: ServingEstimate
    spec: ServingSpec
    profile: object                 # ModelProfile the config indexes into
    platform: Platform
    n_candidates: int               # period-aligned partitions examined
    n_feasible: int                 # configs meeting memory + SLO
    solve_seconds: float


def solve_serving(model: str, platform, spec: ServingSpec, *,
                  max_stages: Optional[int] = None) -> ServingSolution:
    """Grid + coordinate-descent search over (partition, stage memory)."""
    t_start = time.monotonic()
    if isinstance(platform, str):
        platform = get_platform(platform)
    cfg = arch_config_for_model(model)
    profile = resolve_profile(model, platform, seq=spec.prefill_tokens,
                              micro_batch=spec.batch)
    from repro.serverless.runtime.worker import stage_instance_ranges

    T = perf_tables(profile, platform)
    L, J = T.L, T.J
    per_inst = kv_bytes_per_instance(cfg, spec.batch, spec.s_ctx)

    best: Optional[Tuple[Config, ServingEstimate]] = None
    fastest: Optional[Tuple[Config, ServingEstimate]] = None
    n_cand = 0
    n_feas = 0

    def consider(x, stage_mem):
        nonlocal best, fastest, n_feas
        config = Config(x=tuple(x), d=1, z=_expand_z(stage_mem, x, L))
        est = estimate_serving(profile, platform, config, cfg, spec)
        if fastest is None or est.t_request < fastest[1].t_request:
            fastest = (config, est)
        if est.t_request <= spec.slo_s:
            n_feas += 1
            if best is None or (est.cost_per_1k, est.t_request) < (
                    best[1].cost_per_1k, best[1].t_request):
                best = (config, est)
        return est

    for bits in _partitions(L, max_stages):
        try:
            spans = stage_instance_ranges(cfg, bits)
        except ValueError:
            continue                # mid-period cut: not executable
        n_cand += 1
        stages = stages_of(bits)
        los = np.array([lo for lo, _ in stages])
        a_stage = np.add.reduceat(T.a, los)
        s_stage = np.add.reduceat(T.s, los)
        kv = np.array([(sp.inst_hi - sp.inst_lo) * per_inst for sp in spans])
        need = a_stage + s_stage + kv + T.base_memory
        floors = np.searchsorted(T.mem_opts, need)
        if np.any(floors >= J):
            continue                # some stage fits in no memory option
        # candidate stage-memory assignments: the floor, then every uniform
        # level clamped up to it (more memory = more vCPU = lower latency)
        seen = set()
        floor_t = tuple(int(f) for f in floors)
        for lvl in range(int(floors.max()), J):
            cand = tuple(max(lvl, f) for f in floor_t)
            if cand not in seen:
                seen.add(cand)
                consider(bits, cand)
        if floor_t not in seen:
            consider(bits, floor_t)

    # one first-improvement coordinate-descent sweep from the winner
    if best is not None:
        config, est = best
        stage_mem = [config.z[lo] for lo, _ in stages_of(config.x)]
        stages = stages_of(config.x)
        for si in range(len(stage_mem)):
            for j in range(J):
                if j == stage_mem[si]:
                    continue
                trial = list(stage_mem)
                trial[si] = j
                e = consider(config.x, tuple(trial))
                if best[1] is e:
                    stage_mem = trial
                    est = e
                    break

    if best is None:
        if fastest is None:
            raise InfeasibleSLOError(
                f"no period-aligned partition of {model!r} fits the memory "
                f"options of {platform.name} (largest option "
                f"{T.mem_opts[-1] / 2**20:.0f} MB) at batch={spec.batch}, "
                f"context={spec.s_ctx}")
        raise InfeasibleSLOError(
            f"no partition of {model!r} on {platform.name} meets the "
            f"{spec.slo_s:.3f}s SLO: best achievable request latency is "
            f"{fastest[1].t_request:.3f}s "
            f"({len(stages_of(fastest[0].x))} stages, "
            f"{spec.new_tokens} tokens); relax the SLO, shrink the token "
            "budget, or pick a smaller model")

    return ServingSolution(
        model=model, config=best[0], estimate=best[1], spec=spec,
        profile=profile, platform=platform, n_candidates=n_cand,
        n_feasible=n_feas, solve_seconds=time.monotonic() - t_start)


def plan_serving(model: str, platform, *, slo: float, batch: int = 1,
                 prefill_tokens: int = 64, new_tokens: int = 8,
                 max_stages: Optional[int] = None):
    """Solve the serving problem and record it as a ``workload="serve"``
    :class:`repro.api.DeploymentPlan` (the ``repro serve`` front door)."""
    from repro.api.plan import DeploymentPlan, profile_fingerprint

    spec = ServingSpec(slo_s=float(slo), batch=int(batch),
                       prefill_tokens=int(prefill_tokens),
                       new_tokens=int(new_tokens))
    sol = solve_serving(model, platform, spec, max_stages=max_stages)
    est = sol.estimate
    return DeploymentPlan(
        model=model,
        platform=sol.platform.name,
        x=tuple(sol.config.x),
        d=1,
        z=tuple(sol.config.z),
        total_micro_batches=1,
        pipelined_sync=False,
        alpha=(1.0, 0.0),
        profile_fingerprint=profile_fingerprint(sol.profile, sol.platform),
        t_iter=est.t_request,
        c_iter=est.cost_per_request,
        objective=est.cost_per_request,
        solver="serve-grid",
        engine="serve",
        solve_seconds=sol.solve_seconds,
        merge_to=None,
        seq=spec.prefill_tokens,
        micro_batch=spec.batch,
        profile_source=getattr(sol.profile, "source", "analytic"),
        workload="serve",
        serving={
            **spec.as_dict(),
            "t_prefill": est.t_prefill,
            "t_token": est.t_token,
            "t_request": est.t_request,
            "cost_per_request": est.cost_per_request,
            "cost_per_1k": est.cost_per_1k,
            "kv_bytes": list(est.kv_bytes),
            "n_candidates": sol.n_candidates,
            "n_feasible": sol.n_feasible,
        },
    )
