"""Pipelined serving on the execution backends: partitioned prefill +
token-by-token decode as worker programs over the object store.

Each stage runs one :func:`serve_worker_program` generator over its
:class:`~repro.serverless.backends.base.WorkerContext`:

* **prefill** — download the upstream hidden state (``serve/p/act{s-1}``),
  run the stage's prefill, publish the boundary (``serve/p/act{s}``) and the
  stage's decode caches (``kv/s{s}``); the head stage emits token 0 and
  feeds it back (``serve/tok/t0``).
* **decode round t** — download the stage KV (``kv/s{s}``) and the input
  (the fed-back token on stage 0, ``serve/dec/t{t}/act{s-1}`` upstream
  hidden elsewhere), run one decode step, re-publish the KV, forward the
  boundary; the head stage emits token t.

Serverless functions are stateless between invocations, so the KV cache
*is* store traffic — every decode round round-trips it, which is exactly
what the serving planner's cost model charges.  Token ids are bit-identical
to the monolithic ``registry.prefill`` + ``registry.decode_step`` loop on
every backend (``tests/test_serving.py``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.cost import ServingSpec, arch_config_for_model, estimate_serving
from repro.serving.worker import ServeStageWorker, greedy_token

SERVE_BACKENDS = ("emulated", "process")


def _after(*deps):
    """Combine dependency tokens: the latest virtual-clock time on the
    emulated backend (floats), None on wall-clock backends (blocking order
    already happened inside ``download``)."""
    real = [d for d in deps if d is not None]
    return max(real) if real else None


@dataclass(frozen=True)
class ServeResult:
    """One pipelined serving request, executed."""

    tokens: np.ndarray              # [B, new_tokens] int32 greedy tokens
    t_request: float                # backend-clock request latency (s)
    cost_per_request: float         # $ (stage memory occupied for t_request)
    cost_per_1k: float
    backend: str
    store_stats: Any                # runtime.store.StoreStats
    kv_bytes: Tuple[float, ...]     # [S] modeled per-stage KV-cache bytes
    trace: Optional[Any] = None     # repro.obs.Trace when tracing


def serve_worker_program(ctx, *, s: int, S: int, worker: ServeStageWorker,
                         toks: np.ndarray, n_new: int,
                         t_prefill=None, t_decode=None,
                         sink: Optional[List[np.ndarray]] = None,
                         on_decode=None):
    """Stage ``s``'s serving program; yields once per pipeline round.

    ``t_prefill``/``t_decode`` are per-stage compute costs for virtual-clock
    backends (ignored by wall-clock ones).  The head stage appends each
    greedy token ([B, 1] int32) to ``sink``.  ``on_decode`` fires once when
    the program leaves prefill (wall-clock tracers flip their phase there;
    the emulated driver uses the recorder instead).
    """
    tp = 0.0 if t_prefill is None else float(t_prefill[s])
    td = 0.0 if t_decode is None else float(t_decode[s])

    # ------------------------------------------------------------- prefill
    if s == 0:
        x_in, dep = toks, None
    else:
        x_in, dep = ctx.download(f"serve/p/act{s - 1}")
    out, caches = ctx.compute(tp, lambda: worker.prefill(x_in), after=dep)
    kv_nbytes = 0.0
    if worker.has_layers:
        import jax

        kv_nbytes = float(sum(leaf.nbytes
                              for leaf in jax.tree.leaves(caches)))
    if s < S - 1:
        ctx.upload(f"serve/p/act{s}", float(out.nbytes), out)
    else:
        tok = greedy_token(out)
        if sink is not None:
            sink.append(tok)
        if n_new > 1:
            ctx.upload("serve/tok/t0", float(tok.nbytes), tok)
    if worker.has_layers:
        ctx.upload(f"kv/s{s}", kv_nbytes, caches)
    yield

    # -------------------------------------------------------- decode rounds
    if on_decode is not None and n_new > 1:
        on_decode()
    for t in range(1, n_new):
        if worker.has_layers:
            caches, dep_kv = ctx.download(f"kv/s{s}")
        else:
            caches, dep_kv = None, None
        if s == 0:
            x_in, dep_in = ctx.download(f"serve/tok/t{t - 1}")
        else:
            x_in, dep_in = ctx.download(f"serve/dec/t{t}/act{s - 1}")
        out, caches = ctx.compute(
            td, lambda c=caches, x=x_in: worker.decode(c, x),
            after=_after(dep_kv, dep_in))
        if worker.has_layers:
            ctx.upload(f"kv/s{s}", kv_nbytes, caches)
        if s < S - 1:
            ctx.upload(f"serve/dec/t{t}/act{s}", float(out.nbytes), out)
        else:
            tok = greedy_token(out)
            if sink is not None:
                sink.append(tok)
            if t < n_new - 1:
                ctx.upload(f"serve/tok/t{t}", float(tok.nbytes), tok)
        yield


def _spec_from_plan(plan) -> ServingSpec:
    sv = plan.serving or {}
    return ServingSpec(slo_s=sv["slo_s"], batch=sv["batch"],
                       prefill_tokens=sv["prefill_tokens"],
                       new_tokens=sv["new_tokens"])


def make_prompt(cfg, batch: int, prefill_tokens: int, *,
                seed: int = 0) -> np.ndarray:
    """Deterministic prompt token ids [batch, prefill_tokens] int32."""
    import jax

    key = jax.random.fold_in(jax.random.PRNGKey(seed), 1)
    toks = jax.random.randint(key, (batch, prefill_tokens), 0,
                              cfg.vocab_size, dtype=np.int32)
    return np.asarray(toks)


def run_serve_plan(plan, *, backend: str = "emulated", seed: int = 0,
                   prompt: Optional[np.ndarray] = None, trace: bool = False,
                   use_pallas: bool = False, root: Optional[str] = None,
                   payload_true: bool = True,
                   throttle: bool = False) -> ServeResult:
    """Execute a ``workload="serve"`` plan end to end on a backend.

    ``"emulated"`` charges the serving cost model on per-stage virtual
    clocks (deterministic latency/cost); ``"process"`` runs each stage as a
    real OS process over the file store and reports wall-clock latency
    (cold jit compiles included — it is a parity/chaos vehicle, not a
    latency oracle).  Token ids are bit-identical across backends and to
    the monolithic decode loop.
    """
    from repro.api.plan import PlanCompatibilityError
    from repro.models import registry
    from repro.serverless.platform import GB
    from repro.serverless.runtime.worker import stage_instance_ranges
    from repro.serverless.simulator import stage_aggregates

    if getattr(plan, "workload", "train") != "serve":
        raise PlanCompatibilityError(
            "run_serve_plan executes serving plans; this plan for "
            f"{plan.model!r} has workload={plan.workload!r}. Train it "
            "through DeploymentPlan.emulate()/repro emulate instead.")
    if backend not in SERVE_BACKENDS:
        raise ValueError(
            f"unknown serving backend {backend!r}; supported: "
            f"{SERVE_BACKENDS}")

    import jax

    rp = plan.resolve()
    cfg = arch_config_for_model(plan.model)
    spec = _spec_from_plan(plan)
    est = estimate_serving(rp.profile, rp.platform, rp.config, cfg, spec)
    agg = stage_aggregates(rp.profile, rp.platform, rp.config, 1)
    ranges = stage_instance_ranges(cfg, plan.x)
    S = len(ranges)
    params = registry.init_params(cfg, jax.random.PRNGKey(seed))
    toks = (np.asarray(prompt, dtype=np.int32) if prompt is not None
            else make_prompt(cfg, spec.batch, spec.prefill_tokens, seed=seed))
    if toks.shape != (spec.batch, spec.prefill_tokens):
        raise ValueError(
            f"prompt shape {toks.shape} != plan's request shape "
            f"({spec.batch}, {spec.prefill_tokens})")

    rec = None
    if trace:
        from repro.obs import SpanRecorder

        rec = SpanRecorder()

    if backend == "emulated":
        from repro.serverless.backends.emulated import EmulatedBackend

        be = EmulatedBackend()
        if rec is not None:
            be.attach_recorder(rec)
        be.open(agg)
        try:
            workers = [ServeStageWorker(cfg, ranges[s], params,
                                        s_ctx=spec.s_ctx,
                                        use_pallas=use_pallas)
                       for s in range(S)]
            sink: List[np.ndarray] = []
            programs = [serve_worker_program(
                be.context(s, 0), s=s, S=S, worker=workers[s], toks=toks,
                n_new=spec.new_tokens, t_prefill=est.t_prefill_stage,
                t_decode=est.t_decode_stage,
                sink=sink if s == S - 1 else None) for s in range(S)]
            if rec is not None:
                rec.set_step(0)
                rec.set_phase("prefill")
            for s in range(S):          # producers before consumers
                next(programs[s])
            for t in range(1, spec.new_tokens):
                if rec is not None:
                    rec.set_phase("decode")
                for s in range(S):
                    next(programs[s])
            for p in programs:
                p.close()
            tokens = np.hstack(sink)
            t_request = max(float(be.channels[s][0].now) for s in range(S))
            for s in range(S):
                if workers[s].has_layers:
                    be.delete(f"kv/s{s}")
            be.verify_drained()
            stats = be.store_stats
        finally:
            be.close()
    else:
        from repro.serverless.backends.process import ProcessBackend

        be = ProcessBackend(root=root, payload_true=payload_true,
                            throttle=throttle)
        if rec is not None:
            be.attach_recorder(rec)
        be.open(agg)
        try:
            spec_doc = {
                "cfg": cfg, "x": tuple(plan.x),
                "params": jax.tree.map(np.asarray, params),
                "toks": toks, "n_new": spec.new_tokens,
                "s_ctx": spec.s_ctx, "use_pallas": bool(use_pallas),
            }
            wall0 = time.monotonic()
            sink = be.serve(spec_doc)
            t_request = time.monotonic() - wall0
            tokens = np.hstack([np.asarray(t) for t in sink])
            for s in range(S):
                if ranges[s].inst_hi > ranges[s].inst_lo:
                    be.delete(f"kv/s{s}")
            be.verify_drained()
            stats = be.store_stats
        finally:
            be.close()

    price = rp.platform.price_per_gb_s
    cost = float(price * (np.sum(agg.mem) / GB) * t_request)
    tr = None
    if rec is not None:
        from repro.obs import Trace

        tr = Trace(spans=rec.spans,
                   meta={"plan": plan._as_dict(), "backend": backend,
                         "workload": "serve", "model": plan.model,
                         "clock": ("wall" if backend == "process"
                                   else "virtual"),
                         "t_request": t_request, "t_total": t_request,
                         "steps": 1, "d": 1, "S": S,
                         "store": stats.as_dict()})
    return ServeResult(
        tokens=tokens, t_request=float(t_request),
        cost_per_request=cost, cost_per_1k=1000.0 * cost,
        backend=backend, store_stats=stats,
        kv_bytes=est.kv_bytes, trace=tr)


def reference_decode(cfg, params, toks: np.ndarray, n_new: int, *,
                     s_ctx: Optional[int] = None) -> np.ndarray:
    """Monolithic greedy loop (the parity oracle): ``registry.prefill`` +
    ``registry.decode_step`` on one worker, same sampling rule."""
    import jax
    import jax.numpy as jnp

    from repro.models import registry

    if s_ctx is None:
        s_ctx = toks.shape[1] + n_new
    logits, caches = registry.prefill(cfg, params, {"tokens": jnp.asarray(toks)},
                                      capacity=s_ctx)
    out = [greedy_token(logits)]
    for _ in range(1, n_new):
        logits, caches = registry.decode_step(
            cfg, params, caches, jnp.asarray(out[-1]))
        out.append(greedy_token(logits))
    return np.hstack(out)
