"""Autoscaling simulator: a serving plan under bursty arrival traces.

Composes the per-request latency the serving cost model predicts (one
pipeline replica serves one request at a time for ``t_request`` seconds)
with seeded arrival processes, and reports the latency distribution,
SLO-violation fraction, cold starts and cost as the replica count scales —
the capacity-planning table next to the SLO-aware partition choice.

Everything is deterministic under a fixed seed (``np.random.default_rng``);
``benchmarks/serving_bench.py`` gates on byte-identical rows across runs.

Model notes (documented simplifications):

* a replica is one full pipeline (all stages); it serves requests FIFO with
  no cross-request pipelining — ``t_request`` of busy time per request;
* arrivals are dispatched to the earliest-free replica (central queue);
* the first request on each replica pays a cold-start penalty (function
  spawn + model fetch), after which the replica is warm for the trace.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from repro.serverless.platform import GB

#: default function cold start: spawn + runtime init + weight fetch (s).
#: FuncPipe's platforms report O(seconds) cold starts for GB-scale images.
DEFAULT_COLD_START_S = 2.0


def poisson_arrivals(rate: float, horizon: float, *, seed: int = 0) -> np.ndarray:
    """Arrival times of a Poisson process with ``rate`` req/s over
    ``[0, horizon)`` — seeded, deterministic."""
    if rate <= 0:
        raise ValueError(f"rate must be positive, got {rate}")
    rng = np.random.default_rng(seed)
    # draw enough exponential gaps to cover the horizon, then trim
    n = max(16, int(rate * horizon * 2) + 16)
    t = np.cumsum(rng.exponential(1.0 / rate, size=n))
    while t[-1] < horizon:
        t = np.concatenate([t, t[-1] + np.cumsum(
            rng.exponential(1.0 / rate, size=n))])
    return t[t < horizon]


def bursty_arrivals(rate: float, horizon: float, *, burst_factor: float = 4.0,
                    burst_fraction: float = 0.2, period: float = 60.0,
                    seed: int = 0) -> np.ndarray:
    """Two-phase modulated Poisson: each ``period``, a ``burst_fraction``
    window runs at ``burst_factor * rate`` and the remainder at a reduced
    base rate keeping the same average — the diurnal-burst shape of
    production function traces (Alibaba trace analyses), seeded."""
    if not 0.0 < burst_fraction < 1.0:
        raise ValueError(f"burst_fraction in (0,1), got {burst_fraction}")
    base = rate * (1 - burst_factor * burst_fraction) / (1 - burst_fraction)
    base = max(base, rate * 0.05)
    out = []
    n_periods = int(np.ceil(horizon / period))
    for i in range(n_periods):
        t0 = i * period
        burst_end = t0 + burst_fraction * period
        out.append(t0 + poisson_arrivals(
            burst_factor * rate, burst_fraction * period, seed=seed + 2 * i))
        out.append(burst_end + poisson_arrivals(
            base, (1 - burst_fraction) * period, seed=seed + 2 * i + 1))
    t = np.sort(np.concatenate(out))
    return t[t < horizon]


def trace_arrivals(path: str) -> np.ndarray:
    """Arrival times from a trace file: one inter-arrival gap (seconds) per
    line (comments/#-lines skipped) — the hook for replaying production
    request logs through the same simulator."""
    gaps = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            gaps.append(float(line))
    if not gaps:
        raise ValueError(f"trace file {path!r} has no inter-arrival gaps")
    return np.cumsum(np.asarray(gaps, dtype=np.float64))


@dataclass(frozen=True)
class AutoscaleRow:
    """One replica-count operating point."""

    replicas: int
    requests: int
    p50: float
    p95: float
    p99: float
    slo_violation_frac: float
    cold_starts: int
    cost: float                    # $ for the whole trace (busy-time billed)
    cost_per_1k: float
    utilization: float             # busy time / (replicas * horizon)

    def as_dict(self) -> dict:
        return {
            "replicas": self.replicas, "requests": self.requests,
            "p50": self.p50, "p95": self.p95, "p99": self.p99,
            "slo_violation_frac": self.slo_violation_frac,
            "cold_starts": self.cold_starts, "cost": self.cost,
            "cost_per_1k": self.cost_per_1k,
            "utilization": self.utilization,
        }


def simulate_replicas(arrivals: np.ndarray, *, replicas: int,
                      t_request: float, slo_s: float, mem_gb_total: float,
                      price_per_gb_s: float,
                      cold_start_s: float = DEFAULT_COLD_START_S) -> AutoscaleRow:
    """Queue one arrival trace onto ``replicas`` pipeline replicas."""
    if replicas < 1:
        raise ValueError(f"replicas must be >= 1, got {replicas}")
    arrivals = np.sort(np.asarray(arrivals, dtype=np.float64))
    free = np.zeros(replicas)
    cold = np.ones(replicas, dtype=bool)
    lat = np.empty(len(arrivals))
    busy = 0.0
    cold_starts = 0
    for i, a in enumerate(arrivals):
        j = int(np.argmin(free))
        start = max(a, free[j])
        service = t_request
        if cold[j]:
            service += cold_start_s
            cold[j] = False
            cold_starts += 1
        done = start + service
        free[j] = done
        busy += service
        lat[i] = done - a
    if len(lat):
        p50, p95, p99 = (float(np.percentile(lat, q)) for q in (50, 95, 99))
        viol = float(np.mean(lat > slo_s))
    else:
        p50 = p95 = p99 = 0.0
        viol = 0.0
    cost = float(price_per_gb_s * mem_gb_total * busy)
    horizon = float(max(free.max(), arrivals[-1] if len(arrivals) else 0.0))
    util = float(busy / (replicas * horizon)) if horizon > 0 else 0.0
    return AutoscaleRow(
        replicas=replicas, requests=len(arrivals), p50=p50, p95=p95, p99=p99,
        slo_violation_frac=viol, cold_starts=cold_starts, cost=cost,
        cost_per_1k=(1000.0 * cost / len(arrivals)) if len(arrivals) else 0.0,
        utilization=util)


def autoscale_plan(plan, *, rate: float = 1.0, horizon: float = 120.0,
                   replicas: Sequence[int] = (1, 2, 4, 8),
                   arrival: str = "poisson", trace_file: Optional[str] = None,
                   seed: int = 0, burst_factor: float = 4.0,
                   cold_start_s: float = DEFAULT_COLD_START_S) -> List[AutoscaleRow]:
    """Scale a ``workload="serve"`` plan across replica counts under one
    seeded arrival trace (``"poisson"``, ``"bursty"``, or ``"trace"`` with
    ``trace_file``)."""
    from repro.api.plan import PlanCompatibilityError

    if getattr(plan, "workload", "train") != "serve":
        raise PlanCompatibilityError(
            "autoscale_plan simulates serving plans; this plan for "
            f"{plan.model!r} has workload={plan.workload!r} "
            "(plan one with Session.plan(workload='serve') or "
            "`repro serve`)")
    sv = plan.serving or {}
    t_request = float(sv.get("t_request", plan.t_iter))
    slo_s = float(sv["slo_s"])
    rp = plan.resolve()
    from repro.serverless.simulator import stage_aggregates

    agg = stage_aggregates(rp.profile, rp.platform, rp.config, 1)
    mem_gb_total = float(np.sum(agg.mem) / GB)
    if arrival == "poisson":
        arrivals = poisson_arrivals(rate, horizon, seed=seed)
    elif arrival == "bursty":
        arrivals = bursty_arrivals(rate, horizon, burst_factor=burst_factor,
                                   seed=seed)
    elif arrival == "trace":
        if trace_file is None:
            raise ValueError("arrival='trace' needs trace_file=")
        arrivals = trace_arrivals(trace_file)
    else:
        raise ValueError(
            f"unknown arrival process {arrival!r}; "
            "expected poisson | bursty | trace")
    return [
        simulate_replicas(
            arrivals, replicas=int(n), t_request=t_request, slo_s=slo_s,
            mem_gb_total=mem_gb_total,
            price_per_gb_s=rp.platform.price_per_gb_s,
            cold_start_s=cold_start_s)
        for n in replicas
    ]
