"""One pipeline stage's serving math: partitioned prefill + one-token decode.

:class:`ServeStageWorker` is the inference sibling of
``runtime.worker.StageWorker``: it owns a contiguous run of period instances
(plus possibly the embedding and/or the head) and exposes jitted
``prefill``/``decode`` entry points that chain bit-identically to the
monolithic ``registry.prefill`` / ``registry.decode_step`` — both sides run
the same ``lax.scan`` body over the same per-instance parameters, the split
merely chains the scan carry across stages through the object store.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import registry
from repro.models.common import rms_norm
from repro.models.transformer import period_decode, period_prefill
from repro.serverless.runtime.worker import StageSpan


def greedy_token(logits: Any) -> np.ndarray:
    """argmax over the vocab of the last position -> int32 [B, 1].

    The single sampling rule shared by the pipelined engine and the
    monolithic reference loop, so token parity is argmax of bit-identical
    logits on both sides.
    """
    logits = np.asarray(logits)
    return np.argmax(logits[:, -1], axis=-1).astype(np.int32).reshape(-1, 1)


class ServeStageWorker:
    """Stage ``span`` of ``cfg``, serving prefill + decode requests.

    ``prefill(x_in)`` takes the token ids ([B, S] int) when the stage owns
    the embedding, else the upstream hidden state [B, S, d]; it returns
    ``(out, caches)`` where ``out`` is the next stage's input (or last-
    position logits on the head stage) and ``caches`` the stage's decode
    caches (None when the stage owns no layers).  ``decode(caches, x_in)``
    is the single-token analog.
    """

    def __init__(self, cfg: ArchConfig, span: StageSpan, full_params: dict, *,
                 s_ctx: int, jit: bool = True, use_pallas: bool = False):
        if cfg.frontend != "none":
            raise NotImplementedError(
                f"pipelined serving supports token frontends only, "
                f"got frontend={cfg.frontend!r}")
        if cfg.tie_embeddings and span.n_stages > 1:
            raise NotImplementedError(
                "tied embeddings cannot be split across serving stages "
                "(embed and head live in different workers)")
        self.cfg = cfg
        self.span = span
        self.s_ctx = int(s_ctx)
        self.use_pallas = bool(use_pallas)
        self.has_layers = span.inst_hi > span.inst_lo

        p: dict = {}
        if span.owns_embed or cfg.tie_embeddings:
            p["embed"] = full_params["embed"]
        if span.owns_head:
            p["final_norm"] = full_params["final_norm"]
            if not cfg.tie_embeddings:
                p["head"] = full_params["head"]
        if self.has_layers:
            p["layers"] = jax.tree.map(
                lambda a: a[span.inst_lo:span.inst_hi],
                full_params["layers"])
        self.params = p
        self.mask = (jnp.asarray(
            registry.active_mask(cfg)[span.inst_lo:span.inst_hi])
            if self.has_layers else None)

        self._prefill = jax.jit(self._prefill_fn) if jit else self._prefill_fn
        self._decode = jax.jit(self._decode_fn) if jit else self._decode_fn

    # ------------------------------------------------------------- jitted math
    def _embed(self, params, x_in):
        return params["embed"][x_in] if self.span.owns_embed else x_in

    def _head(self, params, h):
        h = rms_norm(h, params["final_norm"], self.cfg.norm_eps)
        head = (params["embed"] if self.cfg.tie_embeddings
                else params["head"])
        return h @ head.T

    def _prefill_fn(self, params, x_in):
        h = self._embed(params, x_in)
        positions = jnp.arange(h.shape[1], dtype=jnp.int32)
        caches = None
        if self.has_layers:
            def body(x, scanned):
                pp, act = scanned
                x, cs = period_prefill(pp, x, act, cfg=self.cfg,
                                       positions=positions,
                                       capacity=self.s_ctx)
                return x, cs

            h, caches = jax.lax.scan(body, h, (params["layers"], self.mask))
        if self.span.owns_head:
            # matches registry.prefill: norm + logits on the last position
            return self._head(params, h[:, -1:]), caches
        return h, caches

    def _decode_fn(self, params, caches, x_in):
        h = self._embed(params, x_in)
        if self.has_layers:
            def body(x, scanned):
                pp, cache, act = scanned
                x, nc = period_decode(pp, x, cache, act, cfg=self.cfg,
                                      use_pallas=self.use_pallas)
                return x, nc

            h, caches = jax.lax.scan(
                body, h, (params["layers"], caches, self.mask))
        if self.span.owns_head:
            return self._head(params, h), caches
        return h, caches

    # --------------------------------------------------------------- frontends
    def prefill(self, x_in) -> Tuple[np.ndarray, Optional[Any]]:
        out, caches = self._prefill(self.params, x_in)
        return (np.asarray(out),
                None if caches is None else jax.tree.map(np.asarray, caches))

    def decode(self, caches, x_in) -> Tuple[np.ndarray, Optional[Any]]:
        out, caches = self._decode(self.params, caches, x_in)
        return (np.asarray(out),
                None if caches is None else jax.tree.map(np.asarray, caches))
