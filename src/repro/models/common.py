"""Shared model building blocks (pure JAX, mesh-agnostic).

All layer functions are *shape driven*: they accept possibly tensor-parallel
sliced parameters and a ``ParallelCtx`` providing the collectives; with the
default local context they are ordinary single-device modules.  The pipeline
runtime (repro.core.pipeline) supplies real collectives inside shard_map.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------- context
@dataclasses.dataclass
class ParallelCtx:
    """Collective hooks.  Defaults are single-device no-ops.

    tp_size / psum_tp: tensor parallelism within a pipeline stage
                       (sub-groups of the 'model' mesh axis).
    dp_size / ep_all_to_all: expert parallelism over the 'data' axis.
    seq_shards / psum_seq: KV/sequence sharding over the 'data' axis for
                       long-context decode (partial-softmax combination).
    """

    tp_size: int = 1
    dp_size: int = 1
    seq_shards: int = 1
    psum_tp: Callable[[Any], Any] = lambda x: x
    ep_all_to_all: Optional[Callable[[Any], Any]] = None  # split/concat experts
    ep_all_to_all_back: Optional[Callable[[Any], Any]] = None
    psum_seq: Callable[[Any], Any] = lambda x: x
    pmax_seq: Optional[Callable[[Any], Any]] = None
    seq_index: Any = 0  # index of this device's sequence shard


LOCAL_CTX = ParallelCtx()


# ---------------------------------------------------------------------- layers
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def init_norm(d: int, dtype) -> jax.Array:
    return jnp.zeros((d,), dtype=dtype)


def dense_init(key, shape, dtype, scale: float = 0.02) -> jax.Array:
    return (scale * jax.random.normal(key, shape, dtype=jnp.float32)).astype(dtype)


# ------------------------------------------------------------------------ rope
def rope_frequencies(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (np.arange(0, hd, 2, dtype=np.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, hd]; positions: broadcastable to [..., seq]."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(hd, theta))  # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    cos = jnp.cos(angles)[..., :, None, :]  # [..., seq, 1, hd/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------- cross entropy
def softmax_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """logits [..., V] fp32 upcast; labels int [...] -> per-token loss [...]."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return logz - gold


def vocab_parallel_cross_entropy(
    local_logits: jax.Array,
    labels: jax.Array,
    vocab_start: jax.Array,
    vocab_shard: int,
    psum: Callable[[Any], Any],
    pmax: Optional[Callable[[Any], Any]] = None,
) -> jax.Array:
    """Megatron-style CE with the vocabulary sharded across an axis.

    local_logits [..., V/s] — this device's vocab slice; combination via psum
    of (max, sumexp, gold-hit).  Matches softmax_cross_entropy on gathered
    logits.
    """
    local_logits = local_logits.astype(jnp.float32)
    local_max = jnp.max(local_logits, axis=-1)
    gmax = pmax(local_max) if pmax is not None else local_max
    sumexp = jnp.sum(jnp.exp(local_logits - gmax[..., None]), axis=-1)
    sumexp = psum(sumexp)
    logz = gmax + jnp.log(sumexp)
    local_label = labels - vocab_start
    in_shard = (local_label >= 0) & (local_label < vocab_shard)
    safe = jnp.clip(local_label, 0, vocab_shard - 1)
    gold_local = jnp.take_along_axis(local_logits, safe[..., None], axis=-1)[..., 0]
    gold = psum(jnp.where(in_shard, gold_local, 0.0))
    return logz - gold


def make_causal_mask(q_pos: jax.Array, k_pos: jax.Array, window: int) -> jax.Array:
    """[q, k] boolean mask.  window==0 -> plain causal; else sliding window."""
    causal = k_pos[None, :] <= q_pos[:, None]
    if window:
        causal &= k_pos[None, :] > (q_pos[:, None] - window)
    return causal
