"""Modality frontend STUBS (the one sanctioned carve-out).

[audio] hubert-xlarge: the mel-spectrogram + conv feature encoder is stubbed;
we synthesize frame embeddings [B, S, d_model] directly (deterministic PRNG),
plus codebook labels in [0, vocab).

[vlm] internvl2-26b: the InternViT encoder + MLP projector are stubbed; we
synthesize patch embeddings [B, n_patches, d_model] that the language model
consumes in its leading positions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def synth_audio_frames(key, cfg: ArchConfig, batch: int, seq: int) -> jax.Array:
    """Stub for the wav2vec2/HuBERT conv feature extractor output."""
    return 0.1 * jax.random.normal(key, (batch, seq, cfg.d_model), jnp.float32)


def synth_patch_embeds(key, cfg: ArchConfig, batch: int) -> jax.Array:
    """Stub for the ViT patch/projector output (n_frontend_tokens patches)."""
    return 0.1 * jax.random.normal(
        key, (batch, cfg.n_frontend_tokens, cfg.d_model), jnp.float32
    )
