"""Grouped-query attention with RoPE, sliding windows and KV caches.

Shape-driven tensor parallelism: the number of local query heads is inferred
from the (possibly TP-sliced) projection weights; ``ctx.psum_tp`` reduces the
row-parallel output projection.

Decode supports two cache layouts:
  * full cache [B, kv, S_ctx, hd]  (global-attention layers)
  * rolling-window cache [B, kv, W, hd] with a monotone write cursor
    (sliding-window layers — the gemma3 local 5/6 layers), O(W) memory.
For ``long_500k`` the *global* layers shard the S_ctx axis over the data mesh
axis and combine partial softmaxes via psum (flash-decode style).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec, GLOBAL_WINDOW
from repro.models.common import ParallelCtx, LOCAL_CTX, apply_rope, dense_init, rms_norm


# ------------------------------------------------------------------ parameters
def init_attn_params(key, cfg: ArchConfig, dtype) -> dict:
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, cfg.n_heads * hd), dtype),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads * hd), dtype),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads * hd), dtype),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, d), dtype, scale=0.02 / max(1, cfg.n_layers) ** 0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _project_qkv(p: dict, x: jax.Array, cfg: ArchConfig):
    """x [B,S,d] -> q [B,S,Hq,hd], k/v [B,S,Hkv,hd] (local head counts)."""
    hd = cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    B, S = x.shape[0], x.shape[1]
    q = q.reshape(B, S, -1, hd)
    k = k.reshape(B, S, -1, hd)
    v = v.reshape(B, S, -1, hd)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


# ------------------------------------------------------- blockwise (flash) path
BLOCKWISE_THRESHOLD = 4_096  # use O(S*block) attention at/above this seq len
Q_BLOCK = 512
K_BLOCK = 1024


def _blockwise_attention(
    q: jax.Array,  # [B,S,Hq,hd]
    k: jax.Array,  # [B,S,Hkv,hd]
    v: jax.Array,
    positions: jax.Array,  # [S]
    causal: bool,
    window: int,
) -> jax.Array:
    """Online-softmax attention scanning over (q-block, k-block) tiles; the
    pure-JAX twin of the Pallas flash kernel (kernels/flash_attention.py).
    Sliding-window layers slice only the in-window keys per q block, so their
    FLOPs/memory scale with S*window rather than S^2.
    """
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = hd**-0.5
    QB = min(Q_BLOCK, S)
    assert S % QB == 0
    nqb = S // QB
    qg = q.reshape(B, S, Hkv, G, hd).astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    if window:
        # pad keys by window so each q block sees exactly [qs-W, qs+QB)
        W = window
        kp = jnp.pad(kf, ((0, 0), (W, 0), (0, 0), (0, 0)))
        vp = jnp.pad(vf, ((0, 0), (W, 0), (0, 0), (0, 0)))
        pp = jnp.pad(positions, (W, 0), constant_values=-1)

        def qblock(i):
            qs = i * QB
            qb = jax.lax.dynamic_slice_in_dim(qg, qs, QB, axis=1)
            qpos = jax.lax.dynamic_slice_in_dim(positions, qs, QB, axis=0)
            kb = jax.lax.dynamic_slice_in_dim(kp, qs, W + QB, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(vp, qs, W + QB, axis=1)
            kpos = jax.lax.dynamic_slice_in_dim(pp, qs, W + QB, axis=0)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb)
            allow = (kpos[None, :] <= qpos[:, None]) & (
                kpos[None, :] > qpos[:, None] - W
            ) & (kpos >= 0)[None, :]
            s = jnp.where(allow[None, None, None], s, -1e30)
            p = jax.nn.softmax(s, axis=-1)
            return jnp.einsum("bhgqk,bkhd->bqhgd", p, vb)

        out = jax.lax.map(jax.checkpoint(qblock), jnp.arange(nqb))  # [nqb,B,QB,...]
        out = jnp.moveaxis(out, 0, 1).reshape(B, S, Hkv, G, hd)
        return out.reshape(B, S, Hq, hd).astype(q.dtype)

    KB = min(K_BLOCK, S)
    assert S % KB == 0
    nkb = S // KB

    def qblock(i):
        qs = i * QB
        qb = jax.lax.dynamic_slice_in_dim(qg, qs, QB, axis=1)
        qpos = jax.lax.dynamic_slice_in_dim(positions, qs, QB, axis=0)

        def kstep(carry, j):
            m, l, acc = carry
            ks = j * KB
            kb = jax.lax.dynamic_slice_in_dim(kf, ks, KB, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(vf, ks, KB, axis=1)
            kpos = jax.lax.dynamic_slice_in_dim(positions, ks, KB, axis=0)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb)
            if causal:
                allow = kpos[None, :] <= qpos[:, None]
                s = jnp.where(allow[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, vb)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, QB), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, QB), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, QB, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kstep), (m0, l0, a0), jnp.arange(nkb)
        )
        o = acc / jnp.maximum(l, 1e-30)[..., None]  # [B,Hkv,G,QB,hd]
        return jnp.moveaxis(o, 3, 1)  # [B,QB,Hkv,G,hd]

    out = jax.lax.map(qblock, jnp.arange(nqb))
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, Hq, hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------- full forward
def attn_forward(
    p: dict,
    x: jax.Array,
    *,
    cfg: ArchConfig,
    spec: LayerSpec,
    positions: jax.Array,
    ctx: ParallelCtx = LOCAL_CTX,
    use_pallas: bool = False,
) -> jax.Array:
    """Training / prefill attention over the full sequence.  x: [B,S,d]."""
    hd = cfg.hd
    q, k, v = _project_qkv(p, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    n_rep = q.shape[2] // k.shape[2]

    if use_pallas:
        from repro.kernels import ops as kops

        out = kops.flash_attention(
            q, k, v,
            causal=cfg.causal,
            window=spec.window,
            positions=positions,
        )
    elif x.shape[1] >= BLOCKWISE_THRESHOLD:
        out = _blockwise_attention(
            q, k, v, positions, cfg.causal, spec.window if cfg.causal else 0
        )
    else:
        k = _repeat_kv(k, n_rep)
        v = _repeat_kv(v, n_rep)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / hd**0.5
        if cfg.causal:
            allow = positions[None, :] <= positions[:, None]  # [S, S]
            if spec.window:
                allow &= positions[None, :] > (positions[:, None] - spec.window)
            scores = jnp.where(allow[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)

    B, S = x.shape[0], x.shape[1]
    out = out.reshape(B, S, -1)
    return ctx.psum_tp(out @ p["wo"])


# --------------------------------------------------------------------- prefill
def attn_prefill(
    p: dict,
    x: jax.Array,
    *,
    cfg: ArchConfig,
    spec: LayerSpec,
    positions: jax.Array,
    ctx: ParallelCtx = LOCAL_CTX,
    capacity: int | None = None,
) -> tuple[jax.Array, "KVCache"]:
    """Full-sequence forward that also returns the KV cache for decoding.
    Window layers keep only the trailing ``window`` keys (ring layout with the
    cursor at S % W so subsequent decode writes continue the ring).  Global
    layers pad the cache out to ``capacity`` (the serving context length) so
    decode has room to append."""
    hd = cfg.hd
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if S >= BLOCKWISE_THRESHOLD:
        out = _blockwise_attention(q, k, v, positions, cfg.causal, spec.window)
    else:
        n_rep = q.shape[2] // k.shape[2]
        kk, vv = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) / hd**0.5
        if cfg.causal:
            allow = positions[None, :] <= positions[:, None]
            if spec.window:
                allow &= positions[None, :] > (positions[:, None] - spec.window)
            scores = jnp.where(allow[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, vv)
    y = ctx.psum_tp(out.reshape(B, S, -1) @ p["wo"])

    kc = jnp.swapaxes(k, 1, 2)  # [B,Hkv,S,hd]
    vc = jnp.swapaxes(v, 1, 2)
    if spec.window and spec.window <= S:
        W = spec.window
        # ring layout: token at global pos p sits in slot p % W
        tail_start = S - W
        tail_k = jax.lax.dynamic_slice_in_dim(kc, tail_start, W, axis=2)
        tail_v = jax.lax.dynamic_slice_in_dim(vc, tail_start, W, axis=2)
        shift = tail_start % W
        kc = jnp.roll(tail_k, shift, axis=2)
        vc = jnp.roll(tail_v, shift, axis=2)
    elif spec.window:  # S < window: ring slots 0..S-1, pad to ring capacity
        tcap = min(spec.window, capacity) if capacity is not None else spec.window
        pad = tcap - S
        if pad > 0:
            kc = jnp.pad(kc, ((0, 0), (0, 0), (0, pad), (0, 0)))
            vc = jnp.pad(vc, ((0, 0), (0, 0), (0, pad), (0, 0)))
    elif capacity is not None and capacity > S:
        pad = capacity - S
        kc = jnp.pad(kc, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vc = jnp.pad(vc, ((0, 0), (0, 0), (0, pad), (0, 0)))
    cache = KVCache(k=kc, v=vc, cursor=jnp.full((B,), S, jnp.int32))
    return y, cache


# --------------------------------------------------------------------- caching
class KVCache(NamedTuple):
    k: jax.Array       # [B, Hkv, C, hd]; C = S_ctx (global) or window (local)
    v: jax.Array
    cursor: jax.Array  # [B] int32: #tokens already written (uniform across B;
                       # kept batch-shaped so pipeline micro-batch slicing works)

    @property
    def capacity(self) -> int:
        return self.k.shape[2]


def init_kv_cache(
    batch: int, n_kv_local: int, capacity: int, hd: int, dtype
) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, n_kv_local, capacity, hd), dtype),
        v=jnp.zeros((batch, n_kv_local, capacity, hd), dtype),
        cursor=jnp.zeros((batch,), jnp.int32),
    )


def cache_capacity(spec: LayerSpec, s_ctx: int, seq_shards: int = 1) -> int:
    """Per-device cache capacity for a layer: rolling window for local layers,
    a 1/seq_shards slice of the context for (possibly sharded) global layers."""
    if spec.window:
        return min(spec.window, s_ctx)
    assert s_ctx % seq_shards == 0
    return s_ctx // seq_shards


def attn_decode(
    p: dict,
    x: jax.Array,
    cache: KVCache,
    *,
    cfg: ArchConfig,
    spec: LayerSpec,
    ctx: ParallelCtx = LOCAL_CTX,
    use_pallas: bool = False,
) -> tuple[jax.Array, KVCache]:
    """One-token decode.  x: [B,1,d].  Returns (out [B,1,d], new cache).

    Global layers with ctx.seq_shards > 1 hold a 1/n slice of the KV sequence;
    new tokens are written round-robin by global position, and the partial
    attention outputs are combined with a (max, sum-exp)-stable psum.
    """
    hd = cfg.hd
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(p, x, cfg)  # q [B,1,Hq,hd]
    pos = cache.cursor[0]  # global position of the incoming token (uniform)
    posv = jnp.full((B, 1), pos, dtype=jnp.int32)
    q = apply_rope(q, posv, cfg.rope_theta)
    k_new = apply_rope(k_new, posv, cfg.rope_theta)

    sharded = (spec.window == GLOBAL_WINDOW) and ctx.seq_shards > 1
    C = cache.capacity
    if sharded:
        # round-robin ownership by global position keeps shards balanced
        # during incremental decode.
        owner = pos % ctx.seq_shards
        slot = pos // ctx.seq_shards
        is_mine = owner == ctx.seq_index
        write_slot = jnp.where(is_mine, slot, 0)
        k_upd = jax.lax.dynamic_update_slice(
            cache.k, jnp.swapaxes(k_new, 1, 2), (0, 0, write_slot, 0)
        )
        v_upd = jax.lax.dynamic_update_slice(
            cache.v, jnp.swapaxes(v_new, 1, 2), (0, 0, write_slot, 0)
        )
        k_cache = jnp.where(is_mine, k_upd, cache.k)
        v_cache = jnp.where(is_mine, v_upd, cache.v)
        # validity: shard i holds slots s with global pos s*shards + i <= pos
        slots = jnp.arange(C, dtype=jnp.int32)
        valid = slots * ctx.seq_shards + ctx.seq_index <= pos
    else:
        # rolling ring-buffer slot for windowed layers; plain append otherwise
        # (unwindowed capacity == S_ctx covers all tokens).
        slot = pos % jnp.int32(C) if spec.window else jnp.minimum(pos, C - 1)
        k_cache = jax.lax.dynamic_update_slice(
            cache.k, jnp.swapaxes(k_new, 1, 2), (0, 0, slot, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            cache.v, jnp.swapaxes(v_new, 1, 2), (0, 0, slot, 0)
        )
        slots = jnp.arange(C, dtype=jnp.int32)
        if spec.window:
            valid = (slots <= pos) | (pos >= C)  # ring buffer fully valid once wrapped
        else:
            valid = slots <= pos

    if use_pallas and not sharded:
        from repro.kernels import ops as kops

        if kops.decode_attention_capable(
                n_q_heads=q.shape[2], n_kv_heads=k_cache.shape[1],
                capacity=C, window=spec.window, seq_shards=ctx.seq_shards):
            # flash-decode kernel: one query token against the append cache;
            # `valid = slots <= pos` is exactly `length = pos + 1`
            o = kops.decode_attention(q[:, 0], k_cache, v_cache, pos + 1)
            out = ctx.psum_tp(o.reshape(B, 1, -1) @ p["wo"])
            return out, KVCache(k=k_cache, v=v_cache, cursor=cache.cursor + 1)

    n_rep = q.shape[2] // k_cache.shape[1]
    kk = jnp.repeat(k_cache, n_rep, axis=1)  # [B, Hq, C, hd]
    vv = jnp.repeat(v_cache, n_rep, axis=1)
    scores = jnp.einsum("bqhd,bhcd->bhqc", q, kk).astype(jnp.float32) / hd**0.5
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)

    if sharded:
        m_local = jnp.max(scores, axis=-1)                        # [B,H,1]
        m = ctx.pmax_seq(m_local) if ctx.pmax_seq is not None else m_local
        e = jnp.exp(scores - m[..., None])
        num = jnp.einsum("bhqc,bhcd->bhqd", e, vv.astype(jnp.float32))
        den = jnp.sum(e, axis=-1)                                 # [B,H,1]
        num = ctx.psum_seq(num)
        den = ctx.psum_seq(den)
        out = (num / den[..., None]).astype(x.dtype)              # [B,H,1,hd]
    else:
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqc,bhcd->bhqd", probs, vv.astype(jnp.float32)).astype(x.dtype)

    out = jnp.swapaxes(out, 1, 2).reshape(B, 1, -1)  # [B,1,Hq*hd]
    out = ctx.psum_tp(out @ p["wo"])
    return out, KVCache(k=k_cache, v=v_cache, cursor=cache.cursor + 1)
