"""Mixture-of-Experts FFN with top-k routing, capacity-based dispatch and
expert parallelism over the data mesh axis.

Dispatch uses scatter-add into an [E, C, d] buffer (unique slots), so it is
jit-friendly and differentiable; with expert parallelism the buffer is
exchanged with two all_to_alls (``ctx.ep_all_to_all`` / ``..._back``), the
standard EP token shuffle.  The router adds the usual load-balance aux loss
(Switch/ST-MoE style) plus a small z-loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoECfg
from repro.models.common import ParallelCtx, LOCAL_CTX, dense_init


def init_moe_params(key, cfg: ArchConfig, dtype, n_experts_local: int | None = None) -> dict:
    mc = cfg.moe
    assert mc is not None
    d, f, e = cfg.d_model, mc.d_ff_expert, mc.n_experts
    e_local = n_experts_local if n_experts_local is not None else e
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), dtype),
        "w_gate": dense_init(ks[1], (e_local, d, f), dtype),
        "w_up": dense_init(ks[2], (e_local, d, f), dtype),
        "w_down": dense_init(
            ks[3], (e_local, f, d), dtype, scale=0.02 / max(1, cfg.n_layers) ** 0.5
        ),
    }


def capacity(n_tokens: int, mc: MoECfg) -> int:
    c = int(n_tokens * mc.top_k * mc.capacity_factor / mc.n_experts)
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def moe_forward(
    p: dict,
    x: jax.Array,
    *,
    cfg: ArchConfig,
    ctx: ParallelCtx = LOCAL_CTX,
) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar fp32)."""
    mc = cfg.moe
    assert mc is not None
    B, S, d = x.shape
    T = B * S
    k = mc.top_k
    E = mc.n_experts
    C = capacity(T, mc)

    tokens = x.reshape(T, d)
    logits = (tokens @ p["router"]).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, sel = jax.lax.top_k(probs, k)  # [T, k]
    gates = gates / jnp.clip(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

    # ----- aux losses (load balance + z-loss)
    onehot = jax.nn.one_hot(sel, E, dtype=jnp.float32)  # [T, k, E]
    frac_routed = jnp.mean(jnp.sum(onehot, axis=1), axis=0)  # [E]
    mean_prob = jnp.mean(probs, axis=0)  # [E]
    lb_loss = E * jnp.sum(frac_routed * mean_prob)
    z_loss = 1e-3 * jnp.mean(jnp.square(jax.scipy.special.logsumexp(logits, axis=-1)))
    aux = mc.router_aux_weight * lb_loss + z_loss

    # ----- slot assignment: token-major priority within each expert
    flat_sel = sel.reshape(T * k)
    flat_onehot = onehot.reshape(T * k, E)
    slot = (jnp.cumsum(flat_onehot, axis=0) - flat_onehot)  # [T*k, E]
    slot = jnp.sum(slot * flat_onehot, axis=-1).astype(jnp.int32)  # [T*k]
    keep = slot < C
    dispatch_idx = jnp.where(keep, flat_sel * C + slot, E * C)  # overflow bucket

    # ----- dispatch: [E*C (+1 overflow), d]
    x_rep = jnp.repeat(tokens, k, axis=0)  # [T*k, d]
    buf = jnp.zeros((E * C + 1, d), dtype=x.dtype)
    buf = buf.at[dispatch_idx].add(x_rep * keep[:, None].astype(x.dtype))
    expert_in = buf[: E * C].reshape(E, C, d)

    # ----- expert parallelism: [E, C, d] -> [E_local, C * dp, d]
    if ctx.ep_all_to_all is not None:
        expert_in = ctx.ep_all_to_all(expert_in)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", expert_in, p["w_up"])
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    expert_out = ctx.psum_tp(expert_out)  # TP row-parallel d_ff slices

    if ctx.ep_all_to_all_back is not None:
        expert_out = ctx.ep_all_to_all_back(expert_out)  # [E, C, d]

    # ----- combine
    flat_out = jnp.concatenate(
        [expert_out.reshape(E * C, d), jnp.zeros((1, d), expert_out.dtype)], axis=0
    )
    gathered = flat_out[dispatch_idx]  # [T*k, d]
    weights = (gates.reshape(T * k) * keep).astype(gathered.dtype)
    out = jnp.sum((gathered * weights[:, None]).reshape(T, k, d), axis=1)
    return out.reshape(B, S, d), aux.astype(jnp.float32)
