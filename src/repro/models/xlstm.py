"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory, sequential recurrence) following arXiv:2405.04517.

mLSTM train/prefill uses the stabilized quadratic parallel form (attention-like
[S,S] weights built from cumulative log-forget-gates); decode is the O(1)
recurrence over the (C, n, m) state.  sLSTM is inherently sequential (its
gates see h_{t-1}) and runs as a lax.scan over time; it carries its own
post-up-projection FFN per the paper's block design, hence ff=NO_FF in the
arch config.

TP note: mLSTM tensors are sliced on d_inner/heads; the sLSTM recurrent matrix
R couples all of h, so sLSTM runs TP-replicated (documented in DESIGN.md).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import ParallelCtx, LOCAL_CTX, dense_init, rms_norm


def _m_dims(cfg: ArchConfig, local_heads: int | None = None):
    xc = cfg.xlstm
    di = int(cfg.d_model * xc.m_proj_factor)
    H = local_heads if local_heads is not None else cfg.n_heads
    return di, H


# ======================================================================= mLSTM
def init_mlstm_params(key, cfg: ArchConfig, dtype) -> dict:
    d = cfg.d_model
    di, H = _m_dims(cfg)
    xc = cfg.xlstm
    ks = jax.random.split(key, 7)
    return {
        "w_up": dense_init(ks[0], (d, di), dtype),
        "w_z": dense_init(ks[1], (d, di), dtype),
        "conv_w": dense_init(ks[2], (xc.conv_kernel, di), dtype, scale=0.1),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": dense_init(ks[3], (di, di), dtype),
        "wk": dense_init(ks[4], (di, di), dtype),
        "wv": dense_init(ks[5], (di, di), dtype),
        "w_if": dense_init(ks[6], (di, 2 * H), dtype),
        "b_i": jnp.zeros((H,), dtype),
        "b_f": jnp.full((H,), 3.0, dtype),  # forget-gate bias toward remembering
        "out_norm": jnp.zeros((di,), dtype),
        "w_down": dense_init(ks[0], (di, d), dtype, scale=0.02 / max(1, cfg.n_layers) ** 0.5),
    }


def _conv1d(xc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    k = w.shape[0]
    pad = jnp.pad(xc, ((0, 0), (k - 1, 0), (0, 0)))
    return sum(pad[:, i : i + xc.shape[1], :] * w[i] for i in range(k)) + b


def _mlstm_qkvgates(p, x, cfg):
    u = x @ p["w_up"]
    z = x @ p["w_z"]
    uc = jax.nn.silu(_conv1d(u, p["conv_w"], p["conv_b"]))
    di = u.shape[-1]
    H = p["b_i"].shape[0]
    dh = di // H
    B, S = x.shape[0], x.shape[1]

    def heads(t):
        return t.reshape(B, S, H, dh)

    q = heads(uc @ p["wq"])
    k = heads(uc @ p["wk"]) / dh**0.5
    v = heads(u @ p["wv"])
    gates = (u @ p["w_if"]).astype(jnp.float32)  # [B,S,2H]
    log_i = gates[..., :H] + p["b_i"].astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(gates[..., H:] + p["b_f"].astype(jnp.float32))
    return q, k, v, z, log_i, log_f, H, dh


MLSTM_CHUNK = 256


def mlstm_forward(
    p: dict, x: jax.Array, *, cfg: ArchConfig, ctx: ParallelCtx = LOCAL_CTX,
    return_state: bool = False,
):
    """Chunkwise-parallel stabilized mLSTM (TFLA-style): quadratic form inside
    fixed-size chunks + a sequential (C, n, m) state across chunks, so memory
    is O(S * chunk) instead of O(S^2).  x [B,S,d] -> [B,S,d]."""
    B, S, _ = x.shape
    q, k, v, z, log_i, log_f, H, dh = _mlstm_qkvgates(p, x, cfg)
    Q = min(MLSTM_CHUNK, S)
    assert S % Q == 0, f"seq {S} not a multiple of mLSTM chunk {Q}"
    nchunks = S // Q

    def to_chunks(t):  # [B,S,...] -> [nchunks,B,Q,...]
        return t.reshape(B, nchunks, Q, *t.shape[2:]).swapaxes(0, 1)

    qf = to_chunks(q.astype(jnp.float32))
    kf = to_chunks(k.astype(jnp.float32))
    vf = to_chunks(v.astype(jnp.float32))
    lif = to_chunks(log_i)
    lff = to_chunks(log_f)
    tri = jnp.tril(jnp.ones((Q, Q), bool))

    def chunk_body(state, chunk):
        C_prev, n_prev, m_prev = state  # [B,H,dh,dh], [B,H,dh], [B,H]
        qc, kc, vc, ic, fc = chunk      # [B,Q,H,dh] / [B,Q,H]
        F = jnp.cumsum(fc, axis=1)      # [B,Q,H] cumulative log-forget in chunk
        # intra-chunk decay D[t,s] = F_t - F_s + i_s  (s <= t)
        D = F[:, :, None, :] - F[:, None, :, :] + ic[:, None, :, :]
        D = jnp.where(tri[None, :, :, None], D, -jnp.inf)
        m_intra = jnp.max(D, axis=2)                      # [B,Q,H]
        m_inter = F + m_prev[:, None, :]                  # carried-state scale
        m_t = jnp.maximum(m_intra, m_inter)               # [B,Q,H]
        a = jnp.exp(D - m_t[:, :, None, :])               # [B,t,s,H]
        qk = jnp.einsum("bthd,bshd->btsh", qc, kc)
        w = a * qk
        num = jnp.einsum("btsh,bshd->bthd", w, vc)
        den_intra = jnp.sum(w, axis=2)                    # [B,t,H]
        scale = jnp.exp(m_inter - m_t)                    # [B,Q,H]
        num = num + scale[..., None] * jnp.einsum("bthk,bhkv->bthv", qc, C_prev)
        den = den_intra + scale * jnp.einsum("bthk,bhk->bth", qc, n_prev)
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        h = num / den[..., None]                          # [B,Q,H,dh]
        # ----- state to next chunk
        F_tot = F[:, -1]                                  # [B,H]
        g = F_tot[:, None, :] - F + ic                    # decay of k_s to chunk end
        m_state = jnp.maximum(jnp.max(g, axis=1), F_tot + m_prev)
        gw = jnp.exp(g - m_state[:, None, :])             # [B,Q,H]
        C_new = jnp.exp(F_tot + m_prev - m_state)[..., None, None] * C_prev + jnp.einsum(
            "bsh,bshk,bshv->bhkv", gw, kc, vc
        )
        n_new = jnp.exp(F_tot + m_prev - m_state)[..., None] * n_prev + jnp.einsum(
            "bsh,bshk->bhk", gw, kc
        )
        return (C_new, n_new, m_state), h

    state0 = (
        jnp.zeros((B, H, dh, dh), jnp.float32),
        jnp.zeros((B, H, dh), jnp.float32),
        jnp.full((B, H), -1e30, jnp.float32),
    )
    (C_f, n_f, m_f), hs = jax.lax.scan(
        jax.checkpoint(chunk_body), state0, (qf, kf, vf, lif, lff)
    )
    h = hs.swapaxes(0, 1).reshape(B, S, -1).astype(x.dtype)
    h = rms_norm(h, p["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = ctx.psum_tp(h @ p["w_down"])
    if return_state:
        kc = cfg.xlstm.conv_kernel - 1
        u_raw = x @ p["w_up"]
        cache = MLSTMCache(C=C_f, n=n_f, m=m_f, conv=u_raw[:, S - kc :, :])
        return out, cache
    return out


class MLSTMCache(NamedTuple):
    C: jax.Array      # [B,H,dk,dv] fp32
    n: jax.Array      # [B,H,dk] fp32
    m: jax.Array      # [B,H] fp32
    conv: jax.Array   # [B,k-1,di]


def init_mlstm_cache(batch: int, cfg: ArchConfig, di_local: int, H_local: int, dtype) -> MLSTMCache:
    dh = di_local // H_local
    return MLSTMCache(
        C=jnp.zeros((batch, H_local, dh, dh), jnp.float32),
        n=jnp.zeros((batch, H_local, dh), jnp.float32),
        m=jnp.full((batch, H_local), -1e30, jnp.float32),
        conv=jnp.zeros((batch, cfg.xlstm.conv_kernel - 1, di_local), dtype),
    )


def mlstm_decode(
    p: dict, x: jax.Array, cache: MLSTMCache, *, cfg: ArchConfig, ctx: ParallelCtx = LOCAL_CTX
) -> Tuple[jax.Array, MLSTMCache]:
    """x [B,1,d] -> ([B,1,d], cache)."""
    B = x.shape[0]
    u = x @ p["w_up"]  # [B,1,di]
    z = x @ p["w_z"]
    hist = jnp.concatenate([cache.conv, u], axis=1)
    conv_out = jnp.einsum("bkd,kd->bd", hist, p["conv_w"]) + p["conv_b"]
    uc = jax.nn.silu(conv_out)  # [B,di]
    di = u.shape[-1]
    H = p["b_i"].shape[0]
    dh = di // H
    q = (uc @ p["wq"]).reshape(B, H, dh).astype(jnp.float32)
    k = ((uc @ p["wk"]) / dh**0.5).reshape(B, H, dh).astype(jnp.float32)
    v = (u[:, 0] @ p["wv"]).reshape(B, H, dh).astype(jnp.float32)
    gates = (u[:, 0] @ p["w_if"]).astype(jnp.float32)
    log_i = gates[:, :H] + p["b_i"].astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(gates[:, H:] + p["b_f"].astype(jnp.float32))

    m_new = jnp.maximum(log_f + cache.m, log_i)  # [B,H]
    fdec = jnp.exp(log_f + cache.m - m_new)
    iinc = jnp.exp(log_i - m_new)
    C = fdec[..., None, None] * cache.C + iinc[..., None, None] * (k[..., :, None] * v[..., None, :])
    n = fdec[..., None] * cache.n + iinc[..., None] * k
    num = jnp.einsum("bhkv,bhk->bhv", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, 1, di).astype(x.dtype)
    h = rms_norm(h, p["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = ctx.psum_tp(h @ p["w_down"])
    return out, MLSTMCache(C=C, n=n, m=m_new, conv=hist[:, 1:])


# ======================================================================= sLSTM
def _s_dims(cfg: ArchConfig):
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    f_ff = int(d * cfg.xlstm.s_proj_factor)
    return d, H, dh, f_ff


def init_slstm_params(key, cfg: ArchConfig, dtype) -> dict:
    d, H, dh, f_ff = _s_dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "w_gates": dense_init(ks[0], (d, 4 * d), dtype),
        "r_gates": dense_init(ks[1], (H, dh, 4 * dh), dtype, scale=dh**-0.5),
        "b_gates": jnp.zeros((4 * d,), dtype),
        "out_norm": jnp.zeros((d,), dtype),
        "w_up_ff": dense_init(ks[2], (d, f_ff), dtype),
        "w_down_ff": dense_init(ks[3], (f_ff, d), dtype, scale=0.02 / max(1, cfg.n_layers) ** 0.5),
    }


class SLSTMCache(NamedTuple):
    c: jax.Array  # [B,H,dh] fp32
    n: jax.Array
    m: jax.Array  # [B,H,dh]
    h: jax.Array  # [B,H,dh] (in x dtype)


def init_slstm_cache(batch: int, cfg: ArchConfig, dtype) -> SLSTMCache:
    _, H, dh, _ = _s_dims(cfg)
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return SLSTMCache(c=z, n=z, m=z - 1e30, h=jnp.zeros((batch, H, dh), dtype))


def _slstm_cell(p, cfg, xg, state: SLSTMCache) -> Tuple[SLSTMCache, jax.Array]:
    """xg: pre-computed input contribution [B, 4d] for one step."""
    d, H, dh, _ = _s_dims(cfg)
    B = xg.shape[0]
    rec = jnp.einsum("bhd,hde->bhe", state.h.astype(jnp.float32), p["r_gates"].astype(jnp.float32))
    g = xg.astype(jnp.float32).reshape(B, H, 4 * dh) + rec  # [B,H,4dh]
    zt, it, ft, ot = jnp.split(g, 4, axis=-1)
    log_i = it
    log_f = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(log_f + state.m, log_i)
    fdec = jnp.exp(log_f + state.m - m_new)
    iinc = jnp.exp(log_i - m_new)
    c = fdec * state.c + iinc * jnp.tanh(zt)
    n = jnp.maximum(fdec * state.n + iinc, 1.0)
    h = jax.nn.sigmoid(ot) * c / n
    return SLSTMCache(c=c, n=n, m=m_new, h=h.astype(state.h.dtype)), h


def slstm_forward(
    p: dict, x: jax.Array, *, cfg: ArchConfig, ctx: ParallelCtx = LOCAL_CTX,
    return_state: bool = False,
):
    """Sequential sLSTM over the sequence + post-up FFN.  x [B,S,d]."""
    B, S, d = x.shape
    xg = x @ p["w_gates"] + p["b_gates"]  # [B,S,4d]
    state = init_slstm_cache(B, cfg, x.dtype)

    def step(st, xg_t):
        st2, h = _slstm_cell(p, cfg, xg_t, st)
        return st2, h

    st_f, hs = jax.lax.scan(step, state, jnp.swapaxes(xg, 0, 1))  # [S,B,H,dh]
    h = jnp.swapaxes(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    h = rms_norm(h, p["out_norm"], cfg.norm_eps)
    ff = jax.nn.gelu(h @ p["w_up_ff"]) @ p["w_down_ff"]
    if return_state:
        return ff, st_f
    return ff


def slstm_decode(
    p: dict, x: jax.Array, cache: SLSTMCache, *, cfg: ArchConfig, ctx: ParallelCtx = LOCAL_CTX
) -> Tuple[jax.Array, SLSTMCache]:
    B, _, d = x.shape
    xg = (x[:, 0] @ p["w_gates"]) + p["b_gates"]
    st, h = _slstm_cell(p, cfg, xg, cache)
    h = h.reshape(B, 1, d).astype(x.dtype)
    h = rms_norm(h, p["out_norm"], cfg.norm_eps)
    ff = jax.nn.gelu(h @ p["w_up_ff"]) @ p["w_down_ff"]
    return ff, st
