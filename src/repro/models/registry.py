"""Model construction and single-device entry points.

Parameter layout: ``params['layers']`` is a tuple over period positions; each
leaf is stacked over *period instances* on axis 0, so a scan over instances
runs the whole network.  ``active_mask(cfg)`` marks padding layers (truncated
final period, and the pipeline's stage padding) to identity.

The pipeline runtime (repro.core.pipeline) consumes the same layout, with the
instance axis re-chunked onto the mesh's model axis.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (
    ArchConfig,
    ATTN,
    MAMBA,
    MLSTM,
    SLSTM,
    GLOBAL_WINDOW,
)
from repro.models import attention, mamba, xlstm
from repro.models.common import (
    ParallelCtx,
    LOCAL_CTX,
    dense_init,
    init_norm,
    rms_norm,
    softmax_cross_entropy,
    vocab_parallel_cross_entropy,
)
from repro.models.transformer import (
    init_layer_params,
    period_decode,
    period_forward,
    period_prefill,
)


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


def active_mask(cfg: ArchConfig, n_instances: Optional[int] = None) -> np.ndarray:
    """bool [n_instances, period_len]: layer (p, j) is a real layer."""
    P = n_instances if n_instances is not None else cfg.n_periods
    idx = np.arange(P * cfg.period_len).reshape(P, cfg.period_len)
    return idx < cfg.n_layers


def init_params(
    cfg: ArchConfig,
    key,
    n_instances: Optional[int] = None,
    n_experts_local: Optional[int] = None,
) -> dict:
    """Stacked parameters.  ``n_instances`` >= cfg.n_periods adds pipeline
    padding instances (their weights exist but are masked to identity)."""
    dtype = _dtype(cfg)
    P = n_instances if n_instances is not None else cfg.n_periods
    k_embed, k_head, k_layers = jax.random.split(key, 3)

    def init_instance(k):
        ks = jax.random.split(k, cfg.period_len)
        return tuple(
            init_layer_params(ks[j], cfg, cfg.period[j], dtype, n_experts_local)
            for j in range(cfg.period_len)
        )

    layer_keys = jax.random.split(k_layers, P)
    stacked = jax.vmap(init_instance)(layer_keys)
    params = {
        "embed": dense_init(k_embed, (cfg.vocab_size, cfg.d_model), dtype),
        "final_norm": init_norm(cfg.d_model, dtype),
        "layers": stacked,
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(k_head, (cfg.vocab_size, cfg.d_model), dtype)
    return params


def embed_inputs(cfg: ArchConfig, params, batch: dict) -> jax.Array:
    """Token/frame/VLM embedding -> [B, S, d]."""
    if cfg.frontend == "audio":
        h = batch["frames"].astype(_dtype(cfg))  # precomputed frame embeddings
    else:
        h = params["embed"][batch["tokens"]]
        if cfg.frontend == "vision":
            n_img = cfg.n_frontend_tokens
            img = batch["image_embeds"].astype(h.dtype)  # [B, n_img, d]
            h = jnp.concatenate([img, h[:, n_img:]], axis=1)
    return h


def _logits(cfg: ArchConfig, params, h: jax.Array) -> jax.Array:
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    return h @ head.T


# ------------------------------------------------------------------- training
def forward(
    cfg: ArchConfig,
    params,
    batch: dict,
    *,
    ctx: ParallelCtx = LOCAL_CTX,
    use_pallas: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Full forward -> (hidden [B,S,d], aux scalar)."""
    h = embed_inputs(cfg, params, batch)
    S = h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    mask = jnp.asarray(active_mask(cfg))

    def body(x, scanned):
        period_params, act = scanned
        x, aux = period_forward(
            period_params, x, act, cfg=cfg, positions=positions, ctx=ctx,
            use_pallas=use_pallas,
        )
        return x, aux

    h, auxs = jax.lax.scan(body, h, (params["layers"], mask))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h, jnp.sum(auxs)


def loss_fn(
    cfg: ArchConfig,
    params,
    batch: dict,
    *,
    ctx: ParallelCtx = LOCAL_CTX,
    use_pallas: bool = False,
) -> Tuple[jax.Array, dict]:
    """Mean next-token (decoder) or masked-prediction (encoder) CE loss."""
    h, aux = forward(cfg, params, batch, ctx=ctx, use_pallas=use_pallas)
    logits = _logits(cfg, params, h)
    labels = batch["labels"]
    if cfg.causal and not cfg.is_encoder:
        logits = logits[:, :-1]
        labels = labels[:, 1:]
    ce = softmax_cross_entropy(logits, labels)
    loss = jnp.mean(ce)
    total = loss + aux
    return total, {"ce": loss, "aux": aux}


# -------------------------------------------------------------------- serving
def init_decode_caches(
    cfg: ArchConfig,
    batch: int,
    s_ctx: int,
    *,
    seq_shards: int = 1,
    dtype=None,
):
    """Cache pytree: tuple over period positions; leaves stacked [P, ...]."""
    dtype = dtype or _dtype(cfg)
    P = cfg.n_periods

    def one(spec):
        if spec.mixer == ATTN:
            cap = attention.cache_capacity(spec, s_ctx, seq_shards if spec.window == GLOBAL_WINDOW else 1)
            c = attention.init_kv_cache(batch, cfg.n_kv_heads, cap, cfg.hd, dtype)
        elif spec.mixer == MAMBA:
            c = mamba.init_mamba_cache(batch, cfg, cfg.mamba.d_inner(cfg.d_model), dtype)
        elif spec.mixer == MLSTM:
            di = int(cfg.d_model * cfg.xlstm.m_proj_factor)
            c = xlstm.init_mlstm_cache(batch, cfg, di, cfg.n_heads, dtype)
        elif spec.mixer == SLSTM:
            c = xlstm.init_slstm_cache(batch, cfg, dtype)
        else:  # pragma: no cover
            raise ValueError(spec.mixer)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (P, *a.shape)), c)

    return tuple(one(spec) for spec in cfg.period)


def decode_step(
    cfg: ArchConfig,
    params,
    caches,
    tokens: jax.Array,  # [B, 1] int32
    *,
    ctx: ParallelCtx = LOCAL_CTX,
    use_pallas: bool = False,
):
    """One-token decode -> (logits [B,1,V], new caches)."""
    h = params["embed"][tokens]
    mask = jnp.asarray(active_mask(cfg))

    def body(x, scanned):
        period_params, cache, act = scanned
        x, new_cache = period_decode(period_params, x, cache, act, cfg=cfg,
                                     ctx=ctx, use_pallas=use_pallas)
        return x, new_cache

    h, new_caches = jax.lax.scan(body, h, (params["layers"], caches, mask))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return _logits(cfg, params, h), new_caches


def prefill(
    cfg: ArchConfig,
    params,
    batch: dict,
    *,
    ctx: ParallelCtx = LOCAL_CTX,
    capacity: int | None = None,
):
    """Prefill -> (last-position logits [B,1,V], caches)."""
    h = embed_inputs(cfg, params, batch)
    S = h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    mask = jnp.asarray(active_mask(cfg))

    def body(x, scanned):
        period_params, act = scanned
        x, caches = period_prefill(
            period_params, x, act, cfg=cfg, positions=positions, ctx=ctx,
            capacity=capacity,
        )
        return x, caches

    h, caches = jax.lax.scan(body, h, (params["layers"], mask))
    h_last = rms_norm(h[:, -1:], params["final_norm"], cfg.norm_eps)
    return _logits(cfg, params, h_last), caches
