"""Selective state-space (Mamba) mixer.

Training/prefill uses a *chunked* selective scan: an associative scan inside
fixed-size chunks plus a sequential scan over chunk boundary states, with
remat on the chunk body, so the [B, S, d_inner, d_state] tensor is never fully
materialized (TPU VMEM/HBM-friendly — this is the hardware adaptation of the
CUDA selective-scan kernel).  Decode is the O(1) single-token recurrence.

TP: the d_inner axis is sliced; the (delta, B, C) projection and the output
projection are row-parallel (ctx.psum_tp).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MambaCfg
from repro.models.common import ParallelCtx, LOCAL_CTX, dense_init

CHUNK = 256


def dt_rank(cfg: ArchConfig) -> int:
    return -(-cfg.d_model // 16)


def init_mamba_params(key, cfg: ArchConfig, dtype) -> dict:
    mc = cfg.mamba
    assert mc is not None
    d = cfg.d_model
    di = mc.d_inner(d)
    r = dt_rank(cfg)
    n = mc.d_state
    ks = jax.random.split(key, 7)
    # S4D-real initialization of A
    a_init = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "w_in_x": dense_init(ks[0], (d, di), dtype),
        "w_in_z": dense_init(ks[1], (d, di), dtype),
        "conv_w": dense_init(ks[2], (mc.d_conv, di), dtype, scale=0.1),
        "conv_b": jnp.zeros((di,), dtype),
        "w_xproj": dense_init(ks[3], (di, r + 2 * n), dtype),
        "w_dt": dense_init(ks[4], (r, di), dtype, scale=r**-0.5),
        "b_dt": jnp.full((di,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(a_init).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "w_out": dense_init(ks[6], (di, d), dtype, scale=0.02 / max(1, cfg.n_layers) ** 0.5),
    }


def _ssm_inputs(p, xc, cfg: ArchConfig, ctx: ParallelCtx):
    """xc [B,S,di_local] -> delta [B,S,di], Bc/Cc [B,S,N] (psum over TP)."""
    mc = cfg.mamba
    r = dt_rank(cfg)
    n = mc.d_state
    dbc = ctx.psum_tp(xc @ p["w_xproj"])  # row-parallel partial sums
    d_raw, b_c, c_c = jnp.split(dbc, [r, r + n], axis=-1)
    delta = jax.nn.softplus(d_raw @ p["w_dt"] + p["b_dt"])  # [B,S,di_local]
    return delta, b_c, c_c


def _conv1d(xc: jax.Array, conv_w: jax.Array, conv_b: jax.Array) -> jax.Array:
    """Causal depthwise conv over seq.  xc [B,S,di]; conv_w [k, di]."""
    k = conv_w.shape[0]
    pad = jnp.pad(xc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xc.shape[1], :] * conv_w[i] for i in range(k))
    return out + conv_b


def mamba_forward(
    p: dict,
    x: jax.Array,
    *,
    cfg: ArchConfig,
    ctx: ParallelCtx = LOCAL_CTX,
    return_state: bool = False,
):
    """x [B,S,d] -> [B,S,d] (+ MambaCache when return_state, for prefill).
    S must be a multiple of CHUNK or < CHUNK."""
    mc = cfg.mamba
    n = mc.d_state
    B, S, _ = x.shape
    xr = x @ p["w_in_x"]  # raw pre-conv activations (tail feeds the decode conv state)
    z = x @ p["w_in_z"]
    xc = jax.nn.silu(_conv1d(xr, p["conv_w"], p["conv_b"]))
    delta, b_c, c_c = _ssm_inputs(p, xc, cfg, ctx)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # [di, N]

    q = min(CHUNK, S)
    assert S % q == 0, f"seq {S} not a multiple of chunk {q}"
    nchunks = S // q
    di = xc.shape[-1]

    def to_chunks(t):
        return t.reshape(B, nchunks, q, *t.shape[2:]).swapaxes(0, 1)

    xs = jax.tree.map(to_chunks, (xc.astype(jnp.float32), delta.astype(jnp.float32),
                                  b_c.astype(jnp.float32), c_c.astype(jnp.float32)))

    def chunk_body(h0, chunk):
        xq, dq, bq, cq = chunk  # [B,q,di], [B,q,di], [B,q,N], [B,q,N]
        abar = jnp.exp(dq[..., None] * A)  # [B,q,di,N]
        bx = (dq * xq)[..., None] * bq[:, :, None, :]  # [B,q,di,N]
        # fold h0 into the first element
        bx = bx.at[:, 0].add(abar[:, 0] * h0)

        def comb(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a2 * a1, a2 * b1 + b2

        _, hs = jax.lax.associative_scan(comb, (abar, bx), axis=1)
        y = jnp.einsum("bqdn,bqn->bqd", hs, cq) + p["D"].astype(jnp.float32) * xq
        return hs[:, -1], y

    h0 = jnp.zeros((B, di, n), jnp.float32)
    h_last, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, xs)
    y = ys.swapaxes(0, 1).reshape(B, S, di).astype(x.dtype)
    out = y * jax.nn.silu(z)
    out = ctx.psum_tp(out @ p["w_out"])
    if return_state:
        kc = mc.d_conv - 1
        cache = MambaCache(conv=xr[:, S - kc :, :], h=h_last)
        return out, cache
    return out


# ----------------------------------------------------------------------- decode
class MambaCache(NamedTuple):
    conv: jax.Array  # [B, d_conv-1, di] trailing inputs
    h: jax.Array     # [B, di, N] fp32 state


def init_mamba_cache(batch: int, cfg: ArchConfig, di_local: int, dtype) -> MambaCache:
    mc = cfg.mamba
    return MambaCache(
        conv=jnp.zeros((batch, mc.d_conv - 1, di_local), dtype),
        h=jnp.zeros((batch, di_local, mc.d_state), jnp.float32),
    )


def mamba_decode(
    p: dict,
    x: jax.Array,
    cache: MambaCache,
    *,
    cfg: ArchConfig,
    ctx: ParallelCtx = LOCAL_CTX,
) -> Tuple[jax.Array, MambaCache]:
    """x [B,1,d] -> ([B,1,d], new cache)."""
    B = x.shape[0]
    xc = x @ p["w_in_x"]  # [B,1,di]
    z = x @ p["w_in_z"]
    hist = jnp.concatenate([cache.conv, xc], axis=1)  # [B, k, di]
    conv_out = jnp.einsum("bkd,kd->bd", hist, p["conv_w"]) + p["conv_b"]
    xc1 = jax.nn.silu(conv_out)[:, None, :]  # [B,1,di]
    delta, b_c, c_c = _ssm_inputs(p, xc1, cfg, ctx)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    abar = jnp.exp(delta[:, 0, :, None].astype(jnp.float32) * A)  # [B,di,N]
    bx = (delta[:, 0] * xc1[:, 0]).astype(jnp.float32)[..., None] * b_c[:, 0, None, :].astype(jnp.float32)
    h = abar * cache.h + bx
    y = jnp.einsum("bdn,bn->bd", h, c_c[:, 0].astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32) * xc1[:, 0].astype(jnp.float32)
    out = (y[:, None, :].astype(x.dtype)) * jax.nn.silu(z)
    out = ctx.psum_tp(out @ p["w_out"])
    return out, MambaCache(conv=hist[:, 1:], h=h)
