"""Generic layer / period / model assembly.

A *layer* = pre-norm mixer (+ residual) then optional pre-norm FFN
(+ residual).  A *period* is the arch's repeating heterogeneous block list
(configs.base.ArchConfig.period); the model is a scan over period instances.
The pipeline runtime reuses ``period_forward`` / ``period_decode`` as its
per-stage unit, so single-device and pipelined execution share all math.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ArchConfig,
    LayerSpec,
    ATTN,
    MAMBA,
    MLSTM,
    SLSTM,
    DENSE_FF,
    MOE_FF,
    NO_FF,
    GLOBAL_WINDOW,
)
from repro.models import attention, mamba, mlp, moe, xlstm
from repro.models.common import ParallelCtx, LOCAL_CTX, init_norm, rms_norm
import dataclasses as _dc


def _repl_ctx(ctx: ParallelCtx) -> ParallelCtx:
    """xLSTM mixers run TP-replicated (core.sharding.xlstm_pspecs): their
    outputs are already complete per lane, so the row-parallel psum hook must
    be identity for them."""
    if ctx.tp_size == 1:
        return ctx
    return _dc.replace(ctx, psum_tp=lambda x: x)


# ------------------------------------------------------------------ parameters
def init_layer_params(key, cfg: ArchConfig, spec: LayerSpec, dtype,
                      n_experts_local: Optional[int] = None) -> dict:
    k1, k2 = jax.random.split(key)
    p: dict = {"norm1": init_norm(cfg.d_model, dtype)}
    if spec.mixer == ATTN:
        p["mixer"] = attention.init_attn_params(k1, cfg, dtype)
    elif spec.mixer == MAMBA:
        p["mixer"] = mamba.init_mamba_params(k1, cfg, dtype)
    elif spec.mixer == MLSTM:
        p["mixer"] = xlstm.init_mlstm_params(k1, cfg, dtype)
    elif spec.mixer == SLSTM:
        p["mixer"] = xlstm.init_slstm_params(k1, cfg, dtype)
    if spec.ff != NO_FF:
        p["norm2"] = init_norm(cfg.d_model, dtype)
        if spec.ff == DENSE_FF:
            p["ff"] = mlp.init_mlp_params(k2, cfg, dtype)
        else:
            p["ff"] = moe.init_moe_params(k2, cfg, dtype, n_experts_local)
    return p


# --------------------------------------------------------------------- forward
def layer_forward(
    p: dict,
    x: jax.Array,
    active,
    *,
    cfg: ArchConfig,
    spec: LayerSpec,
    positions: jax.Array,
    ctx: ParallelCtx = LOCAL_CTX,
    use_pallas: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """One layer.  ``active`` (bool scalar) masks padding layers to identity.
    Returns (x, aux_loss)."""
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if spec.mixer == ATTN:
        mix = attention.attn_forward(
            p["mixer"], h, cfg=cfg, spec=spec, positions=positions, ctx=ctx,
            use_pallas=use_pallas,
        )
    elif spec.mixer == MAMBA:
        mix = mamba.mamba_forward(p["mixer"], h, cfg=cfg, ctx=ctx)
    elif spec.mixer == MLSTM:
        mix = xlstm.mlstm_forward(p["mixer"], h, cfg=cfg, ctx=_repl_ctx(ctx))
    elif spec.mixer == SLSTM:
        mix = xlstm.slstm_forward(p["mixer"], h, cfg=cfg, ctx=_repl_ctx(ctx))
    else:  # pragma: no cover
        raise ValueError(spec.mixer)
    gate = jnp.asarray(active, x.dtype)
    x = x + gate * mix
    aux = jnp.zeros((), jnp.float32)
    if spec.ff != NO_FF:
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if spec.ff == DENSE_FF:
            ff = mlp.mlp_forward(p["ff"], h, ctx=ctx, use_pallas=use_pallas)
        else:
            ff, aux = moe.moe_forward(p["ff"], h, cfg=cfg, ctx=ctx)
            aux = aux * jnp.asarray(active, jnp.float32)
        x = x + gate * ff
    return x, aux


def period_forward(
    period_params,      # tuple over period positions, leaves for ONE instance
    x: jax.Array,
    active,             # bool [period_len]
    *,
    cfg: ArchConfig,
    positions: jax.Array,
    ctx: ParallelCtx = LOCAL_CTX,
    use_pallas: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    for j, spec in enumerate(cfg.period):
        x, a = layer_forward(
            period_params[j], x, active[j],
            cfg=cfg, spec=spec, positions=positions, ctx=ctx, use_pallas=use_pallas,
        )
        aux = aux + a
    return x, aux


# ---------------------------------------------------------------------- decode
def layer_decode(p, x, cache, active, *, cfg, spec, ctx=LOCAL_CTX,
                 use_pallas=False):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if spec.mixer == ATTN:
        mix, new_cache = attention.attn_decode(
            p["mixer"], h, cache, cfg=cfg, spec=spec, ctx=ctx,
            use_pallas=use_pallas,
        )
    elif spec.mixer == MAMBA:
        mix, new_cache = mamba.mamba_decode(p["mixer"], h, cache, cfg=cfg, ctx=ctx)
    elif spec.mixer == MLSTM:
        mix, new_cache = xlstm.mlstm_decode(p["mixer"], h, cache, cfg=cfg, ctx=_repl_ctx(ctx))
    elif spec.mixer == SLSTM:
        mix, new_cache = xlstm.slstm_decode(p["mixer"], h, cache, cfg=cfg, ctx=_repl_ctx(ctx))
    else:  # pragma: no cover
        raise ValueError(spec.mixer)
    gate = jnp.asarray(active, x.dtype)
    x = x + gate * mix
    new_cache = jax.tree.map(
        lambda new, old: jnp.where(active, new, old), new_cache, cache
    )
    if spec.ff != NO_FF:
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if spec.ff == DENSE_FF:
            ff = mlp.mlp_forward(p["ff"], h, ctx=ctx)
        else:
            ff, _ = moe.moe_forward(p["ff"], h, cfg=cfg, ctx=ctx)
        x = x + gate * ff
    return x, new_cache


def period_decode(period_params, x, caches, active, *, cfg, ctx=LOCAL_CTX,
                  use_pallas=False):
    new_caches = []
    for j, spec in enumerate(cfg.period):
        x, c = layer_decode(
            period_params[j], x, caches[j], active[j], cfg=cfg, spec=spec,
            ctx=ctx, use_pallas=use_pallas,
        )
        new_caches.append(c)
    return x, tuple(new_caches)


def layer_prefill(p, x, active, *, cfg, spec, positions, ctx=LOCAL_CTX, capacity=None):
    """Forward + cache construction (serving prefill)."""
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if spec.mixer == ATTN:
        mix, cache = attention.attn_prefill(
            p["mixer"], h, cfg=cfg, spec=spec, positions=positions, ctx=ctx,
            capacity=capacity,
        )
    elif spec.mixer == MAMBA:
        mix, cache = mamba.mamba_forward(p["mixer"], h, cfg=cfg, ctx=ctx, return_state=True)
    elif spec.mixer == MLSTM:
        mix, cache = xlstm.mlstm_forward(p["mixer"], h, cfg=cfg, ctx=_repl_ctx(ctx), return_state=True)
    elif spec.mixer == SLSTM:
        mix, cache = xlstm.slstm_forward(p["mixer"], h, cfg=cfg, ctx=_repl_ctx(ctx), return_state=True)
    else:  # pragma: no cover
        raise ValueError(spec.mixer)
    gate = jnp.asarray(active, x.dtype)
    x = x + gate * mix
    if spec.ff != NO_FF:
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if spec.ff == DENSE_FF:
            ff = mlp.mlp_forward(p["ff"], h, ctx=ctx)
        else:
            ff, _ = moe.moe_forward(p["ff"], h, cfg=cfg, ctx=ctx)
        x = x + gate * ff
    return x, cache


def period_prefill(period_params, x, active, *, cfg, positions, ctx=LOCAL_CTX, capacity=None):
    caches = []
    for j, spec in enumerate(cfg.period):
        x, c = layer_prefill(
            period_params[j], x, active[j], cfg=cfg, spec=spec, positions=positions,
            ctx=ctx, capacity=capacity,
        )
        caches.append(c)
    return x, tuple(caches)
