"""SwiGLU feed-forward (dense).  Column-parallel gate/up, row-parallel down."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import ParallelCtx, LOCAL_CTX, dense_init


def init_mlp_params(key, cfg: ArchConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, f), dtype),
        "w_up": dense_init(ks[1], (d, f), dtype),
        "w_down": dense_init(ks[2], (f, d), dtype, scale=0.02 / max(1, cfg.n_layers) ** 0.5),
    }


def mlp_forward(
    p: dict,
    x: jax.Array,
    *,
    ctx: ParallelCtx = LOCAL_CTX,
    use_pallas: bool = False,
) -> jax.Array:
    if use_pallas:
        from repro.kernels import ops as kops

        h = kops.swiglu(x, p["w_gate"], p["w_up"])
    else:
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return ctx.psum_tp(h @ p["w_down"])
