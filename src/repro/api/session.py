"""Fluent front door over the paper's workflow ①-⑤.

    from repro.api import session
    s = (session("bert-large", platform="aws", global_batch=64)
         .profile()
         .plan(merge_to=14)
         .simulate()
         .emulate(steps=2))
    s.deployment_plan.save("plan.json")
    print(s.sim_result.t_iter, s.engine_result.t_iter)

Each step stores its artifact on the session and returns ``self``; later
steps trigger earlier ones automatically (``plan`` profiles, ``simulate``
plans).  ``save_plan``/``load_plan`` persist the decision as a
:class:`DeploymentPlan` — loading fingerprint-checks the plan against this
session's freshly built profile.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple, Union

from repro.api.plan import DeploymentPlan, profile_fingerprint
from repro.api.plan_cache import PlanCache, resolve_plan_cache
from repro.core import planner
from repro.core.partition import ModelProfile, merge_layers
from repro.core.profiler import resolve_profile
from repro.serverless.platform import Platform, get_platform

# the paper's §5.1 default weight pair (alpha2 = 2^16 * 1e-9)
DEFAULT_ALPHA: Tuple[float, float] = (1.0, 2**16 * 1e-9)


class InfeasiblePlanError(RuntimeError):
    """The solver found no feasible (partition, memory, d) for the budget —
    typed so callers can distinguish infeasibility from real failures."""


class Session:
    """Mutable builder: model + platform + batch budget -> plan -> replay."""

    def __init__(self, model: str, platform: Union[str, Platform] = "aws", *,
                 global_batch: int = 64, micro_batch: Optional[int] = None,
                 seq: Optional[int] = None, pipelined_sync: bool = True,
                 contention: bool = False,
                 plan_cache: Union[None, bool, str, PlanCache] = None):
        self.model = model
        self.platform = (get_platform(platform)
                         if isinstance(platform, str) else platform)
        self.global_batch = global_batch
        # micro_batch=None means "unspecified": 4 for the M budget (the
        # paper's default micro-batch) and each profile family's own default
        # when profiling; an explicit value — even 4 — is honored and
        # recorded in the plan verbatim
        self.micro_batch = 4 if micro_batch is None else micro_batch
        self._profile_mb: Optional[int] = micro_batch
        self.seq = seq
        self.pipelined_sync = pipelined_sync
        self.contention = contention
        # None/False = solve every time; True = default cache dir; a path or
        # PlanCache = that cache (see repro.api.plan_cache)
        self.plan_cache: Optional[PlanCache] = resolve_plan_cache(plan_cache)

        self.model_profile: Optional[ModelProfile] = None
        self.deployment_plan: Optional[DeploymentPlan] = None
        self.plan_result: Optional[planner.PlanResult] = None  # in-memory twin
        self.plans: List[DeploymentPlan] = []       # sweep results
        self.plan_results: List[planner.PlanResult] = []
        self.recommended: Optional[int] = None      # index into .plans
        self.evaluation = None                      # perfmodel Evaluation
        self.sim_result = None                      # simulator SimResult
        self.engine_result = None                   # runtime EngineResult
        self.calibration = None                     # obs.calibrate.Calibration

    @property
    def total_micro_batches(self) -> int:
        return max(1, self.global_batch // self.micro_batch)

    # ------------------------------------------------------------ workflow ①
    def profile(self) -> "Session":
        """Build the layer profile (paper Fig 2 component ③)."""
        self.model_profile = resolve_profile(
            self.model, self.platform, seq=self.seq,
            micro_batch=self._profile_mb)
        return self

    def _require_profile(self) -> ModelProfile:
        if self.model_profile is None:
            self.profile()
        return self.model_profile

    # ------------------------------------------------------------ workflow ②
    def plan(self, *, alpha: Tuple[float, float] = DEFAULT_ALPHA,
             merge_to: Optional[int] = planner.DEFAULT_MERGE_TO,
             solver: str = "cd", engine: str = "batch",
             d_options: Sequence[int] = planner.DEFAULT_D_OPTIONS,
             max_stages: Optional[int] = None, rounds: int = 100,
             seed: int = 0, workload: str = "train",
             slo: Optional[float] = None, serve_batch: Optional[int] = None,
             prefill_tokens: Optional[int] = None,
             new_tokens: Optional[int] = None) -> "Session":
        """Co-optimize partition + resources; freeze a DeploymentPlan.

        ``solver``: ``cd`` / ``cd-steepest`` / ``exhaustive`` (the
        MIQP-style co-optimizer), ``tpdmp`` or ``bayes`` (the §5.6
        comparison algorithms).
        ``engine``: ``batch`` / ``scalar`` (enumeration, identical plans) or
        ``dp`` (the exact cut-point DP — pair it with ``merge_to=None`` to
        plan at full layer depth).

        ``workload="serve"`` switches the objective to inference serving:
        the SLO-aware planner (:mod:`repro.serving.planner`) minimizes
        $/1k-requests subject to ``slo`` seconds per request, with the
        KV-cache counted in the per-stage memory constraint.  Serve plans
        skip the plan cache (its key covers the training knobs only) and
        replay through :func:`repro.serving.run_serve_plan`, not
        ``emulate``/``simulate``.

        With a ``plan_cache`` attached to the session, the solve is keyed on
        (merged-profile fingerprint, platform, objective, M, solver knobs)
        and a verified cache hit skips the solver entirely.
        """
        if workload == "serve":
            from repro.serving.planner import plan_serving

            if slo is None:
                raise ValueError(
                    "plan(workload='serve') needs slo= (seconds per request)")
            kw = dict(slo=slo, max_stages=max_stages)
            if serve_batch is not None:
                kw["batch"] = serve_batch
            if prefill_tokens is not None:
                kw["prefill_tokens"] = prefill_tokens
            if new_tokens is not None:
                kw["new_tokens"] = new_tokens
            self.deployment_plan = plan_serving(
                self.model, self.platform, **kw)
            self.plan_result = None
            return self
        if workload != "train":
            raise ValueError(
                f"unknown workload {workload!r}; expected train | serve")
        prof = self._require_profile()
        M = self.total_micro_batches

        cache_key = None
        if self.plan_cache is not None:
            merged = (merge_layers(prof, merge_to)
                      if merge_to is not None else prof)
            cache_key = PlanCache.solve_key(
                profile_fingerprint=profile_fingerprint(merged, self.platform),
                platform=self.platform.name, alpha=alpha,
                total_micro_batches=M, solver=solver, engine=engine,
                merge_to=merge_to, d_options=d_options, max_stages=max_stages,
                pipelined_sync=self.pipelined_sync,
                rounds=rounds if solver == "bayes" else None,
                seed=seed if solver == "bayes" else None)
            rp = None

            def _verify(plan, merged=merged):
                nonlocal rp
                rp = plan.resolve(profile=merged, platform=self.platform)

            cached = self.plan_cache.get(cache_key, verify=_verify)
            if cached is not None:
                from repro.core.perfmodel import evaluate

                ev = evaluate(rp.profile, rp.platform, rp.config,
                              rp.total_micro_batches,
                              pipelined_sync=rp.pipelined_sync)
                self.plan_result = planner.PlanResult(
                    rp.config, ev, ev.objective(*alpha),
                    cached.solve_seconds, rp.profile)
                self.deployment_plan = cached
                return self

        common = dict(alpha=alpha, total_micro_batches=M, merge_to=merge_to,
                      d_options=d_options, pipelined_sync=self.pipelined_sync)
        if solver in ("cd", "cd-steepest", "exhaustive"):
            r = planner.solve(prof, self.platform, method=solver,
                              engine=engine, max_stages=max_stages, **common)
        elif solver == "tpdmp":
            r = planner.tpdmp_solve(prof, self.platform, engine=engine,
                                    **common)
        elif solver == "bayes":
            if engine != "batch":
                raise ValueError(
                    f"solver='bayes' has no {engine!r} engine: it samples "
                    "through the batched kernel only (engine='batch')")
            r = planner.bayes_solve(prof, self.platform, rounds=rounds,
                                    seed=seed, **common)
        else:
            raise ValueError(f"unknown solver {solver!r}")
        if r is None:
            raise InfeasiblePlanError(
                f"no feasible plan for {self.model} on {self.platform.name} "
                f"at M={M} (try a smaller batch or another platform)")
        self.plan_result = r
        self.deployment_plan = DeploymentPlan.from_result(
            r, model=self.model, platform=self.platform, alpha=alpha,
            total_micro_batches=M, pipelined_sync=self.pipelined_sync,
            solver=solver, engine=engine, merge_to=merge_to, seq=self.seq,
            micro_batch=self._profile_mb)
        if cache_key is not None:
            self.plan_cache.put(cache_key, self.deployment_plan)
        return self

    def sweep(self, *, alphas: Optional[Sequence[Tuple[float, float]]] = None,
              **plan_kw) -> "Session":
        """Plan across the paper's objective-weight pairs; pick the §5.1
        recommendation (fastest plan with speedup/cost ratio >= 0.8)."""
        from repro.serverless.frameworks import ALPHA_PAIRS

        self._require_profile()
        self.plans, self.plan_results = [], []
        for alpha in (ALPHA_PAIRS if alphas is None else alphas):
            try:
                self.plan(alpha=alpha, **plan_kw)
            except InfeasiblePlanError:
                continue
            if self.deployment_plan.config not in [p.config for p in self.plans]:
                self.plans.append(self.deployment_plan)
                self.plan_results.append(self.plan_result)
        if not self.plans:
            raise InfeasiblePlanError(
                f"no feasible plan for {self.model} on {self.platform.name} "
                "at any objective weight")
        rec = planner.recommend(self.plan_results)
        self.recommended = self.plan_results.index(rec)
        self.deployment_plan = self.plans[self.recommended]
        self.plan_result = self.plan_results[self.recommended]
        return self

    # ----------------------------------------------------------- replay paths
    def _require_plan(self) -> DeploymentPlan:
        if self.deployment_plan is None:
            self.plan()
        return self.deployment_plan

    def evaluate(self) -> "Session":
        """Closed-form model prediction for the current plan."""
        self.evaluation = self._require_plan().evaluate(
            profile=self._merged_profile(), platform=self.platform)
        return self

    def simulate(self, *, trace: bool = False) -> "Session":
        """Replay the plan through the analytic discrete-event simulator.
        ``trace=True`` attaches the predicted spans (``sim_result.trace``)."""
        self.sim_result = self._require_plan().simulate(
            contention=self.contention, trace=trace,
            profile=self._merged_profile(),
            platform=self.platform)
        return self

    def emulate(self, exec_config=None, *, steps=None, execution=None,
                backend=None, trace=None, faults=None, tolerance=None,
                payload_true=None, throttle=None,
                bandwidth=None) -> "Session":
        """Execute the plan through the storage-backed runtime engine.

        How to execute is an :class:`repro.serverless.execution.
        ExecutionConfig` (backend, steps, tracing, the process backend's
        payload-true/throttle/bandwidth calibration axes, fault injection
        and recovery policy); the individual keywords are the deprecated
        legacy spelling shimmed through the same config.  ``trace=True``
        records per-worker spans (``engine_result.trace``) — the input
        :meth:`calibrate` folds back into a measured profile."""
        from repro.serverless.execution import ExecutionConfig

        ec = ExecutionConfig.merge(
            exec_config,
            dict(backend=backend, steps=steps, trace=trace, faults=faults,
                 tolerance=tolerance, payload_true=payload_true,
                 throttle=throttle, bandwidth=bandwidth),
            where="Session.emulate")
        self.engine_result = self._require_plan().emulate(
            ec, contention=self.contention, execution=execution,
            profile=self._merged_profile(), platform=self.platform)
        return self

    # ------------------------------------------------------ calibration loop
    def calibrate(self, *, warmup: Optional[int] = None) -> "Session":
        """Fold the last traced emulation back into a *measured* profile.

        Requires a prior ``.emulate(ExecutionConfig(trace=True, ...))``.
        The session's profile is replaced by the measured one (already at
        the plan's merged depth — subsequent merging is a no-op), so a
        following ``.plan(...)`` re-solves against observed reality; the
        :class:`repro.obs.calibrate.Calibration` artifact (observations,
        per-stage scales, named perf-model warnings, residuals) lands on
        ``self.calibration``."""
        from repro.obs.calibrate import calibrate_profile

        if self.engine_result is None or self.engine_result.trace is None:
            raise ValueError(
                "calibrate() needs a traced emulation first — call "
                ".emulate(ExecutionConfig(trace=True, ...)) on this session")
        plan = self.deployment_plan
        rp = plan.resolve(profile=self._merged_profile(),
                          platform=self.platform)
        cal = calibrate_profile(
            self.engine_result.trace, rp.profile, rp.platform, rp.config,
            rp.total_micro_batches, pipelined_sync=rp.pipelined_sync,
            warmup=warmup)
        self.calibration = cal
        self.model_profile = cal.profile
        return self

    def _merged_profile(self) -> ModelProfile:
        plan = self.deployment_plan
        prof = self._require_profile()
        if plan.merge_to is not None:
            prof = merge_layers(prof, plan.merge_to)
        return prof

    # ------------------------------------------------------------ persistence
    def save_plan(self, path) -> "Session":
        self._require_plan().save(path)
        return self

    def load_plan(self, path) -> "Session":
        """Load a saved plan and fingerprint-check it against this session's
        freshly built profile (raises PlanCompatibilityError on drift)."""
        plan = DeploymentPlan.load(path)
        prof = self._require_profile()
        if plan.merge_to is not None:
            prof = merge_layers(prof, plan.merge_to)
        plan.resolve(profile=prof, platform=self.platform)  # raises on drift
        self.deployment_plan = plan
        self.plan_result = None
        return self


def session(model: str, platform: Union[str, Platform] = "aws",
            **kw) -> Session:
    """Entry point: ``repro.api.session("bert-large", platform="aws")``."""
    return Session(model, platform, **kw)
