"""The serializable deployment artifact of the paper's workflow ①-⑤.

A :class:`DeploymentPlan` freezes one co-optimization decision — model,
platform, partition ``x``, per-layer memory ``z``, DP degree ``d``, the
micro-batch budget, the objective weights and the solver's predicted
time/cost — together with a fingerprint of the (merged) layer profile the
decision indexes into.  It round-trips through JSON (``to_json`` /
``from_json``), has a stable content hash, and is accepted directly by the
analytic simulator (``simulate_funcpipe``), the storage-backed engine
(``runtime.run_plan``) and the framework baselines: plan once, save the
JSON, simulate or emulate later — bit-identically.

Replaying rebuilds the profile through ``profiler.resolve_profile`` with the
recorded ``(model, seq, micro_batch, merge_to)`` and verifies it against the
stored fingerprint; a mismatch (profiler drift, edited JSON, wrong platform)
raises :class:`PlanCompatibilityError` instead of silently mis-executing.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.core.partition import ModelProfile, merge_layers, stages_of
from repro.core.perfmodel import Config, Evaluation, evaluate
from repro.serverless.platform import MB, Platform, get_platform

SCHEMA_VERSION = 1


class PlanCompatibilityError(RuntimeError):
    """A DeploymentPlan does not match the profile/platform it is replayed
    against (stale profiler, edited JSON, wrong platform or merge depth)."""


def profile_fingerprint(profile: ModelProfile,
                        platform: Optional[Platform] = None) -> str:
    """Stable 16-hex digest of a layer profile's quantitative content.

    With ``platform`` given, the platform's own parameters (pricing,
    bandwidth curve, storage latency/caps, contention beta) are folded in —
    the compute tables embed some platform behavior but not the cost and
    communication constants, and a plan replayed after those drift would
    otherwise pass the guard and silently report different numbers.

    Profile *provenance* is folded in only for non-analytic sources: every
    pre-provenance fingerprint (saved plans, plan-cache keys) stays
    byte-stable, while a measured profile — even one whose numbers happen to
    coincide with the analytic tables — can never collide with an analytic
    plan-cache entry."""
    arr = profile.arrays()
    h = hashlib.sha256()
    h.update(f"{profile.name}:{profile.L}".encode())
    for key in ("s", "a", "o", "g", "Tf", "Tb"):
        h.update(key.encode())
        h.update(np.ascontiguousarray(arr[key], dtype=np.float64).tobytes())
    if platform is not None:
        h.update(json.dumps(dataclasses.asdict(platform),
                            sort_keys=True).encode())
    if getattr(profile, "source", "analytic") != "analytic":
        h.update(f"source={profile.source}".encode())
        if profile.calibration is not None:
            h.update(json.dumps(dataclasses.asdict(profile.calibration),
                                sort_keys=True).encode())
    return h.hexdigest()[:16]


@dataclass(frozen=True)
class ResolvedPlan:
    """A DeploymentPlan bound back to live objects, ready to execute."""

    profile: ModelProfile         # merged profile the config indexes into
    platform: Platform
    config: Config
    total_micro_batches: int
    pipelined_sync: bool


@dataclass(frozen=True)
class DeploymentPlan:
    """One deployable FuncPipe configuration, serializable and replayable."""

    model: str                    # profiler-resolvable model id
    platform: str                 # Platform.name (see platform.get_platform)
    x: Tuple[int, ...]            # partition boundary bits, len L-1
    z: Tuple[int, ...]            # per-layer memory option index, len L
    d: int                        # data-parallel degree
    total_micro_batches: int      # M (= global_batch / micro_batch)
    alpha: Tuple[float, float]    # objective weights (a1 cost, a2 time)
    pipelined_sync: bool          # eq (2) collective vs eq (1)
    merge_to: Optional[int]       # layer-merge depth (None = unmerged)
    seq: Optional[int]            # profile arg (arch models; None = default)
    micro_batch: Optional[int]    # profile arg (None = family default)
    profile_fingerprint: str      # fingerprint of the MERGED profile
    t_iter: float                 # solver-predicted iteration time (s)
    c_iter: float                 # solver-predicted cost ($ / iteration)
    objective: float              # a1 * c_iter + a2 * t_iter
    solver: str                   # cd | exhaustive | tpdmp | bayes | manual
    engine: str                   # batch | scalar | dp | -
    solve_seconds: float          # provenance only; excluded from the hash
    profile_source: str = "analytic"   # provenance of the solved-against
    #                                    profile: analytic | measured
    workload: str = "train"            # train | serve
    serving: Optional[dict] = None     # serve-workload record (SLO, request
    #                                    shape, latency/cost breakdown) —
    #                                    present iff workload == "serve"
    version: int = SCHEMA_VERSION

    # ------------------------------------------------------------ properties
    @property
    def config(self) -> Config:
        return Config(x=self.x, d=self.d, z=self.z)

    @property
    def n_stages(self) -> int:
        return sum(self.x) + 1

    @property
    def n_workers(self) -> int:
        return self.n_stages * self.d

    @property
    def content_hash(self) -> str:
        """Stable digest of the plan's *content*: identical decisions hash
        identically regardless of which solver/engine found them or how long
        the solve took — ``solver``, ``engine`` and ``solve_seconds`` are
        provenance, not content, and are excluded (a dp-engine plan and a
        batch-engine plan that chose the same (x, z, d, M) are the same
        deployment)."""
        d = self._as_dict()
        for prov in ("solve_seconds", "solver", "engine"):
            d.pop(prov)
        blob = json.dumps(d, sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    # ---------------------------------------------------------- construction
    @classmethod
    def from_result(cls, result, *, platform: Platform,
                    alpha: Tuple[float, float], total_micro_batches: int,
                    model: Optional[str] = None, pipelined_sync: bool = True,
                    solver: str = "cd", engine: str = "batch",
                    merge_to: Optional[int] = None, seq: Optional[int] = None,
                    micro_batch: Optional[int] = None) -> "DeploymentPlan":
        """Freeze a ``planner.PlanResult`` (any solver path) into a plan."""
        cfg, ev = result.config, result.evaluation
        return cls(
            model=model if model is not None else result.profile.name,
            platform=platform.name,
            x=tuple(int(v) for v in cfg.x), z=tuple(int(v) for v in cfg.z),
            d=int(cfg.d), total_micro_batches=int(total_micro_batches),
            alpha=(float(alpha[0]), float(alpha[1])),
            pipelined_sync=bool(pipelined_sync), merge_to=merge_to,
            seq=seq, micro_batch=micro_batch,
            profile_fingerprint=profile_fingerprint(result.profile, platform),
            t_iter=float(ev.t_iter), c_iter=float(ev.c_iter),
            objective=float(result.objective), solver=solver, engine=engine,
            solve_seconds=float(result.solve_seconds),
            profile_source=result.profile.source,
        )

    @classmethod
    def from_config(cls, profile: ModelProfile, platform: Platform,
                    config: Config, total_micro_batches: int, *,
                    model: Optional[str] = None, pipelined_sync: bool = True,
                    merge_to: Optional[int] = None, seq: Optional[int] = None,
                    micro_batch: Optional[int] = None,
                    solver: str = "manual") -> "DeploymentPlan":
        """Freeze a hand-built configuration (e.g. the numeric-emulation
        partition); predictions come from the closed-form model."""
        ev: Evaluation = evaluate(profile, platform, config,
                                  total_micro_batches,
                                  pipelined_sync=pipelined_sync)
        return cls(
            model=model if model is not None else profile.name,
            platform=platform.name,
            x=tuple(int(v) for v in config.x),
            z=tuple(int(v) for v in config.z), d=int(config.d),
            total_micro_batches=int(total_micro_batches),
            alpha=(1.0, 0.0), pipelined_sync=bool(pipelined_sync),
            merge_to=merge_to, seq=seq, micro_batch=micro_batch,
            profile_fingerprint=profile_fingerprint(profile, platform),
            t_iter=float(ev.t_iter), c_iter=float(ev.c_iter),
            objective=float(ev.c_iter), solver=solver, engine="-",
            solve_seconds=0.0, profile_source=profile.source,
        )

    # --------------------------------------------------------- serialization
    def _as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["x"], d["z"] = list(self.x), list(self.z)
        d["alpha"] = list(self.alpha)
        return d

    def to_json(self, *, indent: Optional[int] = 2) -> str:
        return json.dumps(self._as_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, blob: str) -> "DeploymentPlan":
        d = json.loads(blob)
        version = d.get("version", 0)
        if version != SCHEMA_VERSION:
            raise PlanCompatibilityError(
                f"plan schema version {version} != supported {SCHEMA_VERSION}")
        # pre-provenance plans (PR <= 8) predate profile_source; they were
        # by construction solved against analytic profiles
        d.setdefault("profile_source", "analytic")
        # pre-serving plans (PR <= 9) predate the workload axis; every saved
        # plan was a training plan
        d.setdefault("workload", "train")
        d.setdefault("serving", None)
        names = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - names
        if unknown:
            raise PlanCompatibilityError(
                f"plan JSON has unknown fields {sorted(unknown)}")
        missing = names - set(d)
        if missing:
            raise PlanCompatibilityError(
                f"plan JSON is missing fields {sorted(missing)}")
        d["x"] = tuple(int(v) for v in d["x"])
        d["z"] = tuple(int(v) for v in d["z"])
        d["alpha"] = tuple(float(v) for v in d["alpha"])
        return cls(**d)

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path) -> "DeploymentPlan":
        with open(path) as f:
            return cls.from_json(f.read())

    # -------------------------------------------------------------- resolve
    def resolve(self, *, profile: Optional[ModelProfile] = None,
                platform: Optional[Platform] = None,
                check: bool = True) -> ResolvedPlan:
        """Bind the plan back to live objects, verifying compatibility.

        ``profile`` (already merged) and ``platform`` override the recorded
        identifiers — the profile is still fingerprint-checked, so passing a
        freshly built profile that drifted from the one the plan was solved
        against raises :class:`PlanCompatibilityError`.
        """
        from repro.core.profiler import resolve_profile

        if platform is None:
            try:
                platform = get_platform(self.platform)
            except KeyError as e:
                raise PlanCompatibilityError(str(e)) from None
        if profile is None:
            if self.profile_source != "analytic":
                raise PlanCompatibilityError(
                    f"plan for {self.model!r} was solved against a "
                    f"{self.profile_source} profile, which the profiler "
                    "cannot rebuild (it only derives analytic tables) — "
                    "pass the measured profile explicitly "
                    "(ModelProfile.load(...) via profile=, or "
                    "`repro simulate/emulate --profile measured.json`)")
            try:
                full = resolve_profile(self.model, platform, seq=self.seq,
                                       micro_batch=self.micro_batch)
            except KeyError as e:
                raise PlanCompatibilityError(str(e)) from None
            profile = (merge_layers(full, self.merge_to)
                       if self.merge_to is not None else full)
        if check:
            got = profile_fingerprint(profile, platform)
            if got != self.profile_fingerprint:
                src = getattr(profile, "source", "analytic")
                why = (
                    f"  Profile source mismatch: the plan was solved "
                    f"against a {self.profile_source} profile but a "
                    f"{src} profile was supplied."
                    if src != self.profile_source else
                    "  The profiler or platform model changed since the "
                    "plan was saved — re-plan, or pass the original "
                    "profile explicitly.")
                raise PlanCompatibilityError(
                    f"profile/platform fingerprint mismatch for model "
                    f"{self.model!r} on {platform.name}: plan was solved "
                    f"against {self.profile_fingerprint} "
                    f"({self.profile_source}), freshly built state is "
                    f"{got} ({src}; L={profile.L}, "
                    f"merge_to={self.merge_to}).{why}")
        L = profile.L
        if len(self.x) != L - 1 or len(self.z) != L:
            raise PlanCompatibilityError(
                f"plan indexes {len(self.z)} layers but profile "
                f"{profile.name!r} has {L}")
        J = len(platform.memory_options)
        if any(not 0 <= j < J for j in self.z):
            raise PlanCompatibilityError(
                f"plan memory indices {self.z} out of range for platform "
                f"{platform.name!r} with {J} memory options")
        return ResolvedPlan(profile=profile, platform=platform,
                            config=self.config,
                            total_micro_batches=self.total_micro_batches,
                            pipelined_sync=self.pipelined_sync)

    def _require_train(self, what: str) -> None:
        """Training-only entry points reject serve plans with a pointer at
        the serving front door instead of mis-executing them as a 1-step
        training run."""
        if self.workload != "train":
            raise PlanCompatibilityError(
                f"{what} executes *training* plans; this plan for "
                f"{self.model!r} has workload={self.workload!r}. Serve it "
                "through `repro serve` / "
                "repro.serving.run_serve_plan(plan) instead.")

    # ------------------------------------------------------------- execution
    def evaluate(self, **resolve_kw) -> Evaluation:
        """Closed-form performance model prediction (eq 6/7)."""
        self._require_train("DeploymentPlan.evaluate")
        rp = self.resolve(**resolve_kw)
        return evaluate(rp.profile, rp.platform, rp.config,
                        rp.total_micro_batches,
                        pipelined_sync=rp.pipelined_sync)

    def simulate(self, *, contention: bool = False, trace: bool = False,
                 **resolve_kw):
        """Replay through the analytic discrete-event simulator.
        ``trace=True`` materializes the DP's predicted spans as
        ``SimResult.trace`` (``repro.obs.Trace``)."""
        from repro.serverless.simulator import simulate_funcpipe

        self._require_train("DeploymentPlan.simulate")
        rp = self.resolve(**resolve_kw)
        return simulate_funcpipe(rp.profile, rp.platform, rp.config,
                                 rp.total_micro_batches,
                                 pipelined_sync=rp.pipelined_sync,
                                 contention=contention, trace=trace)

    def emulate(self, exec_config=None, *, steps=None, contention: bool = False,
                execution=None, backend=None, trace=None,
                faults=None, tolerance=None, payload_true=None,
                throttle=None, bandwidth=None, **resolve_kw):
        """Execute through the storage-backed engine on an execution
        backend: ``"emulated"`` (virtual-clock cost model), ``"local"``
        (real concurrent workers, wall-clock), ``"process"`` (real OS
        worker processes over a file store), or any registered
        :class:`repro.serverless.backends.ExecutionBackend`.  The same saved
        plan JSON drives every backend unmodified.

        How to execute is an :class:`repro.serverless.execution.
        ExecutionConfig` — backend, steps, tracing, the process backend's
        ``payload_true``/``throttle``/``bandwidth`` calibration axes,
        ``faults`` chaos injection and ``tolerance`` recovery policy.  The
        individual keywords are the deprecated legacy spelling shimmed
        through the same config (never mix the two).  ``trace=True``
        records per-worker spans on the backend's clock
        (``EngineResult.trace``) with this plan's document embedded in the
        trace metadata, so ``repro calibrate`` can re-plan straight from
        the file."""
        from repro.serverless.execution import ExecutionConfig
        from repro.serverless.runtime import run_plan

        self._require_train("DeploymentPlan.emulate")
        ec = ExecutionConfig.merge(
            exec_config,
            dict(backend=backend, steps=steps, trace=trace, faults=faults,
                 tolerance=tolerance, payload_true=payload_true,
                 throttle=throttle, bandwidth=bandwidth),
            where="DeploymentPlan.emulate")
        rp = self.resolve(**resolve_kw)
        res = run_plan(rp.profile, rp.platform, rp.config,
                       rp.total_micro_batches, ec,
                       pipelined_sync=rp.pipelined_sync,
                       contention=contention, execution=execution)
        if res.trace is not None:
            res.trace.meta["plan"] = self._as_dict()
        return res

    # ------------------------------------------------------------ describing
    def describe(self) -> str:
        try:
            platform = get_platform(self.platform)
        except KeyError as e:
            raise PlanCompatibilityError(str(e)) from None
        st = stages_of(self.x)
        mems = [platform.memory_options[self.z[lo]] // MB for lo, _ in st]
        if self.workload == "serve":
            sv = self.serving or {}
            return (f"{self.model} on {self.platform} [serve]: {len(st)} "
                    f"stages, mem={mems}MB, batch={sv.get('batch')}, "
                    f"prefill={sv.get('prefill_tokens')} "
                    f"new={sv.get('new_tokens')} tokens, "
                    f"SLO={sv.get('slo_s')}s, predicted "
                    f"t_request={self.t_iter:.3f}s "
                    f"cost=${sv.get('cost_per_1k', 1000 * self.c_iter):.4f}"
                    f"/1k-req [{self.solver}/{self.engine}, "
                    f"hash {self.content_hash}]")
        mu = max(1, self.total_micro_batches // self.d)
        return (f"{self.model} on {self.platform}: {len(st)} stages x "
                f"d={self.d} ({self.n_workers} workers), mem={mems}MB, "
                f"M={self.total_micro_batches} (mu={mu}/worker), "
                f"sync={'eq(2)' if self.pipelined_sync else 'eq(1)'}, "
                f"predicted t_iter={self.t_iter:.3f}s "
                f"cost=${self.c_iter:.6f}/iter "
                f"[{self.solver}/{self.engine}, hash {self.content_hash}]")
