"""Content-addressed cache of solved DeploymentPlans.

Solving is the expensive step of the paper's workflow — seconds to minutes
per (model, platform, objective) point — yet the decision is a pure function
of the merged profile, the platform and the solver knobs.  This cache keys a
solved :class:`~repro.api.plan.DeploymentPlan` on exactly those inputs (the
same quantities ``DeploymentPlan`` records and fingerprints) so repeated
``repro sweep`` / ``Session.plan`` runs are near-instant.

Safety over speed, twice:

* the key folds in :func:`~repro.api.plan.profile_fingerprint` of the
  *merged* profile + platform, so a profiler or platform-model change is a
  cache miss, never a stale hit;
* every hit is additionally verified through ``plan.resolve(profile=...)``
  before use — a corrupted or hand-edited cache file degrades to a re-solve.

Entries are one plan JSON per file under the cache root (default
``$REPRO_PLAN_CACHE`` or ``~/.cache/repro/plans``), named by a digest of the
solve inputs; delete the directory to flush.  ``--no-plan-cache`` at the CLI
(or ``Session(plan_cache=False)``) bypasses it entirely.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from pathlib import Path
from typing import Optional, Union

from repro.api.plan import DeploymentPlan

_ENV_VAR = "REPRO_PLAN_CACHE"


def default_cache_dir() -> Path:
    env = os.environ.get(_ENV_VAR)
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro" / "plans"


class PlanCache:
    """Disk-backed DeploymentPlan cache, one JSON file per solve key."""

    def __init__(self, root: Union[str, Path, None] = None):
        self.root = Path(root) if root is not None else default_cache_dir()
        self.hits = 0
        self.misses = 0
        self.evictions = 0   # corrupt/stale entries unlinked during get()

    # ------------------------------------------------------------------ keys
    @staticmethod
    def solve_key(*, profile_fingerprint: str, platform: str, alpha,
                  total_micro_batches: int, solver: str, engine: str,
                  merge_to, d_options, max_stages, pipelined_sync: bool,
                  rounds: Optional[int] = None,
                  seed: Optional[int] = None) -> str:
        """Digest of everything that determines the solver's decision.

        ``solver``/``engine`` are included even though ``content_hash``
        treats them as provenance: different engines may legitimately return
        different (equally scored) plans, and a cache must never change
        *which* plan a given command returns."""
        blob = json.dumps({
            "fp": profile_fingerprint, "platform": platform,
            "alpha": [float(a) for a in alpha],
            "M": int(total_micro_batches), "solver": solver, "engine": engine,
            "merge_to": merge_to,
            "d_options": [int(d) for d in d_options],
            "max_stages": max_stages, "pipelined_sync": bool(pipelined_sync),
            "rounds": rounds, "seed": seed,
        }, sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()[:24]

    def _path(self, key: str) -> Path:
        return self.root / f"plan-{key}.json"

    # ---------------------------------------------------------------- lookup
    def get(self, key: str, verify=None) -> Optional[DeploymentPlan]:
        """The cached plan for ``key``, or None.  Unreadable, corrupt or
        ``verify``-failing entries are evicted and count as misses — a hit
        is only ever a plan that will actually be used (``verify`` is the
        caller's resolve check; an exception or falsy return rejects)."""
        path = self._path(key)
        try:
            plan = DeploymentPlan.load(path)
            if verify is not None:
                verify(plan)
        except FileNotFoundError:
            self.misses += 1
            return None
        except Exception:
            # corrupt / stale-schema / drifted entry: evict and re-solve
            try:
                path.unlink()
            except OSError:
                pass
            self.evictions += 1
            self.misses += 1
            return None
        self.hits += 1
        return plan

    def put(self, key: str, plan: DeploymentPlan) -> None:
        self.root.mkdir(parents=True, exist_ok=True)
        # per-process-unique tmp + atomic replace: concurrent solvers of the
        # same key cannot interleave into a corrupt entry
        fd, tmp = tempfile.mkstemp(prefix=f"plan-{key}.", suffix=".tmp",
                                   dir=self.root)
        try:
            with os.fdopen(fd, "w") as f:
                f.write(plan.to_json() + "\n")
            os.replace(tmp, self._path(key))
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise


def resolve_plan_cache(
        spec: Union[None, bool, str, Path, PlanCache]) -> Optional[PlanCache]:
    """Session/CLI cache spec: False/None -> disabled, True -> default dir,
    a path -> that dir, an instance -> itself."""
    if spec is None or spec is False:
        return None
    if spec is True:
        return PlanCache()
    if isinstance(spec, PlanCache):
        return spec
    return PlanCache(spec)
