"""Unified deployment API: one typed front door over the repro's
profile -> co-optimize -> simulate/emulate pipeline (paper workflow ①-⑤).

    from repro.api import session, DeploymentPlan

    s = session("bert-large", platform="aws").profile().plan(merge_to=14)
    s.save_plan("plan.json").simulate().emulate(steps=2)

    plan = DeploymentPlan.load("plan.json")   # later / elsewhere
    plan.simulate(); plan.emulate(steps=2)    # bit-identical replay

The CLI counterpart is ``python -m repro`` (see ``repro.cli``).
"""
from repro.api.plan import (
    DeploymentPlan,
    PlanCompatibilityError,
    ResolvedPlan,
    profile_fingerprint,
)
from repro.api.plan_cache import PlanCache, resolve_plan_cache
from repro.api.session import (
    DEFAULT_ALPHA,
    InfeasiblePlanError,
    Session,
    session,
)
from repro.serverless.execution import ExecutionConfig

__all__ = [
    "DeploymentPlan",
    "ExecutionConfig",
    "InfeasiblePlanError",
    "PlanCache",
    "PlanCompatibilityError",
    "ResolvedPlan",
    "profile_fingerprint",
    "resolve_plan_cache",
    "Session",
    "session",
    "DEFAULT_ALPHA",
]
