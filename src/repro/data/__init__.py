from repro.data.synthetic import make_batch, batch_iterator  # noqa: F401
from repro.data.specs import input_specs  # noqa: F401
