"""ShapeDtypeStruct stand-ins for every model input (dry-run / lowering).

No device allocation happens here; the launch layer attaches NamedShardings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape


def input_specs(cfg: ArchConfig, shape: InputShape, *, global_batch: int | None = None) -> dict:
    B = global_batch if global_batch is not None else shape.global_batch
    S = shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        if cfg.frontend == "audio":
            specs = {
                "frames": sds((B, S, cfg.d_model), jnp.float32),
                "labels": sds((B, S), jnp.int32),
            }
        elif cfg.frontend == "vision":
            specs = {
                "tokens": sds((B, S), jnp.int32),
                "image_embeds": sds((B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32),
                "labels": sds((B, S), jnp.int32),
            }
        else:
            specs = {
                "tokens": sds((B, S), jnp.int32),
                "labels": sds((B, S), jnp.int32),
            }
        return specs
    if shape.kind == "prefill":
        if cfg.frontend == "audio":
            return {"frames": sds((B, S, cfg.d_model), jnp.float32)}
        if cfg.frontend == "vision":
            return {
                "tokens": sds((B, S), jnp.int32),
                "image_embeds": sds((B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32),
            }
        return {"tokens": sds((B, S), jnp.int32)}
    if shape.kind == "decode":
        # caches are produced separately (launch layer / init_decode_caches)
        return {"tokens": sds((B, 1), jnp.int32)}
    raise ValueError(shape.kind)
