"""Deterministic synthetic data pipeline.

Batches are a pure function of (seed, step, shard) so every data-parallel
worker regenerates its own shard without any host coordination — the
serverless-friendly "shared-nothing" loader the paper's workers use, adapted
to SPMD: the global batch is logically [global_batch, seq]; shard w of n takes
rows [w*B/n, (w+1)*B/n).
"""
from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, InputShape
from repro.models import multimodal

ZIPF_S = 1.2  # token unigram skew: learnable signal (uniform tokens would
              # pin the optimal CE at ln(V), making loss curves flat)


def _zipf_logits(vocab: int) -> jax.Array:
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    return -ZIPF_S * jnp.log(ranks)


def sample_tokens(key, shape, vocab: int) -> jax.Array:
    logits = jnp.broadcast_to(_zipf_logits(vocab), (*shape, vocab))
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def make_batch(
    cfg: ArchConfig,
    shape: InputShape,
    *,
    seed: int = 0,
    step: int = 0,
    shard: int = 0,
    n_shards: int = 1,
    global_batch: int | None = None,
    seq_len: int | None = None,
) -> dict:
    B_g = global_batch if global_batch is not None else shape.global_batch
    S = seq_len if seq_len is not None else shape.seq_len
    assert B_g % n_shards == 0
    B = B_g // n_shards
    key = jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(seed), step), shard)
    k1, k2, k3 = jax.random.split(key, 3)

    if shape.kind == "train":
        if cfg.frontend == "audio":
            frames = multimodal.synth_audio_frames(k1, cfg, B, S)
            labels = sample_tokens(k2, (B, S), cfg.vocab_size)
            return {"frames": frames, "labels": labels}
        tokens = sample_tokens(k1, (B, S), cfg.vocab_size)
        batch = {"tokens": tokens, "labels": tokens}  # next-token LM objective
        if cfg.frontend == "vision":
            batch["image_embeds"] = multimodal.synth_patch_embeds(k3, cfg, B)
        return batch
    if shape.kind == "prefill":
        if cfg.frontend == "audio":
            return {"frames": multimodal.synth_audio_frames(k1, cfg, B, S)}
        batch = {"tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab_size, jnp.int32)}
        if cfg.frontend == "vision":
            batch["image_embeds"] = multimodal.synth_patch_embeds(k3, cfg, B)
        return batch
    if shape.kind == "decode":
        return {"tokens": jax.random.randint(k1, (B, 1), 0, cfg.vocab_size, jnp.int32)}
    raise ValueError(shape.kind)


def batch_iterator(
    cfg: ArchConfig,
    shape: InputShape,
    *,
    seed: int = 0,
    shard: int = 0,
    n_shards: int = 1,
    global_batch: int | None = None,
    seq_len: int | None = None,
) -> Iterator[dict]:
    step = 0
    while True:
        yield make_batch(
            cfg, shape, seed=seed, step=step, shard=shard, n_shards=n_shards,
            global_batch=global_batch, seq_len=seq_len,
        )
        step += 1
