"""Ring collectives — the TPU mapping of the paper's storage-based
scatter-reduce (§3.3).

The paper's insight is that LambdaML's 3-phase scatter-reduce leaves the
uplink idle while downloading and vice versa (eq (1): 3s/w − 2s/(nw)); its
pipelined schedule drives both directions at once (eq (2): 2s/w).  On a TPU
torus the same resource exists natively: each ICI link is full duplex.  A
*unidirectional* ring reduce-scatter/all-gather (the LambdaML-equivalent
baseline) moves N(D−1)/D bytes through one direction serially; the
*bidirectional* ring splits every chunk in half and runs two opposing rings
concurrently, halving wall-clock steps exactly as eq (1)→eq (2) halves
storage round-trips.

These functions run *inside shard_map* and operate on gradients/parameters
outside of AD (ZeRO-style sync), so no custom_vjp is required; the in-graph
collectives (psum / ppermute / all_to_all) carry their own transpose rules.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax


# ----------------------------------------------------------------- mesh groups
def tp_groups(stages: int, tp: int) -> list[list[int]]:
    """Sub-groups of the 'model' axis: device m = stage*tp + t."""
    return [[s * tp + t for t in range(tp)] for s in range(stages)]


def stage_peers(stages: int, tp: int) -> list[list[int]]:
    """Groups of devices holding the same tp slice across stages."""
    return [[s * tp + t for s in range(stages)] for t in range(tp)]


def pipeline_perm(stages: int, tp: int) -> list[tuple[int, int]]:
    """(src, dst) pairs moving activations stage s -> s+1 (no wraparound)."""
    return [
        (s * tp + t, (s + 1) * tp + t)
        for s in range(stages - 1)
        for t in range(tp)
    ]


# ------------------------------------------------------------- ring primitives
def _take_chunk(chunks: jax.Array, i) -> jax.Array:
    """chunks [D, c, ...]; dynamic index i."""
    return jax.lax.dynamic_index_in_dim(chunks, i, axis=0, keepdims=False)


def _ring_reduce_scatter_1d(
    x: jax.Array, axis_name: str, *, reverse: bool = False
) -> jax.Array:
    """x local [D*c, ...] -> reduced chunk [c, ...] (device i owns chunk i).

    Rightward ring (reverse=False): packet for chunk i starts at device i+1
    and arrives at i after D-1 hops, each hop adding the local copy.
    """
    D = lax.axis_size(axis_name)
    if D == 1:
        return x
    idx = lax.axis_index(axis_name)
    assert x.shape[0] % D == 0
    chunks = x.reshape(D, x.shape[0] // D, *x.shape[1:])
    sgn = -1 if reverse else 1
    perm = [(i, (i + sgn) % D) for i in range(D)]
    buf = _take_chunk(chunks, (idx - sgn) % D)
    for s in range(D - 1):
        buf = lax.ppermute(buf, axis_name, perm)
        buf = buf + _take_chunk(chunks, (idx - sgn * (2 + s)) % D)
    return buf


def _ring_all_gather_1d(
    x: jax.Array, axis_name: str, *, reverse: bool = False
) -> jax.Array:
    """x local chunk [c, ...] -> gathered [D*c, ...] in global order."""
    D = lax.axis_size(axis_name)
    if D == 1:
        return x
    idx = lax.axis_index(axis_name)
    sgn = -1 if reverse else 1
    # receive from the 'next' device: after k steps we hold chunk idx + k*sgn
    perm = [((i + sgn) % D, i) for i in range(D)]
    out = jnp.zeros((D, *x.shape), x.dtype)
    out = lax.dynamic_update_index_in_dim(out, x, idx, axis=0)
    cur = x
    for k in range(1, D):
        cur = lax.ppermute(cur, axis_name, perm)
        out = lax.dynamic_update_index_in_dim(out, cur, (idx + sgn * k) % D, axis=0)
    return out.reshape(D * x.shape[0], *x.shape[1:])


def ring_reduce_scatter(
    x: jax.Array, axis_name: str, *, bidirectional: bool = True
) -> jax.Array:
    """Reduce-scatter along ``axis_name``; leading dim divided by axis size.
    Device i receives the canonical chunk x[i*c:(i+1)*c] summed over devices.

    bidirectional=True is the FuncPipe-analog schedule: each half of every
    chunk travels in the opposite ring direction in the same step, so both
    link directions carry payload (wall steps ~ halved).  False = the
    LambdaML-equivalent single-direction ring.  Both produce the SAME
    canonical chunk layout (each chunk is split within its leading dim).
    """
    D = lax.axis_size(axis_name)
    if D == 1:
        return x
    c = x.shape[0] // D
    if not bidirectional or c % 2 != 0:
        return _ring_reduce_scatter_1d(x, axis_name)
    chunks = x.reshape(D, c, *x.shape[1:])
    lo = chunks[:, : c // 2].reshape(D * c // 2, *x.shape[1:])
    hi = chunks[:, c // 2 :].reshape(D * c // 2, *x.shape[1:])
    a = _ring_reduce_scatter_1d(lo, axis_name, reverse=False)
    b = _ring_reduce_scatter_1d(hi, axis_name, reverse=True)
    return jnp.concatenate([a, b], axis=0)


def ring_all_gather(
    x: jax.Array, axis_name: str, *, bidirectional: bool = True
) -> jax.Array:
    """All-gather along ``axis_name``; leading dim multiplied by axis size.
    Canonical layout: output[i*c:(i+1)*c] == device i's input."""
    D = lax.axis_size(axis_name)
    if D == 1:
        return x
    c = x.shape[0]
    if not bidirectional or c % 2 != 0:
        return _ring_all_gather_1d(x, axis_name)
    a = _ring_all_gather_1d(x[: c // 2], axis_name, reverse=False)   # [D*c/2,...]
    b = _ring_all_gather_1d(x[c // 2 :], axis_name, reverse=True)
    a = a.reshape(D, c // 2, *x.shape[1:])
    b = b.reshape(D, c // 2, *x.shape[1:])
    return jnp.concatenate([a, b], axis=1).reshape(D * c, *x.shape[1:])


# ------------------------------------------------------------ analytic timing
@dataclasses.dataclass(frozen=True)
class CollectiveCost:
    bytes_on_link: float   # bytes through the busiest link direction
    steps: int             # ring steps (latency term)


def reduce_scatter_cost(nbytes: float, d: int, bidirectional: bool) -> CollectiveCost:
    if d <= 1:
        return CollectiveCost(0.0, 0)
    per_dir = nbytes * (d - 1) / d
    if bidirectional:
        return CollectiveCost(per_dir / 2, d - 1)
    return CollectiveCost(per_dir, d - 1)


def all_gather_cost(nbytes: float, d: int, bidirectional: bool) -> CollectiveCost:
    return reduce_scatter_cost(nbytes, d, bidirectional)


def all_reduce_cost(nbytes: float, d: int, bidirectional: bool) -> CollectiveCost:
    rs = reduce_scatter_cost(nbytes, d, bidirectional)
    return CollectiveCost(rs.bytes_on_link * 2, rs.steps * 2)
