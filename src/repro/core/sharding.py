"""Parameter layout for the pipelined mesh.

Global layout of every layer leaf: ``[model_axis, ppstage, *sliced_dims]``
where index ``m = stage*tp + t`` holds (pipeline stage ``stage``, tensor slice
``t``).  ``PartitionSpec('model', ...)`` then gives each device exactly its
stage's tp-slice.  MoE expert leaves carry an extra 'data'-sharded expert dim
(expert parallelism).  Embedding / head / final norm are replicated.

``TPSpec`` annotations mirror the init_* param structures:
  repl          — copied across tp members
  slice(dim)    — dim divided contiguously by tp (column/row parallel)
  heads(dim,hd) — dim is heads*hd; sliced by whole heads, and *replicated*
                  when there are fewer KV heads than tp members (GQA)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (
    ArchConfig,
    LayerSpec,
    ATTN,
    MAMBA,
    MLSTM,
    SLSTM,
    DENSE_FF,
    MOE_FF,
    NO_FF,
)
from repro.core.plan import PipelinePlan


@dataclass(frozen=True)
class TPSpec:
    mode: str = "repl"            # repl | slice | heads
    dim: int = -1                 # sliced dim (negative = from the end)
    unit: int = 1                 # head_dim for mode="heads"
    heads: int = 0                # total heads for mode="heads"
    ep: bool = False              # expert dim 0 sharded over 'data'
    # gradient sync over tp members required (kv replication / full repl):
    sync_tp: bool = False

    def local_dim_size(self, full: int, tp: int) -> int:
        if self.mode == "repl":
            return full
        if self.mode == "slice":
            assert full % tp == 0, (full, tp)
            return full // tp
        # heads
        if self.heads >= tp:
            assert self.heads % tp == 0
            return (self.heads // tp) * self.unit
        return self.unit  # one (replicated) kv head per member


REPL = TPSpec("repl", sync_tp=True)


def attn_pspecs(cfg: ArchConfig, replicate: bool = False) -> dict:
    if replicate:
        keys = ["wq", "wk", "wv", "wo"] + (["bq", "bk", "bv"] if cfg.qkv_bias else [])
        keys += ["q_norm", "k_norm"] if cfg.qk_norm else []
        return {k: REPL for k in keys}
    hd = cfg.hd
    kvh = TPSpec("heads", -1, hd, cfg.n_kv_heads, sync_tp=True)
    p = {
        "wq": TPSpec("slice", -1),
        "wk": kvh,
        "wv": kvh,
        "wo": TPSpec("slice", 0),
    }
    if cfg.qkv_bias:
        p["bq"] = TPSpec("slice", 0)
        p["bk"] = dataclasses.replace(kvh, dim=0)
        p["bv"] = dataclasses.replace(kvh, dim=0)
    if cfg.qk_norm:
        p["q_norm"] = REPL
        p["k_norm"] = REPL
    return p


def mlp_pspecs(cfg: ArchConfig) -> dict:
    return {
        "w_gate": TPSpec("slice", 1),
        "w_up": TPSpec("slice", 1),
        "w_down": TPSpec("slice", 0),
    }


def moe_pspecs(cfg: ArchConfig) -> dict:
    return {
        "router": REPL,
        "w_gate": TPSpec("slice", 2, ep=True),
        "w_up": TPSpec("slice", 2, ep=True),
        "w_down": TPSpec("slice", 1, ep=True),
    }


def mamba_pspecs(cfg: ArchConfig) -> dict:
    return {
        "w_in_x": TPSpec("slice", 1),
        "w_in_z": TPSpec("slice", 1),
        "conv_w": TPSpec("slice", 1),
        "conv_b": TPSpec("slice", 0),
        "w_xproj": TPSpec("slice", 0),
        "w_dt": TPSpec("slice", 1),
        "b_dt": TPSpec("slice", 0),
        "A_log": TPSpec("slice", 0),
        "D": TPSpec("slice", 0),
        "w_out": TPSpec("slice", 0),
    }


def xlstm_pspecs(cfg: ArchConfig, kind: str) -> dict:
    # Recurrent matrices couple the full width: run TP-replicated (DESIGN.md).
    if kind == MLSTM:
        keys = ["w_up", "w_z", "conv_w", "conv_b", "wq", "wk", "wv",
                "w_if", "b_i", "b_f", "out_norm", "w_down"]
    else:
        keys = ["w_gates", "r_gates", "b_gates", "out_norm", "w_up_ff", "w_down_ff"]
    return {k: REPL for k in keys}


def layer_pspecs(cfg: ArchConfig, spec: LayerSpec) -> dict:
    p: dict = {"norm1": REPL}
    if spec.mixer == ATTN:
        p["mixer"] = attn_pspecs(cfg)
    elif spec.mixer == MAMBA:
        p["mixer"] = mamba_pspecs(cfg)
    else:
        p["mixer"] = xlstm_pspecs(cfg, spec.mixer)
    if spec.ff != NO_FF:
        p["norm2"] = REPL
        p["ff"] = mlp_pspecs(cfg) if spec.ff == DENSE_FF else moe_pspecs(cfg)
    return p


def model_pspecs(cfg: ArchConfig) -> dict:
    """TPSpec pytree matching registry.init_params structure."""
    out = {
        "embed": REPL,
        "final_norm": REPL,
        "layers": tuple(layer_pspecs(cfg, s) for s in cfg.period),
    }
    if not cfg.tie_embeddings:
        out["head"] = REPL
    return out


# ----------------------------------------------------------------- layout ops
def _slice_bounds(ts: TPSpec, full: int, tp: int, t: int) -> tuple[int, int]:
    """start, size of member t's slice of a dim of length ``full``."""
    if ts.mode == "slice":
        sz = full // tp
        return t * sz, sz
    # heads
    if ts.heads >= tp:
        per = ts.heads // tp
        return t * per * ts.unit, per * ts.unit
    # replicate kv heads: member t uses head index t * heads // tp
    h = t * ts.heads // tp
    return h * ts.unit, ts.unit


def layout_leaf(leaf: jax.Array, ts: TPSpec, plan: PipelinePlan) -> jax.Array:
    """[n_periods, *dims] -> [model_axis, ppstage, *tp_sliced_dims]."""
    S, tp = plan.stages, plan.tensor
    P_have = leaf.shape[0]
    pad = plan.n_instances - P_have
    if pad:
        leaf = jnp.concatenate(
            [leaf, jnp.zeros((pad, *leaf.shape[1:]), leaf.dtype)], axis=0
        )
    leaf = leaf.reshape(S, plan.ppstage, *leaf.shape[1:])
    if ts.mode == "repl" or tp == 1:
        out = jnp.broadcast_to(leaf[:, None], (S, tp, *leaf.shape[1:]))
    else:
        dim = ts.dim % (leaf.ndim - 2) + 2  # map leaf-relative dim to padded array
        full = leaf.shape[dim]
        slices = []
        for t in range(tp):
            st, sz = _slice_bounds(ts, full, tp, t)
            slices.append(jax.lax.slice_in_dim(leaf, st, st + sz, axis=dim))
        out = jnp.stack(slices, axis=1)  # [S, tp, ppstage, ...sliced]
    return out.reshape(S * tp, *out.shape[2:])


def leaf_partition_spec(ts: TPSpec, ndim_layout: int, plan: PipelinePlan) -> P:
    """PartitionSpec for a laid-out leaf [model, ppstage, *dims]."""
    axes: list = ["model"] + [None] * (ndim_layout - 1)
    if ts.ep and plan.ep > 1:
        axes[2] = "data"  # expert dim (dim 0 of the original leaf)
    return P(*axes)


def to_pipeline_layout(cfg: ArchConfig, plan: PipelinePlan, params: dict) -> dict:
    specs = model_pspecs(cfg)
    layers = jax.tree.map(
        lambda leaf, ts: layout_leaf(leaf, ts, plan),
        params["layers"],
        specs["layers"],
        is_leaf=lambda x: isinstance(x, TPSpec),
    )
    out = dict(params)
    out["layers"] = layers
    return out


def pipeline_param_specs(cfg: ArchConfig, plan: PipelinePlan) -> dict:
    """PartitionSpec pytree for laid-out params (replicated leaves -> P())."""
    specs = model_pspecs(cfg)

    def layer_spec(ts: TPSpec, leaf_shape_len: int):
        return leaf_partition_spec(ts, leaf_shape_len, plan)

    # need leaf ndim: build from abstract shapes
    shapes = abstract_layout_shapes(cfg, plan)
    layers = jax.tree.map(
        lambda sds, ts: layer_spec(ts, len(sds.shape)),
        shapes["layers"],
        specs["layers"],
        is_leaf=lambda x: isinstance(x, TPSpec),
    )
    out = {"embed": P(), "final_norm": P(), "layers": layers}
    if not cfg.tie_embeddings:
        out["head"] = P()
    return out


def abstract_layout_shapes(cfg: ArchConfig, plan: PipelinePlan) -> dict:
    """ShapeDtypeStructs of laid-out params WITHOUT materializing anything."""
    from repro.models.registry import init_params

    base = jax.eval_shape(
        lambda k: init_params(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    specs = model_pspecs(cfg)

    def lay(sds, ts: TPSpec):
        S, tp = plan.stages, plan.tensor
        dims = list(sds.shape[1:])
        if ts.mode != "repl" and tp > 1:
            d = ts.dim % len(dims)
            dims[d] = ts.local_dim_size(dims[d], tp)
        return jax.ShapeDtypeStruct((S * tp, plan.ppstage, *dims), sds.dtype)

    layers = jax.tree.map(
        lay, base["layers"], specs["layers"], is_leaf=lambda x: isinstance(x, TPSpec)
    )
    out = {"embed": base["embed"], "final_norm": base["final_norm"], "layers": layers}
    if not cfg.tie_embeddings:
        out["head"] = base["head"]
    return out


def abstract_params(cfg: ArchConfig, plan: PipelinePlan, mesh) -> dict:
    """Abstract laid-out params with NamedShardings attached (dry-run)."""
    shapes = abstract_layout_shapes(cfg, plan)
    pspecs = pipeline_param_specs(cfg, plan)
    return jax.tree.map(
        lambda sds, ps: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, ps)
        ),
        shapes,
        pspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct) or isinstance(x, P),
    )


@dataclass(frozen=True)
class GradSync:
    data_rs: bool = True       # reduce-scatter over 'data' (False for EP leaves)
    tp_mode: str = "none"      # none | all (replicated) | kvshare (GQA kv repl)


def grad_sync_specs(cfg: ArchConfig, plan: PipelinePlan) -> dict:
    """Per-leaf sync requirements for the update step (see train.train_step)."""
    specs = model_pspecs(cfg)

    def sync(ts: TPSpec) -> GradSync:
        tp_mode = "none"
        if plan.tensor > 1:
            if ts.mode == "repl":
                tp_mode = "all"
            elif ts.mode == "heads" and ts.heads < plan.tensor:
                tp_mode = "kvshare"
        data_rs = not (ts.ep and plan.ep > 1)
        return GradSync(data_rs=data_rs, tp_mode=tp_mode)

    return jax.tree.map(sync, specs, is_leaf=lambda x: isinstance(x, TPSpec))


def layer_mask_array(cfg: ArchConfig, plan: PipelinePlan) -> np.ndarray:
    """[model_axis, ppstage, period_len] bool — real (non-padding) layers."""
    S, tp = plan.stages, plan.tensor
    idx = np.arange(plan.n_instances * cfg.period_len).reshape(
        S, plan.ppstage, cfg.period_len
    )
    mask = idx < cfg.n_layers
    return np.broadcast_to(mask[:, None], (S, tp, plan.ppstage, cfg.period_len)).reshape(
        S * tp, plan.ppstage, cfg.period_len
    )
