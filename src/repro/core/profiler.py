"""Model Profiler (paper Fig 2, startup component ③).

On the real system this profiles layers on functions of every memory class;
offline we synthesize the same per-layer tables analytically: FLOPs-derived
compute times under the platform's memory->vCPU scaling, plus parameter /
activation / boundary sizes.  Includes the paper's four evaluation models
(Table 1) and a bridge from our ArchConfigs so the serverless planner can
plan any assigned architecture.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ArchConfig, DENSE_FF, MOE_FF, ATTN
from repro.core.partition import LayerProfile, ModelProfile
from repro.serverless.platform import MB, GB, Platform

F32 = 4  # training payloads are fp32 on CPU serverless


def _times(platform: Platform, fwd_flops: float):
    fwd = tuple(platform.compute_time(fwd_flops, m) for m in platform.memory_options)
    bwd = tuple(2.0 * t for t in fwd)
    return fwd, bwd


def _layer(platform, name, params_b, act_b, out_b, grad_b, fwd_flops):
    fwd, bwd = _times(platform, fwd_flops)
    return LayerProfile(
        name=name, param_bytes=params_b, act_bytes=act_b, out_bytes=out_b,
        grad_out_bytes=grad_b, fwd_time=fwd, bwd_time=bwd,
    )


# ----------------------------------------------------------- paper's models
# Table 1: (param_MB, act_MB_per_sample); FLOPs calibrated so AmoebaNet-D36
# computation matches Fig 1(a) (~6 s/iteration).
_PAPER_MODELS = {
    "resnet101": dict(params=170 * MB, act=198 * MB, n_layers=35, kind="cnn"),
    "amoebanet-d18": dict(params=476 * MB, act=432 * MB, n_layers=20, kind="cnn"),
    "amoebanet-d36": dict(params=900 * MB, act=697 * MB, n_layers=38, kind="cnn"),
    "bert-large": dict(params=1153 * MB, act=263 * MB, n_layers=26, kind="bert"),
}
_CNN_FLOPS_PER_PARAM_SAMPLE = 240.0   # conv spatial reuse
_BERT_FLOPS_PER_PARAM_SAMPLE = 256.0  # 2 * seq(128)


def paper_model_profile(name: str, platform: Platform,
                        micro_batch: int = 4) -> ModelProfile:
    spec = _PAPER_MODELS[name]
    L = spec["n_layers"]
    P_total, A_total = spec["params"], spec["act"]
    if spec["kind"] == "cnn":
        # params grow with depth, activations shrink (stride-2 reductions)
        depth = np.arange(L)
        pw = np.exp(depth / L * 1.6)          # ~5x growth first->last
        aw = np.exp(-depth / L * 2.2)         # ~9x shrink
        kf = _CNN_FLOPS_PER_PARAM_SAMPLE
    else:
        # embedding-heavy first layer, uniform encoder blocks
        pw = np.ones(L)
        pw[0] = 3.0
        pw[-1] = 0.3
        aw = np.ones(L)
        kf = _BERT_FLOPS_PER_PARAM_SAMPLE
    pw = pw / pw.sum()
    aw = aw / aw.sum()
    layers = []
    for i in range(L):
        p_b = P_total * pw[i]
        a_b = A_total * aw[i] * micro_batch
        out_b = a_b * 0.5                      # boundary tensor ~ half the act
        flops = kf * (p_b / F32) * micro_batch
        if spec["kind"] == "cnn" and i == 0:
            flops *= 3.0                       # stem convs are FLOP-heavy
        layers.append(_layer(platform, f"L{i}", p_b, a_b, out_b, out_b, flops))
    return ModelProfile(name=name, layers=tuple(layers))


# -------------------------------------------------- assigned-arch bridge
def arch_model_profile(cfg: ArchConfig, platform: Platform, *, seq: int = 512,
                       micro_batch: int = 1) -> ModelProfile:
    """Layer table for one of the assigned architectures (fp32 serverless)."""
    d = cfg.d_model
    layers = []
    act_per_layer = 6 * seq * d * F32 * micro_batch  # residual+mixer+ff buffers
    out_b = seq * d * F32 * micro_batch
    # embedding "layer"
    emb_b = cfg.vocab_size * d * F32
    layers.append(_layer(platform, "embed", emb_b, out_b, out_b, out_b,
                         2 * seq * d * micro_batch))
    n_emb_tables = 1 if cfg.tie_embeddings else 2
    per_layer_params = max(
        0.0, cfg.param_count() * F32 - n_emb_tables * emb_b) / cfg.n_layers
    for i in range(cfg.n_layers):
        spec = cfg.layer_spec(i)
        p_b = per_layer_params
        flops_params = p_b / F32
        if spec.ff == MOE_FF and cfg.moe is not None:
            # only top_k experts touched per token
            frac = cfg.active_param_count() / cfg.param_count()
            flops_params *= frac
        flops = 6 * flops_params * seq * micro_batch / 3  # fwd ~ 2*N*D
        layers.append(_layer(platform, f"layer{i}", p_b, act_per_layer, out_b,
                             out_b, flops))
    # lm head
    layers.append(_layer(platform, "head", emb_b, out_b, out_b, out_b,
                         2 * cfg.vocab_size * d * seq * micro_batch / 1000))
    return ModelProfile(name=cfg.name, layers=tuple(layers))


# ------------------------------------------------------- unified resolution
def known_models():
    """All model ids the profiler can resolve (paper models + arch ids)."""
    from repro.configs import ARCH_IDS

    return sorted(_PAPER_MODELS) + sorted(ARCH_IDS)


def resolve_profile(model: str, platform: Platform, *, seq=None,
                    micro_batch=None) -> ModelProfile:
    """One front door from a model id to its layer profile.

    Accepts the paper's Table 1 models, any assigned arch id, and the
    reduced-arch spelling ``<arch>@reduced[<n_layers>]`` that the numeric
    emulation mode records (so its saved plans replay too); ``None`` keeps
    each family's own default (paper: micro_batch=4; arch: seq=512,
    micro_batch=1).  This is the resolution path ``DeploymentPlan.resolve``
    replays, so the recorded ``profile_args`` must reproduce the profile the
    plan was solved against."""
    import dataclasses

    from repro.configs import ARCH_IDS, get_config

    if model in _PAPER_MODELS:
        return paper_model_profile(model, platform,
                                   micro_batch=4 if micro_batch is None else micro_batch)
    base, _, spec = model.partition("@")
    if base in ARCH_IDS and (not spec or spec.startswith("reduced")):
        cfg = get_config(base)
        if spec:
            cfg = cfg.reduced()
            depth = spec[len("reduced"):]
            if depth:
                try:
                    cfg = dataclasses.replace(cfg, n_layers=int(depth))
                except ValueError:
                    raise KeyError(
                        f"malformed reduced-arch spec {model!r}: depth "
                        f"{depth!r} is not an integer") from None
        return arch_model_profile(cfg, platform,
                                  seq=512 if seq is None else seq,
                                  micro_batch=1 if micro_batch is None else micro_batch)
    raise KeyError(
        f"unknown model {model!r}; known models: {known_models()} "
        "(reduced spelling: <arch>@reduced[<L>])")
