"""Pipeline execution plan: how an arch maps onto the mesh.

This is the TPU analog of the paper's (partition, resource) decision: the
16-wide 'model' axis factors into (pipeline stages x tensor parallel), the
'data' axis carries DP + expert parallelism + (long-decode) sequence sharding,
and the micro-batch count trades bubble time for activation memory — the
knobs the tpu_planner co-optimizes (core/tpu_planner.py).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional

from repro.configs.base import ArchConfig, InputShape


@dataclass(frozen=True)
class PipelinePlan:
    stages: int              # pipeline stages (S_eff)
    tensor: int              # TP within a stage; stages * tensor == model axis
    microbatches: int        # per data-shard micro-batches per step
    ep: int                  # expert-parallel factor over the data axis
    n_instances: int         # padded period instances (stages * ppstage)
    data: int                # data axis size
    pods: int                # pod axis size (1 = single pod)
    seq_shards: int = 1      # KV/sequence sharding over data (long decode)
    remat: str = "tick"      # none | tick | layer

    @property
    def ppstage(self) -> int:
        return self.n_instances // self.stages

    @property
    def model_axis(self) -> int:
        return self.stages * self.tensor


def make_plan(
    cfg: ArchConfig,
    shape: InputShape,
    *,
    data: int = 16,
    model: int = 16,
    pods: int = 1,
    stages: Optional[int] = None,
    tensor: Optional[int] = None,
    microbatches: Optional[int] = None,
    remat: str = "tick",
) -> PipelinePlan:
    stages = stages if stages is not None else cfg.stages
    tensor = tensor if tensor is not None else cfg.tensor
    assert stages * tensor == model, (stages, tensor, model)
    n_inst = -(-cfg.n_periods // stages) * stages

    ep = 1
    if cfg.moe is not None:
        ep = math.gcd(cfg.moe.n_experts, data)

    seq_shards = 1
    B = shape.global_batch
    local_batch = max(1, B // pods)
    if shape.kind == "decode" and B < pods * data:
        # batch too small to shard: replicate it everywhere and shard the
        # long KV sequence over (pod x data) instead (flash-decode combine)
        seq_shards = pods * data
        local_batch = B
        ep = 1  # replicated tokens use the psum EP path (moe ep_mode="psum")

    if microbatches is None:
        if shape.kind == "train":
            microbatches = max(1, min(2 * stages, local_batch // data))
        else:
            microbatches = max(1, min(stages, local_batch // max(1, data)))
    return PipelinePlan(
        stages=stages,
        tensor=tensor,
        microbatches=microbatches,
        ep=ep,
        n_instances=n_inst,
        data=data,
        pods=pods,
        seq_shards=seq_shards,
        remat=remat,
    )
