"""Partition utilities: the paper's hat/tilde accumulation operators (eq (4))
and the layer-merging pass (§4 "MIQP solution") that keeps the optimization
problem minute-scale.

A *partition* is represented by the boundary vector x ∈ {0,1}^(L-1):
x[i] == 1 iff the model is cut between layer i and i+1 (0-indexed; the paper's
x_i "partitioned after layer i").  Stages are the contiguous runs.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np


def hat(u: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Forward accumulation within partitions: hat_u[i] = u[i] + hat_u[i-1]*(1-x[i-1]).

    Batch-aware: ``u`` may be ``[..., L]`` with ``x`` ``[..., L-1]`` — the
    recurrence runs along the last axis, vectorized over leading axes, with
    the same per-element operation order as the scalar form (so scalar and
    batched callers see bit-identical results)."""
    u = np.asarray(u, dtype=np.float64)
    x = np.asarray(x)
    out = np.empty_like(u)
    out[..., 0] = u[..., 0]
    for i in range(1, u.shape[-1]):
        out[..., i] = u[..., i] + out[..., i - 1] * (1 - x[..., i - 1])
    return out


def tilde(u: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Backward accumulation: tilde_u[i] = u[i] + tilde_u[i+1]*(1-x[i]).

    Batch-aware along the last axis, like :func:`hat`."""
    u = np.asarray(u, dtype=np.float64)
    x = np.asarray(x)
    L = u.shape[-1]
    out = np.empty_like(u)
    out[..., L - 1] = u[..., L - 1]
    for i in range(L - 2, -1, -1):
        out[..., i] = u[..., i] + out[..., i + 1] * (1 - x[..., i])
    return out


def suffix_sum(u: np.ndarray) -> np.ndarray:
    """Right-fold suffix sums along the last axis: out[i] = u[i] + out[i+1].

    Both the scalar oracle (`perfmodel.evaluate`) and the batched kernel
    (`perfmodel.evaluate_batch`) reduce suffixes through this helper so their
    floating-point association is identical — a requirement for the
    bit-for-bit property test between the two."""
    u = np.asarray(u, dtype=np.float64)
    out = np.empty_like(u)
    L = u.shape[-1]
    out[..., L - 1] = u[..., L - 1]
    for i in range(L - 2, -1, -1):
        out[..., i] = u[..., i] + out[..., i + 1]
    return out


def suffix_max(u: np.ndarray) -> np.ndarray:
    """Suffix maxima along the last axis: out[i] = max(u[i], out[i+1])."""
    u = np.asarray(u, dtype=np.float64)
    out = np.empty_like(u)
    L = u.shape[-1]
    out[..., L - 1] = u[..., L - 1]
    for i in range(L - 2, -1, -1):
        np.maximum(u[..., i], out[..., i + 1], out=out[..., i])
    return out


def segment_sum_table(u: np.ndarray) -> np.ndarray:
    """Sums of every contiguous segment of ``u`` along the last axis.

    ``seg[..., lo, hi] = u[lo] + ... + u[hi]`` (zero where ``lo > hi``),
    accumulated as ``seg[lo, hi] = seg[lo, hi - 1] + u[hi]`` — the same
    per-element operation order as :func:`hat` restricted to one stage, so a
    stage's entry is bit-identical to ``hat(u, x)[hi]`` for any partition in
    which ``[lo, hi]`` is a stage (IEEE addition commutes, so growing the
    segment on the right reproduces hat's fold exactly).  Batch-aware over
    leading axes like :func:`hat`."""
    u = np.asarray(u, dtype=np.float64)
    L = u.shape[-1]
    seg = np.zeros(u.shape[:-1] + (L, L), dtype=np.float64)
    for hi in range(L):
        seg[..., hi, hi] = u[..., hi]
        if hi:
            seg[..., :hi, hi] = seg[..., :hi, hi - 1] + u[..., hi, None]
    return seg


def segment_sum_table_rev(u: np.ndarray) -> np.ndarray:
    """Like :func:`segment_sum_table` but folded from the right —
    ``seg[lo, hi] = u[lo] + seg[lo + 1, hi]`` — matching :func:`tilde`'s
    association, so a stage's entry is bit-identical to ``tilde(u, x)[lo]``
    for any partition in which ``[lo, hi]`` is a stage."""
    u = np.asarray(u, dtype=np.float64)
    L = u.shape[-1]
    seg = np.zeros(u.shape[:-1] + (L, L), dtype=np.float64)
    for lo in range(L - 1, -1, -1):
        seg[..., lo, lo] = u[..., lo]
        if lo < L - 1:
            seg[..., lo, lo + 1:] = u[..., lo, None] + seg[..., lo + 1, lo + 1:]
    return seg


def stage_ids(x: np.ndarray) -> np.ndarray:
    """Per-layer stage index for a batch of partitions: ``x`` is ``[..., L-1]``
    boundary bits, the result is ``[..., L]`` with values in ``[0, n_stages)``
    (the segment-sum companion of :func:`stages_of`)."""
    x = np.asarray(x, dtype=np.int64)
    ids = np.zeros(x.shape[:-1] + (x.shape[-1] + 1,), dtype=np.int64)
    np.cumsum(x, axis=-1, out=ids[..., 1:])
    return ids


def stages_of(x: Sequence[int]) -> List[Tuple[int, int]]:
    """[(lo, hi)] inclusive layer ranges of each stage."""
    lo = 0
    out = []
    for i, xi in enumerate(x):
        if xi:
            out.append((lo, i))
            lo = i + 1
    out.append((lo, len(x)))
    return out


def highest_layers(x: Sequence[int]) -> List[int]:
    """The paper's H: last layer index of each stage."""
    return [hi for _, hi in stages_of(x)]


def lowest_layers(x: Sequence[int]) -> List[int]:
    return [lo for lo, _ in stages_of(x)]


# ------------------------------------------------------------------ profiles
@dataclass(frozen=True)
class LayerProfile:
    """Per-layer quantities (paper Table 2).  Sizes in bytes, times in
    seconds, indexed by memory option j for the compute times."""

    name: str
    param_bytes: float          # s_i
    act_bytes: float            # a_i  (per micro-batch)
    out_bytes: float            # o_i  (per micro-batch)
    grad_out_bytes: float       # g_i  (per micro-batch, bwd boundary)
    fwd_time: Tuple[float, ...]   # T_fc^{i,j}
    bwd_time: Tuple[float, ...]   # T_bc^{i,j}


PROFILE_SOURCES = ("analytic", "measured")
PROFILE_SCHEMA_VERSION = 1


@dataclass(frozen=True)
class CalibrationMeta:
    """Provenance of a *measured* profile: which traced run patched it.

    Frozen with scalar fields only — :class:`ModelProfile` is an
    ``lru_cache`` key in ``perfmodel.perf_tables``, so everything hanging
    off it must stay hashable."""

    backend: str                 # execution backend that produced the trace
    clock: str                   # "wall" | "virtual"
    steps: int                   # traced training steps folded in
    base_fingerprint: str        # fingerprint of the analytic profile patched
    t_total: float               # traced run's total seconds (trace clock)


@dataclass(frozen=True)
class ModelProfile:
    name: str
    layers: Tuple[LayerProfile, ...]
    source: str = "analytic"                      # analytic | measured
    calibration: Optional[CalibrationMeta] = None

    def __post_init__(self):
        if self.source not in PROFILE_SOURCES:
            raise ValueError(
                f"profile source {self.source!r} not in {PROFILE_SOURCES}")
        if self.source == "measured" and self.calibration is None:
            raise ValueError(
                "a measured profile must carry its CalibrationMeta")

    @property
    def L(self) -> int:
        return len(self.layers)

    def arrays(self):
        """Per-layer quantity arrays, built once per profile and cached (the
        planner hot path used to rebuild this dict on every ``evaluate``
        call).  The arrays are marked read-only; treat them as immutable."""
        cached = self.__dict__.get("_arrays_cache")
        if cached is not None:
            return cached
        ls = self.layers
        cached = {
            "s": np.array([l.param_bytes for l in ls]),
            "a": np.array([l.act_bytes for l in ls]),
            "o": np.array([l.out_bytes for l in ls]),
            "g": np.array([l.grad_out_bytes for l in ls]),
            "Tf": np.array([l.fwd_time for l in ls]),   # [L, J]
            "Tb": np.array([l.bwd_time for l in ls]),
        }
        for arr in cached.values():
            arr.setflags(write=False)
        object.__setattr__(self, "_arrays_cache", cached)
        return cached

    @property
    def param_bytes(self) -> float:
        return float(sum(l.param_bytes for l in self.layers))

    # --------------------------------------------------------- serialization
    # Analytic profiles are rebuilt from the profiler and never serialized;
    # measured profiles (repro.obs.calibrate) exist only as artifacts of a
    # traced run, so they round-trip through JSON like DeploymentPlans do.
    def to_json(self, *, indent: Optional[int] = 2) -> str:
        d = {
            "version": PROFILE_SCHEMA_VERSION,
            "name": self.name,
            "source": self.source,
            "calibration": (None if self.calibration is None
                            else dataclasses.asdict(self.calibration)),
            "layers": [dataclasses.asdict(l) for l in self.layers],
        }
        return json.dumps(d, indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, blob: str) -> "ModelProfile":
        d = json.loads(blob)
        version = d.get("version")
        if version != PROFILE_SCHEMA_VERSION:
            raise ValueError(f"profile schema version {version!r} != "
                             f"supported {PROFILE_SCHEMA_VERSION}")
        layers = tuple(LayerProfile(
            name=l["name"],
            param_bytes=float(l["param_bytes"]),
            act_bytes=float(l["act_bytes"]),
            out_bytes=float(l["out_bytes"]),
            grad_out_bytes=float(l["grad_out_bytes"]),
            fwd_time=tuple(float(t) for t in l["fwd_time"]),
            bwd_time=tuple(float(t) for t in l["bwd_time"]),
        ) for l in d["layers"])
        cal = d.get("calibration")
        return cls(name=d["name"], layers=layers,
                   source=d.get("source", "analytic"),
                   calibration=(None if cal is None
                                else CalibrationMeta(**cal)))

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path) -> "ModelProfile":
        with open(path) as f:
            return cls.from_json(f.read())


def merge_boundaries(profile: ModelProfile, target_L: int,
                     criterion: str = "compute") -> List[int]:
    """Group edges of the §4 layer merge: ``[0, b_1, ..., b_{k-1}, L]`` with
    super-layer ``g`` spanning original layers ``[edges[g], edges[g+1])``.

    Hierarchical: starting from one group, the heaviest splittable group is
    repeatedly split at its most balanced interior point, so the boundary set
    at depth ``k`` is by construction a superset of every shallower depth's.
    Nested boundaries make the planner's search space grow monotonically with
    merge depth — deeper merging can never lose a plan that a shallower depth
    could express, which is what makes plan quality monotone in ``target_L``
    (the seed's one-pass greedy did not nest; see the ROADMAP
    merge-boundary item)."""
    ls = profile.layers
    if criterion == "compute":
        w = np.array([np.mean(l.fwd_time) + np.mean(l.bwd_time) for l in ls])
    elif criterion == "param":
        w = np.array([l.param_bytes for l in ls])
    elif criterion == "activation":
        w = np.array([l.act_bytes for l in ls])
    else:
        raise ValueError(criterion)
    w = np.maximum(w, 1e-12)
    csum = np.concatenate([[0.0], np.cumsum(w)])
    edges = [0, len(ls)]
    while len(edges) - 1 < min(target_L, len(ls)):
        # heaviest group with more than one layer; leftmost breaks ties
        best_g, best_w = None, -np.inf
        for g in range(len(edges) - 1):
            gw = csum[edges[g + 1]] - csum[edges[g]]
            if edges[g + 1] - edges[g] > 1 and gw > best_w:
                best_g, best_w = g, gw
        lo, hi = edges[best_g], edges[best_g + 1]
        left = csum[lo + 1:hi] - csum[lo]     # weight left of each interior cut
        total = csum[hi] - csum[lo]
        k = int(np.argmin(np.maximum(left, total - left)))  # first minimizer
        edges.insert(best_g + 1, lo + k + 1)
    return edges


def merge_layers(profile: ModelProfile, target_L: int,
                 criterion: str = "compute") -> ModelProfile:
    """Balanced hierarchical merging (paper §4): contiguous layers are merged
    so the chosen criterion (compute time / param size / activation size) is
    roughly balanced across the ``target_L`` merged super-layers, with
    boundaries that nest across depths (see :func:`merge_boundaries`)."""
    ls = profile.layers
    if len(ls) <= target_L:
        return profile
    edges = merge_boundaries(profile, target_L, criterion)
    groups: List[List[int]] = [list(range(edges[g], edges[g + 1]))
                               for g in range(len(edges) - 1)]

    def merge_group(idx: List[int]) -> LayerProfile:
        sub = [ls[i] for i in idx]
        J = len(sub[0].fwd_time)
        return LayerProfile(
            name=f"{sub[0].name}..{sub[-1].name}",
            param_bytes=sum(l.param_bytes for l in sub),
            act_bytes=sum(l.act_bytes for l in sub),
            out_bytes=sub[-1].out_bytes,           # boundary output only
            grad_out_bytes=sub[0].grad_out_bytes,  # boundary grad only
            fwd_time=tuple(sum(l.fwd_time[j] for l in sub) for j in range(J)),
            bwd_time=tuple(sum(l.bwd_time[j] for l in sub) for j in range(J)),
        )

    return ModelProfile(name=profile.name,
                        layers=tuple(merge_group(g) for g in groups),
                        source=profile.source,
                        calibration=profile.calibration)
