"""GPipe-style pipeline parallelism inside shard_map — the paper's §3.2
training pipeline mapped onto the TPU mesh.

The 'model' mesh axis factors into (stages x tensor).  Parameters arrive
pre-laid-out (core.sharding): every layer leaf is [1, ppstage, *sliced] per
device.  A lax.scan over T = mu + stages - 1 *ticks* moves micro-batches
through the stages with lax.ppermute — communication is a pipeline stage
overlapped with compute, exactly the paper's scheduling policy (its
upload/download stages become the permute).  jax.grad through the scan yields
the reversed backward pipeline automatically (the vjp of ppermute is the
opposite permute), i.e. GPipe's synchronous fill/drain.

All functions here execute INSIDE shard_map.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig, ATTN, MAMBA, MLSTM, SLSTM, GLOBAL_WINDOW
from repro.core import collectives as cc
from repro.core.plan import PipelinePlan
from repro.models import attention, mamba, xlstm
from repro.models.common import ParallelCtx, rms_norm
from repro.models.transformer import period_decode, period_forward, period_prefill

CE_CHUNK = 512


# ------------------------------------------------------------------- contexts
def make_ctx(plan: PipelinePlan, *, has_pod: bool = False) -> ParallelCtx:
    """Collective hooks for model code, bound to the mesh axes."""
    tp = plan.tensor
    groups = cc.tp_groups(plan.stages, tp) if tp > 1 else None

    def psum_tp(x):
        if tp == 1:
            return x
        return lax.psum(x, "model", axis_index_groups=groups)

    ep_fwd = ep_bwd = None
    if plan.ep > 1:
        def ep_fwd(x):  # [E, C, d] -> [E/ep, C*ep, d]
            return lax.all_to_all(x, "data", split_axis=0, concat_axis=1, tiled=True)

        def ep_bwd(x):
            return lax.all_to_all(x, "data", split_axis=1, concat_axis=0, tiled=True)

    psum_seq = pmax_seq = None
    seq_index = 0
    if plan.seq_shards > 1:
        seq_axes = ("pod", "data") if plan.pods > 1 else ("data",)
        psum_seq = lambda x: lax.psum(x, seq_axes)
        pmax_seq = lambda x: lax.pmax(x, seq_axes)
        seq_index = lax.axis_index("data")
        if plan.pods > 1:
            seq_index = lax.axis_index("pod") * plan.data + seq_index

    return ParallelCtx(
        tp_size=tp,
        dp_size=plan.data,
        seq_shards=plan.seq_shards,
        psum_tp=psum_tp,
        ep_all_to_all=ep_fwd,
        ep_all_to_all_back=ep_bwd,
        psum_seq=psum_seq or (lambda x: x),
        pmax_seq=pmax_seq,
        seq_index=seq_index,
    )


def stage_index(plan: PipelinePlan):
    return lax.axis_index("model") // plan.tensor


def _unbox(params_local):
    """Strip the leading model-axis dim (always 1 per device)."""
    return jax.tree.map(lambda a: a[0] if a.ndim >= 1 and a.shape[0] == 1 else a,
                        params_local)


def _get_mb(tree, i, mb: int, axis: int = 0):
    return jax.tree.map(lambda a: lax.dynamic_slice_in_dim(a, i * mb, mb, axis=axis), tree)


def _embed(cfg: ArchConfig, params, batch_mb) -> jax.Array:
    dtype = jnp.dtype(cfg.param_dtype)
    if cfg.frontend == "audio":
        return batch_mb["frames"].astype(dtype)
    h = params["embed"][batch_mb["tokens"]]
    if cfg.frontend == "vision":
        n_img = cfg.n_frontend_tokens
        img = batch_mb["image_embeds"].astype(h.dtype)
        h = jnp.concatenate([img, h[:, n_img:]], axis=1)
    return h


def _chunked_ce(h: jax.Array, head_w: jax.Array, labels: jax.Array,
                shift: bool, tp: int = 1, tp_index=0) -> jax.Array:
    """Mean CE without materializing full [S, V] logits.  h [mb,S,d].

    With tensor parallelism the sequence chunks are partitioned round-robin
    over the tp lanes (lane t takes chunks with index % tp == t), so the loss
    — and hence the gradient seeds — are computed exactly once per data shard.
    Sum over lanes == full mean CE.
    """
    if shift:
        h = h[:, :-1]
        labels = labels[:, 1:]
    mb, S, d = h.shape
    C = min(CE_CHUNK, S)
    pad = (-S) % C
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
    w = (jnp.arange(S + pad) < S).astype(jnp.float32)
    nch = (S + pad) // C
    hc = h.reshape(mb, nch, C, d).swapaxes(0, 1)
    lc = labels.reshape(mb, nch, C).swapaxes(0, 1)
    wc = w.reshape(nch, C)
    if tp > 1:
        lane = (jnp.arange(nch, dtype=jnp.int32) % tp) == tp_index
        wc = wc * lane[:, None].astype(jnp.float32)

    def body(acc, xs):
        hcb, lcb, wcb = xs
        logits = (hcb @ head_w.T).astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lcb[..., None], axis=-1)[..., 0]
        return acc + jnp.sum((logz - gold) * wcb[None]), None

    total, _ = lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32), (hc, lc, wc))
    return total / (mb * S)


# ------------------------------------------------------------------- training
def pipeline_train_loss(
    cfg: ArchConfig,
    plan: PipelinePlan,
    params_local,
    mask_local,            # [ppstage, period_len] bool (already unboxed)
    batch_local,           # leaves [B_local, ...]
    *,
    has_pod: bool = False,
    use_pallas: bool = False,
) -> Tuple[jax.Array, dict]:
    """Differentiable global-mean loss (executed inside shard_map)."""
    S_eff, tp, mu = plan.stages, plan.tensor, plan.microbatches
    ctx = make_ctx(plan, has_pod=has_pod)
    stage = stage_index(plan)
    is_first = stage == 0
    is_last = stage == S_eff - 1
    layers = params_local["layers"]

    some_leaf = jax.tree.leaves(batch_local)[0]
    B_local = some_leaf.shape[0]
    assert B_local % mu == 0, (B_local, mu)
    mb = B_local // mu
    seq = batch_local["labels"].shape[1]
    positions = jnp.arange(seq, dtype=jnp.int32)
    d = cfg.d_model
    dtype = jnp.dtype(cfg.param_dtype)
    head_w = params_local["embed"] if cfg.tie_embeddings else params_local["head"]
    shift = cfg.causal and not cfg.is_encoder

    def stage_compute(x):
        def per_inst(x, xs):
            inst_params, act_row = xs
            x, aux = period_forward(
                inst_params, x, act_row, cfg=cfg, positions=positions, ctx=ctx,
                use_pallas=use_pallas,
            )
            return x, aux

        body = jax.checkpoint(per_inst) if plan.remat == "layer" else per_inst
        x, auxs = lax.scan(body, x, (layers, mask_local))
        return x, jnp.sum(auxs)

    def tick(carry, t):
        act, loss_sum, aux_sum = carry
        in_idx = jnp.clip(t, 0, mu - 1)
        batch_mb = _get_mb(batch_local, in_idx, mb)
        x = lax.cond(
            is_first,
            lambda: _embed(cfg, params_local, batch_mb).astype(dtype),
            lambda: act,
        )
        x, aux = stage_compute(x)
        out_idx = t - (S_eff - 1)
        valid_out = jnp.logical_and(out_idx >= 0, out_idx < mu)
        valid_compute = jnp.logical_and(t - stage >= 0, t - stage < mu)

        def ce_fn():
            lab = _get_mb(batch_local, jnp.clip(out_idx, 0, mu - 1), mb)["labels"]
            hn = rms_norm(x, params_local["final_norm"], cfg.norm_eps)
            return _chunked_ce(hn, head_w, lab, shift, tp=tp,
                               tp_index=lax.axis_index("model") % tp)

        ce = lax.cond(jnp.logical_and(is_last, valid_out), ce_fn,
                      lambda: jnp.zeros((), jnp.float32))
        loss_sum = loss_sum + ce
        aux_sum = aux_sum + jnp.where(valid_compute, aux, 0.0)
        act_next = lax.ppermute(x, "model", cc.pipeline_perm(S_eff, tp))
        return (act_next, loss_sum, aux_sum), None

    T = mu + S_eff - 1
    act0 = jnp.zeros((mb, seq, d), dtype)
    z = jnp.zeros((), jnp.float32)
    tick_fn = jax.checkpoint(tick) if plan.remat in ("tick", "layer") else tick
    (act, loss_sum, aux_sum), _ = lax.scan(tick_fn, (act0, z, z), jnp.arange(T))

    # Differentiate the LOCAL lane loss only — no psum in the grad path.
    # Under check_vma=False the transpose of psum is psum, so seeding a
    # replicated (psum'ed) loss on every device over-counts gradients by the
    # device count.  CE chunks are lane-partitioned (sum over lanes == full
    # CE); aux is computed redundantly per lane, hence the extra /tp.
    dp_norm = mu * plan.data * plan.pods
    ce_local = loss_sum / dp_norm
    aux_local = aux_sum / (dp_norm * tp)
    total_local = ce_local + aux_local

    axes = ("pod", "data", "model") if has_pod else ("data", "model")
    ce_mean = lax.psum(lax.stop_gradient(ce_local), axes)
    aux_mean = lax.psum(lax.stop_gradient(aux_local), axes)
    metrics = {"ce": ce_mean, "aux": aux_mean, "loss": ce_mean + aux_mean}
    return total_local, metrics


# -------------------------------------------------------------------- serving
def pipeline_decode_step(
    cfg: ArchConfig,
    plan: PipelinePlan,
    params_local,
    mask_local,
    caches_local,          # leaves [ppstage, B_local, ...]
    tokens_local,          # [B_local, 1] int32
    *,
    has_pod: bool = False,
):
    """One decode tick for B_local sequences, pipelined over micro-batches.
    Returns (logits [B_local, 1, V], new caches)."""
    S_eff, tp, mu = plan.stages, plan.tensor, plan.microbatches
    ctx = make_ctx(plan, has_pod=has_pod)
    stage = stage_index(plan)
    is_first = stage == 0
    is_last = stage == S_eff - 1
    layers = params_local["layers"]
    B_local = tokens_local.shape[0]
    assert B_local % mu == 0
    mb = B_local // mu
    d = cfg.d_model
    dtype = jnp.dtype(cfg.param_dtype)
    head_w = params_local["embed"] if cfg.tie_embeddings else params_local["head"]
    V = head_w.shape[0]

    def tick(carry, t):
        act, caches, logits_buf = carry
        # stage s processes micro-batch (t - s) at tick t
        my_idx = jnp.clip(t - stage, 0, mu - 1)
        valid = jnp.logical_and(t - stage >= 0, t - stage < mu)
        x = lax.cond(
            is_first,
            lambda: params_local["embed"][
                _get_mb({"t": tokens_local}, my_idx, mb)["t"]
            ].astype(dtype),
            lambda: act,
        )
        mb_caches = jax.tree.map(
            lambda a: lax.dynamic_slice_in_dim(a, my_idx * mb, mb, axis=1), caches
        )

        def per_inst(x, xs):
            inst_params, inst_caches, act_row = xs
            x, new_c = period_decode(inst_params, x, inst_caches, act_row, cfg=cfg, ctx=ctx)
            return x, new_c

        x, new_mb_caches = lax.scan(per_inst, x, (layers, mb_caches, mask_local))
        new_mb_caches = jax.tree.map(
            lambda new, old: jnp.where(valid, new, old), new_mb_caches, mb_caches
        )
        caches = jax.tree.map(
            lambda full, mbv: lax.dynamic_update_slice_in_dim(full, mbv, my_idx * mb, axis=1),
            caches,
            new_mb_caches,
        )

        def logit_fn():
            hn = rms_norm(x, params_local["final_norm"], cfg.norm_eps)
            return (hn @ head_w.T).astype(jnp.float32)

        out_idx = jnp.clip(t - (S_eff - 1), 0, mu - 1)
        valid_out = jnp.logical_and(t - (S_eff - 1) >= 0, t - (S_eff - 1) < mu)
        lg = lax.cond(jnp.logical_and(is_last, valid_out), logit_fn,
                      lambda: jnp.zeros((mb, 1, V), jnp.float32))
        logits_buf = lax.cond(
            valid_out,
            lambda: lax.dynamic_update_slice_in_dim(logits_buf, lg, out_idx * mb, axis=0),
            lambda: logits_buf,
        )
        act_next = lax.ppermute(x, "model", cc.pipeline_perm(S_eff, tp))
        return (act_next, caches, logits_buf), None

    T = mu + S_eff - 1
    act0 = jnp.zeros((mb, 1, d), dtype)
    logits0 = jnp.zeros((B_local, 1, V), jnp.float32)
    (_, new_caches, logits), _ = lax.scan(tick, (act0, caches_local, logits0), jnp.arange(T))
    # broadcast logits from the last stage to everyone (cheap: [B,1,V])
    logits = lax.psum(logits, "model") / tp
    return logits, new_caches


def pipeline_prefill(
    cfg: ArchConfig,
    plan: PipelinePlan,
    params_local,
    mask_local,
    batch_local,
    *,
    capacity: Optional[int] = None,
    has_pod: bool = False,
):
    """Pipelined prefill: returns (last-position logits [B_local,1,V], caches
    with leaves [ppstage, B_local, ...])."""
    assert plan.seq_shards == 1, (
        "seq-sharded (long-context) serving is decode-only; prefill a "
        "sharded cache by resharding an unsharded prefill (DESIGN.md)"
    )
    S_eff, tp, mu = plan.stages, plan.tensor, plan.microbatches
    ctx = make_ctx(plan, has_pod=has_pod)
    stage = stage_index(plan)
    is_first = stage == 0
    is_last = stage == S_eff - 1
    layers = params_local["layers"]
    some_leaf = jax.tree.leaves(batch_local)[0]
    B_local = some_leaf.shape[0]
    assert B_local % mu == 0
    mb = B_local // mu
    seq = (batch_local["frames"] if cfg.frontend == "audio" else batch_local["tokens"]).shape[1]
    positions = jnp.arange(seq, dtype=jnp.int32)
    d = cfg.d_model
    dtype = jnp.dtype(cfg.param_dtype)
    head_w = params_local["embed"] if cfg.tie_embeddings else params_local["head"]
    V = head_w.shape[0]

    # allocate full-stage cache buffers [ppstage, B_local, ...]
    cap = capacity if capacity is not None else seq
    cache_buf = _abstract_stage_caches(cfg, plan, B_local, cap, dtype)

    def tick(carry, t):
        act, caches, logits_buf = carry
        my_idx = jnp.clip(t - stage, 0, mu - 1)
        valid = jnp.logical_and(t - stage >= 0, t - stage < mu)
        batch_mb = _get_mb(batch_local, my_idx, mb)
        x = lax.cond(
            is_first,
            lambda: _embed(cfg, params_local, batch_mb).astype(dtype),
            lambda: act,
        )

        def per_inst(x, xs):
            inst_params, act_row = xs
            x, cs = period_prefill(
                inst_params, x, act_row, cfg=cfg, positions=positions, ctx=ctx,
                capacity=cap,
            )
            return x, cs

        x, mb_caches = lax.scan(per_inst, x, (layers, mask_local))
        caches = jax.tree.map(
            lambda full, mbv: lax.cond(
                valid,
                lambda: lax.dynamic_update_slice_in_dim(
                    full, mbv.astype(full.dtype), my_idx * mb, axis=1
                ),
                lambda: full,
            ),
            caches,
            mb_caches,
        )

        def logit_fn():
            hn = rms_norm(x[:, -1:], params_local["final_norm"], cfg.norm_eps)
            return (hn @ head_w.T).astype(jnp.float32)

        out_idx = jnp.clip(t - (S_eff - 1), 0, mu - 1)
        valid_out = jnp.logical_and(t - (S_eff - 1) >= 0, t - (S_eff - 1) < mu)
        lg = lax.cond(jnp.logical_and(is_last, valid_out), logit_fn,
                      lambda: jnp.zeros((mb, 1, V), jnp.float32))
        logits_buf = lax.cond(
            valid_out,
            lambda: lax.dynamic_update_slice_in_dim(logits_buf, lg, out_idx * mb, axis=0),
            lambda: logits_buf,
        )
        act_next = lax.ppermute(x, "model", cc.pipeline_perm(S_eff, tp))
        return (act_next, caches, logits_buf), None

    T = mu + S_eff - 1
    act0 = jnp.zeros((mb, seq, d), dtype)
    logits0 = jnp.zeros((B_local, 1, V), jnp.float32)
    (_, caches, logits), _ = lax.scan(tick, (act0, cache_buf, logits0), jnp.arange(T))
    logits = lax.psum(logits, "model") / tp
    return logits, caches


def _abstract_stage_caches(cfg: ArchConfig, plan: PipelinePlan, B_local: int,
                           s_ctx: int, dtype):
    """Zero-init per-stage cache buffers [ppstage, B_local, ...] with
    tp-sliced kv heads / d_inner.  Matches the leaves period_decode expects."""
    tp = plan.tensor
    kv_local = max(1, cfg.n_kv_heads // tp) if tp > 1 else cfg.n_kv_heads

    def one(spec):
        if spec.mixer == ATTN:
            capn = attention.cache_capacity(
                spec, s_ctx, plan.seq_shards if spec.window == GLOBAL_WINDOW else 1
            )
            c = attention.init_kv_cache(B_local, kv_local, capn, cfg.hd, dtype)
        elif spec.mixer == MAMBA:
            di = cfg.mamba.d_inner(cfg.d_model) // tp
            c = mamba.init_mamba_cache(B_local, cfg, di, dtype)
        elif spec.mixer == MLSTM:
            di = int(cfg.d_model * cfg.xlstm.m_proj_factor)  # tp-replicated
            c = xlstm.init_mlstm_cache(B_local, cfg, di, cfg.n_heads, dtype)
        elif spec.mixer == SLSTM:
            c = xlstm.init_slstm_cache(B_local, cfg, dtype)
        else:  # pragma: no cover
            raise ValueError(spec.mixer)
        return jax.tree.map(
            lambda a: jnp.zeros((plan.ppstage, *a.shape), a.dtype), c
        )

    return tuple(one(spec) for spec in cfg.period)
