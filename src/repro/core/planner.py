"""Co-optimization of model partition and resource allocation (paper §3.4).

The paper linearizes the nonlinear binary program (3) to an MIQP and calls
Gurobi.  No MIP solver ships offline, so we solve the *same formulation*
with layer merging (paper §4) + exhaustive enumeration over (d, partition)
+ per-stage memory by coordinate descent from the min-feasible assignment —
``method='exhaustive'`` cross-checks the heuristic on small instances (the
tests assert they agree).

Also implements the two comparison algorithms of §5.6:
  * ``tpdmp_solve`` — throughput-maximizing partition under fixed resources,
    grid-searched over resource allocations (TPDMP [63] adaptation);
  * ``bayes_solve`` — black-box random/Bayesian-style search over the joint
    space with the performance model as the evaluator (paper's Bayes setup).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.partition import ModelProfile, merge_layers, stages_of
from repro.core.perfmodel import Config, Evaluation, evaluate
from repro.serverless.platform import Platform

DEFAULT_D_OPTIONS = (1, 2, 4, 8, 16)


@dataclass(frozen=True)
class PlanResult:
    config: Config
    evaluation: Evaluation
    objective: float
    solve_seconds: float
    profile: ModelProfile  # (merged) profile the config indexes into


def _expand_z(stage_mem: Sequence[int], x: Sequence[int], L: int) -> tuple:
    z = []
    s = 0
    for i in range(L):
        z.append(stage_mem[s])
        if i < L - 1 and x[i]:
            s += 1
    return tuple(z)


def _min_feasible_stage_mem(profile, platform, x, d, mu) -> Optional[List[int]]:
    """Smallest memory option per stage satisfying eq (3b), else None."""
    arr = profile.arrays()
    opts = platform.memory_options
    sync_f = 4 - 2 * (1 if d == 1 else 0)
    out = []
    for lo, hi in stages_of(x):
        a = arr["a"][lo : hi + 1].sum()
        s = arr["s"][lo : hi + 1].sum()
        need = mu * a + s * sync_f + platform.base_memory
        j = next((j for j, m in enumerate(opts) if m >= need), None)
        if j is None:
            return None
        out.append(j)
    return out


def _cd_from(profile, platform, x, d, mu, a1, a2, pipelined_sync,
             start: List[int], floor: List[int], sweeps: int = 6):
    J = len(platform.memory_options)
    L = profile.L
    stage_mem = list(start)
    best_cfg = Config(x=tuple(x), d=d, z=_expand_z(stage_mem, x, L))
    best = evaluate(profile, platform, best_cfg, mu * d, pipelined_sync=pipelined_sync)
    if not best.mem_ok:
        return None, None
    best_obj = best.objective(a1, a2)
    n_stages = len(stage_mem)
    for _ in range(sweeps):
        improved = False
        for s in range(n_stages):
            for j in range(floor[s], J):  # never below min-feasible
                if j == stage_mem[s]:
                    continue
                trial = list(stage_mem)
                trial[s] = j
                cfg = Config(x=tuple(x), d=d, z=_expand_z(trial, x, L))
                ev = evaluate(profile, platform, cfg, mu * d, pipelined_sync=pipelined_sync)
                if ev.mem_ok and ev.objective(a1, a2) < best_obj:
                    stage_mem, best_cfg, best, best_obj = trial, cfg, ev, ev.objective(a1, a2)
                    improved = True
        if not improved:
            break
    return best_cfg, best


def _coordinate_descent(profile, platform, x, d, mu, a1, a2, pipelined_sync,
                        init_mem: List[int], sweeps: int = 6):
    """Multi-start coordinate descent on per-stage memory: starts from the
    min-feasible assignment, the max assignment, and uniform levels — greedy
    CD alone gets caught in neighbor-coupled local optima (upload/download
    terms couple adjacent stages)."""
    J = len(platform.memory_options)
    n_stages = len(init_mem)
    starts = [list(init_mem), [J - 1] * n_stages]
    for j in range(J):
        uniform = [max(j, f) for f in init_mem]
        if uniform not in starts:
            starts.append(uniform)
    best_cfg, best_ev, best_obj = None, None, np.inf
    for start in starts:
        cfg, ev = _cd_from(profile, platform, x, d, mu, a1, a2, pipelined_sync,
                           start, init_mem, sweeps)
        if cfg is None:
            continue
        obj = ev.objective(a1, a2)
        if obj < best_obj:
            best_cfg, best_ev, best_obj = cfg, ev, obj
    if best_cfg is None:
        return None, None
    return best_cfg, best_ev


def _partitions(L: int, max_stages: Optional[int] = None):
    for bits in itertools.product((0, 1), repeat=L - 1):
        if max_stages is not None and sum(bits) + 1 > max_stages:
            continue
        yield bits


def solve(
    profile: ModelProfile,
    platform: Platform,
    *,
    alpha: Tuple[float, float],
    total_micro_batches: int,
    d_options: Sequence[int] = DEFAULT_D_OPTIONS,
    merge_to: int = 10,
    max_stages: Optional[int] = None,
    method: str = "cd",
    pipelined_sync: bool = True,
) -> Optional[PlanResult]:
    """FuncPipe's co-optimizer.  Returns the best feasible plan or None."""
    t0 = time.time()
    a1, a2 = alpha
    prof = merge_layers(profile, merge_to)
    L = prof.L
    J = len(platform.memory_options)
    best: Optional[PlanResult] = None
    for d in d_options:
        if total_micro_batches % d or total_micro_batches < d:
            continue
        mu = total_micro_batches // d
        for x in _partitions(L, max_stages):
            init = _min_feasible_stage_mem(prof, platform, x, d, mu)
            if init is None:
                continue
            if method == "exhaustive":
                n_stages = sum(x) + 1
                best_cfg, best_ev, best_o = None, None, np.inf
                for combo in itertools.product(range(J), repeat=n_stages):
                    if any(c < i for c, i in zip(combo, init)):
                        continue
                    cfg = Config(x=tuple(x), d=d, z=_expand_z(list(combo), x, L))
                    ev = evaluate(prof, platform, cfg, total_micro_batches,
                                  pipelined_sync=pipelined_sync)
                    if ev.mem_ok and ev.objective(a1, a2) < best_o:
                        best_cfg, best_ev, best_o = cfg, ev, ev.objective(a1, a2)
                cfg, ev = best_cfg, best_ev
            else:
                cfg, ev = _coordinate_descent(prof, platform, x, d, mu, a1, a2,
                                              pipelined_sync, init)
            if cfg is None:
                continue
            obj = ev.objective(a1, a2)
            if best is None or obj < best.objective:
                best = PlanResult(cfg, ev, obj, 0.0, prof)
    if best is not None:
        best = dataclasses.replace(best, solve_seconds=time.time() - t0)
    return best


# ------------------------------------------------------------------ baselines
def tpdmp_solve(
    profile: ModelProfile,
    platform: Platform,
    *,
    alpha: Tuple[float, float],
    total_micro_batches: int,
    d_options: Sequence[int] = DEFAULT_D_OPTIONS,
    merge_to: int = 10,
    pipelined_sync: bool = True,
) -> Optional[PlanResult]:
    """Throughput-only partitioning (TPDMP-style) under a grid of fixed
    resource allocations; the objective selects among grid points (§5.1)."""
    t0 = time.time()
    a1, a2 = alpha
    prof = merge_layers(profile, merge_to)
    L = prof.L
    J = len(platform.memory_options)
    best: Optional[PlanResult] = None
    for d in d_options:
        if total_micro_batches % d or total_micro_batches < d:
            continue
        mu = total_micro_batches // d
        for j in range(J):  # uniform memory grid
            best_t, best_cfg, best_ev = np.inf, None, None
            for x in _partitions(L):
                cfg = Config(x=tuple(x), d=d, z=tuple([j] * L))
                ev = evaluate(prof, platform, cfg, total_micro_batches,
                              pipelined_sync=pipelined_sync)
                if ev.mem_ok and ev.t_iter < best_t:   # throughput only
                    best_t, best_cfg, best_ev = ev.t_iter, cfg, ev
            if best_cfg is None:
                continue
            obj = best_ev.objective(a1, a2)
            if best is None or obj < best.objective:
                best = PlanResult(best_cfg, best_ev, obj, 0.0, prof)
    if best is not None:
        best = dataclasses.replace(best, solve_seconds=time.time() - t0)
    return best


def bayes_solve(
    profile: ModelProfile,
    platform: Platform,
    *,
    alpha: Tuple[float, float],
    total_micro_batches: int,
    d_options: Sequence[int] = DEFAULT_D_OPTIONS,
    merge_to: int = 10,
    rounds: int = 100,
    seed: int = 0,
    pipelined_sync: bool = True,
) -> Optional[PlanResult]:
    """Black-box joint search (paper's Bayes baseline): seeded random
    proposals + local mutation of the incumbent, evaluated on the performance
    model (the paper does the same to avoid measurement cost, App. E)."""
    t0 = time.time()
    a1, a2 = alpha
    prof = merge_layers(profile, merge_to)
    L = prof.L
    J = len(platform.memory_options)
    rng = np.random.default_rng(seed)
    ds = [d for d in d_options if total_micro_batches % d == 0 and total_micro_batches >= d]
    best: Optional[PlanResult] = None

    def propose():
        if best is not None and rng.random() < 0.5:  # local mutation
            cfg = best.config
            x = list(cfg.x)
            if L > 1 and rng.random() < 0.5:
                i = rng.integers(0, L - 1)
                x[i] = 1 - x[i]
            stage_mem = [cfg.z[lo] for lo, _ in stages_of(x)]
            s = rng.integers(0, len(stage_mem))
            stage_mem[s] = int(np.clip(stage_mem[s] + rng.integers(-1, 2), 0, J - 1))
            return tuple(x), int(cfg.d), stage_mem
        x = tuple(rng.integers(0, 2, size=L - 1))
        d = int(rng.choice(ds))
        stage_mem = list(rng.integers(0, J, size=sum(x) + 1))
        return x, d, stage_mem

    for _ in range(rounds):
        x, d, stage_mem = propose()
        cfg = Config(x=tuple(x), d=d, z=_expand_z(stage_mem, x, L))
        ev = evaluate(prof, platform, cfg, total_micro_batches,
                      pipelined_sync=pipelined_sync)
        if not ev.mem_ok:
            continue
        obj = ev.objective(a1, a2)
        if best is None or obj < best.objective:
            best = PlanResult(cfg, ev, obj, 0.0, prof)
    if best is not None:
        best = dataclasses.replace(best, solve_seconds=time.time() - t0)
    return best


# -------------------------------------------------------------- recommendation
def recommend(results: Sequence[PlanResult], threshold: float = 0.8) -> PlanResult:
    """Paper §5.1: fastest config whose speedup/cost-increase ratio over the
    min-cost config satisfies delta >= threshold."""
    feas = [r for r in results if r is not None]
    assert feas
    mc = min(feas, key=lambda r: r.evaluation.c_iter)
    t_mc, c_mc = mc.evaluation.t_iter, mc.evaluation.c_iter
    cands = []
    for r in feas:
        t_p, c_p = r.evaluation.t_iter, r.evaluation.c_iter
        if c_p <= c_mc or t_p >= t_mc:
            delta = np.inf if (c_p <= c_mc and t_p <= t_mc) else 0.0
        else:
            delta = (t_mc / t_p - 1) / (c_p / c_mc - 1)
        if delta >= threshold:
            cands.append(r)
    if not cands:
        return mc
    return min(cands, key=lambda r: r.evaluation.t_iter)
