"""Co-optimization of model partition and resource allocation (paper §3.4).

The paper linearizes the nonlinear binary program (3) to an MIQP and calls
Gurobi.  No MIP solver ships offline, so we solve the *same formulation*
with layer merging (paper §4) + exhaustive enumeration over (d, partition)
+ per-stage memory by coordinate descent from the min-feasible assignment —
``method='exhaustive'`` cross-checks the heuristic on small instances (the
tests assert they agree).

Three engines drive the search:

  * ``engine='scalar'`` — the seed implementation: one ``perfmodel.evaluate``
    call per candidate.  Kept as the reference the batched engine is
    parity-tested against.
  * ``engine='batch'`` (default) — candidates are enumerated as index arrays
    and evaluated through ``perfmodel.evaluate_batch``: the coordinate
    descent runs every (partition, start) trajectory in lockstep, evaluating
    all (stage, level) neighbors of every incumbent in one batched call per
    coordinate step; exhaustive mode is one batched call per partition.  The
    update rule is the exact scalar rule (strict-improvement, first-minimizer
    tie-breaks), so both engines return the *identical* plan — the batch
    engine is just 1-2 orders of magnitude faster, which is what lets the
    default ``merge_to`` sit at 14 instead of the seed's 10.  On monotone
    platforms (more memory never slower) the batch engine additionally
    prunes partitions by an objective lower bound (t at max memory, cost at
    min-feasible memory); the bound only ever discards partitions that
    provably cannot tie the incumbent, so exactness of the CD-per-partition
    scheme is preserved.
  * ``engine='dp'`` (:func:`dp_solve`) — the exact dynamic program over
    stage cut-points: per-stage costs are (lo, hi, mem-level)-separable on
    the precomputed ``perfmodel.segment_tables`` except for the cross-stage
    boundary transfers, which the DP carries as a one-level boundary state;
    the pipeline bottleneck (max) terms ride along as a Pareto-valued state,
    so the result is *provably optimal* per (d, M) — no CD heuristic, no
    2^(L-1) enumeration.  The only engine for which ``merge_to=None`` (full
    layer depth) is tractable.

Also implements the two comparison algorithms of §5.6:
  * ``tpdmp_solve`` — throughput-maximizing partition under fixed resources,
    grid-searched over resource allocations (TPDMP [63] adaptation);
  * ``bayes_solve`` — black-box random/Bayesian-style search over the joint
    space with the performance model as the evaluator (paper's Bayes setup).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.partition import (
    ModelProfile,
    hat,
    merge_layers,
    stage_ids,
    stages_of,
)
from repro.core.perfmodel import (
    BatchEvaluation,
    Config,
    Evaluation,
    PerfTables,
    SegmentTables,
    evaluate,
    evaluate_batch,
    perf_tables,
    segment_tables,
    sync_time_nonpipelined,
    sync_time_pipelined,
)
from repro.serverless.platform import GB, Platform

DEFAULT_D_OPTIONS = (1, 2, 4, 8, 16)
DEFAULT_MERGE_TO = 14          # seed scalar solver had to stop at 10
_CHUNK_ROWS = 1 << 17          # max evaluate_batch rows per call
_CD_SWEEPS = 6


@dataclass
class PlannerStats:
    """Instrumentation counters from one solve: how much of the search space
    each engine actually expanded vs pruned.  Purely observational — no
    engine changes behavior based on them (``repro plan`` prints them; sweeps
    aggregate them next to the plan-cache hit/miss counters)."""

    engine: str = ""
    # batch/scalar engines: feasible partitions polished through coordinate
    # descent vs discarded by the lower-bound screen before any CD work
    partitions_polished: int = 0
    partitions_pruned: int = 0
    # dp engine: (p, j) suffix states expanded; Pareto rows kept vs discarded
    # by componentwise dominance vs discarded by the admissible completion
    # bound against the incumbent
    dp_states: int = 0
    dp_rows_kept: int = 0
    dp_rows_dominated: int = 0
    dp_rows_bounded: int = 0

    def describe(self) -> str:
        if self.engine == "dp":
            return (f"dp: {self.dp_states} states, "
                    f"{self.dp_rows_kept} rows kept, "
                    f"{self.dp_rows_dominated} dominated, "
                    f"{self.dp_rows_bounded} bounded")
        return (f"{self.engine}: {self.partitions_polished} partitions "
                f"polished, {self.partitions_pruned} pruned")


@dataclass(frozen=True)
class PlanResult:
    config: Config
    evaluation: Evaluation
    objective: float
    solve_seconds: float
    profile: ModelProfile  # (merged) profile the config indexes into
    stats: Optional[PlannerStats] = None   # search-space counters (optional)


def _merged(profile: ModelProfile, merge_to: Optional[int]) -> ModelProfile:
    """merge_to=None means plan at full layer depth (no merging)."""
    return profile if merge_to is None else merge_layers(profile, merge_to)


def _expand_z(stage_mem: Sequence[int], x: Sequence[int], L: int) -> tuple:
    z = []
    s = 0
    for i in range(L):
        z.append(stage_mem[s])
        if i < L - 1 and x[i]:
            s += 1
    return tuple(z)


def _min_feasible_stage_mem(profile, platform, x, d, mu) -> Optional[List[int]]:
    """Smallest memory option per stage satisfying eq (3b), else None.

    Stage sums come from the ``hat`` recurrence (same association as the
    batched path) so both engines agree on feasibility thresholds."""
    arr = profile.arrays()
    opts = platform.memory_options
    sync_f = 4 - 2 * (1 if d == 1 else 0)
    xa = np.asarray(x, dtype=np.int64)
    hat_a = hat(arr["a"], xa)
    hat_s = hat(arr["s"], xa)
    out = []
    for lo, hi in stages_of(x):
        need = mu * hat_a[hi] + hat_s[hi] * sync_f + platform.base_memory
        j = next((j for j, m in enumerate(opts) if m >= need), None)
        if j is None:
            return None
        out.append(j)
    return out


# ------------------------------------------------------------- scalar engine
def _cd_from(profile, platform, x, d, mu, a1, a2, pipelined_sync,
             start: List[int], floor: List[int], sweeps: int = _CD_SWEEPS):
    J = len(platform.memory_options)
    L = profile.L
    stage_mem = list(start)
    best_cfg = Config(x=tuple(x), d=d, z=_expand_z(stage_mem, x, L))
    best = evaluate(profile, platform, best_cfg, mu * d, pipelined_sync=pipelined_sync)
    if not best.mem_ok:
        return None, None, None
    best_obj = best.objective(a1, a2)
    n_stages = len(stage_mem)
    for _ in range(sweeps):
        improved = False
        for s in range(n_stages):
            for j in range(floor[s], J):  # never below min-feasible
                if j == stage_mem[s]:
                    continue
                trial = list(stage_mem)
                trial[s] = j
                cfg = Config(x=tuple(x), d=d, z=_expand_z(trial, x, L))
                ev = evaluate(profile, platform, cfg, mu * d, pipelined_sync=pipelined_sync)
                if ev.mem_ok and ev.objective(a1, a2) < best_obj:
                    stage_mem, best_cfg, best, best_obj = trial, cfg, ev, ev.objective(a1, a2)
                    improved = True
        if not improved:
            break
    return best_cfg, best, best_obj


def _cd_from_steepest(profile, platform, x, d, mu, a1, a2, pipelined_sync,
                      start: List[int], floor: List[int],
                      sweeps: int = _CD_SWEEPS):
    """Steepest-descent CD (``method='cd-steepest'``): each move evaluates
    *all* (stage, level) neighbors of the incumbent and accepts the single
    best strict improvement (ties: first in stage-major, level order).  The
    move budget ``sweeps * n_stages`` matches the first-improvement rule's
    maximum accepted-move count, so the two rules get equal search effort."""
    J = len(platform.memory_options)
    L = profile.L
    stage_mem = list(start)
    best_cfg = Config(x=tuple(x), d=d, z=_expand_z(stage_mem, x, L))
    best = evaluate(profile, platform, best_cfg, mu * d,
                    pipelined_sync=pipelined_sync)
    if not best.mem_ok:
        return None, None, None
    best_obj = best.objective(a1, a2)
    n_stages = len(stage_mem)
    for _ in range(sweeps * max(1, n_stages)):
        move = None                        # (obj, s, j, cfg, ev)
        for s in range(n_stages):
            for j in range(floor[s], J):   # never below min-feasible
                if j == stage_mem[s]:
                    continue
                trial = list(stage_mem)
                trial[s] = j
                cfg = Config(x=tuple(x), d=d, z=_expand_z(trial, x, L))
                ev = evaluate(profile, platform, cfg, mu * d,
                              pipelined_sync=pipelined_sync)
                obj = ev.objective(a1, a2)
                if ev.mem_ok and obj < best_obj and \
                        (move is None or obj < move[0]):
                    move = (obj, s, j, cfg, ev)
        if move is None:
            break
        best_obj, s_mv, j_mv, best_cfg, best = move
        stage_mem[s_mv] = j_mv
    return best_cfg, best, best_obj


def _cd_starts(init_mem: Sequence[int], J: int) -> List[List[int]]:
    """Multi-start list for the per-stage memory CD, deduplicated keeping
    first occurrence: the min-feasible assignment, the max assignment, and
    uniform levels clipped to the feasibility floor."""
    n_stages = len(init_mem)
    starts: List[List[int]] = []
    for cand in [list(init_mem), [J - 1] * n_stages] + [
            [max(j, f) for f in init_mem] for j in range(J)]:
        if cand not in starts:
            starts.append(cand)
    return starts


def _coordinate_descent(profile, platform, x, d, mu, a1, a2, pipelined_sync,
                        init_mem: List[int], sweeps: int = _CD_SWEEPS,
                        rule: str = "first"):
    """Multi-start coordinate descent on per-stage memory: starts from the
    min-feasible assignment, the max assignment, and uniform levels — greedy
    CD alone gets caught in neighbor-coupled local optima (upload/download
    terms couple adjacent stages).  ``rule`` picks the update rule: the
    first-improvement stage sweep (``'first'``) or steepest descent over all
    (stage, level) neighbors (``'steepest'``)."""
    J = len(platform.memory_options)
    descend = _cd_from if rule == "first" else _cd_from_steepest
    best_cfg, best_ev, best_obj = None, None, np.inf
    for start in _cd_starts(init_mem, J):
        cfg, ev, obj = descend(profile, platform, x, d, mu, a1, a2,
                               pipelined_sync, start, init_mem, sweeps)
        if cfg is None:
            continue
        if obj < best_obj:
            best_cfg, best_ev, best_obj = cfg, ev, obj
    if best_cfg is None:
        return None, None
    return best_cfg, best_ev


def _partitions(L: int, max_stages: Optional[int] = None):
    for bits in itertools.product((0, 1), repeat=L - 1):
        if max_stages is not None and sum(bits) + 1 > max_stages:
            continue
        yield bits


def _solve_scalar(profile, platform, *, alpha, total_micro_batches, d_options,
                  merge_to, max_stages, method, pipelined_sync):
    t0 = time.time()
    a1, a2 = alpha
    prof = _merged(profile, merge_to)
    L = prof.L
    J = len(platform.memory_options)
    best: Optional[PlanResult] = None
    stats = PlannerStats(engine="scalar")
    for d in d_options:
        if total_micro_batches % d or total_micro_batches < d:
            continue
        mu = total_micro_batches // d
        for x in _partitions(L, max_stages):
            init = _min_feasible_stage_mem(prof, platform, x, d, mu)
            if init is None:
                continue
            stats.partitions_polished += 1
            if method == "exhaustive":
                n_stages = sum(x) + 1
                best_cfg, best_ev, best_o = None, None, np.inf
                for combo in itertools.product(range(J), repeat=n_stages):
                    if any(c < i for c, i in zip(combo, init)):
                        continue
                    cfg = Config(x=tuple(x), d=d, z=_expand_z(list(combo), x, L))
                    ev = evaluate(prof, platform, cfg, total_micro_batches,
                                  pipelined_sync=pipelined_sync)
                    if ev.mem_ok and ev.objective(a1, a2) < best_o:
                        best_cfg, best_ev, best_o = cfg, ev, ev.objective(a1, a2)
                cfg, ev = best_cfg, best_ev
            else:
                cfg, ev = _coordinate_descent(
                    prof, platform, x, d, mu, a1, a2, pipelined_sync, init,
                    rule="steepest" if method == "cd-steepest" else "first")
            if cfg is None:
                continue
            obj = ev.objective(a1, a2)
            if best is None or obj < best.objective:
                best = PlanResult(cfg, ev, obj, 0.0, prof)
    if best is not None:
        best = dataclasses.replace(best, solve_seconds=time.time() - t0,
                                   stats=stats)
    return best


# ------------------------------------------------------------- batch engine
def _partition_matrix(L: int, max_stages: Optional[int] = None) -> np.ndarray:
    """All boundary vectors of ``_partitions`` as an ``[P, L-1]`` matrix, in
    the same (itertools.product) enumeration order."""
    if L <= 1:
        return np.zeros((1, 0), dtype=np.int64)
    P = 1 << (L - 1)
    bits = (np.arange(P, dtype=np.int64)[:, None]
            >> np.arange(L - 2, -1, -1, dtype=np.int64)) & 1
    if max_stages is not None:
        bits = bits[bits.sum(axis=1) + 1 <= max_stages]
    return bits


def _stage_layout(X: np.ndarray):
    """sid [P, L], n_stages [P], per-stage high-layer index [P, S_max]."""
    sid = stage_ids(X)
    n_stages = sid[:, -1] + 1
    S_max = int(n_stages.max())
    high_pos = np.empty((len(X), S_max), dtype=np.int64)
    for s in range(S_max):
        high_pos[:, s] = np.sum(sid <= s, axis=1) - 1
    return sid, n_stages, high_pos, S_max


def _floors_batch(tables: PerfTables, X, high_pos, n_stages, d, mu):
    """Vectorized `_min_feasible_stage_mem` over a partition matrix: returns
    the per-stage floor indices [P, S_max] (padded stages clamped to 0) and
    the feasibility mask [P]."""
    N = len(X)
    L = tables.L
    sync_f = 4 - 2 * (1 if d == 1 else 0)
    hat_a = hat(np.broadcast_to(tables.a, (N, L)), X)
    hat_s = hat(np.broadcast_to(tables.s, (N, L)), X)
    need = mu * hat_a + hat_s * sync_f + tables.base_memory
    j_need = np.searchsorted(tables.mem_opts, need, side="left")   # [N, L]
    floor_st = np.take_along_axis(j_need, high_pos, axis=1)        # [N, S_max]
    s_idx = np.arange(floor_st.shape[1])[None, :]
    real = s_idx < n_stages[:, None]
    feasible = np.all(~real | (floor_st < tables.J), axis=1)
    return np.where(real, floor_st, 0), feasible


def _starts_batch(floor_st: np.ndarray, n_stages: np.ndarray, J: int):
    """Per-partition CD start candidates [P, K, S_max] + validity mask [P, K],
    mirroring `_cd_starts` (order + keep-first-occurrence dedupe)."""
    N, S_max = floor_st.shape
    K = 2 + J
    cand = np.empty((N, K, S_max), dtype=np.int64)
    cand[:, 0] = floor_st
    cand[:, 1] = J - 1
    for j in range(J):
        cand[:, 2 + j] = np.maximum(j, floor_st)
    pad = np.broadcast_to(
        np.arange(S_max)[None, None, :] >= n_stages[:, None, None], cand.shape)
    cand[pad] = 0
    valid = np.ones((N, K), dtype=bool)
    for k in range(1, K):
        dup = np.zeros(N, dtype=bool)
        for kp in range(k):
            dup |= valid[:, kp] & np.all(cand[:, k] == cand[:, kp], axis=1)
        valid[:, k] = ~dup
    return cand, valid


def _eval_chunked(profile, platform, tables, X, Z, d, M, pipelined_sync) -> BatchEvaluation:
    N = len(X)
    if N <= _CHUNK_ROWS:
        return evaluate_batch(profile, platform, X, Z, d, M,
                              pipelined_sync=pipelined_sync, tables=tables)
    parts = [evaluate_batch(profile, platform, X[lo:lo + _CHUNK_ROWS],
                            Z[lo:lo + _CHUNK_ROWS], d, M,
                            pipelined_sync=pipelined_sync, tables=tables)
             for lo in range(0, N, _CHUNK_ROWS)]
    return BatchEvaluation(*[np.concatenate([getattr(p, f.name) for p in parts])
                             for f in dataclasses.fields(BatchEvaluation)])


def _cd_lockstep(profile, platform, tables, X, sid, n_stages, floor_st, sm, tp,
                 d, M, a1, a2, pipelined_sync, sweeps):
    """Run every (partition, start) CD trajectory in lockstep.

    Each trajectory follows the exact `_cd_from` update rule — per sweep,
    per stage, evaluate all memory levels of that stage against the
    trajectory's incumbent and accept the first minimizer iff it strictly
    improves — but all trajectories' (stage, level) neighbors are evaluated
    in one `evaluate_batch` call per coordinate step.  Returns per-trajectory
    best objectives and final stage assignments (both exactly what the
    scalar engine would compute)."""
    T_, S_max = sm.shape
    L = tables.L
    J = tables.J
    X_t, sid_t, ns_t, fl_t = X[tp], sid[tp], n_stages[tp], floor_st[tp]
    Z0 = np.take_along_axis(sm, sid_t, axis=1)
    be = _eval_chunked(profile, platform, tables, X_t, Z0, d, M, pipelined_sync)
    best_obj = be.masked_objective(a1, a2)
    alive = np.isfinite(best_obj)          # infeasible start == scalar None
    jr = np.arange(J)
    step = max(1, _CHUNK_ROWS // J)
    for _ in range(sweeps):
        improved = np.zeros(T_, dtype=bool)
        for s in range(S_max):
            act = np.nonzero(alive & (ns_t > s))[0]
            for lo in range(0, len(act), step):
                ai = act[lo:lo + step]
                A = len(ai)
                base_z = np.take_along_axis(sm[ai], sid_t[ai], axis=1)   # [A, L]
                mask_s = sid_t[ai] == s
                Z_nb = np.where(mask_s[:, None, :], jr[None, :, None],
                                base_z[:, None, :]).reshape(A * J, L)
                X_nb = np.repeat(X_t[ai], J, axis=0)
                be = evaluate_batch(profile, platform, X_nb, Z_nb, d, M,
                                    pipelined_sync=pipelined_sync, tables=tables)
                obj = be.masked_objective(a1, a2).reshape(A, J)
                obj[jr[None, :] < fl_t[ai, s][:, None]] = np.inf
                bj = np.argmin(obj, axis=1)          # lowest level on ties
                bv = obj[np.arange(A), bj]
                acc = bv < best_obj[ai]              # strict improvement only
                upd = ai[acc]
                sm[upd, s] = bj[acc]
                best_obj[upd] = bv[acc]
                improved[upd] = True
        alive &= improved
        if not alive.any():
            break
    return best_obj, sm


def _cd_lockstep_steepest(profile, platform, tables, X, sid, n_stages,
                          floor_st, sm, tp, d, M, a1, a2, pipelined_sync,
                          sweeps):
    """Lockstep twin of `_cd_from_steepest`: per move, every alive
    trajectory's full (stage, level) neighborhood is evaluated in one
    batched call and the single best strict improvement accepted
    (np.argmin's first-occurrence = the scalar rule's stage-major, level
    tie-break), with the same ``sweeps * n_stages`` per-trajectory move
    budget — so batch and scalar steepest return identical plans."""
    T_, S_max = sm.shape
    L = tables.L
    J = tables.J
    X_t, sid_t, ns_t, fl_t = X[tp], sid[tp], n_stages[tp], floor_st[tp]
    Z0 = np.take_along_axis(sm, sid_t, axis=1)
    be = _eval_chunked(profile, platform, tables, X_t, Z0, d, M, pipelined_sync)
    best_obj = be.masked_objective(a1, a2)
    alive = np.isfinite(best_obj)          # infeasible start == scalar None
    moves = np.zeros(T_, dtype=np.int64)
    max_moves = sweeps * np.maximum(ns_t, 1)
    NB = S_max * J
    jr = np.arange(J)
    sr = np.arange(S_max)
    step = max(1, _CHUNK_ROWS // NB)
    while alive.any():
        act = np.nonzero(alive)[0]
        for lo in range(0, len(act), step):
            ai = act[lo:lo + step]
            A = len(ai)
            base_z = np.take_along_axis(sm[ai], sid_t[ai], axis=1)   # [A, L]
            # neighbor (stage, level) tensor: set stage s to level j
            mask = sid_t[ai][:, None, :] == sr[None, :, None]        # [A, S, L]
            Z_nb = np.where(mask[:, :, None, :], jr[None, None, :, None],
                            base_z[:, None, None, :]).reshape(A * NB, L)
            X_nb = np.repeat(X_t[ai], NB, axis=0)
            be = evaluate_batch(profile, platform, X_nb, Z_nb, d, M,
                                pipelined_sync=pipelined_sync, tables=tables)
            obj = be.masked_objective(a1, a2).reshape(A, S_max, J)
            obj[sr[None, :] >= ns_t[ai][:, None]] = np.inf    # padded stages
            obj[jr[None, None, :] < fl_t[ai][:, :, None]] = np.inf  # floors
            flat = obj.reshape(A, NB)
            bj = np.argmin(flat, axis=1)         # first minimizer on ties
            bv = flat[np.arange(A), bj]
            acc = bv < best_obj[ai]              # strict improvement only
            upd = ai[acc]
            s_mv, j_mv = np.divmod(bj[acc], J)
            sm[upd, s_mv] = j_mv
            best_obj[upd] = bv[acc]
            moves[upd] += 1
            alive[ai[~acc]] = False
            alive[upd[moves[upd] >= max_moves[upd]]] = False
    return best_obj, sm


def _reduce_per_partition(tp, best_obj, sm):
    """Per-partition minimum over start trajectories, first-start tie-break
    (`tp` must be sorted ascending; trajectories ordered by start rank)."""
    seg = np.flatnonzero(np.r_[True, tp[1:] != tp[:-1]])
    pres = tp[seg]
    min_obj = np.minimum.reduceat(best_obj, seg)
    tidx = np.arange(len(tp))
    cand = np.where(best_obj == min_obj[np.searchsorted(pres, tp)], tidx, len(tp))
    win = np.minimum.reduceat(cand, seg)
    return pres, min_obj, sm[win]


def _lb_screen(profile, platform, tables, X, sid, floor_st, n_stages, d, M,
               a1, a2, pipelined_sync):
    """Pruning screen: per-partition objective lower bound + achievable prime.

    The lower bound combines the iteration time at max memory (valid because
    the tables are monotone) with the cost at the min-feasible allocation;
    it is shrunk by 1e-9 relative so float noise can never prune a partition
    that ties the optimum.  Both screening evaluations (floor and max
    assignments) are real CD start points, so the better of their objectives
    is an *achievable* incumbent that primes pruning before any CD runs."""
    N = len(X)
    Zmax = np.full((N, tables.L), tables.J - 1, dtype=np.int64)
    be_max = _eval_chunked(profile, platform, tables, X, Zmax, d, M, pipelined_sync)
    t_min = be_max.t_iter
    s_idx = np.arange(floor_st.shape[1])[None, :]
    memfloor = d * np.where(s_idx < n_stages[:, None],
                            tables.mem_opts[floor_st], 0.0).sum(axis=1)
    lb = a1 * tables.price_per_gb_s * (memfloor / GB) * t_min + a2 * t_min
    Zfloor = np.take_along_axis(floor_st, sid, axis=1)
    be_floor = _eval_chunked(profile, platform, tables, X, Zfloor, d, M,
                             pipelined_sync)
    prime = float(min(be_max.masked_objective(a1, a2).min(),
                      be_floor.masked_objective(a1, a2).min()))
    return lb * (1 - 1e-9), prime


def _solve_batch(profile, platform, *, alpha, total_micro_batches, d_options,
                 merge_to, max_stages, method, pipelined_sync):
    t0 = time.time()
    a1, a2 = alpha
    prof = _merged(profile, merge_to)
    L = prof.L
    M = total_micro_batches
    tables = perf_tables(prof, platform)
    J = tables.J
    best_key = None                  # (objective, d_rank, partition enum idx)
    best_state = None                # (x row, z row, d)
    stats = PlannerStats(engine="batch")
    X_all = _partition_matrix(L, max_stages)         # d-independent
    sid_all, ns_all, hp_all, S_max = _stage_layout(X_all)

    for d_rank, d in enumerate(d_options):
        if M % d or M < d:
            continue
        mu = M // d
        floor_st, feasible = _floors_batch(tables, X_all, hp_all, ns_all, d, mu)
        idx = np.nonzero(feasible)[0]
        if len(idx) == 0:
            continue
        X_f, sid_f, ns_f, fl_f = X_all[idx], sid_all[idx], ns_all[idx], floor_st[idx]

        if method == "exhaustive":
            for p in range(len(idx)):
                S = int(ns_f[p])
                total = J ** S
                if total > 10**12:  # int64 digit decode + any hope of finishing
                    raise ValueError(
                        f"method='exhaustive' would enumerate {J}^{S} memory "
                        "combos; use method='cd' at this depth")
                # stream combos in itertools.product order, chunked so memory
                # stays bounded (the scalar engine streamed one at a time)
                pows = J ** np.arange(S - 1, -1, -1, dtype=np.int64)
                best_o, best_z = np.inf, None
                for clo in range(0, total, _CHUNK_ROWS):
                    ci = np.arange(clo, min(clo + _CHUNK_ROWS, total),
                                   dtype=np.int64)
                    combos = (ci[:, None] // pows) % J
                    combos = combos[np.all(combos >= fl_f[p, :S], axis=1)]
                    if len(combos) == 0:
                        continue
                    Z = combos[:, sid_f[p]]                     # [C, L]
                    X_rep = np.broadcast_to(X_f[p], (len(combos), L - 1))
                    be = _eval_chunked(prof, platform, tables, X_rep, Z, d, M,
                                       pipelined_sync)
                    obj = be.masked_objective(a1, a2)
                    k = int(np.argmin(obj))                     # first minimizer
                    if obj[k] < best_o:     # strict: earlier chunks win ties
                        best_o, best_z = float(obj[k]), Z[k]
                if best_z is None or not np.isfinite(best_o):
                    continue
                key = (best_o, d_rank, int(idx[p]))
                if best_key is None or key < best_key:
                    best_key, best_state = key, (X_f[p], best_z, d)
            stats.partitions_polished += len(idx)
            continue

        # ---- coordinate descent over all partitions, LB-pruned and chunked
        cand_sm, valid = _starts_batch(fl_f, ns_f, J)
        pruning = tables.monotone and a1 >= 0 and a2 >= 0
        if pruning:
            lb, prime = _lb_screen(prof, platform, tables, X_f, sid_f, fl_f,
                                   ns_f, d, M, a1, a2, pipelined_sync)
            order = np.argsort(lb, kind="stable")
        else:
            lb, prime = np.full(len(idx), -np.inf), np.inf
            order = np.arange(len(idx))
        # grow chunks: a small first chunk (best LB candidates) establishes
        # the incumbent cheaply, so the bulk of the space is LB-pruned
        max_chunk = max(64, _CHUNK_ROWS // ((2 + J) * J))
        chunk, pos = 64, 0
        polished_d = 0
        while pos < len(order):
            sel = order[pos:pos + chunk]
            pos += chunk
            chunk = min(max_chunk, chunk * 4)
            inc = min(prime, best_key[0]) if best_key is not None else prime
            if pruning and lb[sel].min() > inc:
                break                    # lb sorted: nothing later can tie
            sel = sel[lb[sel] <= inc]
            if len(sel) == 0:
                continue
            polished_d += len(sel)
            tp, rank = np.nonzero(valid[sel])
            sm = cand_sm[sel][tp, rank].copy()
            lockstep = (_cd_lockstep_steepest if method == "cd-steepest"
                        else _cd_lockstep)
            b_obj, sm = lockstep(prof, platform, tables, X_f[sel], sid_f[sel],
                                 ns_f[sel], fl_f[sel], sm, tp, d, M, a1, a2,
                                 pipelined_sync, _CD_SWEEPS)
            pres, min_obj, win_sm = _reduce_per_partition(tp, b_obj, sm)
            for q in range(len(pres)):
                if not np.isfinite(min_obj[q]):
                    continue
                p_loc = int(pres[q])
                key = (float(min_obj[q]), d_rank, int(idx[sel[p_loc]]))
                if best_key is None or key < best_key:
                    z = np.take_along_axis(win_sm[q][None, :],
                                           sid_f[sel[p_loc]][None, :], axis=1)[0]
                    best_key, best_state = key, (X_f[sel[p_loc]], z, d)
        stats.partitions_polished += polished_d
        stats.partitions_pruned += len(idx) - polished_d

    if best_state is None:
        return None
    x_row, z_row, d = best_state
    cfg = Config(x=tuple(int(v) for v in x_row), d=int(d),
                 z=tuple(int(v) for v in z_row))
    ev = evaluate(prof, platform, cfg, M, pipelined_sync=pipelined_sync)
    return PlanResult(cfg, ev, ev.objective(a1, a2), time.time() - t0, prof,
                      stats)


# ----------------------------------------------------------------- dp engine
# Finalists within this relative band of the DP optimum are re-scored through
# the scalar oracle: the DP accumulates stage-at-a-time while `evaluate` folds
# whole-chain suffixes, so their float association differs by ~1e-13 relative
# — re-ranking a 1e-9 band through `evaluate` makes the returned plan the
# oracle-arithmetic argmin even across such near-ties.
_DP_FINALIST_RTOL = 1e-9
_DP_FINALIST_CAP = 64          # max finalists re-scored per (d, state sweep)
_INIT_ROW = -1                 # back-pointer sentinel: row starts a suffix


@dataclass(frozen=True)
class _DpTables:
    """Per-(profile, platform, d) working tables for the cut-point DP."""

    feas: np.ndarray       # [L, L, J] stage [lo, hi] fits at mem level j
    ts: np.ndarray         # [L, L, J] per-stage sync time (eq 1/2; 0 if d==1)
    cutf: np.ndarray       # [L, J] one side of the fwd boundary comm at cut k
    cutb: np.ndarray       # [L, J] one side of the bwd boundary comm at cut k
    fmin_pre: np.ndarray   # [L+1] lower bound on fwd compute of layers < p
    bmin_pre: np.ndarray   # [L+1] same for bwd compute
    cutf_min: np.ndarray   # [L] min over allowed j of cutf[k]
    cutb_min: np.ndarray   # [L] min over allowed j of cutb[k]
    minmem: np.ndarray     # [L+1] min total stage memory covering layers < p


def _dp_tables(tables: PerfTables, segs: SegmentTables, d: int, mu: int,
               pipelined_sync: bool, j_only: Optional[int]) -> _DpTables:
    L, J = tables.L, tables.J
    W, t_lat = tables.W, tables.t_lat
    sync_f = 4 - 2 * (1 if d == 1 else 0)
    # eq (3b), same operation order as the scalar oracle's threshold
    need = mu * segs.a_hat + segs.s_hat * sync_f + tables.base_memory
    feas = need[:, :, None] <= tables.mem_opts[None, None, :]
    if d > 1:
        # the scalar helpers broadcast over the [L, L, 1] / [J] operands with
        # the oracle's exact operation order (d > 1 here, so no early return)
        sync_fn = (sync_time_pipelined if pipelined_sync
                   else sync_time_nonpipelined)
        ts = sync_fn(segs.s_tilde[:, :, None], W, d, t_lat)
    else:
        ts = np.zeros((L, L, J))
    cutf = np.zeros((L, J))
    cutb = np.zeros((L, J))
    if L > 1:
        cutf[1:] = tables.o[:L - 1, None] / W[None, :] + t_lat
        cutb[1:] = tables.g[1:, None] / W[None, :] + t_lat
    if j_only is not None:
        mask = np.zeros(J, dtype=bool)
        mask[j_only] = True
        feas = feas & mask[None, None, :]
        jcols = [j_only]
    else:
        jcols = list(range(J))
    # ---- admissible completion bounds for layers [0, p): per-layer best-case
    # compute, the cheapest memory cover (a tiny DP over segment floors), and
    # the cheapest possible boundary terms of the one cut that is certain
    f_min = tables.Tf_beta[:, jcols].min(axis=1)
    b_min = tables.Tb_beta[:, jcols].min(axis=1)
    fmin_pre = np.concatenate([[0.0], np.cumsum(f_min)])
    bmin_pre = np.concatenate([[0.0], np.cumsum(b_min)])
    cutf_min = cutf[:, jcols].min(axis=1)
    cutb_min = cutb[:, jcols].min(axis=1)
    seg_mem = np.where(feas.any(-1),
                       tables.mem_opts[feas.argmax(-1)], np.inf)  # [L, L]
    minmem = np.full(L + 1, np.inf)
    minmem[0] = 0.0
    for q in range(1, L + 1):
        minmem[q] = np.min(minmem[:q] + seg_mem[:q, q - 1])
    return _DpTables(feas=feas, ts=ts, cutf=cutf, cutb=cutb,
                     fmin_pre=fmin_pre, bmin_pre=bmin_pre,
                     cutf_min=cutf_min, cutb_min=cutb_min, minmem=minmem)


def _nondominated(V: np.ndarray) -> np.ndarray:
    """Indices of the non-dominated rows of ``V`` (componentwise minimize),
    keeping one representative of every duplicate row.  Exactness of the DP
    only needs soundness here: a dropped row is always covered by a kept row
    that is <= it in every component (dominance is transitive, so comparing
    against *all* lexicographically earlier rows — kept or not — is enough).
    """
    n = len(V)
    if n <= 1:
        return np.arange(n)
    Vu, first = np.unique(V, axis=0, return_index=True)   # lex-sorted rows
    m = len(Vu)
    # a dominating row always sorts lexicographically earlier, so sweep in
    # lex order comparing each chunk only against the kept set so far (any
    # dominated-but-dropped earlier row has a kept dominator by transitivity)
    # plus its own chunk-internal predecessors — O(m * kept) instead of O(m^2)
    kept_idx = [0]
    P = Vu[0:1]
    step = 256
    for lo in range(1, m, step):
        hi = min(lo + step, m)
        C = Vu[lo:hi]
        dom = np.all(P[None, :, :] <= C[:, None, :], axis=-1).any(axis=1)
        intra = np.all(C[None, :, :] <= C[:, None, :], axis=-1)
        intra &= np.arange(lo, hi)[None, :] < np.arange(lo, hi)[:, None]
        dom |= intra.any(axis=1)
        new = np.nonzero(~dom)[0]
        if len(new):
            kept_idx.extend((lo + new).tolist())
            P = np.concatenate([P, C[new]])
    return np.sort(first[np.array(kept_idx)])


def _dp_candidates(tables: PerfTables, segs: SegmentTables, d: int, mu: int,
                   a1: float, a2: float, pipelined_sync: bool,
                   max_stages: Optional[int], j_only: Optional[int] = None,
                   incumbent: float = np.inf,
                   stats: Optional[PlannerStats] = None):
    """Exact DP over stage cut-points for one data-parallel degree.

    Suffix plans are built right to left.  A state is ``(p, j)`` — the suffix
    covers layers ``[p, L-1]`` and its leftmost stage runs at memory level
    ``j`` (the boundary state: the next cut's download/upload terms need it).
    A state's value is the Pareto set of 6-vectors

        (msum, fadd, fmax, bsum, bmax, worst)

    = (suffix stage-memory sum, additive forward time, forward per-round
    bottleneck delta_f candidates, additive backward suffix time, backward
    bottleneck candidates, max over suffix stages of eq (7)'s backward
    completion + sync).  The final objective and every transition are
    monotone nondecreasing in all six components, so componentwise dominance
    pruning is exact; an admissible completion bound additionally prunes
    against ``incumbent`` (any achievable objective, e.g. from the CD
    heuristic) without ever discarding a potential optimum.  Returns
    ``(finalists, best_dp_objective)`` where finalists are ``(x, z)`` tuples
    within ``_DP_FINALIST_RTOL`` of the DP optimum."""
    L, J = tables.L, tables.J
    mem = tables.mem_opts
    t = _dp_tables(tables, segs, d, mu, pipelined_sync, j_only)
    jcols = [j_only] if j_only is not None else list(range(J))
    b_cost = a1 * tables.price_per_gb_s * d / GB
    guard = incumbent * (1 + _DP_FINALIST_RTOL)
    use_count = max_stages is not None
    states = {}

    for p in range(L - 1, -1, -1):
        for j in jcols:
            blocks = []
            if t.feas[p, L - 1, j]:
                fc = segs.f[p, L - 1, j]
                bc = segs.b[p, L - 1, j]
                worst = bc + (mu - 1) * bc + t.ts[p, L - 1, j]
                blocks.append((
                    np.array([[mem[j], fc, fc, bc, bc, worst]]),
                    np.ones(1, dtype=np.int64),
                    np.array([[L, 0, _INIT_ROW]], dtype=np.int64)))
            for i in range(p + 1, L):
                if not t.feas[p, i - 1, j]:
                    continue
                fc = segs.f[p, i - 1, j]
                bc = segs.b[p, i - 1, j]
                cf_u = t.cutf[i, j]          # this stage uploads its output
                cb_d = t.cutb[i, j]          # ... and downloads the grad back
                tsn = t.ts[p, i - 1, j]
                for jl in jcols:
                    parent = states.get((i, jl))
                    if parent is None:
                        continue
                    Vp, cp, _ = parent
                    cf_d = t.cutf[i, jl]     # right stage downloads the fwd
                    cb_u = t.cutb[i, jl]     # ... and uploads the bwd grad
                    n = len(Vp)
                    V = np.empty((n, 6))
                    V[:, 0] = Vp[:, 0] + mem[j]
                    V[:, 1] = Vp[:, 1] + (fc + cf_u + cf_d)
                    V[:, 2] = np.maximum(Vp[:, 2], max(fc, cf_u, cf_d))
                    V[:, 3] = Vp[:, 3] + (bc + cb_u + cb_d)
                    V[:, 4] = np.maximum(Vp[:, 4], max(bc, cb_u, cb_d))
                    V[:, 5] = np.maximum(
                        Vp[:, 5], V[:, 3] + (mu - 1) * V[:, 4] + tsn)
                    cnt = cp + 1
                    bp = np.column_stack([
                        np.full(n, i, dtype=np.int64),
                        np.full(n, jl, dtype=np.int64),
                        np.arange(n, dtype=np.int64)])
                    if use_count:
                        ok = cnt <= max_stages - (1 if p > 0 else 0)
                        if not ok.all():
                            V, cnt, bp = V[ok], cnt[ok], bp[ok]
                        if len(V) == 0:
                            continue
                    blocks.append((V, cnt, bp))
            if not blocks:
                continue
            if p > 0 and not np.isfinite(t.minmem[p]):
                continue            # layers [0, p) cannot be covered at all
            V = np.vstack([b[0] for b in blocks])
            cnt = np.concatenate([b[1] for b in blocks])
            bp = np.vstack([b[2] for b in blocks])
            if p > 0:
                # admissible completion bound: remaining layers at best-case
                # compute/memory plus the guaranteed cut at p (its j-side
                # terms are exact — j is this state's boundary level)
                f_pre = t.fmin_pre[p] + t.cutf[p, j] + t.cutf_min[p]
                b_pre = t.bmin_pre[p] + t.cutb[p, j] + t.cutb_min[p]
                t_lb = (V[:, 1] + f_pre + (mu - 1) * V[:, 2]
                        + np.maximum(V[:, 5],
                                     V[:, 3] + b_pre + (mu - 1) * V[:, 4]))
                obj_lb = (a2 + b_cost * (V[:, 0] + t.minmem[p])) * t_lb
                ok = obj_lb <= guard
                if not ok.all():
                    if stats is not None:
                        stats.dp_rows_bounded += int(len(ok) - ok.sum())
                    V, cnt, bp = V[ok], cnt[ok], bp[ok]
                if len(V) == 0:
                    continue
            key = np.column_stack([V, cnt]) if use_count else V
            idx = _nondominated(key)
            if stats is not None:
                stats.dp_states += 1
                stats.dp_rows_dominated += len(key) - len(idx)
                stats.dp_rows_kept += len(idx)
            V, cnt, bp = V[idx], cnt[idx], bp[idx]
            states[(p, j)] = (V, cnt, bp)
            if p > 0:
                # single-stage completions are real plans: refresh the
                # incumbent so later (deeper-prefix) states prune harder
                for jc in jcols:
                    if not t.feas[0, p - 1, jc]:
                        continue
                    if use_count and not (cnt + 1 <= max_stages).any():
                        continue
                    rows = (slice(None) if not use_count
                            else cnt + 1 <= max_stages)
                    Vr = V[rows]
                    bsum_c = Vr[:, 3] + (segs.b[0, p - 1, jc]
                                         + t.cutb[p, j] + t.cutb[p, jc])
                    bmax_c = np.maximum(Vr[:, 4], max(
                        segs.b[0, p - 1, jc], t.cutb[p, j], t.cutb[p, jc]))
                    worst_c = np.maximum(
                        Vr[:, 5],
                        bsum_c + (mu - 1) * bmax_c + t.ts[0, p - 1, jc])
                    fadd_c = Vr[:, 1] + (segs.f[0, p - 1, jc]
                                         + t.cutf[p, jc] + t.cutf[p, j])
                    fmax_c = np.maximum(Vr[:, 2], max(
                        segs.f[0, p - 1, jc], t.cutf[p, jc], t.cutf[p, j]))
                    t_c = fadd_c + (mu - 1) * fmax_c + worst_c
                    obj_c = (a2 + b_cost * (Vr[:, 0] + mem[jc])) * t_c
                    low = float(obj_c.min())
                    if low < incumbent:
                        incumbent = low
                        guard = incumbent * (1 + _DP_FINALIST_RTOL)

    # ---- collect full plans, keep the near-tie band, walk back-pointers
    done = []
    for j in jcols:
        st = states.get((0, j))
        if st is None:
            continue
        V = st[0]
        obj = ((a2 + b_cost * V[:, 0])
               * (V[:, 1] + (mu - 1) * V[:, 2] + V[:, 5]))
        for r in np.argsort(obj, kind="stable"):
            done.append((float(obj[r]), j, int(r)))
    if not done:
        return [], np.inf
    done.sort()
    best = done[0][0]
    finalists = []
    for obj, j, r in done[:_DP_FINALIST_CAP]:
        if obj > best * (1 + _DP_FINALIST_RTOL):
            break
        finalists.append(_dp_walk(states, L, j, r))
    return finalists, best


def _dp_walk(states, L: int, j: int, row: int) -> Tuple[tuple, tuple]:
    """Reconstruct (x, z) from the back-pointer chain of one final row."""
    x = [0] * (L - 1)
    z = [0] * L
    p = 0
    while True:
        _, _, bp = states[(p, j)]
        pi, pj, pr = (int(v) for v in bp[row])
        hi = L - 1 if pr == _INIT_ROW else pi - 1
        for k in range(p, hi + 1):
            z[k] = j
        if pr == _INIT_ROW:
            break
        x[pi - 1] = 1
        p, j, row = pi, pj, pr
    return tuple(x), tuple(z)


def _dp_seed_incumbent(prof, platform, tables, d, mu, M, a1, a2,
                       pipelined_sync):
    """A cheap achievable objective to prime the DP's completion-bound
    pruning: balanced compute splits at every stage count (the hierarchical
    merge boundaries restricted to full depth), floor/max memory per split,
    then the multi-start CD polish on the best split.  Purely an upper bound
    — the DP stays exact regardless of its quality."""
    L = prof.L
    w = tables.Tf_beta.mean(axis=1) + tables.Tb_beta.mean(axis=1)
    csum = np.cumsum(w)
    total = csum[-1]
    best_obj, best_x = np.inf, None
    for S in range(1, L + 1):
        cuts = sorted({int(np.searchsorted(csum, total * k / S))
                       for k in range(1, S)} - {L - 1})
        x = tuple(1 if i in cuts else 0 for i in range(L - 1))
        init = _min_feasible_stage_mem(prof, platform, x, d, mu)
        if init is None:
            continue
        J = tables.J
        for sm in (init, [J - 1] * len(init)):
            cfg = Config(x=x, d=d, z=_expand_z(sm, x, L))
            ev = evaluate(prof, platform, cfg, M, pipelined_sync=pipelined_sync)
            if ev.mem_ok and ev.objective(a1, a2) < best_obj:
                best_obj, best_x = ev.objective(a1, a2), x
    if best_x is None:
        return np.inf
    init = _min_feasible_stage_mem(prof, platform, best_x, d, mu)
    cfg, ev = _coordinate_descent(prof, platform, best_x, d, mu, a1, a2,
                                  pipelined_sync, init)
    if cfg is not None:
        best_obj = min(best_obj, ev.objective(a1, a2))
    return best_obj


def dp_solve(
    profile: ModelProfile,
    platform: Platform,
    *,
    alpha: Tuple[float, float],
    total_micro_batches: int,
    d_options: Sequence[int] = DEFAULT_D_OPTIONS,
    merge_to: Optional[int] = None,
    max_stages: Optional[int] = None,
    pipelined_sync: bool = True,
) -> Optional[PlanResult]:
    """Exact cut-point planner (``engine='dp'``): provably optimal (x, z) per
    (d, M) in polynomial table work — ``merge_to=None`` (the default) plans
    at full layer depth, the regime the enumeration engines cannot reach.
    Every returned plan is re-scored through the scalar ``evaluate`` oracle,
    so the reported objective is directly comparable across engines."""
    t0 = time.time()
    a1, a2 = alpha
    prof = _merged(profile, merge_to)
    M = total_micro_batches
    tables = perf_tables(prof, platform)
    segs = segment_tables(prof, platform)
    best, best_key = None, None
    stats = PlannerStats(engine="dp")
    for d_rank, d in enumerate(d_options):
        if M % d or M < d:
            continue
        mu = max(1, M // d)
        seed = _dp_seed_incumbent(prof, platform, tables, d, mu, M, a1, a2,
                                  pipelined_sync)
        finalists, _ = _dp_candidates(tables, segs, d, mu, a1, a2,
                                      pipelined_sync, max_stages,
                                      incumbent=seed, stats=stats)
        for x, z in finalists:
            cfg = Config(x=x, d=d, z=z)
            ev = evaluate(prof, platform, cfg, M, pipelined_sync=pipelined_sync)
            if not ev.mem_ok:
                continue
            key = (ev.objective(a1, a2), d_rank)
            if best_key is None or key < best_key:
                best_key = key
                best = PlanResult(cfg, ev, key[0], 0.0, prof)
    if best is not None:
        best = dataclasses.replace(best, solve_seconds=time.time() - t0,
                                   stats=stats)
    return best


def solve(
    profile: ModelProfile,
    platform: Platform,
    *,
    alpha: Tuple[float, float],
    total_micro_batches: int,
    d_options: Sequence[int] = DEFAULT_D_OPTIONS,
    merge_to: Optional[int] = DEFAULT_MERGE_TO,
    max_stages: Optional[int] = None,
    method: str = "cd",
    pipelined_sync: bool = True,
    engine: str = "batch",
) -> Optional[PlanResult]:
    """FuncPipe's co-optimizer.  Returns the best feasible plan or None.

    ``method`` selects the per-partition memory search: ``'cd'``
    (first-improvement coordinate descent, the reference rule),
    ``'cd-steepest'`` (steepest descent over all (stage, level) neighbors —
    same multi-start set and move budget, typically fewer moves to
    converge) or ``'exhaustive'`` (enumerate memory combos, small J^S only).

    ``engine='batch'`` (default) and ``engine='scalar'`` return identical
    plans; the batch engine evaluates candidate sets through
    ``perfmodel.evaluate_batch`` and is the one fast enough for
    ``merge_to`` >= 14.  ``engine='dp'`` runs the exact cut-point DP
    (:func:`dp_solve`): provably optimal per (d, M), polynomial instead of
    2^(L-1), and the only engine that reaches ``merge_to=None`` (full layer
    depth); ``method`` is ignored there — the DP is already exact.
    ``merge_to=None`` disables layer merging for any engine (the enumeration
    engines then pay the full 2^(L-1) space — only sensible for tiny L)."""
    if method not in ("cd", "cd-steepest", "exhaustive"):
        raise ValueError(f"unknown method {method!r}")
    if engine == "dp":
        return dp_solve(profile, platform, alpha=alpha,
                        total_micro_batches=total_micro_batches,
                        d_options=d_options, merge_to=merge_to,
                        max_stages=max_stages, pipelined_sync=pipelined_sync)
    kw = dict(alpha=alpha, total_micro_batches=total_micro_batches,
              d_options=d_options, merge_to=merge_to, max_stages=max_stages,
              method=method, pipelined_sync=pipelined_sync)
    if engine == "batch":
        return _solve_batch(profile, platform, **kw)
    if engine == "scalar":
        return _solve_scalar(profile, platform, **kw)
    raise ValueError(f"unknown engine {engine!r}")


# ------------------------------------------------------------------ baselines
def tpdmp_solve(
    profile: ModelProfile,
    platform: Platform,
    *,
    alpha: Tuple[float, float],
    total_micro_batches: int,
    d_options: Sequence[int] = DEFAULT_D_OPTIONS,
    merge_to: Optional[int] = DEFAULT_MERGE_TO,
    pipelined_sync: bool = True,
    engine: str = "batch",
) -> Optional[PlanResult]:
    """Throughput-only partitioning (TPDMP-style) under a grid of fixed
    resource allocations; the objective selects among grid points (§5.1).

    ``engine='dp'`` swaps the per-(d, memory-level) partition enumeration for
    the exact cut-point DP restricted to that uniform level and a pure
    time objective — the same fixed-resource optimum, reachable at full
    layer depth."""
    t0 = time.time()
    a1, a2 = alpha
    prof = _merged(profile, merge_to)
    L = prof.L
    J = len(platform.memory_options)
    best: Optional[PlanResult] = None
    if engine == "dp":
        M = total_micro_batches
        tables = perf_tables(prof, platform)
        segs = segment_tables(prof, platform)
        for d in d_options:
            if M % d or M < d:
                continue
            mu = max(1, M // d)
            for j in range(J):
                finalists, _ = _dp_candidates(
                    tables, segs, d, mu, 0.0, 1.0, pipelined_sync,
                    None, j_only=j)
                grid_t, grid_cfg, grid_ev = np.inf, None, None
                for x, z in finalists:
                    cfg = Config(x=x, d=d, z=z)
                    ev = evaluate(prof, platform, cfg, M,
                                  pipelined_sync=pipelined_sync)
                    if ev.mem_ok and ev.t_iter < grid_t:   # throughput only
                        grid_t, grid_cfg, grid_ev = ev.t_iter, cfg, ev
                if grid_cfg is None:
                    continue
                obj = grid_ev.objective(a1, a2)
                if best is None or obj < best.objective:
                    best = PlanResult(grid_cfg, grid_ev, obj, 0.0, prof)
        if best is not None:
            best = dataclasses.replace(best, solve_seconds=time.time() - t0)
        return best
    if engine == "batch":
        M = total_micro_batches
        tables = perf_tables(prof, platform)
        X_all = _partition_matrix(L)
        for d in d_options:
            if M % d or M < d:
                continue
            for j in range(J):
                Z = np.full((len(X_all), L), j, dtype=np.int64)
                be = _eval_chunked(prof, platform, tables, X_all, Z, d, M,
                                   pipelined_sync)
                t = np.where(be.mem_ok, be.t_iter, np.inf)
                k = int(np.argmin(t))                # first fastest partition
                if not np.isfinite(t[k]):
                    continue
                ev = be.pick(k)
                obj = ev.objective(a1, a2)
                if best is None or obj < best.objective:
                    cfg = Config(x=tuple(int(v) for v in X_all[k]), d=d,
                                 z=tuple([j] * L))
                    best = PlanResult(cfg, ev, obj, 0.0, prof)
        if best is not None:
            best = dataclasses.replace(best, solve_seconds=time.time() - t0)
        return best
    if engine != "scalar":
        raise ValueError(f"unknown engine {engine!r}")
    for d in d_options:
        if total_micro_batches % d or total_micro_batches < d:
            continue
        for j in range(J):  # uniform memory grid
            best_t, best_cfg, best_ev = np.inf, None, None
            for x in _partitions(L):
                cfg = Config(x=tuple(x), d=d, z=tuple([j] * L))
                ev = evaluate(prof, platform, cfg, total_micro_batches,
                              pipelined_sync=pipelined_sync)
                if ev.mem_ok and ev.t_iter < best_t:   # throughput only
                    best_t, best_cfg, best_ev = ev.t_iter, cfg, ev
            if best_cfg is None:
                continue
            obj = best_ev.objective(a1, a2)
            if best is None or obj < best.objective:
                best = PlanResult(best_cfg, best_ev, obj, 0.0, prof)
    if best is not None:
        best = dataclasses.replace(best, solve_seconds=time.time() - t0)
    return best


def bayes_solve(
    profile: ModelProfile,
    platform: Platform,
    *,
    alpha: Tuple[float, float],
    total_micro_batches: int,
    d_options: Sequence[int] = DEFAULT_D_OPTIONS,
    merge_to: Optional[int] = DEFAULT_MERGE_TO,
    rounds: int = 100,
    seed: int = 0,
    pipelined_sync: bool = True,
    batch_size: int = 16,
) -> Optional[PlanResult]:
    """Black-box joint search (paper's Bayes baseline): seeded random
    proposals + local mutation of the incumbent, evaluated on the performance
    model (the paper does the same to avoid measurement cost, App. E).

    Proposals are drawn in chunks of ``batch_size`` (mutations within a
    chunk share the incumbent at chunk start) and each chunk is evaluated
    through the batched kernel; ``batch_size=1`` recovers the fully
    sequential seed behavior."""
    t0 = time.time()
    a1, a2 = alpha
    prof = _merged(profile, merge_to)
    L = prof.L
    J = len(platform.memory_options)
    tables = perf_tables(prof, platform)
    rng = np.random.default_rng(seed)
    ds = [d for d in d_options if total_micro_batches % d == 0 and total_micro_batches >= d]
    best: Optional[PlanResult] = None

    def propose():
        if best is not None and rng.random() < 0.5:  # local mutation
            cfg = best.config
            x = list(cfg.x)
            if L > 1 and rng.random() < 0.5:
                i = rng.integers(0, L - 1)
                x[i] = 1 - x[i]
            stage_mem = [cfg.z[lo] for lo, _ in stages_of(x)]
            s = rng.integers(0, len(stage_mem))
            stage_mem[s] = int(np.clip(stage_mem[s] + rng.integers(-1, 2), 0, J - 1))
            return tuple(x), int(cfg.d), stage_mem
        x = tuple(rng.integers(0, 2, size=L - 1))
        d = int(rng.choice(ds))
        stage_mem = list(rng.integers(0, J, size=sum(x) + 1))
        return x, d, stage_mem

    done = 0
    while done < rounds:
        n = min(batch_size, rounds - done)
        done += n
        props = [propose() for _ in range(n)]
        cfgs = [Config(x=tuple(x), d=d, z=_expand_z(sm, x, L))
                for x, d, sm in props]
        evs: List[Optional[Evaluation]] = [None] * n
        by_d = {}
        for i, cfg in enumerate(cfgs):
            by_d.setdefault(cfg.d, []).append(i)
        for d, ids in by_d.items():
            X = np.array([cfgs[i].x for i in ids], dtype=np.int64).reshape(len(ids), L - 1)
            Z = np.array([cfgs[i].z for i in ids], dtype=np.int64)
            be = evaluate_batch(prof, platform, X, Z, d, total_micro_batches,
                                pipelined_sync=pipelined_sync, tables=tables)
            for row, i in enumerate(ids):
                evs[i] = be.pick(row)
        for cfg, ev in zip(cfgs, evs):
            if not ev.mem_ok:
                continue
            obj = ev.objective(a1, a2)
            if best is None or obj < best.objective:
                best = PlanResult(cfg, ev, obj, 0.0, prof)
    if best is not None:
        best = dataclasses.replace(best, solve_seconds=time.time() - t0)
    return best


# -------------------------------------------------------------- recommendation
def recommend(results: Sequence[PlanResult], threshold: float = 0.8) -> PlanResult:
    """Paper §5.1: fastest config whose speedup/cost-increase ratio over the
    min-cost config satisfies delta >= threshold."""
    feas = [r for r in results if r is not None]
    assert feas
    mc = min(feas, key=lambda r: r.evaluation.c_iter)
    t_mc, c_mc = mc.evaluation.t_iter, mc.evaluation.c_iter
    cands = []
    for r in feas:
        t_p, c_p = r.evaluation.t_iter, r.evaluation.c_iter
        if c_p <= c_mc or t_p >= t_mc:
            delta = np.inf if (c_p <= c_mc and t_p <= t_mc) else 0.0
        else:
            delta = (t_mc / t_p - 1) / (c_p / c_mc - 1)
        if delta >= threshold:
            cands.append(r)
    if not cands:
        return mc
    return min(cands, key=lambda r: r.evaluation.t_iter)
