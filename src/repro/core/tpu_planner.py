"""TPU adaptation of the paper's co-optimization (DESIGN.md §2).

On serverless, FuncPipe jointly chooses (model partition, #replicas,
per-worker memory).  On a fixed 16x16 pod the same *joint* decision becomes
(pipeline stages S, tensor width tp = 16/S, micro-batch count mu, remat
policy): S x tp trades pipeline bubble against TP-psum traffic; mu trades
bubble against activation memory; remat trades recompute FLOPs against HBM.
The objective is the same weighted alpha1*cost + alpha2*time with
cost = chips * t_step (chip-seconds are the pod's "GB-seconds").

The evaluator is the analytic roofline (launch.roofline) extended with a
per-chip HBM feasibility estimate; enumeration is exact (the space is tiny —
this is where the serverless MIQP's layer-merging hardness disappears on
fixed-size chips).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig, InputShape, MOE_FF
from repro.core.plan import PipelinePlan, make_plan

HBM_BYTES = 16e9          # v5e
CHIP_SECOND_PRICE = 1.0   # relative cost unit


@dataclass(frozen=True)
class TpuPlanResult:
    plan: PipelinePlan
    t_step_est: float
    cost: float           # chip-seconds per step
    hbm_est: float
    objective: float
    note: str = ""


def _hbm_estimate(cfg: ArchConfig, shape: InputShape, plan: PipelinePlan) -> float:
    """Per-chip bytes: params + grads + ZeRO opt shard + pipeline activations."""
    P_BYTES = 2 if cfg.param_dtype == "bfloat16" else 4
    moe_params = 0.0
    if cfg.moe is not None:
        n_moe = sum(1 for i in range(cfg.n_layers) if cfg.layer_spec(i).ff == MOE_FF)
        moe_params = n_moe * cfg.moe.n_experts * 3 * cfg.d_model * cfg.moe.d_ff_expert
    dense = cfg.param_count() - moe_params
    params_chip = (dense / (plan.stages * plan.tensor)
                   + moe_params / (plan.stages * plan.tensor * plan.ep))
    weights = params_chip * P_BYTES
    grads = params_chip * 4.0
    opt = params_chip * 3 * 4.0 / plan.data  # master+m+v fp32, ZeRO-1
    if shape.kind != "train":
        grads = opt = 0.0
    B_local = max(1, shape.global_batch // (plan.pods * plan.data))
    mb = max(1, B_local // plan.microbatches)
    T = plan.microbatches + plan.stages - 1
    act_carry = mb * shape.seq_len * cfg.d_model * P_BYTES
    acts = act_carry * (T if plan.remat in ("tick", "layer") else T * 4)
    return weights + grads + opt + acts + 1e9  # +1GB working set


def solve(
    cfg: ArchConfig,
    shape: InputShape,
    *,
    alpha: Tuple[float, float] = (1.0, 1.0),
    data: int = 16,
    model: int = 16,
    pods: int = 1,
) -> List[TpuPlanResult]:
    """Enumerate (S, tp, mu, remat); return feasible results sorted by the
    objective (best first).  Respects period-alignment: stages must keep an
    integer number of period instances per stage (padding allowed but counted
    as wasted compute via the analytic flops of padded layers)."""
    from repro.launch.roofline import analytic_roofline

    a1, a2 = alpha
    out: List[TpuPlanResult] = []
    B_local = max(1, shape.global_batch // (pods * data))
    for stages in (1, 2, 4, 8, 16):
        if stages > model:
            continue
        tensor = model // stages
        # tp feasibility: head/ff divisibility (heads sliced whole)
        if tensor > 1 and cfg.n_heads % tensor and cfg.n_kv_heads % tensor:
            if cfg.n_heads % tensor:
                continue
        mus = sorted({1, min(stages, B_local), min(2 * stages, B_local),
                      min(4 * stages, B_local), B_local})
        for mu in mus:
            if mu < 1 or B_local % mu:
                continue
            for remat in ("tick", "none"):
                try:
                    plan = make_plan(cfg, shape, data=data, model=model,
                                     pods=pods, stages=stages, tensor=tensor,
                                     microbatches=mu, remat=remat)
                except AssertionError:
                    continue
                hbm = _hbm_estimate(cfg, shape, plan)
                if hbm > HBM_BYTES:
                    continue
                r = analytic_roofline(cfg, shape, plan)
                # padded-layer waste: padded instances do real math
                pad_waste = (plan.n_instances * cfg.period_len) / max(1, cfg.n_layers)
                t = r.t_step_est * pad_waste
                chips = pods * data * model
                cost = chips * t * CHIP_SECOND_PRICE
                obj = a1 * cost + a2 * t
                out.append(TpuPlanResult(plan=plan, t_step_est=t, cost=cost,
                                         hbm_est=hbm, objective=obj))
    out.sort(key=lambda x: x.objective)
    return out
