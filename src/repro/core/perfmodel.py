"""The paper's performance model (§3.4.2 + Appendix A/B), term by term.

Given a concrete configuration (partition x, data-parallel degree d, per-layer
memory m_i) and a layer profile, computes the iteration time eq (7) and cost
eq (6), the memory constraint eq (3b), and the synchronization times for both
scatter-reduce algorithms — eq (1) (LambdaML, non-pipelined) and eq (2)
(FuncPipe, pipelined).

Two tiers:

  * ``evaluate`` — the scalar oracle: one configuration at a time, simple
    per-layer Python, easy to audit against the paper's equations.
  * ``evaluate_batch`` — the vectorized kernel: an ``[N, L-1]`` matrix of
    partition vectors plus ``[N, L]`` memory-index assignments, all N
    configurations evaluated with pure numpy (batched ``hat``/``tilde``
    recurrences, suffix sums/maxima, precomputed per-(layer, memory-option)
    tables from :func:`perf_tables`).  This is what the co-optimizer's hot
    path calls; it is property-tested to be *bit-for-bit* equal to the
    oracle (both reduce through the same right-fold helpers in
    ``repro.core.partition`` so their float association is identical).

Validation ladder: these closed forms are checked against the independent
longest-path DP in ``repro.serverless.simulator``, and both against the
*executable* ground truth — ``repro.serverless.runtime``, which runs the
schedule through an emulated object store (with real JAX numerics when an
``Execution`` is attached).  See ``benchmarks/runtime_accuracy.py``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.partition import (
    ModelProfile,
    hat,
    highest_layers,
    lowest_layers,
    segment_sum_table,
    segment_sum_table_rev,
    suffix_max,
    suffix_sum,
    tilde,
)
from repro.serverless.platform import GB, Platform


# --------------------------------------------------------------- sync times
def sync_time_nonpipelined(s_grad: float, w: float, n: int, t_lat: float) -> float:
    """Eq (1): LambdaML's 3-phase storage scatter-reduce."""
    if n <= 1:
        return 0.0
    return 3 * s_grad / w - 2 * s_grad / (n * w) + 4 * t_lat


def sync_time_pipelined(s_grad: float, w: float, n: int, t_lat: float) -> float:
    """Eq (2): FuncPipe's full-duplex pipelined scatter-reduce."""
    if n <= 1:
        return 0.0
    return 2 * s_grad / w + (2 + n) * t_lat


@dataclass(frozen=True)
class Config:
    """A co-optimization decision: partition boundaries x (len L-1, {0,1}),
    data-parallel degree d, and per-layer memory option index z (len L,
    constant within a stage)."""

    x: tuple
    d: int
    z: tuple  # memory option INDEX per layer

    def mem(self, platform: Platform) -> np.ndarray:
        return np.array([platform.memory_options[j] for j in self.z], dtype=np.float64)


@dataclass(frozen=True)
class Evaluation:
    t_iter: float
    c_iter: float
    t_f: float
    t_sync_max: float
    mem_ok: bool
    c_mem_gb: float

    def objective(self, a1: float, a2: float) -> float:
        return a1 * self.c_iter + a2 * self.t_iter


# ---------------------------------------------------------- precomputed tables
@dataclass(frozen=True)
class PerfTables:
    """Per-(layer, memory-option) tables for one (profile, platform) pair.

    Built once and cached (:func:`perf_tables`); shared by the scalar oracle,
    the batched kernel and ``simulator.stage_aggregates`` so all three charge
    identical compute/bandwidth terms.  ``monotone`` records whether more
    memory is never worse (bandwidth non-decreasing, compute times
    non-increasing in the option index) — the property the planner's
    lower-bound pruning relies on."""

    L: int
    J: int
    t_lat: float
    base_memory: float
    price_per_gb_s: float
    mem_opts: np.ndarray        # [J] bytes
    W: np.ndarray               # [J] per-function bandwidth
    Tf_beta: np.ndarray         # [L, J] beta * forward compute time
    Tb_beta: np.ndarray         # [L, J] beta * backward compute time
    s: np.ndarray               # [L] parameter bytes
    a: np.ndarray               # [L] activation bytes per micro-batch
    o: np.ndarray               # [L] forward boundary bytes
    g: np.ndarray               # [L] backward boundary bytes
    monotone: bool


@functools.lru_cache(maxsize=256)
def perf_tables(profile: ModelProfile, platform: Platform) -> PerfTables:
    arr = profile.arrays()
    opts = np.array(platform.memory_options, dtype=np.float64)
    if not np.all(np.diff(opts) > 0):
        # the batched planner floors feasibility via searchsorted
        raise ValueError(
            f"platform {platform.name!r} memory_options must be strictly "
            "ascending")
    W = np.array([platform.bandwidth(mo) for mo in platform.memory_options],
                 dtype=np.float64)
    Tf_beta = platform.contention_beta * arr["Tf"].astype(np.float64)
    Tb_beta = platform.contention_beta * arr["Tb"].astype(np.float64)
    mem_opts = opts
    monotone = bool(
        np.all(np.diff(W) >= 0)
        and np.all(np.diff(Tf_beta, axis=1) <= 0)
        and np.all(np.diff(Tb_beta, axis=1) <= 0)
    )
    for t in (W, Tf_beta, Tb_beta, mem_opts):
        t.setflags(write=False)
    return PerfTables(
        L=profile.L, J=len(platform.memory_options),
        t_lat=platform.storage_latency, base_memory=float(platform.base_memory),
        price_per_gb_s=platform.price_per_gb_s, mem_opts=mem_opts, W=W,
        Tf_beta=Tf_beta, Tb_beta=Tb_beta,
        s=arr["s"], a=arr["a"], o=arr["o"], g=arr["g"], monotone=monotone,
    )


@dataclass(frozen=True)
class SegmentTables:
    """Per-(lo, hi[, mem-option]) stage aggregates for one (profile, platform)
    pair: every contiguous layer segment's compute/byte sums, materialized in
    O(L^2·J) once and cached.  This is what the planner's DP engine reads —
    a candidate stage ``[lo, hi]`` at memory level ``j`` costs one table
    lookup instead of a per-layer reduction.

    Association discipline: ``a_hat``/``s_hat`` reproduce :func:`hat`'s fold
    bit-for-bit (they feed the eq (3b) memory threshold, where a one-ulp
    disagreement with the scalar oracle could flip feasibility) and
    ``s_tilde`` reproduces :func:`tilde`'s (it feeds the eq (1)/(2) sync
    terms).  ``f``/``b`` use the hat fold for the per-stage compute sums."""

    f: np.ndarray        # [L, L, J] beta-scaled forward compute sum of [lo..hi]
    b: np.ndarray        # [L, L, J] beta-scaled backward compute sum
    a_hat: np.ndarray    # [L, L] activation bytes (hat association, eq 3b)
    s_hat: np.ndarray    # [L, L] parameter bytes (hat association, eq 3b)
    s_tilde: np.ndarray  # [L, L] parameter bytes (tilde association, sync)


@functools.lru_cache(maxsize=256)
def segment_tables(profile: ModelProfile, platform: Platform) -> SegmentTables:
    T = perf_tables(profile, platform)
    # fold per memory option: [J, L] -> [J, L, L] -> [L, L, J]
    f = np.moveaxis(segment_sum_table(np.ascontiguousarray(T.Tf_beta.T)), 0, -1)
    b = np.moveaxis(segment_sum_table(np.ascontiguousarray(T.Tb_beta.T)), 0, -1)
    a_hat = segment_sum_table(T.a)
    s_hat = segment_sum_table(T.s)
    s_tilde = segment_sum_table_rev(T.s)
    for t in (f, b, a_hat, s_hat, s_tilde):
        t.setflags(write=False)
    return SegmentTables(f=f, b=b, a_hat=a_hat, s_hat=s_hat, s_tilde=s_tilde)


# ------------------------------------------------------------- scalar oracle
def evaluate(
    profile: ModelProfile,
    platform: Platform,
    config: Config,
    total_micro_batches: int,
    *,
    pipelined_sync: bool = True,
) -> Evaluation:
    """Evaluate eq (3a)'s components for one configuration."""
    arr = profile.arrays()
    L = profile.L
    x = np.asarray(config.x, dtype=np.int64)
    assert len(x) == L - 1
    d = config.d
    m = config.mem(platform)
    z = np.asarray(config.z)
    mu = max(1, total_micro_batches // d)  # micro-batches per worker
    beta = platform.contention_beta
    t_lat = platform.storage_latency
    W = np.array([platform.bandwidth(mo) for mo in platform.memory_options])

    w_i = W[z]                                    # per-layer worker bandwidth
    t_fc = beta * arr["Tf"][np.arange(L), z]      # forward compute per layer
    t_bc = beta * arr["Tb"][np.arange(L), z]

    # forward boundary comms (eq 8)
    t_fu = np.zeros(L)
    t_fd = np.zeros(L)
    for i in range(L - 1):
        if x[i]:
            t_fu[i] = arr["o"][i] / w_i[i] + t_lat
            t_fd[i] = arr["o"][i] / w_i[i + 1] + t_lat
    # backward boundary comms (App. B)
    t_bu = np.zeros(L)
    t_bd = np.zeros(L)
    for i in range(1, L):
        if x[i - 1]:
            t_bu[i] = arr["g"][i] / w_i[i] + t_lat
            t_bd[i] = arr["g"][i] / w_i[i - 1] + t_lat

    # ---- forward time
    hat_tfc = hat(t_fc, x)
    t_f0 = suffix_sum(t_fc)[0] + suffix_sum(t_fu)[0] + suffix_sum(t_fd)[0]
    delta_f = max(hat_tfc.max(), t_fu.max() if L > 1 else 0.0, t_fd.max() if L > 1 else 0.0)
    t_f = t_f0 + (mu - 1) * delta_f

    # ---- backward completion per partition-lowest layer (App. B)
    tilde_tbc = tilde(t_bc, x)
    lows = lowest_layers(x)
    sync_fn = sync_time_pipelined if pipelined_sync else sync_time_nonpipelined
    tilde_s = tilde(arr["s"], x)

    # suffix reductions (right folds shared with evaluate_batch); the pads
    # make index i+1 == L read the scalar path's "else 0.0" branch
    zero = np.zeros(1)
    ss_bc = suffix_sum(t_bc)
    ss_bu = np.concatenate([suffix_sum(t_bu), zero])
    ss_bd = np.concatenate([suffix_sum(t_bd), zero])
    sm_bc = suffix_max(tilde_tbc)
    sm_bu = np.concatenate([suffix_max(t_bu), zero])
    sm_bd = np.concatenate([suffix_max(t_bd), zero])

    worst = 0.0
    t_sync_max = 0.0
    for i in lows:
        tb = ss_bc[i] + ss_bu[i + 1] + ss_bd[i + 1]
        db = max(sm_bc[i], sm_bu[i + 1], sm_bd[i + 1])
        tb += (mu - 1) * db
        ts = sync_fn(tilde_s[i], w_i[i], d, t_lat) if d > 1 else 0.0
        t_sync_max = max(t_sync_max, ts)
        worst = max(worst, tb + ts)

    t_iter = t_f + worst

    # ---- memory constraint (3b) and cost (5)/(6)
    hat_a = hat(arr["a"], x)
    hat_s = hat(arr["s"], x)
    highs = highest_layers(x)
    sync_mem_factor = 4 - 2 * (1 if d == 1 else 0)
    mem_ok = all(
        mu * hat_a[i] + hat_s[i] * sync_mem_factor + platform.base_memory <= m[i]
        for i in highs
    )
    c_mem = d * sum(m[i] for i in highs)          # bytes across all workers
    c_iter = platform.price_per_gb_s * (c_mem / GB) * t_iter

    return Evaluation(
        t_iter=float(t_iter),
        c_iter=float(c_iter),
        t_f=float(t_f),
        t_sync_max=float(t_sync_max),
        mem_ok=bool(mem_ok),
        c_mem_gb=float(c_mem / GB),
    )


# ------------------------------------------------------------ batched kernel
@dataclass(frozen=True)
class BatchEvaluation:
    """Column-wise :class:`Evaluation` for N configurations."""

    t_iter: np.ndarray            # [N]
    c_iter: np.ndarray            # [N]
    t_f: np.ndarray               # [N]
    t_sync_max: np.ndarray        # [N]
    mem_ok: np.ndarray            # [N] bool
    c_mem_gb: np.ndarray          # [N]

    def __len__(self) -> int:
        return len(self.t_iter)

    def objective(self, a1: float, a2: float) -> np.ndarray:
        return a1 * self.c_iter + a2 * self.t_iter

    def masked_objective(self, a1: float, a2: float) -> np.ndarray:
        """Objective with infeasible rows forced to +inf (argmin-safe)."""
        return np.where(self.mem_ok, self.objective(a1, a2), np.inf)

    def pick(self, i: int) -> Evaluation:
        return Evaluation(
            t_iter=float(self.t_iter[i]), c_iter=float(self.c_iter[i]),
            t_f=float(self.t_f[i]), t_sync_max=float(self.t_sync_max[i]),
            mem_ok=bool(self.mem_ok[i]), c_mem_gb=float(self.c_mem_gb[i]),
        )


def evaluate_batch(
    profile: ModelProfile,
    platform: Platform,
    X: np.ndarray,
    Z: np.ndarray,
    d: int,
    total_micro_batches: int,
    *,
    pipelined_sync: bool = True,
    tables: Optional[PerfTables] = None,
) -> BatchEvaluation:
    """Vectorized :func:`evaluate` over N configurations at one DP degree.

    ``X`` is ``[N, L-1]`` partition-boundary bits, ``Z`` is ``[N, L]``
    per-layer memory-option indices.  Every arithmetic step mirrors the
    scalar oracle's operation order (shared ``hat``/``tilde``/suffix
    helpers), so the outputs are bit-for-bit equal to N scalar calls."""
    T = tables if tables is not None else perf_tables(profile, platform)
    X = np.asarray(X, dtype=np.int64)
    Z = np.asarray(Z, dtype=np.int64)
    if X.ndim != 2 or Z.ndim != 2:
        raise ValueError("X must be [N, L-1] and Z [N, L]")
    N, L = Z.shape
    if X.shape != (N, L - 1):
        raise ValueError(f"X {X.shape} inconsistent with Z {Z.shape}")
    mu = max(1, total_micro_batches // d)
    t_lat = T.t_lat
    lidx = np.arange(L)

    w_i = T.W[Z]                                  # [N, L]
    t_fc = T.Tf_beta[lidx, Z]                     # [N, L]
    t_bc = T.Tb_beta[lidx, Z]

    cut = X == 1                                  # [N, L-1]
    t_fu = np.zeros((N, L))
    t_fd = np.zeros((N, L))
    t_fu[:, :-1] = np.where(cut, T.o[:L - 1] / w_i[:, :-1] + t_lat, 0.0)
    t_fd[:, :-1] = np.where(cut, T.o[:L - 1] / w_i[:, 1:] + t_lat, 0.0)
    t_bu = np.zeros((N, L))
    t_bd = np.zeros((N, L))
    t_bu[:, 1:] = np.where(cut, T.g[1:] / w_i[:, 1:] + t_lat, 0.0)
    t_bd[:, 1:] = np.where(cut, T.g[1:] / w_i[:, :-1] + t_lat, 0.0)

    # ---- forward time
    hat_tfc = hat(t_fc, X)
    t_f0 = suffix_sum(t_fc)[:, 0] + suffix_sum(t_fu)[:, 0] + suffix_sum(t_fd)[:, 0]
    # t_fu/t_fd are all-zero when L == 1, matching the scalar "else 0.0"
    delta_f = np.maximum(hat_tfc.max(axis=1),
                         np.maximum(t_fu.max(axis=1), t_fd.max(axis=1)))
    t_f = t_f0 + (mu - 1) * delta_f

    # ---- backward completion per partition-lowest layer (App. B)
    tilde_tbc = tilde(t_bc, X)
    tilde_s = tilde(np.broadcast_to(T.s, (N, L)), X)
    zero = np.zeros((N, 1))
    ss_bc = suffix_sum(t_bc)
    ss_bu = np.concatenate([suffix_sum(t_bu), zero], axis=1)
    ss_bd = np.concatenate([suffix_sum(t_bd), zero], axis=1)
    sm_bc = suffix_max(tilde_tbc)
    sm_bu = np.concatenate([suffix_max(t_bu), zero], axis=1)
    sm_bd = np.concatenate([suffix_max(t_bd), zero], axis=1)

    tb = ss_bc + ss_bu[:, 1:] + ss_bd[:, 1:]                     # [N, L]
    db = np.maximum(sm_bc, np.maximum(sm_bu[:, 1:], sm_bd[:, 1:]))
    tb = tb + (mu - 1) * db

    if d > 1:
        if pipelined_sync:
            ts = 2 * tilde_s / w_i + (2 + d) * t_lat
        else:
            ts = 3 * tilde_s / w_i - 2 * tilde_s / (d * w_i) + 4 * t_lat
    else:
        ts = np.zeros((N, L))

    is_low = np.zeros((N, L), dtype=bool)
    is_low[:, 0] = True
    is_low[:, 1:] = cut
    worst = np.where(is_low, tb + ts, 0.0).max(axis=1)
    t_sync_max = np.where(is_low, ts, 0.0).max(axis=1)
    t_iter = t_f + worst

    # ---- memory constraint (3b) and cost (5)/(6)
    hat_a = hat(np.broadcast_to(T.a, (N, L)), X)
    hat_s = hat(np.broadcast_to(T.s, (N, L)), X)
    is_high = np.zeros((N, L), dtype=bool)
    is_high[:, L - 1] = True
    is_high[:, :L - 1] = cut
    sync_mem_factor = 4 - 2 * (1 if d == 1 else 0)
    m = T.mem_opts[Z]                                            # [N, L]
    need = mu * hat_a + hat_s * sync_mem_factor + T.base_memory
    mem_ok = np.all(~is_high | (need <= m), axis=1)
    c_mem = np.zeros(N)
    for i in range(L):  # sequential accumulation == Python sum over highs
        c_mem = c_mem + np.where(is_high[:, i], m[:, i], 0.0)
    c_mem = d * c_mem
    c_iter = T.price_per_gb_s * (c_mem / GB) * t_iter

    return BatchEvaluation(
        t_iter=t_iter, c_iter=c_iter, t_f=t_f, t_sync_max=t_sync_max,
        mem_ok=mem_ok, c_mem_gb=c_mem / GB,
    )
