"""The paper's performance model (§3.4.2 + Appendix A/B), term by term.

Given a concrete configuration (partition x, data-parallel degree d, per-layer
memory m_i) and a layer profile, computes the iteration time eq (7) and cost
eq (6), the memory constraint eq (3b), and the synchronization times for both
scatter-reduce algorithms — eq (1) (LambdaML, non-pipelined) and eq (2)
(FuncPipe, pipelined).

Validation ladder: these closed forms are checked against the independent
longest-path DP in ``repro.serverless.simulator``, and both against the
*executable* ground truth — ``repro.serverless.runtime``, which runs the
schedule through an emulated object store (with real JAX numerics when an
``Execution`` is attached).  See ``benchmarks/runtime_accuracy.py``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.core.partition import (
    ModelProfile,
    hat,
    highest_layers,
    lowest_layers,
    stages_of,
    tilde,
)
from repro.serverless.platform import GB, Platform


# --------------------------------------------------------------- sync times
def sync_time_nonpipelined(s_grad: float, w: float, n: int, t_lat: float) -> float:
    """Eq (1): LambdaML's 3-phase storage scatter-reduce."""
    if n <= 1:
        return 0.0
    return 3 * s_grad / w - 2 * s_grad / (n * w) + 4 * t_lat


def sync_time_pipelined(s_grad: float, w: float, n: int, t_lat: float) -> float:
    """Eq (2): FuncPipe's full-duplex pipelined scatter-reduce."""
    if n <= 1:
        return 0.0
    return 2 * s_grad / w + (2 + n) * t_lat


@dataclass(frozen=True)
class Config:
    """A co-optimization decision: partition boundaries x (len L-1, {0,1}),
    data-parallel degree d, and per-layer memory option index z (len L,
    constant within a stage)."""

    x: tuple
    d: int
    z: tuple  # memory option INDEX per layer

    def mem(self, platform: Platform) -> np.ndarray:
        return np.array([platform.memory_options[j] for j in self.z], dtype=np.float64)


@dataclass(frozen=True)
class Evaluation:
    t_iter: float
    c_iter: float
    t_f: float
    t_sync_max: float
    mem_ok: bool
    c_mem_gb: float

    def objective(self, a1: float, a2: float) -> float:
        return a1 * self.c_iter + a2 * self.t_iter


def evaluate(
    profile: ModelProfile,
    platform: Platform,
    config: Config,
    total_micro_batches: int,
    *,
    pipelined_sync: bool = True,
) -> Evaluation:
    """Evaluate eq (3a)'s components for one configuration."""
    arr = profile.arrays()
    L = profile.L
    x = np.asarray(config.x, dtype=np.int64)
    assert len(x) == L - 1
    d = config.d
    m = config.mem(platform)
    z = np.asarray(config.z)
    mu = max(1, total_micro_batches // d)  # micro-batches per worker
    beta = platform.contention_beta
    t_lat = platform.storage_latency
    W = np.array([platform.bandwidth(mo) for mo in platform.memory_options])

    w_i = W[z]                                    # per-layer worker bandwidth
    t_fc = beta * arr["Tf"][np.arange(L), z]      # forward compute per layer
    t_bc = beta * arr["Tb"][np.arange(L), z]

    xpad = np.concatenate([x, [0]])               # x_i defined for 1..L-1
    # forward boundary comms (eq 8)
    t_fu = np.zeros(L)
    t_fd = np.zeros(L)
    for i in range(L - 1):
        if x[i]:
            t_fu[i] = arr["o"][i] / w_i[i] + t_lat
            t_fd[i] = arr["o"][i] / w_i[i + 1] + t_lat
    # backward boundary comms (App. B)
    t_bu = np.zeros(L)
    t_bd = np.zeros(L)
    for i in range(1, L):
        if x[i - 1]:
            t_bu[i] = arr["g"][i] / w_i[i] + t_lat
            t_bd[i] = arr["g"][i] / w_i[i - 1] + t_lat

    # ---- forward time
    hat_tfc = hat(t_fc, x)
    t_f0 = t_fc.sum() + t_fu.sum() + t_fd.sum()
    delta_f = max(hat_tfc.max(), t_fu.max() if L > 1 else 0.0, t_fd.max() if L > 1 else 0.0)
    t_f = t_f0 + (mu - 1) * delta_f

    # ---- backward completion per partition-lowest layer (App. B)
    tilde_tbc = tilde(t_bc, x)
    lows = lowest_layers(x)
    sync_fn = sync_time_pipelined if pipelined_sync else sync_time_nonpipelined
    tilde_s = tilde(arr["s"], x)

    worst = 0.0
    t_sync_max = 0.0
    for i in lows:
        tb = t_bc[i:].sum() + t_bu[i + 1:].sum() + t_bd[i + 1:].sum()
        db = max(tilde_tbc[i:].max(), t_bu[i + 1:].max() if i + 1 < L else 0.0,
                 t_bd[i + 1:].max() if i + 1 < L else 0.0)
        tb += (mu - 1) * db
        ts = sync_fn(tilde_s[i], w_i[i], d, t_lat) if d > 1 else 0.0
        t_sync_max = max(t_sync_max, ts)
        worst = max(worst, tb + ts)

    t_iter = t_f + worst

    # ---- memory constraint (3b) and cost (5)/(6)
    hat_a = hat(arr["a"], x)
    hat_s = hat(arr["s"], x)
    highs = highest_layers(x)
    sync_mem_factor = 4 - 2 * (1 if d == 1 else 0)
    mem_ok = all(
        mu * hat_a[i] + hat_s[i] * sync_mem_factor + platform.base_memory <= m[i]
        for i in highs
    )
    c_mem = d * sum(m[i] for i in highs)          # bytes across all workers
    c_iter = platform.price_per_gb_s * (c_mem / GB) * t_iter

    return Evaluation(
        t_iter=float(t_iter),
        c_iter=float(c_iter),
        t_f=float(t_f),
        t_sync_max=float(t_sync_max),
        mem_ok=bool(mem_ok),
        c_mem_gb=float(c_mem / GB),
    )
