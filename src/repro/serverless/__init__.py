from repro.serverless.platform import AWS_LAMBDA, ALIBABA_FC, Platform  # noqa: F401
