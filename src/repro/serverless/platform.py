"""Serverless platform models (§5.1 testbeds).

Memory options, memory->bandwidth and memory->CPU scaling, pricing and
storage characteristics for the two platforms the paper evaluates.  Numbers
follow the paper's measurements: ~70 MB/s per AWS Lambda function, <40 ms S3
latency, 1 vCPU per 1769 MB, price proportional to GB-s.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

MB = 1024**2
GB = 1024**3


@dataclass(frozen=True)
class Platform:
    name: str
    memory_options: Tuple[int, ...]          # bytes
    price_per_gb_s: float                    # $ / (GB * s)
    storage_latency: float                   # t_lat, seconds
    base_memory: int                         # s0 — runtime/framework footprint
    max_function_bandwidth: float            # bytes/s at full allocation
    full_bw_memory: int                      # memory at/above which bw saturates
    cpu_per_memory: float                    # vCPUs per byte of memory
    max_vcpus: float
    flops_per_vcpu: float                    # effective f32 FLOP/s per vCPU
    storage_total_bandwidth: Optional[float] = None  # cloud-storage side cap
    contention_beta: float = 1.15            # paper's beta (comm/compute overlap)
    max_lifetime: float = 15 * 60.0          # function timeout, seconds

    def bandwidth(self, mem: int) -> float:
        frac = min(1.0, mem / self.full_bw_memory)
        return self.max_function_bandwidth * frac

    def vcpus(self, mem: int) -> float:
        return min(self.max_vcpus, mem * self.cpu_per_memory)

    def compute_time(self, flops: float, mem: int) -> float:
        return flops / (self.flops_per_vcpu * self.vcpus(mem))

    def cost(self, mem: int, runtime: float, n_workers: int = 1) -> float:
        return self.price_per_gb_s * (mem / GB) * runtime * n_workers


AWS_LAMBDA = Platform(
    name="aws_lambda",
    memory_options=(512 * MB, 1024 * MB, 2048 * MB, 3072 * MB, 4096 * MB,
                    6144 * MB, 8192 * MB, 10240 * MB),
    price_per_gb_s=0.0000166667,
    storage_latency=0.040,
    base_memory=300 * MB,
    max_function_bandwidth=70 * MB,
    full_bw_memory=1769 * MB,
    cpu_per_memory=1.0 / (1769 * MB),
    max_vcpus=6.0,
    flops_per_vcpu=40e9,
    storage_total_bandwidth=None,  # S3: effectively unlimited concurrent bw
)

ALIBABA_FC = Platform(
    name="alibaba_fc",
    memory_options=(1 * GB, 2 * GB, 4 * GB, 8 * GB, 16 * GB, 32 * GB),
    price_per_gb_s=0.000016384,
    storage_latency=0.035,
    base_memory=300 * MB,
    max_function_bandwidth=80 * MB,
    full_bw_memory=2 * GB,
    cpu_per_memory=1.0 / (2 * GB),
    max_vcpus=16.0,
    flops_per_vcpu=40e9,
    storage_total_bandwidth=10e9 / 8,  # OSS: 10 Gb/s total (§5.7)
)


# name -> Platform, including the short aliases the CLI accepts; serialized
# DeploymentPlans record `Platform.name` so loading resolves through here
PLATFORMS = {
    "aws_lambda": AWS_LAMBDA,
    "aws": AWS_LAMBDA,
    "alibaba_fc": ALIBABA_FC,
    "alibaba": ALIBABA_FC,
}


def get_platform(name: str) -> Platform:
    """Resolve a platform by name or alias (case-insensitive)."""
    try:
        return PLATFORMS[name.lower()]
    except KeyError:
        raise KeyError(
            f"unknown platform {name!r}; known: {sorted(set(PLATFORMS))}"
        ) from None
