"""Deterministic fault injection + the recovery policy objects that defeat it.

FuncPipe's deployment substrate treats failure as the contract: Lambda kills
functions at 15 minutes, invocations fail transiently, stragglers are
routine — the paper's Function Manager (§3.1 ⑧) exists precisely to
checkpoint to storage and relaunch workers.  This module is the chaos side
of that story plus the policy objects the engine uses to survive it:

* :class:`FaultPlan` — a seeded, serializable schedule of fault events
  (transient store put/get errors, worker crashes at (stage, replica, step,
  phase), straggler slowdowns, and a function-lifetime cap à la Lambda).
  Same seed -> same schedule; JSON round-trips exactly, so a chaos run is
  replayable byte-for-byte.
* :class:`FaultInjector` — wraps any registered
  :class:`~repro.serverless.backends.base.ExecutionBackend` and decorates
  the :class:`WorkerContext`\\ s it hands out, firing the plan's events at
  deterministic per-worker op counts.  The engine never knows the substrate
  is rigged, so every existing and future backend (emulated, local,
  aws/oss, process) is chaos-testable through the same protocol.
* :class:`RetryPolicy` / :class:`FaultTolerance` — the engine-side recovery
  configuration: exponential backoff with deterministic jitter on transient
  store ops, checkpoint cadence, restart budget, and the lifetime safety
  margin the Function Manager restarts under.
* :class:`ResilientContext` — the engine's retry wrapper around a worker
  context: transient store errors are retried with the policy's backoff,
  charged on the worker's own clock (``op="retry"`` spans), and converted
  to :class:`FaultToleranceExceeded` when the budget runs out.

The acceptance bar is numeric: a plan trained *through* a FaultPlan must
produce params bit-identical to the fault-free run (``tests/test_faults.py``)
— recovery replays steps from store-backed checkpoints, and every replayed
program is idempotent over store keys, so the math cannot drift.
"""
from __future__ import annotations

import json
import random
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.serverless.backends.base import (
    ExecutionBackend,
    StepTiming,
    WorkerContext,
    WorkerProgram,
)
from repro.serverless.retry import RetryPolicy
from repro.serverless.runtime.store import ProducerDeadError, StoreAbortedError

PHASES = ("fwd", "bwd")


# --------------------------------------------------------------------- errors
class TransientStoreError(RuntimeError):
    """An injected transient store failure (the 5xx/throttle class of S3/OSS
    errors): the request never happened, retrying is safe and expected."""


class WorkerCrashed(RuntimeError):
    """A worker function died mid-step (injected crash or lifetime-cap kill).
    Recoverable: the engine relaunches from the last store checkpoint."""

    def __init__(self, msg: str, *, stage: int = -1, replica: int = -1,
                 step: int = -1, kind: str = "crash"):
        super().__init__(msg)
        self.stage = stage
        self.replica = replica
        self.step = step
        self.kind = kind


class FaultToleranceExceeded(RuntimeError):
    """The configured recovery budget ran out (retries exhausted on one op,
    or more restarts than ``FaultTolerance.max_restarts``)."""


#: what the engine may catch and recover from (via checkpoint/restart) when
#: fault tolerance is enabled; FaultToleranceExceeded is deliberately NOT
#: recoverable — it is the typed "give up" signal
RECOVERABLE_ERRORS: Tuple[type, ...] = (
    WorkerCrashed, TimeoutError, StoreAbortedError, ProducerDeadError,
)


def is_recoverable(exc: BaseException) -> bool:
    import threading

    if isinstance(exc, FaultToleranceExceeded):
        return False
    return isinstance(exc, RECOVERABLE_ERRORS + (threading.BrokenBarrierError,))


# --------------------------------------------------------------------- events
@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.  ``kind``:

    * ``"transient"`` — the ``index``-th store op of kind ``op`` (``put`` |
      ``get``) issued by worker (stage, replica) during ``step`` fails with
      :class:`TransientStoreError` for ``times`` consecutive attempts.
    * ``"crash"`` — the worker raises :class:`WorkerCrashed` at its next op
      once it is in ``phase`` of ``step``.
    * ``"straggle"`` — the worker's first compute of ``step`` is slowed by
      ``slow_s`` seconds (virtual charge on modeled clocks, a real sleep on
      wall clocks).

    Events are *consumed* when they fire: a step replayed after recovery
    does not re-trigger the fault that killed it (the schedule is a list of
    events, not a rule), which is what makes chaos runs terminate.
    """

    kind: str                   # transient | crash | straggle
    stage: int
    replica: int
    step: int
    op: str = "get"             # transient: put | get
    index: int = 0              # transient: nth op of that kind in the step
    times: int = 1              # transient: consecutive failing attempts
    phase: str = "fwd"          # crash: fwd | bwd
    slow_s: float = 0.0         # straggle: extra seconds

    def to_dict(self) -> dict:
        d = {"kind": self.kind, "stage": self.stage, "replica": self.replica,
             "step": self.step}
        if self.kind == "transient":
            d.update(op=self.op, index=self.index, times=self.times)
        elif self.kind == "crash":
            d["phase"] = self.phase
        elif self.kind == "straggle":
            d["slow_s"] = self.slow_s
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "FaultEvent":
        known = {"kind", "stage", "replica", "step", "op", "index", "times",
                 "phase", "slow_s"}
        extra = set(d) - known
        if extra:
            raise ValueError(f"unknown FaultEvent fields {sorted(extra)}")
        return cls(**{k: d[k] for k in d})


@dataclass(frozen=True)
class FaultPlan:
    """A serializable schedule of fault events plus the platform's lifetime
    cap.  ``lifetime_steps`` models the Lambda 15-minute limit in engine
    steps: any worker older than that many steps since its (re)launch is
    killed at its next op — the engine's Function Manager must checkpoint
    and relaunch under the cap to make progress."""

    events: Tuple[FaultEvent, ...] = ()
    lifetime_steps: Optional[int] = None
    seed: Optional[int] = None          # provenance only

    # ------------------------------------------------------------ generation
    @classmethod
    def generate(cls, seed: int, *, steps: int, S: int, d: int,
                 n_transient: int = 2, n_crashes: int = 1,
                 n_stragglers: int = 0, transient_times: int = 1,
                 straggle_s: float = 0.05,
                 lifetime_steps: Optional[int] = None) -> "FaultPlan":
        """Seeded random schedule over a ``steps`` x ``S`` x ``d`` run.  Same
        arguments -> identical plan (``random.Random(seed)``, no global
        state).  Crashes are only scheduled from step 1 on when possible so
        a checkpoint exists to recover from (step-0 crashes are legal — the
        engine rebuilds from initial state — just slower)."""
        rng = random.Random(seed)
        events: List[FaultEvent] = []
        for _ in range(n_transient):
            events.append(FaultEvent(
                kind="transient", stage=rng.randrange(S),
                replica=rng.randrange(d), step=rng.randrange(steps),
                op=rng.choice(("put", "get")), index=rng.randrange(2),
                times=transient_times))
        for _ in range(n_crashes):
            events.append(FaultEvent(
                kind="crash", stage=rng.randrange(S),
                replica=rng.randrange(d),
                step=rng.randrange(min(1, steps - 1), steps),
                phase=rng.choice(PHASES)))
        for _ in range(n_stragglers):
            events.append(FaultEvent(
                kind="straggle", stage=rng.randrange(S),
                replica=rng.randrange(d), step=rng.randrange(steps),
                slow_s=straggle_s * (1 + rng.random())))
        return cls(events=tuple(events), lifetime_steps=lifetime_steps,
                   seed=seed)

    # --------------------------------------------------------- serialization
    def to_json(self, *, indent: Optional[int] = 1) -> str:
        doc = {"version": 1, "seed": self.seed,
               "lifetime_steps": self.lifetime_steps,
               "events": [e.to_dict() for e in self.events]}
        return json.dumps(doc, indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        doc = json.loads(text)
        if not isinstance(doc, dict) or doc.get("version") != 1:
            raise ValueError("not a FaultPlan JSON (expected version 1)")
        return cls(events=tuple(FaultEvent.from_dict(e)
                                for e in doc.get("events", [])),
                   lifetime_steps=doc.get("lifetime_steps"),
                   seed=doc.get("seed"))

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path) -> "FaultPlan":
        with open(path) as f:
            return cls.from_json(f.read())

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + 1
        if self.lifetime_steps is not None:
            out["lifetime_steps"] = self.lifetime_steps
        return out


# --------------------------------------------------------------- retry policy
# RetryPolicy lives in repro.serverless.retry (dependency-free) so the cloud
# backend config can carry it without importing this module; re-exported here
# because the fault-tolerance surface is where users meet it.
@dataclass(frozen=True)
class FaultTolerance:
    """Engine-side recovery configuration (``run_plan(tolerance=...)``,
    ``Execution.tolerance``, ``repro emulate --retries/--checkpoint-every``).

    ``checkpoint_every=N`` uploads every stage's param/opt state into the
    object store after each N-th step (charged like any upload);
    ``None`` disables checkpointing — crashes then replay from step 0.
    ``lifetime_steps`` overrides the injected/platform function-lifetime cap
    the Function Manager restarts under (margin ``lifetime_safety``).
    """

    retry: RetryPolicy = RetryPolicy()
    checkpoint_every: Optional[int] = 1
    max_restarts: int = 8
    lifetime_steps: Optional[int] = None
    lifetime_safety: float = 0.9


# --------------------------------------------------------------- fault report
@dataclass
class FaultReport:
    """What the run survived: faults injected (by kind), retries spent,
    restarts driven, checkpoints written, and the recovery overhead on the
    backend's clock (retry backoff + checkpoint-restore time; replayed step
    time shows up in ``t_iter`` itself)."""

    injected: Dict[str, int] = field(default_factory=dict)
    retries: int = 0
    restarts: int = 0
    planned_restarts: int = 0       # lifetime-cap restarts (Function Manager)
    checkpoints: int = 0
    recovery_s: float = 0.0
    resumed_steps: List[int] = field(default_factory=list)

    def count_injected(self, kind: str) -> None:
        self.injected[kind] = self.injected.get(kind, 0) + 1

    def as_dict(self) -> dict:
        return {"injected": dict(self.injected), "retries": self.retries,
                "restarts": self.restarts,
                "planned_restarts": self.planned_restarts,
                "checkpoints": self.checkpoints,
                "recovery_s": self.recovery_s,
                "resumed_steps": list(self.resumed_steps)}

    def describe(self) -> str:
        inj = " ".join(f"{k}={v}" for k, v in sorted(self.injected.items())) \
            or "none"
        return (f"faults injected: {inj}; retries={self.retries} "
                f"restarts={self.restarts} "
                f"(planned={self.planned_restarts}) "
                f"checkpoints={self.checkpoints} "
                f"recovery={self.recovery_s:.3f}s")


# ------------------------------------------------------------------ injection
class _PlanState:
    """Mutable once-only firing state shared by all contexts of one run."""

    def __init__(self, plan: FaultPlan, report: Optional[FaultReport]):
        self.plan = plan
        self.report = report
        # transient events keep a remaining-attempts countdown; others a flag
        self.remaining: Dict[int, int] = {
            i: e.times for i, e in enumerate(plan.events)
            if e.kind == "transient"}
        self.fired: set = set()

    def _note(self, kind: str) -> None:
        if self.report is not None:
            self.report.count_injected(kind)

    # ---- per-op checks (called by FaultyContext before delegating) --------
    def transient_for(self, stage: int, replica: int, step: int, op: str,
                      count: int) -> bool:
        for i, e in enumerate(self.plan.events):
            if (e.kind == "transient" and e.stage == stage
                    and e.replica == replica and e.step == step
                    and e.op == op and e.index == count
                    and self.remaining.get(i, 0) > 0):
                self.remaining[i] -= 1
                self._note("transient")
                return True
        return False

    def crash_for(self, stage: int, replica: int, step: int,
                  phase: str) -> bool:
        for i, e in enumerate(self.plan.events):
            if (e.kind == "crash" and i not in self.fired
                    and e.stage == stage and e.replica == replica
                    and e.step == step and e.phase == phase):
                self.fired.add(i)
                self._note("crash")
                return True
        return False

    def straggle_for(self, stage: int, replica: int, step: int) -> float:
        for i, e in enumerate(self.plan.events):
            if (e.kind == "straggle" and i not in self.fired
                    and e.stage == stage and e.replica == replica
                    and e.step == step):
                self.fired.add(i)
                self._note("straggle")
                return e.slow_s
        return 0.0


class FaultyWorkerContext(WorkerContext):
    """Decorates a backend's worker context with the plan's fault events.

    Op counting is *per worker per step* and counts only ops that proceed
    (failed attempts re-match until the event's ``times`` are spent), so
    injection points are deterministic on single-threaded virtual clocks and
    on real concurrent threads alike — each worker's program is serial.
    """

    def __init__(self, inner: WorkerContext, state: _PlanState, stage: int,
                 replica: int, injector: "FaultInjector"):
        self.inner = inner
        self.state = state
        self.stage = stage
        self.replica = replica
        self.injector = injector
        self.phase = "fwd"
        self._n_put = 0
        self._n_get = 0

    # ------------------------------------------------------------- triggers
    def _step(self) -> int:
        return self.injector.current_step

    def _check_liveness(self) -> None:
        inj = self.injector
        cap = inj.plan.lifetime_steps
        if cap is not None and inj.age >= cap:
            if self.state.report is not None and not inj._lifetime_noted:
                inj._lifetime_noted = True
                self.state.report.count_injected("lifetime")
            raise WorkerCrashed(
                f"worker (stage {self.stage}, replica {self.replica}) "
                f"exceeded the function lifetime cap ({cap} steps since "
                "launch) — the platform killed it", stage=self.stage,
                replica=self.replica, step=self._step(), kind="lifetime")
        if self.state.crash_for(self.stage, self.replica, self._step(),
                                self.phase):
            raise WorkerCrashed(
                f"injected crash: worker (stage {self.stage}, replica "
                f"{self.replica}) died in {self.phase} of step "
                f"{self._step()}", stage=self.stage, replica=self.replica,
                step=self._step())

    def _check_transient(self, op: str, count: int, key: str) -> None:
        if self.state.transient_for(self.stage, self.replica, self._step(),
                                    op, count):
            raise TransientStoreError(
                f"injected transient store {op} error on {key!r} (worker "
                f"stage {self.stage}, replica {self.replica}, step "
                f"{self._step()})")

    # ------------------------------------------------------------- protocol
    def download(self, key: str):
        self._check_liveness()
        self._check_transient("get", self._n_get, key)
        out = self.inner.download(key)
        self._n_get += 1
        return out

    def compute(self, cost_s: float, fn: Optional[Callable[[], Any]] = None,
                after: Any = None) -> Any:
        self._check_liveness()
        extra = self.state.straggle_for(self.stage, self.replica,
                                        self._step())
        if extra > 0.0:
            self.inner.wait(extra, op="compute")
        return self.inner.compute(cost_s, fn, after=after)

    def upload(self, key: str, nbytes: float, value: Any = None) -> Any:
        self._check_liveness()
        self._check_transient("put", self._n_put, key)
        out = self.inner.upload(key, nbytes, value=value)
        self._n_put += 1
        return out

    def phase_barrier(self) -> None:
        self.inner.phase_barrier()
        self.phase = "bwd"
        self._check_liveness()          # bwd-phase crashes fire at the fence

    def wait(self, seconds: float, op: str = "retry") -> None:
        self.inner.wait(seconds, op=op)

    def fetch(self, key: str, op: str = "download"):
        self._check_liveness()
        self._check_transient("get", self._n_get, key)
        out = self.inner.fetch(key, op=op)
        self._n_get += 1
        return out


class FaultInjector(ExecutionBackend):
    """Chaos wrapper around any :class:`ExecutionBackend`: same registry
    contract, same store, same clocks — but worker contexts fire the
    :class:`FaultPlan`'s events.  ``name``/``wall_clock`` mirror the inner
    backend so results attribute to the substrate that actually ran."""

    def __init__(self, inner: ExecutionBackend, plan: FaultPlan,
                 report: Optional[FaultReport] = None):
        self.inner = inner
        self.plan = plan
        self.state = _PlanState(plan, report)
        self.name = inner.name
        self.wall_clock = inner.wall_clock
        self.current_step = 0
        self.age = 0                    # steps since last (re)launch
        self._lifetime_noted = False

    def set_report(self, report: FaultReport) -> None:
        self.state.report = report

    @property
    def lifetime_steps(self) -> Optional[int]:
        return self.plan.lifetime_steps

    # ------------------------------------------------------------ delegation
    @property
    def hosts_programs(self) -> bool:  # type: ignore[override]
        return bool(getattr(self.inner, "hosts_programs", False))

    def bind_run(self, **kw) -> None:
        """Program-hosting backends get the injector itself: they ship the
        plan's events to their worker processes and merge the consumed state
        back into ``self.state`` (the authoritative once-only schedule)."""
        self.inner.bind_run(**kw, injector=self)

    def stage_step(self, k: int, *, batch=None, losses=None) -> None:
        self.inner.stage_step(k, batch=batch, losses=losses)

    def worker_handles(self):
        return self.inner.worker_handles()

    def attach_recorder(self, recorder) -> None:
        self.inner.attach_recorder(recorder)

    def open(self, agg) -> None:
        self.inner.open(agg)
        self.current_step = 0
        self.age = 0

    def context(self, s: int, r: int) -> FaultyWorkerContext:
        return FaultyWorkerContext(self.inner.context(s, r), self.state,
                                   s, r, self)

    def run_step(self, k: int, programs: Dict[Tuple[int, int], WorkerProgram],
                 *, pipelined_sync: bool = True) -> StepTiming:
        self.current_step = k
        timing = self.inner.run_step(k, programs,
                                     pipelined_sync=pipelined_sync)
        self.age += 1
        return timing

    @property
    def store_stats(self):
        return self.inner.store_stats

    def _store_for_verification(self):
        return self.inner._store_for_verification()

    def delete(self, key: str) -> None:
        self.inner.delete(key)

    def recover(self) -> int:
        """A relaunch resets the function-lifetime age: the engine's restart
        provisioned fresh function instances."""
        self.age = 0
        return self.inner.recover()

    def verify_drained(self) -> None:
        self.inner.verify_drained()

    def close(self) -> None:
        self.inner.close()


class ResilientContext(WorkerContext):
    """The engine's retry wrapper: transient store errors back off and retry
    on the worker's own clock (``op="retry"`` spans — visible in ``repro
    inspect``), then surface as :class:`FaultToleranceExceeded` when
    ``RetryPolicy.max_attempts`` is spent.  Compute errors pass through —
    a crashed worker is the restart path's business, not the retry loop's."""

    def __init__(self, inner: WorkerContext, policy: RetryPolicy,
                 report: FaultReport):
        self.inner = inner
        self.policy = policy
        self.report = report

    def _retrying(self, op: Callable[[], Any], token: str) -> Any:
        attempt = 1
        while True:
            try:
                return op()
            except TransientStoreError as e:
                if attempt >= self.policy.max_attempts:
                    raise FaultToleranceExceeded(
                        f"store op on {token!r} still failing after "
                        f"{attempt} attempts: {e}") from e
                delay = self.policy.delay(attempt, token)
                self.report.retries += 1
                self.report.recovery_s += delay
                self.inner.wait(delay, op="retry")
                attempt += 1

    def download(self, key: str):
        return self._retrying(lambda: self.inner.download(key), key)

    def compute(self, cost_s: float, fn: Optional[Callable[[], Any]] = None,
                after: Any = None) -> Any:
        return self.inner.compute(cost_s, fn, after=after)

    def upload(self, key: str, nbytes: float, value: Any = None) -> Any:
        return self._retrying(
            lambda: self.inner.upload(key, nbytes, value=value), key)

    def phase_barrier(self) -> None:
        self.inner.phase_barrier()

    def wait(self, seconds: float, op: str = "retry") -> None:
        self.inner.wait(seconds, op=op)

    def fetch(self, key: str, op: str = "download"):
        return self._retrying(lambda: self.inner.fetch(key, op=op), key)


__all__ = [
    "FaultEvent", "FaultPlan", "FaultInjector", "FaultReport",
    "FaultTolerance", "FaultToleranceExceeded", "FaultyWorkerContext",
    "ResilientContext", "RetryPolicy", "TransientStoreError", "WorkerCrashed",
    "RECOVERABLE_ERRORS", "is_recoverable", "replace",
]
