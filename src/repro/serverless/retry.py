"""Retry policy for transient store errors (dependency-free).

Lives in its own module because both ends of the stack need it without
importing each other: the engine's fault-tolerance layer
(``repro.serverless.faults``) retries with it, and the cloud adapter config
surface (``repro.serverless.backends.cloud.CloudConfig``) carries it so real
S3/OSS runs and chaos tests speak the same backoff language.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass


@dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff with deterministic jitter for transient store
    errors.  ``delay(attempt, token)`` is a pure function of the policy, the
    attempt number and the token (usually the store key), so retried runs
    charge identical backoff on the virtual clock — chaos runs replay
    bit-identically in time as well as in value."""

    max_attempts: int = 5
    base_delay_s: float = 0.05
    multiplier: float = 2.0
    max_delay_s: float = 2.0
    jitter: float = 0.25            # +- fraction of the backoff
    seed: int = 0

    def delay(self, attempt: int, token: str = "") -> float:
        d = min(self.base_delay_s * self.multiplier ** max(0, attempt - 1),
                self.max_delay_s)
        if self.jitter:
            h = zlib.crc32(f"{self.seed}:{token}:{attempt}".encode())
            u = 2.0 * (h / 0xFFFFFFFF) - 1.0          # [-1, 1], deterministic
            d *= 1.0 + self.jitter * u
        return d
