"""Process-parallel execution backend: S x d real OS processes.

Where the ``local`` backend runs the plan's workers as threads in one
Python process (GIL-serialized JAX compute, thread-state liveness), this
backend launches each stage worker as a *real OS process* over the
file-backed :class:`~repro.serverless.backends.process_worker.FileStore` —
true parallel JAX compute, real cross-process visibility/ordering races,
and fault semantics with teeth: an injected crash SIGKILLs an actual
process, a lifetime cap makes it exit planned, and consumers notice either
through frozen heartbeat mtimes, not shared memory.

The engine cooperates through the ``hosts_programs`` hooks on the backend
protocol: generator programs cannot cross a process boundary, so each child
runs the engine's own ``_worker_step_program`` locally over the shared
store (``bind_run`` ships the execution spec before ``open``,
``stage_step`` ships each step's evaluated batch, ``worker_handles`` hands
the engine RPC proxies that quack like ``StageWorker`` for checkpointing
and final param assembly).  Numerics are the acceptance bar, same as every
backend: K-step trained params bit-identical to ``emulated``/``local`` on
both sync schedules, through injected crashes, with the store drained
(``tests/test_backends.py`` / ``tests/test_faults.py``).

``payload_true=True`` charges real payload ``nbytes`` per transfer and
``throttle=True`` sleeps each worker's uplink/downlink to the platform's
configured per-worker bandwidth (``agg.w[s]``), giving the wall-clock time
axis a calibration the trace-feedback loop can act on.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.serverless.backends.base import (
    ExecutionBackend,
    StepTiming,
    WorkerProgram,
)
from repro.serverless.backends.local import (
    DEFAULT_GET_TIMEOUT,
    LocalWorkerContext,
    _primary_error,
)
from repro.serverless.backends.process_worker import (
    EXIT_LIFETIME,
    FileStore,
    worker_main,
)
from repro.serverless.runtime.store import (
    ProducerDeadError,
    StoreAbortedError,
    StoreStats,
)

# a producer process whose heartbeat file mtime is older than this is dead;
# generous vs the thread backend's 5s — child heartbeats ride a daemon
# thread, but process scheduling and cold jit compiles add real jitter
DEFAULT_PROCESS_LEASE = 20.0

# S x d real OS processes, each importing jax: beyond this the host is
# benchmarking its scheduler and RAM, not the plan
MAX_PROCESSES = 64

#: extra slack the parent's collect loop grants past the store get timeout
#: before declaring the step wedged
_COLLECT_SLACK = 60.0


def _errors_by_name() -> Dict[str, Any]:
    from repro.serverless import faults as F

    return {
        "WorkerCrashed": F.WorkerCrashed,
        "TransientStoreError": F.TransientStoreError,
        "FaultToleranceExceeded": F.FaultToleranceExceeded,
        "StoreAbortedError": StoreAbortedError,
        "ProducerDeadError": ProducerDeadError,
        "TimeoutError": TimeoutError,
        "BrokenBarrierError": threading.BrokenBarrierError,
    }


class ProcessWorkerHandle:
    """RPC proxy for one child's :class:`StageWorker`: exposes the
    ``params``/``span``/``export_state``/``load_state`` surface the engine's
    checkpoint and param-assembly paths touch, forwarding over the pipe.
    State reads are memoized per backend generation (a run_step or recover
    invalidates them)."""

    def __init__(self, backend: "ProcessBackend", s: int, r: int, span):
        self._backend = backend
        self._s = s
        self._r = r
        self.span = span
        self._cache: Optional[Tuple[int, dict]] = None

    def export_state(self) -> dict:
        gen = self._backend._generation
        if self._cache is not None and self._cache[0] == gen:
            return self._cache[1]
        state = self._backend._rpc((self._s, self._r),
                                   {"op": "export_state"})["state"]
        self._cache = (gen, state)
        return state

    def load_state(self, state: dict) -> None:
        self._backend._rpc((self._s, self._r),
                           {"op": "load_state", "state": state})
        self._cache = None

    def reset(self) -> None:
        self._backend._rpc((self._s, self._r), {"op": "reset"})
        self._cache = None

    @property
    def params(self) -> dict:
        return self.export_state()["params"]


class ProcessBackend(ExecutionBackend):
    """S x d worker OS processes over a payload-true-capable file store."""

    name = "process"
    wall_clock = True
    hosts_programs = True

    def __init__(self, *, root: Optional[str] = None,
                 get_timeout: float = DEFAULT_GET_TIMEOUT,
                 lease_timeout: float = DEFAULT_PROCESS_LEASE,
                 payload_true: bool = False, throttle: bool = False,
                 bandwidth: Optional[float] = None):
        self.root = root
        self.get_timeout = get_timeout
        self.lease_timeout = lease_timeout
        self.payload_true = payload_true
        self.throttle = throttle
        self.bandwidth = bandwidth      # override; default = agg.w[s]
        self.agg = None
        self.store: Optional[FileStore] = None
        self._t0 = 0.0
        self._steps_done = 0
        self._generation = 0            # bumps invalidate handle caches
        self._procs: Dict[Tuple[int, int], Any] = {}
        self._conns: Dict[Tuple[int, int], Any] = {}
        self._dead: Dict[Tuple[int, int], str] = {}   # worker -> crash kind
        self._handles: Optional[List[List[ProcessWorkerHandle]]] = None
        self._owns_root = False
        # bound run state (hosts_programs cooperation)
        self._execution = None
        self._config = None
        self._tolerance = None
        self._injector = None
        self._batch = None
        self._losses: Optional[Dict] = None

    # ------------------------------------------------------- run cooperation
    def bind_run(self, *, execution=None, config=None, tolerance=None,
                 report=None, injector=None) -> None:
        self._execution = execution
        self._config = config
        self._tolerance = tolerance
        self._injector = injector
        del report      # child retries merge through the injector's report

    def stage_step(self, k: int, *, batch=None, losses=None) -> None:
        if batch is not None:
            import jax
            import numpy as np

            batch = jax.tree.map(np.asarray, batch)
        self._batch = batch
        self._losses = losses

    def worker_handles(self) -> List[List[ProcessWorkerHandle]]:
        if self._handles is None:
            from repro.serverless.runtime.worker import stage_instance_ranges

            spans = stage_instance_ranges(self._execution.cfg,
                                          self._config.x)
            self._handles = [
                [ProcessWorkerHandle(self, s, r, spans[s])
                 for r in range(self.agg.d)]
                for s in range(self.agg.S)]
        else:
            # the engine rebuilding "from scratch" (crash before the first
            # checkpoint): every surviving child reloads its initial state
            for row in self._handles:
                for h in row:
                    h.reset()
        return self._handles

    # -------------------------------------------------------------- lifecycle
    def open(self, agg) -> None:
        if os.name != "posix":
            raise RuntimeError(
                "the process backend needs POSIX file locks and signals; "
                "replay this plan on 'local' or 'emulated' instead")
        if agg.S * agg.d > MAX_PROCESSES:
            raise ValueError(
                f"plan spawns {agg.S}x{agg.d}={agg.S * agg.d} worker "
                f"processes; the process backend caps at {MAX_PROCESSES} "
                "— replay this plan on the emulated backend instead")
        self.agg = agg
        self._owns_root = self.root is None
        root = self.root or tempfile.mkdtemp(prefix="funcpipe-procstore-")
        self._root = root
        # the parent's store client is unthrottled: it only moves engine-
        # owned checkpoint objects, which a platform's control plane writes
        self.store = FileStore(root, timeout=self.get_timeout,
                               lease_timeout=self.lease_timeout,
                               payload_true=self.payload_true)
        self._t0 = time.monotonic()
        self._steps_done = 0
        self._generation += 1
        self._procs.clear()
        self._conns.clear()
        self._dead.clear()
        self._handles = None
        for s in range(agg.S):
            for r in range(agg.d):
                self._spawn(s, r)
        self._await_ready(list(self._procs))

    def _exec_spec(self) -> Optional[dict]:
        if self._execution is None:
            return None
        import jax
        import numpy as np

        ex = self._execution
        return {"cfg": ex.cfg, "x": tuple(self._config.x),
                "init_params": jax.tree.map(np.asarray, ex.init_params),
                "mu": int(self.agg.mu), "optimizer": ex.optimizer,
                "jit": ex.jit, "remat": ex.remat}

    def _spawn(self, s: int, r: int) -> None:
        import multiprocessing as mp

        ctx = mp.get_context("spawn")   # no forked jax/thread state
        parent_conn, child_conn = ctx.Pipe()
        bw = None
        if self.throttle:
            bw = self.bandwidth or float(self.agg.w[s])
        init = {"root": self._root, "s": s, "r": r,
                "agg": self.agg, "exec_spec": self._exec_spec(),
                "get_timeout": self.get_timeout,
                "lease_timeout": self.lease_timeout,
                "payload_true": self.payload_true,
                "bandwidth": bw, "t_lat": float(self.agg.t_lat),
                "t0": self._t0}
        p = ctx.Process(target=worker_main, args=(child_conn, init),
                        name=f"funcpipe-s{s}r{r}", daemon=True)
        p.start()
        child_conn.close()
        self._procs[(s, r)] = p
        self._conns[(s, r)] = parent_conn

    def _await_ready(self, workers) -> None:
        # generous: each child imports jax from scratch under spawn
        deadline = time.monotonic() + 120.0
        for w in workers:
            while not self._conns[w].poll(0.2):
                if not self._procs[w].is_alive():
                    raise RuntimeError(
                        f"worker process s{w[0]}r{w[1]} died during spawn "
                        f"(exit code {self._procs[w].exitcode})")
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"worker process s{w[0]}r{w[1]} never reported "
                        "ready (jax import wedged?)")
            try:
                msg = self._conns[w].recv()
            except EOFError:
                self._procs[w].join(timeout=5.0)
                raise RuntimeError(
                    f"worker process s{w[0]}r{w[1]} died during spawn "
                    f"(exit code {self._procs[w].exitcode})") from None
            assert "ready" in msg, msg

    def _rpc(self, w: Tuple[int, int], cmd: dict) -> dict:
        conn = self._conns[w]
        conn.send(cmd)
        if not conn.poll(self.get_timeout + _COLLECT_SLACK):
            raise TimeoutError(
                f"worker s{w[0]}r{w[1]} did not answer {cmd['op']!r}")
        return conn.recv()

    # ------------------------------------------------------------ observation
    def _clock(self) -> float:
        return time.monotonic() - self._t0

    def context(self, s: int, r: int) -> LocalWorkerContext:
        # parent-side contexts carry only engine traffic (checkpoint
        # write/restore); worker=None — the parent must not heartbeat a
        # child's lease
        if self.recorder is None:
            return LocalWorkerContext(self.store)
        tr = self.recorder.tracer(s, r)
        tr.step = self._steps_done
        tr.phase = "fwd"
        return LocalWorkerContext(self.store, tracer=tr, clock=self._clock)

    @property
    def store_stats(self) -> StoreStats:
        return self.store.stats

    def _store_for_verification(self):
        return self.store

    # --------------------------------------------------------------- stepping
    def _fault_payload(self) -> Optional[dict]:
        inj = self._injector
        if inj is None:
            return None
        return {"events": [e.to_dict() for e in inj.plan.events],
                "lifetime_steps": inj.plan.lifetime_steps,
                "remaining": dict(inj.state.remaining),
                "fired": sorted(inj.state.fired),
                "age": inj.age}

    def _merge_fault(self, delta: Optional[dict]) -> None:
        """Fold a child's fault-consumption state back into the parent's
        injector (the authoritative once-only schedule) and count what
        actually fired for the report."""
        inj = self._injector
        if delta is None:
            return
        if inj is not None and "remaining" in delta:
            state = inj.state
            for i, rem in delta["remaining"].items():
                i = int(i)
                spent = state.remaining.get(i, 0) - rem
                if spent > 0:
                    state.remaining[i] = rem
                    for _ in range(spent):
                        state._note("transient")
            for i in delta.get("fired", ()):
                if i not in state.fired:
                    state.fired.add(i)
                    state._note(inj.plan.events[i].kind)
        report = self._report()
        if report is not None:
            report.retries += delta.get("retries", 0)
            report.recovery_s += delta.get("recovery_s", 0.0)

    def _report(self):
        inj = self._injector
        return None if inj is None else inj.state.report

    def _note_lifetime(self) -> None:
        inj = self._injector
        if inj is None or inj._lifetime_noted:
            return
        inj._lifetime_noted = True
        if inj.state.report is not None:
            inj.state.report.count_injected("lifetime")

    def _on_death(self, w: Tuple[int, int], k: int, errors: list,
                  had_dying_msg: bool) -> None:
        """A worker process died: join it, classify the death from its exit
        code, poison the substrate for its peers, and synthesize the
        :class:`WorkerCrashed` the engine's recovery path expects."""
        from repro.serverless import faults as F

        p = self._procs[w]
        p.join(timeout=5.0)
        kind = "lifetime" if p.exitcode == EXIT_LIFETIME else "crash"
        self._dead[w] = kind
        self.store.mark_dead(w)
        s, r = w
        if kind == "lifetime":
            self._note_lifetime()
            msg = (f"worker (stage {s}, replica {r}) exceeded the function "
                   "lifetime cap — the platform recycled its process "
                   f"(exit {EXIT_LIFETIME})")
        else:
            msg = (f"worker process (stage {s}, replica {r}) died in step "
                   f"{k} (exit code {p.exitcode})")
            if not had_dying_msg and self._injector is not None:
                # dying report lost with the process: consume the matching
                # crash event so the replay does not re-fire it
                state = self._injector.state
                for i, e in enumerate(self._injector.plan.events):
                    if (e.kind == "crash" and i not in state.fired
                            and e.stage == s and e.replica == r
                            and e.step == k):
                        state.fired.add(i)
                        state._note("crash")
                        break
        err = F.WorkerCrashed(msg, stage=s, replica=r, step=k, kind=kind)
        self.store.abort(err)
        if not had_dying_msg:
            errors.append(err)

    def _absorb(self, w: Tuple[int, int], k: int, msg: dict, errors: list,
                syncs: list) -> bool:
        """Process one child reply; True when the worker is accounted for
        this step."""
        s, r = w
        if "ready" in msg:      # stale handshake (respawn race); ignore
            return False
        body = msg.get("ok") and msg or msg.get("error") or msg.get("dying")
        if isinstance(body, dict) and self.recorder is not None:
            for span in body.get("spans") or ():
                self.recorder.spans.append(span)
        if msg.get("ok"):
            self._merge_fault(msg.get("fault"))
            syncs.append(float(msg.get("sync_s") or 0.0))
            loss = msg.get("loss")
            if loss is not None and self._losses is not None:
                self._losses[(s, r)] = tuple(loss)
            return True
        if "dying" in msg:
            from repro.serverless import faults as F

            d = msg["dying"]
            self._merge_fault(d.get("fault"))
            if d["kind"] == "lifetime":
                self._note_lifetime()
            errors.append(F.WorkerCrashed(d["msg"], stage=s, replica=r,
                                          step=k, kind=d["kind"]))
            # the process is now killing itself; reap it when it lands
            self._dead[w] = d["kind"]
            self._procs[w].join(timeout=5.0)
            self.store.mark_dead(w)
            return True
        if "error" in msg:
            d = msg["error"]
            self._merge_fault(d.get("fault"))
            cls = _errors_by_name().get(d["type"], RuntimeError)
            errors.append(_reconstruct_error(cls, d["msg"]))
            return True
        return False

    def run_step(self, k: int, programs: Dict[Tuple[int, int], WorkerProgram],
                 *, pipelined_sync: bool = True) -> StepTiming:
        # the engine's generator programs cannot cross the process boundary;
        # each child runs the identical program locally — close these
        # unstarted (no op ever fires on the parent's copies)
        for gen in programs.values():
            gen.close()
        cmd = {"op": "step", "k": k, "pipelined": bool(pipelined_sync),
               "batch": self._batch, "fault": self._fault_payload(),
               "retry": (self._tolerance.retry
                         if self._tolerance is not None else None),
               "trace": self.recorder is not None,
               "trace_step": self._steps_done}
        errors: list = []
        syncs: List[float] = []
        pending = set(self._conns)
        for w in list(pending):
            try:
                self._conns[w].send(cmd)
            except (BrokenPipeError, OSError):
                self._on_death(w, k, errors, had_dying_msg=False)
                pending.discard(w)
        deadline = time.monotonic() + self.get_timeout + _COLLECT_SLACK
        while pending:
            progressed = False
            for w in list(pending):
                conn = self._conns[w]
                try:
                    has_msg = conn.poll(0.0)
                except (BrokenPipeError, OSError):
                    has_msg = False
                if has_msg:
                    try:
                        msg = conn.recv()
                    except EOFError:
                        self._on_death(w, k, errors, had_dying_msg=False)
                        pending.discard(w)
                        progressed = True
                        continue
                    if self._absorb(w, k, msg, errors, syncs):
                        pending.discard(w)
                    progressed = True
                elif not self._procs[w].is_alive():
                    # drain any message the kernel buffered before death
                    if conn.poll(0.0):
                        continue
                    had = self._dead.get(w) is not None
                    self._on_death(w, k, errors, had_dying_msg=had)
                    pending.discard(w)
                    progressed = True
            if pending and not progressed:
                if time.monotonic() > deadline:
                    who = ", ".join(f"s{s}r{r}" for s, r in sorted(pending))
                    budget = self.get_timeout + _COLLECT_SLACK
                    raise TimeoutError(
                        f"step {k} wedged: no reply from worker processes "
                        f"[{who}] within {budget:.0f}s")
                time.sleep(0.01)
        self._generation += 1
        if errors:
            raise _primary_error(errors)
        self._steps_done += 1
        return StepTiming(end=time.monotonic() - self._t0,
                          sync=max(syncs) if syncs else 0.0)

    # ---------------------------------------------------------------- serving
    def serve(self, spec: dict, *, trace_step: int = 0) -> list:
        """Broadcast one pipelined serving request (``repro.serving``) to
        every stage worker and collect replies.  Each child builds its
        ``ServeStageWorker`` from ``spec`` and drives its serving program to
        completion over the shared file store (the blocking ``take``\\ s
        self-synchronize the pipeline); the head stage replies with the
        greedy tokens.  Returns the head stage's token list ([B, 1] int32
        arrays in decode order)."""
        cmd = {"op": "serve", "spec": spec,
               "trace": self.recorder is not None, "trace_step": trace_step}
        errors: list = []
        tokens: Optional[list] = None
        pending = set(self._conns)
        for w in list(pending):
            try:
                self._conns[w].send(cmd)
            except (BrokenPipeError, OSError):
                self._on_death(w, 0, errors, had_dying_msg=False)
                pending.discard(w)
        deadline = time.monotonic() + self.get_timeout + _COLLECT_SLACK
        while pending:
            progressed = False
            for w in list(pending):
                conn = self._conns[w]
                try:
                    has_msg = conn.poll(0.0)
                except (BrokenPipeError, OSError):
                    has_msg = False
                if has_msg:
                    try:
                        msg = conn.recv()
                    except EOFError:
                        self._on_death(w, 0, errors, had_dying_msg=False)
                        pending.discard(w)
                        progressed = True
                        continue
                    if "ready" in msg:      # stale handshake; ignore
                        progressed = True
                        continue
                    body = msg.get("ok") and msg or msg.get("error")
                    if isinstance(body, dict) and self.recorder is not None:
                        for span in body.get("spans") or ():
                            self.recorder.spans.append(span)
                    if msg.get("ok"):
                        if msg.get("tokens") is not None:
                            tokens = msg["tokens"]
                    elif "error" in msg:
                        d = msg["error"]
                        cls = _errors_by_name().get(d["type"], RuntimeError)
                        errors.append(_reconstruct_error(cls, d["msg"]))
                    pending.discard(w)
                    progressed = True
                elif not self._procs[w].is_alive():
                    if conn.poll(0.0):
                        continue
                    had = self._dead.get(w) is not None
                    self._on_death(w, 0, errors, had_dying_msg=had)
                    pending.discard(w)
                    progressed = True
            if pending and not progressed:
                if time.monotonic() > deadline:
                    who = ", ".join(f"s{s}r{r}" for s, r in sorted(pending))
                    raise TimeoutError(
                        "serve request wedged: no reply from worker "
                        f"processes [{who}] within "
                        f"{self.get_timeout + _COLLECT_SLACK:.0f}s")
                time.sleep(0.01)
        self._generation += 1
        if errors:
            raise _primary_error(errors)
        if tokens is None:
            raise RuntimeError(
                "serve request produced no tokens (head stage never "
                "replied with its sink)")
        return tokens

    # --------------------------------------------------------------- recovery
    def recover(self) -> int:
        """Engine-driven relaunch: revive the poisoned store, purge residual
        non-checkpoint objects (counted), clear the barrier rendezvous
        files, and respawn only the *dead* worker processes — survivors
        keep their warm jit caches and are re-stated through
        ``load_state``/``reset`` RPCs, exactly what a Function Manager
        relaunching failed functions does."""
        self.store.revive()
        shutil.rmtree(self.store.barriers_root, ignore_errors=True)
        os.makedirs(self.store.barriers_root, exist_ok=True)
        purged = 0
        for key in list(self.store.keys()):
            if not key.startswith("ckpt/"):
                self.store.delete(key)
                purged += 1
        dead = sorted(self._dead)
        self._dead.clear()
        for w in dead:
            try:
                self._conns[w].close()
            except OSError:
                pass
            self._procs[w].join(timeout=5.0)
            self._spawn(*w)
        if dead:
            self._await_ready(dead)
        self._generation += 1
        return purged

    def delete(self, key: str) -> None:
        self.store.delete(key)

    def close(self) -> None:
        for w, conn in list(self._conns.items()):
            try:
                conn.send({"op": "exit"})
            except (BrokenPipeError, OSError):
                pass
        for w, p in list(self._procs.items()):
            p.join(timeout=5.0)
            if p.is_alive():
                p.terminate()
                p.join(timeout=2.0)
            if p.is_alive():    # pragma: no cover - terminate() sufficed
                p.kill()
                p.join(timeout=2.0)
        for conn in self._conns.values():
            try:
                conn.close()
            except OSError:
                pass
        self._procs.clear()
        self._conns.clear()
        self._dead.clear()
        self._handles = None
        if self.store is not None and self._owns_root:
            shutil.rmtree(self._root, ignore_errors=True)
        self.store = None


def _reconstruct_error(cls, msg):
    """Rebuild a child-reported exception as its real type so the engine's
    ``is_recoverable`` classification works across the process boundary."""
    try:
        return cls(msg)
    except TypeError:   # pragma: no cover - exotic signature
        return RuntimeError(msg)
