"""The execution-backend contract behind ``DeploymentPlan.emulate()``.

FuncPipe's deployment story is a *plan* executing on a storage+invocation
substrate: AWS Lambda + S3, Alibaba FC + OSS, or — here — substitutes that
run on one host.  An :class:`ExecutionBackend` is exactly that substrate,
split into the two interfaces the paper's workers need:

* an **object store** (``put``/``get``/``delete``/``keys``, byte accounting
  via :class:`~repro.serverless.runtime.store.StoreStats`, and a visibility
  rule — virtual ``visible_at`` timestamps or real blocking gets);
* a **worker-invocation surface**: spawn the plan's ``S x d`` stage workers
  and drive each one's per-step program (:class:`WorkerContext`), either on
  a per-worker virtual clock or on real concurrent threads.

The GPipe orchestrator (``runtime.engine``) expresses each worker's training
step as a *generator program* over its :class:`WorkerContext` — download,
compute, upload, a fwd/bwd phase fence, then a ``("sync", grad_vector)``
yield that the backend answers with the reduced gradient.  The engine never
touches a store or a clock directly; a real boto3/OSS backend slots in by
implementing this module's two classes and registering a name.

Time semantics are the one axis backends may legitimately differ on
(``wall_clock``): the emulated backend charges the paper's cost model on a
virtual clock, the local backend measures the host.  *Numerics may not
differ*: a plan replayed on any backend must train to bit-identical params.
"""
from __future__ import annotations

from abc import ABC, abstractmethod
from dataclasses import dataclass
from typing import Any, Callable, Dict, Generator, Optional, Tuple

from repro.serverless.runtime.store import StoreStats, assert_store_drained

# a worker's per-step program: yields None after each fwd/bwd micro-batch op
# group, then yields ("sync", grad_vector_or_None) and receives the reduced
# vector via .send(); see engine._worker_step_program
WorkerProgram = Generator[Optional[Tuple[str, Any]], Any, None]


@dataclass(frozen=True)
class StepTiming:
    """What one executed training step cost on the backend's clock.

    ``end`` is the step's completion time measured from the start of the run
    (virtual seconds on the emulated clock, host seconds on wall-clock
    backends) — monotone across steps, so the engine derives per-iteration
    time as ``end_of_last_step / steps``.  ``sync`` is the slowest stage's
    scatter-reduce duration within the step.
    """

    end: float
    sync: float


class WorkerContext(ABC):
    """One stage worker's handle onto the backend: its serial resources
    (CPU, uplink, downlink) and its view of the shared object store.

    ``download``/``compute`` return opaque *tokens* that express data
    dependencies to virtual-clock backends (the engine passes a download's
    token as ``compute(after=...)``); wall-clock backends return ``None``
    and rely on real blocking order.
    """

    @abstractmethod
    def download(self, key: str) -> Tuple[Any, Any]:
        """Fetch-and-consume ``key``: waits for visibility, charges the
        downlink, frees the object (every pipeline boundary object has
        exactly one consumer).  Returns ``(value, token)``."""

    @abstractmethod
    def compute(self, cost_s: float, fn: Optional[Callable[[], Any]] = None,
                after: Any = None) -> Any:
        """Charge ``cost_s`` of serial CPU (starting no earlier than the
        ``after`` token) and run the real math ``fn`` if given.  Returns
        ``fn()``'s result (or None)."""

    @abstractmethod
    def upload(self, key: str, nbytes: float, value: Any = None) -> Any:
        """Publish ``value`` under ``key``, charging ``nbytes`` on the
        uplink; the object becomes visible to downloads when the upload
        completes.  Returns a token."""

    @abstractmethod
    def phase_barrier(self) -> None:
        """Program-order fence between the forward and backward phases: the
        worker issues no backward download before its forward uploads are
        done (virtual clocks must model this; real serial workers get it
        for free)."""

    def wait(self, seconds: float, op: str = "retry") -> None:
        """Charge ``seconds`` of idle occupancy on this worker (retry
        backoff, injected straggle).  Virtual clocks stall the worker's
        resources and emit an ``op`` span; wall-clock backends sleep.  The
        default is a no-op so minimal backends stay valid."""

    def fetch(self, key: str, op: str = "download") -> Tuple[Any, Any]:
        """Non-consuming ``download``: waits for visibility and charges the
        downlink but leaves the object in the store (checkpoint restores
        read the same object once per stage worker).  Emits an ``op`` span
        (``"restart"`` for recovery reads).  Returns ``(value, token)``.

        Default raises — backends that support fault-tolerant recovery
        must implement it."""
        raise NotImplementedError(
            f"{type(self).__name__} does not implement fetch(); this "
            "backend cannot restore from store-backed checkpoints")


class ExecutionBackend(ABC):
    """One storage+invocation substrate a DeploymentPlan can execute on.

    Lifecycle: ``open(agg)`` provisions the store and the ``S x d`` worker
    slots for one run; ``context(s, r)`` hands out worker handles;
    ``run_step(k, programs, ...)`` drives one training step's programs to
    completion (answering their sync yields) and reports its timing;
    ``close()`` tears down.  ``verify_drained()`` asserts the byte-
    conservation invariant — puts == deletes, nothing residual — after the
    final step.
    """

    #: registry name (see ``repro.serverless.backends.get_backend``)
    name: str = "?"
    #: True when timings are host wall-clock (local/real platforms); False
    #: when the backend charges the paper's cost model on a virtual clock
    wall_clock: bool = False
    #: optional ``repro.obs.SpanRecorder`` installed before ``open()``;
    #: tracing-capable backends emit one Span per resource task into it
    recorder = None
    #: True when the backend *hosts* the worker programs itself (each worker
    #: runs ``engine._worker_step_program`` in its own OS process/container
    #: rather than receiving a generator from the engine).  The engine then
    #: calls ``bind_run``/``stage_step``/``worker_handles`` instead of
    #: building workers and generators in-process — generators cannot cross
    #: a process boundary.
    hosts_programs: bool = False

    def bind_run(self, **kw) -> None:
        """Program-hosting hook: receive the run's execution spec before
        ``open()`` (``execution=``, ``config=``, ``tolerance=``, ``report=``
        and, when fault injection is active, ``injector=``).  Backends with
        ``hosts_programs=False`` ignore it."""

    def stage_step(self, k: int, *, batch=None, losses=None) -> None:
        """Program-hosting hook: called right before ``run_step(k, ...)``
        with the step's evaluated batch (``Execution.batch_fn`` closures are
        not picklable, so the engine evaluates and the backend ships) and
        the mutable ``losses`` dict the hosted programs must fill.  No-op
        for backends that run engine-built generators."""

    def worker_handles(self):
        """Program-hosting hook: the ``S x d`` grid of stage-worker proxies
        (each exposing ``.params``/``.span``/``export_state``/``load_state``
        like ``runtime.worker.StageWorker``) in place of the engine's own
        ``make_workers()``.  Only meaningful when ``hosts_programs``."""
        raise NotImplementedError(
            f"{type(self).__name__} does not host worker programs")

    def attach_recorder(self, recorder) -> None:
        """Install a span recorder (``repro.obs.SpanRecorder``) for the next
        ``open()``/run: the emulated backend emits virtual-clock spans, the
        local backend wall-clock spans.  Backends that do not trace simply
        leave the recorder empty — attaching is never an error."""
        self.recorder = recorder

    @abstractmethod
    def open(self, agg) -> None:
        """Provision the store + worker slots for one run of the plan whose
        per-stage cost terms are ``agg`` (``simulator.StageAggregates``)."""

    @abstractmethod
    def context(self, s: int, r: int) -> WorkerContext:
        """The handle for stage ``s``, replica ``r`` (valid after open)."""

    @abstractmethod
    def run_step(self, k: int, programs: Dict[Tuple[int, int], WorkerProgram],
                 *, pipelined_sync: bool = True) -> StepTiming:
        """Drive every worker's step-``k`` program to completion, including
        the scatter-reduce each program requests via its ``("sync", vec)``
        yield, and return the step's timing."""

    @property
    @abstractmethod
    def store_stats(self) -> StoreStats:
        """Byte-accounting counters of the run's object store."""

    def delete(self, key: str) -> None:
        """Remove ``key`` from the run's store with counted accounting
        (engine-side cleanup of checkpoint objects before the final drain
        check).  Missing keys are ignored."""
        self._store_for_verification().delete(key)

    def recover(self) -> int:
        """Reset the substrate after a failed step so the engine can replay
        from a checkpoint: purge every residual non-checkpoint object (with
        counted deletes, preserving byte conservation) and revive any
        aborted machinery.  Returns the number of purged objects.  The
        default store-purge suffices for backends whose workers hold no
        cross-step state."""
        store = self._store_for_verification()
        purged = 0
        for key in list(store.keys()):
            if not key.startswith("ckpt/"):
                store.delete(key)
                purged += 1
        return purged

    def verify_drained(self) -> None:
        """Raise if the store holds residual objects or the put/delete byte
        accounting does not conserve (see ``store.assert_store_drained``)."""
        assert_store_drained(self._store_for_verification())

    @abstractmethod
    def _store_for_verification(self):
        """The underlying store object (must expose keys/live_bytes/stats)."""

    def close(self) -> None:
        """Release resources (thread pools, temp dirs).  Idempotent."""
