"""Wall-clock backend: real concurrent stage workers on one host.

Where the emulated backend *models* serverless execution on a virtual clock,
this backend *performs* it: the plan's ``S x d`` stage workers run as real
threads, exchanging every boundary activation, gradient and scatter-reduce
chunk through a thread-safe :class:`LocalStore` whose ``get`` genuinely
blocks until the producer's ``put`` lands — the storage-visibility and
ordering races of a real platform, which the deterministic virtual-clock
interleave can never hit.  Numerics are the point: a plan replayed here must
train to params bit-identical to the emulated backend (same JAX stage math,
same ring-ordered fp32 reduction — see ``tests/test_backends.py``).

Time is host wall-clock (``wall_clock=True``): ``t_iter`` measures this
machine, not Lambda, so cost/time outputs are only self-relative; modeled
compute costs are ignored (no sleeping) and the *modeled* byte sizes are
still recorded in ``StoreStats`` so byte accounting matches the emulated
backend object-for-object.

The store is dict-backed by default; pass ``fs_root`` to spill every payload
through files (pickle round-trip per object) — closer to an object-store
client, useful for exercising serialization of the values that would cross
S3/OSS.
"""
from __future__ import annotations

import os
import pickle
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.serverless.backends.base import (
    ExecutionBackend,
    StepTiming,
    WorkerContext,
    WorkerProgram,
)
from repro.serverless.runtime.scatter_reduce import local_scatter_reduce
from repro.serverless.runtime.store import (
    ProducerDeadError,
    StoreAbortedError,
    StoreStats,
    producer_of_key,
    producer_worker_of_key,
)

# deadlock backstop: a blocking get that outwaits this is a lost producer
# (a peer worker thread died), not a slow one
DEFAULT_GET_TIMEOUT = 120.0

# a producer whose last heartbeat is older than this is *dead*, not slow:
# its consumers fail over immediately instead of burning the get timeout
DEFAULT_LEASE_TIMEOUT = 5.0

# S x d real threads; past this the run would be measuring the host's
# scheduler, not the plan — replay large plans on the emulated backend
MAX_WORKERS = 256


@dataclass
class _Stored:
    nbytes: float
    value: Any = None
    path: Optional[str] = None


class LocalStore:
    """Thread-safe key -> object namespace with *blocking* visibility.

    ``put`` makes the object immediately visible and wakes waiters; ``get``
    blocks until the key exists (raising ``TimeoutError`` after ``timeout``
    seconds so a dead producer fails the run instead of hanging it);
    ``take`` is the fetch-and-consume used for single-consumer pipeline
    boundary objects.  ``nbytes`` is the *modeled* object size (the same
    numbers the emulated store charges), kept for byte accounting; payloads
    ride in memory, or through ``fs_root`` files when given.

    Liveness: workers ``heartbeat()`` as they make progress and are
    ``mark_dead()``-ed when their thread dies.  A blocked ``get`` checks the
    awaited key's *producer lease* (the engine key schema names exactly one
    producer worker per key): a dead or heartbeat-stale producer raises
    :class:`ProducerDeadError` immediately — "dead", not "slow" — instead of
    burning the full get timeout.  ``abort()`` poisons the store, waking
    every waiter with :class:`StoreAbortedError`; ``revive()`` un-poisons it
    for the engine's recovery replay.
    """

    def __init__(self, timeout: float = DEFAULT_GET_TIMEOUT,
                 fs_root: Optional[str] = None,
                 lease_timeout: float = DEFAULT_LEASE_TIMEOUT):
        self.timeout = timeout
        self.lease_timeout = lease_timeout
        self.fs_root = fs_root
        self._cv = threading.Condition()
        self._objects: Dict[str, _Stored] = {}
        self._live_bytes = 0.0
        self._seq = 0
        self._poison: Optional[BaseException] = None
        self._heartbeats: Dict[Tuple[int, int], float] = {}
        self._dead: set = set()
        self.stats = StoreStats()
        if fs_root is not None:
            os.makedirs(fs_root, exist_ok=True)

    # ------------------------------------------------------ liveness / leases
    def heartbeat(self, worker: Tuple[int, int]) -> None:
        """Record that worker (stage, replica) is alive and making progress
        (called by its context on every store/compute op)."""
        with self._cv:
            self._heartbeats[worker] = time.monotonic()

    def mark_dead(self, worker: Tuple[int, int]) -> None:
        """Declare a worker dead (its thread raised); wakes every waiter so
        consumers of its keys fail over immediately."""
        with self._cv:
            self._dead.add(worker)
            self._cv.notify_all()

    def heartbeat_age(self, worker: Tuple[int, int]) -> Optional[float]:
        """Seconds since the worker's last heartbeat (None: never beat)."""
        with self._cv:
            beat = self._heartbeats.get(worker)
        return None if beat is None else time.monotonic() - beat

    def abort(self, reason: BaseException) -> None:
        """Poison the store: every current and future blocking op raises
        :class:`StoreAbortedError` naming ``reason`` (the first worker death
        of the step) instead of hanging until its timeout."""
        with self._cv:
            if self._poison is None:
                self._poison = reason
            self._cv.notify_all()

    def revive(self) -> None:
        """Clear poison and liveness state for a recovery replay (the
        engine respawns every worker, so old leases are meaningless)."""
        with self._cv:
            self._poison = None
            self._dead.clear()
            self._heartbeats.clear()

    # ----------------------------------------------------------- fs payloads
    def _spill(self, value: Any) -> Optional[str]:
        if self.fs_root is None or value is None:
            return None
        with self._cv:
            self._seq += 1
            path = os.path.join(self.fs_root, f"obj-{self._seq}.pkl")
        with open(path, "wb") as f:
            pickle.dump(value, f)
        return path

    @staticmethod
    def _load(obj: _Stored) -> Any:
        if obj.path is None:
            return obj.value
        with open(obj.path, "rb") as f:
            return pickle.load(f)

    # ------------------------------------------------------------ store API
    def put(self, key: str, nbytes: float, value: Any = None) -> None:
        path = self._spill(value)
        with self._cv:
            prev = self._objects.get(key)
            if prev is not None:
                # overwrite frees the old object: count the implicit delete
                # (and its spill file) so drain accounting stays conserved
                self._live_bytes -= prev.nbytes
                self.stats.count_delete(key, prev.nbytes)
                if prev.path is not None:
                    try:
                        os.remove(prev.path)
                    except OSError:
                        pass
            obj = _Stored(nbytes=float(nbytes),
                          value=None if path is not None else value, path=path)
            self._objects[key] = obj
            self._live_bytes += obj.nbytes
            self.stats.count_put(key, obj.nbytes, self._live_bytes)
            self._cv.notify_all()

    def _wait_for(self, key: str) -> _Stored:
        deadline = time.monotonic() + self.timeout
        producer = producer_worker_of_key(key)
        while True:
            if self._poison is not None:
                raise StoreAbortedError(
                    f"store aborted while waiting for {key!r}: "
                    f"{self._poison}") from self._poison
            if key in self._objects:
                return self._objects[key]
            if producer is not None:
                if producer in self._dead:
                    raise ProducerDeadError(
                        f"object {key!r} will never arrive: its producer "
                        f"worker (stage {producer[0]}, replica "
                        f"{producer[1]}) died")
                beat = self._heartbeats.get(producer)
                if (beat is not None
                        and time.monotonic() - beat > self.lease_timeout):
                    raise ProducerDeadError(
                        f"object {key!r} will never arrive: its producer "
                        f"worker (stage {producer[0]}, replica "
                        f"{producer[1]}) stopped heartbeating "
                        f"{time.monotonic() - beat:.1f}s ago (lease "
                        f"timeout {self.lease_timeout:.0f}s)")
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError(self._diagnose_timeout_locked(key))
            # woken early by put/abort/mark_dead; the poll interval only
            # bounds how late a *silently* stale heartbeat is noticed
            self._cv.wait(min(remaining, self.lease_timeout / 4.0, 0.25))

    def _diagnose_timeout_locked(self, key: str) -> str:
        """Rich get-timeout message (caller holds the lock): the missing
        key, which keys *do* exist, who held the producer lease, and how
        stale its heartbeat is — a statement, not a guess."""
        producer = producer_worker_of_key(key)
        existing = sorted(self._objects)
        sample = ", ".join(existing[:8]) if existing else "none"
        if producer is None:
            who = producer_of_key(key)
            lease = f"no producer lease on record ({who})"
        else:
            age = None
            beat = self._heartbeats.get(producer)
            if beat is not None:
                age = time.monotonic() - beat
            state = ("marked dead" if producer in self._dead
                     else f"last heartbeat {age:.1f}s ago" if age is not None
                     else "never heartbeat")
            lease = (f"producer lease held by worker (stage {producer[0]}, "
                     f"replica {producer[1]}) — {state}")
        return (f"object {key!r} never became visible within "
                f"{self.timeout:.0f}s; {lease}; "
                f"{len(existing)} keys present (e.g. [{sample}])")

    def get(self, key: str, return_nbytes: bool = False) -> Any:
        """Block until ``key`` is visible, then return its payload (or a
        ``(payload, modeled_nbytes)`` pair with ``return_nbytes=True`` —
        tracing needs the object size alongside the value)."""
        with self._cv:
            obj = self._wait_for(key)
            self.stats.count_get(key, obj.nbytes)
        value = self._load(obj)
        return (value, obj.nbytes) if return_nbytes else value

    def take(self, key: str, return_nbytes: bool = False) -> Any:
        """Blocking fetch-and-consume (get + delete, atomically)."""
        with self._cv:
            obj = self._wait_for(key)
            self.stats.count_get(key, obj.nbytes)
            value = self._load(obj)   # before delete unlinks any spill file
            self._delete_locked(key)
        return (value, obj.nbytes) if return_nbytes else value

    def delete(self, key: str) -> None:
        with self._cv:
            self._delete_locked(key)

    def _delete_locked(self, key: str) -> None:
        obj = self._objects.pop(key, None)
        if obj is not None:
            self._live_bytes -= obj.nbytes
            self.stats.count_delete(key, obj.nbytes)
            if obj.path is not None:
                try:
                    os.remove(obj.path)
                except OSError:
                    pass

    def keys(self):
        with self._cv:
            return list(self._objects)

    def __contains__(self, key: str) -> bool:
        with self._cv:
            return key in self._objects

    def __len__(self) -> int:
        with self._cv:
            return len(self._objects)

    @property
    def live_bytes(self) -> float:
        return self._live_bytes


class LocalWorkerContext(WorkerContext):
    """A stage worker on a real thread: blocking store, no modeled clock.

    With ``tracer``/``clock`` set (``repro.obs.WorkerTracer`` + seconds since
    run start), every store op and compute emits one *wall-clock* span; a
    blocking download's visibility wait is part of its span, which is exactly
    the stall the timeline should show.
    """

    def __init__(self, store: LocalStore, tracer=None, clock=None,
                 worker: Optional[Tuple[int, int]] = None):
        self.store = store
        self.tracer = tracer
        self.clock = clock
        self.worker = worker

    def _beat(self) -> None:
        if self.worker is not None:
            self.store.heartbeat(self.worker)

    def download(self, key: str):
        self._beat()
        if self.tracer is None:
            return self.store.take(key), None
        t0 = self.clock()
        value, nb = self.store.take(key, return_nbytes=True)
        self.tracer.emit("download", t0, self.clock(), nbytes=nb, key=key)
        return value, None

    def compute(self, cost_s: float, fn: Optional[Callable[[], Any]] = None,
                after: Any = None) -> Any:
        # modeled cost is the virtual clock's business; here compute is real
        self._beat()
        if self.tracer is None:
            return fn() if fn is not None else None
        t0 = self.clock()
        out = fn() if fn is not None else None
        self.tracer.emit("compute", t0, self.clock())
        return out

    def upload(self, key: str, nbytes: float, value: Any = None) -> Any:
        self._beat()
        if self.tracer is None:
            self.store.put(key, nbytes, value=value)
            return None
        t0 = self.clock()
        self.store.put(key, nbytes, value=value)
        self.tracer.emit("upload", t0, self.clock(), nbytes=nbytes, key=key)
        return None

    def phase_barrier(self) -> None:
        # a serial worker's forward uploads complete before it proceeds;
        # for tracing this is also the worker's fwd -> bwd phase flip
        self._beat()
        if self.tracer is not None:
            self.tracer.phase = "bwd"
        return None

    def wait(self, seconds: float, op: str = "retry") -> None:
        # real backoff on the wall-clock backend (the time is honest, and
        # the op span makes recovery overhead visible in the trace)
        self._beat()
        if self.tracer is None:
            time.sleep(seconds)
            return
        t0 = self.clock()
        time.sleep(seconds)
        self.tracer.emit(op, t0, self.clock())

    def fetch(self, key: str, op: str = "download"):
        # non-consuming blocking get (checkpoint restore)
        self._beat()
        if self.tracer is None:
            return self.store.get(key), None
        t0 = self.clock()
        value, nb = self.store.get(key, return_nbytes=True)
        self.tracer.emit(op, t0, self.clock(), nbytes=nb, key=key)
        return value, None


def _primary_error(errors: List[BaseException]) -> BaseException:
    """The error that *caused* a failed step, not its collateral: an
    exceeded tolerance budget must surface over the crash it wraps, a crash
    over the StoreAborted/BrokenBarrier/Timeout wreckage it strands its
    peers in."""
    def rank(e: BaseException) -> int:
        name = type(e).__name__
        if name == "FaultToleranceExceeded":
            return 0
        if name == "WorkerCrashed":
            return 1
        if name == "TransientStoreError":
            return 2
        if isinstance(e, (StoreAbortedError, ProducerDeadError,
                          threading.BrokenBarrierError, TimeoutError)):
            return 4
        return 3
    return min(errors, key=rank)


class LocalBackend(ExecutionBackend):
    """Real-concurrency substitute platform on the host."""

    name = "local"
    wall_clock = True

    def __init__(self, *, fs_root: Optional[str] = None,
                 get_timeout: float = DEFAULT_GET_TIMEOUT,
                 lease_timeout: float = DEFAULT_LEASE_TIMEOUT):
        self.fs_root = fs_root
        self.get_timeout = get_timeout
        self.lease_timeout = lease_timeout
        self.agg = None
        self.store: Optional[LocalStore] = None
        self._t0 = 0.0
        # per-(stage, replica) WorkerTracers when a recorder is attached;
        # contexts for step k are handed out after run_step(k-1) returned,
        # so _steps_done stamps each tracer's step at context creation
        self._tracers: Dict[Tuple[int, int], Any] = {}
        self._steps_done = 0

    # --------------------------------------------------------------- lifecycle
    def open(self, agg) -> None:
        if agg.S * agg.d > MAX_WORKERS:
            raise ValueError(
                f"plan spawns {agg.S}x{agg.d}={agg.S * agg.d} concurrent "
                f"workers; the local backend caps at {MAX_WORKERS} threads "
                "— replay this plan on the emulated backend instead")
        self.agg = agg
        self.store = self._make_store()
        self._tracers = {}
        self._steps_done = 0
        self._t0 = time.perf_counter()

    def _make_store(self) -> LocalStore:
        """Store-provisioning hook: cloud adapters subclass this backend and
        swap in a client-backed store with the same blocking surface."""
        return LocalStore(timeout=self.get_timeout, fs_root=self.fs_root,
                          lease_timeout=self.lease_timeout)

    def recover(self) -> int:
        """Revive the poisoned store and purge residual non-checkpoint keys
        so the engine can replay from the last checkpoint."""
        self.store.revive()
        return super().recover()

    def _clock(self) -> float:
        """Seconds since run start — the trace's wall-clock time base."""
        return time.perf_counter() - self._t0

    def context(self, s: int, r: int) -> LocalWorkerContext:
        if self.recorder is None:
            return LocalWorkerContext(self.store, worker=(s, r))
        tr = self.recorder.tracer(s, r)
        tr.step = self._steps_done
        tr.phase = "fwd"
        self._tracers[(s, r)] = tr
        return LocalWorkerContext(self.store, tracer=tr, clock=self._clock,
                                  worker=(s, r))

    @property
    def store_stats(self) -> StoreStats:
        return self.store.stats

    def _store_for_verification(self):
        return self.store

    # --------------------------------------------------------------- stepping
    def run_step(self, k: int, programs: Dict[Tuple[int, int], WorkerProgram],
                 *, pipelined_sync: bool = True) -> StepTiming:
        agg = self.agg
        S, d = agg.S, agg.d
        # the barrier timeout mirrors the store's: a peer that never arrives
        # (died worker) breaks the barrier instead of hanging the run
        barriers = ({s: threading.Barrier(d, timeout=self.get_timeout)
                     for s in range(S)} if d > 1 else {})
        sync_secs: Dict[Tuple[int, int], float] = {}
        errors: List[BaseException] = []
        err_lock = threading.Lock()

        def drive(s: int, r: int, gen: WorkerProgram) -> None:
            try:
                y = next(gen)
                while True:
                    if isinstance(y, tuple) and y[0] == "sync":
                        tr = self._tracers.get((s, r))
                        if tr is not None:
                            tr.phase = "sync"   # this worker's own tracer
                        t0 = time.perf_counter()
                        reduced = local_scatter_reduce(
                            self.store, r, d, agg.s_stage[s], y[1],
                            key_prefix=f"k{k}/sync{s}",
                            pipelined=pipelined_sync, barrier=barriers.get(s),
                            tracer=tr, clock=self._clock)
                        sync_secs[(s, r)] = time.perf_counter() - t0
                        y = gen.send(reduced)
                    else:
                        y = next(gen)
            except StopIteration:
                return
            except BaseException as e:  # propagate to the main thread
                with err_lock:
                    errors.append(e)
                # a died worker starves its peers' blocking gets *and* their
                # sync barrier: mark it dead, poison the store and break the
                # barriers so every peer fails over now, not at timeout
                self.store.mark_dead((s, r))
                self.store.abort(e)
                for b in barriers.values():
                    b.abort()

        threads = [
            threading.Thread(target=drive, args=(s, r, gen),
                             name=f"funcpipe-s{s}r{r}", daemon=True)
            for (s, r), gen in programs.items()
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise _primary_error(errors)

        sync = 0.0
        for s in range(S):
            stage = [sync_secs.get((s, r), 0.0) for r in range(d)]
            sync = max(sync, max(stage))
        self._steps_done += 1
        return StepTiming(end=time.perf_counter() - self._t0, sync=sync)
