"""Virtual-clock backend: the paper's cost model as the execution substrate.

Wraps the emulated :class:`~repro.serverless.runtime.store.ObjectStore` and
per-worker :class:`~repro.serverless.runtime.store.StageChannel` clocks
behind the :class:`ExecutionBackend` contract.  The driver advances every
worker's generator program single-threaded in the deterministic GPipe
interleave (replica-major, micro-batch, stage — the order the pre-backend
engine hard-coded), so timings, store traffic and ``StoreStats`` are
identical to the historical engine: the emulated run stays within the ~4%
bound of ``simulate_funcpipe`` that ``benchmarks/runtime_accuracy.py``
tracks.

Numerics run as fast as the host allows while the virtual clock charges what
Lambda/FC + S3/OSS would have — time here is *modeled*, never measured.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.serverless.backends.base import (
    ExecutionBackend,
    StepTiming,
    WorkerContext,
    WorkerProgram,
)
from repro.serverless.runtime.scatter_reduce import (
    pipelined_scatter_reduce,
    three_phase_scatter_reduce,
)
from repro.serverless.runtime.store import ObjectStore, StageChannel, StoreStats


class EmulatedWorkerContext(WorkerContext):
    """A stage worker bound to one virtual-clock :class:`StageChannel`."""

    def __init__(self, channel: StageChannel, store: ObjectStore):
        self.channel = channel
        self.store = store

    def download(self, key: str):
        value, end = self.channel.download(key)
        self.store.delete(key)            # single consumer: free on arrival
        return value, end

    def compute(self, cost_s: float, fn: Optional[Callable[[], Any]] = None,
                after: Any = None) -> Any:
        ready = self.channel.cpu_free if after is None else after
        self.channel.compute(cost_s, ready=ready)
        return fn() if fn is not None else None

    def upload(self, key: str, nbytes: float, value: Any = None) -> Any:
        return self.channel.upload(key, nbytes, ready=self.channel.cpu_free,
                                   value=value)

    def phase_barrier(self) -> None:
        self.channel.join_uplink_into_downlink()

    def wait(self, seconds: float, op: str = "retry") -> None:
        # retry backoff / injected straggle: the worker is blocked, so all
        # three virtual resources stall (numerics pay nothing — time here is
        # modeled, and the charge is deterministic, keeping chaos runs
        # bit-identical in time as well as in value)
        self.channel.stall(seconds, op=op)

    def fetch(self, key: str, op: str = "download"):
        # non-consuming download (checkpoint restore): charge the downlink,
        # leave the object live — every stage worker of the stage reads the
        # same checkpoint object once
        return self.channel.download(key, ready=self.channel.dn_free, op=op)


class EmulatedBackend(ExecutionBackend):
    """Today's emulated store + virtual clocks behind the backend API."""

    name = "emulated"
    wall_clock = False

    def __init__(self) -> None:
        self.agg = None
        self.store: Optional[ObjectStore] = None
        self.channels: List[List[StageChannel]] = []

    # --------------------------------------------------------------- lifecycle
    def open(self, agg) -> None:
        self.agg = agg
        self.store = ObjectStore(latency=agg.t_lat)
        self.channels = [
            [StageChannel(self.store, agg.w[s], agg.t_lat, name=f"s{s}r{r}")
             for r in range(agg.d)]
            for s in range(agg.S)
        ]
        if self.recorder is not None:
            # every charged channel task — boundary transfers, computes and
            # each scatter-reduce chunk — emits one virtual-clock span
            for s in range(agg.S):
                for r in range(agg.d):
                    self.channels[s][r].tracer = self.recorder.tracer(s, r)

    def context(self, s: int, r: int) -> EmulatedWorkerContext:
        return EmulatedWorkerContext(self.channels[s][r], self.store)

    @property
    def store_stats(self) -> StoreStats:
        return self.store.stats

    def _store_for_verification(self):
        return self.store

    # --------------------------------------------------------------- stepping
    def run_step(self, k: int, programs: Dict[Tuple[int, int], WorkerProgram],
                 *, pipelined_sync: bool = True) -> StepTiming:
        agg = self.agg
        S, mu, d = agg.S, agg.mu, agg.d
        sync_fn = (pipelined_scatter_reduce if pipelined_sync
                   else three_phase_scatter_reduce)
        rec = self.recorder
        if rec is not None:
            rec.set_step(k)
            rec.set_phase("fwd")

        # forward: one (download, compute, upload) group per advance, in the
        # replica-major GPipe interleave — producers are always issued before
        # their consumers, and StoreStats.peak_bytes sees the same live set
        # the historical engine produced
        for r in range(d):
            for m in range(mu):
                for s in range(S):
                    next(programs[(s, r)])
        # backward (the first advance also runs the worker's phase barrier)
        if rec is not None:
            rec.set_phase("bwd")
        for r in range(d):
            for _ in range(mu):
                for s in range(S - 1, -1, -1):
                    next(programs[(s, r)])

        # every program now flattens its gradient and requests the sync
        if rec is not None:
            rec.set_phase("sync")
        values: Dict[Tuple[int, int], Any] = {}
        for s in range(S):
            for r in range(d):
                tag, vec = next(programs[(s, r)])
                assert tag == "sync", tag
                values[(s, r)] = vec

        step_end = 0.0
        step_sync = 0.0
        for s in range(S):
            row = self.channels[s]
            done = [row[r].cpu_free if s == 0
                    else max(row[r].cpu_free, row[r].up_free)
                    for r in range(d)]
            vals = [values[(s, r)] for r in range(d)]
            numeric = any(v is not None for v in vals)
            if d > 1:
                reduced, ends = sync_fn(
                    self.store, row, agg.s_stage[s], done,
                    values=vals if numeric else None,
                    key_prefix=f"k{k}/sync{s}")
            else:
                reduced, ends = (vals[0] if numeric else None), done
            stage_end = max(ends)
            step_sync = max(step_sync, stage_end - max(done))
            step_end = max(step_end, stage_end)
            for r in range(d):
                row[r].release_at(ends[r])
            for r in range(d):
                try:
                    programs[(s, r)].send(reduced)
                except StopIteration:
                    pass
                else:  # pragma: no cover - program must end after the sync
                    raise RuntimeError(
                        f"worker (s={s}, r={r}) program yielded after sync")
        return StepTiming(end=step_end, sync=step_sync)
