"""Pluggable execution backends for the storage-backed runtime engine.

One :class:`ExecutionBackend` is one storage+invocation substrate a
:class:`~repro.api.DeploymentPlan` can execute on:

    emulated   virtual-clock object store + per-worker clocks — behavior-
               and cost-model-identical to the analytic stack (default)
    local      real wall-clock: S x d concurrent worker threads over a
               blocking in-memory (or filesystem) store — exercises the
               visibility/ordering races the virtual clock never hits,
               trains to bit-identical params
    aws / oss  real-platform stubs (boto3 / oss2 adapters not vendored)

Select by name anywhere a plan executes::

    plan.emulate(backend="local")
    session(...).emulate(backend="local")
    python -m repro emulate plan.json --backend local

Third-party backends register with :func:`register_backend`.
"""
from __future__ import annotations

from typing import Callable, Dict, Union

from repro.serverless.backends.base import (  # noqa: F401
    ExecutionBackend,
    StepTiming,
    WorkerContext,
)
from repro.serverless.backends.cloud import (  # noqa: F401
    AliyunOssBackend,
    AwsS3Backend,
    BackendUnavailableError,
)
from repro.serverless.backends.emulated import (  # noqa: F401
    EmulatedBackend,
    EmulatedWorkerContext,
)
from repro.serverless.backends.local import (  # noqa: F401
    LocalBackend,
    LocalStore,
    LocalWorkerContext,
)

_REGISTRY: Dict[str, Callable[[], ExecutionBackend]] = {}


def register_backend(name: str,
                     factory: Callable[[], ExecutionBackend]) -> None:
    """Register a backend factory under ``name`` (overwrites allowed, so a
    real adapter can shadow a stub)."""
    _REGISTRY[name] = factory


def available_backends() -> tuple:
    """Registered backend names, stable order."""
    return tuple(sorted(_REGISTRY))


def get_backend(spec: Union[str, ExecutionBackend]) -> ExecutionBackend:
    """Resolve a backend: an instance passes through (pre-configured
    backends, e.g. ``LocalBackend(fs_root=...)``); a name constructs a fresh
    instance from the registry."""
    if isinstance(spec, ExecutionBackend):
        return spec
    try:
        factory = _REGISTRY[spec]
    except (KeyError, TypeError):
        raise KeyError(
            f"unknown execution backend {spec!r}; available: "
            f"{', '.join(available_backends())}") from None
    return factory()


register_backend("emulated", EmulatedBackend)
register_backend("local", LocalBackend)
register_backend("aws", AwsS3Backend)
register_backend("oss", AliyunOssBackend)
