"""Pluggable execution backends for the storage-backed runtime engine.

One :class:`ExecutionBackend` is one storage+invocation substrate a
:class:`~repro.api.DeploymentPlan` can execute on:

    emulated   virtual-clock object store + per-worker clocks — behavior-
               and cost-model-identical to the analytic stack (default)
    local      real wall-clock: S x d concurrent worker threads over a
               blocking in-memory (or filesystem) store — exercises the
               visibility/ordering races the virtual clock never hits,
               trains to bit-identical params
    aws / oss  real-platform stubs (boto3 / oss2 adapters not vendored)

Select by name anywhere a plan executes::

    plan.emulate(backend="local")
    session(...).emulate(backend="local")
    python -m repro emulate plan.json --backend local

Third-party backends register with :func:`register_backend`.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Union

from repro.serverless.backends.base import (  # noqa: F401
    ExecutionBackend,
    StepTiming,
    WorkerContext,
)
from repro.serverless.backends.cloud import (  # noqa: F401
    AliyunOssBackend,
    AwsS3Backend,
    BackendUnavailableError,
)
from repro.serverless.backends.emulated import (  # noqa: F401
    EmulatedBackend,
    EmulatedWorkerContext,
)
from repro.serverless.backends.local import (  # noqa: F401
    LocalBackend,
    LocalStore,
    LocalWorkerContext,
)
from repro.serverless.backends.process import (  # noqa: F401
    ProcessBackend,
    ProcessWorkerHandle,
)

_REGISTRY: Dict[str, Callable[[], ExecutionBackend]] = {}


def register_backend(name: str,
                     factory: Callable[[], ExecutionBackend]) -> None:
    """Register a backend factory under ``name`` (overwrites allowed, so a
    real adapter can shadow a stub)."""
    _REGISTRY[name] = factory


def available_backends() -> tuple:
    """Registered backend names, stable order."""
    return tuple(sorted(_REGISTRY))


def _availability_of(name: str) -> Optional[str]:
    """None when backend ``name`` should work on this host; otherwise a short
    reason it will fail at open (missing client lib, no POSIX locks, ...)."""
    import importlib.util
    import os

    if name == "process":
        if os.name != "posix":
            return "needs POSIX file locks + signals"
        if importlib.util.find_spec("fcntl") is None:  # pragma: no cover
            return "fcntl module missing"
        return None
    client = {"aws": "boto3", "oss": "oss2"}.get(name)
    if client is not None and importlib.util.find_spec(client) is None:
        return f"{client} not installed"
    return None


def backend_availability() -> Dict[str, Optional[str]]:
    """Registered backend name -> None (available on this host) or a short
    reason it is not (used by backend-selection error messages and the CLI's
    ``--backend`` help)."""
    return {name: _availability_of(name) for name in available_backends()}


def _describe_backends() -> str:
    parts = []
    for name, why in backend_availability().items():
        parts.append(name if why is None else f"{name} (unavailable: {why})")
    return ", ".join(parts)


def get_backend(spec: Union[str, ExecutionBackend]) -> ExecutionBackend:
    """Resolve a backend: an instance passes through (pre-configured
    backends, e.g. ``LocalBackend(fs_root=...)``); a name constructs a fresh
    instance from the registry."""
    if isinstance(spec, ExecutionBackend):
        return spec
    try:
        factory = _REGISTRY[spec]
    except (KeyError, TypeError):
        raise KeyError(
            f"unknown execution backend {spec!r}; available: "
            f"{_describe_backends()}") from None
    return factory()


register_backend("emulated", EmulatedBackend)
register_backend("local", LocalBackend)
register_backend("process", ProcessBackend)
register_backend("aws", AwsS3Backend)
register_backend("oss", AliyunOssBackend)
