"""Spawn-safe worker entrypoint + process-grade file store for the
``process`` backend.

This module is what runs *inside* each of the ``S x d`` worker OS processes
(``multiprocessing`` spawn target), plus the storage substrate they share:

* :class:`FileStore` — the process-grade :class:`~repro.serverless.backends.
  local.LocalStore`: a directory of object files with fcntl-file-lock atomic
  put/get/take/delete, a shared ``stats.json`` accounting file maintained
  through the same :class:`~repro.serverless.runtime.store.StoreStats`
  methods every other store uses, and *mtime-based* producer heartbeats and
  leases — a SIGKILL'd producer's heartbeat file freezes, so its consumers
  raise :class:`ProducerDeadError` instead of burning the get timeout, and
  ``abort()`` poisons the store through a file every process sees.
* :class:`FileBarrier` — a ``threading.Barrier`` lookalike over marker files
  (``wait()`` only), generation-counted so the eq (1) collective's three
  phase fences line up across processes; poisoned stores break it.
* :func:`worker_main` — the child process: builds its
  :class:`~repro.serverless.runtime.worker.StageWorker`, heartbeats from a
  daemon thread, and serves step/export/load/reset commands over a pipe,
  driving the engine's own ``_worker_step_program`` generator locally
  (generators cannot cross a process boundary, so the program runs where
  the state lives).  Injected crashes are *real*: the worker marks itself
  dead, poisons the store, flushes a dying message and SIGKILLs its own
  process; lifetime-cap kills exit with :data:`EXIT_LIFETIME` so the parent
  can tell a planned platform recycle from a crash.

Payload-true mode charges each transfer the *real* payload size
(``np.ndarray.nbytes`` / ``len(blob)``) instead of the modeled one, and the
optional per-worker bandwidth throttle sleeps ``nbytes / bandwidth + t_lat``
per transfer — together they give wall-clock traces a calibrated time axis.

Crash-consistency note: fault-injected kills fire at op *boundaries* (the
injector raises before delegating to the store), so the lock-protected
object+accounting updates are never torn by an injected SIGKILL.
"""
from __future__ import annotations

import contextlib
import json
import os
import pickle
import struct
import threading
import time
from typing import Any, Dict, Optional, Tuple

try:
    import fcntl
except ImportError:                      # non-POSIX host
    fcntl = None

from repro.serverless.runtime.store import (
    ProducerDeadError,
    StoreAbortedError,
    StoreStats,
    producer_of_key,
    producer_worker_of_key,
)

#: planned process exit code for a function-lifetime-cap kill (vs SIGKILL
#: for a crash): the parent's Function Manager relaunch telling them apart
EXIT_LIFETIME = 43

#: object-file header: little-endian float64 charged nbytes + payload flag
_HEADER = struct.Struct("<d")


def _true_payload_nbytes(value: Any, blob: bytes) -> float:
    """Real transfer size of ``value``: array ``nbytes`` when it has one,
    raw length for bytes-likes, else the pickled wire size."""
    nb = getattr(value, "nbytes", None)
    if nb is not None:
        return float(nb)
    if isinstance(value, (bytes, bytearray, memoryview)):
        return float(len(value))
    return float(len(blob))


class FileStore:
    """Cross-process key -> object namespace with blocking visibility.

    API-compatible with :class:`~repro.serverless.backends.local.LocalStore`
    (so ``LocalWorkerContext`` and ``local_scatter_reduce`` run over it
    unchanged): ``put`` publishes atomically (tmp file + ``os.replace``
    under a global file lock), ``get``/``take`` poll for the object file,
    failing over on a dead/poisoned producer; accounting lives in one shared
    ``stats.json`` updated through :class:`StoreStats` under the same lock.

    Liveness is filesystem truth, not thread state: ``heartbeat`` touches a
    per-worker file's mtime, so a SIGKILL'd worker's lease goes stale by
    itself; ``mark_dead`` drops a marker file; ``abort`` writes a poison
    file every blocked consumer in every process notices on its next poll.
    """

    def __init__(self, root: str, timeout: float = 120.0,
                 lease_timeout: float = 20.0, payload_true: bool = False,
                 bandwidth: Optional[float] = None, t_lat: float = 0.0):
        if fcntl is None:
            raise RuntimeError(
                "FileStore needs POSIX file locks (fcntl); the process "
                "backend is unavailable on this host")
        self.root = root
        self.timeout = timeout
        self.lease_timeout = lease_timeout
        self.payload_true = payload_true
        self.bandwidth = bandwidth      # bytes/s uplink+downlink throttle
        self.t_lat = t_lat              # per-request round-trip, throttled
        self._objects = os.path.join(root, "objects")
        self._tmp = os.path.join(root, "tmp")
        self._hb = os.path.join(root, "hb")
        self._dead = os.path.join(root, "dead")
        self.barriers_root = os.path.join(root, "barriers")
        self._lock_path = os.path.join(root, "lock")
        self._stats_path = os.path.join(root, "stats.json")
        self._poison_path = os.path.join(root, "poison")
        self._seq = 0
        for d in (self._objects, self._tmp, self._hb, self._dead,
                  self.barriers_root):
            os.makedirs(d, exist_ok=True)
        with self._locked():
            if not os.path.exists(self._stats_path):
                self._dump_acct(StoreStats(), 0.0)

    # ---------------------------------------------------------------- locking
    @contextlib.contextmanager
    def _locked(self):
        fd = os.open(self._lock_path, os.O_RDWR | os.O_CREAT, 0o644)
        try:
            fcntl.flock(fd, fcntl.LOCK_EX)
            yield
        finally:
            os.close(fd)        # close releases the flock, even on SIGKILL

    # ------------------------------------------------------------- accounting
    def _load_acct(self) -> Tuple[StoreStats, float]:
        with open(self._stats_path) as f:
            d = json.load(f)
        live = d.pop("live_bytes", 0.0)
        return StoreStats(**d), live

    def _dump_acct(self, stats: StoreStats, live: float) -> None:
        d = stats.as_dict()
        d["live_bytes"] = live
        tmp = self._tmp_path()
        with open(tmp, "w") as f:
            json.dump(d, f)
        os.replace(tmp, self._stats_path)

    @property
    def stats(self) -> StoreStats:
        with self._locked():
            return self._load_acct()[0]

    @property
    def live_bytes(self) -> float:
        with self._locked():
            return self._load_acct()[1]

    # ------------------------------------------------------------------ paths
    def _obj_path(self, key: str) -> str:
        return os.path.join(self._objects, *key.split("/"))

    def _tmp_path(self) -> str:
        self._seq += 1
        return os.path.join(
            self._tmp, f"t{os.getpid()}-{threading.get_ident()}-{self._seq}")

    def _hb_path(self, worker: Tuple[int, int]) -> str:
        return os.path.join(self._hb, f"s{worker[0]}r{worker[1]}")

    def _dead_path(self, worker: Tuple[int, int]) -> str:
        return os.path.join(self._dead, f"s{worker[0]}r{worker[1]}")

    @staticmethod
    def _read_header(path: str) -> Optional[float]:
        try:
            with open(path, "rb") as f:
                return _HEADER.unpack(f.read(_HEADER.size))[0]
        except (OSError, struct.error):
            return None

    # ------------------------------------------------------ liveness / leases
    def heartbeat(self, worker: Tuple[int, int]) -> None:
        path = self._hb_path(worker)
        try:
            os.utime(path)
        except OSError:
            with open(path, "a"):
                pass

    def mark_dead(self, worker: Tuple[int, int]) -> None:
        with open(self._dead_path(worker), "a"):
            pass

    def heartbeat_age(self, worker: Tuple[int, int]) -> Optional[float]:
        try:
            return time.time() - os.stat(self._hb_path(worker)).st_mtime
        except OSError:
            return None

    def _poison_text(self) -> Optional[str]:
        try:
            with open(self._poison_path) as f:
                return f.read()
        except OSError:
            return None

    def abort(self, reason: BaseException) -> None:
        # first poison wins (matches LocalStore): collateral errors from
        # peers failing over must not overwrite the originating crash
        try:
            fd = os.open(self._poison_path,
                         os.O_WRONLY | os.O_CREAT | os.O_EXCL, 0o644)
        except FileExistsError:
            return
        with os.fdopen(fd, "w") as f:
            f.write(f"{type(reason).__name__}: {reason}")

    def revive(self) -> None:
        try:
            os.remove(self._poison_path)
        except OSError:
            pass
        for d in (self._dead, self._hb):
            for fn in os.listdir(d):
                try:
                    os.remove(os.path.join(d, fn))
                except OSError:
                    pass

    # --------------------------------------------------------------- throttle
    def _throttle(self, nbytes: float) -> None:
        if self.bandwidth:
            time.sleep(nbytes / self.bandwidth + self.t_lat)

    # -------------------------------------------------------------- store API
    def put(self, key: str, nbytes: float, value: Any = None) -> None:
        blob = None
        if value is not None:
            blob = pickle.dumps(value, protocol=pickle.HIGHEST_PROTOCOL)
            if self.payload_true:
                nbytes = _true_payload_nbytes(value, blob)
        nbytes = float(nbytes)
        self._throttle(nbytes)          # uplink: transfer precedes visibility
        tmp = self._tmp_path()
        with open(tmp, "wb") as f:
            f.write(_HEADER.pack(nbytes))
            if blob is None:
                f.write(b"\x00")
            else:
                f.write(b"\x01")
                f.write(blob)
        path = self._obj_path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with self._locked():
            stats, live = self._load_acct()
            prev = self._read_header(path)
            if prev is not None:
                # overwrite frees the old object: count the implicit delete
                live -= prev
                stats.count_delete(key, prev)
            os.replace(tmp, path)
            live += nbytes
            stats.count_put(key, nbytes, live)
            self._dump_acct(stats, live)

    def _wait_for(self, key: str) -> str:
        deadline = time.monotonic() + self.timeout
        producer = producer_worker_of_key(key)
        path = self._obj_path(key)
        poll = min(0.01, self.lease_timeout / 4.0)
        while True:
            poison = self._poison_text()
            if poison is not None:
                raise StoreAbortedError(
                    f"store aborted while waiting for {key!r}: {poison}")
            if os.path.exists(path):
                return path
            if producer is not None:
                if os.path.exists(self._dead_path(producer)):
                    raise ProducerDeadError(
                        f"object {key!r} will never arrive: its producer "
                        f"worker (stage {producer[0]}, replica "
                        f"{producer[1]}) died")
                age = self.heartbeat_age(producer)
                if age is not None and age > self.lease_timeout:
                    raise ProducerDeadError(
                        f"object {key!r} will never arrive: its producer "
                        f"worker (stage {producer[0]}, replica "
                        f"{producer[1]}) stopped heartbeating "
                        f"{age:.1f}s ago (lease timeout "
                        f"{self.lease_timeout:.0f}s)")
            if time.monotonic() > deadline:
                raise TimeoutError(self._diagnose_timeout(key))
            time.sleep(poll)

    def _diagnose_timeout(self, key: str) -> str:
        producer = producer_worker_of_key(key)
        existing = sorted(self.keys())
        sample = ", ".join(existing[:8]) if existing else "none"
        if producer is None:
            lease = f"no producer lease on record ({producer_of_key(key)})"
        else:
            age = self.heartbeat_age(producer)
            state = ("marked dead"
                     if os.path.exists(self._dead_path(producer))
                     else f"last heartbeat {age:.1f}s ago" if age is not None
                     else "never heartbeat")
            lease = (f"producer lease held by worker (stage {producer[0]}, "
                     f"replica {producer[1]}) — {state}")
        return (f"object {key!r} never became visible within "
                f"{self.timeout:.0f}s; {lease}; "
                f"{len(existing)} keys present (e.g. [{sample}])")

    def _read_obj(self, path: str) -> Tuple[float, Optional[bytes]]:
        with open(path, "rb") as f:
            nbytes = _HEADER.unpack(f.read(_HEADER.size))[0]
            flag = f.read(1)
            blob = f.read() if flag == b"\x01" else None
        return nbytes, blob

    def get(self, key: str, return_nbytes: bool = False) -> Any:
        path = self._obj_path(key)
        while True:
            self._wait_for(key)
            with self._locked():
                if not os.path.exists(path):
                    continue            # consumed between poll and lock
                nbytes, blob = self._read_obj(path)
                stats, live = self._load_acct()
                stats.count_get(key, nbytes)
                self._dump_acct(stats, live)
            break
        self._throttle(nbytes)          # downlink
        value = None if blob is None else pickle.loads(blob)
        return (value, nbytes) if return_nbytes else value

    def take(self, key: str, return_nbytes: bool = False) -> Any:
        path = self._obj_path(key)
        while True:
            self._wait_for(key)
            with self._locked():
                if not os.path.exists(path):
                    continue
                nbytes, blob = self._read_obj(path)
                os.remove(path)
                stats, live = self._load_acct()
                stats.count_get(key, nbytes)
                live -= nbytes
                stats.count_delete(key, nbytes)
                self._dump_acct(stats, live)
            break
        self._throttle(nbytes)
        value = None if blob is None else pickle.loads(blob)
        return (value, nbytes) if return_nbytes else value

    def delete(self, key: str) -> None:
        path = self._obj_path(key)
        with self._locked():
            nbytes = self._read_header(path)
            if nbytes is None:
                return
            os.remove(path)
            stats, live = self._load_acct()
            live -= nbytes
            stats.count_delete(key, nbytes)
            self._dump_acct(stats, live)

    def keys(self):
        out = []
        for dirpath, _dirs, files in os.walk(self._objects):
            rel = os.path.relpath(dirpath, self._objects)
            for fn in files:
                out.append(fn if rel == "."
                           else f"{rel}/{fn}".replace(os.sep, "/"))
        return out

    def __contains__(self, key: str) -> bool:
        return os.path.exists(self._obj_path(key))

    def __len__(self) -> int:
        return len(self.keys())


class FileBarrier:
    """``threading.Barrier``-shaped rendezvous over marker files: party
    ``index`` of ``parties`` drops ``g{generation}/r{index}`` and polls until
    all parties arrived.  The generation counter advances per ``wait()``
    call, which is what keeps the eq (1) collective's successive fences
    distinct across processes.  A poisoned store (peer died) breaks the
    barrier with :class:`threading.BrokenBarrierError` — the same
    recoverable type the thread backend's aborted barriers raise."""

    def __init__(self, store: FileStore, name: str, parties: int, index: int,
                 timeout: float):
        self.store = store
        self.dir = os.path.join(store.barriers_root, name)
        self.parties = parties
        self.index = index
        self.timeout = timeout
        self._generation = 0

    def wait(self) -> None:
        gen_dir = os.path.join(self.dir, f"g{self._generation}")
        self._generation += 1
        os.makedirs(gen_dir, exist_ok=True)
        with open(os.path.join(gen_dir, f"r{self.index}"), "a"):
            pass
        deadline = time.monotonic() + self.timeout
        while True:
            if self.store._poison_text() is not None:
                raise threading.BrokenBarrierError
            try:
                if len(os.listdir(gen_dir)) >= self.parties:
                    return
            except OSError:             # purged under us by recover()
                raise threading.BrokenBarrierError from None
            if time.monotonic() > deadline:
                raise threading.BrokenBarrierError
            time.sleep(0.005)


# =========================================================== child entrypoint
def _np_tree(tree):
    import jax
    import numpy as np

    return jax.tree.map(np.asarray, tree)


def _fault_delta(state, report) -> Optional[dict]:
    """The step's fault-consumption state, shipped back so the parent keeps
    the authoritative once-only schedule across workers and replays."""
    out: Dict[str, Any] = {}
    if state is not None:
        out["remaining"] = dict(state.remaining)
        out["fired"] = sorted(state.fired)
    if report is not None:
        out["retries"] = report.retries
        out["recovery_s"] = report.recovery_s
    return out or None


def _run_step(conn, store: FileStore, s: int, r: int, agg, worker, cmd,
              t0: float) -> None:
    """Drive one training step's program locally; reply ok / error / dying."""
    import os as _os
    import signal

    from repro.serverless import faults as F
    from repro.serverless.backends.local import LocalWorkerContext
    from repro.serverless.runtime.engine import _worker_step_program
    from repro.serverless.runtime.scatter_reduce import local_scatter_reduce

    k = cmd["k"]
    d = agg.d
    spans: list = []
    tracer = None
    clock = None
    if cmd["trace"]:
        from repro.obs.schema import WorkerTracer

        tracer = WorkerTracer(spans, s, r)
        tracer.step = cmd["trace_step"]
        tracer.phase = "fwd"
        clock = lambda: time.monotonic() - t0          # noqa: E731

    ctx = LocalWorkerContext(store, tracer=tracer, clock=clock, worker=(s, r))
    fault_state = None
    if cmd.get("fault") is not None:
        fp = cmd["fault"]
        plan = F.FaultPlan(
            events=tuple(F.FaultEvent.from_dict(e) for e in fp["events"]),
            lifetime_steps=fp["lifetime_steps"])
        fault_state = F._PlanState(plan, None)   # parent owns the report
        fault_state.remaining = {int(i): n
                                 for i, n in fp["remaining"].items()}
        fault_state.fired = set(fp["fired"])

        class _InjectorShim:
            """What FaultyWorkerContext reads off its injector, mirrored
            from the parent's FaultInjector for this one step."""

        shim = _InjectorShim()
        shim.plan = plan
        shim.current_step = k
        shim.age = fp["age"]
        shim._lifetime_noted = True      # the parent counts "lifetime"
        ctx = F.FaultyWorkerContext(ctx, fault_state, s, r, shim)
    report = None
    if cmd.get("retry") is not None:
        report = F.FaultReport()
        ctx = F.ResilientContext(ctx, cmd["retry"], report)

    barrier = (FileBarrier(store, f"k{k}-s{s}", d, r, store.timeout)
               if d > 1 else None)
    losses: Dict = {}
    sync_s = 0.0
    gen = _worker_step_program(ctx, k=k, s=s, r=r, agg=agg, worker=worker,
                               batch=cmd["batch"], losses=losses)
    try:
        y = next(gen)
        while True:
            if isinstance(y, tuple) and y[0] == "sync":
                if tracer is not None:
                    tracer.phase = "sync"
                ts = time.monotonic()
                reduced = local_scatter_reduce(
                    store, r, d, agg.s_stage[s], y[1],
                    key_prefix=f"k{k}/sync{s}",
                    pipelined=cmd["pipelined"], barrier=barrier,
                    tracer=tracer, clock=clock)
                sync_s = time.monotonic() - ts
                y = gen.send(reduced)
            else:
                y = next(gen)
    except StopIteration:
        conn.send({"ok": True, "sync_s": sync_s, "loss": losses.get((s, r)),
                   "spans": spans, "fault": _fault_delta(fault_state, report)})
    except F.WorkerCrashed as e:
        # a real function death: poison the substrate so peers fail over,
        # flush the dying report (the kernel buffers it past our death),
        # then actually die — SIGKILL for a crash, a planned exit code for
        # the lifetime cap so the parent relaunches instead of blaming us
        store.mark_dead((s, r))
        store.abort(e)
        conn.send({"dying": {"kind": e.kind, "msg": str(e), "step": k,
                             "spans": spans,
                             "fault": _fault_delta(fault_state, report)}})
        if e.kind == "lifetime":
            _os._exit(EXIT_LIFETIME)
        _os.kill(_os.getpid(), signal.SIGKILL)
    except BaseException as e:  # noqa: BLE001 - shipped to the parent
        store.mark_dead((s, r))
        store.abort(e)
        conn.send({"error": {"type": type(e).__name__, "msg": str(e),
                             "step": k, "spans": spans, "sync_s": sync_s,
                             "fault": _fault_delta(fault_state, report)}})
        # stay alive: the parent's recover() revives the store and this
        # worker serves the replay (its jit caches survive the recovery)


def _run_serve(conn, store: FileStore, s: int, r: int, cmd,
               t0: float) -> None:
    """Drive one serving request's stage program locally (``repro.serving``):
    build the stage's ServeStageWorker from the shipped spec and run its
    prefill + decode program to completion over the shared store — the
    blocking ``take``\\ s synchronize the pipeline, no barriers needed.  The
    head stage replies with the greedy-token sink."""
    from repro.serverless.backends.local import LocalWorkerContext

    tr_spans: list = []
    tracer = None
    clock = None
    if cmd["trace"]:
        from repro.obs.schema import WorkerTracer

        tracer = WorkerTracer(tr_spans, s, r)
        tracer.step = cmd["trace_step"]
        tracer.phase = "prefill"
        clock = lambda: time.monotonic() - t0          # noqa: E731

    ctx = LocalWorkerContext(store, tracer=tracer, clock=clock, worker=(s, r))
    try:
        from repro.serverless.runtime.worker import stage_instance_ranges
        from repro.serving.engine import serve_worker_program
        from repro.serving.worker import ServeStageWorker

        spec = cmd["spec"]
        ranges = stage_instance_ranges(spec["cfg"], spec["x"])
        S = len(ranges)
        sworker = ServeStageWorker(spec["cfg"], ranges[s], spec["params"],
                                   s_ctx=spec["s_ctx"],
                                   use_pallas=spec["use_pallas"])
        sink: list = []

        def on_decode() -> None:
            if tracer is not None:
                tracer.phase = "decode"

        gen = serve_worker_program(
            ctx, s=s, S=S, worker=sworker, toks=spec["toks"],
            n_new=spec["n_new"], sink=sink, on_decode=on_decode)
        for _ in gen:
            pass
        conn.send({"ok": True, "spans": tr_spans,
                   "tokens": sink if s == S - 1 else None})
    except BaseException as e:  # noqa: BLE001 - shipped to the parent
        store.mark_dead((s, r))
        store.abort(e)
        conn.send({"error": {"type": type(e).__name__, "msg": str(e),
                             "spans": tr_spans}})


def worker_main(conn, init: dict) -> None:
    """Child-process entrypoint (``multiprocessing`` spawn target): build
    the stage worker, start heartbeating, then serve commands until told to
    exit (or until an injected fault kills the process for real)."""
    s, r = init["s"], init["r"]
    store = FileStore(
        init["root"], timeout=init["get_timeout"],
        lease_timeout=init["lease_timeout"],
        payload_true=init["payload_true"],
        bandwidth=init["bandwidth"], t_lat=init["t_lat"])

    worker = None
    initial_state = None
    if init["exec_spec"] is not None:
        from repro.serverless.runtime.worker import (
            StageWorker,
            stage_instance_ranges,
        )

        es = init["exec_spec"]
        spans = stage_instance_ranges(es["cfg"], es["x"])
        worker = StageWorker(es["cfg"], spans[s], es["init_params"],
                             mu=es["mu"], optimizer=es["optimizer"],
                             jit=es["jit"], remat=es["remat"])
        # cheap in-process reset snapshot: load_state keeps jit caches warm
        initial_state = _np_tree(worker.export_state())

    # liveness from a daemon thread, not op progress: a long jit compile
    # must not look like death; a SIGKILL stops the thread with the process,
    # freezing the mtime — which is exactly the lease going stale
    stop = threading.Event()

    def beat() -> None:
        while not stop.is_set():
            store.heartbeat((s, r))
            stop.wait(init["lease_timeout"] / 4.0)

    threading.Thread(target=beat, daemon=True,
                     name=f"heartbeat-s{s}r{r}").start()
    store.heartbeat((s, r))
    conn.send({"ready": [s, r]})

    while True:
        try:
            cmd = conn.recv()
        except EOFError:        # parent went away; nothing left to serve
            return
        op = cmd["op"]
        if op == "exit":
            return
        if op == "step":
            _run_step(conn, store, s, r, init["agg"], worker, cmd,
                      init["t0"])
        elif op == "serve":
            _run_serve(conn, store, s, r, cmd, init["t0"])
        elif op == "export_state":
            conn.send({"state": _np_tree(worker.export_state())})
        elif op == "load_state":
            worker.load_state(cmd["state"])
            conn.send({"ok": True})
        elif op == "reset":
            worker.load_state(initial_state)
            conn.send({"ok": True})
        else:  # pragma: no cover - protocol error
            conn.send({"error": {"type": "ValueError",
                                 "msg": f"unknown worker op {op!r}",
                                 "spans": [], "fault": None}})
