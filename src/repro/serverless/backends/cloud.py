"""Real-platform backend stubs: AWS Lambda + S3 and Alibaba FC + OSS.

The :class:`ExecutionBackend` contract is everything a real platform needs
to implement — an object-store client (`put`/`get`/`delete` with the
platform's visibility semantics) plus a function-invocation surface for the
``S x d`` stage workers.  The clients themselves (``boto3`` / ``oss2``) are
not vendored here; these stubs register the names, carry the real config
surface (:class:`CloudConfig` — bucket, region, timeouts, credential env
vars, and the same :class:`~repro.serverless.faults.RetryPolicy` the fault-
tolerance layer uses), and fail *at open time* with an actionable message,
so ``get_backend("aws")`` is a valid call today and a drop-in implementation
tomorrow — no solver, driver or CLI change needed when the real clients
land.

The fault layer is the acceptance harness for those adapters: a real S3/OSS
run faces exactly the transient-error/crash/lifetime behaviors
``FaultInjector`` injects locally, and the adapters inherit the engine's
recovery machinery (retries per ``CloudConfig.retry``, checkpoint/restart
via the Function Manager) for free.
"""
from __future__ import annotations

import importlib.util
import os
from dataclasses import dataclass
from typing import Optional, Tuple

from repro.serverless.backends.base import ExecutionBackend
from repro.serverless.retry import RetryPolicy


@dataclass(frozen=True)
class CloudConfig:
    """Configuration a real cloud adapter needs — shared with the fault
    layer so chaos tests and real runs speak the same retry language.

    ``credential_env`` names the environment variables the adapter reads
    (never stores): an ``open()`` with missing credentials should fail with
    the variable names, not a client stack trace.
    """

    bucket: str = ""
    region: Optional[str] = None
    endpoint: Optional[str] = None        # OSS/S3-compatible endpoint URL
    key_prefix: str = "funcpipe/"         # namespace within the bucket
    retry: RetryPolicy = RetryPolicy()    # transient-error backoff (shared
    #                                       with the engine's fault layer)
    connect_timeout_s: float = 5.0
    read_timeout_s: float = 60.0
    invoke_timeout_s: float = 900.0       # function-lifetime cap (Lambda: 15m)
    credential_env: Tuple[str, ...] = ()

    def missing_credentials(self) -> Tuple[str, ...]:
        """Which of the required credential env vars are unset."""
        return tuple(v for v in self.credential_env if not os.environ.get(v))


AWS_CLOUD_CONFIG = CloudConfig(
    region="us-east-1",
    credential_env=("AWS_ACCESS_KEY_ID", "AWS_SECRET_ACCESS_KEY"),
)

OSS_CLOUD_CONFIG = CloudConfig(
    endpoint="https://oss-cn-hangzhou.aliyuncs.com",
    credential_env=("OSS_ACCESS_KEY_ID", "OSS_ACCESS_KEY_SECRET"),
)


class BackendUnavailableError(NotImplementedError):
    """A registered backend name whose implementation is not present in this
    environment (cloud stubs).  Subclasses NotImplementedError so generic
    callers still recognize it, while the CLI can catch this type alone
    without masking genuine NotImplementedError bugs."""


class _CloudStub(ExecutionBackend):
    """Shared stub behavior: name the missing client, fail on open()."""

    wall_clock = True
    client_module = "?"
    platform_blurb = "?"
    extra = "?"                    # pip extra that would pull the client in
    default_config: CloudConfig = CloudConfig()

    def __init__(self, config: Optional[CloudConfig] = None):
        self.config = config if config is not None else self.default_config

    def _unavailable(self) -> "BackendUnavailableError":
        have_client = importlib.util.find_spec(self.client_module) is not None
        if have_client:
            detail = (
                f"the {self.client_module!r} client is importable but the "
                f"{self.name} backend's store/invoke adapters are not "
                "implemented yet")
        else:
            detail = (
                f"requires the {self.client_module!r} client — "
                f"`pip install repro[{self.extra}]` (or `pip install "
                f"{self.client_module}`) to pull it in")
        missing = self.config.missing_credentials()
        cred = ""
        if missing:
            cred = (f"  Credentials: set {', '.join(missing)} before "
                    "opening this backend.")
        return BackendUnavailableError(
            f"backend {self.name!r} ({self.platform_blurb}) is a stub: "
            f"{detail}.{cred}  Replay the plan on 'emulated' (virtual-clock "
            "cost model) or 'local' (real concurrency on this host) "
            "instead; the same DeploymentPlan JSON will drive the real "
            "backend unchanged once it lands.")

    def open(self, agg) -> None:
        raise self._unavailable()

    def context(self, s: int, r: int):  # pragma: no cover - open() raises
        raise self._unavailable()

    def run_step(self, k, programs, *, pipelined_sync=True):  # pragma: no cover
        raise self._unavailable()

    @property
    def store_stats(self):  # pragma: no cover - open() raises first
        raise self._unavailable()

    def _store_for_verification(self):  # pragma: no cover
        raise self._unavailable()


class AwsS3Backend(_CloudStub):
    """AWS Lambda workers synchronizing through S3 (paper §5.1 setup)."""

    name = "aws"
    client_module = "boto3"
    platform_blurb = "AWS Lambda + S3"
    extra = "aws"
    default_config = AWS_CLOUD_CONFIG


class AliyunOssBackend(_CloudStub):
    """Alibaba Function Compute workers synchronizing through OSS (§5.7)."""

    name = "oss"
    client_module = "oss2"
    platform_blurb = "Alibaba Function Compute + OSS"
    extra = "oss"
    default_config = OSS_CLOUD_CONFIG
