"""Real-platform backends: AWS Lambda + S3 (boto3 adapter) and Alibaba
FC + OSS (stub).

The :class:`ExecutionBackend` contract is everything a real platform needs
to implement — an object-store client (``put``/``get``/``delete`` with the
platform's visibility semantics) plus a function-invocation surface for the
``S x d`` stage workers.

The ``aws`` backend is a *real adapter* now: :class:`S3ObjectStore` speaks
the boto3 S3 client surface (``put_object``/``get_object``/``delete_object``
/``list_objects_v2``) behind the same blocking-visibility API as
:class:`~repro.serverless.backends.local.LocalStore`, with transient S3
error codes (SlowDown, InternalError, ...) retried per the
:class:`CloudConfig`'s :class:`~repro.serverless.retry.RetryPolicy`, and
:class:`AwsS3Backend` subclasses :class:`LocalBackend` so the stage workers
run concurrently on this host while every object crosses S3.  ``boto3`` is
*not* vendored: when it is missing (or credentials/bucket are not
configured) ``open()`` raises :class:`BackendUnavailableError` naming
exactly what to install or set.  The adapter is unit-tested against an
in-memory fake S3 client (``tests/test_cloud_s3.py``), so its correctness
does not depend on the package being installed.

``oss`` remains a stub carrying the real config surface; the fault layer is
the acceptance harness for both: a real S3/OSS run faces exactly the
transient-error/crash/lifetime behaviors ``FaultInjector`` injects locally,
and the adapters inherit the engine's recovery machinery (retries per
``CloudConfig.retry``, checkpoint/restart via the Function Manager).
"""
from __future__ import annotations

import importlib.util
import os
import pickle
import threading
import time
from dataclasses import dataclass
from typing import Any, Optional, Tuple

from repro.serverless.backends.base import ExecutionBackend
from repro.serverless.backends.local import (
    DEFAULT_GET_TIMEOUT,
    DEFAULT_LEASE_TIMEOUT,
    LocalBackend,
)
from repro.serverless.retry import RetryPolicy
from repro.serverless.runtime.store import (
    ProducerDeadError,
    StoreAbortedError,
    StoreStats,
    producer_of_key,
    producer_worker_of_key,
)


@dataclass(frozen=True)
class CloudConfig:
    """Configuration a real cloud adapter needs — shared with the fault
    layer so chaos tests and real runs speak the same retry language.

    ``credential_env`` names the environment variables the adapter reads
    (never stores): an ``open()`` with missing credentials should fail with
    the variable names, not a client stack trace.
    """

    bucket: str = ""
    region: Optional[str] = None
    endpoint: Optional[str] = None        # OSS/S3-compatible endpoint URL
    key_prefix: str = "funcpipe/"         # namespace within the bucket
    retry: RetryPolicy = RetryPolicy()    # transient-error backoff (shared
    #                                       with the engine's fault layer)
    connect_timeout_s: float = 5.0
    read_timeout_s: float = 60.0
    invoke_timeout_s: float = 900.0       # function-lifetime cap (Lambda: 15m)
    credential_env: Tuple[str, ...] = ()

    def missing_credentials(self) -> Tuple[str, ...]:
        """Which of the required credential env vars are unset."""
        return tuple(v for v in self.credential_env if not os.environ.get(v))


AWS_CLOUD_CONFIG = CloudConfig(
    region="us-east-1",
    credential_env=("AWS_ACCESS_KEY_ID", "AWS_SECRET_ACCESS_KEY"),
)

OSS_CLOUD_CONFIG = CloudConfig(
    endpoint="https://oss-cn-hangzhou.aliyuncs.com",
    credential_env=("OSS_ACCESS_KEY_ID", "OSS_ACCESS_KEY_SECRET"),
)


class BackendUnavailableError(NotImplementedError):
    """A registered backend name whose implementation cannot run in this
    environment (missing client library, credentials, or bucket — or a
    cloud stub).  Subclasses NotImplementedError so generic callers still
    recognize it, while the CLI can catch this type alone without masking
    genuine NotImplementedError bugs."""


# ---------------------------------------------------------------- S3 adapter
#: S3 error codes that mean "retry me" (throttles and 5xx), per the S3 API
#: reference — the same class of failure FaultInjector's TransientStoreError
#: models locally
RETRYABLE_S3_CODES = frozenset({
    "SlowDown", "InternalError", "ServiceUnavailable", "RequestTimeout",
    "ThrottlingException", "Throttling", "503", "500",
})

#: codes that mean "the object is not there (yet)" — the blocking-visibility
#: poll keeps waiting instead of failing
_MISSING_CODES = frozenset({"NoSuchKey", "404", "NotFound"})


def _s3_error_code(exc: BaseException) -> str:
    """The S3 error code off a botocore ``ClientError`` (or anything
    shaped like one), without importing botocore."""
    response = getattr(exc, "response", None)
    if isinstance(response, dict):
        return str(response.get("Error", {}).get("Code", ""))
    return ""


class S3ObjectStore:
    """Blocking-visibility object store over a boto3-shaped S3 client.

    API-compatible with :class:`~repro.serverless.backends.local.LocalStore`
    (put/get/take/delete/keys, heartbeats/leases, abort/revive, ``stats``/
    ``live_bytes``), so ``LocalWorkerContext`` and ``local_scatter_reduce``
    drive it unchanged.  Visibility is real: ``get`` polls ``get_object``
    until the key exists (S3 gives read-after-write consistency, so one
    successful poll is authoritative).  Worker liveness stays in-process
    (the workers are this host's threads); only the *objects* cross S3.

    ``client`` is anything exposing ``put_object``/``get_object``/
    ``delete_object``/``list_objects_v2`` with boto3's call/return shapes —
    the real boto3 client, or a fake in tests.  Transient S3 error codes
    are retried with ``config.retry``'s deterministic backoff; retries are
    counted in ``retried_ops`` for observability.
    """

    def __init__(self, client: Any, config: CloudConfig,
                 timeout: float = DEFAULT_GET_TIMEOUT,
                 lease_timeout: float = DEFAULT_LEASE_TIMEOUT):
        if not config.bucket:
            raise ValueError(
                "S3ObjectStore needs CloudConfig.bucket (the S3 bucket "
                "objects live in)")
        self.client = client
        self.config = config
        self.bucket = config.bucket
        self.prefix = config.key_prefix
        self.timeout = timeout
        self.lease_timeout = lease_timeout
        self.stats = StoreStats()
        self.retried_ops = 0
        self._lock = threading.Lock()
        self._live_bytes = 0.0
        self._sizes: dict = {}          # key -> charged nbytes (accounting)
        self._poison: Optional[BaseException] = None
        self._heartbeats: dict = {}
        self._dead: set = set()

    # ------------------------------------------------------------- transport
    def _s3(self, op: str, **kw):
        """One S3 call with the config's retry policy on transient codes."""
        attempt = 1
        policy = self.config.retry
        while True:
            try:
                return getattr(self.client, op)(**kw)
            except Exception as e:      # noqa: BLE001 - classified by code
                code = _s3_error_code(e)
                if code in _MISSING_CODES:
                    raise
                if (code in RETRYABLE_S3_CODES
                        and attempt < policy.max_attempts):
                    with self._lock:
                        self.retried_ops += 1
                    time.sleep(policy.delay(attempt, kw.get("Key", op)))
                    attempt += 1
                    continue
                raise

    def _skey(self, key: str) -> str:
        return f"{self.prefix}{key}"

    def _get_blob(self, key: str) -> Optional[bytes]:
        try:
            resp = self._s3("get_object", Bucket=self.bucket,
                            Key=self._skey(key))
        except Exception as e:          # noqa: BLE001 - classified by code
            if _s3_error_code(e) in _MISSING_CODES:
                return None
            raise
        return resp["Body"].read()

    # ------------------------------------------------------ liveness / leases
    def heartbeat(self, worker: Tuple[int, int]) -> None:
        with self._lock:
            self._heartbeats[worker] = time.monotonic()

    def mark_dead(self, worker: Tuple[int, int]) -> None:
        with self._lock:
            self._dead.add(worker)

    def heartbeat_age(self, worker: Tuple[int, int]) -> Optional[float]:
        with self._lock:
            beat = self._heartbeats.get(worker)
        return None if beat is None else time.monotonic() - beat

    def abort(self, reason: BaseException) -> None:
        with self._lock:
            if self._poison is None:
                self._poison = reason

    def revive(self) -> None:
        with self._lock:
            self._poison = None
            self._dead.clear()
            self._heartbeats.clear()

    # -------------------------------------------------------------- store API
    def put(self, key: str, nbytes: float, value: Any = None) -> None:
        blob = pickle.dumps((float(nbytes), value),
                            protocol=pickle.HIGHEST_PROTOCOL)
        self._s3("put_object", Bucket=self.bucket, Key=self._skey(key),
                 Body=blob)
        with self._lock:
            prev = self._sizes.pop(key, None)
            if prev is not None:
                # overwrite frees the old object: count the implicit delete
                self._live_bytes -= prev
                self.stats.count_delete(key, prev)
            self._sizes[key] = float(nbytes)
            self._live_bytes += float(nbytes)
            self.stats.count_put(key, float(nbytes), self._live_bytes)

    def _check_liveness(self, key: str) -> None:
        with self._lock:
            poison = self._poison
            producer = producer_worker_of_key(key)
            dead = producer in self._dead
            beat = self._heartbeats.get(producer)
        if poison is not None:
            raise StoreAbortedError(
                f"store aborted while waiting for {key!r}: "
                f"{poison}") from poison
        if producer is None:
            return
        if dead:
            raise ProducerDeadError(
                f"object {key!r} will never arrive: its producer worker "
                f"(stage {producer[0]}, replica {producer[1]}) died")
        if beat is not None and time.monotonic() - beat > self.lease_timeout:
            age = time.monotonic() - beat
            raise ProducerDeadError(
                f"object {key!r} will never arrive: its producer worker "
                f"(stage {producer[0]}, replica {producer[1]}) stopped "
                f"heartbeating {age:.1f}s ago (lease timeout "
                f"{self.lease_timeout:.0f}s)")

    def _fetch(self, key: str, consume: bool, return_nbytes: bool) -> Any:
        deadline = time.monotonic() + self.timeout
        while True:
            self._check_liveness(key)
            blob = self._get_blob(key)
            if blob is not None:
                break
            if time.monotonic() > deadline:
                raise TimeoutError(self._diagnose_timeout(key))
            time.sleep(min(0.01, self.lease_timeout / 4.0))
        nbytes, value = pickle.loads(blob)
        with self._lock:
            self.stats.count_get(key, nbytes)
        if consume:
            self._s3("delete_object", Bucket=self.bucket,
                     Key=self._skey(key))
            with self._lock:
                self._sizes.pop(key, None)
                self._live_bytes -= nbytes
                self.stats.count_delete(key, nbytes)
        return (value, nbytes) if return_nbytes else value

    def _diagnose_timeout(self, key: str) -> str:
        producer = producer_worker_of_key(key)
        existing = sorted(self._sizes)
        sample = ", ".join(existing[:8]) if existing else "none"
        if producer is None:
            lease = f"no producer lease on record ({producer_of_key(key)})"
        else:
            age = self.heartbeat_age(producer)
            state = ("marked dead" if producer in self._dead
                     else f"last heartbeat {age:.1f}s ago" if age is not None
                     else "never heartbeat")
            lease = (f"producer lease held by worker (stage {producer[0]}, "
                     f"replica {producer[1]}) — {state}")
        return (f"object {key!r} never became visible within "
                f"{self.timeout:.0f}s; {lease}; "
                f"{len(existing)} keys tracked (e.g. [{sample}])")

    def get(self, key: str, return_nbytes: bool = False) -> Any:
        return self._fetch(key, consume=False, return_nbytes=return_nbytes)

    def take(self, key: str, return_nbytes: bool = False) -> Any:
        return self._fetch(key, consume=True, return_nbytes=return_nbytes)

    def delete(self, key: str) -> None:
        with self._lock:
            nbytes = self._sizes.pop(key, None)
        if nbytes is None:
            return
        self._s3("delete_object", Bucket=self.bucket, Key=self._skey(key))
        with self._lock:
            self._live_bytes -= nbytes
            self.stats.count_delete(key, nbytes)

    def keys(self):
        out = []
        kw = dict(Bucket=self.bucket, Prefix=self.prefix)
        while True:
            resp = self._s3("list_objects_v2", **kw)
            for obj in resp.get("Contents", ()) or ():
                out.append(obj["Key"][len(self.prefix):])
            if not resp.get("IsTruncated"):
                return out
            kw["ContinuationToken"] = resp["NextContinuationToken"]

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._sizes

    def __len__(self) -> int:
        with self._lock:
            return len(self._sizes)

    @property
    def live_bytes(self) -> float:
        with self._lock:
            return self._live_bytes


class AwsS3Backend(LocalBackend):
    """AWS Lambda workers synchronizing through S3 (paper §5.1 setup).

    The store is *real* (every object round-trips through the configured S3
    bucket via boto3, with ``CloudConfig.retry`` on transient codes); the
    compute side runs the stage workers as this host's threads — the
    Lambda-invocation surface is the remaining gap to the full platform.
    ``open()`` fails with an actionable :class:`BackendUnavailableError`
    when boto3, credentials, or the bucket are missing.  Tests inject a
    fake boto3-shaped ``client`` to exercise the adapter hermetically.
    """

    name = "aws"
    client_module = "boto3"
    platform_blurb = "AWS Lambda + S3"
    extra = "aws"
    default_config = AWS_CLOUD_CONFIG

    def __init__(self, config: Optional[CloudConfig] = None, *,
                 client: Any = None,
                 get_timeout: float = DEFAULT_GET_TIMEOUT,
                 lease_timeout: float = DEFAULT_LEASE_TIMEOUT):
        super().__init__(get_timeout=get_timeout,
                         lease_timeout=lease_timeout)
        self.config = config if config is not None else self.default_config
        self._client = client

    def _make_client(self) -> Any:
        if self._client is not None:
            return self._client
        if importlib.util.find_spec(self.client_module) is None:
            raise BackendUnavailableError(
                f"backend {self.name!r} ({self.platform_blurb}) requires "
                f"the {self.client_module!r} client — `pip install "
                f"repro[{self.extra}]` (or `pip install "
                f"{self.client_module}`) to pull it in.  Replay the plan on "
                "'emulated', 'local', or 'process' instead; the same "
                "DeploymentPlan JSON drives this backend unchanged once "
                "the client is installed.")
        missing = self.config.missing_credentials()
        if missing:
            raise BackendUnavailableError(
                f"backend {self.name!r}: {self.client_module} is installed "
                f"but credentials are missing — set {', '.join(missing)} "
                "before opening this backend.")
        if not self.config.bucket:
            raise BackendUnavailableError(
                f"backend {self.name!r}: no S3 bucket configured — pass "
                "CloudConfig(bucket=...) to AwsS3Backend (objects need a "
                "bucket to live in).")
        import boto3

        return boto3.client(
            "s3", region_name=self.config.region,
            endpoint_url=self.config.endpoint)

    def open(self, agg) -> None:
        # resolve the client first: a missing boto3/credentials/bucket must
        # surface as the actionable BackendUnavailableError, not whatever
        # provisioning trips over afterwards
        self._client = self._make_client()
        super().open(agg)

    def _make_store(self) -> S3ObjectStore:
        return S3ObjectStore(self._make_client(), self.config,
                             timeout=self.get_timeout,
                             lease_timeout=self.lease_timeout)


# -------------------------------------------------------------------- stubs
class _CloudStub(ExecutionBackend):
    """Shared stub behavior: name the missing client, fail on open()."""

    wall_clock = True
    client_module = "?"
    platform_blurb = "?"
    extra = "?"                    # pip extra that would pull the client in
    default_config: CloudConfig = CloudConfig()

    def __init__(self, config: Optional[CloudConfig] = None):
        self.config = config if config is not None else self.default_config

    def _unavailable(self) -> "BackendUnavailableError":
        have_client = importlib.util.find_spec(self.client_module) is not None
        if have_client:
            detail = (
                f"the {self.client_module!r} client is importable but the "
                f"{self.name} backend's store/invoke adapters are not "
                "implemented yet")
        else:
            detail = (
                f"requires the {self.client_module!r} client — "
                f"`pip install repro[{self.extra}]` (or `pip install "
                f"{self.client_module}`) to pull it in")
        missing = self.config.missing_credentials()
        cred = ""
        if missing:
            cred = (f"  Credentials: set {', '.join(missing)} before "
                    "opening this backend.")
        return BackendUnavailableError(
            f"backend {self.name!r} ({self.platform_blurb}) is a stub: "
            f"{detail}.{cred}  Replay the plan on 'emulated' (virtual-clock "
            "cost model), 'local' (real concurrency on this host), or "
            "'process' (real worker processes) instead; the same "
            "DeploymentPlan JSON will drive the real backend unchanged "
            "once it lands.")

    def open(self, agg) -> None:
        raise self._unavailable()

    def context(self, s: int, r: int):  # pragma: no cover - open() raises
        raise self._unavailable()

    def run_step(self, k, programs, *, pipelined_sync=True):  # pragma: no cover
        raise self._unavailable()

    @property
    def store_stats(self):  # pragma: no cover - open() raises first
        raise self._unavailable()

    def _store_for_verification(self):  # pragma: no cover
        raise self._unavailable()


class AliyunOssBackend(_CloudStub):
    """Alibaba Function Compute workers synchronizing through OSS (§5.7)."""

    name = "oss"
    client_module = "oss2"
    platform_blurb = "Alibaba Function Compute + OSS"
    extra = "oss"
    default_config = OSS_CLOUD_CONFIG
