"""Real-platform backend stubs: AWS Lambda + S3 and Alibaba FC + OSS.

The :class:`ExecutionBackend` contract is everything a real platform needs
to implement — an object-store client (`put`/`get`/`delete` with the
platform's visibility semantics) plus a function-invocation surface for the
``S x d`` stage workers.  The clients themselves (``boto3`` / ``oss2``) are
not vendored here; these stubs register the names, carry the wiring notes,
and fail *at open time* with an actionable message, so ``get_backend("aws")``
is a valid call today and a drop-in implementation tomorrow — no solver,
driver or CLI change needed when the real clients land.
"""
from __future__ import annotations

import importlib.util

from repro.serverless.backends.base import ExecutionBackend


class BackendUnavailableError(NotImplementedError):
    """A registered backend name whose implementation is not present in this
    environment (cloud stubs).  Subclasses NotImplementedError so generic
    callers still recognize it, while the CLI can catch this type alone
    without masking genuine NotImplementedError bugs."""


class _CloudStub(ExecutionBackend):
    """Shared stub behavior: name the missing client, fail on open()."""

    wall_clock = True
    client_module = "?"
    platform_blurb = "?"

    def _unavailable(self) -> "BackendUnavailableError":
        have_client = importlib.util.find_spec(self.client_module) is not None
        detail = (
            f"the {self.client_module!r} client is importable but the "
            f"{self.name} backend's store/invoke adapters are not "
            "implemented yet"
            if have_client else
            f"requires the {self.client_module!r} client, which is not "
            "installed in this environment"
        )
        return BackendUnavailableError(
            f"backend {self.name!r} ({self.platform_blurb}) is a stub: "
            f"{detail}.  Replay the plan on 'emulated' (virtual-clock cost "
            "model) or 'local' (real concurrency on this host) instead; the "
            "same DeploymentPlan JSON will drive the real backend unchanged "
            "once it lands.")

    def open(self, agg) -> None:
        raise self._unavailable()

    def context(self, s: int, r: int):  # pragma: no cover - open() raises
        raise self._unavailable()

    def run_step(self, k, programs, *, pipelined_sync=True):  # pragma: no cover
        raise self._unavailable()

    @property
    def store_stats(self):  # pragma: no cover - open() raises first
        raise self._unavailable()

    def _store_for_verification(self):  # pragma: no cover
        raise self._unavailable()


class AwsS3Backend(_CloudStub):
    """AWS Lambda workers synchronizing through S3 (paper §5.1 setup)."""

    name = "aws"
    client_module = "boto3"
    platform_blurb = "AWS Lambda + S3"


class AliyunOssBackend(_CloudStub):
    """Alibaba Function Compute workers synchronizing through OSS (§5.7)."""

    name = "oss"
    client_module = "oss2"
    platform_blurb = "Alibaba Function Compute + OSS"
