"""Storage-based gradient scatter-reduce, executed over store keys (§3.3).

Two algorithms, both operating on the emulated :class:`ObjectStore`:

``three_phase_scatter_reduce``
    LambdaML's barriered collective (paper eq (1)).  Phase 1: every worker
    uploads the n-1 gradient chunks owned by the others; phase 2 (after a
    barrier): each worker downloads the n-1 partials of its own chunk,
    reduces, re-uploads the result; phase 3 (after a barrier): everyone
    downloads the n-1 reduced chunks.  Within a phase the chunk puts/gets
    pipeline on one request stream, so the emulated completion time equals
    eq (1) exactly: ``3 s/w - 2 s/(n w) + 4 t_lat``.

``pipelined_scatter_reduce``
    FuncPipe's barrier-free full-duplex schedule (paper eq (2)).  Worker i
    uploads its partial chunks in staggered round order (chunk for worker
    (i+r) mod n in round r) so that each destination can start pulling
    immediately; the downlink pulls each partial as soon as it becomes
    visible (a fresh GET round-trip each, since availability events are
    distinct), reduces incrementally, re-uploads its reduced chunk and pulls
    the other reduced chunks.  Uplink and downlink overlap, giving
    ``~2 s/w + O(n) t_lat`` — the eq (2) schedule.

Numerics: when per-worker gradient vectors are supplied they are moved
through the same keys and the returned reduction is the exact chunk-wise sum
(identical, bit for bit, on every worker — all workers download the same
reduced chunk objects).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serverless.runtime.store import ObjectStore, StageChannel


def _chunk_values(values, n: int):
    if values is None:
        return None
    return [np.array_split(np.asarray(v), n) for v in values]


def _cleanup(store: ObjectStore, key_prefix: str, n: int) -> None:
    """Every consumer has pulled its chunks by return time; free the keys so
    live storage stays bounded across training steps."""
    for j in range(n):
        for i in range(n):
            if i != j:
                store.delete(f"{key_prefix}/part/{j}/{i}")
        store.delete(f"{key_prefix}/red/{j}")


def ring_reduce(own, parts):
    """The collective's deterministic fp32 reduction: start from the owned
    chunk, add partials in the order given.  Both the emulated collectives
    and the wall-clock :func:`local_scatter_reduce` reduce through this one
    function (with partials in the same ring order), so trained params are
    bit-identical across backends."""
    acc = np.asarray(own, dtype=np.float32).copy()
    for p in parts:
        acc += np.asarray(p, dtype=np.float32)
    return acc


def _reduce_chunks(chunks, owner: int, n: int):
    """Owner's deterministic reduction order: own chunk, then ring order."""
    return ring_reduce(chunks[owner][owner],
                       [chunks[(owner - r) % n][owner] for r in range(1, n)])


def three_phase_scatter_reduce(
    store: ObjectStore,
    channels: Sequence[StageChannel],
    nbytes: float,
    ready: Sequence[float],
    *,
    values: Optional[Sequence[np.ndarray]] = None,
    key_prefix: str = "sr3",
) -> Tuple[Optional[np.ndarray], List[float]]:
    """LambdaML 3-phase collective.  Returns (reduced vector | None, end times)."""
    n = len(channels)
    assert len(ready) == n
    assert all(ch.store is store for ch in channels)
    if n == 1:
        v = None if values is None else np.asarray(values[0], dtype=np.float32)
        return v, [ready[0]]
    chunk_b = nbytes / n
    chunks = _chunk_values(values, n)

    # phase 1: worker i uploads its partials of everyone else's chunk
    for i, ch in enumerate(channels):
        first = True
        for r in range(1, n):
            j = (i + r) % n
            val = None if chunks is None else chunks[i][j]
            ch.upload(f"{key_prefix}/part/{j}/{i}", chunk_b, ready=ready[i],
                      value=val, new_request=first)
            first = False
    barrier1 = max(ch.up_free for ch in channels)

    # phase 2: download the n-1 partials of the owned chunk, reduce, re-upload
    reduced_chunks: List[Optional[np.ndarray]] = [None] * n
    for i, ch in enumerate(channels):
        first = True
        for r in range(1, n):
            src = (i - r) % n
            _, t = ch.download(f"{key_prefix}/part/{i}/{src}", ready=barrier1,
                               new_request=first)
            first = False
        if chunks is not None:
            reduced_chunks[i] = _reduce_chunks(chunks, i, n)
        ch.upload(f"{key_prefix}/red/{i}", chunk_b, ready=t,
                  value=reduced_chunks[i], new_request=True)
    barrier2 = max(ch.up_free for ch in channels)

    # phase 3: everyone downloads the other n-1 reduced chunks
    ends = []
    for i, ch in enumerate(channels):
        t = barrier2
        first = True
        for r in range(1, n):
            src = (i + r) % n
            _, t = ch.download(f"{key_prefix}/red/{src}", ready=barrier2,
                               new_request=first)
            first = False
        ends.append(t)

    _cleanup(store, key_prefix, n)
    reduced = None if chunks is None else np.concatenate(reduced_chunks)
    return reduced, ends


def local_scatter_reduce(
    store,
    index: int,
    n: int,
    nbytes: float,
    value: Optional[np.ndarray],
    *,
    key_prefix: str,
    pipelined: bool = True,
    barrier=None,
    tracer=None,
    clock=None,
) -> Optional[np.ndarray]:
    """One worker's share of the storage scatter-reduce on a *wall-clock*
    store (``backends.local.LocalStore``): call from ``n`` concurrent worker
    threads, each with its own ``index``.

    Moves the same objects under the same keys as the emulated collectives
    and reduces through :func:`ring_reduce` in the identical ring order, so
    the returned vector is bit-identical to the virtual-clock backends' —
    but here ``store.get`` genuinely *blocks* until the producer's put lands,
    exercising the visibility/ordering races the virtual clock never hits.

    ``pipelined=False`` inserts the two phase barriers of the LambdaML eq (1)
    collective (``barrier`` must then be a ``threading.Barrier(n)``); the
    pipelined eq (2) schedule needs no phase barriers — downlinks ride on
    blocking visibility alone.  Either way one final barrier fences the
    cleanup: a worker frees its reduced chunk only after every peer has
    pulled it, which is what keeps the store drained across steps.

    With ``tracer``/``clock`` set (``repro.obs.WorkerTracer`` + a seconds
    clock), every per-chunk put/take/get and barrier wait emits one
    wall-clock span — the local mirror of the emulated collectives' per-chunk
    channel spans.
    """
    i = index
    if n == 1:
        return None if value is None else np.asarray(value, dtype=np.float32)
    trace_on = tracer is not None and clock is not None

    def _traced_put(key, val):
        if not trace_on:
            store.put(key, chunk_b, value=val)
            return
        t0 = clock()
        store.put(key, chunk_b, value=val)
        tracer.emit("upload", t0, clock(), nbytes=chunk_b, key=key)

    def _traced_fetch(fetch, key):
        if not trace_on:
            return fetch(key)
        # the blocking visibility wait is inside fetch(); the span covers it,
        # matching the emulated download span which starts at data-ready
        t0 = clock()
        val, nb = fetch(key, True)
        tracer.emit("download", t0, clock(), nbytes=nb, key=key)
        return val

    def _traced_wait(b):
        if not trace_on:
            b.wait()
            return
        t0 = clock()
        b.wait()
        tracer.emit("barrier", t0, clock())

    chunk_b = nbytes / n
    chunks = None if value is None else np.array_split(np.asarray(value), n)

    # scatter: upload my partials of everyone else's chunk, staggered order
    for r in range(1, n):
        j = (i + r) % n
        _traced_put(f"{key_prefix}/part/{j}/{i}",
                    None if chunks is None else chunks[j])
    if not pipelined and barrier is not None:
        _traced_wait(barrier)             # eq (1) phase-1 barrier

    # reduce: pull the n-1 partials of the owned chunk (blocking as they
    # surface), reduce in ring order, publish the reduced chunk
    parts = [_traced_fetch(store.take, f"{key_prefix}/part/{i}/{(i - r) % n}")
             for r in range(1, n)]
    reduced_i = None if chunks is None else ring_reduce(chunks[i], parts)
    _traced_put(f"{key_prefix}/red/{i}", reduced_i)
    if not pipelined and barrier is not None:
        _traced_wait(barrier)             # eq (1) phase-2 barrier

    # all-gather: pull the other reduced chunks
    out: List[Optional[np.ndarray]] = [None] * n
    out[i] = reduced_i
    for r in range(1, n):
        src = (i + r) % n
        out[src] = _traced_fetch(store.get, f"{key_prefix}/red/{src}")
    if barrier is not None:
        _traced_wait(barrier)             # cleanup fence: all peers have read
    store.delete(f"{key_prefix}/red/{i}")
    return None if chunks is None else np.concatenate(out)


def pipelined_scatter_reduce(
    store: ObjectStore,
    channels: Sequence[StageChannel],
    nbytes: float,
    ready: Sequence[float],
    *,
    values: Optional[Sequence[np.ndarray]] = None,
    key_prefix: str = "srp",
) -> Tuple[Optional[np.ndarray], List[float]]:
    """FuncPipe pipelined collective.  Returns (reduced vector | None, end times)."""
    n = len(channels)
    assert len(ready) == n
    assert all(ch.store is store for ch in channels)
    if n == 1:
        v = None if values is None else np.asarray(values[0], dtype=np.float32)
        return v, [ready[0]]
    chunk_b = nbytes / n
    chunks = _chunk_values(values, n)

    # scatter: staggered partial-chunk uploads, one pipelined stream each
    for i, ch in enumerate(channels):
        first = True
        for r in range(1, n):
            j = (i + r) % n
            val = None if chunks is None else chunks[i][j]
            ch.upload(f"{key_prefix}/part/{j}/{i}", chunk_b, ready=ready[i],
                      value=val, new_request=first)
            first = False

    # reduce: each worker pulls its partials as they surface (overlapping its
    # own uplink), reduces, and re-uploads the reduced chunk — no barrier
    reduced_chunks: List[Optional[np.ndarray]] = [None] * n
    red_up_end = [0.0] * n
    for i, ch in enumerate(channels):
        # downloads need no explicit ready[i] gate: the reduced-chunk upload
        # below serializes behind the scatter uploads via up_free, which
        # already start at ready[i]
        for r in range(1, n):
            src = (i - r) % n
            _, t = ch.download(f"{key_prefix}/part/{i}/{src}", new_request=True)
        if chunks is not None:
            reduced_chunks[i] = _reduce_chunks(chunks, i, n)
        red_up_end[i] = ch.upload(f"{key_prefix}/red/{i}", chunk_b, ready=t,
                                  value=reduced_chunks[i], new_request=True)

    # all-gather: pull the other reduced chunks as they surface
    ends = []
    for i, ch in enumerate(channels):
        t = red_up_end[i]
        for r in range(1, n):
            src = (i + r) % n
            _, t = ch.download(f"{key_prefix}/red/{src}", new_request=True)
        ends.append(max(t, red_up_end[i]))

    _cleanup(store, key_prefix, n)
    reduced = None if chunks is None else np.concatenate(reduced_chunks)
    return reduced, ends
