"""Emulated cloud object store with a virtual clock (the runtime's S3/OSS).

The paper's workers exchange *everything* — activations, boundary gradients,
scatter-reduce chunks — through cloud storage.  This module emulates that
storage for the execution engine: objects live under named keys and carry a
``visible_at`` timestamp on the virtual clock; each serverless worker owns a
``StageChannel`` with three serial resources (CPU, uplink, downlink) whose
free-times advance as tasks are charged.

Cost model (identical to ``repro.serverless.simulator``):

  * a transfer occupies the initiating link for ``nbytes / bandwidth`` plus
    one storage round-trip ``t_lat``.  Requests that continue a pipelined
    HTTP stream on the same link (``new_request=False``, used by the
    scatter-reduce for back-to-back chunk puts/gets of locally available
    data) skip the repeated round-trip — this is what makes the emulated
    3-phase collective land exactly on eq (1);
  * a download can start only once the object is visible
    (``visible_at`` = the producer's upload completion);
  * per-worker bandwidth follows ``Platform.bandwidth(mem)`` degraded by the
    §5.4 co-location contention model and capped by the §5.7 storage-side
    total bandwidth (``effective_bandwidth`` below reuses the simulator's
    functions so the two never drift).

Virtual time is fully decoupled from wall time: numerics (real JAX arrays
stored under the keys) run as fast as the host allows while the clock charges
what AWS Lambda / Alibaba FC + S3 / OSS would have.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict

# single source of truth for per-worker bandwidth (§5.4 + §5.7), re-exported
# here as part of the runtime's public surface
from repro.serverless.simulator import effective_bandwidth  # noqa: F401


@dataclass
class StoredObject:
    nbytes: float
    visible_at: float
    value: Any = None


def classify_key(key: str) -> str:
    """Key class for the per-prefix byte breakdown: the engine's keys are
    ``k{k}/r{r}/m{m}/act{s}`` (forward activations), ``.../grad{s}``
    (backward boundary gradients), ``k{k}/sync{s}/part|red/...``
    (scatter-reduce chunks — parameter-gradient traffic), ``ckpt/s{s}``
    (the Function Manager's store-backed stage checkpoints) and ``kv/s{s}``
    (the serving engine's per-stage KV-cache state, persisted between decode
    tokens).  The serving boundary keys ``serve/p/act{s}`` /
    ``serve/dec/t{t}/act{s}`` count as activations."""
    if key.startswith("ckpt/"):
        return "ckpt"
    if key.startswith("kv/"):
        return "kv"
    if "/part/" in key or "/red/" in key:
        return "sync"
    base = key.rsplit("/", 1)[-1]
    if base.startswith("act"):
        return "act"
    if base.startswith("grad"):
        return "grad"
    return "other"


def producer_worker_of_key(key: str):
    """Infer the (stage, replica) that produces ``key`` under the engine's
    key schema, or ``None`` when the key is outside it.  This is the
    producer-*lease* rule the LocalStore's liveness diagnostics use: every
    engine key has exactly one producer worker."""
    try:
        parts = key.split("/")
        base = parts[-1]
        if key.startswith("ckpt/"):
            return None
        if len(parts) >= 4 and parts[1].startswith("sync"):
            stage = int(parts[1][4:])
            if parts[2] == "part":
                # k{k}/sync{s}/part/{j}/{i}: uploaded by replica i
                return (stage, int(parts[4]))
            # k{k}/sync{s}/red/{j}: reduced by the owner replica of chunk j
            return (stage, int(parts[3]))
        replica = int(parts[1][1:])
        if base.startswith("act"):
            return (int(base[3:]), replica)
        if base.startswith("grad"):
            return (int(base[4:]) + 1, replica)
    except (ValueError, IndexError):
        pass
    return None


def producer_of_key(key: str, x=None) -> str:
    """Best-effort human description of which worker produces ``key`` under
    the engine's key schema (used by store-timeout diagnostics when no
    explicit lease was recorded).  ``k{k}/r{r}/m{m}/act{s}`` is uploaded by
    stage ``s`` of replica ``r``; ``.../grad{s}`` by stage ``s+1``;
    ``k{k}/sync{s}/part/{j}/{i}`` by replica ``i`` of stage ``s``;
    ``.../red/{j}`` by the owner replica of chunk ``j``."""
    try:
        parts = key.split("/")
        base = parts[-1]
        if key.startswith("ckpt/"):
            return "the engine's checkpoint writer"
        if "sync" in key and len(parts) >= 4:
            stage = int(parts[1][4:])
            if parts[2] == "part":
                return (f"replica {int(parts[4])} of stage {stage} "
                        "(scatter-reduce part)")
            return (f"the owner replica of chunk {int(parts[3])} at stage "
                    f"{stage} (scatter-reduce reduced chunk)")
        replica = int(parts[1][1:])
        if base.startswith("act"):
            return f"worker (stage {int(base[3:])}, replica {replica})"
        if base.startswith("grad"):
            return f"worker (stage {int(base[4:]) + 1}, replica {replica})"
    except (ValueError, IndexError):
        pass
    return "an unknown producer (key outside the engine schema)"


class StoreAbortedError(RuntimeError):
    """The store was poisoned because a worker died: every blocked consumer
    is woken with this instead of burning its full get-timeout.  The engine
    treats it as recoverable collateral of the originating crash."""


class ProducerDeadError(RuntimeError):
    """A consumer's lease check found the producer of the awaited key dead
    (no heartbeat within the lease timeout) — 'dead', not merely 'slow', so
    the consumer fails over to recovery immediately."""


@dataclass
class StoreStats:
    puts: int = 0
    gets: int = 0
    deletes: int = 0
    bytes_in: float = 0.0
    bytes_out: float = 0.0
    bytes_deleted: float = 0.0
    peak_bytes: float = 0.0
    # per key-class breakdown (classify_key: act | grad | sync | other) so
    # byte-conservation failures can name the offending traffic class
    class_bytes_in: Dict[str, float] = field(default_factory=dict)
    class_bytes_out: Dict[str, float] = field(default_factory=dict)
    class_bytes_deleted: Dict[str, float] = field(default_factory=dict)

    # ------------------------------------------------- shared bookkeeping
    # every store implementation (emulated ObjectStore, wall-clock
    # LocalStore) funnels its counter updates through these three, so the
    # per-class accounting can never drift between backends
    def count_put(self, key: str, nbytes: float, live_bytes: float) -> None:
        self.puts += 1
        self.bytes_in += nbytes
        self.peak_bytes = max(self.peak_bytes, live_bytes)
        cls = classify_key(key)
        self.class_bytes_in[cls] = self.class_bytes_in.get(cls, 0.0) + nbytes

    def count_get(self, key: str, nbytes: float) -> None:
        self.gets += 1
        self.bytes_out += nbytes
        cls = classify_key(key)
        self.class_bytes_out[cls] = self.class_bytes_out.get(cls, 0.0) + nbytes

    def count_delete(self, key: str, nbytes: float) -> None:
        self.deletes += 1
        self.bytes_deleted += nbytes
        cls = classify_key(key)
        self.class_bytes_deleted[cls] = \
            self.class_bytes_deleted.get(cls, 0.0) + nbytes

    def as_dict(self) -> dict:
        """JSON-ready counters (trace metadata / ``repro inspect``)."""
        return {
            "puts": self.puts, "gets": self.gets, "deletes": self.deletes,
            "bytes_in": self.bytes_in, "bytes_out": self.bytes_out,
            "bytes_deleted": self.bytes_deleted,
            "peak_bytes": self.peak_bytes,
            "class_bytes_in": dict(self.class_bytes_in),
            "class_bytes_out": dict(self.class_bytes_out),
            "class_bytes_deleted": dict(self.class_bytes_deleted),
        }


class ObjectStore:
    """Flat key -> object namespace (one bucket)."""

    def __init__(self, latency: float = 0.0):
        self.latency = latency
        self._objects: Dict[str, StoredObject] = {}
        self._live_bytes = 0.0
        self.stats = StoreStats()

    def put(self, key: str, nbytes: float, value: Any = None,
            visible_at: float = 0.0) -> StoredObject:
        prev = self._objects.get(key)
        if prev is not None:
            # an overwrite implicitly frees the old object; count it so the
            # puts==deletes / bytes conservation invariant stays meaningful
            self._live_bytes -= prev.nbytes
            self.stats.count_delete(key, prev.nbytes)
        obj = StoredObject(nbytes=float(nbytes), visible_at=visible_at, value=value)
        self._objects[key] = obj
        self._live_bytes += obj.nbytes
        self.stats.count_put(key, obj.nbytes, self._live_bytes)
        return obj

    def head(self, key: str) -> StoredObject:
        if key not in self._objects:
            raise KeyError(f"object {key!r} was never uploaded")
        return self._objects[key]

    def get(self, key: str) -> StoredObject:
        obj = self.head(key)
        self.stats.count_get(key, obj.nbytes)
        return obj

    def delete(self, key: str) -> None:
        obj = self._objects.pop(key, None)
        if obj is not None:
            self._live_bytes -= obj.nbytes
            self.stats.count_delete(key, obj.nbytes)

    def keys(self):
        return list(self._objects)

    def __contains__(self, key: str) -> bool:
        return key in self._objects

    def __len__(self) -> int:
        return len(self._objects)

    @property
    def live_bytes(self) -> float:
        return self._live_bytes

    def assert_drained(self) -> None:
        """Byte-accounting invariant at the end of a run: every uploaded
        object was eventually consumed and freed (puts - deletes == residual
        == nothing).  A leaked key here means a collective or the engine
        forgot its cleanup — storage cost on a real platform would grow
        without bound across training steps."""
        assert_store_drained(self)


def assert_store_drained(store) -> None:
    """Shared drain/conservation check for any backend store (emulated or
    wall-clock): no residual objects, object count conserved, and bytes
    conserved up to float summation order."""
    leftover = store.keys()
    if leftover:
        sample = ", ".join(sorted(leftover)[:8])
        raise RuntimeError(
            f"store not drained: {len(leftover)} residual objects "
            f"({store.live_bytes:.0f} live bytes), e.g. [{sample}]")
    st = store.stats
    if st.puts != st.deletes:
        raise RuntimeError(
            f"store object count not conserved: {st.puts} puts vs "
            f"{st.deletes} deletes with an empty store")
    # different backends sum the same per-object sizes in different orders
    if abs(st.bytes_in - st.bytes_deleted) > 1e-6 * max(st.bytes_in, 1.0):
        # name the offending key class (activations / gradients / sync
        # chunks) so the leak points at a collective or a pipeline boundary
        worst, worst_delta = "?", 0.0
        for cls in set(st.class_bytes_in) | set(st.class_bytes_deleted):
            delta = abs(st.class_bytes_in.get(cls, 0.0)
                        - st.class_bytes_deleted.get(cls, 0.0))
            if delta > worst_delta:
                worst, worst_delta = cls, delta
        raise RuntimeError(
            f"store bytes not conserved: {st.bytes_in:.0f} uploaded vs "
            f"{st.bytes_deleted:.0f} deleted with an empty store "
            f"(worst key class: {worst!r}, "
            f"{st.class_bytes_in.get(worst, 0.0):.0f} in vs "
            f"{st.class_bytes_deleted.get(worst, 0.0):.0f} deleted)")


class StageChannel:
    """A worker's virtual clock: serial CPU, uplink and downlink resources.

    Mirrors the resource model of ``simulator.simulate_funcpipe``: each
    resource processes its tasks in issue order; a task starts at
    ``max(data-ready, resource-free)``.
    """

    def __init__(self, store: ObjectStore, bandwidth: float, latency: float,
                 name: str = "worker"):
        assert bandwidth > 0, bandwidth
        self.store = store
        self.bandwidth = bandwidth
        self.latency = latency
        self.name = name
        self.cpu_free = 0.0
        self.up_free = 0.0
        self.dn_free = 0.0
        # optional repro.obs.WorkerTracer: when set, every charged resource
        # task (incl. each scatter-reduce chunk) emits one virtual-clock span
        self.tracer = None

    # ------------------------------------------------------------- resources
    def compute(self, duration: float, ready: float = 0.0) -> float:
        start = max(ready, self.cpu_free)
        self.cpu_free = start + duration
        if self.tracer is not None:
            self.tracer.emit("compute", start, self.cpu_free)
        return self.cpu_free

    def upload(self, key: str, nbytes: float, ready: float = 0.0,
               value: Any = None, new_request: bool = True) -> float:
        start = max(ready, self.up_free)
        end = start + nbytes / self.bandwidth + (self.latency if new_request else 0.0)
        self.up_free = end
        self.store.put(key, nbytes, value=value, visible_at=end)
        if self.tracer is not None:
            self.tracer.emit("upload", start, end, nbytes=nbytes, key=key)
        return end

    def download(self, key: str, ready: float = 0.0, new_request: bool = True,
                 op: str = "download"):
        obj = self.store.get(key)
        # span start is when the transfer begins — the visibility wait shows
        # up as a gap (bubble), not as link occupancy
        start = max(ready, self.dn_free, obj.visible_at)
        end = start + obj.nbytes / self.bandwidth + (self.latency if new_request else 0.0)
        self.dn_free = end
        if self.tracer is not None:
            self.tracer.emit(op, start, end, nbytes=obj.nbytes,
                             key=key)
        return obj.value, end

    def stall(self, duration: float, op: str = "retry") -> float:
        """Charge ``duration`` of idle occupancy across *all* resources (the
        worker is blocked in a retry backoff or an injected straggle — it
        can neither compute nor transfer).  Emits one ``op`` span."""
        start = self.now
        end = start + duration
        self.cpu_free = max(self.cpu_free, end)
        self.up_free = max(self.up_free, end)
        self.dn_free = max(self.dn_free, end)
        if self.tracer is not None:
            self.tracer.emit(op, start, end)
        return end

    # --------------------------------------------------------------- ordering
    def join_uplink_into_downlink(self) -> None:
        """Program-order barrier between the forward and backward phases: a
        worker issues no backward download before its forward uploads are
        done (the ``fwd_u_end[s, mu-1]`` term of the simulator's DP)."""
        if self.tracer is not None and self.up_free > self.dn_free:
            # the fence's wait interval (downlink held back by the uplink)
            self.tracer.emit("barrier", self.dn_free, self.up_free)
        self.dn_free = max(self.dn_free, self.up_free)

    def release_at(self, t: float) -> None:
        """Advance every resource to at least ``t`` (post-sync barrier)."""
        self.cpu_free = max(self.cpu_free, t)
        self.up_free = max(self.up_free, t)
        self.dn_free = max(self.dn_free, t)

    @property
    def now(self) -> float:
        return max(self.cpu_free, self.up_free, self.dn_free)
