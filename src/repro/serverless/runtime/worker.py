"""Serverless stage workers: real JAX forward/backward for a layer range.

A :class:`StageWorker` owns the contiguous slice of the model that the
planner assigned to one pipeline stage — a range of period instances plus,
for the boundary stages, the embedding table / final norm + LM head — and
executes the same math as the monolithic ``registry.loss_fn`` /
``core.pipeline.pipeline_train_loss`` paths: ``embed_inputs`` ->
``period_forward`` scan -> ``rms_norm`` + CE.  Because the instance scan is
simply split at stage boundaries, the engine's pipelined execution is
numerically the monolithic forward, up to fp32 summation order.

Partition bridge: the planner's boundary vector ``x`` indexes the arch
profile produced by ``core.profiler.arch_model_profile`` (layer table
``[embed, layer_0..layer_{n-1}, head]``).  ``stage_instance_ranges`` maps
those cuts onto period-instance ranges; cuts must fall on period boundaries
(always true for ``period_len == 1`` families).

Backward runs through ``jax.vjp``.  With ``jit=True`` (default) the worker
caches a jitted forward and a jitted backward per input-shape signature —
the seed implementation re-traced an un-jitted ``jax.vjp`` closure on every
micro-batch, which dominated engine wall-clock (see the ``walltime`` rows of
``benchmarks/runtime_accuracy.py``).  The jitted forward runs ``jax.vjp``
*inside* the jit and returns the residual-carrying pullback (a
``jax.tree_util.Partial`` pytree), so the backward consumes cached
residuals instead of recomputing the forward inside the VJP — the
recompute variant is kept behind ``remat=True`` for the A/B wall-clock
comparison.  Holding residuals between fwd and bwd is exactly what the
paper's activation-memory term ``mu * a_i`` accounts for.  Gradients are
accumulated in fp32 across micro-batches; ``grad_vector`` flattens them for
the storage scatter-reduce and ``apply_update`` applies the optimizer on
fp32 masters (same math as ``testing.pipeline_equiv.reference_step``).

MoE note: the router aux loss is seeded per micro-batch (weight ``1/mu``),
which matches full-batch routing only when the aux statistic is linear in
the batch — the same caveat as the shard_map pipeline (see
``testing/pipeline_equiv.py``); dense families are exact.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.partition import stages_of
from repro.models import registry
from repro.models.common import rms_norm, softmax_cross_entropy
from repro.models.transformer import period_forward
from repro.optim import Optimizer


@dataclass(frozen=True)
class StageSpan:
    """What pipeline stage ``index`` of ``n_stages`` owns."""

    index: int
    n_stages: int
    inst_lo: int          # first owned period instance
    inst_hi: int          # one past the last owned instance (may equal lo)
    owns_embed: bool
    owns_head: bool


def stage_instance_ranges(cfg: ArchConfig, x) -> List[StageSpan]:
    """Map profile-layer cuts ``x`` (over ``arch_model_profile``'s
    ``[embed, layers..., head]`` table) to period-instance spans."""
    L = len(x) + 1
    expect = cfg.n_layers + 2
    if L != expect:
        raise ValueError(
            f"partition is over {L} profile layers but arch {cfg.name!r} "
            f"profiles to {expect} ([embed] + {cfg.n_layers} layers + [head])")
    plen = cfg.period_len
    spans = []
    stages = stages_of(tuple(x))
    for s, (lo, hi) in enumerate(stages):
        lo_l = max(lo, 1) - 1          # first model layer in the stage
        hi_l = min(hi, cfg.n_layers) - 1   # last model layer (inclusive)
        if lo_l > hi_l:                # embed-only or head-only stage
            inst_lo = inst_hi = 0 if lo == 0 else cfg.n_periods
        else:
            if lo_l % plen != 0:
                raise ValueError(
                    f"stage {s} starts mid-period (layer {lo_l}, period_len={plen}); "
                    "numeric execution needs period-aligned cuts")
            if hi_l != cfg.n_layers - 1 and (hi_l + 1) % plen != 0:
                raise ValueError(
                    f"stage {s} ends mid-period (layer {hi_l}, period_len={plen}); "
                    "numeric execution needs period-aligned cuts")
            inst_lo = lo_l // plen
            inst_hi = -(-(hi_l + 1) // plen)
        spans.append(StageSpan(
            index=s, n_stages=len(stages), inst_lo=inst_lo, inst_hi=inst_hi,
            owns_embed=(lo == 0), owns_head=(hi == L - 1),
        ))
    return spans


class StageWorker:
    """One serverless function: params + optimizer shard for a stage span."""

    def __init__(self, cfg: ArchConfig, span: StageSpan, full_params: dict,
                 *, mu: int, optimizer: Optimizer, jit: bool = True,
                 remat: bool = False):
        if cfg.frontend != "none":
            raise NotImplementedError(
                "runtime numeric execution covers token-LM archs; "
                f"frontend={cfg.frontend!r} is not wired up")
        if cfg.tie_embeddings and span.n_stages > 1:
            raise NotImplementedError(
                "tied embeddings span two stages; untie or use a single stage")
        self.cfg = cfg
        self.span = span
        self.mu = mu
        self.optimizer = optimizer
        self.dtype = jnp.dtype(cfg.param_dtype)

        p: Dict[str, Any] = {}
        if span.owns_embed:
            p["embed"] = full_params["embed"]
        if span.owns_head:
            p["final_norm"] = full_params["final_norm"]
            if cfg.tie_embeddings:
                if not span.owns_embed:  # unreachable (guarded above)
                    raise NotImplementedError
            else:
                p["head"] = full_params["head"]
        if span.inst_hi > span.inst_lo:
            p["layers"] = jax.tree.map(
                lambda a: a[span.inst_lo:span.inst_hi], full_params["layers"])
            self.mask = jnp.asarray(
                registry.active_mask(cfg)[span.inst_lo:span.inst_hi])
        else:
            self.mask = None
        self.params = p

        # fp32 masters + optimizer state, per leaf (ZeRO-less: the stage owns
        # its whole shard, replicas hold identical copies)
        self.opt_state = jax.tree.map(
            lambda a: {"master": a.astype(jnp.float32),
                       **optimizer.init_state(a.astype(jnp.float32))},
            self.params)

        flat, self._treedef = jax.tree.flatten(self.params)
        self._shapes = [l.shape for l in flat]
        self._sizes = [int(np.prod(l.shape)) for l in flat]
        self.grad_nbytes = float(sum(self._sizes)) * 4  # fp32 sync payload

        self._vjps: Dict[int, Any] = {}
        self._grad_acc = None
        self.jit = jit
        self.remat = remat
        self._saved_inputs: Dict[int, Tuple[Any, Any]] = {}
        self._saved_sigs: Dict[int, Any] = {}
        self._jitted: Dict[Any, Tuple[Any, Any]] = {}  # shape sig -> (fwd, bwd)

    # ------------------------------------------------------------- stage math
    def _stage_fn(self, params, x, batch_mb):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        if self.span.owns_embed:
            x = registry.embed_inputs(cfg, params, batch_mb)
        if self.mask is not None:
            seq = x.shape[1]
            positions = jnp.arange(seq, dtype=jnp.int32)

            def body(h, xs):
                inst_params, act_row = xs
                h, a = period_forward(inst_params, h, act_row, cfg=cfg,
                                      positions=positions)
                return h, a

            x, auxs = jax.lax.scan(body, x, (params["layers"], self.mask))
            aux = aux + jnp.sum(auxs)
        if self.span.owns_head:
            h = rms_norm(x, params["final_norm"], cfg.norm_eps)
            head_w = params["embed"] if cfg.tie_embeddings else params["head"]
            logits = h @ head_w.T
            labels = batch_mb["labels"]
            if cfg.causal and not cfg.is_encoder:
                logits = logits[:, :-1]
                labels = labels[:, 1:]
            ce = jnp.mean(softmax_cross_entropy(logits, labels))
            return ce, aux
        return x, aux

    # ------------------------------------------------------------- jit cache
    def _shape_sig(self, x_in, batch_mb):
        leaf = lambda a: (tuple(a.shape), str(jnp.asarray(a).dtype))
        x_sig = None if x_in is None else leaf(x_in)
        b_sig = tuple(sorted((k, leaf(v)) for k, v in batch_mb.items()))
        return (x_sig, b_sig)

    def _get_jitted(self, sig):
        """Jitted (fwd, bwd) pair for one (stage-shape, micro-batch-shape)
        signature, traced once per signature instead of per micro-batch.

        Default (``remat=False``): the forward runs ``jax.vjp`` under jit and
        returns the pullback as a ``jax.tree_util.Partial`` — its leaves ARE
        the residuals, cached in function memory until the backward consumes
        them, so the backward does no forward recompute.  ``remat=True``
        keeps the recompute-inside-VJP variant (no residuals held) for the
        wall-clock A/B in ``benchmarks/runtime_accuracy.py``."""
        fns = self._jitted.get(sig)
        if fns is not None:
            return fns

        def vjp_of(params, x_in, batch_mb):
            if self.span.owns_embed:
                return jax.vjp(lambda p: self._stage_fn(p, None, batch_mb),
                               params)
            return jax.vjp(lambda p, x: self._stage_fn(p, x, batch_mb),
                           params, x_in)

        def unpack(grads):
            g_params = jax.tree.map(lambda g: g.astype(jnp.float32), grads[0])
            g_in = grads[1] if len(grads) > 1 else None
            return g_params, g_in

        def cotangent(g_out):
            seed = jnp.asarray(1.0 / self.mu, jnp.float32)
            return (seed, seed) if self.span.owns_head else (g_out, seed)

        if self.remat:
            def fwd_fn(params, x_in, batch_mb):
                return self._stage_fn(params, x_in, batch_mb)

            def bwd_fn(params, x_in, batch_mb, g_out):
                _, vjp = vjp_of(params, x_in, batch_mb)
                return unpack(vjp(cotangent(g_out)))
        else:
            def fwd_fn(params, x_in, batch_mb):
                out_aux, vjp = vjp_of(params, x_in, batch_mb)
                return out_aux, vjp

            def bwd_fn(vjp, g_out):
                return unpack(vjp(cotangent(g_out)))

        fns = (jax.jit(fwd_fn), jax.jit(bwd_fn))
        self._jitted[sig] = fns
        return fns

    # ---------------------------------------------------------------- fwd/bwd
    def forward(self, m: int, x_in, batch_mb) -> Tuple[Any, float]:
        """Run the stage on micro-batch ``m``.  Returns (output, aux) where
        output is the boundary activation — or the micro-batch CE for the
        last stage."""
        if self.jit:
            x_val = None if self.span.owns_embed else jnp.asarray(x_in)
            sig = self._shape_sig(x_val, batch_mb)
            fwd, _ = self._get_jitted(sig)
            if self.remat:
                out, aux = fwd(self.params, x_val, batch_mb)
                self._saved_inputs[m] = (x_val, batch_mb)
            else:
                (out, aux), vjp = fwd(self.params, x_val, batch_mb)
                self._vjps[m] = vjp          # residuals cached until backward
                self._saved_sigs[m] = sig
            return out, float(aux)
        if self.span.owns_embed:
            out_aux, vjp = jax.vjp(
                lambda p: self._stage_fn(p, None, batch_mb), self.params)
        else:
            out_aux, vjp = jax.vjp(
                lambda p, x: self._stage_fn(p, x, batch_mb), self.params,
                jnp.asarray(x_in))
        self._vjps[m] = vjp
        out, aux = out_aux
        return out, float(aux)

    def _accumulate(self, g_params) -> None:
        if self._grad_acc is None:
            self._grad_acc = g_params
        else:
            self._grad_acc = jax.tree.map(jnp.add, self._grad_acc, g_params)

    def backward(self, m: int, g_out) -> Optional[jax.Array]:
        """VJP for micro-batch ``m``.  ``g_out`` is the cotangent arriving
        from stage s+1 (ignored on the last stage, which seeds the loss).
        Returns the cotangent for stage s-1 (None on stage 0)."""
        if self.jit:
            g_val = None if self.span.owns_head else jnp.asarray(g_out)
            if self.remat:
                x_val, batch_mb = self._saved_inputs.pop(m)
                _, bwd = self._get_jitted(self._shape_sig(x_val, batch_mb))
                g_params, g_in = bwd(self.params, x_val, batch_mb, g_val)
            else:
                vjp = self._vjps.pop(m)      # frees residuals after the call
                _, bwd = self._get_jitted(self._saved_sigs.pop(m))
                g_params, g_in = bwd(vjp, g_val)
            self._accumulate(g_params)
            return g_in
        vjp = self._vjps.pop(m)
        seed = jnp.asarray(1.0 / self.mu, jnp.float32)
        if self.span.owns_head:
            cot = (seed, seed)
        else:
            cot = (jnp.asarray(g_out), seed)
        grads = vjp(cot)
        g_params = grads[0]
        g_in = grads[1] if len(grads) > 1 else None
        g_params = jax.tree.map(lambda g: g.astype(jnp.float32), g_params)
        self._accumulate(g_params)
        return g_in

    # ------------------------------------------------------------ checkpoints
    def export_state(self) -> dict:
        """The stage's full persistent state — params + fp32 masters +
        optimizer moments — as a plain pytree of arrays.  Everything else
        (cached VJP residuals, gradient accumulators, jit caches) is
        per-step transient: a worker restored from this tree at a step
        boundary continues bit-identically."""
        return {"params": self.params, "opt_state": self.opt_state}

    def load_state(self, state: dict) -> None:
        """Restore from :meth:`export_state` (the Function Manager's
        relaunch path).  Resets every transient accumulator — a relaunched
        function starts its step from scratch."""
        treedef = jax.tree.structure(self.params)
        if jax.tree.structure(state["params"]) != treedef:
            raise ValueError(
                f"checkpointed stage state does not match stage {self.span.index}: "
                f"{jax.tree.structure(state['params'])} != {treedef}")
        self.params = jax.tree.map(jnp.asarray, state["params"])
        self.opt_state = jax.tree.map(jnp.asarray, state["opt_state"])
        self._vjps.clear()
        self._saved_inputs.clear()
        self._saved_sigs.clear()
        self._grad_acc = None

    # ------------------------------------------------------------------- sync
    def grad_vector(self) -> np.ndarray:
        """Accumulated stage gradient, flattened fp32 (scatter-reduce payload)."""
        assert self._grad_acc is not None, "backward() must run first"
        flat = jax.tree.leaves(self._grad_acc)
        return np.concatenate([np.asarray(l, np.float32).ravel() for l in flat])

    def apply_update(self, reduced: np.ndarray, step: int) -> None:
        """Optimizer step from the (already averaged) flat gradient."""
        parts = []
        off = 0
        for shape, size in zip(self._shapes, self._sizes):
            parts.append(jnp.asarray(reduced[off:off + size]).reshape(shape))
            off += size
        assert off == len(reduced), (off, len(reduced))
        g_tree = jax.tree.unflatten(self._treedef, parts)

        step_idx = jnp.asarray(step, jnp.int32)

        def upd(g, st):
            sub = {k: v for k, v in st.items() if k != "master"}
            new_m, new_sub = self.optimizer.update(g, st["master"], sub, step_idx)
            return new_m, {"master": new_m, **new_sub}

        is_leaf = lambda v: isinstance(v, dict) and "master" in v
        flat_g = jax.tree.leaves(g_tree)
        flat_st, st_def = jax.tree.flatten(self.opt_state, is_leaf=is_leaf)
        outs = [upd(g, st) for g, st in zip(flat_g, flat_st)]
        flat_p, p_def = jax.tree.flatten(self.params)
        new_params = [m.astype(p.dtype) for (m, _), p in zip(outs, flat_p)]
        self.params = jax.tree.unflatten(p_def, new_params)
        self.opt_state = jax.tree.unflatten(st_def, [st for _, st in outs])
        self._grad_acc = None


def assemble_params(cfg: ArchConfig, workers: List[StageWorker]) -> dict:
    """Re-assemble monolithic ``registry.init_params``-layout params from one
    replica's stage workers (for checkpointing / equivalence checks)."""
    out: Dict[str, Any] = {}
    layer_parts = [w.params["layers"] for w in workers if "layers" in w.params]
    if layer_parts:
        out["layers"] = jax.tree.map(
            lambda *parts: jnp.concatenate(parts, axis=0), *layer_parts)
    for w in workers:
        if w.span.owns_embed:
            out["embed"] = w.params["embed"]
        if w.span.owns_head:
            out["final_norm"] = w.params["final_norm"]
            if not cfg.tie_embeddings:
                out["head"] = w.params["head"]
    return out
