"""Storage-backed serverless execution engine (the executable ground truth
for the analytic stack: perfmodel eq (7) -> simulator DP -> this runtime).

    store          emulated object store + per-worker virtual clocks
    scatter_reduce storage collectives: pipelined eq (2) vs 3-phase eq (1),
                   emulated and wall-clock (thread-concurrent) forms
    worker         stage workers running real JAX for their layer range
    engine         GPipe orchestration of a planner Config for K steps,
                   executing on a pluggable ``repro.serverless.backends``
                   ExecutionBackend (emulated | local | ...)
"""
from repro.serverless.runtime.engine import EngineResult, Execution, run_plan  # noqa: F401
from repro.serverless.runtime.scatter_reduce import (  # noqa: F401
    local_scatter_reduce,
    pipelined_scatter_reduce,
    ring_reduce,
    three_phase_scatter_reduce,
)
from repro.serverless.runtime.store import (  # noqa: F401
    ObjectStore,
    StageChannel,
    StoreStats,
    assert_store_drained,
    effective_bandwidth,
)
from repro.serverless.runtime.worker import (  # noqa: F401
    StageSpan,
    StageWorker,
    assemble_params,
    stage_instance_ranges,
)
