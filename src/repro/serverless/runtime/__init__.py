"""Storage-backed serverless execution engine (the executable ground truth
for the analytic stack: perfmodel eq (7) -> simulator DP -> this runtime).

    store          emulated object store + per-worker virtual clocks
    scatter_reduce storage collectives: pipelined eq (2) vs 3-phase eq (1)
    worker         stage workers running real JAX for their layer range
    engine         GPipe orchestration of a planner Config for K steps
"""
from repro.serverless.runtime.engine import EngineResult, Execution, run_plan  # noqa: F401
from repro.serverless.runtime.scatter_reduce import (  # noqa: F401
    pipelined_scatter_reduce,
    three_phase_scatter_reduce,
)
from repro.serverless.runtime.store import (  # noqa: F401
    ObjectStore,
    StageChannel,
    effective_bandwidth,
)
from repro.serverless.runtime.worker import (  # noqa: F401
    StageSpan,
    StageWorker,
    assemble_params,
    stage_instance_ranges,
)
