"""Orchestrator: run a FuncPipe plan end-to-end through an execution backend.

Takes a profiled model + platform + planner configuration and executes the
GPipe schedule of Fig 3 for K steps on an ``S x d`` grid of serverless
workers: per replica, all micro-batch forwards flow downstream through
activation keys, the reversed backwards flow gradient keys upstream, then
each stage's ``d`` replicas synchronize with a storage scatter-reduce
(pipelined eq (2) or the 3-phase eq (1) baseline).

The orchestrator talks *only* to the :class:`ExecutionBackend` protocol
(``repro.serverless.backends``): each worker's step is expressed once, as a
generator program over its :class:`WorkerContext` (download, compute,
upload, phase fence, sync request), and the backend decides what a clock and
a store are —

  * ``backend="emulated"`` (default): virtual clocks charging the same
    per-stage costs as the analytic simulator (``simulator.stage_aggregates``),
    so the engine's simulated iteration time independently validates
    ``simulate_funcpipe``;
  * ``backend="local"``: the programs run on real concurrent threads over a
    blocking wall-clock store — actual visibility/ordering races, host
    timings, bit-identical trained params.

Two axes of use on any backend:

  * timing-only (``execution=None``): objects carry sizes, not values; used
    by ``benchmarks/runtime_accuracy.py`` for the three-level accuracy table.
  * numeric (``execution=Execution(...)``): K full training steps with real
    JAX stage workers; final params match a monolithic fp32 loop within
    summation-order noise — and match *bit-for-bit* across backends.

Not charged (matching the simulator): input-batch fetches (the shared-
nothing synthetic loader regenerates shards in-function, ``data.synthetic``),
the optimizer update FLOPs, and function cold-starts.

After the last step the engine verifies the store drained — every put
deleted, bytes conserved — on whichever backend ran (the paper's storage
bill depends on exactly this invariant holding across steps).
"""
from __future__ import annotations

import time as _time
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Any, Callable, Dict, List, Optional, Union

import numpy as np

from repro.core.perfmodel import Config
from repro.serverless.execution import ExecutionConfig
from repro.serverless.platform import GB, Platform
from repro.serverless.runtime.store import StoreStats
from repro.serverless.simulator import stage_aggregates, unpack_plan_args

if TYPE_CHECKING:
    # typing only: backends imports runtime.store, so the runtime package
    # must not import backends at module scope (get_backend is pulled in
    # lazily inside run_plan)
    from repro.serverless.backends import ExecutionBackend, WorkerContext


@dataclass(frozen=True)
class Execution:
    """Numeric-execution attachment: which arch to actually run."""

    cfg: Any                                  # ArchConfig
    optimizer: Any                            # repro.optim.Optimizer
    init_params: dict                         # registry.init_params layout
    batch_fn: Callable[[int], dict]           # step -> global batch (leaves [B, ...])
    jit: bool = True                          # jit-cache stage fwd/bwd per shape
    remat: bool = False                       # recompute fwd in bwd (A/B only)
    tolerance: Optional[Any] = None           # faults.FaultTolerance (retry /
    #                                           checkpoint / restart policy)


@dataclass(frozen=True)
class EngineResult:
    t_iter: float                 # seconds per training iteration (backend clock)
    t_total: float                # seconds for all steps (backend clock)
    steps: int
    cost: float                   # $ per iteration (GB-s pricing, all workers)
    n_workers: int
    total_mem_gb: float
    backend: str = "emulated"     # which ExecutionBackend executed the plan
    wall_clock: bool = False      # True: t_* are host seconds, not modeled
    breakdown: Dict[str, float] = field(default_factory=dict)
    metrics: List[Dict[str, float]] = field(default_factory=list)  # per step
    params: Optional[dict] = None          # final assembled params (numeric mode)
    store_stats: Optional[StoreStats] = None
    trace: Optional[Any] = None            # repro.obs.Trace (trace=True runs)
    fault_report: Optional[Any] = None     # faults.FaultReport (chaos /
    #                                        fault-tolerant runs), else None

    @property
    def losses(self) -> List[float]:
        return [m["loss"] for m in self.metrics]


def _split_batch(batch: dict, r: int, d: int, m: int, mu: int):
    """Micro-batch m of replica r from the global batch (row-contiguous)."""
    import jax

    def sl(a):
        B = a.shape[0]
        assert B % (d * mu) == 0, (B, d, mu)
        per_r = B // d
        mb = per_r // mu
        lo = r * per_r + m * mb
        return a[lo:lo + mb]

    return jax.tree.map(sl, batch)


def _worker_step_program(ctx: WorkerContext, *, k: int, s: int, r: int, agg,
                         worker, batch, losses: Dict) -> Any:
    """One stage worker's step-``k`` program over its backend context.

    The single expression of the GPipe schedule from a worker's point of
    view, shared by every backend: ``mu`` forward micro-batches (yield after
    each op group so virtual-clock drivers can interleave workers), the
    fwd/bwd phase fence, ``mu`` backwards in reverse order, then a
    ``("sync", grad_vector)`` yield answered by the backend with the reduced
    gradient, from which the worker applies its optimizer update.
    """
    S, mu, d = agg.S, agg.mu, agg.d
    ce_acc = 0.0
    aux_acc = 0.0

    # ---------------------------------------------------------------- forward
    for m in range(mu):
        x_val, dep = (None, None)
        if s > 0:
            x_val, dep = ctx.download(f"k{k}/r{r}/m{m}/act{s - 1}")
        fn = None
        if worker is not None:
            batch_mb = _split_batch(batch, r, d, m, mu)
            fn = (lambda x_val=x_val, batch_mb=batch_mb, m=m:
                  worker.forward(m, x_val, batch_mb))
        res = ctx.compute(agg.t_fc[s], fn, after=dep)
        out = None
        if worker is not None:
            out, aux = res
            aux_acc += aux / (mu * d)
            if s == S - 1:
                ce_acc += float(out) / (mu * d)
        if s < S - 1:
            ctx.upload(f"k{k}/r{r}/m{m}/act{s}", agg.out_b[s], value=out)
        yield

    # program order: backward downloads wait for forward uploads
    ctx.phase_barrier()

    # --------------------------------------------------------------- backward
    for m in range(mu - 1, -1, -1):
        g_in, dep = (None, None)
        if s < S - 1:
            g_in, dep = ctx.download(f"k{k}/r{r}/m{m}/grad{s}")
        fn = None
        if worker is not None:
            fn = lambda g_in=g_in, m=m: worker.backward(m, g_in)  # noqa: E731
        g_out = ctx.compute(agg.t_bc[s], fn, after=dep)
        if s > 0:
            ctx.upload(f"k{k}/r{r}/m{m}/grad{s - 1}", agg.grad_b[s],
                       value=g_out)
        yield

    # ------------------------------------------------------------------- sync
    vec = worker.grad_vector() if worker is not None else None
    reduced = yield ("sync", vec)
    if worker is not None:
        worker.apply_update(reduced / d, step=k)
        losses[(s, r)] = (ce_acc, aux_acc)


def run_plan(
    profile,
    platform: Optional[Platform] = None,
    config: Optional[Config] = None,
    total_micro_batches: Optional[int] = None,
    exec_config: Optional[ExecutionConfig] = None,
    *,
    steps: Optional[int] = None,
    pipelined_sync: Optional[bool] = None,
    contention: bool = False,
    execution: Optional[Execution] = None,
    backend: Union[None, str, ExecutionBackend] = None,
    trace: Optional[bool] = None,
    faults: Optional[Any] = None,
    tolerance: Optional[Any] = None,
) -> EngineResult:
    """Execute training iterations of the plan through a backend.

    Accepts either the explicit ``(profile, platform, config, M)`` tuple or a
    single :class:`repro.api.DeploymentPlan` as the first argument (see
    ``simulator.unpack_plan_args``).  How to execute — backend, step count,
    tracing, the process backend's calibration axes, fault injection and
    recovery policy — is an :class:`repro.serverless.execution.
    ExecutionConfig` (``exec_config``); the individual ``steps`` / ``backend``
    / ``trace`` / ``faults`` / ``tolerance`` keywords are the deprecated
    legacy spelling of the same settings and may not be mixed with it.
    ``trace=True`` records one span per worker resource task
    (download/compute/upload/barrier, plus per-chunk scatter-reduce
    transfers) on the backend's clock and returns it as
    ``EngineResult.trace`` (a :class:`repro.obs.Trace`).

    Fault tolerance: ``faults`` (a :class:`repro.serverless.faults.FaultPlan`
    or a path to its JSON) wraps the backend in a chaos
    :class:`~repro.serverless.faults.FaultInjector`; ``tolerance`` (a
    :class:`~repro.serverless.faults.FaultTolerance`, also settable via
    ``Execution.tolerance``) enables the recovery machinery — retry with
    backoff on transient store errors, per-stage param/opt checkpoints into
    the object store every N steps, and checkpoint/restart of the whole
    worker grid on a crash or function-lifetime expiry.  A chaos run must
    train to params bit-identical to the fault-free run."""
    ec = ExecutionConfig.merge(
        exec_config,
        dict(backend=backend, steps=steps, trace=trace, faults=faults,
             tolerance=tolerance),
        where="run_plan")
    steps, trace = ec.steps, ec.trace

    # plan-accepting front door: remember the plan so a traced run is
    # self-describing (repro calibrate reads it back out of the file)
    plan_doc = None
    if hasattr(profile, "_as_dict") and hasattr(profile, "resolve"):
        plan_doc = profile._as_dict()
        if plan_doc.get("workload", "train") != "train":
            from repro.api.plan import PlanCompatibilityError

            raise PlanCompatibilityError(
                "run_plan executes *training* plans; this plan for "
                f"{plan_doc.get('model')!r} has "
                f"workload={plan_doc.get('workload')!r}. Serve it through "
                "`repro serve` / repro.serving.run_serve_plan(plan) "
                "instead.")
    profile, platform, config, total_micro_batches, pipelined_sync = \
        unpack_plan_args("run_plan", profile, platform, config,
                         total_micro_batches, pipelined_sync)
    agg = stage_aggregates(profile, platform, config, total_micro_batches,
                           contention=contention)
    S, mu, d = agg.S, agg.mu, agg.d
    be = ec.resolve_backend()

    # ------------------------------------------------- fault-tolerance setup
    # lazy import: runtime/__init__ imports this module at package-import
    # time, and faults.py imports backends (which imports runtime.store)
    report = None
    faults_obj = ec.resolved_faults()
    tol = ec.resolved_tolerance()
    if tol is None and execution is not None:
        tol = execution.tolerance
    if faults_obj is not None or tol is not None:
        from repro.serverless import faults as F

        if faults_obj is not None:
            if tol is None:
                tol = F.FaultTolerance()    # chaos implies recovery
        report = F.FaultReport()
        if faults_obj is not None:
            be = F.FaultInjector(be, faults_obj, report)
        # the Function Manager's lifetime policy: an explicit tolerance cap
        # wins; otherwise the engine knows the platform's cap the same way
        # it knows Lambda's 15 minutes — from the environment (fault plan)
        fm = None
        if tol is not None:
            cap = tol.lifetime_steps
            if cap is None and faults_obj is not None:
                cap = faults_obj.lifetime_steps
            if cap is not None:
                from repro.checkpoint import FunctionManager

                fm = FunctionManager(lifetime_steps=cap,
                                     safety=tol.lifetime_safety)
    else:
        fm = None

    def mk_ctx(s: int, r: int):
        ctx = be.context(s, r)
        if tol is not None:
            ctx = F.ResilientContext(ctx, tol.retry, report)
        return ctx

    recorder = None
    if trace:
        from repro.obs import SpanRecorder

        recorder = SpanRecorder()
        be.attach_recorder(recorder)

    # program-hosting backends (process, real platforms) run the worker
    # programs *inside* their own workers: generators cannot cross a process
    # boundary, so the engine ships the run's execution spec up front and
    # receives RPC worker proxies instead of building StageWorkers in-process
    hosts = bool(getattr(be, "hosts_programs", False))
    if hosts:
        be.bind_run(execution=execution, config=config, tolerance=tol,
                    report=report)

    def make_workers():
        if hosts:
            return be.worker_handles()
        from repro.serverless.runtime.worker import (
            StageWorker,
            stage_instance_ranges,
        )

        spans = stage_instance_ranges(execution.cfg, config.x)
        assert len(spans) == S
        return [[StageWorker(execution.cfg, spans[s], execution.init_params,
                             mu=mu, optimizer=execution.optimizer,
                             jit=execution.jit, remat=execution.remat)
                 for r in range(d)] for s in range(S)]

    be.open(agg)
    workers = make_workers() if execution is not None else None
    metrics_by_step: Dict[int, Dict[str, float]] = {}
    iter_ends: Dict[int, float] = {}
    sync_durations: Dict[int, float] = {}

    # ------------------------------------------------ checkpoint / restart
    last_ckpt_step = -1          # state-after-step index of the newest ckpt
    ckpt_stages: set = set()     # stages with a live ckpt/s{s} object

    def write_checkpoint(k_done: int) -> None:
        """Checkpoint every stage's param/opt state into the object store
        (state after step ``k_done``), charged like any upload.  Replicas
        hold identical state, so one object per stage suffices."""
        nonlocal last_ckpt_step
        from repro.checkpoint import pack_state

        for s in range(S):
            blob = None
            if workers is not None:
                blob = pack_state(workers[s][0].export_state(),
                                  step=k_done + 1)
                nbytes = float(len(blob))
            else:
                # timing-only: fp32 masters + two moments alongside the
                # stage's params — the modeled checkpoint payload
                nbytes = 3.0 * float(agg.s_stage[s])
            mk_ctx(s, 0).upload(f"ckpt/s{s}", nbytes, value=blob)
            ckpt_stages.add(s)
        last_ckpt_step = k_done
        report.checkpoints += 1

    def restore_from_checkpoint() -> None:
        """Relaunch the worker grid from the newest store checkpoint (or
        from scratch when none exists yet): every worker re-fetches its
        stage's state — ``op="restart"`` spans — and resets its transient
        step state.  Bit-identical to having never crashed."""
        nonlocal workers
        from repro.checkpoint import unpack_state

        if last_ckpt_step < 0:
            # nothing persisted yet: rebuild from initial state
            if execution is not None:
                workers = make_workers()
            return
        for s in range(S):
            state = None
            for r in range(d):
                value, _ = mk_ctx(s, r).fetch(f"ckpt/s{s}", op="restart")
                if workers is not None:
                    if state is None:
                        state, _step = unpack_state(
                            value, workers[s][r].export_state())
                    workers[s][r].load_state(state)

    restarts = 0
    steps_since_launch = 0
    pending_restore = False
    k = 0
    try:
        while k < steps:
            try:
                if pending_restore:
                    t0r = _time.perf_counter()
                    restore_from_checkpoint()
                    report.recovery_s += _time.perf_counter() - t0r
                    pending_restore = False
                if fm is not None and fm.should_restart(steps_since_launch):
                    # planned relaunch under the platform's lifetime cap —
                    # checkpoint current progress, recycle the functions,
                    # restore (the paper's Function Manager, §3.1 ⑧)
                    if last_ckpt_step < k - 1:
                        write_checkpoint(k - 1)
                    be.recover()
                    fm.restarted()
                    report.planned_restarts += 1
                    t0r = _time.perf_counter()
                    restore_from_checkpoint()
                    report.recovery_s += _time.perf_counter() - t0r
                    steps_since_launch = 0
                batch = (execution.batch_fn(k)
                         if execution is not None else None)
                losses: Dict = {}
                if hosts:
                    be.stage_step(k, batch=batch, losses=losses)
                programs = {
                    (s, r): _worker_step_program(
                        mk_ctx(s, r), k=k, s=s, r=r, agg=agg,
                        worker=None if workers is None else workers[s][r],
                        batch=batch, losses=losses)
                    for s in range(S) for r in range(d)
                }
                timing = be.run_step(k, programs,
                                     pipelined_sync=pipelined_sync)
            except Exception as e:
                from repro.serverless import faults as F

                if tol is None or not F.is_recoverable(e):
                    raise
                if restarts >= tol.max_restarts:
                    raise F.FaultToleranceExceeded(
                        f"step {k} still failing after {restarts} restarts "
                        f"(max_restarts={tol.max_restarts}): {e}") from e
                restarts += 1
                report.restarts += 1
                be.recover()        # purge residual keys, revive the store
                k = last_ckpt_step + 1
                report.resumed_steps.append(k)
                steps_since_launch = 0
                pending_restore = True
                continue
            # ---------------------------------------------- step succeeded
            # keyed by step index: a replayed step overwrites its earlier,
            # aborted attempt's bookkeeping
            iter_ends[k] = timing.end
            sync_durations[k] = timing.sync
            if workers is not None:
                ce_sum = sum(losses[(S - 1, r)][0] for r in range(d))
                aux_sum = sum(losses[(s, r)][1]
                              for s in range(S) for r in range(d))
                metrics_by_step[k] = {"ce": ce_sum, "aux": aux_sum,
                                      "loss": ce_sum + aux_sum}
            if (tol is not None and tol.checkpoint_every
                    and (k + 1) % tol.checkpoint_every == 0
                    and k + 1 < steps):
                write_checkpoint(k)
            k += 1
            steps_since_launch += 1
        # checkpoint objects are engine-owned state, not leaked traffic:
        # delete them (counted) before asserting the drain invariant
        for s in sorted(ckpt_stages):
            be.delete(f"ckpt/s{s}")
        be.verify_drained()
        stats = be.store_stats
        # assemble before close(): program-hosting backends read final
        # params out of their worker processes, which close() tears down
        params = None
        if workers is not None:
            from repro.serverless.runtime.worker import assemble_params

            params = assemble_params(execution.cfg,
                                     [workers[s][0] for s in range(S)])
    finally:
        be.close()
    metrics = [metrics_by_step[i] for i in sorted(metrics_by_step)]

    t_total = iter_ends[steps - 1]
    t_iter = t_total / steps
    mem_total = d * float(agg.mem.sum())
    cost = platform.price_per_gb_s * (mem_total / GB) * t_iter
    comp = float(agg.t_fc.sum() + agg.t_bc.sum())
    sync_t = float(np.mean([sync_durations[i] for i in sorted(sync_durations)]))
    trace_obj = None
    if recorder is not None:
        from repro.obs import Trace

        trace_obj = Trace(
            spans=recorder.spans,
            meta={
                "model": profile.name,
                "backend": be.name,
                "clock": "wall" if be.wall_clock else "virtual",
                "S": S, "d": d, "mu": mu, "steps": steps,
                "n_workers": agg.n_workers,
                "t_total": float(t_total),
                "t_iter": float(t_iter),
                "step_ends": [float(iter_ends[i]) for i in sorted(iter_ends)],
                "step_syncs": [float(sync_durations[i])
                               for i in sorted(sync_durations)],
                "bandwidth": [float(w) for w in agg.w],
                "t_lat": float(agg.t_lat),
                "pipelined_sync": bool(pipelined_sync),
                "contention": bool(contention),
                "payload_true": bool(ec.payload_true),
                "throttle": bool(ec.throttle),
                "store": stats.as_dict(),
            },
        )
        if report is not None:
            trace_obj.meta["fault_report"] = report.as_dict()
        if plan_doc is not None:
            trace_obj.meta["plan"] = plan_doc
    return EngineResult(
        t_iter=float(t_iter),
        t_total=float(t_total),
        steps=steps,
        cost=float(cost),
        n_workers=agg.n_workers,
        total_mem_gb=mem_total / GB,
        backend=be.name,
        wall_clock=be.wall_clock,
        breakdown={
            "compute": comp,
            "pipeline_comm": float(max(0.0, t_iter - comp - sync_t)) if S > 1 else 0.0,
            "sync": sync_t,
        },
        metrics=metrics,
        params=params,
        store_stats=stats,
        trace=trace_obj,
        fault_report=report,
    )
