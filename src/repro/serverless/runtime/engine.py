"""Orchestrator: run a FuncPipe plan end-to-end through an execution backend.

Takes a profiled model + platform + planner configuration and executes the
GPipe schedule of Fig 3 for K steps on an ``S x d`` grid of serverless
workers: per replica, all micro-batch forwards flow downstream through
activation keys, the reversed backwards flow gradient keys upstream, then
each stage's ``d`` replicas synchronize with a storage scatter-reduce
(pipelined eq (2) or the 3-phase eq (1) baseline).

The orchestrator talks *only* to the :class:`ExecutionBackend` protocol
(``repro.serverless.backends``): each worker's step is expressed once, as a
generator program over its :class:`WorkerContext` (download, compute,
upload, phase fence, sync request), and the backend decides what a clock and
a store are —

  * ``backend="emulated"`` (default): virtual clocks charging the same
    per-stage costs as the analytic simulator (``simulator.stage_aggregates``),
    so the engine's simulated iteration time independently validates
    ``simulate_funcpipe``;
  * ``backend="local"``: the programs run on real concurrent threads over a
    blocking wall-clock store — actual visibility/ordering races, host
    timings, bit-identical trained params.

Two axes of use on any backend:

  * timing-only (``execution=None``): objects carry sizes, not values; used
    by ``benchmarks/runtime_accuracy.py`` for the three-level accuracy table.
  * numeric (``execution=Execution(...)``): K full training steps with real
    JAX stage workers; final params match a monolithic fp32 loop within
    summation-order noise — and match *bit-for-bit* across backends.

Not charged (matching the simulator): input-batch fetches (the shared-
nothing synthetic loader regenerates shards in-function, ``data.synthetic``),
the optimizer update FLOPs, and function cold-starts.

After the last step the engine verifies the store drained — every put
deleted, bytes conserved — on whichever backend ran (the paper's storage
bill depends on exactly this invariant holding across steps).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Any, Callable, Dict, List, Optional, Union

import numpy as np

from repro.core.perfmodel import Config
from repro.serverless.platform import GB, Platform
from repro.serverless.runtime.store import StoreStats
from repro.serverless.simulator import stage_aggregates, unpack_plan_args

if TYPE_CHECKING:
    # typing only: backends imports runtime.store, so the runtime package
    # must not import backends at module scope (get_backend is pulled in
    # lazily inside run_plan)
    from repro.serverless.backends import ExecutionBackend, WorkerContext


@dataclass(frozen=True)
class Execution:
    """Numeric-execution attachment: which arch to actually run."""

    cfg: Any                                  # ArchConfig
    optimizer: Any                            # repro.optim.Optimizer
    init_params: dict                         # registry.init_params layout
    batch_fn: Callable[[int], dict]           # step -> global batch (leaves [B, ...])
    jit: bool = True                          # jit-cache stage fwd/bwd per shape
    remat: bool = False                       # recompute fwd in bwd (A/B only)


@dataclass(frozen=True)
class EngineResult:
    t_iter: float                 # seconds per training iteration (backend clock)
    t_total: float                # seconds for all steps (backend clock)
    steps: int
    cost: float                   # $ per iteration (GB-s pricing, all workers)
    n_workers: int
    total_mem_gb: float
    backend: str = "emulated"     # which ExecutionBackend executed the plan
    wall_clock: bool = False      # True: t_* are host seconds, not modeled
    breakdown: Dict[str, float] = field(default_factory=dict)
    metrics: List[Dict[str, float]] = field(default_factory=list)  # per step
    params: Optional[dict] = None          # final assembled params (numeric mode)
    store_stats: Optional[StoreStats] = None
    trace: Optional[Any] = None            # repro.obs.Trace (trace=True runs)

    @property
    def losses(self) -> List[float]:
        return [m["loss"] for m in self.metrics]


def _split_batch(batch: dict, r: int, d: int, m: int, mu: int):
    """Micro-batch m of replica r from the global batch (row-contiguous)."""
    import jax

    def sl(a):
        B = a.shape[0]
        assert B % (d * mu) == 0, (B, d, mu)
        per_r = B // d
        mb = per_r // mu
        lo = r * per_r + m * mb
        return a[lo:lo + mb]

    return jax.tree.map(sl, batch)


def _worker_step_program(ctx: WorkerContext, *, k: int, s: int, r: int, agg,
                         worker, batch, losses: Dict) -> Any:
    """One stage worker's step-``k`` program over its backend context.

    The single expression of the GPipe schedule from a worker's point of
    view, shared by every backend: ``mu`` forward micro-batches (yield after
    each op group so virtual-clock drivers can interleave workers), the
    fwd/bwd phase fence, ``mu`` backwards in reverse order, then a
    ``("sync", grad_vector)`` yield answered by the backend with the reduced
    gradient, from which the worker applies its optimizer update.
    """
    S, mu, d = agg.S, agg.mu, agg.d
    ce_acc = 0.0
    aux_acc = 0.0

    # ---------------------------------------------------------------- forward
    for m in range(mu):
        x_val, dep = (None, None)
        if s > 0:
            x_val, dep = ctx.download(f"k{k}/r{r}/m{m}/act{s - 1}")
        fn = None
        if worker is not None:
            batch_mb = _split_batch(batch, r, d, m, mu)
            fn = (lambda x_val=x_val, batch_mb=batch_mb, m=m:
                  worker.forward(m, x_val, batch_mb))
        res = ctx.compute(agg.t_fc[s], fn, after=dep)
        out = None
        if worker is not None:
            out, aux = res
            aux_acc += aux / (mu * d)
            if s == S - 1:
                ce_acc += float(out) / (mu * d)
        if s < S - 1:
            ctx.upload(f"k{k}/r{r}/m{m}/act{s}", agg.out_b[s], value=out)
        yield

    # program order: backward downloads wait for forward uploads
    ctx.phase_barrier()

    # --------------------------------------------------------------- backward
    for m in range(mu - 1, -1, -1):
        g_in, dep = (None, None)
        if s < S - 1:
            g_in, dep = ctx.download(f"k{k}/r{r}/m{m}/grad{s}")
        fn = None
        if worker is not None:
            fn = lambda g_in=g_in, m=m: worker.backward(m, g_in)  # noqa: E731
        g_out = ctx.compute(agg.t_bc[s], fn, after=dep)
        if s > 0:
            ctx.upload(f"k{k}/r{r}/m{m}/grad{s - 1}", agg.grad_b[s],
                       value=g_out)
        yield

    # ------------------------------------------------------------------- sync
    vec = worker.grad_vector() if worker is not None else None
    reduced = yield ("sync", vec)
    if worker is not None:
        worker.apply_update(reduced / d, step=k)
        losses[(s, r)] = (ce_acc, aux_acc)


def run_plan(
    profile,
    platform: Optional[Platform] = None,
    config: Optional[Config] = None,
    total_micro_batches: Optional[int] = None,
    *,
    steps: int = 1,
    pipelined_sync: Optional[bool] = None,
    contention: bool = False,
    execution: Optional[Execution] = None,
    backend: Union[str, ExecutionBackend] = "emulated",
    trace: bool = False,
) -> EngineResult:
    """Execute ``steps`` training iterations of the plan through a backend.

    Accepts either the explicit ``(profile, platform, config, M)`` tuple or a
    single :class:`repro.api.DeploymentPlan` as the first argument (see
    ``simulator.unpack_plan_args``).  ``backend`` is a registry name
    (``emulated``, ``local``, ...) or a pre-configured
    :class:`ExecutionBackend` instance.  ``trace=True`` records one span per
    worker resource task (download/compute/upload/barrier, plus per-chunk
    scatter-reduce transfers) on the backend's clock and returns it as
    ``EngineResult.trace`` (a :class:`repro.obs.Trace`)."""
    from repro.serverless.backends import get_backend

    profile, platform, config, total_micro_batches, pipelined_sync = \
        unpack_plan_args("run_plan", profile, platform, config,
                         total_micro_batches, pipelined_sync)
    agg = stage_aggregates(profile, platform, config, total_micro_batches,
                           contention=contention)
    S, mu, d = agg.S, agg.mu, agg.d
    be = get_backend(backend)

    recorder = None
    if trace:
        from repro.obs import SpanRecorder

        recorder = SpanRecorder()
        be.attach_recorder(recorder)

    workers = None
    if execution is not None:
        from repro.serverless.runtime.worker import StageWorker, stage_instance_ranges

        spans = stage_instance_ranges(execution.cfg, config.x)
        assert len(spans) == S
        workers = [[StageWorker(execution.cfg, spans[s], execution.init_params,
                                mu=mu, optimizer=execution.optimizer,
                                jit=execution.jit, remat=execution.remat)
                    for r in range(d)] for s in range(S)]

    be.open(agg)
    metrics: List[Dict[str, float]] = []
    iter_ends: List[float] = []
    sync_durations: List[float] = []

    try:
        for k in range(steps):
            batch = execution.batch_fn(k) if execution is not None else None
            losses: Dict = {}
            programs = {
                (s, r): _worker_step_program(
                    be.context(s, r), k=k, s=s, r=r, agg=agg,
                    worker=None if workers is None else workers[s][r],
                    batch=batch, losses=losses)
                for s in range(S) for r in range(d)
            }
            timing = be.run_step(k, programs, pipelined_sync=pipelined_sync)
            iter_ends.append(timing.end)
            sync_durations.append(timing.sync)
            if workers is not None:
                ce_sum = sum(losses[(S - 1, r)][0] for r in range(d))
                aux_sum = sum(losses[(s, r)][1]
                              for s in range(S) for r in range(d))
                metrics.append({"ce": ce_sum, "aux": aux_sum,
                                "loss": ce_sum + aux_sum})
        be.verify_drained()
        stats = be.store_stats
    finally:
        be.close()

    t_total = iter_ends[-1]
    t_iter = t_total / steps
    mem_total = d * float(agg.mem.sum())
    cost = platform.price_per_gb_s * (mem_total / GB) * t_iter
    comp = float(agg.t_fc.sum() + agg.t_bc.sum())
    sync_t = float(np.mean(sync_durations))
    params = None
    if workers is not None:
        from repro.serverless.runtime.worker import assemble_params

        params = assemble_params(execution.cfg, [workers[s][0] for s in range(S)])

    trace_obj = None
    if recorder is not None:
        from repro.obs import Trace

        trace_obj = Trace(
            spans=recorder.spans,
            meta={
                "model": profile.name,
                "backend": be.name,
                "clock": "wall" if be.wall_clock else "virtual",
                "S": S, "d": d, "mu": mu, "steps": steps,
                "n_workers": agg.n_workers,
                "t_total": float(t_total),
                "t_iter": float(t_iter),
                "step_ends": [float(t) for t in iter_ends],
                "step_syncs": [float(t) for t in sync_durations],
                "bandwidth": [float(w) for w in agg.w],
                "pipelined_sync": bool(pipelined_sync),
                "store": stats.as_dict(),
            },
        )
    return EngineResult(
        t_iter=float(t_iter),
        t_total=float(t_total),
        steps=steps,
        cost=float(cost),
        n_workers=agg.n_workers,
        total_mem_gb=mem_total / GB,
        backend=be.name,
        wall_clock=be.wall_clock,
        breakdown={
            "compute": comp,
            "pipeline_comm": float(max(0.0, t_iter - comp - sync_t)) if S > 1 else 0.0,
            "sync": sync_t,
        },
        metrics=metrics,
        params=params,
        store_stats=stats,
        trace=trace_obj,
    )
