"""Orchestrator: run a FuncPipe plan end-to-end through the emulated store.

Takes a profiled model + platform + planner configuration and executes the
GPipe schedule of Fig 3 for K steps on an ``S x d`` grid of emulated
serverless workers: per replica, all micro-batch forwards flow downstream
through activation keys, the reversed backwards flow gradient keys upstream,
then each stage's ``d`` replicas synchronize with a storage scatter-reduce
(pipelined eq (2) or the 3-phase eq (1) baseline).  Every byte moves through
:class:`ObjectStore`; every task charges the virtual clock with the same
per-stage costs the analytic simulator uses (``simulator.stage_aggregates``),
so the engine's simulated iteration time independently validates
``simulate_funcpipe`` — and, with an :class:`Execution` attached, the
workers run *real JAX* for their layers, validating the plan's numerics
against the monolithic training path.

Two axes of use:

  * timing-only (``execution=None``): objects carry sizes, not values; used
    by ``benchmarks/runtime_accuracy.py`` for the three-level accuracy table.
  * numeric (``execution=Execution(...)``): K full training steps; final
    params match a monolithic fp32 loop within summation-order noise.

Not charged (matching the simulator): input-batch fetches (the shared-
nothing synthetic loader regenerates shards in-function, ``data.synthetic``),
the optimizer update FLOPs, and function cold-starts.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.partition import ModelProfile
from repro.core.perfmodel import Config
from repro.serverless.platform import GB, Platform
from repro.serverless.runtime.scatter_reduce import (
    pipelined_scatter_reduce,
    three_phase_scatter_reduce,
)
from repro.serverless.runtime.store import ObjectStore, StageChannel, StoreStats
from repro.serverless.simulator import stage_aggregates, unpack_plan_args


@dataclass(frozen=True)
class Execution:
    """Numeric-execution attachment: which arch to actually run."""

    cfg: Any                                  # ArchConfig
    optimizer: Any                            # repro.optim.Optimizer
    init_params: dict                         # registry.init_params layout
    batch_fn: Callable[[int], dict]           # step -> global batch (leaves [B, ...])
    jit: bool = True                          # jit-cache stage fwd/bwd per shape
    remat: bool = False                       # recompute fwd in bwd (A/B only)


@dataclass(frozen=True)
class EngineResult:
    t_iter: float                 # simulated seconds per training iteration
    t_total: float                # simulated seconds for all steps
    steps: int
    cost: float                   # $ per iteration (GB-s pricing, all workers)
    n_workers: int
    total_mem_gb: float
    breakdown: Dict[str, float] = field(default_factory=dict)
    metrics: List[Dict[str, float]] = field(default_factory=list)  # per step
    params: Optional[dict] = None          # final assembled params (numeric mode)
    store_stats: Optional[StoreStats] = None

    @property
    def losses(self) -> List[float]:
        return [m["loss"] for m in self.metrics]


def _split_batch(batch: dict, r: int, d: int, m: int, mu: int):
    """Micro-batch m of replica r from the global batch (row-contiguous)."""
    import jax

    def sl(a):
        B = a.shape[0]
        assert B % (d * mu) == 0, (B, d, mu)
        per_r = B // d
        mb = per_r // mu
        lo = r * per_r + m * mb
        return a[lo:lo + mb]

    return jax.tree.map(sl, batch)


def run_plan(
    profile,
    platform: Optional[Platform] = None,
    config: Optional[Config] = None,
    total_micro_batches: Optional[int] = None,
    *,
    steps: int = 1,
    pipelined_sync: Optional[bool] = None,
    contention: bool = False,
    execution: Optional[Execution] = None,
) -> EngineResult:
    """Execute ``steps`` training iterations of the plan through the store.

    Accepts either the explicit ``(profile, platform, config, M)`` tuple or a
    single :class:`repro.api.DeploymentPlan` as the first argument (see
    ``simulator.unpack_plan_args``)."""
    profile, platform, config, total_micro_batches, pipelined_sync = \
        unpack_plan_args("run_plan", profile, platform, config,
                         total_micro_batches, pipelined_sync)
    agg = stage_aggregates(profile, platform, config, total_micro_batches,
                           contention=contention)
    S, mu, d = agg.S, agg.mu, agg.d
    store = ObjectStore(latency=agg.t_lat)
    channels = [[StageChannel(store, agg.w[s], agg.t_lat, name=f"s{s}r{r}")
                 for r in range(d)] for s in range(S)]
    sync_fn = pipelined_scatter_reduce if pipelined_sync else three_phase_scatter_reduce

    workers = None
    if execution is not None:
        from repro.serverless.runtime.worker import StageWorker, stage_instance_ranges

        spans = stage_instance_ranges(execution.cfg, config.x)
        assert len(spans) == S
        workers = [[StageWorker(execution.cfg, spans[s], execution.init_params,
                                mu=mu, optimizer=execution.optimizer,
                                jit=execution.jit, remat=execution.remat)
                    for r in range(d)] for s in range(S)]

    metrics: List[Dict[str, float]] = []
    iter_ends: List[float] = []
    sync_durations: List[float] = []

    for k in range(steps):
        batch = execution.batch_fn(k) if execution is not None else None
        ce_sum = 0.0
        aux_sum = 0.0

        # ---------------------------------------------------------- forward
        for r in range(d):
            for m in range(mu):
                for s in range(S):
                    ch = channels[s][r]
                    x_val = None
                    if s > 0:
                        key = f"k{k}/r{r}/m{m}/act{s - 1}"
                        x_val, _ = ch.download(key)
                        store.delete(key)
                    t_ready = ch.cpu_free if s == 0 else ch.dn_free
                    ch.compute(agg.t_fc[s], ready=t_ready)
                    out = None
                    if workers is not None:
                        batch_mb = _split_batch(batch, r, d, m, mu)
                        out, aux = workers[s][r].forward(m, x_val, batch_mb)
                        aux_sum += aux / (mu * d)
                        if s == S - 1:
                            ce_sum += float(out) / (mu * d)
                    if s < S - 1:
                        ch.upload(f"k{k}/r{r}/m{m}/act{s}", agg.out_b[s],
                                  ready=ch.cpu_free, value=out)

        # program order: backward downloads wait for forward uploads
        for row in channels:
            for ch in row:
                ch.join_uplink_into_downlink()

        # --------------------------------------------------------- backward
        for r in range(d):
            for m in range(mu - 1, -1, -1):
                for s in range(S - 1, -1, -1):
                    ch = channels[s][r]
                    g_in_val = None
                    if s < S - 1:
                        key = f"k{k}/r{r}/m{m}/grad{s}"
                        g_in_val, _ = ch.download(key)
                        store.delete(key)
                    t_ready = ch.cpu_free if s == S - 1 else ch.dn_free
                    ch.compute(agg.t_bc[s], ready=t_ready)
                    g_out = None
                    if workers is not None:
                        g_out = workers[s][r].backward(m, g_in_val)
                    if s > 0:
                        ch.upload(f"k{k}/r{r}/m{m}/grad{s - 1}",
                                  agg.grad_b[s], ready=ch.cpu_free, value=g_out)

        # ------------------------------------------------------------- sync
        step_end = 0.0
        step_sync = 0.0
        for s in range(S):
            row = channels[s]
            done = [row[r].cpu_free if s == 0 else max(row[r].cpu_free, row[r].up_free)
                    for r in range(d)]
            values = None
            if workers is not None:
                values = [workers[s][r].grad_vector() for r in range(d)]
            if d > 1:
                reduced, ends = sync_fn(
                    store, row, agg.s_stage[s], done, values=values,
                    key_prefix=f"k{k}/sync{s}")
            else:
                reduced, ends = (values[0] if values is not None else None), done
            if workers is not None:
                avg = reduced / d
                for r in range(d):
                    workers[s][r].apply_update(avg, step=k)
            stage_end = max(ends)
            step_sync = max(step_sync, stage_end - max(done))
            step_end = max(step_end, stage_end)
            for r in range(d):
                row[r].release_at(ends[r])

        if workers is not None:
            metrics.append({"ce": ce_sum, "aux": aux_sum,
                            "loss": ce_sum + aux_sum})
        iter_ends.append(step_end)
        sync_durations.append(step_sync)

    t_total = iter_ends[-1]
    t_iter = t_total / steps
    mem_total = d * float(agg.mem.sum())
    cost = platform.price_per_gb_s * (mem_total / GB) * t_iter
    comp = float(agg.t_fc.sum() + agg.t_bc.sum())
    sync_t = float(np.mean(sync_durations))
    params = None
    if workers is not None:
        from repro.serverless.runtime.worker import assemble_params

        params = assemble_params(execution.cfg, [workers[s][0] for s in range(S)])
    return EngineResult(
        t_iter=float(t_iter),
        t_total=float(t_total),
        steps=steps,
        cost=float(cost),
        n_workers=agg.n_workers,
        total_mem_gb=mem_total / GB,
        breakdown={
            "compute": comp,
            "pipeline_comm": float(max(0.0, t_iter - comp - sync_t)) if S > 1 else 0.0,
            "sync": sync_t,
        },
        metrics=metrics,
        params=params,
        store_stats=store.stats,
    )
