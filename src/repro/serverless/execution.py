"""One typed surface for "how to execute a plan": :class:`ExecutionConfig`.

Before this module, the execution knobs — backend name, step count, span
tracing, the process backend's payload-true/throttle/bandwidth calibration
axes, fault injection and the retry/checkpoint recovery policy — were
repeated as keyword sprawl across four entry points (``runtime.run_plan``,
``DeploymentPlan.emulate``, ``Session.emulate``, ``repro emulate``), each
with its own copy of the validation ("payload_true requires the process
backend", "--bandwidth implies --throttle", ...).  ExecutionConfig is the
single frozen, JSON-round-trippable home for all of them; every entry point
accepts either an ExecutionConfig or the legacy keywords (shimmed through
:meth:`ExecutionConfig.merge` with a :class:`DeprecationWarning`), and all
validation lives here.

Import discipline: the runtime engine imports this module at module scope,
and ``backends``/``faults`` import ``runtime.store`` — so this module must
import both of those only lazily (inside methods), mirroring the engine's
own rule.
"""
from __future__ import annotations

import dataclasses
import json
import warnings
from dataclasses import dataclass
from typing import Any, Dict, Optional, Union

EXEC_SCHEMA_VERSION = 1

#: legacy keyword -> ExecutionConfig field (identity today; kept explicit so
#: the shim errors out loudly if an entry point grows an unmapped knob)
LEGACY_EXECUTION_KWARGS = ("backend", "steps", "trace", "payload_true",
                           "throttle", "bandwidth", "faults", "tolerance",
                           "retries", "checkpoint_every")


@dataclass(frozen=True)
class ExecutionConfig:
    """How to run a plan through the storage-backed engine.

    ``backend`` is a registry name (``emulated`` / ``local`` / ``process`` /
    ``aws`` / ``oss`` / any ``register_backend``'ed name) or a pre-built
    :class:`~repro.serverless.backends.ExecutionBackend` instance (instances
    execute fine but do not serialize).  ``payload_true`` / ``throttle`` /
    ``bandwidth`` are the process backend's calibrated byte/time axes;
    ``bandwidth`` implies ``throttle``.  ``faults`` is a
    :class:`~repro.serverless.faults.FaultPlan` or a path to its JSON;
    ``tolerance`` a :class:`~repro.serverless.faults.FaultTolerance`;
    ``retries`` / ``checkpoint_every`` are the CLI-style shorthands folded
    into the tolerance by :meth:`resolved_tolerance`.
    """

    backend: Union[str, Any] = "emulated"
    steps: int = 1
    trace: bool = False
    payload_true: bool = False
    throttle: bool = False
    bandwidth: Optional[float] = None     # bytes/s override for the throttle
    faults: Optional[Any] = None          # FaultPlan | path to its JSON
    tolerance: Optional[Any] = None       # FaultTolerance
    retries: Optional[int] = None         # -> tolerance.retry.max_attempts
    checkpoint_every: Optional[int] = None

    # ------------------------------------------------------------ validation
    def __post_init__(self):
        if not isinstance(self.steps, int) or self.steps < 1:
            raise ValueError(f"steps must be a positive int, got "
                             f"{self.steps!r}")
        if self.bandwidth is not None:
            if not self.bandwidth > 0:
                raise ValueError(f"bandwidth must be > 0 bytes/s, got "
                                 f"{self.bandwidth!r}")
            # an explicit bandwidth is only meaningful as a throttle rate
            object.__setattr__(self, "throttle", True)
        for name in ("retries", "checkpoint_every"):
            v = getattr(self, name)
            if v is not None and (not isinstance(v, int) or v < 1):
                raise ValueError(f"{name} must be a positive int, got {v!r}")

    @property
    def needs_process_backend(self) -> bool:
        return bool(self.payload_true or self.throttle
                    or self.bandwidth is not None)

    @staticmethod
    def _process_required_msg() -> str:
        return ("payload_true/throttle/bandwidth need the process backend "
                "(real payloads moving through a real store); pass "
                "backend='process'")

    # ------------------------------------------------------------ legacy shim
    @classmethod
    def merge(cls, exec_config: Optional["ExecutionConfig"],
              legacy: Dict[str, Any], *, where: str) -> "ExecutionConfig":
        """The deprecation shim every entry point routes through: either an
        ExecutionConfig or legacy keywords, never both.  ``legacy`` maps
        keyword name -> value with ``None`` meaning "not passed" (booleans
        included — entry points declare ``trace=None`` etc. so an explicit
        legacy value is distinguishable from the default)."""
        unknown = set(legacy) - set(LEGACY_EXECUTION_KWARGS)
        if unknown:
            raise TypeError(f"{where}: unmapped execution kwargs "
                            f"{sorted(unknown)}")
        passed = {k: v for k, v in legacy.items() if v is not None}
        if exec_config is not None:
            if not isinstance(exec_config, cls):
                raise TypeError(
                    f"{where}: expected an ExecutionConfig, got "
                    f"{type(exec_config).__name__}")
            if passed:
                raise ValueError(
                    f"{where}: pass execution settings either as an "
                    f"ExecutionConfig or as legacy keywords, not both "
                    f"(got ExecutionConfig plus {sorted(passed)})")
            return exec_config
        if passed:
            warnings.warn(
                f"{where}: execution keywords {sorted(passed)} are "
                "deprecated; pass ExecutionConfig(...) instead",
                DeprecationWarning, stacklevel=3)
        return cls(**passed)

    # -------------------------------------------------------------- resolving
    def resolve_backend(self):
        """Instantiate + configure the execution backend.  The single
        authoritative home of the "calibration flags need the process
        backend" rule (entry points used to each carry a copy)."""
        from repro.serverless.backends import ProcessBackend, get_backend

        be = get_backend(self.backend)
        if self.needs_process_backend:
            if not isinstance(be, ProcessBackend):
                raise ValueError(self._process_required_msg())
            be.payload_true = bool(self.payload_true)
            be.throttle = bool(self.throttle)
            if self.bandwidth is not None:
                be.bandwidth = float(self.bandwidth)
        return be

    def resolved_faults(self):
        """The FaultPlan to inject (paths loaded), or None."""
        if self.faults is None:
            return None
        if isinstance(self.faults, str):
            from repro.serverless.faults import FaultPlan

            return FaultPlan.load(self.faults)
        return self.faults

    def resolved_tolerance(self):
        """Fold the ``retries``/``checkpoint_every`` shorthands into a
        FaultTolerance (None when no recovery knob was set at all — the
        engine treats that as "recovery machinery off unless faults are
        injected")."""
        if (self.tolerance is None and self.retries is None
                and self.checkpoint_every is None):
            return None
        from repro.serverless.faults import FaultTolerance

        tol = self.tolerance if self.tolerance is not None else FaultTolerance()
        if self.retries is not None:
            tol = dataclasses.replace(
                tol, retry=dataclasses.replace(tol.retry,
                                               max_attempts=self.retries))
        if self.checkpoint_every is not None:
            tol = dataclasses.replace(tol,
                                      checkpoint_every=self.checkpoint_every)
        return tol

    # --------------------------------------------------------- serialization
    def _as_dict(self) -> dict:
        if not isinstance(self.backend, str):
            raise TypeError(
                "ExecutionConfig with a backend *instance* does not "
                "serialize — construct it with the registry name instead "
                f"(got {type(self.backend).__name__})")
        d = dataclasses.asdict(self)
        if self.faults is not None and not isinstance(self.faults, str):
            # embed the fault plan's own JSON document (it is versioned)
            d["faults"] = {"fault_plan": json.loads(self.faults.to_json())}
        if self.tolerance is not None:
            d["tolerance"] = dataclasses.asdict(self.tolerance)
        d["version"] = EXEC_SCHEMA_VERSION
        return d

    def to_json(self, *, indent: Optional[int] = 2) -> str:
        return json.dumps(self._as_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, blob: str) -> "ExecutionConfig":
        d = json.loads(blob)
        version = d.pop("version", None)
        if version != EXEC_SCHEMA_VERSION:
            raise ValueError(f"execution config schema version {version!r} "
                             f"!= supported {EXEC_SCHEMA_VERSION}")
        names = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - names
        if unknown:
            raise ValueError(f"execution config JSON has unknown fields "
                             f"{sorted(unknown)}")
        if isinstance(d.get("faults"), dict):
            from repro.serverless.faults import FaultPlan

            d["faults"] = FaultPlan.from_json(
                json.dumps(d["faults"]["fault_plan"]))
        if d.get("tolerance") is not None:
            from repro.serverless.faults import FaultTolerance, RetryPolicy

            t = dict(d["tolerance"])
            t["retry"] = RetryPolicy(**t["retry"])
            d["tolerance"] = FaultTolerance(**t)
        return cls(**d)
