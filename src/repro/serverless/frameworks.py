"""The evaluated training designs (§5.1 baselines + FuncPipe itself), each a
resource-allocation policy over the simulator.

  LambdaML     — pure DP; max memory per worker, max local batch in memory.
  HybridPS     — DP with a parameter-server VM for synchronization.
  LambdaML-GA / HybridPS-GA — gradient accumulation (micro-batch 1) with the
                 minimum feasible memory per worker.
  FuncPipe     — pipeline plan from the MIQP co-optimizer (core.planner).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.partition import ModelProfile
from repro.core import planner
from repro.serverless.platform import Platform
from repro.serverless.simulator import SimResult, simulate_data_parallel, simulate_funcpipe


def _max_local_batch(profile, platform, mem, micro_batch, n_workers) -> int:
    arr = profile.arrays()
    per_mb_act = arr["a"].sum()  # bytes per micro-batch
    sync_f = 4 if n_workers > 1 else 2
    budget = mem - arr["s"].sum() * sync_f - platform.base_memory
    if budget <= 0:
        return 0
    n_mb = int(budget // per_mb_act)
    return n_mb * micro_batch


def lambda_ml(
    profile: ModelProfile,
    platform: Platform,
    global_batch: int,
    *,
    micro_batch: int = 4,
    sync: str = "scatter_reduce",
    grad_accum: bool = False,
    contention: bool = False,
    ps: bool = False,
) -> Optional[SimResult]:
    """LambdaML policy: max memory, max local batch -> fewest workers."""
    J = len(platform.memory_options)
    if grad_accum:
        # min memory that fits ONE micro-batch of size 1
        arr = profile.arrays()
        per_sample_act = arr["a"].sum() / micro_batch
        for j in range(J):
            mem = platform.memory_options[j]
            if per_sample_act + arr["s"].sum() * 4 + platform.base_memory <= mem:
                break
        else:
            return None
        # same worker count as non-GA LambdaML for comparability (paper §5.1)
        base = lambda_ml(profile, platform, global_batch, micro_batch=micro_batch,
                         sync=sync, contention=contention, ps=ps)
        if base is None:
            return None
        n_workers = base.n_workers
        return simulate_data_parallel(
            profile, platform, n_workers=n_workers, mem_index=j,
            samples_per_worker=global_batch // n_workers, micro_batch=1,
            sync="ps" if ps else sync, grad_accum=True, contention=contention,
        )
    j = J - 1
    mem = platform.memory_options[j]
    local = _max_local_batch(profile, platform, mem, micro_batch, n_workers=2)
    if local <= 0:
        return None
    local = min(local, global_batch)
    n_workers = max(1, -(-global_batch // local))
    local = global_batch // n_workers
    return simulate_data_parallel(
        profile, platform, n_workers=n_workers, mem_index=j,
        samples_per_worker=local, micro_batch=micro_batch,
        sync="ps" if ps else sync, contention=contention,
    )


def hybrid_ps(profile, platform, global_batch, *, micro_batch: int = 4,
              grad_accum: bool = False, contention: bool = False):
    return lambda_ml(profile, platform, global_batch, micro_batch=micro_batch,
                     grad_accum=grad_accum, contention=contention, ps=True)


@dataclass(frozen=True)
class FuncPipeResult:
    plans: List[planner.PlanResult]
    sims: List[SimResult]
    recommended: int  # index into plans/sims
    deployment_plans: Optional[List] = None  # DeploymentPlans when replayed
    engine_results: Optional[List] = None    # EngineResults when executed

    @property
    def recommended_sim(self) -> SimResult:
        return self.sims[self.recommended]


# the paper's four weight pairs (§5.1); scaled: cost in $, time in s
ALPHA_PAIRS: Tuple[Tuple[float, float], ...] = (
    (1.0, 0.0),
    (1.0, 2**16 * 1e-9),
    (1.0, 2**19 * 1e-9),
    (1.0, 2**22 * 1e-9),
)


def funcpipe_replay(
    deployment_plans: Sequence,
    *,
    contention: bool = False,
    backend: Optional[str] = None,
    engine_steps: int = 1,
) -> Optional[FuncPipeResult]:
    """The FuncPipe policy over saved :class:`repro.api.DeploymentPlan`
    artifacts — no solver run.  Each plan is resolved (fingerprint-checked
    against its recorded model/platform), identical configs are deduped,
    then simulated under this call's ``contention`` setting and fed through
    the same §5.1 recommendation as :func:`funcpipe`.

    With ``backend`` set (``"emulated"``, ``"local"``, or any registered
    execution backend), every kept plan is additionally *executed* through
    the storage-backed engine on that backend for ``engine_steps`` steps
    (timing axis), and the per-plan ``EngineResult``s ride along on
    ``FuncPipeResult.engine_results``."""
    from repro.core.perfmodel import evaluate

    uniq, sims, kept = [], [], []
    engine_results: Optional[List] = [] if backend is not None else None
    seen = set()
    for p in deployment_plans:
        key = (p.x, p.d, p.z)       # dedupe before the profile rebuild
        if key in seen:
            continue
        seen.add(key)
        rp = p.resolve()
        ev = evaluate(rp.profile, rp.platform, rp.config,
                      rp.total_micro_batches,
                      pipelined_sync=rp.pipelined_sync)
        uniq.append(planner.PlanResult(
            rp.config, ev, ev.objective(*p.alpha), p.solve_seconds,
            rp.profile))
        sims.append(simulate_funcpipe(
            rp.profile, rp.platform, rp.config, rp.total_micro_batches,
            pipelined_sync=rp.pipelined_sync, contention=contention))
        if engine_results is not None:
            from repro.serverless.execution import ExecutionConfig
            from repro.serverless.runtime import run_plan

            engine_results.append(run_plan(
                rp.profile, rp.platform, rp.config, rp.total_micro_batches,
                ExecutionConfig(steps=engine_steps, backend=backend),
                pipelined_sync=rp.pipelined_sync, contention=contention))
        kept.append(p)
    if not uniq:
        return None
    rec = uniq.index(planner.recommend(uniq))
    return FuncPipeResult(plans=uniq, sims=sims, recommended=rec,
                          deployment_plans=kept,
                          engine_results=engine_results)


def funcpipe(
    profile: ModelProfile,
    platform: Platform,
    global_batch: int,
    *,
    micro_batch: int = 4,
    alphas: Sequence[Tuple[float, float]] = ALPHA_PAIRS,
    merge_to: int = 8,
    pipelined_sync: bool = True,
    contention: bool = False,
    d_options: Sequence[int] = planner.DEFAULT_D_OPTIONS,
) -> Optional[FuncPipeResult]:
    """FuncPipe policy: co-optimized plans across the objective weights.

    To replay saved DeploymentPlans instead of solving, use
    :func:`funcpipe_replay`."""
    M = max(1, global_batch // micro_batch)
    plans = []
    for alpha in alphas:
        r = planner.solve(profile, platform, alpha=alpha, total_micro_batches=M,
                          merge_to=merge_to, pipelined_sync=pipelined_sync,
                          d_options=d_options)
        if r is not None:
            plans.append(r)
    if not plans:
        return None
    # dedupe identical configs
    uniq = []
    seen = set()
    for r in plans:
        key = (r.config.x, r.config.d, r.config.z)
        if key not in seen:
            seen.add(key)
            uniq.append(r)
    sims = [
        simulate_funcpipe(r.profile, platform, r.config, M,
                          pipelined_sync=pipelined_sync, contention=contention)
        for r in uniq
    ]
    rec_plan = planner.recommend(uniq)
    rec = uniq.index(rec_plan)
    return FuncPipeResult(plans=uniq, sims=sims, recommended=rec)
