"""Discrete-event simulation of serverless training (independent of the
closed-form performance model — used to validate it, Table 3 analog).

Each pipeline worker owns three serial resources: CPU, uplink, downlink.
Tasks are processed in the GPipe order of Fig 3 (all micro-batch forwards,
then reversed backwards, then sync), so the event-driven simulation reduces
to a longest-path DP over task end-times with per-resource serialization.

Also simulates the data-parallel baselines (LambdaML / HybridPS, ±gradient
accumulation) under the same platform model.

This module stays *analytic*: it never moves bytes or runs layer math.  The
executable ground truth is ``repro.serverless.runtime`` — an emulated object
store plus stage workers that run the same schedule with real JAX numerics
and per-object transfers (``stage_aggregates`` below is the shared cost
model).  ``benchmarks/runtime_accuracy.py`` cross-validates the three levels
(closed form vs this DP vs the runtime engine).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from repro.core.partition import ModelProfile, stages_of
from repro.core.perfmodel import (
    Config,
    perf_tables,
    sync_time_nonpipelined,
    sync_time_pipelined,
)
from repro.serverless.platform import GB, Platform


@dataclass(frozen=True)
class SimResult:
    t_iter: float
    cost: float
    n_workers: int
    total_mem_gb: float
    breakdown: Dict[str, float] = field(default_factory=dict)
    # predicted repro.obs.Trace (simulate_funcpipe(..., trace=True) only)
    trace: Optional[object] = None

    @property
    def throughput(self) -> float:  # samples/s given meta in breakdown
        return self.breakdown.get("samples", 0.0) / self.t_iter


def bandwidth_contention(n_workers: int, knee: int = 16, exp: float = 0.25) -> float:
    """Per-worker bandwidth multiplier: platforms co-locate functions, so
    per-function bandwidth degrades past ~``knee`` concurrent workers
    (paper §5.4 observation)."""
    if n_workers <= knee:
        return 1.0
    return (knee / n_workers) ** exp


def storage_capped_bw(platform: Platform, w: float, n_workers: int) -> float:
    """§5.7: Alibaba OSS (and Azure storage) cap TOTAL concurrent storage
    bandwidth; with n workers hitting storage at once each sees at most
    cap/n.  AWS S3 is modeled uncapped (paper §5.1)."""
    cap = platform.storage_total_bandwidth
    if cap is None or n_workers <= 0:
        return w
    return min(w, cap / n_workers)


def effective_bandwidth(
    platform: Platform, mem: int, n_workers: int, *, contention: bool = False
) -> float:
    """Per-worker storage bandwidth under §5.4 contention + §5.7 caps — the
    single derivation shared by the DP below and the runtime engine."""
    w = platform.bandwidth(mem)
    if contention:
        w *= bandwidth_contention(n_workers)
    return storage_capped_bw(platform, w, n_workers)


# --------------------------------------------------- shared per-stage costs
@dataclass(frozen=True)
class StageAggregates:
    """Per-stage cost terms of a FuncPipe configuration.

    Shared between the longest-path DP below and the executable runtime
    (``repro.serverless.runtime.engine``) so both charge identical compute
    times, boundary-transfer times, effective bandwidths (§5.4 contention +
    §5.7 storage-side caps) and per-stage memory."""

    S: int                    # number of pipeline stages
    mu: int                   # micro-batches per worker
    d: int                    # data-parallel degree
    n_workers: int            # S * d
    t_lat: float              # storage latency
    t_fc: np.ndarray          # [S] forward compute per micro-batch
    t_bc: np.ndarray          # [S] backward compute per micro-batch
    w: np.ndarray             # [S] effective per-worker storage bandwidth
    out_b: np.ndarray         # [S] forward boundary bytes (stage output)
    grad_b: np.ndarray        # [S] backward boundary bytes (grad at stage lo)
    s_stage: np.ndarray       # [S] parameter bytes per stage
    mem: np.ndarray           # [S] allocated function memory (bytes)
    t_up_f: np.ndarray        # [S] fwd boundary upload time (stage s -> store)
    t_dn_f: np.ndarray        # [S] fwd boundary download time (store -> stage s)
    t_up_b: np.ndarray        # [S] bwd boundary upload time
    t_dn_b: np.ndarray        # [S] bwd boundary download time


def stage_aggregates(
    profile: ModelProfile,
    platform: Platform,
    config: Config,
    total_micro_batches: int,
    *,
    contention: bool = False,
) -> StageAggregates:
    tables = perf_tables(profile, platform)   # shared with evaluate/evaluate_batch
    x = np.asarray(config.x)
    d = config.d
    mu = max(1, total_micro_batches // d)
    stages = stages_of(x)
    S = len(stages)
    z = np.asarray(config.z)
    t_lat = tables.t_lat
    L = tables.L
    los = np.array([lo for lo, _ in stages])
    his = np.array([hi for _, hi in stages])

    n_workers = S * d

    # per-stage aggregates (memory option constant within stage) from the
    # precomputed per-(layer, option) tables — same beta-scaled compute terms
    # the closed-form model charges
    lidx = np.arange(L)
    t_fc = np.add.reduceat(tables.Tf_beta[lidx, z], los)
    t_bc = np.add.reduceat(tables.Tb_beta[lidx, z], los)
    w = np.array([
        effective_bandwidth(platform, platform.memory_options[z[lo]], n_workers,
                            contention=contention)
        for lo in los
    ])
    out_b = tables.o[his]                                          # fwd boundary
    grad_b = tables.g[los]                                         # bwd boundary
    s_stage = np.add.reduceat(tables.s, los)
    mem = tables.mem_opts[z[los]]

    t_up_f = out_b / w + t_lat      # stage s uploads its output
    t_dn_f = np.empty(S)
    t_dn_f[1:] = out_b[:-1] / w[1:] + t_lat
    t_dn_f[0] = 0.0
    t_up_b = grad_b / w + t_lat     # stage s uploads grad toward s-1
    t_dn_b = np.empty(S)
    t_dn_b[:-1] = grad_b[1:] / w[:-1] + t_lat
    t_dn_b[-1] = 0.0
    return StageAggregates(
        S=S, mu=mu, d=d, n_workers=n_workers, t_lat=t_lat,
        t_fc=t_fc, t_bc=t_bc, w=w, out_b=out_b, grad_b=grad_b,
        s_stage=s_stage, mem=mem,
        t_up_f=t_up_f, t_dn_f=t_dn_f, t_up_b=t_up_b, t_dn_b=t_dn_b,
    )


def unpack_plan_args(fn_name, profile, platform, config, total_micro_batches,
                     pipelined_sync):
    """Shared DeploymentPlan front door for the plan-accepting entry points
    (this module's :func:`simulate_funcpipe` and ``runtime.run_plan``): a
    plan as the first argument is resolved — profile rebuilt +
    fingerprint-checked — and its recorded sync algorithm used unless
    ``pipelined_sync`` overrides it.  Mixing a plan with explicit
    platform/config/M is rejected rather than silently ignored."""
    if not isinstance(profile, ModelProfile):
        if not hasattr(profile, "resolve"):
            raise TypeError(
                f"{fn_name} takes (profile, platform, config, M) or a "
                f"DeploymentPlan as first argument, got "
                f"{type(profile).__name__}")
        if platform is not None or config is not None \
                or total_micro_batches is not None:
            raise ValueError(
                f"{fn_name}(plan, ...) takes no platform/config/"
                "total_micro_batches — they are recorded in the plan; use "
                "plan.resolve(platform=...) for overrides")
        rp = profile.resolve()
        if pipelined_sync is None:
            pipelined_sync = rp.pipelined_sync
        profile, platform, config = rp.profile, rp.platform, rp.config
        total_micro_batches = rp.total_micro_batches
    if pipelined_sync is None:
        pipelined_sync = True
    return profile, platform, config, total_micro_batches, pipelined_sync


# ------------------------------------------------------------------- FuncPipe
def simulate_funcpipe(
    profile,
    platform: Optional[Platform] = None,
    config: Optional[Config] = None,
    total_micro_batches: Optional[int] = None,
    *,
    pipelined_sync: Optional[bool] = None,
    contention: bool = False,
    trace: bool = False,
) -> SimResult:
    """Simulate one FuncPipe iteration.

    Accepts either the explicit ``(profile, platform, config, M)`` tuple or
    a single :class:`repro.api.DeploymentPlan` as the first argument (see
    :func:`unpack_plan_args`).  ``trace=True`` additionally materializes the
    DP's task intervals as *predicted* spans — one representative replica
    (r=0) per stage, one step — in the same ``repro.obs`` schema the runtime
    backends emit, returned as ``SimResult.trace`` for gap attribution."""
    profile, platform, config, total_micro_batches, pipelined_sync = \
        unpack_plan_args("simulate_funcpipe", profile, platform, config,
                         total_micro_batches, pipelined_sync)
    agg = stage_aggregates(profile, platform, config, total_micro_batches,
                           contention=contention)
    S, mu, d = agg.S, agg.mu, agg.d
    t_lat = agg.t_lat
    t_fc, t_bc, w = agg.t_fc, agg.t_bc, agg.w
    s_stage = agg.s_stage
    t_up_f, t_dn_f, t_up_b, t_dn_b = agg.t_up_f, agg.t_dn_f, agg.t_up_b, agg.t_dn_b
    n_workers = agg.n_workers

    NEG = 0.0
    fwd_d_end = np.zeros((S, mu))
    fwd_c_end = np.zeros((S, mu))
    fwd_u_end = np.zeros((S, mu))
    for m in range(mu):
        for s in range(S):
            if s == 0:
                ready = 0.0
            else:
                prev_dn = fwd_d_end[s, m - 1] if m else NEG
                fwd_d_end[s, m] = max(fwd_u_end[s - 1, m], prev_dn) + t_dn_f[s]
                ready = fwd_d_end[s, m]
            prev_c = fwd_c_end[s, m - 1] if m else NEG
            fwd_c_end[s, m] = max(ready, prev_c) + t_fc[s]
            if s < S - 1:
                prev_u = fwd_u_end[s, m - 1] if m else NEG
                fwd_u_end[s, m] = max(fwd_c_end[s, m], prev_u) + t_up_f[s]

    bwd_d_end = np.zeros((S, mu))
    bwd_c_end = np.zeros((S, mu))
    bwd_u_end = np.zeros((S, mu))
    for mi, m in enumerate(range(mu - 1, -1, -1)):  # reversed micro-batch order
        for s in range(S - 1, -1, -1):
            if s == S - 1:
                ready = fwd_c_end[s, mu - 1]
            else:
                prev_dn = bwd_d_end[s, m + 1] if mi else NEG
                bwd_d_end[s, m] = max(bwd_u_end[s + 1, m], prev_dn, fwd_u_end[s, mu - 1]) + t_dn_b[s]
                ready = bwd_d_end[s, m]
            prev_c = bwd_c_end[s, m + 1] if mi else fwd_c_end[s, mu - 1]
            bwd_c_end[s, m] = max(ready, prev_c) + t_bc[s]
            if s > 0:
                prev_u = bwd_u_end[s, m + 1] if mi else fwd_u_end[s, mu - 1]
                bwd_u_end[s, m] = max(bwd_c_end[s, m], prev_u) + t_up_b[s]

    sync_fn = sync_time_pipelined if pipelined_sync else sync_time_nonpipelined
    end = 0.0
    sync_total = 0.0
    sync_spans = []                                      # (s, done, ts)
    for s in range(S):
        done = bwd_c_end[s, 0] if S == 1 else max(bwd_c_end[s, 0], bwd_u_end[s, 0] if s > 0 else 0.0)
        ts = sync_fn(s_stage[s], w[s], d, t_lat) if d > 1 else 0.0
        sync_total = max(sync_total, ts)
        end = max(end, done + ts)
        sync_spans.append((s, done, ts))

    trace_obj = None
    if trace:
        trace_obj = _predicted_trace(
            profile, agg, fwd_d_end, fwd_c_end, fwd_u_end,
            bwd_d_end, bwd_c_end, bwd_u_end, sync_spans,
            end=float(end), pipelined_sync=pipelined_sync)

    mem_total = d * float(agg.mem.sum())
    cost = platform.price_per_gb_s * (mem_total / GB) * end
    comp = float(t_fc.sum() + t_bc.sum())
    return SimResult(
        t_iter=float(end),
        cost=float(cost),
        n_workers=n_workers,
        total_mem_gb=mem_total / GB,
        breakdown={
            "compute": comp,
            "pipeline_comm": float(end - comp - sync_total) if S > 1 else 0.0,
            "sync": float(sync_total),
        },
        trace=trace_obj,
    )


def _predicted_trace(profile, agg: StageAggregates,
                     fwd_d_end, fwd_c_end, fwd_u_end,
                     bwd_d_end, bwd_c_end, bwd_u_end, sync_spans,
                     *, end: float, pipelined_sync: bool):
    """Materialize the longest-path DP's task intervals as predicted spans.

    Every DP cell already *is* a task end-time on a serial resource, so the
    span is just ``[end - duration, end]`` with the shared cost-model sizes
    attached — same schema, keys and phase labels as the runtime backends
    (step 0, replica 0: the DP models one representative replica; the sync
    term is emitted as a single aggregate ``op="sync"`` span per stage, not
    per chunk, because eq (1)/(2) are closed forms)."""
    from repro.obs import Span, Trace

    S, mu, d = agg.S, agg.mu, agg.d
    spans = []
    for m in range(mu):
        for s in range(S):
            if s > 0:
                spans.append(Span(
                    stage=s, replica=0, step=0, phase="fwd", op="download",
                    start=float(fwd_d_end[s, m] - agg.t_dn_f[s]),
                    end=float(fwd_d_end[s, m]),
                    nbytes=float(agg.out_b[s - 1]),
                    key=f"k0/r0/m{m}/act{s - 1}"))
            spans.append(Span(
                stage=s, replica=0, step=0, phase="fwd", op="compute",
                start=float(fwd_c_end[s, m] - agg.t_fc[s]),
                end=float(fwd_c_end[s, m])))
            if s < S - 1:
                spans.append(Span(
                    stage=s, replica=0, step=0, phase="fwd", op="upload",
                    start=float(fwd_u_end[s, m] - agg.t_up_f[s]),
                    end=float(fwd_u_end[s, m]),
                    nbytes=float(agg.out_b[s]),
                    key=f"k0/r0/m{m}/act{s}"))
    for m in range(mu - 1, -1, -1):
        for s in range(S - 1, -1, -1):
            if s < S - 1:
                spans.append(Span(
                    stage=s, replica=0, step=0, phase="bwd", op="download",
                    start=float(bwd_d_end[s, m] - agg.t_dn_b[s]),
                    end=float(bwd_d_end[s, m]),
                    nbytes=float(agg.grad_b[s + 1]),
                    key=f"k0/r0/m{m}/grad{s}"))
            spans.append(Span(
                stage=s, replica=0, step=0, phase="bwd", op="compute",
                start=float(bwd_c_end[s, m] - agg.t_bc[s]),
                end=float(bwd_c_end[s, m])))
            if s > 0:
                spans.append(Span(
                    stage=s, replica=0, step=0, phase="bwd", op="upload",
                    start=float(bwd_u_end[s, m] - agg.t_up_b[s]),
                    end=float(bwd_u_end[s, m]),
                    nbytes=float(agg.grad_b[s]),
                    key=f"k0/r0/m{m}/grad{s - 1}"))
    if d > 1:
        for s, done, ts in sync_spans:
            spans.append(Span(
                stage=s, replica=0, step=0, phase="sync", op="sync",
                start=float(done), end=float(done + ts),
                nbytes=float(agg.s_stage[s])))
    return Trace(
        spans=spans,
        meta={
            "model": profile.name,
            "backend": "predicted",
            "clock": "virtual",
            "S": S, "d": d, "mu": mu, "steps": 1,
            "n_workers": agg.n_workers,
            "t_total": end,
            "t_iter": end,
            "bandwidth": [float(x) for x in agg.w],
            "pipelined_sync": bool(pipelined_sync),
        },
    )


# ------------------------------------------------------- data-parallel designs
def simulate_data_parallel(
    profile: ModelProfile,
    platform: Platform,
    *,
    n_workers: int,
    mem_index: int,
    samples_per_worker: int,
    micro_batch: int,
    sync: str = "scatter_reduce",          # scatter_reduce | pipelined | ps
    grad_accum: bool = False,
    ps_bandwidth: float = 10e9 / 8,
    ps_price_per_s: float = 1.53 / 3600.0,  # c5.9xlarge
    contention: bool = False,
) -> SimResult:
    """One iteration of DP training (LambdaML / HybridPS + GA variants)."""
    arr = profile.arrays()
    mem = platform.memory_options[mem_index]
    w = platform.bandwidth(mem)
    if contention:
        w *= bandwidth_contention(n_workers)
    w_storage = storage_capped_bw(platform, w, n_workers)
    s_grad = arr["s"].sum()
    t_lat = platform.storage_latency

    n_mb = max(1, samples_per_worker // micro_batch)
    comp = (arr["Tf"][:, mem_index].sum() + arr["Tb"][:, mem_index].sum()) * n_mb
    if grad_accum:
        comp *= 1.10  # per-step overhead of accumulation

    if n_workers == 1:
        sync_t = 0.0
    elif sync == "ps":
        eff = min(w, ps_bandwidth / n_workers)
        sync_t = 2 * s_grad / eff + 2 * t_lat
    elif sync == "pipelined":
        sync_t = sync_time_pipelined(s_grad, w_storage, n_workers, t_lat)
    else:
        sync_t = sync_time_nonpipelined(s_grad, w_storage, n_workers, t_lat)

    t_iter = comp + sync_t
    cost = platform.price_per_gb_s * (mem / GB) * t_iter * n_workers
    if sync == "ps" and n_workers > 1:
        cost += ps_price_per_s * t_iter
    return SimResult(
        t_iter=float(t_iter),
        cost=float(cost),
        n_workers=n_workers,
        total_mem_gb=n_workers * mem / GB,
        breakdown={"compute": float(comp), "sync": float(sync_t),
                   "samples": float(n_workers * samples_per_worker)},
    )
