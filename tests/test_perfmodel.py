"""Properties of the paper's performance model + validation against the
discrete-event simulator (Table 3 analog)."""
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core import planner
from repro.core.perfmodel import (
    Config,
    evaluate,
    sync_time_nonpipelined,
    sync_time_pipelined,
)
from repro.core.profiler import paper_model_profile
from repro.core.partition import merge_layers
from repro.serverless.platform import AWS_LAMBDA
from repro.serverless.simulator import simulate_funcpipe


# ------------------------------------------------ eq (1) vs eq (2) properties
@given(
    s=st.floats(1e6, 2e9),
    w=st.floats(1e6, 1e9),
    n=st.integers(2, 64),
    t_lat=st.floats(0.0, 0.05),
)
@settings(max_examples=300, deadline=None)
def test_pipelined_sync_beats_nonpipelined(s, w, n, t_lat):
    """Eq (2) < eq (1) whenever transfer dominates latency: the pipelined
    schedule saves (1 - 2/n) * s/w transfer at the price of (n - 2) * t_lat."""
    t1 = sync_time_nonpipelined(s, w, n, t_lat)
    t2 = sync_time_pipelined(s, w, n, t_lat)
    saving = (1 - 2 / n) * s / w
    extra_lat = (n - 2) * t_lat
    if saving > extra_lat:
        assert t2 < t1
    assert t1 == pytest.approx(3 * s / w - 2 * s / (n * w) + 4 * t_lat)
    assert t2 == pytest.approx(2 * s / w + (2 + n) * t_lat)


def test_paper_numeric_example():
    """§3.3: 280 MB model, 8 workers, 70 MB/s -> transfer 11s -> 8s (~27%)."""
    s, w, n = 280e6, 70e6, 8
    t1 = sync_time_nonpipelined(s, w, n, 0.0)
    t2 = sync_time_pipelined(s, w, n, 0.0)
    assert t1 == pytest.approx(11.0, rel=0.05)
    assert t2 == pytest.approx(8.0, rel=0.05)
    assert (t1 - t2) / t1 == pytest.approx(0.27, abs=0.02)


# ------------------------------------------------------- model vs simulator
@pytest.mark.parametrize("model", ["amoebanet-d18", "bert-large"])
@pytest.mark.parametrize("alpha", [(1.0, 0.0), (1.0, 2**19 * 1e-9)])
def test_perfmodel_matches_simulator(model, alpha):
    """Analytical t_iter within ~20% of the discrete-event simulation (the
    paper reports ~11% mean error against the real system, App. E)."""
    prof = paper_model_profile(model, AWS_LAMBDA)
    M = 16
    r = planner.solve(prof, AWS_LAMBDA, alpha=alpha, total_micro_batches=M, merge_to=8)
    assert r is not None
    sim = simulate_funcpipe(r.profile, AWS_LAMBDA, r.config, M)
    err = abs(sim.t_iter - r.evaluation.t_iter) / sim.t_iter
    assert err < 0.25, (sim.t_iter, r.evaluation.t_iter)


def test_bandwidth_monotonicity():
    """More memory (=> more bandwidth/CPU) never slows an identical plan."""
    prof = merge_layers(paper_model_profile("amoebanet-d18", AWS_LAMBDA), 6)
    L = prof.L
    x = tuple(1 if i == L // 2 else 0 for i in range(L - 1))
    prev = None
    for j in range(len(AWS_LAMBDA.memory_options)):
        cfg = Config(x=x, d=4, z=tuple([j] * L))
        ev = evaluate(prof, AWS_LAMBDA, cfg, 16)
        if prev is not None:
            assert ev.t_iter <= prev + 1e-9
        prev = ev.t_iter


def test_memory_constraint_enforced():
    prof = merge_layers(paper_model_profile("amoebanet-d36", AWS_LAMBDA), 6)
    L = prof.L
    cfg = Config(x=tuple([0] * (L - 1)), d=1, z=tuple([0] * L))  # all on 512MB
    ev = evaluate(prof, AWS_LAMBDA, cfg, 16)
    assert not ev.mem_ok  # a 900MB model can't fit a 512MB worker
