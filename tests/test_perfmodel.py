"""Properties of the paper's performance model + validation against the
discrete-event simulator (Table 3 analog), and the bit-for-bit equivalence
of the batched kernel against the scalar oracle."""
import dataclasses

import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core import planner
from repro.core.perfmodel import (
    Config,
    evaluate,
    evaluate_batch,
    perf_tables,
    sync_time_nonpipelined,
    sync_time_pipelined,
)
from repro.core.profiler import paper_model_profile
from repro.core.partition import LayerProfile, ModelProfile, merge_layers
from repro.serverless.platform import ALIBABA_FC, AWS_LAMBDA, MB
from repro.serverless.simulator import simulate_funcpipe


# ------------------------------------------------ eq (1) vs eq (2) properties
@given(
    s=st.floats(1e6, 2e9),
    w=st.floats(1e6, 1e9),
    n=st.integers(2, 64),
    t_lat=st.floats(0.0, 0.05),
)
@settings(max_examples=300, deadline=None)
def test_pipelined_sync_beats_nonpipelined(s, w, n, t_lat):
    """Eq (2) < eq (1) whenever transfer dominates latency: the pipelined
    schedule saves (1 - 2/n) * s/w transfer at the price of (n - 2) * t_lat."""
    t1 = sync_time_nonpipelined(s, w, n, t_lat)
    t2 = sync_time_pipelined(s, w, n, t_lat)
    saving = (1 - 2 / n) * s / w
    extra_lat = (n - 2) * t_lat
    if saving > extra_lat:
        assert t2 < t1
    assert t1 == pytest.approx(3 * s / w - 2 * s / (n * w) + 4 * t_lat)
    assert t2 == pytest.approx(2 * s / w + (2 + n) * t_lat)


def test_paper_numeric_example():
    """§3.3: 280 MB model, 8 workers, 70 MB/s -> transfer 11s -> 8s (~27%)."""
    s, w, n = 280e6, 70e6, 8
    t1 = sync_time_nonpipelined(s, w, n, 0.0)
    t2 = sync_time_pipelined(s, w, n, 0.0)
    assert t1 == pytest.approx(11.0, rel=0.05)
    assert t2 == pytest.approx(8.0, rel=0.05)
    assert (t1 - t2) / t1 == pytest.approx(0.27, abs=0.02)


# ------------------------------------------------------- model vs simulator
@pytest.mark.parametrize("model", ["amoebanet-d18", "bert-large"])
@pytest.mark.parametrize("alpha", [(1.0, 0.0), (1.0, 2**19 * 1e-9)])
def test_perfmodel_matches_simulator(model, alpha):
    """Analytical t_iter within ~20% of the discrete-event simulation (the
    paper reports ~11% mean error against the real system, App. E)."""
    prof = paper_model_profile(model, AWS_LAMBDA)
    M = 16
    r = planner.solve(prof, AWS_LAMBDA, alpha=alpha, total_micro_batches=M, merge_to=8)
    assert r is not None
    sim = simulate_funcpipe(r.profile, AWS_LAMBDA, r.config, M)
    err = abs(sim.t_iter - r.evaluation.t_iter) / sim.t_iter
    assert err < 0.25, (sim.t_iter, r.evaluation.t_iter)


def test_bandwidth_monotonicity():
    """More memory (=> more bandwidth/CPU) never slows an identical plan."""
    prof = merge_layers(paper_model_profile("amoebanet-d18", AWS_LAMBDA), 6)
    L = prof.L
    x = tuple(1 if i == L // 2 else 0 for i in range(L - 1))
    prev = None
    for j in range(len(AWS_LAMBDA.memory_options)):
        cfg = Config(x=x, d=4, z=tuple([j] * L))
        ev = evaluate(prof, AWS_LAMBDA, cfg, 16)
        if prev is not None:
            assert ev.t_iter <= prev + 1e-9
        prev = ev.t_iter


# --------------------------------------------- batched kernel == scalar oracle
def _random_instance(seed: int):
    """Random (profile, platform, X, Z, d, M, pipelined) evaluation batch."""
    rng = np.random.default_rng(seed)
    L = int(rng.integers(1, 9))
    base = AWS_LAMBDA if rng.random() < 0.5 else ALIBABA_FC
    J = int(rng.integers(1, len(base.memory_options) + 1))
    platform = dataclasses.replace(base, memory_options=base.memory_options[:J])
    layers = []
    for i in range(L):
        fwd = tuple(float(rng.uniform(0.05, 2.0) / (j + 1)) for j in range(J))
        layers.append(LayerProfile(
            name=f"l{i}",
            param_bytes=float(rng.uniform(5, 300)) * MB,
            act_bytes=float(rng.uniform(5, 150)) * MB,
            out_bytes=float(rng.uniform(1, 50)) * MB,
            grad_out_bytes=float(rng.uniform(1, 50)) * MB,
            fwd_time=fwd,
            bwd_time=tuple(2 * t for t in fwd),
        ))
    profile = ModelProfile(name=f"rand{seed}", layers=tuple(layers))
    N = int(rng.integers(1, 24))
    X = rng.integers(0, 2, size=(N, L - 1))
    Z = rng.integers(0, J, size=(N, L))
    d = int(rng.choice([1, 2, 3, 4, 8, 16]))
    M = int(rng.integers(1, 65))
    pipelined = bool(rng.random() < 0.5)
    return profile, platform, X, Z, d, M, pipelined


def _assert_batch_matches_scalar(seed: int):
    profile, platform, X, Z, d, M, pipelined = _random_instance(seed)
    be = evaluate_batch(profile, platform, X, Z, d, M, pipelined_sync=pipelined)
    assert len(be) == len(X)
    for n in range(len(X)):
        cfg = Config(x=tuple(int(v) for v in X[n]), d=d,
                     z=tuple(int(v) for v in Z[n]))
        ev = evaluate(profile, platform, cfg, M, pipelined_sync=pipelined)
        got = be.pick(n)
        # bit-for-bit: the kernel and the oracle share their reduction order
        assert got.t_iter == ev.t_iter, (seed, n)
        assert got.c_iter == ev.c_iter, (seed, n)
        assert got.t_f == ev.t_f, (seed, n)
        assert got.t_sync_max == ev.t_sync_max, (seed, n)
        assert got.mem_ok == ev.mem_ok, (seed, n)
        assert got.c_mem_gb == ev.c_mem_gb, (seed, n)
        a1, a2 = 1.0, 2**19 * 1e-9
        assert be.objective(a1, a2)[n] == ev.objective(a1, a2), (seed, n)


@given(seed=st.integers(0, 10_000))
@settings(max_examples=100, deadline=None)
def test_evaluate_batch_matches_scalar_property(seed):
    """Hypothesis sweep: evaluate_batch == N scalar evaluate calls, exactly."""
    _assert_batch_matches_scalar(seed)


@pytest.mark.parametrize("seed", range(20))
def test_evaluate_batch_matches_scalar_seeded(seed):
    """Deterministic subset of the property test (runs without hypothesis)."""
    _assert_batch_matches_scalar(seed)


def test_evaluate_batch_paper_model():
    """Sanity on a real profile: all partitions of a merged bert at once."""
    prof = merge_layers(paper_model_profile("bert-large", AWS_LAMBDA), 6)
    L, J = prof.L, len(AWS_LAMBDA.memory_options)
    P = 1 << (L - 1)
    X = (np.arange(P)[:, None] >> np.arange(L - 2, -1, -1)) & 1
    Z = np.full((P, L), J - 1)
    be = evaluate_batch(prof, AWS_LAMBDA, X, Z, 4, 16)
    for n in (0, P // 3, P - 1):
        ev = evaluate(prof, AWS_LAMBDA,
                      Config(x=tuple(int(v) for v in X[n]), d=4, z=tuple([J - 1] * L)), 16)
        assert be.pick(n) == ev


def test_perf_tables_cached_and_monotone():
    prof = merge_layers(paper_model_profile("bert-large", AWS_LAMBDA), 6)
    t1 = perf_tables(prof, AWS_LAMBDA)
    t2 = perf_tables(prof, AWS_LAMBDA)
    assert t1 is t2                       # lru-cached
    assert t1.monotone                    # more memory is never slower
    assert prof.arrays() is prof.arrays()  # arrays dict built once per profile


def test_memory_constraint_enforced():
    prof = merge_layers(paper_model_profile("amoebanet-d36", AWS_LAMBDA), 6)
    L = prof.L
    cfg = Config(x=tuple([0] * (L - 1)), d=1, z=tuple([0] * L))  # all on 512MB
    ev = evaluate(prof, AWS_LAMBDA, cfg, 16)
    assert not ev.mem_ok  # a 900MB model can't fit a 512MB worker
