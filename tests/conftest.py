"""Shared pytest fixtures.  NOTE: no XLA_FLAGS here — the main test process
sees exactly 1 device; multi-device checks run in subprocesses
(repro.testing.*) with their own fake-device flags.
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_multidev(module: str, *args: str, devices: int = 8, timeout: int = 1200):
    """Run ``python -m repro.testing.<module> args...`` with fake devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", f"repro.testing.{module}", *map(str, args)],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"{module} {args} failed (rc={proc.returncode})\n"
            f"stdout:\n{proc.stdout[-3000:]}\nstderr:\n{proc.stderr[-3000:]}"
        )
    return proc.stdout


@pytest.fixture(scope="session")
def multidev():
    return run_multidev


@pytest.fixture(autouse=True)
def _isolated_plan_cache(tmp_path_factory, monkeypatch):
    """Keep the CLI's default-on plan cache out of ~/.cache during tests:
    every test gets a fresh, throwaway cache directory."""
    monkeypatch.setenv("REPRO_PLAN_CACHE",
                       str(tmp_path_factory.mktemp("plan-cache")))
