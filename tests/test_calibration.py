"""Closed-loop trace calibration: measured-profile round-trip + provenance
fingerprint drift, exact round-trip on the virtual-clock backend,
calibrate-then-replan determinism, named perf-model warning signatures,
the Session chain, and the `repro calibrate` CLI."""
import dataclasses
import json

import numpy as np
import pytest

from repro.api import (
    DeploymentPlan,
    ExecutionConfig,
    PlanCompatibilityError,
    session,
)
from repro.api.plan import profile_fingerprint
from repro.cli import main as cli_main
from repro.core.partition import ModelProfile, stages_of
from repro.core.perfmodel import Config
from repro.obs import Trace, calibrate_trace
from repro.obs.calibrate import calibrate_profile, observe_stages, replan
from repro.serverless.platform import AWS_LAMBDA

ALPHA = (1.0, 2**16 * 1e-9)
FAST = dict(merge_to=6, d_options=(1, 2, 4))


@pytest.fixture(scope="module")
def traced():
    """One traced virtual-clock run: (plan, resolved, trace)."""
    s = session("bert-large", platform="aws", global_batch=64).plan(
        alpha=ALPHA, **FAST)
    plan = s.deployment_plan
    res = plan.emulate(ExecutionConfig(steps=1, trace=True))
    return plan, plan.resolve(), res.trace


def _calibrate(rp, trace, **kw):
    return calibrate_profile(trace, rp.profile, rp.platform, rp.config,
                             rp.total_micro_batches,
                             pipelined_sync=rp.pipelined_sync, **kw)


# ------------------------------------------------------------ exact loop
def test_emulated_trace_round_trips_exactly(traced):
    plan, rp, trace = traced
    cal = _calibrate(rp, trace)
    # the virtual-clock backend IS the cost model: scales are exactly 1,
    # residuals are float noise, and no systematic warning may fire
    for row in cal.scales:
        for k in ("fwd", "bwd", "out", "grad"):
            if row[k] is not None:
                assert row[k] == pytest.approx(1.0, abs=1e-9)
    assert cal.baseline["max_rel_err"] < 1e-9
    assert cal.residual["max_rel_err"] <= cal.baseline["max_rel_err"] + 1e-12
    assert not [w for w in cal.warnings if w.name != "unobserved-stages"]
    assert cal.profile.source == "measured"
    meta = cal.profile.calibration
    assert meta.backend == "emulated" and meta.clock == "virtual"
    assert meta.base_fingerprint == profile_fingerprint(rp.profile,
                                                        rp.platform)


def test_observe_stages_counts(traced):
    plan, rp, trace = traced
    obs = observe_stages(trace)
    assert len(obs) == plan.n_stages
    M = plan.total_micro_batches
    for o in obs:
        assert o.n_fwd == M and o.n_bwd == M


# ----------------------------------------------------- provenance + JSON
def test_measured_profile_json_round_trip(tmp_path, traced):
    plan, rp, trace = traced
    measured = _calibrate(rp, trace).profile
    p = tmp_path / "measured.json"
    measured.save(p)
    again = ModelProfile.load(p)
    assert again == measured
    assert profile_fingerprint(again, rp.platform) \
        == profile_fingerprint(measured, rp.platform)


def test_measured_fingerprint_never_collides_with_analytic(traced):
    plan, rp, trace = traced
    measured = _calibrate(rp, trace).profile
    # even with numerically identical tables (scales were exactly 1.0),
    # provenance folds into the fingerprint: a measured profile can never
    # hit an analytic plan-cache entry
    fp_analytic = profile_fingerprint(rp.profile, rp.platform)
    fp_measured = profile_fingerprint(measured, rp.platform)
    assert fp_analytic != fp_measured
    # ...and the calibration metadata is part of the identity
    bumped = dataclasses.replace(
        measured, calibration=dataclasses.replace(
            measured.calibration, t_total=measured.calibration.t_total + 1))
    assert profile_fingerprint(bumped, rp.platform) != fp_measured


def test_measured_plan_resolve_guards(traced):
    plan, rp, trace = traced
    cal = _calibrate(rp, trace)
    rep = replan(cal, plan)
    assert rep.new_plan.profile_source == "measured"
    # measured plans cannot be rebuilt by the profiler...
    with pytest.raises(PlanCompatibilityError, match="measured"):
        rep.new_plan.resolve()
    # ...the analytic profile is named as a source mismatch...
    with pytest.raises(PlanCompatibilityError, match="source mismatch"):
        rep.new_plan.resolve(profile=rp.profile)
    # ...and the measured profile resolves cleanly
    rp2 = rep.new_plan.resolve(profile=cal.profile)
    assert rp2.profile is cal.profile


def test_calibrating_a_measured_profile_is_rejected(traced):
    plan, rp, trace = traced
    measured = _calibrate(rp, trace).profile
    with pytest.raises(ValueError, match="analytic"):
        calibrate_profile(trace, measured, rp.platform, rp.config,
                          rp.total_micro_batches)


# ---------------------------------------------------------- determinism
def test_calibrate_then_replan_is_deterministic(traced):
    plan, rp, trace = traced

    def once():
        res = plan.emulate(ExecutionConfig(steps=1, trace=True))
        cal = _calibrate(rp, res.trace)
        return cal, replan(cal, plan)

    (cal1, rep1), (cal2, rep2) = once(), once()
    assert cal1.profile == cal2.profile
    assert rep1.new_plan.content_hash == rep2.new_plan.content_hash


# ------------------------------------------------------ warning signatures
def test_compute_underestimate_warning(traced):
    plan, rp, trace = traced
    slowed = Trace(spans=[
        dataclasses.replace(s, end=s.start + 2.0 * s.duration)
        if s.op == "compute" else s
        for s in trace.spans], meta=dict(trace.meta))
    cal = _calibrate(rp, slowed)
    names = {w.name: w for w in cal.warnings}
    assert "compute-underestimate" in names
    assert names["compute-underestimate"].magnitude == pytest.approx(2.0,
                                                                     rel=1e-6)
    # the measured tables absorb the slowdown: residual error collapses
    # (|pred - obs| / obs = |1 - 2| / 2 against the doubled spans)
    assert cal.baseline["max_rel_err"] == pytest.approx(0.5, rel=1e-6)
    assert cal.residual["max_rel_err"] < 1e-9
    for row in cal.scales:
        assert row["fwd"] == pytest.approx(2.0, rel=1e-6)
        assert row["bwd"] == pytest.approx(2.0, rel=1e-6)


def test_unobserved_stage_keeps_analytic_tables(traced):
    plan, rp, trace = traced
    holey = Trace(spans=[s for s in trace.spans
                         if not (s.stage == 0 and s.op == "compute")],
                  meta=dict(trace.meta))
    cal = _calibrate(rp, holey)
    assert any(w.name == "unobserved-stages" and 0 in w.stages
               for w in cal.warnings)
    # stage 0's layers keep the analytic values verbatim
    (lo, hi) = stages_of(rp.config.x)[0]
    for i in range(lo, hi + 1):
        assert cal.profile.layers[i].fwd_time \
            == rp.profile.layers[i].fwd_time


def test_eq2_sync_underestimate_warning():
    # the fast bert plan solves to d=1 (no sync), so build a manual d=2
    # deployment and inflate the observed per-step sync makespan
    from repro.core.partition import merge_layers
    from repro.core.profiler import paper_model_profile

    prof = merge_layers(paper_model_profile("bert-large", AWS_LAMBDA), 6)
    L = prof.L
    cfg = Config(x=tuple(1 if i == 2 else 0 for i in range(L - 1)),
                 d=2, z=tuple(5 for _ in range(L)))
    plan = DeploymentPlan.from_config(prof, AWS_LAMBDA, cfg, 8,
                                      model="bert-large", merge_to=6)
    res = plan.emulate(ExecutionConfig(steps=1, trace=True),
                       profile=prof)
    trace = res.trace
    trace.meta["step_syncs"] = [3.0 * v for v in trace.meta["step_syncs"]]
    cal = calibrate_profile(trace, prof, AWS_LAMBDA, cfg, 8)
    names = [w.name for w in cal.warnings]
    assert "eq2-sync-underestimate" in names
    w = next(w for w in cal.warnings if w.name == "eq2-sync-underestimate")
    assert w.magnitude == pytest.approx(3.0, rel=0.2)


# ------------------------------------------------------------- session chain
def test_session_calibrate_chain():
    s = session("bert-large", platform="aws", global_batch=64).plan(
        alpha=ALPHA, **FAST)
    with pytest.raises(ValueError, match="traced emulation"):
        s.calibrate()
    s.emulate(ExecutionConfig(steps=1, trace=True)).calibrate()
    assert s.calibration is not None
    assert s.model_profile.source == "measured"
    # re-planning now solves against observed reality; the plan records it
    s.plan(alpha=ALPHA, merge_to=None, engine="dp")
    assert s.deployment_plan.profile_source == "measured"
    # and the measured plan replays through the session unchanged
    s.emulate(ExecutionConfig(steps=1))
    assert s.engine_result is not None


# ------------------------------------------------------------ trace front door
def test_calibrate_trace_from_saved_file(tmp_path, traced):
    plan, rp, trace = traced
    p = tmp_path / "trace.json"
    trace.save(p)
    cal, plan2 = calibrate_trace(Trace.load(p))
    assert plan2.content_hash == plan.content_hash
    assert cal.profile.source == "measured"
    # a trace without an embedded plan needs one passed explicitly
    bare = Trace(spans=list(trace.spans),
                 meta={k: v for k, v in trace.meta.items() if k != "plan"})
    with pytest.raises(ValueError, match="plan"):
        calibrate_trace(bare)
    cal2, _ = calibrate_trace(bare, plan=plan)
    assert cal2.profile == cal.profile


# --------------------------------------------------------------------- CLI
def test_cli_calibrate_loop(tmp_path, capsys):
    t, pl = str(tmp_path / "t.json"), str(tmp_path / "plan.json")
    mp, rp = str(tmp_path / "measured.json"), str(tmp_path / "replan.json")
    assert cli_main(["emulate", "--model", "bert-large", "--fast",
                     "--steps", "1", "--trace", t, "-o", pl]) == 0
    capsys.readouterr()
    assert cli_main(["calibrate", t, "--profile-out", mp, "-o", rp]) == 0
    out = capsys.readouterr().out
    assert "prediction error" in out
    assert "re-plan on the measured profile" in out
    # measured plans replay only with their measured profile
    assert cli_main(["simulate", rp, "--profile", mp]) == 0
    with pytest.raises(SystemExit, match="measured"):
        cli_main(["simulate", rp])
    # --no-replan stops after the calibration report
    capsys.readouterr()
    assert cli_main(["calibrate", t, "--no-replan"]) == 0
    assert "re-plan" not in capsys.readouterr().out


def test_cli_calibrate_rejects_bad_inputs(tmp_path):
    with pytest.raises(SystemExit, match="no such trace"):
        cli_main(["calibrate", str(tmp_path / "nope.json")])
