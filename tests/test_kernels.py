"""Pallas kernel validation: shape/dtype sweeps in interpret mode against the
pure-jnp oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.swiglu import swiglu


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("S,Hq,Hkv,hd", [
    (128, 4, 4, 64),     # MHA
    (256, 8, 2, 64),     # GQA 4:1
    (256, 4, 1, 128),    # MQA
    (128, 2, 2, 96),     # phi3-like head_dim
    (384, 8, 4, 256),    # gemma3-like head_dim (odd-multiple seq blocks)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(S, Hq, Hkv, hd, dtype):
    key = jax.random.PRNGKey(42)
    B = 2
    q = jax.random.normal(key, (B, S, Hq, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, hd), dtype)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [64, 128, 1024])
def test_flash_attention_window(window):
    key = jax.random.PRNGKey(0)
    B, S, Hq, Hkv, hd = 1, 256, 4, 2, 64
    q = jax.random.normal(key, (B, S, Hq, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, hd))
    out = flash_attention(q, k, v, causal=True, window=window, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal():
    key = jax.random.PRNGKey(7)
    B, S, Hq, Hkv, hd = 2, 128, 4, 4, 80  # hubert-like
    q = jax.random.normal(key, (B, S, Hq, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, hd))
    out = flash_attention(q, k, v, causal=False, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("C,length", [(512, 1), (512, 511), (1024, 700), (2048, 2048)])
@pytest.mark.parametrize("Hq,Hkv,hd", [(8, 2, 64), (4, 4, 128), (16, 2, 128)])
def test_decode_attention(C, length, Hq, Hkv, hd):
    key = jax.random.PRNGKey(3)
    B = 2
    q = jax.random.normal(key, (B, Hq, hd))
    kc = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, C, hd))
    vc = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, C, hd))
    out = decode_attention(q, kc, vc, jnp.int32(length), interpret=True)
    expect = ref.decode_attention_ref(q, kc, vc, jnp.int32(length))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("T,d,f", [(256, 256, 512), (512, 512, 2048), (128, 384, 1536)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swiglu(T, d, f, dtype):
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (T, d), dtype)
    wg = (0.05 * jax.random.normal(jax.random.fold_in(key, 1), (d, f))).astype(dtype)
    wu = (0.05 * jax.random.normal(jax.random.fold_in(key, 2), (d, f))).astype(dtype)
    out = swiglu(x, wg, wu, interpret=True)
    expect = ref.swiglu_ref(x, wg, wu)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


def test_ops_dispatch_ref_mode(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_MODE", "ref")
    from repro.kernels import ops
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 64, 2, 32))
    k = jax.random.normal(key, (1, 64, 2, 32))
    v = jax.random.normal(key, (1, 64, 2, 32))
    out = ops.flash_attention(q, k, v, causal=True)
    assert out.shape == q.shape
