"""Simulator behaviour + the paper's §5 claims reproduced in simulation."""
import numpy as np
import pytest

from repro.core import planner
from repro.core.profiler import paper_model_profile
from repro.serverless.frameworks import funcpipe, hybrid_ps, lambda_ml
from repro.serverless.platform import ALIBABA_FC, AWS_LAMBDA
from repro.serverless.simulator import simulate_data_parallel, simulate_funcpipe


def test_pipelined_sync_improves_dp_training():
    """Fig 8: pipelined scatter-reduce improves DP iteration time, more with
    higher DP degree (2-18% iteration, 6-26% sync in the paper)."""
    prof = paper_model_profile("amoebanet-d18", AWS_LAMBDA)
    gains = []
    for n in [2, 4, 8, 16, 32]:
        a = simulate_data_parallel(prof, AWS_LAMBDA, n_workers=n, mem_index=7,
                                   samples_per_worker=4, micro_batch=4,
                                   sync="scatter_reduce")
        b = simulate_data_parallel(prof, AWS_LAMBDA, n_workers=n, mem_index=7,
                                   samples_per_worker=4, micro_batch=4,
                                   sync="pipelined")
        gains.append(1 - b.breakdown["sync"] / a.breakdown["sync"])
        # at n=2 eq(1)==eq(2) exactly (3s/w - s/w == 2s/w); strictly better after
        assert b.t_iter <= a.t_iter * (1 + 1e-9)
        if n > 2:
            assert b.t_iter < a.t_iter
    assert gains[-1] > gains[0]          # growing with DP degree
    assert 0.05 < gains[-1] < 0.35       # paper: 6-26% (bound 33%)


@pytest.mark.parametrize("model,gb", [("amoebanet-d36", 64), ("bert-large", 64),
                                      ("amoebanet-d36", 256)])
def test_funcpipe_beats_lambdaml_at_scale(model, gb):
    """Fig 5: 1.3-2.2x speedup and cost reduction vs LambdaML for the larger
    models and batches."""
    prof = paper_model_profile(model, AWS_LAMBDA)
    lm = lambda_ml(prof, AWS_LAMBDA, gb)
    fp = funcpipe(prof, AWS_LAMBDA, gb)
    rec = fp.recommended_sim
    speedup = lm.t_iter / rec.t_iter
    assert speedup > 1.25, speedup
    best_cost = min(s.cost for s in fp.sims)
    assert best_cost < lm.cost  # some Pareto point is cheaper


def test_small_model_small_gain():
    """Fig 5/6b: small models see small or no improvement."""
    prof = paper_model_profile("resnet101", AWS_LAMBDA)
    lm = lambda_ml(prof, AWS_LAMBDA, 16)
    fp = funcpipe(prof, AWS_LAMBDA, 16)
    rec = fp.recommended_sim
    assert rec.t_iter < lm.t_iter * 1.3  # comparable
    assert min(s.cost for s in fp.sims) < lm.cost * 1.5


def test_hybrid_ps_bottlenecks_at_scale():
    """§5.2: the central PS saturates as workers grow."""
    prof = paper_model_profile("amoebanet-d36", AWS_LAMBDA)
    hp_small = lambda_ml(prof, AWS_LAMBDA, 16, ps=True)
    hp_large = lambda_ml(prof, AWS_LAMBDA, 512, ps=True)
    lm_large = lambda_ml(prof, AWS_LAMBDA, 512)
    assert hp_large.t_iter > lm_large.t_iter  # decentralized wins at scale


def test_alibaba_storage_cap():
    """§5.7: Alibaba's 10Gb/s OSS cap exists in the platform model."""
    assert ALIBABA_FC.storage_total_bandwidth is not None
    assert AWS_LAMBDA.storage_total_bandwidth is None


def test_grad_accum_cheaper_but_slower():
    prof = paper_model_profile("amoebanet-d18", AWS_LAMBDA)
    base = lambda_ml(prof, AWS_LAMBDA, 64)
    ga = lambda_ml(prof, AWS_LAMBDA, 64, grad_accum=True)
    assert ga.t_iter >= base.t_iter * 0.99
    assert ga.total_mem_gb <= base.total_mem_gb
