"""Observability layer: span schema, trace validation, metrics, attribution.

The heavy lifting is shared with test_backends' timing plan (bert-large
merged to 6 layers): traced emulated runs must reproduce the backend's own
StepTiming and StoreStats *exactly* — the trace is a decomposition of the
run, not a parallel estimate of it.
"""
import json

import pytest

from repro.core.partition import merge_layers
from repro.core.perfmodel import Config
from repro.core.profiler import paper_model_profile
from repro.obs import (
    ELAPSED,
    Span,
    Trace,
    TraceValidationError,
    gap_attribution,
    pipeline_health,
    validate_trace,
)
from repro.serverless.platform import AWS_LAMBDA
from repro.serverless.runtime import run_plan
from repro.serverless.runtime.store import classify_key
from repro.serverless.simulator import simulate_funcpipe


def _timing_plan(d=4):
    prof = merge_layers(paper_model_profile("bert-large", AWS_LAMBDA), 6)
    L = prof.L
    x = tuple(1 if i == 2 else 0 for i in range(L - 1))
    return prof, Config(x=x, d=d, z=tuple(5 for _ in range(L)))


@pytest.fixture(scope="module")
def traced_run():
    prof, cfg = _timing_plan(d=4)
    res = run_plan(prof, AWS_LAMBDA, cfg, 8, steps=2, trace=True)
    sim = simulate_funcpipe(prof, AWS_LAMBDA, cfg, 8, trace=True)
    return prof, cfg, res, sim


# ------------------------------------------------------------------ schema
def test_span_schema_roundtrip():
    sp = Span(stage=1, replica=2, step=0, phase="fwd", op="upload",
              start=1.0, end=2.5, nbytes=100.0, key="k0/r2/m0/act1")
    assert sp.worker == "s1r2"
    assert sp.duration == 1.5
    assert sp.resource == "uplink"
    assert Span.from_dict(sp.to_dict()) == sp
    # compute spans carry no key/bytes and map to the cpu lane
    cp = Span(stage=0, replica=0, step=0, phase="bwd", op="compute",
              start=0.0, end=1.0)
    assert cp.resource == "cpu"
    assert "key" not in cp.to_dict() and "nbytes" not in cp.to_dict()


def test_classify_key():
    assert classify_key("k0/r1/m2/act3") == "act"
    assert classify_key("k0/r1/m2/grad3") == "grad"
    assert classify_key("k0/sync1/part/2/0") == "sync"
    assert classify_key("k0/sync1/red/2") == "sync"
    assert classify_key("whatever") == "other"


# ------------------------------------------------ emulated trace invariants
def test_emulated_trace_validates(traced_run):
    _, cfg, res, _ = traced_run
    tr = res.trace
    assert tr is not None and len(tr.spans) > 0
    validate_trace(tr)   # non-overlap per lane + phase ordering
    workers = {sp.worker for sp in tr.spans}
    assert workers == {f"s{s}r{r}" for s in range(sum(cfg.x) + 1)
                       for r in range(cfg.d)}


def test_emulated_span_ends_reproduce_step_timing(traced_run):
    """Per step, the last span end IS the step's StepTiming.end (exact)."""
    _, _, res, _ = traced_run
    tr = res.trace
    for k, end in enumerate(tr.meta["step_ends"]):
        assert max(s.end for s in tr.spans if s.step == k) == end


def test_emulated_span_bytes_reconcile_bit_exact(traced_run):
    """Spans are emitted adjacent to each store op, in the same serial
    order, so the float sums match StoreStats bit for bit."""
    _, _, res, _ = traced_run
    tr, ss = res.trace, res.store_stats
    assert sum(s.nbytes for s in tr.spans if s.op == "upload") == ss.bytes_in
    assert sum(s.nbytes for s in tr.spans if s.op == "download") == ss.bytes_out
    assert pipeline_health(tr)["reconciliation"]["ok"]


def test_store_stats_class_breakdown(traced_run):
    _, _, res, _ = traced_run
    ss = res.store_stats
    assert set(ss.class_bytes_in) == {"act", "grad", "sync"}
    assert sum(ss.class_bytes_in.values()) == pytest.approx(ss.bytes_in)
    assert sum(ss.class_bytes_deleted.values()) == \
        pytest.approx(ss.bytes_deleted)
    d = ss.as_dict()
    assert d["puts"] == ss.puts and "class_bytes_in" in d


def test_validate_trace_rejects_overlap_and_disorder():
    base = dict(stage=0, replica=0, step=0, phase="fwd", op="compute")
    tr = Trace(spans=[Span(start=0.0, end=2.0, **base),
                      Span(start=1.0, end=3.0, **base)], meta={})
    with pytest.raises(TraceValidationError, match="overlap"):
        validate_trace(tr)
    tr2 = Trace(spans=[
        Span(stage=0, replica=0, step=0, phase="bwd", op="compute",
             start=0.0, end=1.0),
        Span(stage=0, replica=0, step=0, phase="fwd", op="compute",
             start=2.0, end=3.0)], meta={})
    with pytest.raises(TraceValidationError, match="before fwd ends"):
        validate_trace(tr2)


# ----------------------------------------------------------- chrome export
def test_chrome_trace_roundtrip(tmp_path, traced_run):
    _, _, res, sim = traced_run
    tr = res.trace
    tr.predicted = sim.trace.spans
    path = tmp_path / "t.json"
    tr.save(path)
    doc = json.loads(path.read_text())        # valid JSON, object form
    assert isinstance(doc["traceEvents"], list)
    xs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    # one X event per observed + predicted span, ts/dur in microseconds
    assert len(xs) == len(tr.spans) + len(tr.predicted)
    assert all(e["dur"] >= 0 for e in xs)
    t2 = Trace.load(path)
    assert len(t2.spans) == len(tr.spans)
    assert len(t2.predicted) == len(tr.predicted)
    assert t2.spans[0] == tr.spans[0]
    assert t2.meta["step_ends"] == tr.meta["step_ends"]


# ---------------------------------------------------- predicted + metrics
def test_predicted_trace_validates(traced_run):
    _, cfg, _, sim = traced_run
    tr = sim.trace
    validate_trace(tr)
    S = sum(cfg.x) + 1
    assert {s.stage for s in tr.spans} == set(range(S))
    assert all(s.replica == 0 and s.step == 0 for s in tr.spans)
    assert {s.op for s in tr.spans} == {"download", "compute", "upload",
                                        "sync"}
    # predicted makespan is the simulated t_iter
    assert max(s.end for s in tr.spans) == pytest.approx(sim.t_iter)


def test_pipeline_health_metrics(traced_run):
    _, cfg, res, _ = traced_run
    h = pipeline_health(res.trace)
    S = sum(cfg.x) + 1
    assert [row["stage"] for row in h["stages"]] == list(range(S))
    for row in h["stages"]:
        assert 0.0 <= row["bubble_frac"] <= 1.0
        assert row["compute_frac"] + row["bubble_frac"] == pytest.approx(1.0)
        assert 0.0 <= row["up_bw_util"] <= 1.0
    assert h["straggler_ratio"] >= 1.0
    pb = h["phase_bytes"]
    assert pb["fwd"]["up"] > 0 and pb["sync"]["up"] > 0


def test_gap_attribution_ranks_cells(traced_run):
    _, _, res, sim = traced_run
    tr = res.trace
    bare = Trace(spans=tr.spans, meta=tr.meta)   # no predicted attached
    with pytest.raises(ValueError, match="no predicted"):
        gap_attribution(bare)
    rows = gap_attribution(tr, predicted=sim.trace.spans)
    gaps = [abs(r.gap_s) for r in rows]
    assert gaps == sorted(gaps, reverse=True)
    # busy cells exclude the closed-form sync phase; elapsed rows include it
    assert all(r.phase != "sync" or r.op == ELAPSED for r in rows)
    assert any(r.op == ELAPSED for r in rows)
    # the emulated backend charges the shared cost model: compute cells agree
    for r in rows:
        if r.op == "compute":
            assert r.observed_s == pytest.approx(r.predicted_s, rel=1e-9)


# ------------------------------------------------------------ local backend
def test_local_backend_trace_validates():
    prof, cfg = _timing_plan(d=2)
    res = run_plan(prof, AWS_LAMBDA, cfg, 8, steps=1, backend="local",
                   trace=True)
    tr = res.trace
    assert tr.meta["clock"] == "wall"
    validate_trace(tr)
    ss = res.store_stats
    # modeled byte sums still reconcile (thread order differs: approx)
    up = sum(s.nbytes for s in tr.spans if s.op == "upload")
    dn = sum(s.nbytes for s in tr.spans if s.op == "download")
    assert up == pytest.approx(ss.bytes_in)
    assert dn == pytest.approx(ss.bytes_out)
    # wall-clock traces carry no bandwidth-utilization columns (cross-clock)
    assert "up_bw_util" not in pipeline_health(tr)["stages"][0]


def test_untraced_run_has_no_trace():
    prof, cfg = _timing_plan(d=1)
    res = run_plan(prof, AWS_LAMBDA, cfg, 4, steps=1)
    assert res.trace is None
    sim = simulate_funcpipe(prof, AWS_LAMBDA, cfg, 4)
    assert sim.trace is None


# ------------------------------------------------------- planner + cache
def test_planner_stats_populated():
    from repro.core import planner

    prof = merge_layers(paper_model_profile("bert-large", AWS_LAMBDA), 6)
    alpha = (1.0, 2**16 * 1e-9)
    r = planner.solve(prof, AWS_LAMBDA, alpha=alpha, total_micro_batches=16,
                      d_options=(1, 2), merge_to=6)
    assert r.stats is not None and r.stats.engine == "batch"
    assert r.stats.partitions_polished > 0
    assert "polished" in r.stats.describe()
    r_dp = planner.dp_solve(prof, AWS_LAMBDA, alpha=alpha,
                            total_micro_batches=16, d_options=(1, 2),
                            merge_to=6)
    assert r_dp.stats.engine == "dp"
    assert r_dp.stats.dp_states > 0 and r_dp.stats.dp_rows_kept > 0
    assert "states" in r_dp.stats.describe()


def test_plan_cache_eviction_counter(tmp_path):
    from repro.api.plan_cache import PlanCache

    cache = PlanCache(tmp_path)
    key = "deadbeef"
    path = cache._path(key)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text("{not json")
    assert cache.get(key) is None
    assert cache.evictions == 1 and cache.misses == 1
    assert not path.exists()
    assert cache.get(key) is None        # plain miss, no eviction
    assert cache.evictions == 1 and cache.misses == 2


# ------------------------------------------------------------- CLI surface
def test_cli_trace_and_inspect(tmp_path, capsys):
    from repro.cli import main as cli_main

    trace = tmp_path / "t.json"
    rc = cli_main(["emulate", "--model", "bert-large", "--fast",
                   "--steps", "1", "--trace", str(trace),
                   "--no-plan-cache"])
    out = capsys.readouterr().out
    assert rc == 0 and trace.exists()
    assert "wrote trace" in out
    assert "store uploads by key class:" in out
    rc = cli_main(["inspect", str(trace)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "trace OK" in out
    assert "gap attribution" in out
    assert "byte reconciliation vs StoreStats: OK" in out


def test_cli_inspect_rejects_invalid(tmp_path, capsys):
    from repro.cli import main as cli_main

    bad = tmp_path / "bad.json"
    bad.write_text("[]")
    with pytest.raises(SystemExit, match="not a repro trace"):
        cli_main(["inspect", str(bad)])
