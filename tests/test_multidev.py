"""Multi-device integration tests.  Each runs a repro.testing.* module in a
subprocess with 8 fake CPU devices so this pytest process keeps seeing 1
device (dry-run isolation rule)."""
import inspect

import pytest


def _multidev_missing_apis():
    """The repro.testing harness modules target the modern mesh/shard_map
    surface; probe for it instead of failing 13 tests on older jax."""
    import jax

    missing = []
    if not hasattr(jax.sharding, "AxisType"):
        missing.append("jax.sharding.AxisType")
    if not hasattr(jax, "set_mesh"):
        missing.append("jax.set_mesh")
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        missing.append("jax.shard_map")
    else:
        try:
            if "check_vma" not in inspect.signature(sm).parameters:
                missing.append("jax.shard_map(check_vma=)")
        except (TypeError, ValueError):
            pass
    return missing


_MISSING = _multidev_missing_apis()
pytestmark = pytest.mark.skipif(
    bool(_MISSING),
    reason="repro.testing multidev modules need "
           f"{', '.join(_MISSING)} (newer jax required)")


def test_ring_collectives(multidev):
    multidev("collectives_check")


@pytest.mark.parametrize("arch,stages,tensor,layers", [
    ("phi3-mini-3.8b", 4, 1, 4),      # pure pipeline + padding-free
    ("qwen2.5-14b", 2, 4, 4),         # deep TP, qkv bias
    ("gemma3-4b", 2, 4, "none"),      # sliding window + kv-share sync
    ("dbrx-132b", 4, 1, 4),           # MoE + expert parallelism
    ("jamba-v0.1-52b", 2, 1, "none"), # hybrid mamba+attn+moe period
    ("xlstm-125m", 2, 2, "none"),     # sLSTM/mLSTM, tp-replicated mixers
    ("hubert-xlarge", 4, 2, 4),       # encoder, no shift
])
def test_pipeline_train_equivalence(multidev, arch, stages, tensor, layers):
    """Pipelined train step == single-device step (loss + updated params)."""
    args = [arch, stages, tensor] + ([] if layers == "none" else [layers])
    out = multidev("pipeline_equiv", *args)
    assert "loss_err" in out


@pytest.mark.parametrize("arch,stages,tensor,seq_shards", [
    ("phi3-mini-3.8b", 4, 1, 1),
    ("gemma3-4b", 2, 2, 2),           # data-axis-sharded KV (long-ctx path)
    ("jamba-v0.1-52b", 2, 1, 1),
    ("dbrx-132b", 2, 2, 1),
    ("xlstm-125m", 2, 2, 1),
])
def test_pipeline_serve_equivalence(multidev, arch, stages, tensor, seq_shards):
    """Pipelined prefill+decode == single-device prefill+decode logits."""
    out = multidev("serve_equiv", arch, stages, tensor, seq_shards)
    assert "decode_err" in out
