"""Checkpoint round-trip, data-pipeline determinism, roofline HLO parsing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import FunctionManager, restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.configs.base import InputShape
from repro.data.synthetic import make_batch
from repro.launch import roofline as rl
from repro.models import registry


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("phi3-mini-3.8b").reduced()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    path = str(tmp_path / "ckpt.msgpack")
    save_checkpoint(path, params, step=7)
    like = jax.tree.map(lambda a: jnp.zeros_like(a), params)
    restored, step = restore_checkpoint(path, like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_function_manager(tmp_path):
    fm = FunctionManager(str(tmp_path / "c.msgpack"), lifetime=0.0)
    assert fm.should_checkpoint()
    fm.checkpoint_and_restart({"w": jnp.ones(3)}, step=1)
    assert fm.restarts == 1
    assert os.path.exists(fm.path)


def test_data_determinism_and_sharding():
    cfg = get_config("phi3-mini-3.8b").reduced()
    shape = InputShape("t", 32, 8, "train")
    a = make_batch(cfg, shape, seed=1, step=3, shard=0, n_shards=2)
    b = make_batch(cfg, shape, seed=1, step=3, shard=0, n_shards=2)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = make_batch(cfg, shape, seed=1, step=3, shard=1, n_shards=2)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))
    d = make_batch(cfg, shape, seed=1, step=4, shard=0, n_shards=2)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(d["tokens"]))
    assert a["tokens"].shape == (4, 32)


HLO_SAMPLE = """
HloModule test

%body.1 (arg: (s32[], f32[64,8])) -> (s32[], f32[64,8]) {
  %ag.1 = f32[128,8] all-gather(f32[64,8] %p), replica_groups={{0,1},{2,3}}, dimensions={0}
  %cp.1 = f32[64,8] collective-permute(f32[64,8] %p), source_target_pairs={{0,1},{1,2}}
}

%cond.1 (arg: (s32[], f32[64,8])) -> pred[] {
  %c = s32[] constant(5)
  %lt = pred[] compare(s32[] %i, s32[] %c), direction=LT
}

ENTRY %main (p0: f32[64,8]) -> f32[64,8] {
  %w = (s32[], f32[64,8]) while((s32[], f32[64,8]) %init), condition=%cond.1, body=%body.1
  %ar.2 = f32[32,4] all-reduce(f32[32,4] %x), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs.1 = f32[16,8] reduce-scatter(f32[64,8] %y), replica_groups=[2,4]<=[8], dimensions={0}
}
"""


def test_parse_collectives_kinds_and_groups():
    ops = rl.parse_collectives(HLO_SAMPLE, trip_weighted=False)
    kinds = sorted(o.kind for o in ops)
    assert kinds == ["all-gather", "all-reduce", "collective-permute", "reduce-scatter"]
    ag = next(o for o in ops if o.kind == "all-gather")
    assert ag.group_size == 2
    assert ag.result_bytes == 128 * 8 * 4
    rs = next(o for o in ops if o.kind == "reduce-scatter")
    assert rs.group_size == 4  # iota form [2,4]


def test_trip_multipliers():
    mult = rl.computation_multipliers(HLO_SAMPLE)
    assert mult.get("body.1", 0) == 5.0
    ops = rl.parse_collectives(HLO_SAMPLE, trip_weighted=True)
    ag = next(o for o in ops if o.kind == "all-gather")
    assert ag.trip_mult == 5.0
    ar = next(o for o in ops if o.kind == "all-reduce")
    assert ar.trip_mult == 1.0


def test_link_bytes_semantics():
    op = rl.CollectiveOp("all-gather", 1024, 4)
    assert op.link_bytes == 1024 * 3 / 4
    op = rl.CollectiveOp("all-reduce", 1024, 4)
    assert op.link_bytes == 2 * 1024 * 3 / 4
    op = rl.CollectiveOp("collective-permute", 1024, 1)
    assert op.link_bytes == 1024
    op = rl.CollectiveOp("reduce-scatter", 256, 4)  # result = shard
    assert op.link_bytes == 256 * 3


def test_analytic_roofline_shapes():
    from repro.core.plan import make_plan
    from repro.configs import INPUT_SHAPES
    cfg = get_config("phi3-mini-3.8b")
    for sname in ["train_4k", "prefill_32k", "decode_32k"]:
        shape = INPUT_SHAPES[sname]
        plan = make_plan(cfg, shape)
        r = rl.analytic_roofline(cfg, shape, plan)
        assert r.flops > 0 and r.hbm_bytes > 0
        assert r.bottleneck in ("compute", "memory", "collective")
    # decode should be memory-bound (KV cache streaming)
    shape = INPUT_SHAPES["decode_32k"]
    plan = make_plan(cfg, shape)
    r = rl.analytic_roofline(cfg, shape, plan)
    assert r.bottleneck == "memory"
