"""hat/tilde operators (paper eq (4)) and layer-merging invariants."""
import numpy as np
from _hypo import given, settings, st

from repro.core.partition import (
    hat,
    highest_layers,
    lowest_layers,
    merge_layers,
    stages_of,
    tilde,
)
from repro.core.profiler import paper_model_profile
from repro.serverless.platform import AWS_LAMBDA


@given(
    u=st.lists(st.floats(0.0, 100.0), min_size=2, max_size=12),
    data=st.data(),
)
@settings(max_examples=200, deadline=None)
def test_hat_tilde_partition_sums(u, data):
    L = len(u)
    x = data.draw(st.lists(st.integers(0, 1), min_size=L - 1, max_size=L - 1))
    u = np.array(u)
    h = hat(u, np.array(x))
    t = tilde(u, np.array(x))
    for lo, hi in stages_of(x):
        seg = u[lo:hi + 1].sum()
        assert np.isclose(h[hi], seg)   # hat at highest layer = stage sum
        assert np.isclose(t[lo], seg)   # tilde at lowest layer = stage sum
    assert highest_layers(x) == [hi for _, hi in stages_of(x)]
    assert lowest_layers(x) == [lo for lo, _ in stages_of(x)]


@given(target=st.integers(2, 20))
@settings(max_examples=30, deadline=None)
def test_merge_preserves_totals(target):
    prof = paper_model_profile("amoebanet-d36", AWS_LAMBDA)
    merged = merge_layers(prof, target)
    assert merged.L <= max(target, 1) + 1
    assert np.isclose(merged.param_bytes, prof.param_bytes)
    a0 = sum(l.act_bytes for l in prof.layers)
    a1 = sum(l.act_bytes for l in merged.layers)
    assert np.isclose(a0, a1)
    for j in range(len(prof.layers[0].fwd_time)):
        f0 = sum(l.fwd_time[j] for l in prof.layers)
        f1 = sum(l.fwd_time[j] for l in merged.layers)
        assert np.isclose(f0, f1)


def test_merge_balances_compute():
    prof = paper_model_profile("amoebanet-d36", AWS_LAMBDA)
    merged = merge_layers(prof, 8, criterion="compute")
    w = [np.mean(l.fwd_time) + np.mean(l.bwd_time) for l in merged.layers]
    assert max(w) / (sum(w) / len(w)) < 3.0  # no monster super-layer
