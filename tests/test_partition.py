"""hat/tilde operators (paper eq (4)) and layer-merging invariants."""
import numpy as np
from _hypo import given, settings, st

from repro.core.partition import (
    hat,
    highest_layers,
    lowest_layers,
    merge_boundaries,
    merge_layers,
    segment_sum_table,
    segment_sum_table_rev,
    stages_of,
    tilde,
)
from repro.core.profiler import paper_model_profile
from repro.serverless.platform import AWS_LAMBDA


@given(
    u=st.lists(st.floats(0.0, 100.0), min_size=2, max_size=12),
    data=st.data(),
)
@settings(max_examples=200, deadline=None)
def test_hat_tilde_partition_sums(u, data):
    L = len(u)
    x = data.draw(st.lists(st.integers(0, 1), min_size=L - 1, max_size=L - 1))
    u = np.array(u)
    h = hat(u, np.array(x))
    t = tilde(u, np.array(x))
    for lo, hi in stages_of(x):
        seg = u[lo:hi + 1].sum()
        assert np.isclose(h[hi], seg)   # hat at highest layer = stage sum
        assert np.isclose(t[lo], seg)   # tilde at lowest layer = stage sum
    assert highest_layers(x) == [hi for _, hi in stages_of(x)]
    assert lowest_layers(x) == [lo for lo, _ in stages_of(x)]


@given(target=st.integers(2, 20))
@settings(max_examples=30, deadline=None)
def test_merge_preserves_totals(target):
    prof = paper_model_profile("amoebanet-d36", AWS_LAMBDA)
    merged = merge_layers(prof, target)
    assert merged.L <= max(target, 1) + 1
    assert np.isclose(merged.param_bytes, prof.param_bytes)
    a0 = sum(l.act_bytes for l in prof.layers)
    a1 = sum(l.act_bytes for l in merged.layers)
    assert np.isclose(a0, a1)
    for j in range(len(prof.layers[0].fwd_time)):
        f0 = sum(l.fwd_time[j] for l in prof.layers)
        f1 = sum(l.fwd_time[j] for l in merged.layers)
        assert np.isclose(f0, f1)


def test_merge_balances_compute():
    prof = paper_model_profile("amoebanet-d36", AWS_LAMBDA)
    merged = merge_layers(prof, 8, criterion="compute")
    w = [np.mean(l.fwd_time) + np.mean(l.bwd_time) for l in merged.layers]
    assert max(w) / (sum(w) / len(w)) < 3.0  # no monster super-layer


def test_merge_boundaries_nest_across_depths():
    """Hierarchical merging: depth k's boundary set contains depth k-1's for
    every k, so the planner's cut-point space grows monotonically with merge
    depth (the property behind monotone plan quality)."""
    for model in ("bert-large", "amoebanet-d36"):
        prof = paper_model_profile(model, AWS_LAMBDA)
        prev = None
        for target in range(2, prof.L + 1):
            edges = set(merge_boundaries(prof, target))
            assert len(edges) == target + 1
            if prev is not None:
                assert prev <= edges    # refinement: superset of shallower
            prev = edges
        # full depth merges nothing
        assert merge_layers(prof, prof.L) is prof


@given(
    u=st.lists(st.floats(0.0, 100.0), min_size=1, max_size=10),
    data=st.data(),
)
@settings(max_examples=100, deadline=None)
def test_segment_tables_match_hat_tilde_bitwise(u, data):
    """The DP's per-segment sums must agree bit-for-bit with the hat/tilde
    stage reductions the scalar oracle uses (a one-ulp disagreement could
    flip eq (3b) feasibility between engines)."""
    L = len(u)
    x = data.draw(st.lists(st.integers(0, 1), min_size=L - 1, max_size=L - 1))
    u = np.array(u)
    seg_h = segment_sum_table(u)
    seg_t = segment_sum_table_rev(u)
    h = hat(u, np.array(x))
    t = tilde(u, np.array(x))
    for lo, hi in stages_of(x):
        assert seg_h[lo, hi] == h[hi]       # exact, not approx
        assert seg_t[lo, hi] == t[lo]
