"""Co-optimizer correctness: heuristic vs exhaustive, feasibility, and
dominance over the baseline algorithms on the paper's models."""
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core import planner
from repro.core.partition import LayerProfile, ModelProfile, stages_of
from repro.core.profiler import paper_model_profile
from repro.serverless.platform import AWS_LAMBDA, MB


def random_profile(rng, L=5, J=3):
    layers = []
    for i in range(L):
        fwd = tuple(float(rng.uniform(0.05, 2.0) / (j + 1)) for j in range(J))
        layers.append(LayerProfile(
            name=f"l{i}",
            param_bytes=float(rng.uniform(5, 200)) * MB,
            act_bytes=float(rng.uniform(5, 150)) * MB,
            out_bytes=float(rng.uniform(1, 50)) * MB,
            grad_out_bytes=float(rng.uniform(1, 50)) * MB,
            fwd_time=fwd,
            bwd_time=tuple(2 * t for t in fwd),
        ))
    return ModelProfile(name="rand", layers=tuple(layers))


import dataclasses

SMALL = dataclasses.replace(
    AWS_LAMBDA,
    memory_options=AWS_LAMBDA.memory_options[3:6],  # J=3 for exhaustive
)


@given(seed=st.integers(0, 50))
@settings(max_examples=12, deadline=None)
def test_cd_matches_exhaustive_small(seed):
    """Coordinate descent finds the exhaustive optimum on small instances."""
    rng = np.random.default_rng(seed)
    prof = random_profile(rng, L=4, J=3)
    kw = dict(alpha=(1.0, 1e-4), total_micro_batches=8,
              d_options=(1, 2, 4), merge_to=4)
    a = planner.solve(prof, SMALL, method="cd", **kw)
    b = planner.solve(prof, SMALL, method="exhaustive", **kw)
    if a is None or b is None:
        assert a is None and b is None
        return
    assert a.objective <= b.objective * 1.02 + 1e-12


# --------------------------------------------- batched engine == seed scalar
def _assert_same_plan(a, b):
    assert (a is None) == (b is None)
    if a is not None:
        assert a.config == b.config
        assert a.objective == b.objective
        assert a.evaluation == b.evaluation


@given(seed=st.integers(0, 60))
@settings(max_examples=10, deadline=None)
def test_batch_engine_parity_random(seed):
    """The vectorized solve returns the identical plan as the seed scalar
    solver — both methods — on random small instances."""
    rng = np.random.default_rng(seed)
    prof = random_profile(rng, L=4, J=3)
    kw = dict(alpha=(1.0, 1e-4), total_micro_batches=8,
              d_options=(1, 2, 4), merge_to=4)
    for method in ("cd", "exhaustive"):
        _assert_same_plan(
            planner.solve(prof, SMALL, method=method, engine="scalar", **kw),
            planner.solve(prof, SMALL, method=method, engine="batch", **kw))


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("method", ["cd", "exhaustive"])
def test_batch_engine_parity_seeded(seed, method):
    """Deterministic subset of the parity property (no hypothesis needed)."""
    rng = np.random.default_rng(seed + 100)
    prof = random_profile(rng, L=4, J=3)
    kw = dict(alpha=(1.0, 1e-4), total_micro_batches=8,
              d_options=(1, 2, 4), merge_to=4)
    _assert_same_plan(
        planner.solve(prof, SMALL, method=method, engine="scalar", **kw),
        planner.solve(prof, SMALL, method=method, engine="batch", **kw))


@pytest.mark.parametrize("alpha", [(1.0, 0.0), (1.0, 2**19 * 1e-9)])
def test_batch_engine_parity_paper_model(alpha):
    """Parity on a real profile at the seed's working depth."""
    prof = paper_model_profile("amoebanet-d18", AWS_LAMBDA)
    kw = dict(alpha=alpha, total_micro_batches=16, merge_to=8)
    _assert_same_plan(planner.solve(prof, AWS_LAMBDA, engine="scalar", **kw),
                      planner.solve(prof, AWS_LAMBDA, engine="batch", **kw))


@pytest.mark.parametrize("seed", range(6))
def test_cd_steepest_parity_batch_scalar(seed):
    """method='cd-steepest': the lockstep batch twin follows the scalar
    steepest rule exactly (same moves, same tie-breaks) — identical plans."""
    rng = np.random.default_rng(seed + 300)
    prof = random_profile(rng, L=4, J=3)
    kw = dict(alpha=(1.0, 1e-4), total_micro_batches=8,
              d_options=(1, 2, 4), merge_to=4, method="cd-steepest")
    _assert_same_plan(
        planner.solve(prof, SMALL, engine="scalar", **kw),
        planner.solve(prof, SMALL, engine="batch", **kw))


@pytest.mark.parametrize("seed", range(8))
def test_cd_steepest_never_worse_than_first(seed):
    """Parity pin vs the first-improvement rule on random instances: same
    multi-start set and move budget, never a worse final objective."""
    rng = np.random.default_rng(seed)
    prof = random_profile(rng, L=5, J=3)
    kw = dict(alpha=(1.0, 2**16 * 1e-9), total_micro_batches=16,
              d_options=(1, 2, 4), merge_to=None)
    first = planner.solve(prof, SMALL, method="cd", engine="batch", **kw)
    steep = planner.solve(prof, SMALL, method="cd-steepest", engine="batch",
                          **kw)
    assert (first is None) == (steep is None)
    if first is not None:
        assert steep.objective <= first.objective * (1 + 1e-12)


def test_cd_steepest_paper_model_matches_exhaustive_quality():
    """On a real profile, steepest lands on the same optimum as the
    first-improvement multi-start CD (both verified against exhaustive
    elsewhere at this depth)."""
    prof = paper_model_profile("amoebanet-d18", AWS_LAMBDA)
    kw = dict(alpha=(1.0, 2**19 * 1e-9), total_micro_batches=16, merge_to=8)
    first = planner.solve(prof, AWS_LAMBDA, method="cd", **kw)
    steep = planner.solve(prof, AWS_LAMBDA, method="cd-steepest", **kw)
    assert steep.objective <= first.objective * (1 + 1e-12)


def test_solve_rejects_unknown_method():
    prof = paper_model_profile("bert-large", AWS_LAMBDA)
    with pytest.raises(ValueError, match="unknown method"):
        planner.solve(prof, AWS_LAMBDA, alpha=(1.0, 0.0),
                      total_micro_batches=8, method="cd-steepest-typo")


def test_tpdmp_engine_parity():
    prof = paper_model_profile("bert-large", AWS_LAMBDA)
    kw = dict(alpha=(1.0, 2**19 * 1e-9), total_micro_batches=16, merge_to=8)
    _assert_same_plan(planner.tpdmp_solve(prof, AWS_LAMBDA, engine="scalar", **kw),
                      planner.tpdmp_solve(prof, AWS_LAMBDA, engine="batch", **kw))


def test_deep_merge_solves_fast_and_matches_quality():
    """Deep search is the dp engine's regime: merge_to=16 and full depth
    (L=26, 2^25 partitions per d — hopeless for the enumeration engines)
    complete in well under a minute, and — because the hierarchical merge
    boundaries nest and the DP is exact — quality is *monotone* in depth,
    not merely within an alignment tolerance."""
    prof = paper_model_profile("bert-large", AWS_LAMBDA)
    kw = dict(alpha=(1.0, 2**19 * 1e-9), total_micro_batches=16)
    shallow = planner.solve(prof, AWS_LAMBDA, merge_to=8, **kw)
    deep = planner.solve(prof, AWS_LAMBDA, merge_to=16, engine="dp", **kw)
    full = planner.solve(prof, AWS_LAMBDA, merge_to=None, engine="dp", **kw)
    assert shallow is not None and deep is not None and full is not None
    assert deep.evaluation.mem_ok and full.evaluation.mem_ok
    assert full.profile.L == prof.L          # genuinely unmerged
    assert deep.solve_seconds < 60.0
    assert full.solve_seconds < 60.0
    assert deep.objective <= shallow.objective * (1 + 1e-9)
    assert full.objective <= deep.objective * (1 + 1e-9)


# ------------------------------------------------- exact DP cut-point engine
@given(seed=st.integers(0, 120))
@settings(max_examples=14, deadline=None)
def test_dp_matches_exhaustive_random(seed):
    """The DP engine is exact: it returns the exhaustive-enumeration optimum
    (same oracle-scored objective) on random small instances."""
    rng = np.random.default_rng(seed)
    L = int(rng.integers(3, 7))
    prof = random_profile(rng, L=L, J=3)
    kw = dict(alpha=(1.0, 1e-4), total_micro_batches=8,
              d_options=(1, 2, 4), merge_to=L)
    ex = planner.solve(prof, SMALL, method="exhaustive", engine="batch", **kw)
    dp = planner.solve(prof, SMALL, engine="dp", **kw)
    assert (ex is None) == (dp is None)
    if ex is not None:
        assert dp.objective == ex.objective
        assert dp.evaluation.mem_ok


@pytest.mark.parametrize("seed", range(4))
def test_dp_matches_exhaustive_seeded(seed):
    """Deterministic subset of the exactness property (no hypothesis)."""
    rng = np.random.default_rng(seed + 300)
    prof = random_profile(rng, L=6, J=3)
    kw = dict(alpha=(1.0, 1e-4), total_micro_batches=8,
              d_options=(1, 2, 4), merge_to=6)
    ex = planner.solve(prof, SMALL, method="exhaustive", engine="batch", **kw)
    dp = planner.solve(prof, SMALL, engine="dp", **kw)
    assert (ex is None) == (dp is None)
    if ex is not None:
        assert dp.objective == ex.objective


def test_dp_matches_exhaustive_L12():
    """Full-width check at L=12 (2^11 partitions x memory combos), the
    largest instance the exhaustive cross-check still enumerates quickly."""
    import dataclasses as dc

    tiny = dc.replace(AWS_LAMBDA,
                      memory_options=AWS_LAMBDA.memory_options[3:5])  # J=2
    rng = np.random.default_rng(777)
    prof = random_profile(rng, L=12, J=2)
    kw = dict(alpha=(1.0, 1e-4), total_micro_batches=8,
              d_options=(1, 2, 4), merge_to=12)
    ex = planner.solve(prof, tiny, method="exhaustive", engine="batch", **kw)
    dp = planner.solve(prof, tiny, engine="dp", **kw)
    assert ex is not None and dp is not None
    assert dp.objective == ex.objective


def test_dp_respects_max_stages():
    rng = np.random.default_rng(5)
    prof = random_profile(rng, L=6, J=3)
    kw = dict(alpha=(1.0, 1e-4), total_micro_batches=8,
              d_options=(1, 2), merge_to=6, max_stages=2)
    ex = planner.solve(prof, SMALL, method="exhaustive", engine="batch", **kw)
    dp = planner.solve(prof, SMALL, engine="dp", **kw)
    assert (ex is None) == (dp is None)
    if dp is not None:
        assert sum(dp.config.x) + 1 <= 2
        assert dp.objective == ex.objective


@pytest.mark.parametrize("alpha", [(1.0, 0.0), (1.0, 2**19 * 1e-9)])
@pytest.mark.parametrize("model", ["amoebanet-d18", "bert-large"])
def test_dp_never_worse_than_batch(model, alpha):
    """On the paper models the exact DP's objective must be <= the batch
    CD heuristic's at the same depth (equal up to float association when CD
    happens to find the optimum)."""
    prof = paper_model_profile(model, AWS_LAMBDA)
    kw = dict(alpha=alpha, total_micro_batches=16, merge_to=8)
    batch = planner.solve(prof, AWS_LAMBDA, engine="batch", **kw)
    dp = planner.solve(prof, AWS_LAMBDA, engine="dp", **kw)
    assert (batch is None) == (dp is None)
    if batch is not None:
        assert dp.objective <= batch.objective * (1 + 1e-9)


def test_dp_quality_monotone_in_merge_depth():
    """Hierarchical merge boundaries nest, so with an exact solver the
    objective can only improve as the merge depth grows toward full L
    (closes the ROADMAP merge-boundary item)."""
    prof = paper_model_profile("bert-large", AWS_LAMBDA)
    kw = dict(alpha=(1.0, 2**16 * 1e-9), total_micro_batches=16)
    objs = []
    for mt in (6, 10, 14, None):
        r = planner.solve(prof, AWS_LAMBDA, engine="dp", merge_to=mt, **kw)
        assert r is not None and r.evaluation.mem_ok
        objs.append(r.objective)
    for shallow, deep in zip(objs, objs[1:]):
        assert deep <= shallow * (1 + 1e-9)


def test_tpdmp_dp_engine_not_worse():
    """tpdmp's dp engine solves the same fixed-resource grid exactly, so it
    can never report a worse grid point than the enumerating batch engine."""
    prof = paper_model_profile("bert-large", AWS_LAMBDA)
    kw = dict(alpha=(1.0, 2**19 * 1e-9), total_micro_batches=16, merge_to=8)
    batch = planner.tpdmp_solve(prof, AWS_LAMBDA, engine="batch", **kw)
    dp = planner.tpdmp_solve(prof, AWS_LAMBDA, engine="dp", **kw)
    assert (batch is None) == (dp is None)
    if batch is not None:
        assert dp.objective <= batch.objective * (1 + 1e-9)
        assert dp.evaluation.t_iter == pytest.approx(
            batch.evaluation.t_iter, rel=1e-9)


@pytest.mark.parametrize("model", ["resnet101", "amoebanet-d18", "bert-large"])
def test_plans_feasible_and_consistent(model):
    prof = paper_model_profile(model, AWS_LAMBDA)
    r = planner.solve(prof, AWS_LAMBDA, alpha=(1.0, 1e-4), total_micro_batches=16,
                      merge_to=8)
    assert r is not None
    assert r.evaluation.mem_ok
    L = r.profile.L
    assert len(r.config.x) == L - 1
    assert len(r.config.z) == L
    # memory constant within each stage (constraint 3c)
    for lo, hi in stages_of(r.config.x):
        assert len({r.config.z[i] for i in range(lo, hi + 1)}) == 1
    assert 16 % r.config.d == 0


@pytest.mark.parametrize("model", ["amoebanet-d36", "bert-large"])
def test_coopt_beats_baselines(model):
    """§5.6: the co-optimizer's objective is at least as good as TPDMP-style
    (throughput-only) and Bayes-style (random search) on the same model."""
    prof = paper_model_profile(model, AWS_LAMBDA)
    kw = dict(alpha=(1.0, 2**19 * 1e-9), total_micro_batches=16, merge_to=8)
    ours = planner.solve(prof, AWS_LAMBDA, **kw)
    tpdmp = planner.tpdmp_solve(prof, AWS_LAMBDA, **kw)
    bayes = planner.bayes_solve(prof, AWS_LAMBDA, rounds=100, seed=0, **kw)
    assert ours is not None
    for other in (tpdmp, bayes):
        if other is not None:
            assert ours.objective <= other.objective * 1.001


def test_recommendation_rule():
    prof = paper_model_profile("amoebanet-d18", AWS_LAMBDA)
    results = [
        planner.solve(prof, AWS_LAMBDA, alpha=a, total_micro_batches=16, merge_to=8)
        for a in [(1.0, 0.0), (1.0, 2**19 * 1e-9), (1.0, 2**22 * 1e-9)]
    ]
    results = [r for r in results if r is not None]
    rec = planner.recommend(results)
    mc = min(results, key=lambda r: r.evaluation.c_iter)
    # recommended is never slower than the min-cost config
    assert rec.evaluation.t_iter <= mc.evaluation.t_iter + 1e-9
