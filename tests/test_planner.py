"""Co-optimizer correctness: heuristic vs exhaustive, feasibility, and
dominance over the baseline algorithms on the paper's models."""
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core import planner
from repro.core.partition import LayerProfile, ModelProfile, stages_of
from repro.core.profiler import paper_model_profile
from repro.serverless.platform import AWS_LAMBDA, MB


def random_profile(rng, L=5, J=3):
    layers = []
    for i in range(L):
        fwd = tuple(float(rng.uniform(0.05, 2.0) / (j + 1)) for j in range(J))
        layers.append(LayerProfile(
            name=f"l{i}",
            param_bytes=float(rng.uniform(5, 200)) * MB,
            act_bytes=float(rng.uniform(5, 150)) * MB,
            out_bytes=float(rng.uniform(1, 50)) * MB,
            grad_out_bytes=float(rng.uniform(1, 50)) * MB,
            fwd_time=fwd,
            bwd_time=tuple(2 * t for t in fwd),
        ))
    return ModelProfile(name="rand", layers=tuple(layers))


import dataclasses

SMALL = dataclasses.replace(
    AWS_LAMBDA,
    memory_options=AWS_LAMBDA.memory_options[3:6],  # J=3 for exhaustive
)


@given(seed=st.integers(0, 50))
@settings(max_examples=12, deadline=None)
def test_cd_matches_exhaustive_small(seed):
    """Coordinate descent finds the exhaustive optimum on small instances."""
    rng = np.random.default_rng(seed)
    prof = random_profile(rng, L=4, J=3)
    kw = dict(alpha=(1.0, 1e-4), total_micro_batches=8,
              d_options=(1, 2, 4), merge_to=4)
    a = planner.solve(prof, SMALL, method="cd", **kw)
    b = planner.solve(prof, SMALL, method="exhaustive", **kw)
    if a is None or b is None:
        assert a is None and b is None
        return
    assert a.objective <= b.objective * 1.02 + 1e-12


# --------------------------------------------- batched engine == seed scalar
def _assert_same_plan(a, b):
    assert (a is None) == (b is None)
    if a is not None:
        assert a.config == b.config
        assert a.objective == b.objective
        assert a.evaluation == b.evaluation


@given(seed=st.integers(0, 60))
@settings(max_examples=10, deadline=None)
def test_batch_engine_parity_random(seed):
    """The vectorized solve returns the identical plan as the seed scalar
    solver — both methods — on random small instances."""
    rng = np.random.default_rng(seed)
    prof = random_profile(rng, L=4, J=3)
    kw = dict(alpha=(1.0, 1e-4), total_micro_batches=8,
              d_options=(1, 2, 4), merge_to=4)
    for method in ("cd", "exhaustive"):
        _assert_same_plan(
            planner.solve(prof, SMALL, method=method, engine="scalar", **kw),
            planner.solve(prof, SMALL, method=method, engine="batch", **kw))


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("method", ["cd", "exhaustive"])
def test_batch_engine_parity_seeded(seed, method):
    """Deterministic subset of the parity property (no hypothesis needed)."""
    rng = np.random.default_rng(seed + 100)
    prof = random_profile(rng, L=4, J=3)
    kw = dict(alpha=(1.0, 1e-4), total_micro_batches=8,
              d_options=(1, 2, 4), merge_to=4)
    _assert_same_plan(
        planner.solve(prof, SMALL, method=method, engine="scalar", **kw),
        planner.solve(prof, SMALL, method=method, engine="batch", **kw))


@pytest.mark.parametrize("alpha", [(1.0, 0.0), (1.0, 2**19 * 1e-9)])
def test_batch_engine_parity_paper_model(alpha):
    """Parity on a real profile at the seed's working depth."""
    prof = paper_model_profile("amoebanet-d18", AWS_LAMBDA)
    kw = dict(alpha=alpha, total_micro_batches=16, merge_to=8)
    _assert_same_plan(planner.solve(prof, AWS_LAMBDA, engine="scalar", **kw),
                      planner.solve(prof, AWS_LAMBDA, engine="batch", **kw))


def test_tpdmp_engine_parity():
    prof = paper_model_profile("bert-large", AWS_LAMBDA)
    kw = dict(alpha=(1.0, 2**19 * 1e-9), total_micro_batches=16, merge_to=8)
    _assert_same_plan(planner.tpdmp_solve(prof, AWS_LAMBDA, engine="scalar", **kw),
                      planner.tpdmp_solve(prof, AWS_LAMBDA, engine="batch", **kw))


def test_deep_merge_solves_fast_and_matches_quality():
    """The point of the batched engine: merge_to=16 (2^15 partitions per d,
    hopeless for the scalar solver) completes in well under a minute, and its
    plan quality tracks the shallow space.  The greedy merge boundaries of
    different depths don't nest, so the objectives differ by small alignment
    deltas in either direction — assert they stay within 2%."""
    prof = paper_model_profile("bert-large", AWS_LAMBDA)
    kw = dict(alpha=(1.0, 2**19 * 1e-9), total_micro_batches=16)
    shallow = planner.solve(prof, AWS_LAMBDA, merge_to=8, **kw)
    deep = planner.solve(prof, AWS_LAMBDA, merge_to=16, **kw)
    assert shallow is not None and deep is not None
    assert deep.evaluation.mem_ok
    assert deep.solve_seconds < 60.0
    assert deep.objective <= shallow.objective * 1.02


@pytest.mark.parametrize("model", ["resnet101", "amoebanet-d18", "bert-large"])
def test_plans_feasible_and_consistent(model):
    prof = paper_model_profile(model, AWS_LAMBDA)
    r = planner.solve(prof, AWS_LAMBDA, alpha=(1.0, 1e-4), total_micro_batches=16,
                      merge_to=8)
    assert r is not None
    assert r.evaluation.mem_ok
    L = r.profile.L
    assert len(r.config.x) == L - 1
    assert len(r.config.z) == L
    # memory constant within each stage (constraint 3c)
    for lo, hi in stages_of(r.config.x):
        assert len({r.config.z[i] for i in range(lo, hi + 1)}) == 1
    assert 16 % r.config.d == 0


@pytest.mark.parametrize("model", ["amoebanet-d36", "bert-large"])
def test_coopt_beats_baselines(model):
    """§5.6: the co-optimizer's objective is at least as good as TPDMP-style
    (throughput-only) and Bayes-style (random search) on the same model."""
    prof = paper_model_profile(model, AWS_LAMBDA)
    kw = dict(alpha=(1.0, 2**19 * 1e-9), total_micro_batches=16, merge_to=8)
    ours = planner.solve(prof, AWS_LAMBDA, **kw)
    tpdmp = planner.tpdmp_solve(prof, AWS_LAMBDA, **kw)
    bayes = planner.bayes_solve(prof, AWS_LAMBDA, rounds=100, seed=0, **kw)
    assert ours is not None
    for other in (tpdmp, bayes):
        if other is not None:
            assert ours.objective <= other.objective * 1.001


def test_recommendation_rule():
    prof = paper_model_profile("amoebanet-d18", AWS_LAMBDA)
    results = [
        planner.solve(prof, AWS_LAMBDA, alpha=a, total_micro_batches=16, merge_to=8)
        for a in [(1.0, 0.0), (1.0, 2**19 * 1e-9), (1.0, 2**22 * 1e-9)]
    ]
    results = [r for r in results if r is not None]
    rec = planner.recommend(results)
    mc = min(results, key=lambda r: r.evaluation.c_iter)
    # recommended is never slower than the min-cost config
    assert rec.evaluation.t_iter <= mc.evaluation.t_iter + 1e-9
